//! Ablation: Tile Linux migration rate (DESIGN.md §5).
//!
//! The paper attributes much of the static-mapping win to avoided thread
//! migrations. This sweep varies the modelled load-balancer migration
//! probability from 0 (≈ static placement with a randomised initial map)
//! upward, on the Case 1 merge sort. Expected: execution time grows
//! monotonically-ish with migration rate; localised runs suffer *more* per
//! migration (their chunk homing is stranded on the old tile).
//!
//! Run: `cargo bench --bench ablation_migration`
//! Env: TILESIM_SIZE (default 2M), TILESIM_OUT.

use tilesim::harness::SweepTable;
use tilesim::mem::{HashPolicy, MemConfig};
use tilesim::sched::{TileLinuxConfig, TileLinuxScheduler};
use tilesim::sim::{Engine, EngineConfig};
use tilesim::workloads::mergesort::{self, MergesortConfig, Variant};

fn env_u64(name: &str, default: u64) -> u64 {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn run(elems: u64, variant: Variant, policy: HashPolicy, prob: f64) -> (f64, u64) {
    let mut e = Engine::new(EngineConfig::tilepro64(MemConfig {
        hash_policy: policy,
        striping: true,
    }));
    let mut p = mergesort::build(
        &mut e,
        &MergesortConfig {
            elems,
            threads: 64,
            variant,
        },
    );
    let mut sched = TileLinuxScheduler::new(TileLinuxConfig {
        migrate_prob: prob,
        ..Default::default()
    });
    let stats = e.run(&mut p, &mut sched).expect("run");
    (stats.seconds(), stats.migrations)
}

fn main() {
    let elems = env_u64("TILESIM_SIZE", 2_000_000);
    let mut table = SweepTable::new(
        &format!("Ablation: migration probability, merge sort {elems} ints, 64 threads"),
        "migrate_prob",
        vec![
            "case1-like (s)".into(),
            "migrations".into(),
            "localised (s)".into(),
        ],
    );
    for prob in [0.0, 0.1, 0.2, 0.4, 0.8] {
        let (t_nl, migr) = run(elems, Variant::NonLocalised, HashPolicy::AllButStack, prob);
        let (t_loc, _) = run(elems, Variant::Localised, HashPolicy::None, prob);
        table.push_row(format!("{prob:.1}"), vec![t_nl, migr as f64, t_loc]);
    }
    println!("{}", table.render());
    let out = std::env::var("TILESIM_OUT").unwrap_or_else(|_| "bench_results".into());
    table.save(&out, "ablation_migration").expect("save failed");
}
