//! Fig. 2: merge-sort speed-up for all 8 Table 1 cases vs thread count.
//!
//! Paper setup: 100 M integers, striping enabled, speed-up base = Case 1 at
//! one thread. We default to 4 M (the simulator is cycle-approximate, not
//! the silicon; the shape's size dependence is charted by fig3): expected
//! ordering at high thread counts: localised+static (7, 8) on top, then
//! non-localised static/linux under hash (3, 1), with non-localised under
//! local homing (2, 4) collapsing on the tile-0 hot spot.
//!
//! Run: `cargo bench --bench fig2_speedup`
//! Env: TILESIM_SIZE (default 4M), TILESIM_OUT, TILESIM_JOBS.

use tilesim::coordinator::batch::BatchRunner;
use tilesim::coordinator::experiment;

fn env_u64(name: &str, default: u64) -> u64 {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() {
    let elems = env_u64("TILESIM_SIZE", 4_000_000);
    let threads = [1usize, 2, 4, 8, 16, 32, 64];
    let runner = BatchRunner::auto();
    eprintln!("fig2: sweeping on {} worker(s)", runner.jobs());
    let table = runner.table(&experiment::fig2_spec(
        elems,
        &threads,
        experiment::DEFAULT_SEED,
    ));
    println!("{}", table.render());
    if let Some((_, last)) = table.rows.last() {
        println!(
            "at 64 threads: case8 {:.2}x vs case3 {:.2}x vs case2 {:.2}x (paper: 8 ≥ 7 > 3 ≫ 2)",
            last[7], last[2], last[1]
        );
    }
    let out = std::env::var("TILESIM_OUT").unwrap_or_else(|_| "bench_results".into());
    table.save(&out, "fig2").expect("save failed");
}
