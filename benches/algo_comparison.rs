//! Algorithm comparison: merge sort (this paper) vs radix sort (the
//! related-work baseline [3], Morari et al.) under the key cases — how far
//! does the localisation *programming style* carry across algorithms?
//!
//! Expected: merge sort gains substantially from Algorithm 1 under local
//! homing (its accesses are sequential with high chunk reuse); radix's
//! scatter phase is inherently global, so the technique buys it less —
//! which is exactly why [3] resorted to architecture-specific TMC tuning
//! while this paper's pitch is portability for reuse-friendly kernels.
//!
//! Run: `cargo bench --bench algo_comparison`
//! Env: TILESIM_SIZE (default 1M), TILESIM_OUT.

use tilesim::coordinator::{case, experiment};
use tilesim::harness::SweepTable;
use tilesim::sim::Engine;
use tilesim::workloads::radix::{self, RadixConfig};

fn env_u64(name: &str, default: u64) -> u64 {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn run_radix(case_id: u8, elems: u64, threads: usize, seed: u64) -> f64 {
    let c = case(case_id);
    let mut engine = Engine::new(c.engine_config(true));
    let mut program = radix::build(
        &mut engine,
        &RadixConfig {
            elems,
            threads,
            digit_bits: 8,
            localised: c.localised,
        },
    );
    let mut sched = c.mapper.scheduler(seed);
    engine.run(&mut program, sched.as_mut()).expect("radix run").seconds()
}

fn main() {
    let elems = env_u64("TILESIM_SIZE", 1_000_000);
    let threads = 63usize;
    let seed = experiment::DEFAULT_SEED;
    let mut table = SweepTable::new(
        &format!("Merge sort vs radix sort, {elems} ints, {threads} threads (exec time, s)"),
        "case",
        vec!["mergesort".into(), "radix".into()],
    );
    for id in [3u8, 4, 7, 8] {
        let ms = experiment::run_mergesort(&case(id), elems, threads, true, seed).seconds();
        let rs = run_radix(id, elems, threads, seed);
        table.push_row(format!("case{id}"), vec![ms, rs]);
    }
    println!("{}", table.render());
    // Localisation benefit per algorithm (case 4 -> case 8: same static
    // mapping + local homing, only the programming style changes).
    let get = |row: usize, col: usize| table.rows[row].1[col];
    println!(
        "localisation gain (case4/case8): mergesort {:.2}x, radix {:.2}x; \
         radix is the faster algorithm outright (why [3] picked it), and the \
         portable localisation style speeds up both",
        get(1, 0) / get(3, 0),
        get(1, 1) / get(3, 1)
    );
    let out = std::env::var("TILESIM_OUT").unwrap_or_else(|_| "bench_results".into());
    table.save(&out, "algo_comparison").expect("save failed");
}
