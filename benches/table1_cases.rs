//! Table 1: all eight cases at a fixed thread count — execution times and
//! speed-ups vs Case 1, the tabular companion to Fig. 2.
//!
//! Run: `cargo bench --bench table1_cases`
//! Env: TILESIM_SIZE (default 4M), TILESIM_THREADS (default 64),
//!      TILESIM_OUT, TILESIM_JOBS.

use tilesim::coordinator::batch::BatchRunner;
use tilesim::coordinator::experiment;

fn env_u64(name: &str, default: u64) -> u64 {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() {
    let elems = env_u64("TILESIM_SIZE", 4_000_000);
    let threads = env_u64("TILESIM_THREADS", 64) as usize;
    let runner = BatchRunner::auto();
    eprintln!("table1: sweeping on {} worker(s)", runner.jobs());
    let table = runner.table(&experiment::table1_spec(
        elems,
        threads,
        experiment::DEFAULT_SEED,
    ));
    println!("{}", table.render());
    let best = table
        .rows
        .iter()
        .min_by(|a, b| a.1[0].partial_cmp(&b.1[0]).unwrap())
        .map(|(name, _)| name.clone())
        .unwrap_or_default();
    println!("fastest case: {best} (paper: case 8, then 7 and 3)");
    let out = std::env::var("TILESIM_OUT").unwrap_or_else(|_| "bench_results".into());
    table.save(&out, "table1").expect("save failed");
}
