//! Fig. 3: execution time of the best cases across input sizes (§5.2),
//! 64 threads, striping enabled, plus the *intermediate step* series
//! (Case 3 + ext_scr merge without copy-back).
//!
//! Expected shape (the paper's key size claim): while the working set fits
//! the aggregate distributed L3 (64 × 64 KB = 4 MB ⇒ ~1 M ints), hash-based
//! cases are competitive; as the input grows past it, Case 8
//! (localised + local homing) pulls ahead of every hash-for-home style.
//! The intermediate step helps Case 3 but is second-order vs localisation.
//!
//! Run: `cargo bench --bench fig3_datasizes`
//! Env: TILESIM_SIZES (comma list, default 1,2,4,8 M), TILESIM_OUT,
//!      TILESIM_JOBS.

use tilesim::coordinator::batch::BatchRunner;
use tilesim::coordinator::experiment;

fn main() {
    let sizes: Vec<u64> = std::env::var("TILESIM_SIZES")
        .ok()
        .map(|s| {
            s.split(',')
                .map(|x| x.trim().parse().expect("bad TILESIM_SIZES"))
                .collect()
        })
        .unwrap_or_else(|| vec![1_000_000, 2_000_000, 4_000_000, 8_000_000]);
    let runner = BatchRunner::auto();
    eprintln!("fig3: sweeping on {} worker(s)", runner.jobs());
    let table = runner.table(&experiment::fig3_spec(&sizes, 64, experiment::DEFAULT_SEED));
    println!("{}", table.render());
    if let (Some((_, first)), Some((_, last))) = (table.rows.first(), table.rows.last()) {
        println!(
            "case8/case3 time ratio: {:.2} at {} elems -> {:.2} at {} elems (paper: falls with size)",
            first[4] / first[0],
            sizes.first().unwrap(),
            last[4] / last[0],
            sizes.last().unwrap()
        );
    }
    let out = std::env::var("TILESIM_OUT").unwrap_or_else(|_| "bench_results".into());
    table.save(&out, "fig3").expect("save failed");
}
