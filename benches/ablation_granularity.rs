//! Ablation: homing granularity (DESIGN.md §5).
//!
//! The paper argues hash-for-home at *cache-line* granularity is too fine
//! for sequential array computation. This ablation isolates granularity by
//! running the micro-benchmark access pattern with the input homed four
//! ways: line-hashed, page-hashed, stranded on tile 0, and localised
//! (chunk-per-worker). Expected: line-hash ≈ page-hash ≫ tile-0 hot spot,
//! and localisation beating all of them once reuse amortises the copy —
//! i.e. the win comes from *placement on the consumer*, and chunk
//! granularity is what makes that placement possible.
//!
//! Run: `cargo bench --bench ablation_granularity`
//! Env: TILESIM_SIZE (default 1M), TILESIM_REPS (default 16), TILESIM_OUT.

use tilesim::arch::TileId;
use tilesim::coordinator::localise::{build_program, LocaliseConfig, ELEM_BYTES};
use tilesim::harness::SweepTable;
use tilesim::mem::{AllocKind, HashPolicy, Homing, MemConfig, Placement};
use tilesim::sched::StaticMapper;
use tilesim::sim::{Engine, EngineConfig, Loc, TraceBuilder};

fn env_u64(name: &str, default: u64) -> u64 {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

struct Scan {
    passes: u32,
}

impl tilesim::coordinator::ChunkKernel for Scan {
    fn steps(&self) -> u32 {
        self.passes
    }
    fn emit_step(&self, t: &mut TraceBuilder, chunk: Loc, bytes: u64, _i: usize, _s: u32) {
        t.read(chunk, bytes);
    }
}

/// Run the scan with the input explicitly homed via `homing`.
fn run_with_homing(elems: u64, threads: usize, passes: u32, homing: Homing, localised: bool) -> f64 {
    let mut e = Engine::new(EngineConfig::tilepro64(MemConfig {
        hash_policy: HashPolicy::None,
        striping: true,
    }));
    let input = e
        .alloc
        .alloc_with(
            TileId(0),
            elems * ELEM_BYTES,
            AllocKind::Heap,
            homing,
            Placement::Striped,
        )
        .expect("alloc");
    let mut p = build_program(
        &input,
        elems,
        &LocaliseConfig { threads, localised },
        std::rc::Rc::new(Scan { passes }),
    );
    e.run(&mut p, &mut StaticMapper::new()).expect("run").seconds()
}

fn main() {
    let elems = env_u64("TILESIM_SIZE", 1_000_000);
    let passes = env_u64("TILESIM_REPS", 16) as u32;
    let threads = 63;
    let mut table = SweepTable::new(
        &format!("Ablation: homing granularity, {elems} ints, {threads} threads (exec time, s)"),
        "passes",
        vec![
            "line-hash".into(),
            "page-hash".into(),
            "tile0-home".into(),
            "localised".into(),
        ],
    );
    for p in [1u32, passes / 2, passes] {
        let p = p.max(1);
        table.push_row(
            p.to_string(),
            vec![
                run_with_homing(elems, threads, p, Homing::HashForHome, false),
                run_with_homing(elems, threads, p, Homing::PageHash, false),
                run_with_homing(elems, threads, p, Homing::Single(TileId(0)), false),
                run_with_homing(elems, threads, p, Homing::Single(TileId(0)), true),
            ],
        );
    }
    println!("{}", table.render());
    let out = std::env::var("TILESIM_OUT").unwrap_or_else(|_| "bench_results".into());
    table.save(&out, "ablation_granularity").expect("save failed");
}
