//! §Perf: wall-clock throughput of the serve front-end itself — how fast
//! the discrete-event driver pushes simulated requests through the chip,
//! and how the scenario grid scales over the batch worker pool
//! (BENCH_serve.json).
//!
//! This measures *our* implementation, not the simulated machine: the
//! interesting ratios are simulated-requests-per-wall-second (the event
//! loop + memoised service replays) and the pool speedup at grid scale,
//! plus the memoisation amortisation (requests served per engine replay —
//! the bound that keeps a million-request scenario affordable).
//!
//! Run: `cargo bench --bench perf_serve`
//! Env: TILESIM_SERVE_SIZE (default 16384 ints/request),
//!      TILESIM_SERVE_REQUESTS (default 400),
//!      TILESIM_BENCH_SERVE_OUT (default BENCH_serve.json).

use tilesim::arch::{MachineSpec, PartitionSpec};
use tilesim::coherence::ProtocolSpec;
use tilesim::coordinator::batch::{BatchRunner, RunSpec};
use tilesim::coordinator::experiment;
use tilesim::harness::time_it;
use tilesim::serve::{Admission, ArrivalSpec, BatchPolicy, ServeScenario, ServeSweep, SizeMix};
use tilesim::util::json::Json;

fn env_u64(name: &str, default: u64) -> u64 {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() {
    let elems = env_u64("TILESIM_SERVE_SIZE", 1 << 14);
    let requests = env_u64("TILESIM_SERVE_REQUESTS", 400);
    let template = experiment::serve_template(8, elems, 16, experiment::DEFAULT_SEED);

    // --- one scenario, immediate policy: the event-loop + service-replay
    // cost of a single ladder rung near saturation.
    let rung = ServeScenario::new(
        template.clone(),
        ArrivalSpec::Poisson,
        1.0,
        requests,
        1 << 16,
        BatchPolicy::Immediate,
    );
    let r = rung.simulate(1);
    assert_eq!(r.completed + r.dropped, requests, "serve bench sanity");
    let t_rung = time_it(1, 3, || {
        std::hint::black_box(rung.simulate(1).makespan_cycles);
    });
    println!("{}", t_rung.summary("serve: one rung, immediate, rho=1"));
    println!(
        "serve driver: {:.1} k requests/s simulated ({} requests, {} engine replays)",
        requests as f64 / t_rung.min_s / 1e3,
        requests,
        r.batches
    );

    // --- same rung with batching: memoisation means the replay count is
    // bounded by the batch cap, so requests-per-replay is the amortisation
    // this record tracks.
    let mut batched = rung.clone();
    batched.policy = BatchPolicy::Batch { max: 8, wait: 0 };
    batched.rho = 2.0;
    let rb = batched.simulate(1);
    let t_batched = time_it(1, 3, || {
        std::hint::black_box(batched.simulate(1).makespan_cycles);
    });
    println!("{}", t_batched.summary("serve: one rung, batch8, rho=2"));
    println!(
        "serve batching: {:.1} k requests/s simulated, {:.1} requests/dispatch",
        requests as f64 / t_batched.min_s / 1e3,
        rb.completed as f64 / rb.batches.max(1) as f64
    );

    // --- spatial multi-server scaling: the partitioned dispatcher vs the
    // whole chip under overload. The ratios are *simulated* completed
    // req/s (the capacity claim), plus the wall cost of the partitioned
    // event loop itself. At rho=2 a 4-way split is arrival-bound — its
    // ratio tracks the 2x offered rate from below; at rho=4 both sides
    // are capacity-bound and the >= 2x capacity ratio shows directly.
    let partitioned = |spec: &str, rho: f64| {
        ServeScenario::new(
            template.clone(),
            ArrivalSpec::Poisson,
            rho,
            requests,
            1 << 16,
            BatchPolicy::Immediate,
        )
        .with_partitions(PartitionSpec::parse(spec).expect("valid partition spec"))
    };
    let quad_rung = partitioned("4", 2.0);
    let whole2 = partitioned("whole", 2.0).simulate(1);
    let half2 = partitioned("2", 2.0).simulate(1);
    let quad2 = quad_rung.simulate(1);
    let whole4 = partitioned("whole", 4.0).simulate(1);
    let quad4 = partitioned("4", 4.0).simulate(1);
    let t_quad = time_it(1, 3, || {
        std::hint::black_box(quad_rung.simulate(1).makespan_cycles);
    });
    println!("{}", t_quad.summary("serve: one rung, 4 partitions, immediate, rho=2"));
    println!(
        "serve partitions: completed req/s vs whole chip — 2-way {:.2}x and 4-way {:.2}x \
         at rho=2 (arrival-bound), 4-way {:.2}x at rho=4 (capacity-bound)",
        half2.completed_rps / whole2.completed_rps,
        quad2.completed_rps / whole2.completed_rps,
        quad4.completed_rps / whole4.completed_rps
    );

    // --- the default `repro batch serve` grid over the pool: 1 job vs all
    // cores. Scenario count = ladders x rungs; the pool shards scenarios,
    // so this is the grid-scale number the serve PRs move.
    let sweep = ServeSweep::grid(
        &template,
        &[MachineSpec::TilePro64],
        &[ProtocolSpec::default()],
        &experiment::serve_policies(),
        ArrivalSpec::Poisson,
        &experiment::serve_rhos(),
        requests,
        1 << 16,
        false,
        &PartitionSpec::Whole,
        Admission::Fifo,
        &SizeMix::single(elems),
    );
    let n = sweep.scenarios.len();
    let t_serial = time_it(0, 2, || {
        std::hint::black_box(sweep.run(&BatchRunner::new(1)).len());
    });
    let pool = BatchRunner::new(0);
    let t_pool = time_it(0, 2, || {
        std::hint::black_box(sweep.run(&pool).len());
    });
    let pool_speedup = t_serial.min_s / t_pool.min_s;
    println!("{}", t_serial.summary("serve: default grid, 1 job"));
    println!(
        "{}",
        t_pool.summary(&format!("serve: default grid, {} jobs", pool.jobs()))
    );
    println!(
        "serve grid: {n} scenarios/sweep, {:.2}x speedup on {} workers, \
         {:.1} k simulated requests/s at pool width",
        pool_speedup,
        pool.jobs(),
        n as u64 as f64 * requests as f64 / t_pool.min_s / 1e3
    );

    let bench_json = Json::obj(vec![
        ("bench", Json::str("serve_front_end_throughput")),
        ("workload", Json::str("mergesort case 8 per request, tilepro64")),
        ("elems_per_request", Json::num(elems as f64)),
        ("requests", Json::num(requests as f64)),
        ("rung_min_s", Json::num(t_rung.min_s)),
        (
            "rung_requests_per_sec",
            Json::num(requests as f64 / t_rung.min_s),
        ),
        ("rung_engine_replays", Json::num(r.batches as f64)),
        ("batched_min_s", Json::num(t_batched.min_s)),
        (
            "batched_requests_per_sec",
            Json::num(requests as f64 / t_batched.min_s),
        ),
        (
            "batched_requests_per_dispatch",
            Json::num(rb.completed as f64 / rb.batches.max(1) as f64),
        ),
        ("partition_rung_min_s", Json::num(t_quad.min_s)),
        (
            "partition_requests_per_sec",
            Json::num(requests as f64 / t_quad.min_s),
        ),
        (
            "partition_ratio_2way_rho2",
            Json::num(half2.completed_rps / whole2.completed_rps),
        ),
        (
            "partition_ratio_4way_rho2",
            Json::num(quad2.completed_rps / whole2.completed_rps),
        ),
        (
            "partition_ratio_4way_rho4",
            Json::num(quad4.completed_rps / whole4.completed_rps),
        ),
        ("grid_scenarios", Json::num(n as f64)),
        ("grid_serial_min_s", Json::num(t_serial.min_s)),
        ("grid_pool_min_s", Json::num(t_pool.min_s)),
        ("grid_pool_jobs", Json::num(pool.jobs() as f64)),
        ("grid_pool_speedup", Json::num(pool_speedup)),
    ]);
    let path = std::env::var("TILESIM_BENCH_SERVE_OUT")
        .unwrap_or_else(|_| "BENCH_serve.json".into());
    std::fs::write(&path, bench_json.encode()).expect("write BENCH_serve.json");
    println!("wrote {path}");
}
