//! §Perf: wall-clock throughput of the serve front-end itself — how fast
//! the discrete-event driver pushes simulated requests through the chip,
//! and how the scenario grid scales over the batch worker pool
//! (BENCH_serve.json).
//!
//! This measures *our* implementation, not the simulated machine: the
//! interesting ratios are simulated-requests-per-wall-second (the event
//! loop + memoised service replays) and the pool speedup at grid scale,
//! plus the memoisation amortisation (requests served per engine replay —
//! the bound that keeps a million-request scenario affordable).
//!
//! Run: `cargo bench --bench perf_serve`
//! Env: TILESIM_SERVE_SIZE (default 16384 ints/request),
//!      TILESIM_SERVE_REQUESTS (default 400),
//!      TILESIM_BENCH_SERVE_OUT (default BENCH_serve.json).

use tilesim::arch::MachineSpec;
use tilesim::coherence::ProtocolSpec;
use tilesim::coordinator::batch::{BatchRunner, RunSpec};
use tilesim::coordinator::experiment;
use tilesim::harness::time_it;
use tilesim::serve::{ArrivalSpec, BatchPolicy, ServeScenario, ServeSweep};
use tilesim::util::json::Json;

fn env_u64(name: &str, default: u64) -> u64 {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() {
    let elems = env_u64("TILESIM_SERVE_SIZE", 1 << 14);
    let requests = env_u64("TILESIM_SERVE_REQUESTS", 400);
    let template = experiment::serve_template(8, elems, 16, experiment::DEFAULT_SEED);

    // --- one scenario, immediate policy: the event-loop + service-replay
    // cost of a single ladder rung near saturation.
    let rung = ServeScenario {
        run: template.clone(),
        arrival: ArrivalSpec::Poisson,
        rho: 1.0,
        requests,
        queue_cap: 1 << 16,
        policy: BatchPolicy::Immediate,
    };
    let r = rung.simulate(1);
    assert_eq!(r.completed + r.dropped, requests, "serve bench sanity");
    let t_rung = time_it(1, 3, || {
        std::hint::black_box(rung.simulate(1).makespan_cycles);
    });
    println!("{}", t_rung.summary("serve: one rung, immediate, rho=1"));
    println!(
        "serve driver: {:.1} k requests/s simulated ({} requests, {} engine replays)",
        requests as f64 / t_rung.min_s / 1e3,
        requests,
        r.batches
    );

    // --- same rung with batching: memoisation means the replay count is
    // bounded by the batch cap, so requests-per-replay is the amortisation
    // this record tracks.
    let mut batched = rung.clone();
    batched.policy = BatchPolicy::Batch { max: 8, wait: 0 };
    batched.rho = 2.0;
    let rb = batched.simulate(1);
    let t_batched = time_it(1, 3, || {
        std::hint::black_box(batched.simulate(1).makespan_cycles);
    });
    println!("{}", t_batched.summary("serve: one rung, batch8, rho=2"));
    println!(
        "serve batching: {:.1} k requests/s simulated, {:.1} requests/dispatch",
        requests as f64 / t_batched.min_s / 1e3,
        rb.completed as f64 / rb.batches.max(1) as f64
    );

    // --- the default `repro batch serve` grid over the pool: 1 job vs all
    // cores. Scenario count = ladders x rungs; the pool shards scenarios,
    // so this is the grid-scale number the serve PRs move.
    let sweep = ServeSweep::grid(
        &template,
        &[MachineSpec::TilePro64],
        &[ProtocolSpec::default()],
        &experiment::serve_policies(),
        ArrivalSpec::Poisson,
        &experiment::serve_rhos(),
        requests,
        1 << 16,
        false,
    );
    let n = sweep.scenarios.len();
    let t_serial = time_it(0, 2, || {
        std::hint::black_box(sweep.run(&BatchRunner::new(1)).len());
    });
    let pool = BatchRunner::new(0);
    let t_pool = time_it(0, 2, || {
        std::hint::black_box(sweep.run(&pool).len());
    });
    let pool_speedup = t_serial.min_s / t_pool.min_s;
    println!("{}", t_serial.summary("serve: default grid, 1 job"));
    println!(
        "{}",
        t_pool.summary(&format!("serve: default grid, {} jobs", pool.jobs()))
    );
    println!(
        "serve grid: {n} scenarios/sweep, {:.2}x speedup on {} workers, \
         {:.1} k simulated requests/s at pool width",
        pool_speedup,
        pool.jobs(),
        n as u64 as f64 * requests as f64 / t_pool.min_s / 1e3
    );

    let bench_json = Json::obj(vec![
        ("bench", Json::str("serve_front_end_throughput")),
        ("workload", Json::str("mergesort case 8 per request, tilepro64")),
        ("elems_per_request", Json::num(elems as f64)),
        ("requests", Json::num(requests as f64)),
        ("rung_min_s", Json::num(t_rung.min_s)),
        (
            "rung_requests_per_sec",
            Json::num(requests as f64 / t_rung.min_s),
        ),
        ("rung_engine_replays", Json::num(r.batches as f64)),
        ("batched_min_s", Json::num(t_batched.min_s)),
        (
            "batched_requests_per_sec",
            Json::num(requests as f64 / t_batched.min_s),
        ),
        (
            "batched_requests_per_dispatch",
            Json::num(rb.completed as f64 / rb.batches.max(1) as f64),
        ),
        ("grid_scenarios", Json::num(n as f64)),
        ("grid_serial_min_s", Json::num(t_serial.min_s)),
        ("grid_pool_min_s", Json::num(t_pool.min_s)),
        ("grid_pool_jobs", Json::num(pool.jobs() as f64)),
        ("grid_pool_speedup", Json::num(pool_speedup)),
    ]);
    let path = std::env::var("TILESIM_BENCH_SERVE_OUT")
        .unwrap_or_else(|_| "BENCH_serve.json".into());
    std::fs::write(&path, bench_json.encode()).expect("write BENCH_serve.json");
    println!("wrote {path}");
}
