//! Fig. 1: micro-benchmark execution time vs repetition count.
//!
//! Paper setup: 1 M integers over 63 threads on the TILEPro64 @ 860 MHz;
//! *localised* (static mapping + `ucache_hash=none`) vs *non-localised*
//! (Tile Linux default mapping + hash-for-home). Expected shape: the
//! localised line is flatter — its marginal cost per repetition is a local
//! L2 pass — so the gap widens as repetitions grow; at 1 repetition the
//! copy is not amortised and non-localised wins.
//!
//! Run: `cargo bench --bench fig1_microbench`
//! Env: TILESIM_SIZE (elements, default 1M), TILESIM_OUT (json dir),
//!      TILESIM_JOBS (worker threads, default: all cores).

use tilesim::coordinator::batch::BatchRunner;
use tilesim::coordinator::experiment;

fn env_u64(name: &str, default: u64) -> u64 {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() {
    let elems = env_u64("TILESIM_SIZE", 1_000_000);
    let reps = [1u32, 2, 4, 8, 16, 32, 64];
    let runner = BatchRunner::auto();
    eprintln!("fig1: sweeping on {} worker(s)", runner.jobs());
    let table = runner.table(&experiment::fig1_spec(
        elems,
        63,
        &reps,
        experiment::DEFAULT_SEED,
    ));
    println!("{}", table.render());
    let ratio_last = table.rows.last().map(|(_, v)| v[0] / v[1]).unwrap_or(0.0);
    println!(
        "non-localised / localised at {} reps: {:.2}x (paper: grows with repetitions)",
        reps.last().unwrap(),
        ratio_last
    );
    let out = std::env::var("TILESIM_OUT").unwrap_or_else(|_| "bench_results".into());
    table.save(&out, "fig1").expect("save failed");
}
