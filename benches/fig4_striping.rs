//! Fig. 4: influence of memory striping under static mapping (§5.3).
//!
//! Expected shape: moving 16→32 threads, striping helps (ordered static
//! mapping parks threads 0–31 on the top half of the chip, which only
//! reaches 2 of the 4 controllers without striping); at 64 threads all
//! controllers are used either way and the effect shrinks or reverses.
//! With caching on, striping is mostly transparent overall — the paper's
//! closing point.
//!
//! Run: `cargo bench --bench fig4_striping`
//! Env: TILESIM_SIZE (default 2M), TILESIM_OUT, TILESIM_JOBS.

use tilesim::coordinator::batch::BatchRunner;
use tilesim::coordinator::experiment;

fn env_u64(name: &str, default: u64) -> u64 {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() {
    let elems = env_u64("TILESIM_SIZE", 2_000_000);
    let threads = [16usize, 32, 64];
    let runner = BatchRunner::auto();
    eprintln!("fig4: sweeping on {} worker(s)", runner.jobs());
    let table = runner.table(&experiment::fig4_spec(elems, &threads, experiment::DEFAULT_SEED));
    println!("{}", table.render());
    // Striping benefit at 32 threads for the DRAM-bound case 8.
    if table.rows.len() >= 2 {
        let row32 = &table.rows[1].1;
        println!(
            "case8 at 32 threads: striped {:.4}s vs non-striped {:.4}s (paper: striping helps here)",
            row32[2], row32[3]
        );
    }
    let out = std::env::var("TILESIM_OUT").unwrap_or_else(|_| "bench_results".into());
    table.save(&out, "fig4").expect("save failed");

    // The paper's closing observation: with caches OFF the striping effect
    // is "much more observable". Smaller input — every access is DRAM.
    let off = runner.table(&experiment::fig4_cache_off_spec(
        elems / 8,
        &threads,
        experiment::DEFAULT_SEED,
    ));
    println!("{}", off.render());
    off.save(&out, "fig4_cache_off").expect("save failed");
}
