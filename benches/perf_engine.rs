//! §Perf: wall-clock throughput of the simulator itself (line events per
//! second) and of the PJRT request path (keys sorted per second).
//!
//! This is the harness used for the EXPERIMENTS.md §Perf iteration log —
//! it measures *our* implementation, not the simulated machine.
//!
//! Run: `cargo bench --bench perf_engine`
//! Env: TILESIM_SIZE (default 2M), TILESIM_SKIP_PJRT=1 to skip the sorter.

use std::time::Instant;

use tilesim::coordinator::{case, experiment};
use tilesim::harness::time_it;

fn env_u64(name: &str, default: u64) -> u64 {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() {
    let elems = env_u64("TILESIM_SIZE", 2_000_000);

    // --- L3 engine throughput on the fig2 workhorse (case 8, 64 threads).
    let c8 = case(8);
    let stats = experiment::run_mergesort(&c8, elems, 64, true, experiment::DEFAULT_SEED);
    let events = stats.line_accesses;
    let t = time_it(1, 3, || {
        let s = experiment::run_mergesort(&c8, elems, 64, true, experiment::DEFAULT_SEED);
        std::hint::black_box(s.makespan_cycles);
    });
    println!("{}", t.summary("engine: mergesort case8 64t"));
    println!(
        "engine throughput: {:.1} M line-events/s ({} events/run)",
        events as f64 / t.min_s / 1e6,
        events
    );

    // --- also the disaster case (hot-spot path stresses the directory).
    let c2 = case(2);
    let stats2 = experiment::run_mergesort(&c2, elems, 64, true, experiment::DEFAULT_SEED);
    let t2 = time_it(0, 2, || {
        let s = experiment::run_mergesort(&c2, elems, 64, true, experiment::DEFAULT_SEED);
        std::hint::black_box(s.makespan_cycles);
    });
    println!("{}", t2.summary("engine: mergesort case2 64t"));
    println!(
        "engine throughput: {:.1} M line-events/s ({} events/run)",
        stats2.line_accesses as f64 / t2.min_s / 1e6,
        stats2.line_accesses
    );

    // --- request path: PJRT chunked sorter throughput.
    if std::env::var("TILESIM_SKIP_PJRT").is_err() {
        let dir = tilesim::runtime::artifacts_dir();
        match tilesim::runtime::ArtifactSet::load(&dir) {
            Ok(set) => {
                let sorter = tilesim::runtime::ChunkedSorter::new(&set).expect("sorter");
                let mut rng = tilesim::util::rng::Rng::new(7);
                let data = rng.i32_vec(tilesim::runtime::BATCH);
                // Warm + measure single-batch dispatch latency.
                let _ = sorter.sort_batch(&data).expect("sort");
                let t0 = Instant::now();
                let iters = 5;
                for _ in 0..iters {
                    std::hint::black_box(sorter.sort_batch(&data).expect("sort"));
                }
                let per = t0.elapsed().as_secs_f64() / iters as f64;
                println!(
                    "pjrt sorter: {:.2} ms / {} keys = {:.2} M keys/s",
                    per * 1e3,
                    tilesim::runtime::BATCH,
                    tilesim::runtime::BATCH as f64 / per / 1e6
                );
            }
            Err(e) => println!("pjrt sorter: skipped ({e}) — run `make artifacts`"),
        }
    }
}
