//! §Perf: wall-clock throughput of the simulator itself (line events per
//! second), of the batch worker pool (sweep runs per second at 1 vs N
//! jobs — written to BENCH_batch.json so the perf trajectory is recorded
//! per PR), and of the PJRT request path (keys sorted per second).
//!
//! This is the harness used for the EXPERIMENTS.md §Perf iteration log —
//! it measures *our* implementation, not the simulated machine.
//!
//! Run: `cargo bench --bench perf_engine`
//! Env: TILESIM_SIZE (default 2M), TILESIM_SKIP_PJRT=1 to skip the sorter,
//!      TILESIM_BENCH_OUT (default BENCH_batch.json).

use std::time::Instant;

use tilesim::coordinator::batch::BatchRunner;
use tilesim::coordinator::{case, experiment};
use tilesim::harness::time_it;
use tilesim::util::json::Json;

fn env_u64(name: &str, default: u64) -> u64 {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() {
    let elems = env_u64("TILESIM_SIZE", 2_000_000);

    // --- L3 engine throughput on the fig2 workhorse (case 8, 64 threads).
    let c8 = case(8);
    let stats = experiment::run_mergesort(&c8, elems, 64, true, experiment::DEFAULT_SEED);
    let events = stats.line_accesses;
    let t = time_it(1, 3, || {
        let s = experiment::run_mergesort(&c8, elems, 64, true, experiment::DEFAULT_SEED);
        std::hint::black_box(s.makespan_cycles);
    });
    println!("{}", t.summary("engine: mergesort case8 64t"));
    println!(
        "engine throughput: {:.1} M line-events/s ({} events/run)",
        events as f64 / t.min_s / 1e6,
        events
    );

    // --- also the disaster case (hot-spot path stresses the directory).
    let c2 = case(2);
    let stats2 = experiment::run_mergesort(&c2, elems, 64, true, experiment::DEFAULT_SEED);
    let t2 = time_it(0, 2, || {
        let s = experiment::run_mergesort(&c2, elems, 64, true, experiment::DEFAULT_SEED);
        std::hint::black_box(s.makespan_cycles);
    });
    println!("{}", t2.summary("engine: mergesort case2 64t"));
    println!(
        "engine throughput: {:.1} M line-events/s ({} events/run)",
        stats2.line_accesses as f64 / t2.min_s / 1e6,
        stats2.line_accesses
    );

    // --- batch pool: full table1 sweep at 1 job vs all cores. The sweep
    // is the unit of work every figure replays, so this is the number the
    // scaling PRs move; BENCH_batch.json records it per PR.
    let sweep_elems = (elems / 8).max(1 << 14);
    let spec = experiment::table1_spec(sweep_elems, 16, experiment::DEFAULT_SEED);
    let runs = spec.runs.len() + 1; // + baseline
    let t_serial = time_it(0, 2, || {
        std::hint::black_box(BatchRunner::new(1).run(&spec).results.len());
    });
    let pool = BatchRunner::new(0);
    let t_pool = time_it(0, 2, || {
        std::hint::black_box(pool.run(&spec).results.len());
    });
    let speedup = t_serial.min_s / t_pool.min_s;
    println!("{}", t_serial.summary("batch: table1 sweep, 1 job"));
    println!(
        "{}",
        t_pool.summary(&format!("batch: table1 sweep, {} jobs", pool.jobs()))
    );
    println!(
        "batch pool: {runs} runs/sweep, {:.2}x speedup on {} workers",
        speedup,
        pool.jobs()
    );
    let bench_json = Json::obj(vec![
        ("bench", Json::str("batch_table1_sweep")),
        ("elems", Json::num(sweep_elems as f64)),
        ("runs_per_sweep", Json::num(runs as f64)),
        ("jobs", Json::num(pool.jobs() as f64)),
        ("serial_min_s", Json::num(t_serial.min_s)),
        ("serial_mean_s", Json::num(t_serial.mean_s)),
        ("pool_min_s", Json::num(t_pool.min_s)),
        ("pool_mean_s", Json::num(t_pool.mean_s)),
        ("speedup", Json::num(speedup)),
        (
            "runs_per_second",
            Json::num(runs as f64 / t_pool.min_s),
        ),
    ]);
    let bench_path =
        std::env::var("TILESIM_BENCH_OUT").unwrap_or_else(|_| "BENCH_batch.json".into());
    std::fs::write(&bench_path, bench_json.encode()).expect("write BENCH_batch.json");
    println!("wrote {bench_path}");

    // --- request path: PJRT chunked sorter throughput.
    if std::env::var("TILESIM_SKIP_PJRT").is_err() {
        let dir = tilesim::runtime::artifacts_dir();
        match tilesim::runtime::ArtifactSet::load(&dir) {
            Ok(set) => {
                let sorter = tilesim::runtime::ChunkedSorter::new(&set).expect("sorter");
                let mut rng = tilesim::util::rng::Rng::new(7);
                let data = rng.i32_vec(tilesim::runtime::BATCH);
                // Warm + measure single-batch dispatch latency.
                let _ = sorter.sort_batch(&data).expect("sort");
                let t0 = Instant::now();
                let iters = 5;
                for _ in 0..iters {
                    std::hint::black_box(sorter.sort_batch(&data).expect("sort"));
                }
                let per = t0.elapsed().as_secs_f64() / iters as f64;
                println!(
                    "pjrt sorter: {:.2} ms / {} keys = {:.2} M keys/s",
                    per * 1e3,
                    tilesim::runtime::BATCH,
                    tilesim::runtime::BATCH as f64 / per / 1e6
                );
            }
            Err(e) => println!("pjrt sorter: skipped ({e}) — run `make artifacts`"),
        }
    }
}
