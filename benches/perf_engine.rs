//! §Perf: wall-clock throughput of the simulator itself (line events per
//! second), of the streaming replay pipeline (page-run fast path vs the
//! per-line reference walk, written to BENCH_engine.json), of the batch
//! worker pool (sweep runs per second at 1 vs N jobs — BENCH_batch.json),
//! and of the PJRT request path (keys sorted per second).
//!
//! This is the harness used for the EXPERIMENTS.md §Perf iteration log —
//! it measures *our* implementation, not the simulated machine.
//!
//! Run: `cargo bench --bench perf_engine`
//! Env: TILESIM_SIZE (default 2M), TILESIM_SKIP_PJRT=1 to skip the sorter,
//!      TILESIM_BENCH_OUT (default BENCH_batch.json),
//!      TILESIM_BENCH_ENGINE_OUT (default BENCH_engine.json),
//!      TILESIM_BENCH_NOC_OUT (default BENCH_noc.json),
//!      TILESIM_BENCH_FABRIC_OUT (default BENCH_fabric.json),
//!      TILESIM_BENCH_PROTOCOL_OUT (default BENCH_protocol.json).

use std::rc::Rc;
use std::sync::Arc;
use std::time::Instant;

use tilesim::arch::{FabricSpec, Machine, TileId};
use tilesim::coherence::ProtocolSpec;
use tilesim::coordinator::batch::{BatchRunner, RunSpec};
use tilesim::coordinator::localise::{build_program, LocaliseConfig, ELEM_BYTES};
use tilesim::coordinator::{case, experiment, ChunkKernel};
use tilesim::harness::time_it;
use tilesim::mem::{HashPolicy, MemConfig};
use tilesim::sched::StaticMapper;
use tilesim::sim::{Engine, EngineConfig, Loc, Program, RunStats, TraceBuilder};
use tilesim::util::json::Json;
use tilesim::workloads::microbench::{self, MicrobenchConfig};

fn env_u64(name: &str, default: u64) -> u64 {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// Sequential-access microbench: every thread repeatedly scans its chunk.
/// This is the page-run fast path's home turf (long same-home runs) and
/// the workload the replay-throughput trajectory tracks.
struct Scan {
    passes: u32,
}

impl ChunkKernel for Scan {
    fn steps(&self) -> u32 {
        self.passes
    }
    fn emit_step(&self, t: &mut TraceBuilder, chunk: Loc, bytes: u64, _i: usize, _s: u32) {
        t.read(chunk, bytes);
    }
    fn name(&self) -> &'static str {
        "seq-scan"
    }
}

const SCAN_THREADS: usize = 16;
const SCAN_PASSES: u32 = 8;

/// One scan replay; returns the run stats and the program's resident
/// (streamed) trace bytes after the run.
fn scan_replay(elems: u64, page_runs: bool) -> (RunStats, u64) {
    scan_replay_links(elems, page_runs, false)
}

/// Scan replay with optional per-link mesh contention (the BENCH_noc.json
/// workload: same traffic, link servers on/off).
fn scan_replay_links(elems: u64, page_runs: bool, links: bool) -> (RunStats, u64) {
    let mut cfg = EngineConfig::tilepro64(MemConfig {
        hash_policy: HashPolicy::None,
        striping: true,
    });
    if !page_runs {
        cfg = cfg.without_page_runs();
    }
    cfg.contention.links = links;
    let mut e = Engine::new(cfg);
    let input = e.prealloc_touched(TileId(0), elems * ELEM_BYTES);
    let mut p = build_program(
        &input,
        elems,
        &LocaliseConfig {
            threads: SCAN_THREADS,
            localised: false,
        },
        Rc::new(Scan { passes: SCAN_PASSES }),
    );
    let stats = e.run(&mut p, &mut StaticMapper::new()).expect("scan run");
    let resident = p.resident_trace_bytes();
    (stats, resident)
}

/// Scan replay on a tilepro64-grid machine with a heterogeneous fabric
/// applied, links on: measures the per-link service *table* lookup cost
/// against the uniform links-on path, and records the express-channel
/// effect on link queueing.
fn scan_replay_on_fabric(elems: u64, fabric: &str) -> RunStats {
    let machine = Arc::new(
        Machine::tilepro64()
            .with_fabric(&FabricSpec::parse(fabric).expect("bench fabric spec"))
            .expect("bench fabric applies to an 8x8"),
    );
    let mut e = Engine::new(EngineConfig::for_machine(
        machine,
        MemConfig {
            hash_policy: HashPolicy::None,
            striping: true,
        },
    ));
    let input = e.prealloc_touched(TileId(0), elems * ELEM_BYTES);
    let mut p = build_program(
        &input,
        elems,
        &LocaliseConfig {
            threads: SCAN_THREADS,
            localised: false,
        },
        Rc::new(Scan { passes: SCAN_PASSES }),
    );
    e.run(&mut p, &mut StaticMapper::new()).expect("fabric scan run")
}

/// One non-localised micro-benchmark replay under `protocol`, link and
/// coherence billing on (the protocol lab's configuration). Directory
/// protocols now batch uniform same-page runs through the bulk transition
/// hooks; `page_runs = false` forces the per-line reference walk, so the
/// fast/reference pair is the protocol perf-cliff record
/// (`protocol_fast_path` in BENCH_engine.json).
fn protocol_replay(elems: u64, protocol: ProtocolSpec, page_runs: bool) -> RunStats {
    let mut cfg = EngineConfig::tilepro64(MemConfig {
        hash_policy: HashPolicy::AllButStack,
        striping: true,
    })
    .with_protocol(protocol);
    if !page_runs {
        cfg = cfg.without_page_runs();
    }
    cfg.contention.links = true;
    cfg.contention.coherence = true;
    let mut e = Engine::new(cfg);
    let mut p = microbench::build(
        &mut e,
        &MicrobenchConfig {
            elems,
            threads: SCAN_THREADS,
            reps: 4,
            localised: false,
        },
    );
    e.run(&mut p, &mut StaticMapper::new()).expect("protocol run")
}

fn main() {
    let elems = env_u64("TILESIM_SIZE", 2_000_000);

    // --- L3 engine throughput on the fig2 workhorse (case 8, 64 threads).
    let c8 = case(8);
    let stats = experiment::run_mergesort(&c8, elems, 64, true, experiment::DEFAULT_SEED);
    let events = stats.line_accesses;
    let t = time_it(1, 3, || {
        let s = experiment::run_mergesort(&c8, elems, 64, true, experiment::DEFAULT_SEED);
        std::hint::black_box(s.makespan_cycles);
    });
    println!("{}", t.summary("engine: mergesort case8 64t"));
    println!(
        "engine throughput: {:.1} M line-events/s ({} events/run)",
        events as f64 / t.min_s / 1e6,
        events
    );

    // --- also the disaster case (hot-spot path stresses the directory).
    let c2 = case(2);
    let stats2 = experiment::run_mergesort(&c2, elems, 64, true, experiment::DEFAULT_SEED);
    let t2 = time_it(0, 2, || {
        let s = experiment::run_mergesort(&c2, elems, 64, true, experiment::DEFAULT_SEED);
        std::hint::black_box(s.makespan_cycles);
    });
    println!("{}", t2.summary("engine: mergesort case2 64t"));
    println!(
        "engine throughput: {:.1} M line-events/s ({} events/run)",
        stats2.line_accesses as f64 / t2.min_s / 1e6,
        stats2.line_accesses
    );

    // --- replay throughput: sequential-access microbench through the
    // page-run fast path vs the per-line reference walk, plus peak trace
    // bytes streamed vs recorded. This is the BENCH_engine.json record the
    // streaming-pipeline PRs move.
    let scan_elems = elems / 2;
    let (scan_stats, streamed_peak) = scan_replay(scan_elems, true);
    let scan_lines = scan_stats.line_accesses;
    // Symmetric warmup/iteration counts: the recorded speedup must not be
    // biased by cold-start noise on either side.
    let t_fast = time_it(1, 2, || {
        std::hint::black_box(scan_replay(scan_elems, true).0.makespan_cycles);
    });
    let t_ref = time_it(1, 2, || {
        std::hint::black_box(scan_replay(scan_elems, false).0.makespan_cycles);
    });
    let fast_lps = scan_lines as f64 / t_fast.min_s;
    let ref_lps = scan_lines as f64 / t_ref.min_s;
    let speedup = fast_lps / ref_lps;
    // Recorded (materialised) trace size for the same program.
    let recorded_bytes = {
        let mut e = Engine::new(EngineConfig::tilepro64(MemConfig {
            hash_policy: HashPolicy::None,
            striping: true,
        }));
        let input = e.prealloc_touched(TileId(0), scan_elems * ELEM_BYTES);
        let mut p = build_program(
            &input,
            scan_elems,
            &LocaliseConfig {
                threads: SCAN_THREADS,
                localised: false,
            },
            Rc::new(Scan { passes: SCAN_PASSES }),
        );
        Program::from_ops(p.record(), p.num_slots, p.num_events).resident_trace_bytes()
    };
    println!("{}", t_fast.summary("replay: seq-scan, page-run fast path"));
    println!("{}", t_ref.summary("replay: seq-scan, per-line reference walk"));
    println!(
        "replay throughput: fast {:.1} M lines/s vs reference {:.1} M lines/s = {:.2}x \
         | trace bytes: streamed peak {} vs recorded {}",
        fast_lps / 1e6,
        ref_lps / 1e6,
        speedup,
        streamed_peak,
        recorded_bytes
    );

    // --- link billing before/after: the same fast-path scan with per-link
    // mesh servers billed along every remote route. The allocation-free
    // xy_links walk is what keeps the links-on column close to links-off.
    let (links_stats, _) = scan_replay_links(scan_elems, true, true);
    let t_links = time_it(1, 2, || {
        std::hint::black_box(scan_replay_links(scan_elems, true, true).0.makespan_cycles);
    });
    let links_lps = scan_lines as f64 / t_links.min_s;
    let link_reqs: u64 = links_stats.link_requests.iter().sum();
    println!("{}", t_links.summary("replay: seq-scan, link contention on"));
    println!(
        "link contention: {:.1} M lines/s (links on) vs {:.1} M lines/s (off) = {:.2}x overhead \
         | {} link requests, {:.1} M link-billings/s, {} link-queue cycles",
        links_lps / 1e6,
        fast_lps / 1e6,
        fast_lps / links_lps,
        link_reqs,
        link_reqs as f64 / t_links.min_s / 1e6,
        links_stats.link_queue_cycles
    );

    // --- intra-run parallel engine: the same mergesort case-8 replay,
    // sharded across host workers by the deterministic epoch driver
    // (`--intra-jobs`). Stats are byte-identical at every worker count —
    // asserted here, not assumed — so the only thing that moves is
    // wall-clock; the speedup-vs-1-worker column is the record the
    // intra-run parallelism PRs track (BENCH_engine.json `intra_engine`).
    let intra_spec = RunSpec::mergesort(8, elems, 64, experiment::DEFAULT_SEED);
    let intra_seq_json = intra_spec.execute_intra(1).to_json().encode();
    let mut intra_rows = Vec::new();
    let mut intra_seq_lps = 0.0_f64;
    let mut intra_speedup_4w = 1.0_f64;
    for workers in [1usize, 2, 4, 8] {
        let stats = intra_spec.execute_intra(workers);
        assert_eq!(
            stats.to_json().encode(),
            intra_seq_json,
            "intra-jobs {workers} diverged from the sequential engine"
        );
        let t_w = time_it(0, 2, || {
            std::hint::black_box(intra_spec.execute_intra(workers).makespan_cycles);
        });
        let lps = stats.line_accesses as f64 / t_w.min_s;
        if workers == 1 {
            intra_seq_lps = lps;
        }
        let speedup = lps / intra_seq_lps;
        if workers == 4 {
            intra_speedup_4w = speedup;
        }
        println!(
            "intra-run engine: {workers} worker(s) = {:.1} M lines/s ({:.2}x vs sequential)",
            lps / 1e6,
            speedup
        );
        intra_rows.push(Json::obj(vec![
            ("workers", Json::num(workers as f64)),
            ("min_s", Json::num(t_w.min_s)),
            ("lines_per_sec", Json::num(lps)),
            ("speedup_vs_sequential", Json::num(speedup)),
        ]));
    }

    // Assembled into BENCH_engine.json after the protocol section below
    // contributes its fast-path and intra × protocol rows.
    let mut engine_fields = vec![
        ("bench", Json::str("replay_throughput")),
        ("workload", Json::str("seq-scan microbench")),
        ("elems", Json::num(scan_elems as f64)),
        ("threads", Json::num(SCAN_THREADS as f64)),
        ("passes", Json::num(SCAN_PASSES as f64)),
        ("lines_per_run", Json::num(scan_lines as f64)),
        ("fast_min_s", Json::num(t_fast.min_s)),
        ("fast_lines_per_sec", Json::num(fast_lps)),
        ("reference_min_s", Json::num(t_ref.min_s)),
        ("reference_lines_per_sec", Json::num(ref_lps)),
        ("speedup_vs_per_line_walk", Json::num(speedup)),
        ("links_on_lines_per_sec", Json::num(links_lps)),
        ("link_billing_overhead", Json::num(fast_lps / links_lps)),
        ("streamed_peak_trace_bytes", Json::num(streamed_peak as f64)),
        ("recorded_trace_bytes", Json::num(recorded_bytes as f64)),
        (
            "mergesort_case8_lines_per_sec",
            Json::num(events as f64 / t.min_s),
        ),
        ("intra_engine", Json::arr(intra_rows)),
        ("intra_speedup_4_workers", Json::num(intra_speedup_4w)),
    ];

    // --- BENCH_noc.json: the link-contention throughput record (same
    // numbers as above, in the NoC-focused file the link PRs track).
    let noc_json = Json::obj(vec![
        ("bench", Json::str("link_contention_throughput")),
        ("workload", Json::str("seq-scan microbench, tilepro64")),
        ("elems", Json::num(scan_elems as f64)),
        ("threads", Json::num(SCAN_THREADS as f64)),
        ("lines_per_run", Json::num(scan_lines as f64)),
        ("links_on_min_s", Json::num(t_links.min_s)),
        ("links_on_lines_per_sec", Json::num(links_lps)),
        ("links_off_lines_per_sec", Json::num(fast_lps)),
        ("link_billing_overhead", Json::num(fast_lps / links_lps)),
        ("link_requests_per_run", Json::num(link_reqs as f64)),
        (
            "link_billings_per_sec",
            Json::num(link_reqs as f64 / t_links.min_s),
        ),
        (
            "link_queue_cycles",
            Json::num(links_stats.link_queue_cycles as f64),
        ),
    ]);
    let noc_path =
        std::env::var("TILESIM_BENCH_NOC_OUT").unwrap_or_else(|_| "BENCH_noc.json".into());
    std::fs::write(&noc_path, noc_json.encode()).expect("write BENCH_noc.json");
    println!("wrote {noc_path}");

    // --- BENCH_fabric.json: the same links-on scan with a heterogeneous
    // fabric (express row 0 + column 0 over a 4-cycle base) against a
    // *uniform* base=4 run. Both go through the identical per-link table
    // lookup, so their throughput ratio isolates the heterogeneous
    // queueing dynamics, and the link-queue delta is the express-channel
    // effect; the base=1 links-on number above anchors the trajectory.
    let express = "base=4:express-row=0@0.5:express-col=0@0.5";
    let fabric_stats = scan_replay_on_fabric(scan_elems, express);
    let uniform_stats = scan_replay_on_fabric(scan_elems, "base=4");
    let t_fabric = time_it(1, 2, || {
        std::hint::black_box(scan_replay_on_fabric(scan_elems, express).makespan_cycles);
    });
    let t_uniform4 = time_it(1, 2, || {
        std::hint::black_box(scan_replay_on_fabric(scan_elems, "base=4").makespan_cycles);
    });
    let fabric_lps = scan_lines as f64 / t_fabric.min_s;
    let uniform4_lps = scan_lines as f64 / t_uniform4.min_s;
    println!("{}", t_fabric.summary("replay: seq-scan, express fabric"));
    println!("{}", t_uniform4.summary("replay: seq-scan, uniform base=4 fabric"));
    println!(
        "fabric: {:.1} M lines/s (express) vs {:.1} M lines/s (uniform base=4) = {:.2}x \
         express speedup | link-queue cycles {} (express) vs {} (uniform base=4)",
        fabric_lps / 1e6,
        uniform4_lps / 1e6,
        fabric_lps / uniform4_lps,
        fabric_stats.link_queue_cycles,
        uniform_stats.link_queue_cycles
    );
    let fabric_json = Json::obj(vec![
        ("bench", Json::str("heterogeneous_fabric_throughput")),
        ("workload", Json::str("seq-scan microbench, tilepro64 grid")),
        ("fabric", Json::str(express)),
        ("elems", Json::num(scan_elems as f64)),
        ("threads", Json::num(SCAN_THREADS as f64)),
        ("lines_per_run", Json::num(scan_lines as f64)),
        ("express_min_s", Json::num(t_fabric.min_s)),
        ("express_lines_per_sec", Json::num(fabric_lps)),
        ("uniform_base4_min_s", Json::num(t_uniform4.min_s)),
        ("uniform_base4_lines_per_sec", Json::num(uniform4_lps)),
        ("uniform_base1_lines_per_sec", Json::num(links_lps)),
        (
            "express_speedup_over_uniform",
            Json::num(fabric_lps / uniform4_lps),
        ),
        (
            "express_link_queue_cycles",
            Json::num(fabric_stats.link_queue_cycles as f64),
        ),
        (
            "uniform_base4_link_queue_cycles",
            Json::num(uniform_stats.link_queue_cycles as f64),
        ),
    ]);
    let fabric_path = std::env::var("TILESIM_BENCH_FABRIC_OUT")
        .unwrap_or_else(|_| "BENCH_fabric.json".into());
    std::fs::write(&fabric_path, fabric_json.encode()).expect("write BENCH_fabric.json");
    println!("wrote {fabric_path}");

    // --- BENCH_protocol.json + the engine record's protocol_fast_path
    // rows: per-protocol replay throughput on the same micro-benchmark
    // traffic, links + coherence billing on, through the page-run fast
    // path *and* the per-line reference walk. Stats equality is asserted
    // here (the conformance suite pins it per workload too); the
    // fast/reference ratio is the perf-cliff lift this record tracks.
    let proto_elems = elems / 8;
    let mut proto_rows = Vec::new();
    let mut proto_fast_rows = Vec::new();
    let mut default_lps = 0.0_f64;
    for protocol in ProtocolSpec::all() {
        let stats = protocol_replay(proto_elems, protocol, true);
        assert_eq!(
            stats.to_json().encode(),
            protocol_replay(proto_elems, protocol, false).to_json().encode(),
            "protocol {} fast path diverged from the reference walk",
            protocol.label()
        );
        let t_proto = time_it(0, 2, || {
            std::hint::black_box(protocol_replay(proto_elems, protocol, true).makespan_cycles);
        });
        let t_proto_ref = time_it(0, 2, || {
            std::hint::black_box(protocol_replay(proto_elems, protocol, false).makespan_cycles);
        });
        let lps = stats.line_accesses as f64 / t_proto.min_s;
        let ref_lps = stats.line_accesses as f64 / t_proto_ref.min_s;
        if protocol.is_default() {
            default_lps = lps;
        }
        println!(
            "protocol {:>16}: {:>7.1} M lines/s fast vs {:>7.1} M reference = {:.2}x \
             ({:.2}x vs default){}",
            protocol.label(),
            lps / 1e6,
            ref_lps / 1e6,
            lps / ref_lps,
            if default_lps > 0.0 { lps / default_lps } else { 1.0 },
            if protocol.is_default() { " [fused baseline]" } else { "" }
        );
        proto_rows.push(Json::obj(vec![
            ("protocol", Json::str(protocol.label())),
            ("min_s", Json::num(t_proto.min_s)),
            ("lines_per_run", Json::num(stats.line_accesses as f64)),
            ("lines_per_sec", Json::num(lps)),
            (
                "relative_to_default",
                Json::num(if default_lps > 0.0 { lps / default_lps } else { 1.0 }),
            ),
            ("upgrade_hits", Json::num(stats.upgrade_hits as f64)),
        ]));
        proto_fast_rows.push(Json::obj(vec![
            ("protocol", Json::str(protocol.label())),
            ("fast_min_s", Json::num(t_proto.min_s)),
            ("fast_lines_per_sec", Json::num(lps)),
            ("reference_min_s", Json::num(t_proto_ref.min_s)),
            ("reference_lines_per_sec", Json::num(ref_lps)),
            ("speedup_vs_per_line_walk", Json::num(lps / ref_lps)),
        ]));
    }
    let protocol_json = Json::obj(vec![
        ("bench", Json::str("protocol_replay_throughput")),
        ("workload", Json::str("microbench non-localised, tilepro64, links+coherence on")),
        ("elems", Json::num(proto_elems as f64)),
        ("threads", Json::num(SCAN_THREADS as f64)),
        ("protocols", Json::arr(proto_rows)),
    ]);
    let protocol_path = std::env::var("TILESIM_BENCH_PROTOCOL_OUT")
        .unwrap_or_else(|_| "BENCH_protocol.json".into());
    std::fs::write(&protocol_path, protocol_json.encode()).expect("write BENCH_protocol.json");
    println!("wrote {protocol_path}");

    // --- intra × protocol: the epoch driver now composes with directory
    // protocols, so the engine record also tracks the parallel speedup of
    // a protocol replay (byte-identity asserted, as always). Case 8 is
    // localised + static-mapped: its own-homed pages are exactly what
    // phase A admits, so the protocol quanta genuinely run in parallel.
    let intra_proto_spec = RunSpec::new(
        8,
        tilesim::coordinator::batch::Workload::Microbench { reps: 4 },
        proto_elems,
        SCAN_THREADS,
        experiment::DEFAULT_SEED,
    )
    .on_machine(tilesim::arch::MachineSpec::TilePro64, true, true)
    .with_protocol(ProtocolSpec::parse("msi").expect("msi spec"));
    let intra_proto_seq_json = intra_proto_spec.execute_intra(1).to_json().encode();
    let mut intra_proto_rows = Vec::new();
    let mut intra_proto_seq_lps = 0.0_f64;
    for workers in [1usize, 4] {
        let stats = intra_proto_spec.execute_intra(workers);
        assert_eq!(
            stats.to_json().encode(),
            intra_proto_seq_json,
            "msi intra-jobs {workers} diverged from the sequential engine"
        );
        let t_w = time_it(0, 2, || {
            std::hint::black_box(intra_proto_spec.execute_intra(workers).makespan_cycles);
        });
        let lps = stats.line_accesses as f64 / t_w.min_s;
        if workers == 1 {
            intra_proto_seq_lps = lps;
        }
        println!(
            "intra-run engine (msi): {workers} worker(s) = {:.1} M lines/s ({:.2}x vs sequential)",
            lps / 1e6,
            lps / intra_proto_seq_lps
        );
        intra_proto_rows.push(Json::obj(vec![
            ("workers", Json::num(workers as f64)),
            ("min_s", Json::num(t_w.min_s)),
            ("lines_per_sec", Json::num(lps)),
            ("speedup_vs_sequential", Json::num(lps / intra_proto_seq_lps)),
        ]));
    }
    engine_fields.push(("protocol_fast_path", Json::arr(proto_fast_rows)));
    engine_fields.push((
        "intra_protocol",
        Json::obj(vec![
            ("protocol", Json::str("msi")),
            ("workload", Json::str("microbench localised (case 8), links+coherence on")),
            ("rows", Json::arr(intra_proto_rows)),
        ]),
    ));
    let engine_json = Json::obj(engine_fields);
    let engine_path = std::env::var("TILESIM_BENCH_ENGINE_OUT")
        .unwrap_or_else(|_| "BENCH_engine.json".into());
    std::fs::write(&engine_path, engine_json.encode()).expect("write BENCH_engine.json");
    println!("wrote {engine_path}");

    // --- batch pool: full table1 sweep at 1 job vs all cores. The sweep
    // is the unit of work every figure replays, so this is the number the
    // scaling PRs move; BENCH_batch.json records it per PR.
    let sweep_elems = (elems / 8).max(1 << 14);
    let spec = experiment::table1_spec(sweep_elems, 16, experiment::DEFAULT_SEED);
    let runs = spec.runs.len() + 1; // + baseline
    let t_serial = time_it(0, 2, || {
        std::hint::black_box(BatchRunner::new(1).run(&spec).results.len());
    });
    let pool = BatchRunner::new(0);
    let t_pool = time_it(0, 2, || {
        std::hint::black_box(pool.run(&spec).results.len());
    });
    let pool_speedup = t_serial.min_s / t_pool.min_s;
    println!("{}", t_serial.summary("batch: table1 sweep, 1 job"));
    println!(
        "{}",
        t_pool.summary(&format!("batch: table1 sweep, {} jobs", pool.jobs()))
    );
    println!(
        "batch pool: {runs} runs/sweep, {:.2}x speedup on {} workers",
        pool_speedup,
        pool.jobs()
    );
    let bench_json = Json::obj(vec![
        ("bench", Json::str("batch_table1_sweep")),
        ("elems", Json::num(sweep_elems as f64)),
        ("runs_per_sweep", Json::num(runs as f64)),
        ("jobs", Json::num(pool.jobs() as f64)),
        ("serial_min_s", Json::num(t_serial.min_s)),
        ("serial_mean_s", Json::num(t_serial.mean_s)),
        ("pool_min_s", Json::num(t_pool.min_s)),
        ("pool_mean_s", Json::num(t_pool.mean_s)),
        ("speedup", Json::num(pool_speedup)),
        (
            "runs_per_second",
            Json::num(runs as f64 / t_pool.min_s),
        ),
    ]);
    let bench_path =
        std::env::var("TILESIM_BENCH_OUT").unwrap_or_else(|_| "BENCH_batch.json".into());
    std::fs::write(&bench_path, bench_json.encode()).expect("write BENCH_batch.json");
    println!("wrote {bench_path}");

    // --- request path: PJRT chunked sorter throughput.
    if std::env::var("TILESIM_SKIP_PJRT").is_err() {
        let dir = tilesim::runtime::artifacts_dir();
        match tilesim::runtime::ArtifactSet::load(&dir) {
            Ok(set) => {
                let sorter = tilesim::runtime::ChunkedSorter::new(&set).expect("sorter");
                let mut rng = tilesim::util::rng::Rng::new(7);
                let data = rng.i32_vec(tilesim::runtime::BATCH);
                // Warm + measure single-batch dispatch latency.
                let _ = sorter.sort_batch(&data).expect("sort");
                let t0 = Instant::now();
                let iters = 5;
                for _ in 0..iters {
                    std::hint::black_box(sorter.sort_batch(&data).expect("sort"));
                }
                let per = t0.elapsed().as_secs_f64() / iters as f64;
                println!(
                    "pjrt sorter: {:.2} ms / {} keys = {:.2} M keys/s",
                    per * 1e3,
                    tilesim::runtime::BATCH,
                    tilesim::runtime::BATCH as f64 / per / 1e6
                );
            }
            Err(e) => println!("pjrt sorter: skipped ({e}) — run `make artifacts`"),
        }
    }
}
