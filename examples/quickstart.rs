//! Quickstart: the paper's result in 30 seconds.
//!
//! 1. Simulate the conventional style (Case 1) and the fully localised
//!    style (Case 8) on a 1 M-integer parallel merge sort.
//! 2. Sort real data through the AOT-compiled Pallas bitonic kernels via
//!    PJRT, proving the three-layer stack composes.
//!
//! Run: `cargo run --release --example quickstart`

use tilesim::coordinator::{case, experiment};
use tilesim::runtime::{ArtifactSet, ChunkedSorter};
use tilesim::util::rng::Rng;

fn main() {
    // --- 1. the simulated experiment -------------------------------------
    let elems = 1_000_000u64;
    let threads = 64usize;
    println!("merge sort, {elems} ints, {threads} threads on the simulated TILEPro64:\n");
    let base = experiment::run_mergesort(&case(1), elems, threads, true, experiment::DEFAULT_SEED);
    let loc = experiment::run_mergesort(&case(8), elems, threads, true, experiment::DEFAULT_SEED);
    println!("  {:<42} {:.3} ms", case(1).label(), base.seconds() * 1e3);
    println!("  {:<42} {:.3} ms", case(8).label(), loc.seconds() * 1e3);
    println!(
        "\n  localisation speed-up: {:.2}x  (hits: {:.0}% local vs {:.0}% local)\n",
        base.seconds() / loc.seconds(),
        loc.local_hit_rate() * 100.0,
        base.local_hit_rate() * 100.0,
    );

    // --- 2. the real compute path ----------------------------------------
    let dir = tilesim::runtime::artifacts_dir();
    match ArtifactSet::load(&dir) {
        Ok(set) => {
            let sorter = ChunkedSorter::new(&set).expect("full_sort artifact");
            let mut rng = Rng::new(1);
            let data = rng.i32_vec(100_000);
            let t0 = std::time::Instant::now();
            let (sorted, m) = sorter.sort(&data).expect("sort");
            assert!(sorted.windows(2).all(|w| w[0] <= w[1]));
            println!(
                "PJRT path: sorted 100k keys via Pallas bitonic kernels in {:.1} ms ({} dispatches)",
                t0.elapsed().as_secs_f64() * 1e3,
                m.dispatches
            );
        }
        Err(e) => println!("PJRT path skipped ({e}); run `make artifacts` first"),
    }
}
