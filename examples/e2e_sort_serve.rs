//! End-to-end driver (DESIGN.md §4 E2E): the full system on a real
//! workload, all three layers composing.
//!
//! A batch-sort "service": the rust coordinator (L3) receives sort
//! requests of random sizes, chunk-dispatches them to the AOT-compiled
//! JAX/Pallas sorter (L2/L1) over PJRT, k-way merges the results, verifies
//! every response against std sort, and reports latency/throughput
//! percentiles. In parallel it replays the same total workload on the
//! simulated TILEPro64 under Case 1 vs Case 8 to report the paper's
//! headline metric on this exact workload.
//!
//! Run: `cargo run --release --example e2e_sort_serve`
//! Env: E2E_REQUESTS (default 24), E2E_MAX_KEYS (default 200_000).

use std::time::Instant;

use tilesim::coordinator::{case, experiment};
use tilesim::runtime::{ArtifactSet, ChunkedSorter};
use tilesim::util::rng::Rng;

fn env_u64(name: &str, default: u64) -> u64 {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() {
    let n_requests = env_u64("E2E_REQUESTS", 24) as usize;
    let max_keys = env_u64("E2E_MAX_KEYS", 200_000) as usize;

    // --- real serving path ------------------------------------------------
    let dir = tilesim::runtime::artifacts_dir();
    let set = ArtifactSet::load(&dir).expect("artifacts missing — run `make artifacts`");
    let sorter = ChunkedSorter::new(&set).expect("full_sort artifact");

    let mut rng = Rng::new(2014);
    let mut latencies = Vec::with_capacity(n_requests);
    let mut total_keys = 0usize;
    let t_all = Instant::now();
    for req in 0..n_requests {
        let n = rng.range(1_000, max_keys as u64) as usize;
        let data = rng.i32_vec(n);
        let t0 = Instant::now();
        let (sorted, metrics) = sorter.sort(&data).expect("sort failed");
        let dt = t0.elapsed().as_secs_f64();
        // Verify EVERY response.
        let mut want = data.clone();
        want.sort_unstable();
        assert_eq!(sorted, want, "request {req}: wrong result");
        latencies.push(dt);
        total_keys += n;
        if req < 3 {
            println!(
                "req {req}: {n} keys in {:.1} ms ({} PJRT dispatches, {} padded)",
                dt * 1e3,
                metrics.dispatches,
                metrics.padded
            );
        }
    }
    let wall = t_all.elapsed().as_secs_f64();
    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pct = |p: f64| latencies[(p * (latencies.len() - 1) as f64) as usize];
    println!(
        "\nserved {n_requests} requests / {total_keys} keys in {wall:.2}s \
         ({:.1} k keys/s) — all responses verified",
        total_keys as f64 / wall / 1e3
    );
    println!(
        "latency: p50 {:.1} ms, p90 {:.1} ms, max {:.1} ms",
        pct(0.5) * 1e3,
        pct(0.9) * 1e3,
        pct(1.0) * 1e3
    );

    // --- simulated counterpart: the paper's metric on this workload -------
    println!("\nsimulated TILEPro64 on the same total workload ({total_keys} ints):");
    let base = experiment::run_mergesort(
        &case(1),
        total_keys as u64,
        64,
        true,
        experiment::DEFAULT_SEED,
    );
    let loc = experiment::run_mergesort(
        &case(8),
        total_keys as u64,
        64,
        true,
        experiment::DEFAULT_SEED,
    );
    println!(
        "  case 1 {:.1} ms vs case 8 {:.1} ms -> localisation speed-up {:.2}x",
        base.seconds() * 1e3,
        loc.seconds() * 1e3,
        base.seconds() / loc.seconds()
    );
}
