//! Custom workload: the localisation API on *your* computation.
//!
//! The paper claims Algorithm 1 generalises to "any parallelisable array
//! computation where each part of the array is accessed multiple times".
//! This example writes a new kernel (an iterative 3-point stencil) against
//! `coordinator::localise::ChunkKernel`, then measures conventional vs
//! localised under both hash policies — the user-facing workflow for
//! adopting the technique.
//!
//! Run: `cargo run --release --example custom_workload`

use tilesim::arch::TileId;
use tilesim::coordinator::localise::{build_program, ChunkKernel, LocaliseConfig, ELEM_BYTES};
use tilesim::mem::{HashPolicy, MemConfig};
use tilesim::sched::StaticMapper;
use tilesim::sim::{Engine, EngineConfig, Loc, TraceBuilder};

/// Your computation: `sweeps` Jacobi smoothing passes over the chunk.
struct Smoother {
    sweeps: u32,
}

impl ChunkKernel for Smoother {
    /// One sweep per emission step: the streaming trace pipeline buffers a
    /// single sweep no matter how many the config asks for.
    fn steps(&self) -> u32 {
        self.sweeps
    }
    fn emit_step(&self, t: &mut TraceBuilder, chunk: Loc, bytes: u64, _thread: usize, _s: u32) {
        let elems = bytes / ELEM_BYTES;
        t.read(chunk, bytes) // read neighbourhood
            .compute(elems * 3) // 3-point update
            .write(chunk, bytes); // write smoothed values
    }
    fn name(&self) -> &'static str {
        "jacobi-smoother"
    }
}

fn run(policy: HashPolicy, localised: bool, elems: u64, sweeps: u32) -> f64 {
    let mut engine = Engine::new(EngineConfig::tilepro64(MemConfig {
        hash_policy: policy,
        striping: true,
    }));
    // The input is produced by the "main thread" (tile 0) — the worst case
    // for data placement, exactly like the paper's array0.
    let input = engine.prealloc_touched(TileId(0), elems * ELEM_BYTES);
    let mut program = build_program(
        &input,
        elems,
        &LocaliseConfig {
            threads: 63,
            localised,
        },
        std::rc::Rc::new(Smoother { sweeps }),
    );
    engine
        .run(&mut program, &mut StaticMapper::new())
        .expect("run failed")
        .seconds()
}

fn main() {
    let elems = 1_000_000u64;
    let sweeps = 16u32;
    println!("jacobi smoother, {elems} cells, {sweeps} sweeps, 63 threads:\n");
    println!("{:<28}{:>14}{:>14}", "configuration", "time (s)", "speed-up");
    let base = run(HashPolicy::AllButStack, false, elems, sweeps);
    for (label, policy, localised) in [
        ("conventional + hash", HashPolicy::AllButStack, false),
        ("conventional + none", HashPolicy::None, false),
        ("localised + hash", HashPolicy::AllButStack, true),
        ("localised + none", HashPolicy::None, true),
    ] {
        let t = run(policy, localised, elems, sweeps);
        println!("{label:<28}{t:>14.4}{:>13.2}x", base / t);
    }
    println!(
        "\nThe same ChunkKernel ran unmodified under every policy — no\n\
         architecture-specific API, exactly the paper's portability claim."
    );
}
