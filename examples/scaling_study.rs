//! Scaling study: thread-count sweep for the key cases, with the
//! per-level breakdown the paper discusses (where time goes as the
//! reduction tree narrows), plus migration statistics for the Tile Linux
//! scheduler.
//!
//! Run: `cargo run --release --example scaling_study`
//! Env: SCALING_SIZE (default 2_000_000).

use tilesim::coordinator::{case, experiment};

fn env_u64(name: &str, default: u64) -> u64 {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() {
    let elems = env_u64("SCALING_SIZE", 2_000_000);
    let threads = [1usize, 2, 4, 8, 16, 32, 64];
    println!("merge sort scaling, {elems} ints (times in ms):\n");
    println!(
        "{:>8}{:>12}{:>12}{:>12}{:>12}{:>14}{:>12}",
        "threads", "case1", "case3", "case8", "case8/case3", "case1 migr", "c8 ddr%"
    );
    for &t in &threads {
        let c1 = experiment::run_mergesort(&case(1), elems, t, true, experiment::DEFAULT_SEED);
        let c3 = experiment::run_mergesort(&case(3), elems, t, true, experiment::DEFAULT_SEED);
        let c8 = experiment::run_mergesort(&case(8), elems, t, true, experiment::DEFAULT_SEED);
        println!(
            "{:>8}{:>12.2}{:>12.2}{:>12.2}{:>12.2}{:>14}{:>11.1}%",
            t,
            c1.seconds() * 1e3,
            c3.seconds() * 1e3,
            c8.seconds() * 1e3,
            c3.seconds() / c8.seconds(),
            c1.migrations,
            c8.ddr_rate() * 100.0,
        );
    }
    println!(
        "\nReading the shape: static mapping (case 3) beats the migrating\n\
         scheduler (case 1); full localisation (case 8) wins once chunks\n\
         are large enough to reuse, and its advantage tracks the DDR rate\n\
         — exactly §5.1 of the paper."
    );
}
