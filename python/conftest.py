import pathlib
import sys

# Make `compile.*` importable regardless of pytest invocation directory.
sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent))
