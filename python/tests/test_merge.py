"""L1 kernel correctness: Pallas pairwise merge vs the pure-jnp oracle."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.merge import bitonic_merge_1d, merge_pass, merge_sorted_pair
from compile.kernels.ref import merge_pass_ref


def _sorted_runs(num_runs, run, seed, dtype=jnp.int32):
    rng = np.random.default_rng(seed)
    if dtype == jnp.int32:
        x = rng.integers(-(2**20), 2**20, size=(num_runs, run)).astype(np.int32)
    else:
        x = rng.standard_normal((num_runs, run)).astype(np.float32)
    return jnp.asarray(np.sort(x, axis=-1))


@pytest.mark.parametrize("run", [1, 4, 32, 128])
@pytest.mark.parametrize("num_runs", [2, 4, 8])
def test_merge_pass_matches_ref(num_runs, run):
    x = _sorted_runs(num_runs, run, seed=num_runs * 1000 + run)
    got = merge_pass(x)
    want = merge_pass_ref(x)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_merge_pass_float32():
    x = _sorted_runs(4, 64, seed=5, dtype=jnp.float32)
    np.testing.assert_array_equal(
        np.asarray(merge_pass(x)), np.asarray(merge_pass_ref(x))
    )


def test_merge_sorted_pair_disjoint_ranges():
    a = jnp.arange(0, 8, dtype=jnp.int32)
    b = jnp.arange(100, 108, dtype=jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(merge_sorted_pair(a, b)), np.concatenate([a, b])
    )
    # Order of the pair must not matter.
    np.testing.assert_array_equal(
        np.asarray(merge_sorted_pair(b, a)), np.concatenate([a, b])
    )


def test_merge_sorted_pair_interleaved():
    a = jnp.asarray([0, 2, 4, 6], dtype=jnp.int32)
    b = jnp.asarray([1, 3, 5, 7], dtype=jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(merge_sorted_pair(a, b)), np.arange(8)
    )


def test_merge_pass_rejects_odd_runs():
    with pytest.raises(ValueError):
        merge_pass(jnp.zeros((3, 8), dtype=jnp.int32))


def test_bitonic_merge_rejects_non_power_of_two():
    with pytest.raises(ValueError):
        bitonic_merge_1d(jnp.zeros(12, dtype=jnp.int32))


def test_merge_with_duplicates_across_runs():
    x = jnp.asarray([[1, 1, 5, 5], [1, 5, 5, 9]], dtype=jnp.int32)
    got = np.asarray(merge_pass(x)).reshape(-1)
    np.testing.assert_array_equal(got, np.asarray([1, 1, 1, 5, 5, 5, 5, 9]))


@settings(max_examples=25, deadline=None)
@given(
    log_run=st.integers(min_value=0, max_value=7),
    pairs=st.integers(min_value=1, max_value=4),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_merge_pass_hypothesis(log_run, pairs, seed):
    x = _sorted_runs(2 * pairs, 1 << log_run, seed)
    got = merge_pass(x)
    want = merge_pass_ref(x)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
