"""L1 kernel correctness: Pallas chunk sorter vs the pure-jnp oracle.

Hypothesis sweeps shapes (power-of-two chunk lengths, arbitrary chunk
counts) and dtypes; fixed cases pin down the degenerate corners.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.bitonic import bitonic_sort_1d, sort_chunks
from compile.kernels.ref import sort_chunks_ref

DTYPES = [jnp.int32, jnp.float32]


def _rand(shape, dtype, seed):
    rng = np.random.default_rng(seed)
    if dtype == jnp.int32:
        return jnp.asarray(rng.integers(-(2**31), 2**31 - 1, size=shape, dtype=np.int64).astype(np.int32))
    return jnp.asarray(rng.standard_normal(shape).astype(np.float32) * 1e3)


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("chunk", [1, 2, 8, 64, 256])
def test_sort_chunks_matches_ref(dtype, chunk):
    x = _rand((4, chunk), dtype, seed=chunk)
    got = sort_chunks(x)
    want = sort_chunks_ref(x)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_sort_single_chunk_identity_when_sorted():
    x = jnp.arange(128, dtype=jnp.int32)[None, :]
    np.testing.assert_array_equal(np.asarray(sort_chunks(x)), np.asarray(x))


def test_sort_reversed():
    x = jnp.arange(64, dtype=jnp.int32)[::-1][None, :]
    np.testing.assert_array_equal(np.asarray(sort_chunks(x))[0], np.arange(64))


def test_sort_all_equal():
    x = jnp.full((3, 32), 7, dtype=jnp.int32)
    np.testing.assert_array_equal(np.asarray(sort_chunks(x)), np.asarray(x))


def test_sort_with_duplicates_and_negatives():
    x = jnp.asarray([[3, -1, 3, 0, -1, 7, 7, -8]], dtype=jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(sort_chunks(x))[0], np.sort(np.asarray(x)[0])
    )


def test_sort_int32_extremes():
    lo, hi = -(2**31), 2**31 - 1
    x = jnp.asarray([[hi, lo, 0, -1, 1, hi, lo, 0]], dtype=jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(sort_chunks(x))[0], np.sort(np.asarray(x)[0])
    )


def test_bitonic_rejects_non_power_of_two():
    with pytest.raises(ValueError):
        bitonic_sort_1d(jnp.zeros(24, dtype=jnp.int32))


def test_sort_is_permutation():
    x = _rand((2, 128), jnp.int32, seed=9)
    got = np.asarray(sort_chunks(x))
    for r in range(2):
        assert sorted(np.asarray(x)[r].tolist()) == got[r].tolist()


@settings(max_examples=25, deadline=None)
@given(
    log_chunk=st.integers(min_value=0, max_value=8),
    num_chunks=st.integers(min_value=1, max_value=6),
    dtype_ix=st.integers(min_value=0, max_value=1),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_sort_chunks_hypothesis(log_chunk, num_chunks, dtype_ix, seed):
    dtype = DTYPES[dtype_ix]
    x = _rand((num_chunks, 1 << log_chunk), dtype, seed)
    got = sort_chunks(x)
    want = sort_chunks_ref(x)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=15, deadline=None)
@given(
    values=st.lists(
        st.integers(min_value=-(2**31), max_value=2**31 - 1),
        min_size=16,
        max_size=16,
    )
)
def test_bitonic_1d_arbitrary_values(values):
    x = jnp.asarray(values, dtype=jnp.int32)
    got = np.asarray(bitonic_sort_1d(x))
    np.testing.assert_array_equal(got, np.sort(np.asarray(values, dtype=np.int32)))
