"""L2 model correctness: full sorter composition and the NUCA latency model."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels.ref import full_sort_ref
from compile.model import full_sort, latency_model


@pytest.mark.parametrize("num_chunks,chunk", [(1, 8), (2, 4), (4, 16), (8, 64)])
def test_full_sort_matches_ref(num_chunks, chunk):
    rng = np.random.default_rng(num_chunks * 100 + chunk)
    x = jnp.asarray(rng.integers(-(2**30), 2**30, size=(num_chunks, chunk)).astype(np.int32))
    got = full_sort(x)
    want = full_sort_ref(x)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_full_sort_rejects_non_power_of_two():
    with pytest.raises(ValueError):
        full_sort(jnp.zeros((3, 8), dtype=jnp.int32))


def test_full_sort_is_global_permutation():
    rng = np.random.default_rng(0)
    x = rng.integers(0, 100, size=(4, 32)).astype(np.int32)
    got = np.asarray(full_sort(jnp.asarray(x))).reshape(-1)
    np.testing.assert_array_equal(got, np.sort(x.reshape(-1)))
    assert (np.diff(got) >= 0).all()


@settings(max_examples=10, deadline=None)
@given(
    log_nc=st.integers(min_value=0, max_value=3),
    log_c=st.integers(min_value=0, max_value=5),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_full_sort_hypothesis(log_nc, log_c, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(
        rng.integers(-(2**31), 2**31 - 1, size=(1 << log_nc, 1 << log_c), dtype=np.int64).astype(np.int32)
    )
    np.testing.assert_array_equal(
        np.asarray(full_sort(x)), np.asarray(full_sort_ref(x))
    )


# ---------------------------------------------------------------------------
# Latency model
# ---------------------------------------------------------------------------


def _lat(req, dst, level, cont=0.0):
    r = jnp.asarray([req], dtype=jnp.int32)
    d = jnp.asarray([dst], dtype=jnp.int32)
    l = jnp.asarray([level], dtype=jnp.int32)
    c = jnp.asarray([cont], dtype=jnp.float32)
    per, total = latency_model(r, d, l, c)
    assert float(total) == pytest.approx(float(per[0]))
    return float(per[0])


def test_latency_l1_hit():
    assert _lat((0, 0), (0, 0), model.LEVEL_L1) == model.L1_HIT_CYCLES


def test_latency_l2_hit_ignores_distance():
    assert _lat((0, 0), (7, 7), model.LEVEL_L2) == model.L2_HIT_CYCLES


def test_latency_home_hit_local_home():
    # Home on the requesting tile: no hops, but still header + home L2.
    want = model.L2_HIT_CYCLES + model.NOC_HEADER_CYCLES
    assert _lat((3, 4), (3, 4), model.LEVEL_HOME) == want


def test_latency_home_hit_scales_with_manhattan_distance():
    base = _lat((0, 0), (0, 0), model.LEVEL_HOME)
    one = _lat((0, 0), (1, 0), model.LEVEL_HOME)
    diag = _lat((0, 0), (3, 4), model.LEVEL_HOME)
    assert one - base == 2 * model.NOC_HOP_CYCLES
    assert diag - base == 2 * model.NOC_HOP_CYCLES * 7


def test_latency_ddr_dominates_home():
    home = _lat((0, 0), (7, 7), model.LEVEL_HOME)
    ddr = _lat((0, 0), (7, 7), model.LEVEL_DDR)
    assert ddr > home


def test_latency_contention_is_additive():
    base = _lat((2, 2), (5, 5), model.LEVEL_HOME)
    loaded = _lat((2, 2), (5, 5), model.LEVEL_HOME, cont=37.5)
    assert loaded == pytest.approx(base + 37.5)


def test_latency_batch_total_is_sum():
    rng = np.random.default_rng(1)
    n = 64
    req = jnp.asarray(rng.integers(0, 8, size=(n, 2)), dtype=jnp.int32)
    dst = jnp.asarray(rng.integers(0, 8, size=(n, 2)), dtype=jnp.int32)
    lvl = jnp.asarray(rng.integers(0, 4, size=(n,)), dtype=jnp.int32)
    cont = jnp.asarray(rng.random(n), dtype=jnp.float32)
    per, total = latency_model(req, dst, lvl, cont)
    assert float(total) == pytest.approx(float(np.asarray(per).sum()), rel=1e-6)
    assert (np.asarray(per) >= model.L1_HIT_CYCLES).all()


def test_export_specs_cover_all_artifacts():
    names = [name for name, _, _ in model.export_specs()]
    assert names == ["sort_chunks", "merge_pass", "full_sort", "latency_model"]
