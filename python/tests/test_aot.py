"""AOT path: artifacts are valid HLO text with a consistent manifest.

Uses small export shapes would be ideal, but the AOT path must be tested as
shipped, so this lowers the real specs once (module-scoped) and checks
structure; the numeric round-trip through PJRT is covered on the rust side
(rust/tests/integration_runtime.rs).
"""

import hashlib
import json
import pathlib

import pytest

from compile import aot


@pytest.fixture(scope="module")
def artifacts(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    manifest = aot.lower_all(out)
    return out, manifest


def test_all_artifacts_written(artifacts):
    out, manifest = artifacts
    names = {a["name"] for a in manifest["artifacts"]}
    assert names == {"sort_chunks", "merge_pass", "full_sort", "latency_model"}
    for a in manifest["artifacts"]:
        assert (out / a["file"]).exists()


def test_hlo_text_has_entry_computation(artifacts):
    out, manifest = artifacts
    for a in manifest["artifacts"]:
        text = (out / a["file"]).read_text()
        assert "ENTRY" in text, a["name"]
        assert "HloModule" in text, a["name"]


def test_hlo_is_plain_hlo_no_custom_calls(artifacts):
    # interpret=True pallas must lower to plain HLO the CPU PJRT client can
    # run; a Mosaic custom-call here would break the rust runtime.
    out, manifest = artifacts
    for a in manifest["artifacts"]:
        text = (out / a["file"]).read_text()
        assert "tpu_custom_call" not in text, a["name"]
        assert "mosaic" not in text.lower(), a["name"]


def test_manifest_hashes_match_files(artifacts):
    out, manifest = artifacts
    for a in manifest["artifacts"]:
        text = (out / a["file"]).read_text()
        assert hashlib.sha256(text.encode()).hexdigest() == a["sha256"]
        assert len(text) == a["bytes"]


def test_manifest_json_round_trips(artifacts):
    out, manifest = artifacts
    on_disk = json.loads((out / "manifest.json").read_text())
    assert on_disk == manifest


def test_manifest_input_shapes(artifacts):
    _, manifest = artifacts
    by_name = {a["name"]: a for a in manifest["artifacts"]}
    assert by_name["full_sort"]["inputs"] == [
        {"shape": [64, 1024], "dtype": "int32"}
    ]
    lat = by_name["latency_model"]["inputs"]
    assert [i["shape"] for i in lat] == [[1024, 2], [1024, 2], [1024], [1024]]
    assert [i["dtype"] for i in lat] == ["int32", "int32", "int32", "float32"]
