"""AOT compile path: lower the L2 model to HLO text artifacts.

HLO *text* (not `.serialize()`) is the interchange format: jax >= 0.5 emits
HloModuleProtos with 64-bit instruction ids which the rust side's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids, so text round-trips cleanly. See /opt/xla-example/README.md.

Run once via `make artifacts` (no-op when inputs are unchanged); python is
never on the rust request path.

Usage: cd python && python -m compile.aot --out-dir ../artifacts
"""

from __future__ import annotations

import argparse
import hashlib
import json
import pathlib

import jax
from jax._src.lib import xla_client as xc

from .model import export_specs


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec_json(s) -> dict:
    return {"shape": list(s.shape), "dtype": str(s.dtype)}


def lower_all(out_dir: pathlib.Path) -> dict:
    """Lower every export spec, write <name>.hlo.txt files and a manifest.

    The manifest records input shapes/dtypes plus a content hash per
    artifact so the rust runtime can validate what it loads (runtime's
    ArtifactSet checks the manifest at startup).
    """
    out_dir.mkdir(parents=True, exist_ok=True)
    manifest = {"artifacts": []}
    for name, fn, example_args in export_specs():
        lowered = jax.jit(fn).lower(*example_args)
        text = to_hlo_text(lowered)
        path = out_dir / f"{name}.hlo.txt"
        path.write_text(text)
        manifest["artifacts"].append(
            {
                "name": name,
                "file": path.name,
                "inputs": [_spec_json(s) for s in example_args],
                "sha256": hashlib.sha256(text.encode()).hexdigest(),
                "bytes": len(text),
            }
        )
        print(f"wrote {path} ({len(text)} chars)")
    (out_dir / "manifest.json").write_text(json.dumps(manifest, indent=2))
    print(f"wrote {out_dir / 'manifest.json'}")
    return manifest


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out-dir", default="../artifacts")
    args = parser.parse_args()
    lower_all(pathlib.Path(args.out_dir))


if __name__ == "__main__":
    main()
