"""L2 JAX model: the chunked sorter and the analytical NUCA latency model.

The sorter mirrors the paper's merge-sort structure exactly: the input array
is split into num_chunks runs, each run is sorted locally (the Pallas chunk
kernel = the per-thread `mergesort_serial` on a localised copy), then a
log2(num_chunks)-level reduction tree of pairwise merges (the Pallas merge
kernel = the `merge` function) produces the sorted array.

The latency model is a vectorised closed form of the rust event simulator's
per-access cost (rust/src/arch/params.rs mirrors these constants); the rust
integration tests execute the exported HLO and cross-check it against the
event-driven path, so the two layers cannot silently drift apart.

Everything here is build-time Python: `aot.py` lowers these functions once to
HLO text and the rust coordinator executes the artifacts via PJRT.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from .kernels.bitonic import sort_chunks
from .kernels.merge import merge_pass

# ---------------------------------------------------------------------------
# Chunked sorter (calls the L1 Pallas kernels)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("interpret",))
def full_sort(x: jax.Array, *, interpret: bool = True) -> jax.Array:
    """Globally sort a (num_chunks, C) array ascending in row-major order.

    num_chunks and C must be powers of two. The merge tree reshapes between
    levels so the same pairwise-merge kernel handles every level; the level
    loop is unrolled at trace time (static shapes), so the lowered HLO is a
    straight-line pipeline of pallas calls XLA can schedule back-to-back.
    """
    num_chunks, chunk = x.shape
    if num_chunks & (num_chunks - 1) or chunk & (chunk - 1):
        raise ValueError(f"full_sort needs power-of-two dims, got {x.shape}")
    y = sort_chunks(x, interpret=interpret)
    levels = int(math.log2(num_chunks))
    runs, run = num_chunks, chunk
    for _ in range(levels):
        y = merge_pass(y.reshape(runs, run), interpret=interpret)
        runs //= 2
        run *= 2
    return y.reshape(num_chunks, chunk)


# ---------------------------------------------------------------------------
# Analytical NUCA latency model (TILEPro64 DDC)
# ---------------------------------------------------------------------------
# These constants are the single source of truth shared with
# rust/src/arch/params.rs (see LatencyParams::TILEPRO64). Units: cycles at
# 860 MHz, per cache-line (64 B) access.

L1_HIT_CYCLES = 2.0
L2_HIT_CYCLES = 8.0
NOC_HEADER_CYCLES = 6.0
NOC_HOP_CYCLES = 1.0
DDR_CYCLES = 88.0

LEVEL_L1 = 0
LEVEL_L2 = 1
LEVEL_HOME = 2  # remote home tile's L2 = the distributed "L3"
LEVEL_DDR = 3


def latency_model(
    req_xy: jax.Array,  # (N, 2) i32 — requesting tile (x, y)
    dst_xy: jax.Array,  # (N, 2) i32 — home tile (level 2) or controller attach (level 3)
    level: jax.Array,  # (N,) i32 — hit level per access (LEVEL_*)
    contention: jax.Array,  # (N,) f32 — additive queueing cycles (link + home/ctrl)
) -> tuple[jax.Array, jax.Array]:
    """Per-access latency (cycles) and the batch total.

    Level 2 pays round-trip mesh hops to the home tile plus the home L2
    lookup; level 3 pays hops to the memory controller plus DRAM. XY routing
    makes hop count the Manhattan distance.
    """
    hops = jnp.abs(req_xy - dst_xy).sum(axis=-1).astype(jnp.float32)
    mesh = NOC_HEADER_CYCLES + 2.0 * NOC_HOP_CYCLES * hops
    per = jnp.select(
        [level == LEVEL_L1, level == LEVEL_L2, level == LEVEL_HOME],
        [
            jnp.full_like(mesh, L1_HIT_CYCLES),
            jnp.full_like(mesh, L2_HIT_CYCLES),
            L2_HIT_CYCLES + mesh,
        ],
        DDR_CYCLES + mesh,
    )
    per = per + contention
    return per, jnp.sum(per)


# ---------------------------------------------------------------------------
# Export specs (consumed by aot.py and mirrored in artifacts/manifest.json)
# ---------------------------------------------------------------------------

# Shapes chosen so the rust request path sorts 64 Ki keys per executable
# dispatch; N=1024 accesses per latency-model batch.
SORT_NUM_CHUNKS = 64
SORT_CHUNK = 1024
LATENCY_BATCH = 1024


def export_specs():
    """(name, fn, example_args) for every artifact aot.py emits."""
    i32 = jnp.int32
    f32 = jnp.float32
    s = jax.ShapeDtypeStruct
    chunks = s((SORT_NUM_CHUNKS, SORT_CHUNK), i32)
    n = LATENCY_BATCH
    return [
        ("sort_chunks", lambda x: (sort_chunks(x),), (chunks,)),
        ("merge_pass", lambda x: (merge_pass(x),), (chunks,)),
        ("full_sort", lambda x: (full_sort(x),), (chunks,)),
        (
            "latency_model",
            lambda r, d, l, c: latency_model(r, d, l, c),
            (s((n, 2), i32), s((n, 2), i32), s((n,), i32), s((n,), f32)),
        ),
    ]
