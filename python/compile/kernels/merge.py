"""L1 Pallas kernel: bitonic merge of two sorted runs.

This is the merge step of the paper's reduction tree (Algorithm 3/4). Two
ascending runs a and b become a single bitonic sequence [a, reverse(b)], and
one descending-j pass of compare-exchanges merges them in O(n log n) with no
data-dependent control flow - the shape a TPU VPU wants, versus the CPU's
pointer-chasing two-finger merge.

Like the chunk sorter, the BlockSpec (2, R) pulls the *pair* of runs into
VMEM once per grid step (coarse-grained localisation), then the whole merge
network runs out of VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def bitonic_merge_1d(z: jax.Array) -> jax.Array:
    """Merge a bitonic 1-D sequence (asc then desc) into ascending order.

    One descending-j sweep: j = n/2, n/4, ..., 1 with all pairs ascending.
    """
    n = z.shape[-1]
    if n & (n - 1):
        raise ValueError(f"bitonic merge needs a power-of-two length, got {n}")
    idx = jnp.arange(n, dtype=jnp.int32)
    j = n // 2
    while j >= 1:
        partner = idx ^ j
        pz = z[..., partner]
        is_lower = (idx & j) == 0
        lo = jnp.minimum(z, pz)
        hi = jnp.maximum(z, pz)
        z = jnp.where(is_lower, lo, hi)
        j //= 2
    return z


def merge_sorted_pair(a: jax.Array, b: jax.Array) -> jax.Array:
    """Merge two ascending runs into one ascending run of twice the length."""
    z = jnp.concatenate([a, b[..., ::-1]], axis=-1)
    return bitonic_merge_1d(z)


def _merge_pair_kernel(x_ref, o_ref):
    """Pallas kernel body: merge rows 0 and 1 of a (2, R) block in VMEM."""
    merged = merge_sorted_pair(x_ref[0, :], x_ref[1, :])
    run = x_ref.shape[1]
    o_ref[0, :] = merged[:run]
    o_ref[1, :] = merged[run:]


@functools.partial(jax.jit, static_argnames=("interpret",))
def merge_pass(x: jax.Array, *, interpret: bool = True) -> jax.Array:
    """One merge level of the reduction tree over a (num_runs, R) array.

    Rows 2i and 2i+1 (each ascending) are merged; the result is written back
    as two rows so the caller can reshape (num_runs/2, 2R) to continue the
    tree with the same kernel. num_runs must be even.

    VMEM per grid step: 2 blocks of (2, R) -> 4 * R * itemsize.
    """
    num_runs, run = x.shape
    if num_runs % 2:
        raise ValueError(f"merge_pass needs an even number of runs, got {num_runs}")
    return pl.pallas_call(
        _merge_pair_kernel,
        out_shape=jax.ShapeDtypeStruct((num_runs, run), x.dtype),
        grid=(num_runs // 2,),
        in_specs=[pl.BlockSpec((2, run), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((2, run), lambda i: (i, 0)),
        interpret=interpret,
    )(x)
