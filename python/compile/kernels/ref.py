"""Pure-jnp correctness oracles for the Pallas kernels.

Everything here is the "obviously correct" formulation (jnp.sort / concat +
sort); pytest and hypothesis compare the kernels against these on swept
shapes and dtypes. Nothing in this file is ever exported to HLO.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def sort_chunks_ref(x: jax.Array) -> jax.Array:
    """Row-wise ascending sort of a (num_chunks, C) array."""
    return jnp.sort(x, axis=-1)


def merge_pass_ref(x: jax.Array) -> jax.Array:
    """Merge row pairs (2i, 2i+1) of a (num_runs, R) array of ascending runs."""
    num_runs, run = x.shape
    paired = x.reshape(num_runs // 2, 2 * run)
    merged = jnp.sort(paired, axis=-1)
    return merged.reshape(num_runs, run)


def full_sort_ref(x: jax.Array) -> jax.Array:
    """Globally ascending sort of a (num_chunks, C) array, row-major layout."""
    flat = jnp.sort(x.reshape(-1))
    return flat.reshape(x.shape)
