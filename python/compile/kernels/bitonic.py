"""L1 Pallas kernel: per-chunk bitonic sort.

This is the TPU re-think of the paper's *localisation* step (DESIGN.md
SS Hardware-Adaptation): on the TILEPro64 each thread `memcpy`s its chunk into
a freshly allocated array so the chunk is homed on the local tile; on TPU the
BlockSpec below copies one chunk per grid step HBM->VMEM, and the whole
O(C log^2 C) compare-exchange network then runs out of VMEM with no further
HBM traffic. Coarse-grained locality (one chunk per grid step) instead of
fine-grained "hash for home" (line-by-line HBM streaming).

Pallas is run with interpret=True: the CPU PJRT plugin cannot execute Mosaic
custom-calls, and interpret-mode lowers to plain HLO that the rust runtime's
PJRT CPU client executes directly.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _compare_exchange(x: jax.Array, k: int, j: int) -> jax.Array:
    """One vectorised stage of the bitonic network over a 1-D power-of-two array.

    Element i is paired with i^j; the pair sorts ascending when bit k of i is
    0 (the classic bitonic direction rule), so after all (k, j) stages the
    array is ascending.
    """
    n = x.shape[-1]
    idx = jnp.arange(n, dtype=jnp.int32)
    partner = idx ^ j
    px = x[..., partner]
    is_lower = (idx & j) == 0
    dir_up = (idx & k) == 0
    keep_min = jnp.logical_xor(is_lower, jnp.logical_not(dir_up))
    lo = jnp.minimum(x, px)
    hi = jnp.maximum(x, px)
    return jnp.where(keep_min, lo, hi)


def bitonic_sort_1d(x: jax.Array) -> jax.Array:
    """Full bitonic sort of a 1-D power-of-two-length array, ascending.

    Stages are unrolled at trace time (length is static), which is exactly
    what a hand-scheduled TPU kernel would do: the network shape is known at
    compile time, so there is no data-dependent control flow on the VPU.
    """
    n = x.shape[-1]
    if n & (n - 1):
        raise ValueError(f"bitonic sort needs a power-of-two length, got {n}")
    k = 2
    while k <= n:
        j = k // 2
        while j >= 1:
            x = _compare_exchange(x, k, j)
            j //= 2
        k *= 2
    return x


def _sort_chunk_kernel(x_ref, o_ref):
    """Pallas kernel body: sort one (1, C) chunk resident in VMEM."""
    row = x_ref[0, :]
    o_ref[0, :] = bitonic_sort_1d(row)


@functools.partial(jax.jit, static_argnames=("interpret",))
def sort_chunks(x: jax.Array, *, interpret: bool = True) -> jax.Array:
    """Sort each row of a (num_chunks, C) array independently (ascending).

    Grid iterates over chunks; BlockSpec (1, C) is the HBM->VMEM
    "localisation" copy. VMEM footprint per grid step: 2 * C * itemsize
    (input block + output block), far under the ~16 MiB VMEM budget for any
    C we export.
    """
    num_chunks, chunk = x.shape
    return pl.pallas_call(
        _sort_chunk_kernel,
        out_shape=jax.ShapeDtypeStruct((num_chunks, chunk), x.dtype),
        grid=(num_chunks,),
        in_specs=[pl.BlockSpec((1, chunk), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, chunk), lambda i: (i, 0)),
        interpret=interpret,
    )(x)
