//! Spatial multi-server serving: the partition/dispatch contracts named by
//! the acceptance bar.
//!
//! - the JSON record is byte-identical at any `--jobs`/`--intra-jobs`
//!   when the chip is partitioned (P > 1, with an admission order and a
//!   request-size mix in play);
//! - per-server accounting conserves requests: server slices sum to the
//!   aggregate, and aggregate completed + dropped == offered;
//! - an explicit single whole-chip partition (`--partitions 1x1`) routes
//!   through the multi-server dispatcher yet reproduces the plain
//!   single-server driver's bytes exactly — the two event loops are
//!   equivalent, not merely similar;
//! - at fixed ρ the completed throughput is monotone non-decreasing in
//!   the partition count (every rung shares the whole-chip ρ anchor, so
//!   the arrival streams are identical request-for-request);
//! - carving is a true partition: sub-grids are pairwise disjoint, cover
//!   exactly the chip, and their link maps compose injectively into the
//!   parent mesh (XY routes never leave a rectangle).

use std::collections::HashSet;

use tilesim::arch::{Machine, PartitionSpec};
use tilesim::coherence::ProtocolSpec;
use tilesim::coordinator::batch::{BatchRunner, RunSpec};
use tilesim::coordinator::experiment;
use tilesim::metrics::partitioned_link_heatmap;
use tilesim::serve::{Admission, ArrivalSpec, BatchPolicy, ServeScenario, ServeSweep, SizeMix};

const SEED: u64 = experiment::DEFAULT_SEED;

fn scenario(partitions: &str, rho: f64, requests: u64) -> ServeScenario {
    ServeScenario::new(
        RunSpec::mergesort(8, 1 << 10, 4, SEED),
        ArrivalSpec::Poisson,
        rho,
        requests,
        1 << 20,
        BatchPolicy::Immediate,
    )
    .with_partitions(PartitionSpec::parse(partitions).unwrap())
}

#[test]
fn partitioned_record_is_byte_identical_across_jobs_and_intra_jobs() {
    let sweep = ServeSweep::grid(
        &RunSpec::mergesort(8, 1 << 10, 4, SEED),
        &experiment::serve_machines(),
        &[ProtocolSpec::default()],
        &[BatchPolicy::Immediate, BatchPolicy::Batch { max: 4, wait: 0 }],
        ArrivalSpec::Poisson,
        &[0.7, 2.0],
        28,
        1 << 10,
        false,
        &PartitionSpec::parse("2x2").unwrap(),
        Admission::Sjf,
        &SizeMix::parse("75%1024,25%4096").unwrap(),
    );
    sweep.check().unwrap();
    let serial = sweep.to_json(&sweep.run(&BatchRunner::new(1))).encode();
    for jobs in [2usize, 4] {
        let parallel = sweep.to_json(&sweep.run(&BatchRunner::new(jobs))).encode();
        assert_eq!(serial, parallel, "jobs={jobs} changed the partitioned record");
    }
    let intra = sweep
        .to_json(&sweep.run(&BatchRunner::new(1).with_intra_jobs(4)))
        .encode();
    assert_eq!(serial, intra, "intra-run workers changed the partitioned record");
}

#[test]
fn per_server_accounting_conserves_requests() {
    let r = scenario("4", 2.5, 64).simulate(1);
    assert_eq!(r.completed + r.dropped, 64, "every request completes or drops");
    assert_eq!(r.servers.len(), 4);
    assert_eq!(
        r.servers.iter().map(|s| s.completed).sum::<u64>(),
        r.completed,
        "server slices must sum to the aggregate completions"
    );
    assert_eq!(r.servers.iter().map(|s| s.batches).sum::<u64>(), r.batches);
    for s in &r.servers {
        assert!(s.busy_cycles <= r.makespan_cycles, "{}", s.partition);
        assert!((0.0..=1.0).contains(&s.utilisation), "{}", s.partition);
        assert!(s.max_batch_served <= 1, "immediate policy serves one per batch");
    }
}

#[test]
fn single_partition_is_byte_identical_to_the_plain_driver() {
    // `1x1` is a whole-chip carve that is NOT `PartitionSpec::Whole`, so
    // it runs the multi-server event loop; its spec JSON still omits the
    // partitions field (a whole-chip carve is the baseline). Both report
    // and spec must reproduce the plain driver's bytes exactly.
    for (rho, policy) in [
        (0.6, BatchPolicy::Immediate),
        (1.4, BatchPolicy::Batch { max: 4, wait: 0 }),
        (1.4, BatchPolicy::Batch { max: 4, wait: 1 << 14 }),
    ] {
        let plain = ServeScenario::new(
            RunSpec::mergesort(8, 1 << 10, 4, SEED),
            ArrivalSpec::Poisson,
            rho,
            40,
            1 << 20,
            policy,
        );
        let routed = plain.clone().with_partitions(PartitionSpec::parse("1x1").unwrap());
        assert_eq!(
            plain.to_json().encode(),
            routed.to_json().encode(),
            "whole-chip carve must keep the spec bytes"
        );
        assert_eq!(
            plain.simulate(1).to_json().encode(),
            routed.simulate(1).to_json().encode(),
            "dispatch loop must reproduce the plain driver at P=1 (rho={rho})"
        );
    }
}

#[test]
fn throughput_is_monotone_in_partition_count_at_fixed_load() {
    // Same whole-chip ρ anchor ⇒ same arrival stream on every rung; more
    // servers can only drain it sooner.
    for rho in [2.0, 4.0] {
        let whole = scenario("whole", rho, 72).simulate(1);
        let half = scenario("2", rho, 72).simulate(1);
        let quad = scenario("4", rho, 72).simulate(1);
        assert_eq!(whole.offered_rps, half.offered_rps, "shared arrival stream");
        assert_eq!(whole.offered_rps, quad.offered_rps, "shared arrival stream");
        assert!(
            half.completed_rps >= whole.completed_rps,
            "rho={rho}: 2 partitions slower than 1 ({} < {})",
            half.completed_rps,
            whole.completed_rps
        );
        assert!(
            quad.completed_rps >= half.completed_rps,
            "rho={rho}: 4 partitions slower than 2 ({} < {})",
            quad.completed_rps,
            half.completed_rps
        );
    }
}

#[test]
fn carving_is_disjoint_and_covers_the_chip() {
    let machines = [Machine::tilepro64(), Machine::nuca256()];
    for m in &machines {
        for spec in ["2", "4", "8", "16", "2x2", "4x2", "rows2", "rows4", "cols2", "1x1"] {
            let parts = PartitionSpec::parse(spec).unwrap().carve(m).unwrap();
            let mut seen: HashSet<u32> = HashSet::new();
            for p in &parts {
                for t in p.tiles(m) {
                    assert!(
                        seen.insert(t.0),
                        "{spec} on {}: tile {} in two partitions",
                        m.name(),
                        t.0
                    );
                }
            }
            assert_eq!(
                seen.len() as u32,
                m.num_tiles(),
                "{spec} on {}: carve must cover every tile exactly once",
                m.name()
            );
        }
    }
}

#[test]
fn partition_link_maps_compose_injectively_into_the_parent() {
    // Geometry half: every view-local link of every partition maps to a
    // parent link rooted at a tile inside that partition, and no two
    // (partition, local-link) pairs collide — composition is exact
    // addition, never double-counting.
    let m = Machine::tilepro64();
    let parts = PartitionSpec::parse("2x2").unwrap().carve(&m).unwrap();
    let mut seen: HashSet<usize> = HashSet::new();
    for p in &parts {
        let local_links = 4 * p.num_tiles() as usize;
        for i in 0..local_links {
            let g = p.global_link_index(&m, i);
            assert!(g < m.num_links());
            assert!(seen.insert(g), "{}: parent link {g} mapped twice", p.label());
        }
    }

    // Replay half: run each partition's replay with link billing on and
    // compose the maps into one parent heatmap.
    let mut run = RunSpec::mergesort(8, 1 << 10, 4, SEED);
    run.link_contention = true;
    let stats: Vec<_> = parts.iter().map(|p| run.on_partition(p, &m, 1)).collect();
    let slices: Vec<_> = parts.iter().zip(stats.iter()).collect();
    let map = partitioned_link_heatmap(&slices, &m).unwrap();
    assert!(map.contains("4 partition server(s)"), "{map}");
    assert!(map.contains("packets total"), "{map}");
}
