//! Property tests over the substrates (own driver — see util::prop).

use std::sync::Arc;

use tilesim::arch::{hops, Machine, TileId, NUM_TILES, PAGE_BYTES};
use tilesim::cache::{CacheSystem, SetAssoc};
use tilesim::mem::{
    AllocKind, Allocator, HashPolicy, Homing, LineId, MemConfig, VAddr,
};
use tilesim::noc::xy_path;
use tilesim::util::json::{parse, Json};
use tilesim::util::prop::{self, assert_holds};

fn tilepro() -> Arc<Machine> {
    Arc::new(Machine::tilepro64())
}

#[test]
fn prop_allocator_never_overlaps_and_frees_are_reusable() {
    prop::check("allocator non-overlap", 64, |rng| {
        let mut a = Allocator::new(
            tilepro(),
            MemConfig {
                hash_policy: if rng.chance(0.5) {
                    HashPolicy::AllButStack
                } else {
                    HashPolicy::None
                },
                striping: rng.chance(0.5),
            },
        );
        let mut live: Vec<(u64, u64)> = Vec::new();
        let mut addrs = Vec::new();
        for _ in 0..rng.range(1, 60) {
            if !addrs.is_empty() && rng.chance(0.3) {
                let ix = rng.below(addrs.len() as u64) as usize;
                let addr: VAddr = addrs.swap_remove(ix);
                a.free(addr).map_err(|e| e.to_string())?;
                live.retain(|&(s, _)| s != addr.0);
            } else {
                let bytes = rng.range(1, 4 * PAGE_BYTES);
                let tile = TileId(rng.below(NUM_TILES as u64) as u32);
                let r = a.alloc(tile, bytes, AllocKind::Heap).map_err(|e| e.to_string())?;
                let rounded = bytes.div_ceil(PAGE_BYTES) * PAGE_BYTES;
                for &(s, e) in &live {
                    assert_holds(
                        r.addr.0 >= e || s >= r.addr.0 + rounded,
                        "regions overlap",
                    )?;
                }
                live.push((r.addr.0, r.addr.0 + rounded));
                addrs.push(r.addr);
            }
        }
        Ok(())
    });
}

#[test]
fn prop_homing_is_deterministic_and_in_range() {
    prop::check("homing determinism", 128, |rng| {
        let homing = match rng.below(3) {
            0 => Homing::Single(TileId(rng.below(64) as u32)),
            1 => Homing::HashForHome,
            _ => Homing::PageHash,
        };
        let line = LineId(rng.next_u64() % (1 << 30));
        let h1 = homing.home_of(line, NUM_TILES);
        let h2 = homing.home_of(line, NUM_TILES);
        assert_holds(h1 == h2, "homing not deterministic")?;
        assert_holds(h1.unwrap().0 < NUM_TILES, "home out of range")
    });
}

#[test]
fn prop_machine_round_trips_and_homes_in_range() {
    // Any grid — including non-square ones like 4×8 — must round-trip
    // `tile_at(coord(t)) == t` for every tile, keep its controllers on the
    // grid, and hash every line to an in-range home.
    prop::check("machine round trip", 96, |rng| {
        let w = 1 + rng.below(16) as u32;
        let h = 1 + rng.below(16) as u32;
        // Edge capacity: W controllers on a single-row grid, 2W otherwise.
        let cap = if h == 1 { w } else { 2 * w };
        let ctrls = 1 + rng.below(cap as u64) as u32;
        let m = Machine::custom(w, h, ctrls).map_err(|e| e.to_string())?;
        let attaches: std::collections::HashSet<_> =
            m.controllers().iter().map(|c| c.attach).collect();
        assert_holds(
            attaches.len() == ctrls as usize,
            "controllers must attach to distinct tiles",
        )?;
        for t in m.tiles() {
            assert_holds(m.tile_at(m.coord(t)) == t, "coord round trip")?;
            assert_holds(m.coord(t).x < w && m.coord(t).y < h, "coord in range")?;
        }
        for c in m.controllers() {
            assert_holds(c.attach.0 < m.num_tiles(), "controller off-grid")?;
        }
        let homing = if rng.chance(0.5) {
            Homing::HashForHome
        } else {
            Homing::PageHash
        };
        for _ in 0..64 {
            let line = LineId(rng.next_u64() % (1 << 30));
            let home = homing.home_of(line, m.num_tiles()).unwrap();
            assert_holds(home.0 < m.num_tiles(), "home off the machine")?;
            assert_holds(
                m.hops(home, m.nearest_controller(home).attach) < w + h,
                "nearest controller unreachable",
            )?;
        }
        Ok(())
    });
}

#[test]
fn prop_non_square_4x8_machine_homes_every_line() {
    // The explicit non-square pin from the issue: a 4×8 grid homes every
    // line of a large range in-range under both hash granularities.
    let m = Machine::custom(4, 8, 2).unwrap();
    assert_eq!(m.num_tiles(), 32);
    for t in m.tiles() {
        assert_eq!(m.tile_at(m.coord(t)), t);
    }
    for l in 0..100_000u64 {
        for homing in [Homing::HashForHome, Homing::PageHash] {
            let home = homing.home_of(LineId(l), m.num_tiles()).unwrap();
            assert!(home.0 < 32, "line {l} homed off-grid at {home:?}");
        }
    }
}

#[test]
fn prop_cache_contains_iff_inserted_not_evicted_or_invalidated() {
    // Model-based check of SetAssoc against a naive per-set LRU model.
    prop::check("set-assoc vs model", 48, |rng| {
        let sets = 1usize << rng.below(4); // 1..8 sets
        let ways = 1 + rng.below(3) as usize;
        let mut cache = SetAssoc::new(sets, ways);
        let mut model: Vec<Vec<u64>> = vec![Vec::new(); sets]; // MRU at end
        for _ in 0..200 {
            let line = LineId(rng.below(64));
            let set = (line.0 as usize) % sets;
            match rng.below(3) {
                0 => {
                    cache.insert(line);
                    let s = &mut model[set];
                    s.retain(|&l| l != line.0);
                    s.push(line.0);
                    if s.len() > ways {
                        s.remove(0);
                    }
                }
                1 => {
                    let hit = cache.probe(line);
                    let in_model = model[set].contains(&line.0);
                    assert_holds(hit == in_model, "probe disagrees with model")?;
                    if in_model {
                        let s = &mut model[set];
                        s.retain(|&l| l != line.0);
                        s.push(line.0);
                    }
                }
                _ => {
                    cache.invalidate(line);
                    model[set].retain(|&l| l != line.0);
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_coherence_single_writer_no_stale_l1() {
    // After any write, no OTHER tile may hit the written line in its L1.
    prop::check("no stale copies", 32, |rng| {
        let mut sys = CacheSystem::new(tilepro());
        let tiles: Vec<TileId> = (0..4).map(|_| TileId(rng.below(64) as u32)).collect();
        let homes: Vec<TileId> = (0..8).map(|_| TileId(rng.below(64) as u32)).collect();
        for _ in 0..300 {
            let t = tiles[rng.below(tiles.len() as u64) as usize];
            let line = LineId(rng.below(16));
            let home = homes[(line.0 % homes.len() as u64) as usize];
            if rng.chance(0.4) {
                sys.write(t, line, home);
                // Every other tile must now MISS in its private caches —
                // except the home tile, whose L2 *is* the coherent home
                // copy (that's DDC working as designed, not staleness).
                for &other in &tiles {
                    if other != t && other != home {
                        assert_holds(
                            !sys.tile(other).l1.contains(line)
                                && !sys.tile(other).l2.contains(line),
                            "stale private copy after write",
                        )?;
                    }
                }
            } else {
                sys.read(t, line, home);
            }
        }
        Ok(())
    });
}

#[test]
fn prop_xy_route_valid() {
    prop::check("xy routing", 256, |rng| {
        let a = TileId(rng.below(64) as u32);
        let b = TileId(rng.below(64) as u32);
        let m = Machine::tilepro64();
        let path = xy_path(&m, a, b);
        assert_holds(path[0] == a && *path.last().unwrap() == b, "endpoints")?;
        assert_holds(path.len() as u32 == hops(a, b) + 1, "length")?;
        for w in path.windows(2) {
            assert_holds(hops(w[0], w[1]) == 1, "non-adjacent step")?;
        }
        Ok(())
    });
}

#[test]
fn prop_json_round_trips() {
    fn gen_json(rng: &mut tilesim::util::rng::Rng, depth: u32) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.chance(0.5)),
            2 => Json::num((rng.next_u32() as f64) / 8.0),
            3 => Json::str(format!("s{}-\"x\\y\n", rng.below(1000))),
            4 => Json::arr((0..rng.below(4)).map(|_| gen_json(rng, depth - 1))),
            _ => Json::obj(
                (0..rng.below(4))
                    .map(|i| (Box::leak(format!("k{i}").into_boxed_str()) as &str, gen_json(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
    prop::check("json round trip", 128, |rng| {
        let v = gen_json(rng, 3);
        let text = v.encode();
        let back = parse(&text).map_err(|e| e.to_string())?;
        prop::assert_eq_dbg(back, v, "round trip")
    });
}

#[test]
fn prop_first_touch_is_sticky_per_page() {
    prop::check("first touch sticky", 64, |rng| {
        let mut a = Allocator::new(
            tilepro(),
            MemConfig {
                hash_policy: HashPolicy::None,
                striping: true,
            },
        );
        let r = a
            .alloc(TileId(0), rng.range(1, 3 * PAGE_BYTES), AllocKind::Heap)
            .map_err(|e| e.to_string())?;
        let first_toucher = TileId(rng.below(64) as u32);
        let line = r.addr.line();
        let home = a.table.resolve_home(line, first_toucher).map_err(|e| e.to_string())?;
        prop::assert_eq_dbg(home, first_toucher, "first touch")?;
        for _ in 0..10 {
            let other = TileId(rng.below(64) as u32);
            let h = a.table.resolve_home(line, other).map_err(|e| e.to_string())?;
            prop::assert_eq_dbg(h, first_toucher, "re-touch must not re-home")?;
        }
        Ok(())
    });
}
