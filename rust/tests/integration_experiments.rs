//! Integration: the paper's headline *shapes* at moderate scale. These are
//! the claims EXPERIMENTS.md reports; if one breaks, the reproduction is
//! broken even if every mechanism test passes.

use tilesim::coordinator::{case, experiment};
use tilesim::workloads::mergesort::Variant;

const SEED: u64 = experiment::DEFAULT_SEED;

/// Moderate size: big enough for every mechanism (hot spots, L2 overflow,
/// migrations) to engage, small enough for CI.
const N: u64 = 1 << 20;

#[test]
fn shape_fig1_localisation_wins_and_gap_grows() {
    let t = experiment::fig1(256_000, 63, &[1, 8, 32], SEED);
    let gap = |i: usize| t.rows[i].1[0] / t.rows[i].1[1]; // non-loc / loc
    assert!(gap(2) > 1.15, "localisation must win at 32 reps: {}", gap(2));
    assert!(gap(2) > gap(0), "gap must grow with reps");
}

#[test]
fn shape_fig2_localised_static_tops_the_chart() {
    let t = experiment::fig2(N, &[32], SEED);
    let row = &t.rows[0].1;
    let best_localised = row[6].max(row[7]); // case 7 or 8
    for (i, &v) in row.iter().enumerate().take(4) {
        assert!(
            best_localised > v,
            "localised+static must beat case {}: {} vs {}",
            i + 1,
            best_localised,
            v
        );
    }
}

#[test]
fn shape_fig2_local_homing_disaster_without_localisation() {
    let t = experiment::fig2(N, &[32], SEED);
    let row = &t.rows[0].1;
    // Case 4 (static, none, non-localised) must trail case 3 (static,
    // hash) clearly — the tile-0 hot spot.
    assert!(
        row[2] > row[3] * 1.2,
        "case3 {} must clearly beat case4 {}",
        row[2],
        row[3]
    );
}

#[test]
fn shape_speedup_scales_with_threads_for_good_cases() {
    let t = experiment::fig2(N, &[1, 8, 64], SEED);
    for case_ix in [2usize, 6, 7] {
        let s1 = t.rows[0].1[case_ix];
        let s8 = t.rows[1].1[case_ix];
        let s64 = t.rows[2].1[case_ix];
        assert!(s8 > s1 * 2.0, "case {} must scale 1->8", case_ix + 1);
        assert!(s64 > s8, "case {} must keep scaling 8->64", case_ix + 1);
    }
}

#[test]
fn shape_fig3_case8_overtakes_hash_with_size() {
    // Ratio of case8/case3 execution time must fall as size grows (the
    // aggregate-L3 crossover).
    let t = experiment::fig3(&[1 << 19, 1 << 22], 64, SEED);
    let ratio_small = t.rows[0].1[4] / t.rows[0].1[0];
    let ratio_big = t.rows[1].1[4] / t.rows[1].1[0];
    assert!(
        ratio_big < ratio_small,
        "case8 must gain on case3 with size: {ratio_small} -> {ratio_big}"
    );
    assert!(ratio_big < 1.0, "case8 must win outright at 4M: {ratio_big}");
}

#[test]
fn shape_fig3_intermediate_step_helps_but_less_than_localisation() {
    // At 4M (past the aggregate-L3 crossover) full localisation must beat
    // the intermediate-step-only optimisation; below it they are close
    // (paper Fig. 3 shows the same convergence at small sizes).
    let t = experiment::fig3(&[1 << 22], 64, SEED);
    let row = &t.rows[0].1; // [case3, case3+interm, case4, case7, case8]
    assert!(row[1] < row[0], "intermediate step must help case 3");
    assert!(row[4] < row[1], "full localisation must beat it at 4M");
}

#[test]
fn shape_fig4_striping_helps_at_32_threads_non_striped_upper_half() {
    let t = experiment::fig4(N, &[32], SEED);
    let row = &t.rows[0].1; // [c3 striped, c3 non, c8 striped, c8 non]
    // Case 8 is the DRAM-facing case: striping must help at 32 threads
    // (threads 0..31 reach only 2 controllers without striping).
    assert!(
        row[3] > row[2],
        "case8: non-striped {} must be slower than striped {} at 32t",
        row[3],
        row[2]
    );
}

#[test]
fn shape_fig4_striping_transparent_when_cache_absorbs() {
    // For case 3 (hash, everything in distributed L3) striping is near
    // transparent — within 15%.
    let t = experiment::fig4(N, &[64], SEED);
    let row = &t.rows[0].1;
    let rel = (row[1] - row[0]).abs() / row[0];
    assert!(rel < 0.15, "case3 striping effect should be small: {rel}");
}

#[test]
fn shape_migrations_are_costly_for_both_styles() {
    // §4: "the Tile Linux tries to migrate the threads during the
    // execution time, and those migrations are costly not only in terms of
    // cache misses but also because of the resulting delay." At test scale
    // runs are shorter than the default rebalance interval, so use an
    // aggressive load balancer to surface the effect the paper sees on
    // seconds-long runs.
    use tilesim::mem::MemConfig;
    use tilesim::sched::{StaticMapper, TileLinuxConfig, TileLinuxScheduler};
    use tilesim::sim::{Engine, EngineConfig};
    use tilesim::workloads::mergesort::{self, MergesortConfig};

    let run = |variant: Variant, policy, migrating: bool| {
        let mut e = Engine::new(EngineConfig::tilepro64(MemConfig {
            hash_policy: policy,
            striping: true,
        }));
        let mut p = mergesort::build(
            &mut e,
            &MergesortConfig {
                elems: N,
                threads: 32,
                variant,
            },
        );
        if migrating {
            let mut s = TileLinuxScheduler::new(TileLinuxConfig {
                check_interval: 100_000,
                migrate_prob: 0.5,
                seed: SEED,
            });
            e.run(&mut p, &mut s).unwrap()
        } else {
            e.run(&mut p, &mut StaticMapper::new()).unwrap()
        }
    };
    use tilesim::mem::HashPolicy;
    let loc_static = run(Variant::Localised, HashPolicy::None, false);
    let loc_churn = run(Variant::Localised, HashPolicy::None, true);
    let nl_static = run(Variant::NonLocalised, HashPolicy::AllButStack, false);
    let nl_churn = run(Variant::NonLocalised, HashPolicy::AllButStack, true);
    assert!(loc_churn.migrations > 0 && nl_churn.migrations > 0);
    let loc_penalty = loc_churn.makespan_cycles as f64 / loc_static.makespan_cycles as f64;
    let nonloc_penalty = nl_churn.makespan_cycles as f64 / nl_static.makespan_cycles as f64;
    assert!(
        loc_penalty > 1.1 && nonloc_penalty > 1.1,
        "migrations must cost real time: localised {loc_penalty:.3}, \
         non-localised {nonloc_penalty:.3}"
    );
}

#[test]
fn shape_variants_consistent_across_seeds() {
    // The qualitative ordering (case 8 beats case 2) must hold for several
    // Tile Linux seeds — it cannot be a lucky schedule.
    for seed in [1u64, 7, 2014] {
        let c2 = experiment::run_mergesort(&case(2), N / 2, 32, true, seed);
        let c8 = experiment::run_mergesort(&case(8), N / 2, 32, true, seed);
        assert!(
            (c8.makespan_cycles as f64) * 1.5 < c2.makespan_cycles as f64,
            "seed {seed}: case8 {} vs case2 {}",
            c8.makespan_cycles,
            c2.makespan_cycles
        );
    }
}

#[test]
fn shape_intermediate_for_local_homing_is_poor() {
    // §5.2: "The intermediate step has a poor performance (close to that
    // of Case 4) for the local homing policy" — ext_scr allocated by the
    // merging thread cannot amortise, while the non-localised leaf still
    // hammers tile 0.
    let interm_none = experiment::run_mergesort_variant(
        &case(4),
        Variant::NonLocalisedIntermediate,
        N,
        32,
        true,
        SEED,
    );
    let c4 = experiment::run_mergesort(&case(4), N, 32, true, SEED);
    let c8 = experiment::run_mergesort(&case(8), N, 32, true, SEED);
    let to_c4 = interm_none.makespan_cycles as f64 / c4.makespan_cycles as f64;
    assert!(
        (0.5..1.2).contains(&to_c4),
        "intermediate+none should be near case 4: ratio {to_c4}"
    );
    assert!(interm_none.makespan_cycles > c8.makespan_cycles);
}
