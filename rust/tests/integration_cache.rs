//! Integration: DDC cache system + coherence over realistic access mixes.

use std::sync::Arc;

use tilesim::arch::{Machine, TileId, NUM_TILES};
use tilesim::cache::{CacheSystem, ReadPlace, WriteLevel};
use tilesim::mem::{Homing, LineId};

fn sys() -> CacheSystem {
    CacheSystem::new(Arc::new(Machine::tilepro64()))
}

#[test]
fn distributed_l3_is_union_of_l2s() {
    // A 2 MB hash-homed array can't fit one L2 but fits the union: after a
    // full streaming pass by one reader, a second reader's misses are
    // mostly Home hits, not DDR.
    let mut s = sys();
    let homing = Homing::HashForHome;
    let lines = (2u64 << 20) / 64;
    for l in 0..lines {
        let line = LineId(l);
        let home = homing.home_of(line, NUM_TILES).unwrap();
        s.read(TileId(0), line, home);
    }
    let mut home_hits = 0;
    let mut ddr = 0;
    for l in 0..lines {
        let line = LineId(l);
        let home = homing.home_of(line, NUM_TILES).unwrap();
        match s.read(TileId(1), line, home) {
            ReadPlace::Home { .. } => home_hits += 1,
            ReadPlace::Ddr { .. } => ddr += 1,
            _ => {}
        }
    }
    assert!(
        home_hits > ddr * 5,
        "union L3 should serve the re-read: {home_hits} home vs {ddr} ddr"
    );
}

#[test]
fn single_home_tile_cannot_hold_large_array() {
    // Same 2 MB array homed on ONE tile: the second reader mostly misses
    // to DDR — the case 2 disaster in cache terms.
    let mut s = sys();
    let home = TileId(0);
    let lines = (2u64 << 20) / 64;
    for l in 0..lines {
        s.read(TileId(0), LineId(l), home);
    }
    let mut home_hits = 0u64;
    let mut ddr = 0u64;
    for l in 0..lines {
        match s.read(TileId(1), LineId(l), home) {
            ReadPlace::Home { .. } => home_hits += 1,
            ReadPlace::Ddr { .. } => ddr += 1,
            _ => {}
        }
    }
    assert!(
        ddr > home_hits * 5,
        "single 64 KB home can't hold 2 MB: {home_hits} home vs {ddr} ddr"
    );
}

#[test]
fn remote_reader_does_not_pollute_its_l2() {
    let mut s = sys();
    let home = TileId(9);
    for l in 0..1000 {
        s.read(TileId(0), LineId(l), home);
    }
    assert_eq!(
        s.tile(TileId(0)).l2.resident_lines(),
        0,
        "remote lines must not allocate in the reader's L2"
    );
    assert!(s.tile(TileId(0)).l1.resident_lines() > 0);
    assert!(s.tile(home).l2.resident_lines() > 0, "home L2 caches them");
}

#[test]
fn producer_consumer_coherence() {
    // Producer writes lines homed on itself; consumer reads them (home
    // hits); producer overwrites; consumer must see invalidations (its L1
    // copies die) and refetch.
    let mut s = sys();
    let producer = TileId(3);
    let consumer = TileId(60);
    for l in 0..64 {
        assert_eq!(
            s.write(producer, LineId(l), producer).level,
            WriteLevel::LocalL2
        );
    }
    for l in 0..64 {
        let out = s.read(consumer, LineId(l), producer);
        assert_eq!(out, ReadPlace::Home { home: producer });
    }
    // Consumer's L1 now warm.
    for l in 0..16 {
        assert_eq!(s.read(consumer, LineId(l), producer), ReadPlace::L1);
    }
    // Overwrite invalidates the consumer's copies.
    let mut invalidated = 0;
    for l in 0..64 {
        invalidated += s.write(producer, LineId(l), producer).invalidated;
    }
    assert!(invalidated >= 16, "consumer copies must be invalidated");
    for l in 0..16 {
        let out = s.read(consumer, LineId(l), producer);
        assert_ne!(out, ReadPlace::L1, "line {l}: stale L1 copy survived");
    }
}

#[test]
fn false_sharing_ping_pong() {
    // Two writers alternating on the same line invalidate each other every
    // time — the classic pathology the directory must capture.
    let mut s = sys();
    let home = TileId(0);
    let line = LineId(7);
    let mut total_inv = 0;
    for i in 0..20 {
        let writer = if i % 2 == 0 { TileId(1) } else { TileId(2) };
        // Writer reads first (gets a copy), then writes.
        s.read(writer, line, home);
        total_inv += s.write(writer, line, home).invalidated;
    }
    assert!(total_inv >= 18, "ping-pong must invalidate nearly every round");
}

#[test]
fn purge_cleans_all_tiles_and_directory() {
    let mut s = sys();
    for t in 0..8u32 {
        for l in 0..32 {
            s.read(TileId(t), LineId(l), TileId(0));
        }
    }
    s.purge_line_range(LineId(0), LineId(31));
    for t in 0..8u32 {
        assert_eq!(s.tile(TileId(t)).l1.resident_lines(), 0, "tile {t} L1");
    }
    assert_eq!(s.directory.tracked_lines(), 0);
}

#[test]
fn totals_are_consistent() {
    let mut s = sys();
    for l in 0..100 {
        s.read(TileId(0), LineId(l), TileId(0));
        s.read(TileId(0), LineId(l), TileId(0));
    }
    let (hits, misses) = s.totals();
    assert!(hits >= 100);
    assert!(misses >= 100);
}
