//! Differential replay pins for the streaming trace pipeline:
//!
//! 1. **Streamed == recorded.** For every workload × variant × homing mode
//!    at small N, replaying the streamed program and replaying its recorded
//!    `Vec<Op>` materialisation produce byte-identical `RunStats` JSON.
//! 2. **Fast path == reference walk.** The engine's page-run fast path is
//!    cycle-exact with the per-line walk for the same programs.
//!
//! Together these guarantee the streaming refactor changed *how* traces are
//! held in memory and *how fast* lines are accounted — never the numbers.

use std::rc::Rc;

use tilesim::coordinator::localise::{build_program, LocaliseConfig, ELEM_BYTES};
use tilesim::coordinator::ChunkKernel;
use tilesim::mem::{HashPolicy, MemConfig};
use tilesim::sched::{StaticMapper, TileLinuxScheduler};
use tilesim::sim::{Engine, EngineConfig, Program};
use tilesim::workloads::mergesort::{self, MergesortConfig, Variant};
use tilesim::workloads::microbench::{self, MicrobenchConfig};
use tilesim::workloads::radix::{self, RadixConfig};
use tilesim::workloads::{HistogramKernel, MapKernel};

const POLICIES: [HashPolicy; 2] = [HashPolicy::AllButStack, HashPolicy::None];

fn cfg(policy: HashPolicy) -> EngineConfig {
    EngineConfig::tilepro64(MemConfig {
        hash_policy: policy,
        striping: true,
    })
}

/// Replay `build`'s program streamed and recorded (on identically prepared
/// engines) and require byte-identical stats JSON; also replay it through
/// the per-line reference walk and require the same bytes again. Runs the
/// whole comparison twice: once on the paper-baseline config and once with
/// per-link mesh contention enabled (the link servers must be billed in
/// the same order by all three replays).
fn assert_differential(label: &str, policy: HashPolicy, build: &dyn Fn(&mut Engine) -> Program) {
    for links in [false, true] {
        let mk_cfg = || {
            let mut c = cfg(policy);
            c.contention.links = links;
            c
        };
        // Streamed replay on the page-run fast path.
        let mut e_stream = Engine::new(mk_cfg());
        let mut streamed = build(&mut e_stream);

        // Recorded replay: materialise the same streams to Vec<Op>, then run
        // on an engine with identical pre-run (prealloc) state.
        let mut e_rec = Engine::new(mk_cfg());
        let _ = build(&mut e_rec);
        let mut recorded =
            Program::from_ops(streamed.record(), streamed.num_slots, streamed.num_events);

        // Reference-walk replay (per-line translation, no bulk runs).
        let mut e_ref = Engine::new(mk_cfg().without_page_runs());
        let mut for_ref = build(&mut e_ref);

        let s_stream = e_stream
            .run(&mut streamed, &mut StaticMapper::new())
            .unwrap_or_else(|e| panic!("{label} streamed: {e}"));
        let s_rec = e_rec
            .run(&mut recorded, &mut StaticMapper::new())
            .unwrap_or_else(|e| panic!("{label} recorded: {e}"));
        let s_ref = e_ref
            .run(&mut for_ref, &mut StaticMapper::new())
            .unwrap_or_else(|e| panic!("{label} reference: {e}"));

        let js = s_stream.to_json().encode();
        assert_eq!(
            js,
            s_rec.to_json().encode(),
            "{label} ({policy:?}, links={links}): streamed vs recorded stats diverged"
        );
        assert_eq!(
            js,
            s_ref.to_json().encode(),
            "{label} ({policy:?}, links={links}): fast path vs reference walk diverged"
        );
        // The per-link traffic vectors are not part of the JSON record;
        // pin them directly — all three classes (requests, replies,
        // invalidations) must be billed in the same order by all replays.
        assert_eq!(
            s_stream.link_requests, s_ref.link_requests,
            "{label} ({policy:?}, links={links}): per-link traffic diverged"
        );
        assert_eq!(
            s_stream.link_reply_requests, s_ref.link_reply_requests,
            "{label} ({policy:?}, links={links}): reply-class traffic diverged"
        );
        assert_eq!(
            s_stream.link_inval_requests, s_ref.link_inval_requests,
            "{label} ({policy:?}, links={links}): invalidation-class traffic diverged"
        );
        assert_eq!(s_stream.links_modelled(), links);
    }
}

#[test]
fn microbench_streamed_equals_recorded() {
    for policy in POLICIES {
        for localised in [false, true] {
            assert_differential(
                &format!("microbench localised={localised}"),
                policy,
                &|e: &mut Engine| {
                    microbench::build(
                        e,
                        &MicrobenchConfig {
                            elems: 1 << 14,
                            threads: 8,
                            reps: 3,
                            localised,
                        },
                    )
                },
            );
        }
    }
}

#[test]
fn mergesort_streamed_equals_recorded_all_variants() {
    for policy in POLICIES {
        for variant in [
            Variant::NonLocalised,
            Variant::NonLocalisedIntermediate,
            Variant::Localised,
        ] {
            assert_differential(
                &format!("mergesort {variant:?}"),
                policy,
                &|e: &mut Engine| {
                    mergesort::build(
                        e,
                        &MergesortConfig {
                            elems: 1 << 13,
                            threads: 6,
                            variant,
                        },
                    )
                },
            );
        }
    }
}

#[test]
fn radix_streamed_equals_recorded() {
    for policy in POLICIES {
        for localised in [false, true] {
            assert_differential(
                &format!("radix localised={localised}"),
                policy,
                &|e: &mut Engine| {
                    radix::build(
                        e,
                        &RadixConfig {
                            elems: 1 << 13,
                            threads: 4,
                            digit_bits: 8,
                            localised,
                        },
                    )
                },
            );
        }
    }
}

#[test]
fn pingpong_streamed_equals_recorded() {
    // The falseshare workload is the heaviest user of the invalidation
    // fan-out billing: pin it across the streamed / recorded / reference
    // replays too (links=true exercises coherence-link billing).
    use tilesim::workloads::pingpong::{self, PingPongConfig};
    for policy in POLICIES {
        for localised in [false, true] {
            assert_differential(
                &format!("pingpong localised={localised}"),
                policy,
                &|e: &mut Engine| {
                    pingpong::build(
                        e,
                        &PingPongConfig {
                            elems: 1 << 12,
                            threads: 8,
                            passes: 3,
                            localised,
                        },
                    )
                },
            );
        }
    }
}

#[test]
fn chunk_kernels_streamed_equals_recorded() {
    for policy in POLICIES {
        for localised in [false, true] {
            let kernels: Vec<(&str, Rc<dyn ChunkKernel>)> = vec![
                (
                    "map",
                    Rc::new(MapKernel {
                        passes: 3,
                        flops_per_elem: 1,
                    }),
                ),
                ("histogram", Rc::new(HistogramKernel { passes: 3 })),
            ];
            for (name, kernel) in kernels {
                let kernel2 = kernel.clone();
                assert_differential(
                    &format!("kernel {name} localised={localised}"),
                    policy,
                    &move |e: &mut Engine| {
                        let input =
                            e.prealloc_touched(tilesim::arch::TileId(0), (1 << 13) * ELEM_BYTES);
                        build_program(
                            &input,
                            1 << 13,
                            &LocaliseConfig {
                                threads: 4,
                                localised,
                            },
                            kernel2.clone(),
                        )
                    },
                );
            }
        }
    }
}

#[test]
fn heterogeneous_fabric_streamed_equals_recorded_equals_reference() {
    // The fabric acceptance pin: on a machine with corner controllers,
    // a raised base service, an express row, and per-direction asymmetry,
    // the three replays (streamed fast path, recorded, per-line reference
    // walk) still produce byte-identical stats and per-link class vectors
    // — heterogeneous per-link billing must not depend on the line-
    // accounting path.
    use tilesim::arch::{FabricSpec, Machine};
    use tilesim::workloads::pingpong::{self, PingPongConfig};

    let fabric = FabricSpec::parse("ctrl=corners:base=3:express-row=0@0.5:dir=S@2").unwrap();
    let machine = std::sync::Arc::new(Machine::tilepro64().with_fabric(&fabric).unwrap());
    assert!(machine.fabric().uniform_service().is_none());

    let builds: Vec<(&str, Box<dyn Fn(&mut Engine) -> Program>)> = vec![
        (
            "mergesort",
            Box::new(|e: &mut Engine| {
                mergesort::build(
                    e,
                    &MergesortConfig {
                        elems: 1 << 13,
                        threads: 6,
                        variant: Variant::NonLocalised,
                    },
                )
            }),
        ),
        (
            "pingpong",
            Box::new(|e: &mut Engine| {
                pingpong::build(
                    e,
                    &PingPongConfig {
                        elems: 1 << 12,
                        threads: 8,
                        passes: 3,
                        localised: false,
                    },
                )
            }),
        ),
    ];
    for policy in POLICIES {
        for (label, build) in &builds {
            let mk_cfg = || {
                EngineConfig::for_machine(
                    machine.clone(),
                    MemConfig {
                        hash_policy: policy,
                        striping: true,
                    },
                )
            };
            let mut e_stream = Engine::new(mk_cfg());
            let mut streamed = build(&mut e_stream);
            let mut e_rec = Engine::new(mk_cfg());
            let _ = build(&mut e_rec);
            let mut recorded =
                Program::from_ops(streamed.record(), streamed.num_slots, streamed.num_events);
            let mut e_ref = Engine::new(mk_cfg().without_page_runs());
            let mut for_ref = build(&mut e_ref);

            let s_stream = e_stream
                .run(&mut streamed, &mut StaticMapper::new())
                .unwrap_or_else(|e| panic!("fabric {label} streamed: {e}"));
            let s_rec = e_rec
                .run(&mut recorded, &mut StaticMapper::new())
                .unwrap_or_else(|e| panic!("fabric {label} recorded: {e}"));
            let s_ref = e_ref
                .run(&mut for_ref, &mut StaticMapper::new())
                .unwrap_or_else(|e| panic!("fabric {label} reference: {e}"));

            let js = s_stream.to_json().encode();
            assert_eq!(
                js,
                s_rec.to_json().encode(),
                "fabric {label} ({policy:?}): streamed vs recorded"
            );
            assert_eq!(
                js,
                s_ref.to_json().encode(),
                "fabric {label} ({policy:?}): fast vs reference"
            );
            assert_eq!(
                s_stream.link_requests, s_ref.link_requests,
                "fabric {label} ({policy:?})"
            );
            assert_eq!(
                s_stream.link_reply_requests, s_ref.link_reply_requests,
                "fabric {label} ({policy:?})"
            );
            assert_eq!(
                s_stream.link_inval_requests, s_ref.link_inval_requests,
                "fabric {label} ({policy:?})"
            );
            assert!(s_stream.links_modelled());
        }
    }
}

#[test]
fn every_protocol_streamed_equals_recorded_equals_reference() {
    // The protocol layer's equivalence story: directory protocols now ride
    // the page-run fast path (one bulk transition per uniform same-page
    // run, per-line fallback on divergence), so the streamed fast path,
    // the recorded fast path, the per-line reference walk, *and* the
    // intra-run parallel engine must all produce byte-identical stats and
    // per-link class vectors for every protocol, not just the fused
    // default.
    use tilesim::coherence::ProtocolSpec;
    use tilesim::workloads::pingpong::{self, PingPongConfig};

    let builds: Vec<(&str, Box<dyn Fn(&mut Engine) -> Program>)> = vec![
        (
            "microbench",
            Box::new(|e: &mut Engine| {
                microbench::build(
                    e,
                    &MicrobenchConfig {
                        elems: 1 << 13,
                        threads: 8,
                        reps: 3,
                        localised: false,
                    },
                )
            }),
        ),
        (
            "mergesort",
            Box::new(|e: &mut Engine| {
                mergesort::build(
                    e,
                    &MergesortConfig {
                        elems: 1 << 12,
                        threads: 6,
                        variant: Variant::NonLocalised,
                    },
                )
            }),
        ),
        (
            "pingpong",
            Box::new(|e: &mut Engine| {
                pingpong::build(
                    e,
                    &PingPongConfig {
                        elems: 1 << 11,
                        threads: 8,
                        passes: 3,
                        localised: false,
                    },
                )
            }),
        ),
    ];
    for protocol in ProtocolSpec::all() {
        for (label, build) in &builds {
            let label = format!("{} under {}", label, protocol.label());
            let mk_cfg = || {
                let mut c = cfg(HashPolicy::AllButStack).with_protocol(protocol);
                c.contention.links = true;
                c.contention.coherence = true;
                c
            };
            let mut e_stream = Engine::new(mk_cfg());
            let mut streamed = build(&mut e_stream);
            let mut e_rec = Engine::new(mk_cfg());
            let _ = build(&mut e_rec);
            let mut recorded =
                Program::from_ops(streamed.record(), streamed.num_slots, streamed.num_events);
            let mut e_ref = Engine::new(mk_cfg().without_page_runs());
            let mut for_ref = build(&mut e_ref);
            let mut e_par = Engine::new(mk_cfg().with_intra_jobs(4));
            let mut for_par = build(&mut e_par);

            let s_stream = e_stream
                .run(&mut streamed, &mut StaticMapper::new())
                .unwrap_or_else(|e| panic!("{label} streamed: {e}"));
            let s_rec = e_rec
                .run(&mut recorded, &mut StaticMapper::new())
                .unwrap_or_else(|e| panic!("{label} recorded: {e}"));
            let s_ref = e_ref
                .run(&mut for_ref, &mut StaticMapper::new())
                .unwrap_or_else(|e| panic!("{label} reference: {e}"));
            let s_par = e_par
                .run(&mut for_par, &mut StaticMapper::new())
                .unwrap_or_else(|e| panic!("{label} parallel: {e}"));

            let js = s_stream.to_json().encode();
            assert_eq!(
                js,
                s_rec.to_json().encode(),
                "{label}: streamed vs recorded stats diverged"
            );
            assert_eq!(
                js,
                s_ref.to_json().encode(),
                "{label}: fast path vs reference walk diverged"
            );
            assert_eq!(
                js,
                s_par.to_json().encode(),
                "{label}: fast path vs intra-run parallel engine diverged"
            );
            assert_eq!(
                s_stream.link_requests, s_ref.link_requests,
                "{label}: per-link traffic diverged"
            );
            assert_eq!(
                s_stream.link_reply_requests, s_ref.link_reply_requests,
                "{label}: reply-class traffic diverged"
            );
            assert_eq!(
                s_stream.link_inval_requests, s_ref.link_inval_requests,
                "{label}: invalidation-class traffic diverged"
            );
        }
    }
}

#[test]
fn parallel_engine_streamed_equals_recorded_equals_reference() {
    // The intra-run parallel engine joins the equivalence triangle: a
    // streamed replay sharded across 4 epoch workers, a recorded replay
    // on 3 workers, and the sequential per-line reference walk must all
    // produce byte-identical stats JSON and per-link class vectors. The
    // reference walk stays the cycle-exactness oracle for the parallel
    // engine, exactly as it is for the page-run fast path.
    for policy in POLICIES {
        for links in [false, true] {
            let mk_cfg = || {
                let mut c = cfg(policy);
                c.contention.links = links;
                c
            };
            let build = |e: &mut Engine| {
                mergesort::build(
                    e,
                    &MergesortConfig {
                        elems: 1 << 13,
                        threads: 6,
                        variant: Variant::NonLocalised,
                    },
                )
            };
            let mut e_par = Engine::new(mk_cfg().with_intra_jobs(4));
            let mut streamed = build(&mut e_par);
            let mut e_rec = Engine::new(mk_cfg().with_intra_jobs(3));
            let _ = build(&mut e_rec);
            let mut recorded =
                Program::from_ops(streamed.record(), streamed.num_slots, streamed.num_events);
            let mut e_ref = Engine::new(mk_cfg().without_page_runs());
            let mut for_ref = build(&mut e_ref);

            let s_par = e_par
                .run(&mut streamed, &mut StaticMapper::new())
                .unwrap_or_else(|e| panic!("parallel streamed (links={links}): {e}"));
            let s_rec = e_rec
                .run(&mut recorded, &mut StaticMapper::new())
                .unwrap_or_else(|e| panic!("parallel recorded (links={links}): {e}"));
            let s_ref = e_ref
                .run(&mut for_ref, &mut StaticMapper::new())
                .unwrap_or_else(|e| panic!("reference (links={links}): {e}"));

            let js = s_par.to_json().encode();
            assert_eq!(
                js,
                s_rec.to_json().encode(),
                "({policy:?}, links={links}): parallel streamed vs parallel recorded"
            );
            assert_eq!(
                js,
                s_ref.to_json().encode(),
                "({policy:?}, links={links}): parallel engine vs reference walk"
            );
            assert_eq!(
                s_par.link_requests, s_ref.link_requests,
                "({policy:?}, links={links}): per-link traffic diverged"
            );
            assert_eq!(
                s_par.link_reply_requests, s_ref.link_reply_requests,
                "({policy:?}, links={links}): reply-class traffic diverged"
            );
            assert_eq!(
                s_par.link_inval_requests, s_ref.link_inval_requests,
                "({policy:?}, links={links}): invalidation-class traffic diverged"
            );
        }
    }
}

#[test]
fn streamed_equals_recorded_under_migrating_scheduler() {
    // The pull-based loop must interleave identically when the scheduler
    // migrates threads mid-run (same seed ⇒ same migration schedule).
    let build = |e: &mut Engine| {
        mergesort::build(
            e,
            &MergesortConfig {
                elems: 1 << 14,
                threads: 8,
                variant: Variant::Localised,
            },
        )
    };
    let mut e1 = Engine::new(cfg(HashPolicy::None));
    let mut streamed = build(&mut e1);
    let mut e2 = Engine::new(cfg(HashPolicy::None));
    let _ = build(&mut e2);
    let mut recorded = Program::from_ops(streamed.record(), streamed.num_slots, streamed.num_events);
    let s1 = e1
        .run(&mut streamed, &mut TileLinuxScheduler::with_seed(2014))
        .unwrap();
    let s2 = e2
        .run(&mut recorded, &mut TileLinuxScheduler::with_seed(2014))
        .unwrap();
    assert_eq!(s1.to_json().encode(), s2.to_json().encode());
}

#[test]
fn streamed_program_resident_bytes_bounded() {
    // The point of the pipeline: a streamed program keeps a bounded op
    // window while the recorded one holds the whole trace.
    let mut e = Engine::new(cfg(HashPolicy::None));
    let mut p = mergesort::build(
        &mut e,
        &MergesortConfig {
            elems: 1 << 16,
            threads: 4,
            variant: Variant::Localised,
        },
    );
    let ops = p.record();
    let recorded_bytes = Program::from_ops(ops, p.num_slots, p.num_events).resident_trace_bytes();
    assert!(
        p.resident_trace_bytes() * 10 < recorded_bytes,
        "streamed window {} should be far below materialised {}",
        p.resident_trace_bytes(),
        recorded_bytes
    );
}
