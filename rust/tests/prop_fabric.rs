//! Fabric-refactor pins:
//!
//! 1. **Uniform fabric == scalar path.** A `Fabric` built with a single
//!    uniform service value (the machine's `link_service`) bills
//!    identically — full `RunStats` JSON plus all three per-link traffic
//!    vectors — to the pre-refactor scalar billing (`fabric: None`),
//!    across workloads × machines × link/coherence settings. The refactor
//!    replaced the representation, not the numbers.
//! 2. **`EdgesEven` placement == the built-in controller layout**, so the
//!    placement ablation's baseline row is the pre-fabric machine.
//! 3. **`FabricSpec` round-trips** through `label()` for random generated
//!    specs.

use tilesim::arch::{CtrlPlacement, FabricSpec, MachineSpec};
use tilesim::coordinator::batch::{RunSpec, Workload};
use tilesim::util::prop;
use tilesim::util::rng::Rng;
use tilesim::workloads::mergesort::Variant;

fn random_machine(rng: &mut Rng) -> MachineSpec {
    match rng.below(4) {
        0 => MachineSpec::TilePro64,
        1 => MachineSpec::Epiphany16,
        2 => MachineSpec::Nuca256,
        _ => {
            let w = rng.range(2, 9) as u32;
            let h = rng.range(2, 9) as u32;
            MachineSpec::Custom {
                w,
                h,
                ctrls: rng.range(1, 1 + 2 * w as u64) as u32,
            }
        }
    }
}

fn random_workload(rng: &mut Rng) -> Workload {
    match rng.below(4) {
        0 => Workload::Mergesort {
            variant: match rng.below(3) {
                0 => Variant::NonLocalised,
                1 => Variant::NonLocalisedIntermediate,
                _ => Variant::Localised,
            },
        },
        1 => Workload::Microbench {
            reps: rng.range(1, 4) as u32,
        },
        2 => Workload::Radix { digit_bits: 8 },
        _ => Workload::PingPong {
            passes: rng.range(1, 4) as u32,
        },
    }
}

fn assert_same_stats(
    a: &tilesim::sim::RunStats,
    b: &tilesim::sim::RunStats,
    what: &str,
) -> prop::PropResult {
    prop::assert_eq_dbg(a.to_json().encode(), b.to_json().encode(), what)?;
    prop::assert_eq_dbg(a.link_requests.clone(), b.link_requests.clone(), what)?;
    prop::assert_eq_dbg(
        a.link_reply_requests.clone(),
        b.link_reply_requests.clone(),
        what,
    )?;
    prop::assert_eq_dbg(
        a.link_inval_requests.clone(),
        b.link_inval_requests.clone(),
        what,
    )
}

#[test]
fn prop_uniform_fabric_bills_like_the_scalar_path() {
    prop::check("uniform fabric == scalar link billing", 24, |rng| {
        let machine = random_machine(rng);
        let workload = random_workload(rng);
        let threads = rng.range(2, 9) as usize;
        let elems = ((1u64 << rng.range(11, 14)) + rng.below(512)).max(2 * threads as u64);
        let (links, coherence) = match rng.below(3) {
            0 => (false, false),
            1 => (true, false),
            _ => (true, true),
        };
        let mut scalar = RunSpec::mergesort(rng.range(1, 9) as u8, elems, threads, 7);
        scalar.workload = workload;
        scalar.machine = machine;
        scalar.link_contention = links;
        scalar.coherence_links = coherence;
        let mut uniform = scalar.clone();
        let base = machine.build().params.link_service;
        uniform.fabric = Some(FabricSpec::parse(&format!("base={base}")).unwrap());
        uniform.check_thread_capacity().map_err(|e| e.to_string())?;
        assert_same_stats(
            &scalar.execute(),
            &uniform.execute(),
            &format!("machine {} links={links} coherence={coherence}", machine.label()),
        )
    });
}

#[test]
fn prop_edges_placement_is_the_builtin_layout() {
    prop::check("ctrl=edges == built-in controllers", 12, |rng| {
        // epiphany16 is excluded: its single controller hangs off the east
        // edge (the Parallella eLink), which is *not* the EdgesEven layout.
        let machine = match random_machine(rng) {
            MachineSpec::Epiphany16 => MachineSpec::TilePro64,
            m => m,
        };
        let mut base = RunSpec::mergesort(3, 1 << 12, 4, 11);
        base.machine = machine;
        base.link_contention = true;
        base.coherence_links = true;
        let mut placed = base.clone();
        placed.fabric = Some(FabricSpec {
            ctrl: Some(CtrlPlacement::EdgesEven),
            ..FabricSpec::default()
        });
        assert_same_stats(
            &base.execute(),
            &placed.execute(),
            &format!("machine {}", machine.label()),
        )
    });
}

#[test]
fn prop_fabric_spec_round_trips_through_label() {
    prop::check("FabricSpec label round-trip", 64, |rng| {
        let mut clauses: Vec<String> = Vec::new();
        if rng.chance(0.4) {
            clauses.push(random_machine(rng).label());
        }
        if rng.chance(0.5) {
            let p = match rng.below(5) {
                0 => "edges".to_string(),
                1 => "sides".to_string(),
                2 => "corners".to_string(),
                3 => "interior".to_string(),
                _ => format!("{}+{}", rng.below(8), 8 + rng.below(8)),
            };
            clauses.push(format!("ctrl={p}"));
        }
        if rng.chance(0.5) {
            clauses.push(format!("base={}", rng.range(1, 9)));
        }
        for _ in 0..rng.below(3) {
            let factor = match rng.below(4) {
                0 => "0.5".to_string(),
                1 => "0.25".to_string(),
                2 => "2".to_string(),
                _ => "1.5".to_string(),
            };
            let rule = match rng.below(4) {
                0 => format!("express-row={}@{factor}", rng.below(8)),
                1 => format!("express-col={}@{factor}", rng.below(8)),
                2 => format!("edge@{factor}"),
                _ => format!(
                    "dir={}@{factor}",
                    ['E', 'W', 'N', 'S'][rng.below(4) as usize]
                ),
            };
            clauses.push(rule);
        }
        if clauses.is_empty() {
            clauses.push("ctrl=corners".into());
        }
        let text = clauses.join(":");
        let spec = FabricSpec::parse(&text).map_err(|e| format!("parse '{text}': {e}"))?;
        prop::assert_eq_dbg(spec.label(), text.clone(), "label")?;
        prop::assert_eq_dbg(
            FabricSpec::parse(&spec.label()).map_err(|e| e.to_string())?,
            spec,
            &format!("re-parse of '{text}'"),
        )
    });
}

#[test]
fn placement_strategies_produce_distinct_simulations() {
    // Deterministic companion to the prop tests: on a 16×16 grid the four
    // named placements give four distinct makespans for a DRAM-heavy sort.
    let mut seen = std::collections::HashSet::new();
    for p in ["edges", "sides", "corners", "interior"] {
        let mut spec = RunSpec::mergesort(3, 1 << 14, 16, 42);
        spec.machine = MachineSpec::Custom { w: 16, h: 16, ctrls: 4 };
        spec.link_contention = true;
        spec.coherence_links = true;
        spec.fabric = Some(FabricSpec::parse(&format!("ctrl={p}")).unwrap());
        let stats = spec.execute();
        assert!(
            seen.insert(stats.makespan_cycles),
            "placement {p} duplicated another placement's makespan"
        );
    }
}

#[test]
fn express_fabric_strictly_reduces_pingpong_link_queueing() {
    // The CI smoke's in-tree twin: widening row-0/col-0 express channels
    // must strictly reduce the non-localised ping-pong's forward link
    // queueing at every strength step, on both machine sizes.
    for machine in [MachineSpec::TilePro64, MachineSpec::Nuca256] {
        let mut last = u64::MAX;
        for strength in ["1", "0.5", "0.25"] {
            let mut spec = RunSpec::mergesort(4, 1 << 13, 16, 42);
            spec.workload = Workload::PingPong { passes: 4 };
            spec.machine = machine;
            spec.link_contention = true;
            spec.coherence_links = true;
            spec.fabric = Some(
                FabricSpec::parse(&format!(
                    "base=4:express-row=0@{strength}:express-col=0@{strength}"
                ))
                .unwrap(),
            );
            let q = spec.execute().link_queue_cycles;
            assert!(q > 0, "{} @{strength}: ping-pong must queue on links", machine.label());
            assert!(
                q < last,
                "{} @{strength}: expected strictly less queueing ({q} vs {last})",
                machine.label()
            );
            last = q;
        }
    }
}
