//! Integration: the replay engine end-to-end — fork–join programs,
//! failure injection, determinism, accounting identities.

use tilesim::arch::TileId;
use tilesim::mem::{AllocKind, HashPolicy, MemConfig};
use tilesim::sched::{StaticMapper, TileLinuxScheduler};
use tilesim::sim::{Engine, EngineConfig, EngineError, Loc, Program, TraceBuilder};

fn engine(policy: HashPolicy) -> Engine {
    Engine::new(EngineConfig::tilepro64(MemConfig {
        hash_policy: policy,
        striping: true,
    }))
}

#[test]
fn fork_join_diamond() {
    // t0 produces, t1 and t2 consume after a signal, t3 joins both.
    let mut e = engine(HashPolicy::None);
    let shared = e.prealloc_touched(TileId(0), 1 << 16);
    let mut t0 = TraceBuilder::new();
    t0.write(Loc::Abs(shared.addr), 1 << 16).signal(0);
    let mk_consumer = |ev_in: u32, ev_out: u32| {
        let mut b = TraceBuilder::new();
        b.wait(ev_in).read(Loc::Abs(shared.addr), 1 << 16).signal(ev_out);
        b
    };
    let mut t3 = TraceBuilder::new();
    t3.wait(1).wait(2).compute(100);
    let mut p = Program::from_builders(
        vec![t0, mk_consumer(0, 1), mk_consumer(0, 2), t3],
        0,
        3,
    );
    let stats = e.run(&mut p, &mut StaticMapper::new()).unwrap();
    // join thread must finish last-ish: after both consumers' signals.
    let t3_end = stats.thread_cycles[3];
    assert!(t3_end >= stats.thread_cycles[1].min(stats.thread_cycles[2]));
}

#[test]
fn deadlock_cycle_detected() {
    let mut a = TraceBuilder::new();
    a.wait(0).signal(1);
    let mut b = TraceBuilder::new();
    b.wait(1).signal(0);
    let mut p = Program::from_builders(vec![a, b], 0, 2);
    match engine(HashPolicy::None).run(&mut p, &mut StaticMapper::new()) {
        Err(EngineError::Deadlock(mut t)) => {
            t.sort();
            assert_eq!(t, vec![0, 1]);
        }
        other => panic!("expected deadlock, got {other:?}"),
    }
}

#[test]
fn double_free_is_reported() {
    let mut b = TraceBuilder::new();
    b.alloc(0, 4096, AllocKind::Heap).free(0).free(0);
    let mut p = Program::from_builders(vec![b], 1, 0);
    assert!(matches!(
        engine(HashPolicy::None).run(&mut p, &mut StaticMapper::new()),
        Err(EngineError::UnboundSlot { .. })
    ));
}

#[test]
fn accounting_identity_hits_sum_to_accesses() {
    let mut e = engine(HashPolicy::AllButStack);
    let r = e.prealloc_touched(TileId(0), 1 << 18);
    let mut builders = Vec::new();
    for i in 0..8u64 {
        let mut b = TraceBuilder::new();
        let part = Loc::Abs(r.addr.offset(i * (1 << 15)));
        b.read(part, 1 << 15).copy(part, part, 1 << 14);
        builders.push(b);
    }
    let mut p = Program::from_builders(builders, 0, 0);
    let stats = e.run(&mut p, &mut StaticMapper::new()).unwrap();
    assert_eq!(
        stats.l1_hits + stats.l2_hits + stats.home_hits + stats.ddr_accesses,
        stats.line_accesses,
        "every access must be attributed to exactly one level"
    );
}

#[test]
fn runs_are_bit_deterministic() {
    let build = || {
        let mut e = engine(HashPolicy::AllButStack);
        let r = e.prealloc_touched(TileId(0), 1 << 18);
        let mut builders = Vec::new();
        for i in 0..16u64 {
            let mut b = TraceBuilder::new();
            b.read(Loc::Abs(r.addr.offset(i * (1 << 14))), 1 << 14)
                .compute(1000)
                .write(Loc::Abs(r.addr.offset(i * (1 << 14))), 1 << 14);
            builders.push(b);
        }
        (e, Program::from_builders(builders, 0, 0))
    };
    let (e1, mut p1) = build();
    let (e2, mut p2) = build();
    let s1 = e1.run(&mut p1, &mut TileLinuxScheduler::with_seed(7)).unwrap();
    let s2 = e2.run(&mut p2, &mut TileLinuxScheduler::with_seed(7)).unwrap();
    assert_eq!(s1.makespan_cycles, s2.makespan_cycles);
    assert_eq!(s1.thread_cycles, s2.thread_cycles);
    assert_eq!(s1.migrations, s2.migrations);
}

#[test]
fn different_seeds_change_linux_schedule() {
    let build = || {
        let mut e = engine(HashPolicy::AllButStack);
        let r = e.prealloc_touched(TileId(0), 1 << 20);
        let mut builders = Vec::new();
        for i in 0..16u64 {
            let mut b = TraceBuilder::new();
            for _ in 0..32 {
                b.read(Loc::Abs(r.addr.offset(i * (1 << 16))), 1 << 16);
            }
            builders.push(b);
        }
        (e, Program::from_builders(builders, 0, 0))
    };
    let (e1, mut p1) = build();
    let (e2, mut p2) = build();
    let s1 = e1.run(&mut p1, &mut TileLinuxScheduler::with_seed(1)).unwrap();
    let s2 = e2.run(&mut p2, &mut TileLinuxScheduler::with_seed(2)).unwrap();
    assert_ne!(
        (s1.makespan_cycles, s1.migrations),
        (s2.makespan_cycles, s2.migrations),
        "different seeds should differ somewhere"
    );
}

#[test]
fn empty_program_completes() {
    let mut p = Program::from_builders(vec![TraceBuilder::new(); 4], 0, 0);
    let stats = engine(HashPolicy::None)
        .run(&mut p, &mut StaticMapper::new())
        .unwrap();
    assert_eq!(stats.makespan_cycles, 0);
    assert_eq!(stats.line_accesses, 0);
}

#[test]
fn makespan_dominated_by_slowest_thread() {
    let mut e = engine(HashPolicy::None);
    let r = e.prealloc_touched(TileId(0), 1 << 20);
    let mut heavy = TraceBuilder::new();
    for _ in 0..8 {
        heavy.read(Loc::Abs(r.addr), 1 << 20);
    }
    let mut light = TraceBuilder::new();
    light.read(Loc::Abs(r.addr), 64);
    let mut p = Program::from_builders(vec![heavy, light], 0, 0);
    let stats = e.run(&mut p, &mut StaticMapper::new()).unwrap();
    assert_eq!(stats.makespan_cycles, stats.thread_cycles[0]);
    assert!(stats.thread_cycles[1] < stats.thread_cycles[0] / 10);
}
