//! Integration: routing + contention + latency parameters.

use std::sync::Arc;

use tilesim::arch::{hops, HitLevel, LatencyParams, Machine, TileId};
use tilesim::noc::{xy_links, xy_path, ContentionConfig, ContentionModel};

fn model() -> ContentionModel {
    ContentionModel::new(ContentionConfig::default(), Arc::new(Machine::tilepro64()))
}

#[test]
fn latency_grows_with_route_length() {
    let m = Machine::tilepro64();
    let req = TileId(0);
    let mut last = 0;
    for dst in [0u32, 1, 9, 27, 63] {
        let lat = m.access_cycles(req, HitLevel::Home { home: TileId(dst) });
        assert!(lat >= last, "latency must be monotone in distance");
        last = lat;
    }
}

#[test]
fn route_length_matches_latency_hops() {
    let m = Machine::tilepro64();
    let p = LatencyParams::TILEPRO64;
    for (a, b) in [(0u32, 63u32), (5, 58), (12, 12)] {
        let path = xy_path(&m, TileId(a), TileId(b));
        let lat = m.access_cycles(TileId(a), HitLevel::Home { home: TileId(b) });
        let expect = p.l2_hit + p.noc_header + 2 * p.noc_hop * (path.len() as u64 - 1);
        assert_eq!(lat, expect);
        // The machine-aware latency agrees with the tilepro64-pinned twin
        // used by the AOT latency model.
        assert_eq!(lat, p.access_cycles(TileId(a), HitLevel::Home { home: TileId(b) }));
    }
}

#[test]
fn hot_home_throughput_limited_to_service_rate() {
    // Simulate 64 requesters in lockstep rounds hammering one home; the
    // aggregate completion rate must approach 1 line / service cycles.
    let mut m = model();
    let service = 2u64;
    let mut clocks = vec![0u64; 64];
    for _round in 0..200 {
        for t in 0..64 {
            let d = m.home_request(TileId(0), clocks[t], service);
            clocks[t] += 20 + d; // 20cy of base latency per access
        }
    }
    let makespan = *clocks.iter().max().unwrap();
    let total_reqs = 64 * 200;
    let ideal_serialised = total_reqs * service;
    assert!(
        makespan as f64 >= ideal_serialised as f64 * 0.85,
        "hot port must serialise: makespan {makespan} vs floor {ideal_serialised}"
    );
}

#[test]
fn spread_homes_scale_linearly() {
    // Same load spread over 64 homes: makespan stays near per-thread work.
    let mut m = model();
    let mut clocks = vec![0u64; 64];
    for _round in 0..200 {
        for t in 0..64 {
            let d = m.home_request(TileId(t as u32), clocks[t], 2);
            clocks[t] += 20 + d;
        }
    }
    let makespan = *clocks.iter().max().unwrap();
    assert!(
        makespan <= 200 * 22 + 1000,
        "no queueing expected when spread: {makespan}"
    );
}

#[test]
fn controllers_are_parallel_resources() {
    let mut m = model();
    // Saturate controller 0.
    for _ in 0..10_000 {
        m.ctrl_request(0, 0, 4);
    }
    // Controllers 1-3 unaffected.
    for c in 1..4 {
        assert_eq!(m.ctrl_request(c, 0, 4), 0);
    }
}

#[test]
fn mesh_is_symmetric_and_bounded() {
    let m = Machine::tilepro64();
    for a in 0..64u32 {
        for b in 0..64u32 {
            let h = m.hops(TileId(a), TileId(b));
            assert_eq!(h, m.hops(TileId(b), TileId(a)));
            assert_eq!(h, hops(TileId(a), TileId(b)), "preset helper must agree");
            assert!(h <= 14);
        }
    }
}

#[test]
fn shared_column_links_contend_across_threads() {
    // Eight requesters on row 0 all targeting the bottom-left corner: XY
    // routing funnels them into the same west/south column links, so the
    // later requests queue. The same traffic east-west spread across
    // distinct rows sees no link queueing.
    let machine = Arc::new(Machine::tilepro64());
    let mut funnel = ContentionModel::new(ContentionConfig::default(), machine.clone());
    let mut total_funnel = 0;
    for x in 1..8u32 {
        total_funnel += funnel.link_path_request(TileId(x), TileId(56), 0);
    }
    let mut spread = ContentionModel::new(ContentionConfig::default(), machine);
    let mut total_spread = 0;
    for y in 0..8u32 {
        // Row-local east routes: disjoint links per row.
        total_spread += spread.link_path_request(TileId(y * 8), TileId(y * 8 + 7), 0);
    }
    assert!(total_funnel > 0, "funnel must queue on shared links");
    assert_eq!(total_spread, 0, "disjoint rows must not contend");
}

#[test]
fn link_walk_scales_with_machine() {
    // The same logical route is longer on a bigger grid — and the link
    // servers are per-machine, sized by `num_links`.
    let big = Arc::new(Machine::nuca256());
    let mut m = ContentionModel::new(ContentionConfig::default(), big.clone());
    m.link_path_request(TileId(0), TileId(16 * 16 - 1), 0);
    assert_eq!(m.link_requests.len(), 4 * 256);
    assert_eq!(m.link_requests.iter().sum::<u64>(), 30);
    assert_eq!(xy_links(&big, TileId(0), TileId(255)).count(), 30);
}

#[test]
fn invalidation_fanout_links_scale_with_sharer_count() {
    // Sharer sets {0..n} on a 4×4 grid: fan-out + ack traffic equals
    // 2 * sum of home→sharer hop counts, and each extra sharer can only
    // add queueing. (Hand-computed single-set cases live in the
    // contention unit tests.)
    let grid = Arc::new(Machine::custom(4, 4, 2).unwrap());
    let home = TileId(5); // (1,1): asymmetric distances to the corners
    let mut last_delay = 0;
    for n in 1..=8u32 {
        let mut m = ContentionModel::new(ContentionConfig::default(), grid.clone());
        let victims: Vec<TileId> = (0..n)
            .map(TileId)
            .filter(|&t| t != home)
            .collect();
        let d = m.invalidation_fanout_request(home, &victims, 0);
        let expect: u64 = victims
            .iter()
            .map(|&v| 2 * grid.hops(home, v) as u64)
            .sum();
        assert_eq!(
            m.link_inval_requests.iter().sum::<u64>(),
            expect,
            "n={n}: round-trip link crossings must equal 2*sum(hops)"
        );
        assert!(d >= last_delay, "queueing must be monotone in fan-out size");
        last_delay = d;
    }
}

#[test]
fn prop_coherence_billing_is_zero_when_links_off() {
    // The satellite property: reply-path (and invalidation) billing is
    // identically zero — cycles, traffic, and server state — whenever
    // link contention is off, for random routes, times, and payloads.
    tilesim::util::prop::check("reply billing off without links", 128, |rng| {
        let machine = Arc::new(match rng.below(3) {
            0 => Machine::tilepro64(),
            1 => Machine::epiphany16(),
            _ => Machine::custom(
                rng.range(1, 9) as u32,
                rng.range(1, 9) as u32,
                1,
            )
            .expect("valid grid"),
        });
        let cfg = ContentionConfig {
            enabled: rng.chance(0.5),
            links: false,
            coherence: rng.chance(0.5),
        };
        let mut m = ContentionModel::new(cfg, machine.clone());
        let tiles = machine.num_tiles() as u64;
        for _ in 0..rng.range(1, 40) {
            let a = TileId(rng.below(tiles) as u32);
            let b = TileId(rng.below(tiles) as u32);
            let now = rng.below(1 << 20);
            let flits = rng.range(1, 9);
            tilesim::util::prop::assert_eq_dbg(
                m.reply_path_request(a, b, now, flits),
                0,
                "reply delay",
            )?;
            tilesim::util::prop::assert_eq_dbg(
                m.invalidation_fanout_request(a, &[b], now),
                0,
                "invalidation delay",
            )?;
        }
        tilesim::util::prop::assert_eq_dbg(m.reply_link_cycles, 0, "reply cycles")?;
        tilesim::util::prop::assert_eq_dbg(
            m.invalidation_link_cycles,
            0,
            "invalidation cycles",
        )?;
        tilesim::util::prop::assert_holds(
            m.link_reply_requests.iter().all(|&n| n == 0)
                && m.link_inval_requests.iter().all(|&n| n == 0),
            "coherence traffic counted without links",
        )?;
        // A forward request issued *after* the coherence calls must see an
        // empty server: the disabled calls must not have touched state.
        tilesim::util::prop::assert_eq_dbg(
            m.link_path_request(TileId(0), TileId(tiles as u32 - 1), 0),
            0,
            "forward request saw residual server state",
        )
    });
}

#[test]
fn prop_reply_billing_zero_when_engine_link_contention_off() {
    // End-to-end flavour of the same property: a whole engine run with
    // --no-link-contention reports zero reply/invalidation cycles and
    // empty class vectors, under random ping-pong-ish write loads.
    use tilesim::mem::{HashPolicy, MemConfig};
    use tilesim::sched::StaticMapper;
    use tilesim::sim::{EngineConfig, Loc, Program, TraceBuilder};

    tilesim::util::prop::check("engine reply billing off", 8, |rng| {
        let mut cfg = EngineConfig::tilepro64(MemConfig {
            hash_policy: HashPolicy::None,
            striping: true,
        });
        cfg.contention.links = false;
        cfg.contention.coherence = true; // inert without links
        let mut e = tilesim::sim::Engine::new(cfg);
        let r = e.prealloc_touched(TileId(0), 1 << 16);
        let threads = rng.range(2, 9) as usize;
        let mut builders = Vec::new();
        for _ in 0..threads {
            let mut b = TraceBuilder::new();
            for _ in 0..rng.range(1, 5) {
                b.write(Loc::Abs(r.addr), 1 << 14);
            }
            builders.push(b);
        }
        let mut p = Program::from_builders(builders, 0, 0);
        let stats = e.run(&mut p, &mut StaticMapper::new()).expect("run");
        tilesim::util::prop::assert_eq_dbg(stats.reply_link_cycles, 0, "reply cycles")?;
        tilesim::util::prop::assert_eq_dbg(
            stats.invalidation_link_cycles,
            0,
            "invalidation cycles",
        )?;
        tilesim::util::prop::assert_holds(
            stats.link_reply_requests.is_empty() && stats.link_inval_requests.is_empty(),
            "class vectors must stay empty without link contention",
        )
    });
}
