//! Integration: routing + contention + latency parameters.

use tilesim::arch::{hops, LatencyParams, HitLevel, TileId};
use tilesim::noc::{xy_path, ContentionConfig, ContentionModel};

#[test]
fn latency_grows_with_route_length() {
    let p = LatencyParams::TILEPRO64;
    let req = TileId(0);
    let mut last = 0;
    for dst in [0u32, 1, 9, 27, 63] {
        let lat = p.access_cycles(req, HitLevel::Home { home: TileId(dst) });
        assert!(lat >= last, "latency must be monotone in distance");
        last = lat;
    }
}

#[test]
fn route_length_matches_latency_hops() {
    let p = LatencyParams::TILEPRO64;
    for (a, b) in [(0u32, 63u32), (5, 58), (12, 12)] {
        let path = xy_path(TileId(a), TileId(b));
        let lat = p.access_cycles(TileId(a), HitLevel::Home { home: TileId(b) });
        let expect = p.l2_hit + p.noc_header + 2 * p.noc_hop * (path.len() as u64 - 1);
        assert_eq!(lat, expect);
    }
}

#[test]
fn hot_home_throughput_limited_to_service_rate() {
    // Simulate 64 requesters in lockstep rounds hammering one home; the
    // aggregate completion rate must approach 1 line / service cycles.
    let mut m = ContentionModel::new(ContentionConfig::default());
    let service = 2u64;
    let mut clocks = vec![0u64; 64];
    for _round in 0..200 {
        for t in 0..64 {
            let d = m.home_request(TileId(0), clocks[t], service);
            clocks[t] += 20 + d; // 20cy of base latency per access
        }
    }
    let makespan = *clocks.iter().max().unwrap();
    let total_reqs = 64 * 200;
    let ideal_serialised = total_reqs * service;
    assert!(
        makespan as f64 >= ideal_serialised as f64 * 0.85,
        "hot port must serialise: makespan {makespan} vs floor {ideal_serialised}"
    );
}

#[test]
fn spread_homes_scale_linearly() {
    // Same load spread over 64 homes: makespan stays near per-thread work.
    let mut m = ContentionModel::new(ContentionConfig::default());
    let mut clocks = vec![0u64; 64];
    for _round in 0..200 {
        for t in 0..64 {
            let d = m.home_request(TileId(t as u32), clocks[t], 2);
            clocks[t] += 20 + d;
        }
    }
    let makespan = *clocks.iter().max().unwrap();
    assert!(
        makespan <= 200 * 22 + 1000,
        "no queueing expected when spread: {makespan}"
    );
}

#[test]
fn controllers_are_parallel_resources() {
    let mut m = ContentionModel::new(ContentionConfig::default());
    // Saturate controller 0.
    for _ in 0..10_000 {
        m.ctrl_request(0, 0, 4);
    }
    // Controllers 1-3 unaffected.
    for c in 1..4 {
        assert_eq!(m.ctrl_request(c, 0, 4), 0);
    }
}

#[test]
fn mesh_is_symmetric_and_bounded() {
    for a in 0..64u32 {
        for b in 0..64u32 {
            let h = hops(TileId(a), TileId(b));
            assert_eq!(h, hops(TileId(b), TileId(a)));
            assert!(h <= 14);
        }
    }
}
