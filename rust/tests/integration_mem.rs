//! Integration: allocator + page table + homing + striping acting together.

use std::sync::Arc;

use tilesim::arch::{Machine, TileId, PAGE_BYTES};
use tilesim::mem::{
    AllocKind, Allocator, HashPolicy, Homing, LineId, MemConfig, Placement, VAddr,
};

fn alloc(policy: HashPolicy, striping: bool) -> Allocator {
    Allocator::new(
        Arc::new(Machine::tilepro64()),
        MemConfig {
            hash_policy: policy,
            striping,
        },
    )
}

#[test]
fn localisation_rehomes_through_alloc_copy_free_cycle() {
    // The full Algorithm 1 memory story: main's array stuck on tile 0,
    // worker allocates + first-touches a copy, frees it, and the reused
    // pages re-home for the next worker.
    let mut a = alloc(HashPolicy::None, true);
    let input = a.alloc(TileId(0), 1 << 20, AllocKind::Heap).unwrap();
    a.table.touch_region(input.addr, input.bytes, TileId(0));
    assert_eq!(
        a.table.home_of_line(input.addr.line()).unwrap(),
        Some(TileId(0))
    );

    let worker = TileId(42);
    let copy = a.alloc(worker, 1 << 16, AllocKind::Heap).unwrap();
    assert_eq!(a.table.resolve_home(copy.addr.line(), worker).unwrap(), worker);

    a.free(copy.addr).unwrap();
    let copy2 = a.alloc(TileId(7), 1 << 16, AllocKind::Heap).unwrap();
    assert_eq!(copy2.addr, copy.addr, "free list reuses the region");
    assert_eq!(
        a.table.resolve_home(copy2.addr.line(), TileId(7)).unwrap(),
        TileId(7),
        "re-homed on the new first toucher"
    );
}

#[test]
fn many_allocations_never_overlap() {
    let mut a = alloc(HashPolicy::AllButStack, true);
    let mut regions = Vec::new();
    for i in 0..200u64 {
        let r = a
            .alloc(TileId((i % 64) as u32), (i + 1) * 1000, AllocKind::Heap)
            .unwrap();
        regions.push(r);
    }
    let mut spans: Vec<(u64, u64)> = regions
        .iter()
        .map(|r| (r.addr.0, r.addr.0 + r.bytes))
        .collect();
    spans.sort();
    for w in spans.windows(2) {
        assert!(w[0].1 <= w[1].0, "overlap: {w:?}");
    }
}

#[test]
fn hash_policy_spreads_while_none_first_touches() {
    let mut hashed = alloc(HashPolicy::AllButStack, true);
    let r = hashed.alloc(TileId(0), PAGE_BYTES, AllocKind::Heap).unwrap();
    let homes: std::collections::HashSet<_> = (0..1024)
        .map(|i| {
            hashed
                .table
                .home_of_line(LineId(r.addr.line().0 + i))
                .unwrap()
                .unwrap()
        })
        .collect();
    assert!(homes.len() > 48, "hash-for-home spreads: {}", homes.len());

    let mut ft = alloc(HashPolicy::None, true);
    let r = ft.alloc(TileId(0), PAGE_BYTES, AllocKind::Heap).unwrap();
    let toucher = TileId(55);
    let homes: std::collections::HashSet<_> = (0..1024)
        .map(|i| ft.table.resolve_home(LineId(r.addr.line().0 + i), toucher).unwrap())
        .collect();
    assert_eq!(homes.len(), 1);
    assert!(homes.contains(&toucher));
}

#[test]
fn striping_vs_fixed_controller_traffic_split() {
    // Striped: a 1 MB region touches all four controllers roughly equally.
    let mut s = alloc(HashPolicy::None, true);
    let r = s.alloc(TileId(0), 1 << 20, AllocKind::Heap).unwrap();
    let mut counts = [0u32; 4];
    for i in 0..(1 << 20) / 64 {
        counts[s.table.controller_of_line(LineId(r.addr.line().0 + i)).unwrap() as usize] += 1;
    }
    let (min, max) = (counts.iter().min().unwrap(), counts.iter().max().unwrap());
    assert!(max - min <= max / 2, "striping should balance: {counts:?}");

    // Non-striped: single controller after first touch.
    let mut ns = alloc(HashPolicy::None, false);
    let r = ns.alloc(TileId(0), 1 << 20, AllocKind::Heap).unwrap();
    ns.table.touch_region(r.addr, r.bytes, TileId(0));
    let c0 = ns.table.controller_of_line(r.addr.line()).unwrap();
    for i in [100u64, 5_000, 16_000] {
        assert_eq!(
            ns.table.controller_of_line(LineId(r.addr.line().0 + i)).unwrap(),
            c0
        );
    }
}

#[test]
fn stack_allocations_home_on_owner_under_both_policies() {
    for policy in [HashPolicy::AllButStack, HashPolicy::None] {
        let mut a = alloc(policy, true);
        let r = a.alloc(TileId(9), 8 * 1024, AllocKind::Stack).unwrap();
        assert_eq!(
            a.table.home_of_line(r.addr.line()).unwrap(),
            Some(TileId(9)),
            "{policy:?}"
        );
    }
}

#[test]
fn explicit_remote_homing_supported() {
    // Remote homing (paper class II): page homed on a tile that is neither
    // the allocator nor the toucher.
    let mut a = alloc(HashPolicy::None, true);
    let r = a
        .alloc_with(
            TileId(0),
            4096,
            AllocKind::Heap,
            Homing::Single(TileId(33)),
            Placement::Striped,
        )
        .unwrap();
    assert_eq!(a.table.resolve_home(r.addr.line(), TileId(5)).unwrap(), TileId(33));
}

#[test]
fn page_rounding_accounts_high_water() {
    let mut a = alloc(HashPolicy::None, true);
    a.alloc(TileId(0), 1, AllocKind::Heap).unwrap();
    assert_eq!(a.high_water_bytes(), PAGE_BYTES);
    a.alloc(TileId(0), PAGE_BYTES + 1, AllocKind::Heap).unwrap();
    assert_eq!(a.high_water_bytes(), 3 * PAGE_BYTES);
}

#[test]
fn unmapped_lookup_fails_cleanly() {
    let a = alloc(HashPolicy::None, true);
    assert!(a.table.home_of_line(VAddr(1 << 30).line()).is_err());
}
