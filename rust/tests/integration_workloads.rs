//! Integration: workload trace generators — traffic accounting, reduction
//! tree structure, variant relationships.

use tilesim::coordinator::{case, experiment};
use tilesim::mem::{HashPolicy, MemConfig};
use tilesim::sched::StaticMapper;
use tilesim::sim::{Engine, EngineConfig};
use tilesim::workloads::mergesort::{self, MergesortConfig, Variant};
use tilesim::workloads::microbench::{self, MicrobenchConfig};

fn engine(policy: HashPolicy) -> Engine {
    Engine::new(EngineConfig::tilepro64(MemConfig {
        hash_policy: policy,
        striping: true,
    }))
}

#[test]
fn microbench_traffic_scales_linearly_with_reps() {
    let stats = |reps| {
        let mut e = engine(HashPolicy::None);
        let mut p = microbench::build(
            &mut e,
            &MicrobenchConfig {
                elems: 1 << 16,
                threads: 8,
                reps,
                localised: false,
            },
        );
        e.run(&mut p, &mut StaticMapper::new()).unwrap()
    };
    let s4 = stats(4);
    let s8 = stats(8);
    assert_eq!(s8.line_accesses, 2 * s4.line_accesses);
}

#[test]
fn localised_microbench_adds_exactly_one_copy_pass() {
    let count = |localised| {
        let mut e = engine(HashPolicy::None);
        let mut p = microbench::build(
            &mut e,
            &MicrobenchConfig {
                elems: 1 << 16,
                threads: 8,
                reps: 4,
                localised,
            },
        );
        e.run(&mut p, &mut StaticMapper::new()).unwrap().line_accesses
    };
    let non_loc = count(false);
    let loc = count(true);
    // One extra copy pass = 2 * elems/16 lines.
    assert_eq!(loc - non_loc, 2 * (1 << 16) / 16);
}

#[test]
fn mergesort_thread_sweep_same_traffic_order() {
    // Total traffic should not balloon with thread count (same total work,
    // one extra merge level per doubling).
    let lines = |threads| {
        let mut e = engine(HashPolicy::AllButStack);
        let mut p = mergesort::build(
            &mut e,
            &MergesortConfig {
                elems: 1 << 16,
                threads,
                variant: Variant::NonLocalised,
            },
        );
        e.run(&mut p, &mut StaticMapper::new()).unwrap().line_accesses
    };
    let t1 = lines(1);
    let t16 = lines(16);
    assert!(t16 < t1 * 2, "16-thread traffic {t16} vs serial {t1}");
}

#[test]
fn localised_variant_result_slot_chain_is_consistent() {
    // The root result of the localised tree is the last live slot: allocs
    // == frees + live (root ext_scr + nothing else).
    for threads in [2usize, 4, 8, 16] {
        let mut e = engine(HashPolicy::None);
        let mut p = mergesort::build(
            &mut e,
            &MergesortConfig {
                elems: 1 << 14,
                threads,
                variant: Variant::Localised,
            },
        );
        let stats = e.run(&mut p, &mut StaticMapper::new()).unwrap();
        // 2 preallocs (array0 + scratch0) + workload allocs.
        assert_eq!(
            stats.allocs - stats.frees,
            2 + 1,
            "threads={threads}: exactly the root ext_scr must stay live"
        );
    }
}

#[test]
fn intermediate_variant_sits_between() {
    // Traffic: intermediate < plain non-localised (no copy-back).
    // Allocation count: intermediate > plain (ext_scr per merge).
    let run = |variant| {
        let mut e = engine(HashPolicy::AllButStack);
        let mut p = mergesort::build(
            &mut e,
            &MergesortConfig {
                elems: 1 << 15,
                threads: 8,
                variant,
            },
        );
        e.run(&mut p, &mut StaticMapper::new()).unwrap()
    };
    let plain = run(Variant::NonLocalised);
    let interm = run(Variant::NonLocalisedIntermediate);
    assert!(interm.line_accesses < plain.line_accesses);
    assert!(interm.allocs > plain.allocs);
}

#[test]
fn one_thread_equals_pure_serial_sort() {
    // With one thread there are no events/waits and no parallel merges.
    let mut e = engine(HashPolicy::AllButStack);
    let mut p = mergesort::build(
        &mut e,
        &MergesortConfig {
            elems: 1 << 12,
            threads: 1,
            variant: Variant::NonLocalised,
        },
    );
    assert_eq!(p.threads.len(), 1);
    let stats = e.run(&mut p, &mut StaticMapper::new()).unwrap();
    assert!(stats.makespan_cycles > 0);
}

#[test]
fn experiment_helpers_cover_all_cases() {
    for id in 1..=8u8 {
        let c = case(id);
        let stats = experiment::run_mergesort(&c, 1 << 13, 4, true, experiment::DEFAULT_SEED);
        assert!(stats.makespan_cycles > 0, "case {id}");
    }
}

#[test]
fn microbench_63_threads_uneven_tail_part() {
    // 1M is not divisible by 63: the last thread gets the remainder, and
    // the program must still cover every element exactly once per rep.
    let mut e = engine(HashPolicy::None);
    let elems = 1_000_000u64;
    let mut p = microbench::build(
        &mut e,
        &MicrobenchConfig {
            elems,
            threads: 63,
            reps: 1,
            localised: false,
        },
    );
    let stats = e.run(&mut p, &mut StaticMapper::new()).unwrap();
    // One rep = read n + write n at line granularity; parts are
    // line-unaligned so allow per-thread straddle slack (+1 line per
    // boundary per stream).
    let lines = elems * 4 / 64;
    assert!(stats.line_accesses >= 2 * lines);
    assert!(stats.line_accesses <= 2 * lines + 4 * 63);
}
