//! Integration: the PJRT request path against real AOT artifacts.
//!
//! Requires `make artifacts` (skipped with a message otherwise, so plain
//! `cargo test` works before the python side has run).

use tilesim::arch::{HitLevel, LatencyParams, TileId};
use tilesim::runtime::{
    artifacts_dir, AccessDesc, ArtifactSet, ChunkedSorter, LatencyModel, BATCH,
};
use tilesim::util::rng::Rng;

fn load() -> Option<ArtifactSet> {
    let dir = artifacts_dir();
    match ArtifactSet::load(&dir) {
        Ok(set) => Some(set),
        Err(e) => {
            eprintln!("SKIP runtime tests: {e} (run `make artifacts`)");
            None
        }
    }
}

#[test]
fn artifacts_manifest_lists_all_four() {
    let Some(set) = load() else { return };
    let mut names = set.names();
    names.sort();
    assert_eq!(
        names,
        vec!["full_sort", "latency_model", "merge_pass", "sort_chunks"]
    );
}

#[test]
fn sorter_sorts_one_batch_exactly() {
    let Some(set) = load() else { return };
    let sorter = ChunkedSorter::new(&set).unwrap();
    let mut rng = Rng::new(3);
    let data = rng.i32_vec(BATCH);
    let got = sorter.sort_batch(&data).unwrap();
    let mut want = data;
    want.sort_unstable();
    assert_eq!(got, want, "PJRT bitonic sorter != std sort");
}

#[test]
fn sorter_handles_arbitrary_lengths() {
    let Some(set) = load() else { return };
    let sorter = ChunkedSorter::new(&set).unwrap();
    let mut rng = Rng::new(4);
    for n in [0usize, 1, 1000, BATCH - 1, BATCH, BATCH + 1, 3 * BATCH + 17] {
        let data = rng.i32_vec(n);
        let (got, metrics) = sorter.sort(&data).unwrap();
        let mut want = data;
        want.sort_unstable();
        assert_eq!(got, want, "n={n}");
        assert_eq!(metrics.dispatches as usize, n.div_ceil(BATCH));
    }
}

#[test]
fn sorter_handles_extremes_and_duplicates() {
    let Some(set) = load() else { return };
    let sorter = ChunkedSorter::new(&set).unwrap();
    let mut data = vec![i32::MAX; BATCH / 2];
    data.extend(vec![i32::MIN; BATCH / 2]);
    data.extend(vec![0i32; 100]);
    let (got, _) = sorter.sort(&data).unwrap();
    let mut want = data;
    want.sort_unstable();
    assert_eq!(got, want);
}

#[test]
fn latency_model_matches_rust_params_exactly() {
    // The cross-layer drift check: the AOT'd JAX closed form must agree
    // with arch::LatencyParams on every hit level and random tile pairs.
    let Some(set) = load() else { return };
    let model = LatencyModel::new(&set).unwrap();
    let params = LatencyParams::TILEPRO64;
    let mut rng = Rng::new(5);
    let mut accesses = Vec::new();
    let mut expected = Vec::new();
    for _ in 0..256 {
        let req = TileId(rng.below(64) as u32);
        let dst = TileId(rng.below(64) as u32);
        let level = match rng.below(4) {
            0 => HitLevel::L1,
            1 => HitLevel::L2,
            2 => HitLevel::Home { home: dst },
            _ => HitLevel::Ddr { ctrl_attach: dst },
        };
        expected.push(params.access_cycles(req, level) as f32);
        accesses.push(AccessDesc::from_hit(req, level));
    }
    let (per, total) = model.batch(&accesses).unwrap();
    for (i, (got, want)) in per.iter().zip(&expected).enumerate() {
        assert!(
            (got - want).abs() < 1e-3,
            "access {i}: jax {got} vs rust {want} ({:?})",
            accesses[i]
        );
    }
    // Total covers the whole padded batch (pads are L1 = 2.0 cycles).
    let pad = (1024 - accesses.len()) as f32 * 2.0;
    let want_total: f32 = expected.iter().sum::<f32>() + pad;
    assert!(
        (total - want_total).abs() / want_total < 1e-5,
        "total {total} vs {want_total}"
    );
}

#[test]
fn latency_model_contention_term_is_additive() {
    let Some(set) = load() else { return };
    let model = LatencyModel::new(&set).unwrap();
    let base = AccessDesc {
        req: TileId(0),
        dst: TileId(63),
        level: tilesim::runtime::latency::LEVEL_HOME,
        contention: 0.0,
    };
    let loaded = AccessDesc {
        contention: 123.5,
        ..base
    };
    let (per, _) = model.batch(&[base, loaded]).unwrap();
    assert!((per[1] - per[0] - 123.5).abs() < 1e-3);
}

#[test]
fn manifest_rejects_truncated_artifact() {
    // Corrupt a copy of the artifacts dir: size mismatch must fail load.
    let Some(_) = load() else { return };
    let src = artifacts_dir();
    let dst = std::env::temp_dir().join(format!("tilesim-corrupt-{}", std::process::id()));
    std::fs::create_dir_all(&dst).unwrap();
    for entry in std::fs::read_dir(&src).unwrap() {
        let entry = entry.unwrap();
        if entry.file_name().to_string_lossy().ends_with(".stamp") {
            continue;
        }
        std::fs::copy(entry.path(), dst.join(entry.file_name())).unwrap();
    }
    // Truncate one artifact.
    let victim = dst.join("merge_pass.hlo.txt");
    let text = std::fs::read_to_string(&victim).unwrap();
    std::fs::write(&victim, &text[..text.len() / 2]).unwrap();
    let msg = match ArtifactSet::load(&dst) {
        Ok(_) => panic!("corrupted artifacts must not load"),
        Err(err) => format!("{err}"),
    };
    assert!(msg.contains("size mismatch"), "got: {msg}");
    std::fs::remove_dir_all(&dst).ok();
}

#[test]
fn e2e_throughput_smoke() {
    // The end-to-end path moves real data at a sane rate (sanity bound
    // only; perf numbers live in benches/perf_engine.rs).
    let Some(set) = load() else { return };
    let sorter = ChunkedSorter::new(&set).unwrap();
    let mut rng = Rng::new(6);
    let data = rng.i32_vec(2 * BATCH);
    let t0 = std::time::Instant::now();
    let (sorted, _) = sorter.sort(&data).unwrap();
    let dt = t0.elapsed();
    assert!(sorted.windows(2).all(|w| w[0] <= w[1]));
    assert!(
        dt.as_secs() < 30,
        "2-batch sort took {dt:?} — request path is broken"
    );
}
