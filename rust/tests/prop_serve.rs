//! Property tests for the serve front-end (own driver — see util::prop).
//!
//! Each case simulates a full scenario, so case counts stay modest; the
//! properties themselves are exact (no statistical tolerance):
//!
//! - percentiles are ordered: p50 ≤ p99 ≤ p999 ≤ max (nearest rank over
//!   one sorted vector is monotone in p);
//! - completed throughput never exceeds offered (both rates are empirical:
//!   completed ≤ arrived and makespan ≥ last arrival);
//! - latency is pointwise monotone in offered load for a fixed FIFO
//!   scenario (same seed ⇒ same uniform draws ⇒ higher ρ rescales every
//!   gap down ⇒ every request waits at least as long);
//! - an empty-arrival scenario is an all-zero report, not a panic.

use tilesim::coordinator::batch::RunSpec;
use tilesim::serve::{ArrivalSpec, BatchPolicy, ServeScenario};
use tilesim::util::prop::{self, assert_holds};
use tilesim::util::rng::Rng;

/// A random but valid scenario, small enough that one case is a handful of
/// engine replays (service times are memoised per batch size).
fn random_scenario(rng: &mut Rng) -> ServeScenario {
    let threads = if rng.chance(0.5) { 2 } else { 4 };
    let elems = if rng.chance(0.5) { 1 << 9 } else { 1 << 10 };
    let policy = if rng.chance(0.5) {
        BatchPolicy::Immediate
    } else {
        BatchPolicy::Batch {
            max: rng.range(2, 8) as u32,
            wait: rng.below(1 << 14),
        }
    };
    let arrival = if rng.chance(0.5) {
        ArrivalSpec::Poisson
    } else {
        ArrivalSpec::Bursty {
            burst: rng.range(2, 8) as u32,
        }
    };
    ServeScenario::new(
        RunSpec::mergesort(8, elems, threads, rng.next_u64()),
        arrival,
        0.2 + rng.f64() * 2.3,
        rng.below(48),
        1 + rng.below(64) as usize,
        policy,
    )
}

#[test]
fn prop_percentiles_are_ordered_and_requests_conserved() {
    prop::check("serve percentile ordering", 12, |rng| {
        let s = random_scenario(rng);
        s.check().map_err(|e| e.to_string())?;
        let r = s.simulate(1);
        assert_holds(r.p50_cycles <= r.p99_cycles, "p50 > p99")?;
        assert_holds(r.p99_cycles <= r.p999_cycles, "p99 > p999")?;
        assert_holds(r.p999_cycles <= r.max_cycles, "p999 > max")?;
        assert_holds(
            r.completed + r.dropped == s.requests,
            "every request must complete or drop",
        )?;
        assert_holds(
            r.max_batch_served <= s.policy.max_batch() as u64,
            "batch above the policy cap",
        )
    });
}

#[test]
fn prop_completed_throughput_never_exceeds_offered() {
    prop::check("serve throughput conservation", 12, |rng| {
        let s = random_scenario(rng);
        let r = s.simulate(1);
        // Exact, not approximate: completed ≤ arrived, makespan ≥ last
        // arrival, and f64 multiply/divide round monotonically.
        assert_holds(
            r.completed_rps <= r.offered_rps,
            &format!("completed {} > offered {}", r.completed_rps, r.offered_rps),
        )
    });
}

#[test]
fn prop_latency_is_monotone_in_offered_load() {
    prop::check("serve load monotonicity", 10, |rng| {
        // Fixed FIFO scenario (no drops, no batching) at two loads sharing
        // a seed: the higher load's latency digest dominates rung by rung.
        let lo = ServeScenario::new(
            RunSpec::mergesort(8, 1 << 9, 4, rng.next_u64()),
            if rng.chance(0.5) {
                ArrivalSpec::Poisson
            } else {
                ArrivalSpec::Bursty { burst: 4 }
            },
            0.2 + rng.f64() * 1.2,
            24,
            1 << 20,
            BatchPolicy::Immediate,
        );
        let mut hi = lo.clone();
        hi.rho = lo.rho + 0.1 + rng.f64() * 1.5;
        let rl = lo.simulate(1);
        let rh = hi.simulate(1);
        assert_holds(rl.dropped == 0 && rh.dropped == 0, "unbounded queue dropped")?;
        for (a, b, what) in [
            (rl.p50_cycles, rh.p50_cycles, "p50"),
            (rl.p99_cycles, rh.p99_cycles, "p99"),
            (rl.p999_cycles, rh.p999_cycles, "p999"),
            (rl.max_cycles, rh.max_cycles, "max"),
        ] {
            assert_holds(
                a <= b,
                &format!("{what} fell from {a} to {b} as rho rose {} -> {}", lo.rho, hi.rho),
            )?;
        }
        assert_holds(
            rl.mean_cycles <= rh.mean_cycles,
            "mean latency fell under higher load",
        )
    });
}

#[test]
fn prop_empty_arrivals_yield_all_zero_report() {
    prop::check("serve empty scenario", 32, |rng| {
        let mut s = random_scenario(rng);
        s.requests = 0;
        let r = s.simulate(1);
        assert_holds(
            r.completed == 0
                && r.dropped == 0
                && r.batches == 0
                && r.makespan_cycles == 0
                && r.p50_cycles == 0
                && r.max_cycles == 0
                && r.mean_cycles == 0.0
                && r.offered_rps == 0.0
                && r.completed_rps == 0.0,
            "empty scenario must be the zero report",
        )
    });
}
