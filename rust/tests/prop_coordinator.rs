//! Property tests over the coordinator: generated programs always
//! validate, never deadlock, and obey accounting identities; the
//! localisation transform preserves the access semantics.

use tilesim::arch::TileId;
use tilesim::coordinator::localise::{build_program, LocaliseConfig, ELEM_BYTES};
use tilesim::mem::{HashPolicy, MemConfig};
use tilesim::sched::{StaticMapper, TileLinuxScheduler};
use tilesim::sim::{Engine, EngineConfig, Loc, TraceBuilder};
use std::rc::Rc;
use tilesim::util::prop::{self, assert_holds};
use tilesim::workloads::mergesort::{self, MergesortConfig, Variant};
use tilesim::workloads::microbench::{self, MicrobenchConfig};

fn engine(policy: HashPolicy, striping: bool) -> Engine {
    Engine::new(EngineConfig::tilepro64(MemConfig {
        hash_policy: policy,
        striping,
    }))
}

fn rand_policy(rng: &mut tilesim::util::rng::Rng) -> HashPolicy {
    if rng.chance(0.5) {
        HashPolicy::AllButStack
    } else {
        HashPolicy::None
    }
}

#[test]
fn prop_mergesort_programs_always_complete() {
    prop::check("mergesort completes", 24, |rng| {
        let threads = 1 + rng.below(16) as usize;
        let elems = (threads as u64 * 2).max(1 << rng.range(8, 13));
        let variant = match rng.below(3) {
            0 => Variant::NonLocalised,
            1 => Variant::NonLocalisedIntermediate,
            _ => Variant::Localised,
        };
        let mut e = engine(rand_policy(rng), rng.chance(0.5));
        let mut p = mergesort::build(&mut e, &MergesortConfig { elems, threads, variant });
        p.validate().map_err(|e| e.to_string())?;
        let stats = if rng.chance(0.5) {
            e.run(&mut p, &mut StaticMapper::new())
        } else {
            e.run(&mut p, &mut TileLinuxScheduler::with_seed(rng.next_u64()))
        }
        .map_err(|e| e.to_string())?;
        assert_holds(stats.makespan_cycles > 0, "zero makespan")?;
        assert_holds(
            stats.l1_hits + stats.l2_hits + stats.home_hits + stats.ddr_accesses
                == stats.line_accesses,
            "level accounting broken",
        )?;
        assert_holds(
            *stats.thread_cycles.iter().max().unwrap() == stats.makespan_cycles,
            "makespan != max thread clock",
        )
    });
}

#[test]
fn prop_microbench_traffic_formula() {
    // Non-localised traffic is exactly reps * (read+write) lines of the
    // touched ranges; localised adds exactly one copy pass.
    prop::check("microbench traffic", 24, |rng| {
        let threads = 1 + rng.below(32) as usize;
        let elems = (threads as u64 * 16).max(1 << rng.range(10, 15));
        let reps = 1 + rng.below(8) as u32;
        let count = |localised: bool| -> Result<u64, String> {
            let mut e = engine(HashPolicy::None, true);
            let mut p = microbench::build(
                &mut e,
                &MicrobenchConfig { elems, threads, reps, localised },
            );
            Ok(e.run(&mut p, &mut StaticMapper::new())
                .map_err(|e| e.to_string())?
                .line_accesses)
        };
        let non_loc = count(false)?;
        let loc = count(true)?;
        assert_holds(non_loc % reps as u64 == 0, "rep traffic must divide evenly")?;
        let one_pass = non_loc / reps as u64;
        // Parts are element-aligned while local copies are page-aligned,
        // so each thread's copy may straddle ±1 line per stream.
        let delta = loc - non_loc;
        assert_holds(
            delta >= one_pass.saturating_sub(2 * threads as u64)
                && delta <= one_pass + 2 * threads as u64,
            &format!("copy adds ~one pass: delta {delta} vs pass {one_pass}"),
        )
    });
}

#[test]
fn prop_localisation_preserves_kernel_traffic_shape() {
    // For any generated scan/compute kernel, the localised program issues
    // the same kernel accesses (plus the copy) and always terminates.
    prop::check("localise transform", 24, |rng| {
        let threads = 1 + rng.below(16) as usize;
        let elems = (threads as u64).max(1 << rng.range(8, 14));
        let passes = 1 + rng.below(6) as u32;
        let writes = rng.chance(0.5);
        let kernel: Rc<dyn tilesim::coordinator::ChunkKernel> =
            Rc::new(move |t: &mut TraceBuilder, chunk: Loc, bytes: u64, _i: usize| {
                for _ in 0..passes {
                    t.read(chunk, bytes);
                    if writes {
                        t.write(chunk, bytes);
                    }
                }
            });
        let mut run = |localised: bool| -> Result<tilesim::sim::RunStats, String> {
            let mut e = engine(rand_policy(rng), true);
            let input = e.prealloc_touched(TileId(0), elems * ELEM_BYTES);
            let mut p = build_program(
                &input,
                elems,
                &LocaliseConfig { threads, localised },
                kernel.clone(),
            );
            p.validate().map_err(|e| e.to_string())?;
            e.run(&mut p, &mut StaticMapper::new()).map_err(|e| e.to_string())
        };
        let conv = run(false)?;
        let loc = run(true)?;
        // Kernel traffic is preserved; localisation adds roughly one copy
        // pass (read+write), modulo per-thread line-alignment straddle.
        let per_pass = conv.line_accesses / (passes as u64 * if writes { 2 } else { 1 });
        let delta = loc.line_accesses - conv.line_accesses;
        // Sub-line chunks make the per-pass estimate loose (straddled reads
        // count double); bound the copy delta generously but meaningfully.
        assert_holds(
            delta >= threads as u64 && delta <= 2 * per_pass + 4 * threads as u64,
            &format!(
                "copy delta {delta} outside [threads, 2*pass+4t] (pass {per_pass}, threads {threads})"
            ),
        )?;
        assert_holds(loc.frees as usize == threads, "step 5 must free every chunk")
    });
}

#[test]
fn prop_seeded_runs_replay_exactly() {
    prop::check("determinism", 12, |rng| {
        let seed = rng.next_u64();
        let threads = 2 + rng.below(8) as usize;
        let elems = 1u64 << 12;
        let run = || {
            let mut e = engine(HashPolicy::AllButStack, true);
            let mut p = mergesort::build(
                &mut e,
                &MergesortConfig { elems, threads, variant: Variant::Localised },
            );
            e.run(&mut p, &mut TileLinuxScheduler::with_seed(seed))
                .map_err(|e| e.to_string())
        };
        let a = run()?;
        let b = run()?;
        prop::assert_eq_dbg(a.makespan_cycles, b.makespan_cycles, "makespan")?;
        prop::assert_eq_dbg(a.thread_cycles, b.thread_cycles, "clocks")?;
        prop::assert_eq_dbg(a.migrations, b.migrations, "migrations")
    });
}

#[test]
fn prop_localised_never_slower_with_more_reuse() {
    // The benefit of localisation is monotone in reuse count (under local
    // homing with static mapping): more passes can only widen the ratio.
    prop::check("reuse monotonicity", 8, |rng| {
        let threads = 4 + rng.below(12) as usize;
        let elems = 1u64 << 16;
        let ratio = |passes: u32| -> Result<f64, String> {
            let run = |localised| -> Result<u64, String> {
                let mut e = engine(HashPolicy::None, true);
                let input = e.prealloc_touched(TileId(0), elems * ELEM_BYTES);
                let kernel = move |t: &mut TraceBuilder, chunk: Loc, bytes: u64, _i: usize| {
                    for _ in 0..passes {
                        t.read(chunk, bytes);
                    }
                };
                let mut p = build_program(
                    &input,
                    elems,
                    &LocaliseConfig { threads, localised },
                    Rc::new(kernel),
                );
                Ok(e.run(&mut p, &mut StaticMapper::new())
                    .map_err(|e| e.to_string())?
                    .makespan_cycles)
            };
            Ok(run(false)? as f64 / run(true)? as f64)
        };
        let low = ratio(2)?;
        let high = ratio(16)?;
        assert_holds(
            high > low,
            &format!("ratio must grow with reuse: {low:.3} -> {high:.3}"),
        )
    });
}
