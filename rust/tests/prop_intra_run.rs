//! Intra-run parallelism pins: the epoch driver (`--intra-jobs`) is an
//! execution strategy, not a model change, so `RunStats` must be
//! **byte-identical** — the full JSON record, including every per-link
//! and per-class vector and all coherence counters — at *every* worker
//! count, for every workload × protocol × links-on/off combination.
//!
//! Worker count 7 is deliberately prime and not a divisor of the tile
//! count: chunk boundaries land mid-row, which is where a merge-order
//! bug would show.

use tilesim::coherence::ProtocolSpec;
use tilesim::mem::{HashPolicy, MemConfig};
use tilesim::sched::{StaticMapper, TileLinuxScheduler};
use tilesim::sim::{plan_intra_workers, Engine, EngineConfig, Program};
use tilesim::workloads::mergesort::{self, MergesortConfig, Variant};
use tilesim::workloads::microbench::{self, MicrobenchConfig};
use tilesim::workloads::pingpong::{self, PingPongConfig};
use tilesim::workloads::radix::{self, RadixConfig};

const WORKER_COUNTS: [usize; 4] = [1, 2, 4, 7];

/// Replay `build`'s program at every worker count (on identically
/// prepared engines) and require byte-identical stats JSON plus
/// identical per-link class vectors against the sequential (1-worker)
/// replay.
fn assert_intra_identical(
    label: &str,
    mk_cfg: &dyn Fn() -> EngineConfig,
    build: &dyn Fn(&mut Engine) -> Program,
) {
    let mut baseline: Option<(String, Vec<u64>, Vec<u64>, Vec<u64>)> = None;
    for workers in WORKER_COUNTS {
        let mut e = Engine::new(mk_cfg().with_intra_jobs(workers));
        let mut p = build(&mut e);
        let stats = e
            .run(&mut p, &mut StaticMapper::new())
            .unwrap_or_else(|err| panic!("{label} intra-jobs={workers}: {err}"));
        let row = (
            stats.to_json().encode(),
            stats.link_requests.clone(),
            stats.link_reply_requests.clone(),
            stats.link_inval_requests.clone(),
        );
        match &baseline {
            None => baseline = Some(row),
            Some(b) => {
                assert_eq!(
                    b.0, row.0,
                    "{label}: stats JSON diverged at intra-jobs={workers}"
                );
                assert_eq!(
                    b.1, row.1,
                    "{label}: per-link request traffic diverged at intra-jobs={workers}"
                );
                assert_eq!(
                    b.2, row.2,
                    "{label}: reply-class traffic diverged at intra-jobs={workers}"
                );
                assert_eq!(
                    b.3, row.3,
                    "{label}: invalidation-class traffic diverged at intra-jobs={workers}"
                );
            }
        }
    }
}

/// Every workload the paper replays, on the default protocol, links off
/// and on: the full grid the issue pins.
#[test]
fn all_workloads_byte_identical_across_worker_counts() {
    type Build = Box<dyn Fn(&mut Engine) -> Program>;
    let builds: Vec<(&str, Build)> = vec![
        (
            "mergesort non-localised",
            Box::new(|e: &mut Engine| {
                mergesort::build(
                    e,
                    &MergesortConfig {
                        elems: 1 << 13,
                        threads: 6,
                        variant: Variant::NonLocalised,
                    },
                )
            }),
        ),
        (
            "mergesort localised",
            Box::new(|e: &mut Engine| {
                mergesort::build(
                    e,
                    &MergesortConfig {
                        elems: 1 << 13,
                        threads: 6,
                        variant: Variant::Localised,
                    },
                )
            }),
        ),
        (
            "microbench",
            Box::new(|e: &mut Engine| {
                microbench::build(
                    e,
                    &MicrobenchConfig {
                        elems: 1 << 13,
                        threads: 8,
                        reps: 3,
                        localised: false,
                    },
                )
            }),
        ),
        (
            "pingpong",
            Box::new(|e: &mut Engine| {
                pingpong::build(
                    e,
                    &PingPongConfig {
                        elems: 1 << 12,
                        threads: 8,
                        passes: 3,
                        localised: false,
                    },
                )
            }),
        ),
        (
            "radix",
            Box::new(|e: &mut Engine| {
                radix::build(
                    e,
                    &RadixConfig {
                        elems: 1 << 12,
                        threads: 4,
                        digit_bits: 8,
                        localised: true,
                    },
                )
            }),
        ),
    ];
    for policy in [HashPolicy::AllButStack, HashPolicy::None] {
        for links in [false, true] {
            for (label, build) in &builds {
                let mk_cfg = move || {
                    let mut c = EngineConfig::tilepro64(MemConfig {
                        hash_policy: policy,
                        striping: true,
                    });
                    c.contention.links = links;
                    c
                };
                assert_intra_identical(
                    &format!("{label} ({policy:?}, links={links})"),
                    &mk_cfg,
                    build,
                );
            }
        }
    }
}

/// Directory protocols now *compose* with the epoch driver (phase-A
/// quanta are protocol-action-free by the eligibility preconditions), so
/// this is a genuine parallel-vs-sequential pin: stats stay
/// byte-identical at any requested worker count under every protocol —
/// the opaque home permutation included.
#[test]
fn protocols_byte_identical_across_worker_counts() {
    for protocol in ProtocolSpec::all() {
        let mk_cfg = move || {
            let mut c = EngineConfig::tilepro64(MemConfig {
                hash_policy: HashPolicy::AllButStack,
                striping: true,
            })
            .with_protocol(protocol);
            c.contention.links = true;
            c.contention.coherence = true;
            c
        };
        assert_intra_identical(
            &format!("mergesort under {}", protocol.label()),
            &mk_cfg,
            &|e: &mut Engine| {
                mergesort::build(
                    e,
                    &MergesortConfig {
                        elems: 1 << 12,
                        threads: 6,
                        variant: Variant::NonLocalised,
                    },
                )
            },
        );
        // A localised, write-heavy workload too: its own-homed pages are
        // exactly what phase A admits, so this leg actually runs protocol
        // quanta in parallel rather than fencing everything to phase B.
        assert_intra_identical(
            &format!("localised microbench under {}", protocol.label()),
            &mk_cfg,
            &|e: &mut Engine| {
                microbench::build(
                    e,
                    &MicrobenchConfig {
                        elems: 1 << 13,
                        threads: 8,
                        reps: 3,
                        localised: true,
                    },
                )
            },
        );
    }
}

/// The caches-off bandwidth mode routes everything through shared
/// servers; the planner keeps it sequential, and the stats must not
/// notice a requested worker count.
#[test]
fn caches_off_byte_identical_across_worker_counts() {
    let mk_cfg = || {
        EngineConfig::tilepro64(MemConfig {
            hash_policy: HashPolicy::None,
            striping: true,
        })
        .without_caches()
    };
    assert_intra_identical("microbench caches-off", &mk_cfg, &|e: &mut Engine| {
        microbench::build(
            e,
            &MicrobenchConfig {
                elems: 1 << 13,
                threads: 8,
                reps: 3,
                localised: false,
            },
        )
    });
}

/// A migrating scheduler is dynamic: the run must fall back to the
/// sequential engine (same seed ⇒ identical stats at every requested
/// worker count).
#[test]
fn migrating_scheduler_forces_sequential_fallback() {
    let build = |e: &mut Engine| {
        mergesort::build(
            e,
            &MergesortConfig {
                elems: 1 << 13,
                threads: 8,
                variant: Variant::Localised,
            },
        )
    };
    let mut baseline = None;
    for workers in WORKER_COUNTS {
        let mut e = Engine::new(
            EngineConfig::tilepro64(MemConfig {
                hash_policy: HashPolicy::None,
                striping: true,
            })
            .with_intra_jobs(workers),
        );
        let mut p = build(&mut e);
        let stats = e
            .run(&mut p, &mut TileLinuxScheduler::with_seed(2014))
            .unwrap();
        let js = stats.to_json().encode();
        match &baseline {
            None => baseline = Some(js),
            Some(b) => assert_eq!(b, &js, "migrating sched diverged at intra-jobs={workers}"),
        }
    }
}

/// The planner's gating table, pinned row by row: worker count 1 (or any
/// violated precondition) routes through the sequential path.
#[test]
fn worker_planning_gating_table() {
    // requested <= 1 never parallelises.
    assert_eq!(plan_intra_workers(0, 64, true, false, false, true), 1);
    assert_eq!(plan_intra_workers(1, 64, true, false, false, true), 1);
    // All preconditions met: granted, clamped to the tile count.
    assert_eq!(plan_intra_workers(4, 64, true, false, false, true), 4);
    assert_eq!(plan_intra_workers(128, 64, true, false, false, true), 64);
    // Active protocols and the opaque home permutation are deliberate
    // non-gates: phase-A quanta are protocol-action-free and the scan
    // judges permuted homes, so both compose with the epoch driver.
    assert_eq!(plan_intra_workers(4, 64, true, true, false, true), 4);
    assert_eq!(plan_intra_workers(4, 64, true, false, true, true), 4);
    assert_eq!(plan_intra_workers(4, 64, true, true, true, true), 4);
    // Each genuinely violated precondition alone forces sequential.
    assert_eq!(plan_intra_workers(4, 64, false, false, false, true), 1);
    assert_eq!(plan_intra_workers(4, 64, true, false, false, false), 1);
}
