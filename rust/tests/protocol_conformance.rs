//! Conformance suite for the pluggable coherence protocols.
//!
//! Pins the contracts the protocol API redesign promises:
//!
//! 1. **Pinned baseline.** The default protocol (write-invalidate, the
//!    fused billing path the paper's figures were recorded on) is
//!    byte-identical whether it is left unspecified or named explicitly.
//! 2. **Links-off collapse.** A directory protocol only engages on the
//!    coherence link servers; with the links off every non-opaque protocol
//!    replays byte-identically to the default.
//! 3. **Counter hygiene.** Each per-protocol counter moves only under the
//!    protocols that define it, and the JSON record gates the new fields
//!    on non-zero values so baseline records keep their exact shape.
//! 4. **Determinism.** Every protocol replays byte-identically under the
//!    same seed, and the opaque wrapper's permutation is a pure function
//!    of its seed.

use tilesim::coherence::ProtocolSpec;
use tilesim::mem::{HashPolicy, MemConfig};
use tilesim::sched::StaticMapper;
use tilesim::sim::{Engine, EngineConfig, RunStats};
use tilesim::workloads::mergesort::{self, MergesortConfig, Variant};
use tilesim::workloads::microbench::{self, MicrobenchConfig};
use tilesim::workloads::pingpong::{self, PingPongConfig};

fn cfg(protocol: ProtocolSpec, links: bool) -> EngineConfig {
    let mut c = EngineConfig::tilepro64(MemConfig {
        hash_policy: HashPolicy::AllButStack,
        striping: true,
    })
    .with_protocol(protocol);
    c.contention.links = links;
    c.contention.coherence = links;
    c
}

fn run_microbench(protocol: ProtocolSpec, links: bool) -> RunStats {
    let mut e = Engine::new(cfg(protocol, links));
    let mut p = microbench::build(
        &mut e,
        &MicrobenchConfig {
            elems: 1 << 13,
            threads: 8,
            reps: 4,
            localised: false,
        },
    );
    e.run(&mut p, &mut StaticMapper::new()).expect("microbench")
}

fn run_pingpong(protocol: ProtocolSpec, links: bool) -> RunStats {
    let mut e = Engine::new(cfg(protocol, links));
    let mut p = pingpong::build(
        &mut e,
        &PingPongConfig {
            elems: 1 << 11,
            threads: 8,
            passes: 4,
            localised: false,
        },
    );
    e.run(&mut p, &mut StaticMapper::new()).expect("pingpong")
}

fn run_mergesort(protocol: ProtocolSpec, links: bool) -> RunStats {
    let mut e = Engine::new(cfg(protocol, links));
    let mut p = mergesort::build(
        &mut e,
        &MergesortConfig {
            elems: 1 << 12,
            threads: 6,
            variant: Variant::NonLocalised,
        },
    );
    e.run(&mut p, &mut StaticMapper::new()).expect("mergesort")
}

#[test]
fn explicit_default_protocol_is_byte_identical() {
    // The acceptance pin: naming the default protocol must not perturb a
    // single byte of the baseline record, links on or off.
    let named = ProtocolSpec::parse("write-invalidate").unwrap();
    for links in [false, true] {
        let base = run_microbench(ProtocolSpec::default(), links);
        let explicit = run_microbench(named, links);
        assert_eq!(
            base.to_json().encode(),
            explicit.to_json().encode(),
            "links={links}"
        );
    }
}

#[test]
fn links_off_collapses_every_directory_protocol_to_the_default() {
    for workload in [run_microbench, run_pingpong, run_mergesort] {
        let base = workload(ProtocolSpec::default(), false).to_json().encode();
        for p in ProtocolSpec::all() {
            if p.permutes_homes() {
                continue; // opaque re-homes lines even with the links off
            }
            assert_eq!(
                workload(p, false).to_json().encode(),
                base,
                "protocol {} must be inert with the links off",
                p.label()
            );
        }
    }
}

#[test]
fn every_protocol_is_deterministic() {
    for p in ProtocolSpec::all() {
        let a = run_pingpong(p, true).to_json().encode();
        let b = run_pingpong(p, true).to_json().encode();
        assert_eq!(a, b, "protocol {} must replay identically", p.label());
    }
}

#[test]
fn upgrade_counters_move_only_under_their_protocols() {
    // Microbench: each thread re-writes its private output chunk every
    // rep, so sole-sharer upgrades fire under MSI/MESI/MOESI while the
    // fused default path never counts one. No cross-thread sharing means
    // write-update has nobody to fan out to.
    let by_label: Vec<(String, RunStats)> = ProtocolSpec::all()
        .into_iter()
        .map(|p| (p.label(), run_microbench(p, true)))
        .collect();
    for (label, s) in &by_label {
        match label.as_str() {
            "write-invalidate" | "opaque" => {
                assert_eq!(s.upgrade_hits, 0, "{label}");
                assert_eq!(s.owner_replies, 0, "{label}");
                assert_eq!(s.update_fanout_cycles, 0, "{label}");
            }
            "msi" | "mesi" | "moesi" => {
                assert!(s.upgrade_hits > 0, "{label} must count upgrades");
                assert_eq!(s.update_fanout_cycles, 0, "{label}");
            }
            "write-update" => {
                assert_eq!(s.upgrade_hits, 0, "{label}");
                assert_eq!(
                    s.update_fanout_cycles, 0,
                    "{label}: private chunks leave nobody to update"
                );
            }
            other => panic!("unlabelled protocol {other}"),
        }
    }
}

#[test]
fn shared_lines_engage_update_fanout_and_owner_replies() {
    // The non-localised ping-pong writes adjacent-thread-shared lines:
    // write-update must fan updates out to the other sharers, and MOESI's
    // dirty owners must source replies instead of the home.
    let wu = run_pingpong(ProtocolSpec::parse("write-update").unwrap(), true);
    assert!(
        wu.update_fanout_cycles > 0,
        "write-update must bill update fan-out on shared lines"
    );
    let moesi = run_pingpong(ProtocolSpec::parse("moesi").unwrap(), true);
    assert!(
        moesi.owner_replies > 0,
        "moesi must source replies from dirty owners"
    );
    let mesi = run_pingpong(ProtocolSpec::parse("mesi").unwrap(), true);
    assert_eq!(mesi.owner_replies, 0, "mesi flushes through the home");
}

#[test]
fn json_record_gates_the_new_counters() {
    // Baseline records must keep their exact shape: the per-protocol
    // counters appear only when non-zero.
    let base = run_microbench(ProtocolSpec::default(), true).to_json().encode();
    for key in ["upgrade_hits", "owner_replies", "update_fanout_cycles"] {
        assert!(!base.contains(key), "baseline JSON must omit {key}");
    }
    let msi = run_microbench(ProtocolSpec::parse("msi").unwrap(), true)
        .to_json()
        .encode();
    assert!(msi.contains("upgrade_hits"));
}

#[test]
fn page_run_fast_path_matches_reference_walk_for_every_protocol() {
    // The perf-cliff fix: directory protocols now batch uniform same-page
    // runs through the bulk hooks (one directory view + one transition
    // per run). The per-line walk stays the oracle — for every protocol ×
    // workload the fast path must be byte-identical, per-link class
    // vectors included. The workloads cover both regimes: microbench's
    // private streams batch cleanly, while the non-localised ping-pong
    // and mergesort interleave sharers so runs diverge mid-page and the
    // per-line fallback must splice in without a cycle of drift.
    type Runner = fn(ProtocolSpec, bool) -> RunStats;
    let runners: [(&str, Runner); 3] = [
        ("microbench", run_microbench),
        ("pingpong", run_pingpong),
        ("mergesort", run_mergesort),
    ];
    for p in ProtocolSpec::all() {
        for (wl, runner) in runners {
            let fast = runner(p, true);
            let mut e = Engine::new(cfg(p, true).without_page_runs());
            let reference = match wl {
                "microbench" => {
                    let mut prog = microbench::build(
                        &mut e,
                        &MicrobenchConfig {
                            elems: 1 << 13,
                            threads: 8,
                            reps: 4,
                            localised: false,
                        },
                    );
                    e.run(&mut prog, &mut StaticMapper::new()).unwrap()
                }
                "pingpong" => {
                    let mut prog = pingpong::build(
                        &mut e,
                        &PingPongConfig {
                            elems: 1 << 11,
                            threads: 8,
                            passes: 4,
                            localised: false,
                        },
                    );
                    e.run(&mut prog, &mut StaticMapper::new()).unwrap()
                }
                _ => {
                    let mut prog = mergesort::build(
                        &mut e,
                        &MergesortConfig {
                            elems: 1 << 12,
                            threads: 6,
                            variant: Variant::NonLocalised,
                        },
                    );
                    e.run(&mut prog, &mut StaticMapper::new()).unwrap()
                }
            };
            let label = format!("{wl} under {}", p.label());
            assert_eq!(
                fast.to_json().encode(),
                reference.to_json().encode(),
                "{label}: page-run fast path vs per-line reference walk"
            );
            assert_eq!(
                fast.link_requests, reference.link_requests,
                "{label}: per-link traffic"
            );
            assert_eq!(
                fast.link_reply_requests, reference.link_reply_requests,
                "{label}: reply-class traffic"
            );
            assert_eq!(
                fast.link_inval_requests, reference.link_inval_requests,
                "{label}: invalidation-class traffic"
            );
        }
    }
}

#[test]
fn fast_path_keeps_protocol_counter_hygiene() {
    // Batching must not double- or under-count the per-protocol counters:
    // the bulk hooks emit one aggregate that is *applied per line*, so
    // upgrade_hits / owner_replies / update_fanout_cycles match the
    // per-line walk exactly — and the zero/absent JSON gates stay intact.
    for p in ProtocolSpec::all() {
        let fast = run_pingpong(p, true);
        let mut e = Engine::new(cfg(p, true).without_page_runs());
        let mut prog = pingpong::build(
            &mut e,
            &PingPongConfig {
                elems: 1 << 11,
                threads: 8,
                passes: 4,
                localised: false,
            },
        );
        let reference = e.run(&mut prog, &mut StaticMapper::new()).unwrap();
        let label = p.label();
        assert_eq!(fast.upgrade_hits, reference.upgrade_hits, "{label}");
        assert_eq!(fast.owner_replies, reference.owner_replies, "{label}");
        assert_eq!(
            fast.update_fanout_cycles, reference.update_fanout_cycles,
            "{label}"
        );
        assert_eq!(fast.invalidations, reference.invalidations, "{label}");
    }
}

#[test]
fn opaque_is_a_pure_function_of_its_seed() {
    let a = run_mergesort(ProtocolSpec::parse("opaque").unwrap(), true);
    let b = run_mergesort(ProtocolSpec::parse("opaque").unwrap(), true);
    assert_eq!(a.to_json().encode(), b.to_json().encode());
    let other_seed = run_mergesort(ProtocolSpec::parse("opaque@7").unwrap(), true);
    assert_ne!(
        a.to_json().encode(),
        other_seed.to_json().encode(),
        "a different opaque seed must re-home the traffic"
    );
    let base = run_mergesort(ProtocolSpec::default(), true);
    assert_ne!(
        a.to_json().encode(),
        base.to_json().encode(),
        "the permutation must move homes off the identity"
    );
}
