//! The batch orchestrator's core contract: an identical `SweepSpec` + seed
//! must yield byte-identical `ResultStore` JSON at `--jobs 1` and
//! `--jobs N`. Every downstream consumer (EXPERIMENTS.md numbers, the CI
//! perf trajectory, sweep diffing between PRs) leans on this.

use tilesim::coordinator::batch::{derive_seeds, BatchRunner, SweepSpec, Workload};
use tilesim::coordinator::experiment;
use tilesim::workloads::mergesort::Variant;

const SEED: u64 = experiment::DEFAULT_SEED;

#[test]
fn table1_sweep_json_identical_across_jobs() {
    let spec = experiment::table1_spec(1 << 14, 4, SEED);
    let serial = BatchRunner::new(1).run(&spec).to_json(&spec).encode();
    for jobs in [2usize, 4, 8] {
        let parallel = BatchRunner::new(jobs).run(&spec).to_json(&spec).encode();
        assert_eq!(serial, parallel, "jobs={jobs} changed the sweep JSON");
    }
}

#[test]
fn grid_sweep_json_identical_across_jobs() {
    let spec = SweepSpec::grid(
        "determinism grid",
        &[1, 4, 8],
        &[
            Workload::Mergesort {
                variant: Variant::NonLocalised,
            },
            Workload::Mergesort {
                variant: Variant::Localised,
            },
        ],
        &[1 << 12, 1 << 13],
        &[2, 4],
        &derive_seeds(SEED, 2),
    );
    assert_eq!(spec.runs.len(), 3 * 2 * 2 * 2 * 2, "full cross product");
    let a = BatchRunner::new(1).run(&spec).to_json(&spec).encode();
    let b = BatchRunner::new(8).run(&spec).to_json(&spec).encode();
    assert_eq!(a, b, "grid sweep must not depend on worker count");
}

#[test]
fn microbench_grid_deterministic_too() {
    let spec = SweepSpec::grid(
        "microbench grid",
        &[1, 8],
        &[Workload::Microbench { reps: 3 }],
        &[1 << 13],
        &[4],
        &derive_seeds(7, 1),
    );
    let a = BatchRunner::new(1).run(&spec).to_json(&spec).encode();
    let b = BatchRunner::new(4).run(&spec).to_json(&spec).encode();
    assert_eq!(a, b);
}

#[test]
fn fig_spec_tables_match_across_jobs() {
    // The rendered tables (what the paper figures are built from) must be
    // identical too — same floats, same order.
    let spec = experiment::fig1_spec(1 << 13, 4, &[1, 4], SEED);
    let t1 = BatchRunner::new(1).table(&spec);
    let tn = BatchRunner::new(4).table(&spec);
    assert_eq!(t1.render(), tn.render());
    assert_eq!(t1.to_json().encode(), tn.to_json().encode());
}

#[test]
fn derived_seeds_are_reproducible() {
    assert_eq!(derive_seeds(SEED, 16), derive_seeds(SEED, 16));
    // A prefix of a longer derivation equals the shorter one: run count
    // changes must not reshuffle earlier runs' seeds.
    assert_eq!(derive_seeds(SEED, 16)[..8], derive_seeds(SEED, 8)[..]);
}

#[test]
fn repeated_sweeps_are_bit_identical() {
    // Same spec executed twice through the pool: not just equal tables but
    // equal raw stats (migrations, queue cycles — everything in the JSON).
    let spec = experiment::fig2_spec(1 << 13, &[4], SEED);
    let runner = BatchRunner::new(4);
    let a = runner.run(&spec).to_json(&spec).encode();
    let b = runner.run(&spec).to_json(&spec).encode();
    assert_eq!(a, b);
}
