//! Integration: schedulers driving the engine — migration costs are real
//! and visible, static pinning is stable.

use tilesim::arch::TileId;
use tilesim::mem::{HashPolicy, MemConfig};
use tilesim::sched::{Scheduler, StaticMapper, TileLinuxConfig, TileLinuxScheduler};
use tilesim::sim::{Engine, EngineConfig, Loc, Program, TraceBuilder};

fn long_running_program(e: &mut Engine, threads: usize) -> Program {
    let r = e.prealloc_touched(TileId(0), 1 << 22);
    let mut builders = Vec::new();
    let part = (1u64 << 22) / threads as u64;
    for i in 0..threads as u64 {
        let mut b = TraceBuilder::new();
        for _ in 0..64 {
            b.read(Loc::Abs(r.addr.offset(i * part)), part);
        }
        builders.push(b);
    }
    Program::from_builders(builders, 0, 0)
}

fn engine() -> Engine {
    Engine::new(EngineConfig::tilepro64(MemConfig {
        hash_policy: HashPolicy::AllButStack,
        striping: true,
    }))
}

#[test]
fn tile_linux_migrates_on_long_runs_static_never() {
    let mut e1 = engine();
    let mut p1 = long_running_program(&mut e1, 16);
    let s_linux = e1.run(&mut p1, &mut TileLinuxScheduler::with_seed(3)).unwrap();
    assert!(s_linux.migrations > 0, "long run must see migrations");

    let mut e2 = engine();
    let mut p2 = long_running_program(&mut e2, 16);
    let s_static = e2.run(&mut p2, &mut StaticMapper::new()).unwrap();
    assert_eq!(s_static.migrations, 0);
}

#[test]
fn migrations_cost_time() {
    // Same program under migrate_prob 0 vs 0.9: heavy migration must be
    // slower (direct cost + locality loss).
    let run = |prob: f64| {
        let mut e = engine();
        let mut p = long_running_program(&mut e, 16);
        let mut sched = TileLinuxScheduler::new(TileLinuxConfig {
            migrate_prob: prob,
            seed: 11,
            ..Default::default()
        });
        e.run(&mut p, &mut sched).unwrap()
    };
    let calm = run(0.0);
    let churny = run(0.9);
    assert!(churny.migrations > calm.migrations);
    assert!(
        churny.makespan_cycles > calm.makespan_cycles,
        "churn {} !> calm {}",
        churny.makespan_cycles,
        calm.makespan_cycles
    );
}

#[test]
fn migration_strands_first_touch_locality() {
    // A thread that first-touched its data locally, then migrates, pays
    // remote-home latency afterwards: DDR/home accesses must appear in the
    // post-migration phase.
    let e = Engine::new(EngineConfig::tilepro64(MemConfig {
        hash_policy: HashPolicy::None,
        striping: true,
    }));
    let mut b = TraceBuilder::new();
    b.alloc(0, 1 << 16, tilesim::mem::AllocKind::Heap)
        .write(Loc::Slot { slot: 0, offset: 0 }, 1 << 16);
    for _ in 0..128 {
        b.read(Loc::Slot { slot: 0, offset: 0 }, 1 << 16);
    }
    let mut p = Program::from_builders(vec![b], 1, 0);
    // Aggressive migration so it certainly fires mid-run.
    let mut sched = TileLinuxScheduler::new(TileLinuxConfig {
        check_interval: 200_000,
        migrate_prob: 1.0,
        seed: 5,
    });
    let stats = e.run(&mut p, &mut sched).unwrap();
    assert!(stats.migrations > 0);
    assert!(
        stats.home_hits + stats.ddr_accesses > (1 << 16) / 64,
        "post-migration reads must be remote: {} home, {} ddr",
        stats.home_hits,
        stats.ddr_accesses
    );
}

#[test]
fn static_mapper_is_ordered_and_dense() {
    let mut s = StaticMapper::new();
    let tiles: Vec<_> = (0..64).map(|t| s.initial_tile(t)).collect();
    for (i, t) in tiles.iter().enumerate() {
        assert_eq!(t.index(), i);
    }
}

#[test]
fn tile_linux_initial_spread_covers_chip_at_64_threads() {
    let mut s = TileLinuxScheduler::with_seed(9);
    let tiles: std::collections::HashSet<_> = (0..64).map(|t| s.initial_tile(t)).collect();
    assert_eq!(tiles.len(), 64);
}
