//! The serve front-end's core contract (same shape as
//! `batch_determinism.rs` for the sweep layer): an identical scenario grid
//! + seed must yield a byte-identical JSON record at `--jobs 1` and
//! `--jobs N`, at any `--intra-jobs`, and across repeated runs — and the
//! record must actually carry the latency/throughput/knee content the
//! acceptance bar names.

use tilesim::arch::PartitionSpec;
use tilesim::coherence::ProtocolSpec;
use tilesim::coordinator::batch::{BatchRunner, RunSpec};
use tilesim::coordinator::experiment;
use tilesim::serve::{Admission, ArrivalGen, ArrivalSpec, BatchPolicy, ServeSweep, SizeMix};
use tilesim::util::json::{parse, Json};

const SEED: u64 = experiment::DEFAULT_SEED;

fn small_sweep() -> ServeSweep {
    ServeSweep::grid(
        &RunSpec::mergesort(8, 1 << 10, 4, SEED),
        &experiment::serve_machines(),
        &[ProtocolSpec::default()],
        &[BatchPolicy::Immediate, BatchPolicy::Batch { max: 4, wait: 0 }],
        ArrivalSpec::Poisson,
        &[0.6, 1.4],
        32,
        1 << 10,
        false,
        &PartitionSpec::Whole,
        Admission::Fifo,
        &SizeMix::single(1 << 10),
    )
}

#[test]
fn serve_record_identical_across_jobs() {
    let sweep = small_sweep();
    let serial = sweep.to_json(&sweep.run(&BatchRunner::new(1))).encode();
    for jobs in [2usize, 4, 8] {
        let parallel = sweep.to_json(&sweep.run(&BatchRunner::new(jobs))).encode();
        assert_eq!(serial, parallel, "jobs={jobs} changed the serve record");
    }
}

#[test]
fn serve_record_identical_across_intra_jobs() {
    let sweep = small_sweep();
    let base = sweep
        .to_json(&sweep.run(&BatchRunner::new(1)))
        .encode();
    let intra = sweep
        .to_json(&sweep.run(&BatchRunner::new(1).with_intra_jobs(4)))
        .encode();
    assert_eq!(base, intra, "intra-run workers changed the serve record");
}

#[test]
fn repeated_serve_runs_are_bit_identical() {
    let sweep = small_sweep();
    let runner = BatchRunner::new(4);
    let a = sweep.to_json(&sweep.run(&runner)).encode();
    let b = sweep.to_json(&sweep.run(&runner)).encode();
    assert_eq!(a, b);
}

#[test]
fn arrival_streams_are_reproducible_at_integration_level() {
    // The generator is the only stochastic component; its event sequence
    // must be a pure function of (spec, seed) — repeated construction
    // included.
    for spec in [ArrivalSpec::Poisson, ArrivalSpec::Bursty { burst: 4 }] {
        let a = ArrivalGen::arrival_times(spec, 700.0, SEED, 4096);
        let b = ArrivalGen::arrival_times(spec, 700.0, SEED, 4096);
        assert_eq!(a, b, "{}", spec.label());
    }
}

#[test]
fn record_round_trips_and_carries_the_acceptance_content() {
    // The emitted record must parse back (it is what CI's jq smoke reads)
    // and contain: percentile latencies, throughput-vs-load rows, and a
    // detected saturation knee for the tilepro64 ladder (rho=1.4 cannot
    // keep up on a single-server queue).
    let sweep = small_sweep();
    let record = sweep.to_json(&sweep.run(&BatchRunner::new(2)));
    let parsed = parse(&record.encode()).expect("record must round-trip");
    let scenarios = parsed.get("scenarios").and_then(|s| s.as_arr()).unwrap();
    assert_eq!(scenarios.len(), 4);
    for s in scenarios {
        let rep = s.get("report").unwrap();
        for key in ["p50_cycles", "p99_cycles", "p999_cycles", "offered_rps", "completed_rps"] {
            assert!(rep.get(key).is_some(), "report missing {key}");
        }
    }
    let ladders = parsed.get("ladders").and_then(|l| l.as_arr()).unwrap();
    assert_eq!(ladders.len(), 2);
    for l in ladders {
        let label = l.get("label").and_then(|x| x.as_str()).unwrap();
        assert!(label.starts_with("tilepro64/"), "{label}");
        assert_eq!(l.get("rows").and_then(|r| r.as_arr()).unwrap().len(), 2);
        assert!(
            !matches!(l.get("knee"), Some(&Json::Null) | None),
            "ladder {label} must detect its knee at rho=1.4"
        );
        let knee_rho = l
            .get("knee")
            .and_then(|k| k.get("rho"))
            .and_then(|r| r.as_f64())
            .unwrap();
        assert_eq!(knee_rho, 1.4);
    }
}
