//! Pluggable directory coherence protocols (the protocol lab).
//!
//! The paper's localisation argument is really an argument about *coherence
//! traffic*: how the home-tile directory turns a sharing pattern into mesh
//! packets. The seed hard-coded one answer — the TILEPro64's write-through
//! write-invalidate DDC — inside `cache/directory.rs`. This module factors
//! the protocol out into a [`Protocol`] state machine so the same workloads
//! can replay under different answers:
//!
//! | spec | behaviour |
//! |------|-----------|
//! | `write-invalidate` | the seed's posted write-through + sharer invalidation (default; pinned baselines replay byte-identically) |
//! | `msi` | write-invalidate + an explicit S→M ownership upgrade round trip when a sole sharer re-writes a remotely-homed line |
//! | `mesi` | ownership retained: that same sole-sharer re-write is a *silent* E→M upgrade (no mesh traffic); a later foreign read pays the owner→home writeback |
//! | `moesi` | mesi + owner forwarding: foreign reads are served owner→reader directly (O state), skipping the home writeback |
//! | `write-update` | stores stream data-sized updates to every other sharer instead of invalidating them |
//! | `opaque[@seed]` | write-invalidate behind a seeded permutation of every home tile (opaque home mapping, after arXiv:2011.05422) |
//!
//! A transition ([`Protocol::on_read`] / [`on_write`](Protocol::on_write) /
//! [`on_evict`](Protocol::on_evict)) receives a [`LineCtx`] snapshot of the
//! directory's view of one line and returns the typed
//! [`CoherenceAction`]s the engine must bill on the mesh via the existing
//! `ContentionModel` traffic classes. Transitions are *pure*: all state
//! lives in the directory sharer sets and the cache layer's dirty-owner
//! column, so the conformance suite can drive every protocol through
//! every ctx shape without an engine.
//!
//! The run-level bulk hooks
//! ([`on_read_run`](Protocol::on_read_run) /
//! [`on_write_run`](Protocol::on_write_run)) are how protocols ride the
//! engine's page-run fast path: when every line of a same-page run has
//! the same directory view, the engine evaluates one transition into an
//! allocation-free [`ActionRun`] and applies it per line; any state
//! divergence inside the run falls back to the per-line walk. The
//! default implementation returns `None` (always correct); every
//! shipped protocol overrides it with the same closed form its per-line
//! hook uses, pinned action-for-action by the conformance unit tests.
//!
//! **Engagement contract:** when coherence-link billing is off
//! (`ContentionConfig::coherence` or `links` cleared — including every
//! pinned tilepro64 paper baseline), every transition returns no actions
//! and the engine keeps the seed's fused write-invalidate path. Protocol
//! semantics only diverge where their traffic can be billed.

use crate::arch::TileId;
use crate::util::rng::Rng;

/// Seed used by `opaque` when none is given (the repo-wide default seed).
pub const DEFAULT_OPAQUE_SEED: u64 = 2014;

/// Which protocol family a [`ProtocolSpec`] selects.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ProtocolKind {
    /// The seed's posted write-through write-invalidate DDC (default).
    WriteInvalidate,
    /// Explicit S→M upgrade round trips; home always current.
    Msi,
    /// Silent E→M upgrades; dirty owner writes back on foreign read.
    Mesi,
    /// Mesi + owner-sourced data replies (O state).
    Moesi,
    /// Data-sized update fan-out to sharers instead of invalidation.
    WriteUpdate,
    /// Write-invalidate behind a seeded home-tile permutation.
    Opaque,
}

/// Parsed `--protocol` selection: a protocol kind plus the opaque
/// variant's permutation seed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ProtocolSpec {
    pub kind: ProtocolKind,
    /// Home-permutation seed; only meaningful when `kind == Opaque`.
    pub opaque_seed: u64,
}

impl Default for ProtocolSpec {
    fn default() -> Self {
        ProtocolSpec {
            kind: ProtocolKind::WriteInvalidate,
            opaque_seed: DEFAULT_OPAQUE_SEED,
        }
    }
}

impl ProtocolSpec {
    pub fn new(kind: ProtocolKind) -> Self {
        ProtocolSpec {
            kind,
            ..Default::default()
        }
    }

    /// Parse a `--protocol` value: `write-invalidate` (alias `wi`), `msi`,
    /// `mesi`, `moesi`, `write-update` (alias `wu`), `opaque`,
    /// `opaque@<seed>`.
    pub fn parse(s: &str) -> Result<ProtocolSpec, String> {
        let lower = s.to_ascii_lowercase();
        let kind = match lower.as_str() {
            "write-invalidate" | "wi" => ProtocolKind::WriteInvalidate,
            "msi" => ProtocolKind::Msi,
            "mesi" => ProtocolKind::Mesi,
            "moesi" => ProtocolKind::Moesi,
            "write-update" | "wu" => ProtocolKind::WriteUpdate,
            "opaque" => ProtocolKind::Opaque,
            _ => {
                if let Some(seed) = lower.strip_prefix("opaque@") {
                    let seed: u64 = seed
                        .parse()
                        .map_err(|_| format!("bad opaque seed in protocol spec: {s}"))?;
                    return Ok(ProtocolSpec {
                        kind: ProtocolKind::Opaque,
                        opaque_seed: seed,
                    });
                }
                return Err(format!(
                    "unknown protocol: {s} (expected write-invalidate|msi|mesi|moesi|write-update|opaque[@seed])"
                ));
            }
        };
        Ok(ProtocolSpec::new(kind))
    }

    /// Stable label used in run labels, JSON, and report columns.
    pub fn label(&self) -> String {
        match self.kind {
            ProtocolKind::WriteInvalidate => "write-invalidate".to_string(),
            ProtocolKind::Msi => "msi".to_string(),
            ProtocolKind::Mesi => "mesi".to_string(),
            ProtocolKind::Moesi => "moesi".to_string(),
            ProtocolKind::WriteUpdate => "write-update".to_string(),
            ProtocolKind::Opaque => {
                if self.opaque_seed == DEFAULT_OPAQUE_SEED {
                    "opaque".to_string()
                } else {
                    format!("opaque@{}", self.opaque_seed)
                }
            }
        }
    }

    /// The default (seed-equivalent) protocol: run labels and JSON omit it
    /// so every pinned record keeps its bytes.
    pub fn is_default(&self) -> bool {
        self.kind == ProtocolKind::WriteInvalidate
    }

    /// Whether runs under this spec permute home tiles.
    pub fn permutes_homes(&self) -> bool {
        self.kind == ProtocolKind::Opaque
    }

    /// Every protocol the lab sweeps, in report-column order (ties in a
    /// winner scan break towards the earlier entry, so the seed protocol
    /// leads).
    pub fn all() -> Vec<ProtocolSpec> {
        [
            ProtocolKind::WriteInvalidate,
            ProtocolKind::Msi,
            ProtocolKind::Mesi,
            ProtocolKind::Moesi,
            ProtocolKind::WriteUpdate,
            ProtocolKind::Opaque,
        ]
        .into_iter()
        .map(ProtocolSpec::new)
        .collect()
    }

    /// Instantiate the transition state machine for this spec (`Opaque`
    /// shares write-invalidate transitions; its home permutation is
    /// applied by the engine, not the state machine).
    pub fn build(&self) -> Box<dyn Protocol> {
        match self.kind {
            ProtocolKind::WriteInvalidate | ProtocolKind::Opaque => Box::new(WriteInvalidate),
            ProtocolKind::Msi => Box::new(Msi),
            ProtocolKind::Mesi => Box::new(Mesi),
            ProtocolKind::Moesi => Box::new(Moesi),
            ProtocolKind::WriteUpdate => Box::new(WriteUpdate),
        }
    }
}

/// Per-line protocol state as seen by one tile (the classic MOESI
/// lattice; protocols use the subset they define).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LineState {
    Invalid,
    Shared,
    Exclusive,
    Modified,
    Owned,
}

/// The directory's view of one line at transition time. `others` and
/// `was_sharer` come from the sharer bitmask, `owner` from the cache
/// layer's dirty-owner map, `links_on` from
/// `ContentionModel::coherence_enabled()`.
#[derive(Clone, Copy, Debug)]
pub struct LineCtx {
    /// Tile performing the access.
    pub requestor: TileId,
    /// The line's (possibly permuted) home tile.
    pub home: TileId,
    /// Sharers other than the requestor.
    pub others: u32,
    /// Requestor already in the sharer set.
    pub was_sharer: bool,
    /// Current dirty owner, if any tile holds the line M/O.
    pub owner: Option<TileId>,
    /// Coherence-link billing active; when false every transition is ∅.
    pub links_on: bool,
}

impl LineCtx {
    fn foreign_owner(&self) -> Option<TileId> {
        self.owner.filter(|&o| o != self.requestor)
    }
}

/// Upper bound on the actions one transition can emit (worst case is
/// MSI's upgrade + owner writeback + post + fan-out + ack = 5; one slot
/// of headroom for future protocols).
pub const MAX_RUN_ACTIONS: usize = 6;

/// Fixed-capacity action list returned by the run-level bulk hooks.
///
/// The page-run fast path evaluates **one** transition per same-page
/// run and applies it line by line, so the result must not allocate —
/// a `Vec` per run would put malloc back in the hot loop the fast path
/// exists to avoid.
#[derive(Clone, Copy, Debug)]
pub struct ActionRun {
    len: u8,
    buf: [CoherenceAction; MAX_RUN_ACTIONS],
}

impl ActionRun {
    pub fn new() -> Self {
        ActionRun {
            len: 0,
            buf: [CoherenceAction::Ack; MAX_RUN_ACTIONS],
        }
    }

    fn push(&mut self, a: CoherenceAction) {
        self.buf[usize::from(self.len)] = a;
        self.len += 1;
    }

    pub fn as_slice(&self) -> &[CoherenceAction] {
        &self.buf[..usize::from(self.len)]
    }

    pub fn len(&self) -> usize {
        usize::from(self.len)
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
}

impl Default for ActionRun {
    fn default() -> Self {
        ActionRun::new()
    }
}

/// One mesh-billable consequence of a transition. The engine maps each
/// action onto the `ContentionModel` traffic class it occupies.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CoherenceAction {
    /// Posted write-through of the store data to the home tile
    /// (request-class route + home port + ack reply).
    PostToHome,
    /// Header-only ownership-upgrade round trip requestor↔home
    /// (invalidation class; MSI S→M).
    UpgradeRoundTrip,
    /// Silent in-cache E→M upgrade: no mesh traffic; the requestor
    /// becomes the line's dirty owner (MESI/MOESI).
    SilentUpgrade,
    /// Home invalidates every other sharer and collects their acks
    /// (invalidation class).
    InvalidateFanout,
    /// Home streams the store data to every other sharer and collects
    /// acks (write-update; data-sized packets on the invalidation-route
    /// class).
    UpdateFanout,
    /// Home serves the line to the requestor (reply class).
    DataReplyFromHome,
    /// The dirty owner flushes the line to home before home acts on it
    /// (reply class, owner→home).
    WritebackToHome { owner: TileId },
    /// The dirty owner streams the line straight to the requestor
    /// (reply class, owner→requestor; MOESI O-state serve).
    OwnerReply { owner: TileId },
    /// A bare acknowledgement completing a round trip.
    Ack,
}

/// A directory coherence protocol as a pure per-line state machine.
///
/// Implementations must uphold three invariants (pinned by the
/// conformance suite in `rust/tests/protocol_conformance.rs`):
///
/// 1. **links off ⇒ no actions** — every transition returns an empty
///    vector when `ctx.links_on` is false;
/// 2. **single writer** — a write that leaves another tile's copy valid
///    must either invalidate it ([`CoherenceAction::InvalidateFanout`])
///    or update it ([`CoherenceAction::UpdateFanout`]);
/// 3. **no stale reads** — a read of a line with a foreign dirty owner
///    must source current data ([`CoherenceAction::WritebackToHome`] or
///    [`CoherenceAction::OwnerReply`]).
pub trait Protocol {
    fn kind(&self) -> ProtocolKind;

    /// The requestor's state for a line in ctx (classification only; no
    /// transition).
    fn line_state(&self, ctx: &LineCtx) -> LineState;

    /// Transition for a load by `ctx.requestor`.
    fn on_read(&self, ctx: &LineCtx) -> Vec<CoherenceAction>;

    /// Transition for a store by `ctx.requestor`.
    fn on_write(&self, ctx: &LineCtx) -> Vec<CoherenceAction>;

    /// Transition for the requestor dropping its copy (purge/free).
    fn on_evict(&self, ctx: &LineCtx) -> Vec<CoherenceAction>;

    /// Bulk run-level read hook: when every line of a same-page run has
    /// the same directory view (`ctx` holds for all of them), the engine
    /// evaluates **one** transition and applies it per line. `None`
    /// means "no closed form — walk per line", which is the default and
    /// always correct. An implementation returning `Some` must emit
    /// exactly the actions [`on_read`](Protocol::on_read) would for the
    /// same ctx (the conformance unit tests sweep a ctx grid to pin
    /// this).
    fn on_read_run(&self, _ctx: &LineCtx) -> Option<ActionRun> {
        None
    }

    /// Bulk run-level write hook; same contract as
    /// [`on_read_run`](Protocol::on_read_run) against
    /// [`on_write`](Protocol::on_write).
    fn on_write_run(&self, _ctx: &LineCtx) -> Option<ActionRun> {
        None
    }
}

/// Shared write transition of the invalidation-family protocols.
///
/// `silent_sole`: a sole-sharer re-write of a remotely-homed line
/// upgrades in place (MESI/MOESI) instead of posting through.
/// `msi_upgrade`: the same re-write stays a posted write but pays an
/// explicit ownership round trip (MSI).
/// `owner_forward`: a foreign dirty owner streams to the writer (MOESI)
/// instead of flushing home (MESI).
fn invalidating_write_into(
    ctx: &LineCtx,
    silent_sole: bool,
    msi_upgrade: bool,
    owner_forward: bool,
    push: &mut impl FnMut(CoherenceAction),
) {
    if !ctx.links_on {
        return;
    }
    let sole_rewrite = ctx.others == 0 && (ctx.was_sharer || ctx.owner == Some(ctx.requestor));
    if ctx.home != ctx.requestor && sole_rewrite {
        if silent_sole {
            push(CoherenceAction::SilentUpgrade);
            return;
        }
        if msi_upgrade {
            push(CoherenceAction::UpgradeRoundTrip);
        }
    }
    if let Some(o) = ctx.foreign_owner() {
        push(if owner_forward {
            CoherenceAction::OwnerReply { owner: o }
        } else {
            CoherenceAction::WritebackToHome { owner: o }
        });
    }
    if ctx.home != ctx.requestor {
        push(CoherenceAction::PostToHome);
    }
    if ctx.others > 0 {
        push(CoherenceAction::InvalidateFanout);
        push(CoherenceAction::Ack);
    }
}

fn invalidating_write(
    ctx: &LineCtx,
    silent_sole: bool,
    msi_upgrade: bool,
    owner_forward: bool,
) -> Vec<CoherenceAction> {
    let mut a = Vec::new();
    invalidating_write_into(ctx, silent_sole, msi_upgrade, owner_forward, &mut |x| {
        a.push(x)
    });
    a
}

/// [`invalidating_write`] into an allocation-free [`ActionRun`] (the
/// run-level bulk hooks).
fn invalidating_write_run(
    ctx: &LineCtx,
    silent_sole: bool,
    msi_upgrade: bool,
    owner_forward: bool,
) -> ActionRun {
    let mut r = ActionRun::new();
    invalidating_write_into(ctx, silent_sole, msi_upgrade, owner_forward, &mut |x| {
        r.push(x)
    });
    r
}

/// Shared read transition: foreign dirty owners are flushed (or forward
/// the data), then home serves remotely-homed lines.
fn serve_read_into(ctx: &LineCtx, owner_forward: bool, push: &mut impl FnMut(CoherenceAction)) {
    if !ctx.links_on {
        return;
    }
    if let Some(o) = ctx.foreign_owner() {
        if owner_forward {
            push(CoherenceAction::OwnerReply { owner: o });
            return;
        }
        push(CoherenceAction::WritebackToHome { owner: o });
    }
    if ctx.home != ctx.requestor {
        push(CoherenceAction::DataReplyFromHome);
    }
}

fn serve_read(ctx: &LineCtx, owner_forward: bool) -> Vec<CoherenceAction> {
    let mut a = Vec::new();
    serve_read_into(ctx, owner_forward, &mut |x| a.push(x));
    a
}

/// [`serve_read`] into an allocation-free [`ActionRun`].
fn serve_read_run(ctx: &LineCtx, owner_forward: bool) -> ActionRun {
    let mut r = ActionRun::new();
    serve_read_into(ctx, owner_forward, &mut |x| r.push(x));
    r
}

/// Write-update's store transition: post through, then stream the data
/// to every other sharer (their copies stay valid).
fn update_write_into(ctx: &LineCtx, push: &mut impl FnMut(CoherenceAction)) {
    if !ctx.links_on {
        return;
    }
    if ctx.home != ctx.requestor {
        push(CoherenceAction::PostToHome);
    }
    if ctx.others > 0 {
        push(CoherenceAction::UpdateFanout);
    }
}

/// Eviction: only a dirty owner has anything to flush.
fn evict_dirty(ctx: &LineCtx) -> Vec<CoherenceAction> {
    if ctx.links_on && ctx.owner == Some(ctx.requestor) {
        vec![CoherenceAction::WritebackToHome {
            owner: ctx.requestor,
        }]
    } else {
        Vec::new()
    }
}

fn shared_or_invalid(ctx: &LineCtx) -> LineState {
    if ctx.was_sharer {
        LineState::Shared
    } else {
        LineState::Invalid
    }
}

/// The seed's protocol: posted write-through stores, home always
/// current, every other sharer invalidated on write. Never sets owners.
pub struct WriteInvalidate;

impl Protocol for WriteInvalidate {
    fn kind(&self) -> ProtocolKind {
        ProtocolKind::WriteInvalidate
    }
    fn line_state(&self, ctx: &LineCtx) -> LineState {
        shared_or_invalid(ctx)
    }
    fn on_read(&self, ctx: &LineCtx) -> Vec<CoherenceAction> {
        serve_read(ctx, false)
    }
    fn on_write(&self, ctx: &LineCtx) -> Vec<CoherenceAction> {
        invalidating_write(ctx, false, false, false)
    }
    fn on_evict(&self, ctx: &LineCtx) -> Vec<CoherenceAction> {
        evict_dirty(ctx)
    }
    fn on_read_run(&self, ctx: &LineCtx) -> Option<ActionRun> {
        Some(serve_read_run(ctx, false))
    }
    fn on_write_run(&self, ctx: &LineCtx) -> Option<ActionRun> {
        Some(invalidating_write_run(ctx, false, false, false))
    }
}

/// Write-invalidate + explicit S→M upgrades: a sole sharer re-writing a
/// remotely-homed line pays a header round trip to reclaim ownership
/// before the posted write. Home stays current, so no owners either.
pub struct Msi;

impl Protocol for Msi {
    fn kind(&self) -> ProtocolKind {
        ProtocolKind::Msi
    }
    fn line_state(&self, ctx: &LineCtx) -> LineState {
        if ctx.owner == Some(ctx.requestor) {
            LineState::Modified
        } else {
            shared_or_invalid(ctx)
        }
    }
    fn on_read(&self, ctx: &LineCtx) -> Vec<CoherenceAction> {
        serve_read(ctx, false)
    }
    fn on_write(&self, ctx: &LineCtx) -> Vec<CoherenceAction> {
        invalidating_write(ctx, false, true, false)
    }
    fn on_evict(&self, ctx: &LineCtx) -> Vec<CoherenceAction> {
        evict_dirty(ctx)
    }
    fn on_read_run(&self, ctx: &LineCtx) -> Option<ActionRun> {
        Some(serve_read_run(ctx, false))
    }
    fn on_write_run(&self, ctx: &LineCtx) -> Option<ActionRun> {
        Some(invalidating_write_run(ctx, false, true, false))
    }
}

/// Ownership retained: the sole-sharer re-write is silent (E→M), the
/// home copy goes stale, and a foreign read pays the owner→home
/// writeback before home serves it.
pub struct Mesi;

impl Mesi {
    fn classify(ctx: &LineCtx) -> LineState {
        if ctx.owner == Some(ctx.requestor) {
            LineState::Modified
        } else if ctx.was_sharer && ctx.others == 0 {
            LineState::Exclusive
        } else {
            shared_or_invalid(ctx)
        }
    }
}

impl Protocol for Mesi {
    fn kind(&self) -> ProtocolKind {
        ProtocolKind::Mesi
    }
    fn line_state(&self, ctx: &LineCtx) -> LineState {
        Mesi::classify(ctx)
    }
    fn on_read(&self, ctx: &LineCtx) -> Vec<CoherenceAction> {
        serve_read(ctx, false)
    }
    fn on_write(&self, ctx: &LineCtx) -> Vec<CoherenceAction> {
        invalidating_write(ctx, true, false, false)
    }
    fn on_evict(&self, ctx: &LineCtx) -> Vec<CoherenceAction> {
        evict_dirty(ctx)
    }
    fn on_read_run(&self, ctx: &LineCtx) -> Option<ActionRun> {
        Some(serve_read_run(ctx, false))
    }
    fn on_write_run(&self, ctx: &LineCtx) -> Option<ActionRun> {
        Some(invalidating_write_run(ctx, true, false, false))
    }
}

/// Mesi + the O state: a foreign read is served owner→reader directly
/// and the owner keeps the dirty line (no home writeback until the
/// owner is invalidated or evicted).
pub struct Moesi;

impl Protocol for Moesi {
    fn kind(&self) -> ProtocolKind {
        ProtocolKind::Moesi
    }
    fn line_state(&self, ctx: &LineCtx) -> LineState {
        if ctx.owner == Some(ctx.requestor) && ctx.others > 0 {
            LineState::Owned
        } else {
            Mesi::classify(ctx)
        }
    }
    fn on_read(&self, ctx: &LineCtx) -> Vec<CoherenceAction> {
        serve_read(ctx, true)
    }
    fn on_write(&self, ctx: &LineCtx) -> Vec<CoherenceAction> {
        invalidating_write(ctx, true, false, true)
    }
    fn on_evict(&self, ctx: &LineCtx) -> Vec<CoherenceAction> {
        evict_dirty(ctx)
    }
    fn on_read_run(&self, ctx: &LineCtx) -> Option<ActionRun> {
        Some(serve_read_run(ctx, true))
    }
    fn on_write_run(&self, ctx: &LineCtx) -> Option<ActionRun> {
        Some(invalidating_write_run(ctx, true, false, true))
    }
}

/// Stores post through to home as usual, but other sharers receive
/// data-sized updates instead of invalidations — their copies stay
/// valid, so re-reads hit locally at the price of fan-out bandwidth.
pub struct WriteUpdate;

impl Protocol for WriteUpdate {
    fn kind(&self) -> ProtocolKind {
        ProtocolKind::WriteUpdate
    }
    fn line_state(&self, ctx: &LineCtx) -> LineState {
        shared_or_invalid(ctx)
    }
    fn on_read(&self, ctx: &LineCtx) -> Vec<CoherenceAction> {
        serve_read(ctx, false)
    }
    fn on_write(&self, ctx: &LineCtx) -> Vec<CoherenceAction> {
        let mut a = Vec::new();
        update_write_into(ctx, &mut |x| a.push(x));
        a
    }
    fn on_evict(&self, ctx: &LineCtx) -> Vec<CoherenceAction> {
        evict_dirty(ctx)
    }
    fn on_read_run(&self, ctx: &LineCtx) -> Option<ActionRun> {
        Some(serve_read_run(ctx, false))
    }
    fn on_write_run(&self, ctx: &LineCtx) -> Option<ActionRun> {
        let mut r = ActionRun::new();
        update_write_into(ctx, &mut |x| r.push(x));
        Some(r)
    }
}

/// Seeded Fisher–Yates permutation of home tiles (the `opaque` mode):
/// every resolved home `t` is remapped to `perm[t]`, modelling a home
/// mapping the programmer cannot predict (arXiv:2011.05422). Permuting
/// a page-uniform home keeps it page-uniform, so the engine's page-run
/// fast path stays valid.
pub struct HomePermutation {
    map: Vec<u32>,
}

impl HomePermutation {
    pub fn new(seed: u64, num_tiles: u32) -> Self {
        let mut map: Vec<u32> = (0..num_tiles).collect();
        // Domain-separated from workload/scheduler streams on the same seed.
        let mut rng = Rng::new(seed ^ 0x6F70_6171_7565_u64);
        rng.shuffle(&mut map);
        HomePermutation { map }
    }

    #[inline]
    pub fn map(&self, t: TileId) -> TileId {
        TileId(self.map[t.index()])
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx(
        requestor: u32,
        home: u32,
        others: u32,
        was_sharer: bool,
        owner: Option<u32>,
        links_on: bool,
    ) -> LineCtx {
        LineCtx {
            requestor: TileId(requestor),
            home: TileId(home),
            others,
            was_sharer,
            owner: owner.map(TileId),
            links_on,
        }
    }

    fn protos() -> Vec<Box<dyn Protocol>> {
        ProtocolSpec::all().iter().map(|s| s.build()).collect()
    }

    #[test]
    fn parse_and_label_round_trip() {
        for s in ProtocolSpec::all() {
            assert_eq!(ProtocolSpec::parse(&s.label()).unwrap(), s);
        }
        assert_eq!(
            ProtocolSpec::parse("opaque@7").unwrap(),
            ProtocolSpec {
                kind: ProtocolKind::Opaque,
                opaque_seed: 7
            }
        );
        assert_eq!(ProtocolSpec::parse("opaque@7").unwrap().label(), "opaque@7");
        assert_eq!(ProtocolSpec::parse("WI").unwrap().kind, ProtocolKind::WriteInvalidate);
        assert_eq!(ProtocolSpec::parse("wu").unwrap().kind, ProtocolKind::WriteUpdate);
        assert!(ProtocolSpec::parse("mosi").is_err());
        assert!(ProtocolSpec::parse("opaque@x").is_err());
        assert!(ProtocolSpec::default().is_default());
        assert!(!ProtocolSpec::new(ProtocolKind::Mesi).is_default());
    }

    #[test]
    fn links_off_means_no_actions_for_every_protocol() {
        // The conformance gate: with coherence billing off, every
        // transition of every protocol is a no-op, whatever the ctx.
        let shapes = [
            ctx(1, 0, 0, false, None, false),
            ctx(1, 0, 3, true, None, false),
            ctx(1, 0, 2, true, Some(5), false),
            ctx(0, 0, 1, true, Some(1), false),
        ];
        for p in protos() {
            for c in &shapes {
                assert!(p.on_read(c).is_empty(), "{:?} read", p.kind());
                assert!(p.on_write(c).is_empty(), "{:?} write", p.kind());
                assert!(p.on_evict(c).is_empty(), "{:?} evict", p.kind());
            }
        }
    }

    #[test]
    fn single_writer_every_other_copy_invalidated_or_updated() {
        // A write with other sharers must leave no stale copy behind:
        // invalidation-family protocols fan out invalidations,
        // write-update fans out the new data.
        let c = ctx(1, 0, 3, true, None, true);
        for p in protos() {
            let a = p.on_write(&c);
            let handled = a.contains(&CoherenceAction::InvalidateFanout)
                || a.contains(&CoherenceAction::UpdateFanout);
            assert!(handled, "{:?} leaves stale sharers: {a:?}", p.kind());
            if p.kind() == ProtocolKind::WriteUpdate {
                assert!(!a.contains(&CoherenceAction::InvalidateFanout));
            }
        }
    }

    #[test]
    fn no_stale_reads_foreign_owner_always_sources_data() {
        // Reading a line some other tile holds dirty must surface that
        // tile's data: MESI flushes it home, MOESI forwards it.
        let c = ctx(2, 0, 1, false, Some(5), true);
        for p in protos() {
            let a = p.on_read(&c);
            let sourced = a.iter().any(|x| {
                matches!(
                    x,
                    CoherenceAction::WritebackToHome { owner } | CoherenceAction::OwnerReply { owner }
                    if *owner == TileId(5)
                )
            });
            assert!(sourced, "{:?} reads stale data: {a:?}", p.kind());
        }
    }

    #[test]
    fn only_silent_protocols_create_owners() {
        // SilentUpgrade is the sole owner-creating action; WI/MSI/WU keep
        // home current on every write, so their reads never need a flush.
        let sole_rewrite = ctx(3, 0, 0, true, None, true);
        for p in protos() {
            let silent = p
                .on_write(&sole_rewrite)
                .contains(&CoherenceAction::SilentUpgrade);
            let expects = matches!(p.kind(), ProtocolKind::Mesi | ProtocolKind::Moesi);
            assert_eq!(silent, expects, "{:?}", p.kind());
        }
    }

    #[test]
    fn sole_sharer_rewrite_ladder() {
        // The microbench re-write case (out_part written every rep):
        // WI posts; MSI posts + pays an upgrade; MESI/MOESI go silent.
        let c = ctx(3, 0, 0, true, None, true);
        assert_eq!(
            WriteInvalidate.on_write(&c),
            vec![CoherenceAction::PostToHome]
        );
        assert_eq!(
            Msi.on_write(&c),
            vec![CoherenceAction::UpgradeRoundTrip, CoherenceAction::PostToHome]
        );
        assert_eq!(Mesi.on_write(&c), vec![CoherenceAction::SilentUpgrade]);
        assert_eq!(Moesi.on_write(&c), vec![CoherenceAction::SilentUpgrade]);
        assert_eq!(WriteUpdate.on_write(&c), vec![CoherenceAction::PostToHome]);
    }

    #[test]
    fn locally_homed_writes_never_upgrade_or_go_silent() {
        // home == requestor: the "remote post" never happens, so neither
        // do its optimisations — only the fan-out when sharers exist.
        let c = ctx(0, 0, 2, true, None, true);
        for p in protos() {
            let a = p.on_write(&c);
            assert!(!a.contains(&CoherenceAction::SilentUpgrade), "{:?}", p.kind());
            assert!(!a.contains(&CoherenceAction::UpgradeRoundTrip), "{:?}", p.kind());
            assert!(!a.contains(&CoherenceAction::PostToHome), "{:?}", p.kind());
        }
        let sole = ctx(0, 0, 0, true, None, true);
        for p in protos() {
            assert!(p.on_write(&sole).is_empty(), "{:?}", p.kind());
        }
    }

    #[test]
    fn moesi_forwards_where_mesi_flushes() {
        let c = ctx(2, 0, 1, false, Some(5), true);
        assert_eq!(
            Mesi.on_read(&c),
            vec![
                CoherenceAction::WritebackToHome { owner: TileId(5) },
                CoherenceAction::DataReplyFromHome
            ]
        );
        assert_eq!(
            Moesi.on_read(&c),
            vec![CoherenceAction::OwnerReply { owner: TileId(5) }]
        );
    }

    #[test]
    fn write_over_foreign_owner_flushes_then_invalidates() {
        let c = ctx(2, 0, 1, false, Some(5), true);
        let a = Mesi.on_write(&c);
        assert_eq!(
            a,
            vec![
                CoherenceAction::WritebackToHome { owner: TileId(5) },
                CoherenceAction::PostToHome,
                CoherenceAction::InvalidateFanout,
                CoherenceAction::Ack,
            ]
        );
        let a = Moesi.on_write(&c);
        assert_eq!(a[0], CoherenceAction::OwnerReply { owner: TileId(5) });
    }

    #[test]
    fn update_fanout_only_with_sharers() {
        let none = ctx(2, 0, 0, false, None, true);
        assert_eq!(WriteUpdate.on_write(&none), vec![CoherenceAction::PostToHome]);
        let some = ctx(2, 0, 4, true, None, true);
        assert_eq!(
            WriteUpdate.on_write(&some),
            vec![CoherenceAction::PostToHome, CoherenceAction::UpdateFanout]
        );
    }

    #[test]
    fn eviction_flushes_only_dirty_owners() {
        let dirty = ctx(5, 0, 0, true, Some(5), true);
        let clean = ctx(5, 0, 0, true, None, true);
        for p in protos() {
            assert_eq!(
                p.on_evict(&dirty),
                vec![CoherenceAction::WritebackToHome { owner: TileId(5) }],
                "{:?}",
                p.kind()
            );
            assert!(p.on_evict(&clean).is_empty(), "{:?}", p.kind());
        }
    }

    #[test]
    fn line_states_classify_the_lattice() {
        let invalid = ctx(1, 0, 2, false, None, true);
        let shared = ctx(1, 0, 2, true, None, true);
        let exclusive = ctx(1, 0, 0, true, None, true);
        let modified = ctx(1, 0, 0, true, Some(1), true);
        let owned = ctx(1, 0, 2, true, Some(1), true);
        assert_eq!(Mesi.line_state(&invalid), LineState::Invalid);
        assert_eq!(Mesi.line_state(&shared), LineState::Shared);
        assert_eq!(Mesi.line_state(&exclusive), LineState::Exclusive);
        assert_eq!(Mesi.line_state(&modified), LineState::Modified);
        assert_eq!(Moesi.line_state(&owned), LineState::Owned);
        assert_eq!(Moesi.line_state(&modified), LineState::Modified);
        // MSI has no E: a sole clean sharer is still just Shared.
        assert_eq!(Msi.line_state(&exclusive), LineState::Shared);
        assert_eq!(Msi.line_state(&modified), LineState::Modified);
        assert_eq!(WriteInvalidate.line_state(&exclusive), LineState::Shared);
        assert_eq!(WriteUpdate.line_state(&invalid), LineState::Invalid);
    }

    #[test]
    fn home_permutation_is_a_seeded_bijection() {
        let p = HomePermutation::new(2014, 64);
        assert_eq!(p.len(), 64);
        let mut seen: Vec<u32> = (0..64).map(|t| p.map(TileId(t)).0).collect();
        seen.sort_unstable();
        assert_eq!(seen, (0..64).collect::<Vec<_>>());
        // Deterministic per seed, different across seeds.
        let q = HomePermutation::new(2014, 64);
        assert!((0..64).all(|t| p.map(TileId(t)) == q.map(TileId(t))));
        let r = HomePermutation::new(7, 64);
        assert!((0..64).any(|t| p.map(TileId(t)) != r.map(TileId(t))));
        // Actually permutes (not the identity) on every lab grid size.
        for tiles in [16u32, 64, 256] {
            let p = HomePermutation::new(2014, tiles);
            assert!(
                (0..tiles).any(|t| p.map(TileId(t)).0 != t),
                "identity permutation on {tiles} tiles"
            );
        }
    }

    #[test]
    fn bulk_run_hooks_match_per_line_transitions() {
        // The run-level contract: every shipped protocol answers the
        // bulk hooks, and the one evaluated transition is action-for-
        // action what the per-line hook returns, over a full ctx grid
        // (links on/off × local/remote home × sharer counts × owner
        // shapes). The engine's fast path leans on exactly this.
        let mut shapes = Vec::new();
        for links_on in [false, true] {
            for (req, home) in [(0u32, 0u32), (1, 0), (3, 7)] {
                for others in [0u32, 1, 3] {
                    for was_sharer in [false, true] {
                        for owner in [None, Some(req), Some(5)] {
                            shapes.push(ctx(req, home, others, was_sharer, owner, links_on));
                        }
                    }
                }
            }
        }
        for p in protos() {
            for c in &shapes {
                let read = p
                    .on_read_run(c)
                    .unwrap_or_else(|| panic!("{:?} has no bulk read hook", p.kind()));
                assert_eq!(
                    read.as_slice(),
                    p.on_read(c).as_slice(),
                    "{:?} bulk read diverges on {c:?}",
                    p.kind()
                );
                let write = p
                    .on_write_run(c)
                    .unwrap_or_else(|| panic!("{:?} has no bulk write hook", p.kind()));
                assert_eq!(
                    write.as_slice(),
                    p.on_write(c).as_slice(),
                    "{:?} bulk write diverges on {c:?}",
                    p.kind()
                );
                assert!(read.len() <= MAX_RUN_ACTIONS && write.len() <= MAX_RUN_ACTIONS);
                assert_eq!(read.is_empty(), read.len() == 0);
            }
        }
    }

    #[test]
    fn spec_build_matches_kind() {
        for s in ProtocolSpec::all() {
            let built = s.build().kind();
            if s.kind == ProtocolKind::Opaque {
                // Opaque shares write-invalidate transitions.
                assert_eq!(built, ProtocolKind::WriteInvalidate);
            } else {
                assert_eq!(built, s.kind);
            }
        }
    }
}
