//! The request-path sorter: executes the AOT-compiled chunked sorter
//! (L1 Pallas bitonic kernels composed by the L2 JAX model) via PJRT.
//!
//! The exported executable sorts a fixed (64 × 1024) i32 batch per
//! dispatch; arbitrary lengths are handled by padding the tail batch with
//! `i32::MAX` sentinels and k-way merging batch results in rust — the same
//! chunk-then-merge structure the paper's merge sort uses, with the chunk
//! work on the accelerator and the coordination in rust.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::runtime::artifact::{ArtifactError, ArtifactSet};
use crate::runtime::xla;

/// Batch geometry — must match python/compile/model.py's export specs.
pub const NUM_CHUNKS: usize = 64;
pub const CHUNK: usize = 1024;
pub const BATCH: usize = NUM_CHUNKS * CHUNK;

pub struct ChunkedSorter<'a> {
    set: &'a ArtifactSet,
}

#[derive(Debug, Default, Clone, Copy)]
pub struct SortMetrics {
    pub dispatches: u64,
    /// Elements padded in the tail batch.
    pub padded: u64,
}

impl<'a> ChunkedSorter<'a> {
    pub fn new(set: &'a ArtifactSet) -> Result<Self, ArtifactError> {
        // Fail fast if the artifact is missing or has unexpected geometry.
        let meta = set
            .manifest
            .get("full_sort")
            .ok_or_else(|| ArtifactError::Unknown("full_sort".into(), String::new()))?;
        assert_eq!(
            meta.inputs[0].shape,
            vec![NUM_CHUNKS, CHUNK],
            "full_sort artifact shape drifted from runtime constants"
        );
        set.executable("full_sort")?;
        Ok(ChunkedSorter { set })
    }

    /// Sort exactly one batch (BATCH elements) on the accelerator.
    pub fn sort_batch(&self, data: &[i32]) -> Result<Vec<i32>, ArtifactError> {
        assert_eq!(data.len(), BATCH, "sort_batch needs exactly {BATCH} elems");
        let exe = self.set.executable("full_sort")?;
        let lit = xla::Literal::vec1(data).reshape(&[NUM_CHUNKS as i64, CHUNK as i64])?;
        let result = exe.execute::<xla::Literal>(&[lit])?[0][0].to_literal_sync()?;
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<i32>()?)
    }

    /// Sort any slice: pad → per-batch accelerator sorts → k-way merge.
    pub fn sort(&self, data: &[i32]) -> Result<(Vec<i32>, SortMetrics), ArtifactError> {
        let mut metrics = SortMetrics::default();
        if data.is_empty() {
            return Ok((Vec::new(), metrics));
        }
        let nbatches = data.len().div_ceil(BATCH);
        let mut runs: Vec<Vec<i32>> = Vec::with_capacity(nbatches);
        for b in 0..nbatches {
            let lo = b * BATCH;
            let hi = (lo + BATCH).min(data.len());
            let mut batch = data[lo..hi].to_vec();
            metrics.padded += (BATCH - batch.len()) as u64;
            batch.resize(BATCH, i32::MAX);
            let sorted = self.sort_batch(&batch)?;
            metrics.dispatches += 1;
            runs.push(sorted);
        }
        // K-way merge of the sorted runs, dropping pad sentinels beyond the
        // original length.
        let mut heap: BinaryHeap<Reverse<(i32, usize, usize)>> = runs
            .iter()
            .enumerate()
            .map(|(r, run)| Reverse((run[0], r, 0)))
            .collect();
        let mut out = Vec::with_capacity(data.len());
        while out.len() < data.len() {
            let Reverse((v, r, i)) = heap.pop().expect("merge underflow");
            out.push(v);
            if i + 1 < runs[r].len() {
                heap.push(Reverse((runs[r][i + 1], r, i + 1)));
            }
        }
        Ok((out, metrics))
    }
}

#[cfg(test)]
mod tests {
    // PJRT-backed tests live in rust/tests/integration_runtime.rs (they
    // need built artifacts); here we only test the pure-rust merge logic
    // via a stub that mimics batch sorting.

    #[test]
    fn kway_merge_logic() {
        // Reimplement the merge locally over pre-sorted runs to pin the
        // algorithm (the integration test exercises the real path).
        let runs = [vec![1, 4, 7], vec![2, 5, 8], vec![0, 3, 6]];
        let mut heap: std::collections::BinaryHeap<std::cmp::Reverse<(i32, usize, usize)>> = runs
            .iter()
            .enumerate()
            .map(|(r, run)| std::cmp::Reverse((run[0], r, 0)))
            .collect();
        let mut out = Vec::new();
        while let Some(std::cmp::Reverse((v, r, i))) = heap.pop() {
            out.push(v);
            if i + 1 < runs[r].len() {
                heap.push(std::cmp::Reverse((runs[r][i + 1], r, i + 1)));
            }
        }
        assert_eq!(out, (0..9).collect::<Vec<_>>());
    }
}
