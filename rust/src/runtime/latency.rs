//! PJRT wrapper for the AOT'd analytical NUCA latency model (L2).
//!
//! The rust event simulator and the JAX closed form share constants by
//! construction; `integration_runtime.rs` executes this wrapper against
//! `arch::LatencyParams::access_cycles` on random batches so any drift
//! between the layers fails tests.

use crate::arch::{HitLevel, TileId};
use crate::runtime::artifact::{ArtifactError, ArtifactSet};
use crate::runtime::xla;

/// Batch size exported by python/compile/model.py (LATENCY_BATCH).
pub const LATENCY_BATCH: usize = 1024;

/// Hit-level encoding shared with the python model.
pub const LEVEL_L1: i32 = 0;
pub const LEVEL_L2: i32 = 1;
pub const LEVEL_HOME: i32 = 2;
pub const LEVEL_DDR: i32 = 3;

/// One access descriptor for the batch model.
#[derive(Clone, Copy, Debug)]
pub struct AccessDesc {
    pub req: TileId,
    /// Home tile (level 2) or controller attach tile (level 3); ignored
    /// for levels 0/1.
    pub dst: TileId,
    pub level: i32,
    pub contention: f32,
}

impl AccessDesc {
    /// Build from the simulator's HitLevel (zero contention term).
    pub fn from_hit(req: TileId, level: HitLevel) -> AccessDesc {
        let (dst, lvl) = match level {
            HitLevel::L1 => (req, LEVEL_L1),
            HitLevel::L2 => (req, LEVEL_L2),
            HitLevel::Home { home } => (home, LEVEL_HOME),
            HitLevel::Ddr { ctrl_attach } => (ctrl_attach, LEVEL_DDR),
        };
        AccessDesc {
            req,
            dst,
            level: lvl,
            contention: 0.0,
        }
    }
}

pub struct LatencyModel<'a> {
    set: &'a ArtifactSet,
}

impl<'a> LatencyModel<'a> {
    pub fn new(set: &'a ArtifactSet) -> Result<Self, ArtifactError> {
        set.executable("latency_model")?;
        Ok(LatencyModel { set })
    }

    /// Evaluate a batch (padded/truncated to LATENCY_BATCH internally).
    /// Returns (per-access cycles for the first `n`, batch total of the
    /// padded batch — pads are L1 accesses).
    pub fn batch(&self, accesses: &[AccessDesc]) -> Result<(Vec<f32>, f32), ArtifactError> {
        let n = accesses.len().min(LATENCY_BATCH);
        let mut req = Vec::with_capacity(LATENCY_BATCH * 2);
        let mut dst = Vec::with_capacity(LATENCY_BATCH * 2);
        let mut level = Vec::with_capacity(LATENCY_BATCH);
        let mut cont = Vec::with_capacity(LATENCY_BATCH);
        for i in 0..LATENCY_BATCH {
            let a = accesses.get(i).copied().unwrap_or(AccessDesc {
                req: TileId(0),
                dst: TileId(0),
                level: LEVEL_L1,
                contention: 0.0,
            });
            let rc = a.req.coord();
            let dc = a.dst.coord();
            req.push(rc.x as i32);
            req.push(rc.y as i32);
            dst.push(dc.x as i32);
            dst.push(dc.y as i32);
            level.push(a.level);
            cont.push(a.contention);
        }
        let exe = self.set.executable("latency_model")?;
        let req_l = xla::Literal::vec1(&req).reshape(&[LATENCY_BATCH as i64, 2])?;
        let dst_l = xla::Literal::vec1(&dst).reshape(&[LATENCY_BATCH as i64, 2])?;
        let lvl_l = xla::Literal::vec1(&level);
        let cont_l = xla::Literal::vec1(&cont);
        let result =
            exe.execute::<xla::Literal>(&[req_l, dst_l, lvl_l, cont_l])?[0][0].to_literal_sync()?;
        let (per_l, total_l) = result.to_tuple2()?;
        let per: Vec<f32> = per_l.to_vec::<f32>()?;
        let total = total_l.get_first_element::<f32>()?;
        Ok((per[..n].to_vec(), total))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn access_desc_from_hit_levels() {
        let a = AccessDesc::from_hit(TileId(3), HitLevel::L1);
        assert_eq!(a.level, LEVEL_L1);
        let a = AccessDesc::from_hit(TileId(3), HitLevel::Home { home: TileId(60) });
        assert_eq!(a.level, LEVEL_HOME);
        assert_eq!(a.dst, TileId(60));
        let a = AccessDesc::from_hit(
            TileId(3),
            HitLevel::Ddr { ctrl_attach: TileId(2) },
        );
        assert_eq!(a.level, LEVEL_DDR);
        assert_eq!(a.dst, TileId(2));
    }
}
