//! AOT artifact loading: `artifacts/manifest.json` + `*.hlo.txt` → compiled
//! PJRT executables.
//!
//! Python runs once at build time (`make artifacts`); this module is the
//! only bridge, and it loads HLO *text* — see python/compile/aot.py for why
//! text (xla_extension 0.5.1 rejects jax ≥0.5's 64-bit-id protos).

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::runtime::xla;
use crate::util::json::{self, Json};

#[derive(Debug)]
pub enum ArtifactError {
    Io {
        path: PathBuf,
        source: std::io::Error,
    },
    Manifest(json::ParseError),
    MissingField(&'static str),
    Unknown(String, String),
    SizeMismatch {
        name: String,
        expected: usize,
        actual: usize,
    },
    Xla(xla::Error),
}

impl std::fmt::Display for ArtifactError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ArtifactError::Io { path, source } => {
                write!(f, "io error reading {}: {source}", path.display())
            }
            ArtifactError::Manifest(e) => write!(f, "manifest parse error: {e}"),
            ArtifactError::MissingField(name) => write!(f, "manifest missing field {name}"),
            ArtifactError::Unknown(name, have) => {
                write!(f, "unknown artifact '{name}' (have: {have})")
            }
            ArtifactError::SizeMismatch {
                name,
                expected,
                actual,
            } => write!(
                f,
                "artifact {name}: size mismatch (manifest {expected} B, file {actual} B)"
            ),
            ArtifactError::Xla(e) => write!(f, "xla error: {e}"),
        }
    }
}

impl std::error::Error for ArtifactError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            ArtifactError::Io { source, .. } => Some(source),
            ArtifactError::Manifest(e) => Some(e),
            ArtifactError::Xla(e) => Some(e),
            _ => None,
        }
    }
}

impl From<json::ParseError> for ArtifactError {
    fn from(e: json::ParseError) -> ArtifactError {
        ArtifactError::Manifest(e)
    }
}

impl From<xla::Error> for ArtifactError {
    fn from(e: xla::Error) -> ArtifactError {
        ArtifactError::Xla(e)
    }
}

/// Input spec recorded by aot.py.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct InputSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl InputSpec {
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Manifest entry for one artifact.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: String,
    pub inputs: Vec<InputSpec>,
    pub bytes: usize,
}

/// Parsed manifest.
pub struct Manifest {
    pub artifacts: Vec<ArtifactMeta>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest, ArtifactError> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|source| ArtifactError::Io {
            path: path.clone(),
            source,
        })?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest, ArtifactError> {
        let v = json::parse(text)?;
        let arts = v
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or(ArtifactError::MissingField("artifacts"))?;
        let mut artifacts = Vec::new();
        for a in arts {
            let name = a
                .get("name")
                .and_then(Json::as_str)
                .ok_or(ArtifactError::MissingField("name"))?
                .to_string();
            let file = a
                .get("file")
                .and_then(Json::as_str)
                .ok_or(ArtifactError::MissingField("file"))?
                .to_string();
            let bytes = a
                .get("bytes")
                .and_then(Json::as_usize)
                .ok_or(ArtifactError::MissingField("bytes"))?;
            let mut inputs = Vec::new();
            for i in a
                .get("inputs")
                .and_then(Json::as_arr)
                .ok_or(ArtifactError::MissingField("inputs"))?
            {
                let shape = i
                    .get("shape")
                    .and_then(Json::as_arr)
                    .ok_or(ArtifactError::MissingField("shape"))?
                    .iter()
                    .map(|j| j.as_usize().unwrap_or(0))
                    .collect();
                let dtype = i
                    .get("dtype")
                    .and_then(Json::as_str)
                    .ok_or(ArtifactError::MissingField("dtype"))?
                    .to_string();
                inputs.push(InputSpec { shape, dtype });
            }
            artifacts.push(ArtifactMeta {
                name,
                file,
                inputs,
                bytes,
            });
        }
        Ok(Manifest { artifacts })
    }

    pub fn get(&self, name: &str) -> Option<&ArtifactMeta> {
        self.artifacts.iter().find(|a| a.name == name)
    }
}

/// All compiled executables, keyed by artifact name. One PJRT client is
/// shared; each artifact compiles once at startup and is reused for every
/// request (python never runs again).
pub struct ArtifactSet {
    pub client: xla::PjRtClient,
    pub manifest: Manifest,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl ArtifactSet {
    /// Load and compile every artifact in `dir`.
    pub fn load(dir: &Path) -> Result<ArtifactSet, ArtifactError> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu()?;
        let mut executables = HashMap::new();
        for meta in &manifest.artifacts {
            let path = dir.join(&meta.file);
            let text = std::fs::read_to_string(&path).map_err(|source| ArtifactError::Io {
                path: path.clone(),
                source,
            })?;
            if text.len() != meta.bytes {
                return Err(ArtifactError::SizeMismatch {
                    name: meta.name.clone(),
                    expected: meta.bytes,
                    actual: text.len(),
                });
            }
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().expect("utf-8 artifact path"),
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp)?;
            executables.insert(meta.name.clone(), exe);
        }
        Ok(ArtifactSet {
            client,
            manifest,
            executables,
        })
    }

    pub fn executable(&self, name: &str) -> Result<&xla::PjRtLoadedExecutable, ArtifactError> {
        self.executables.get(name).ok_or_else(|| {
            ArtifactError::Unknown(
                name.to_string(),
                self.executables
                    .keys()
                    .cloned()
                    .collect::<Vec<_>>()
                    .join(", "),
            )
        })
    }

    pub fn names(&self) -> Vec<&str> {
        self.manifest.artifacts.iter().map(|a| a.name.as_str()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{"artifacts":[
        {"name":"full_sort","file":"full_sort.hlo.txt","bytes":7,
         "inputs":[{"shape":[64,1024],"dtype":"int32"}],"sha256":"x"},
        {"name":"latency_model","file":"latency_model.hlo.txt","bytes":3,
         "inputs":[{"shape":[1024,2],"dtype":"int32"},{"shape":[1024],"dtype":"float32"}],
         "sha256":"y"}]}"#;

    #[test]
    fn parse_manifest() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.artifacts.len(), 2);
        let fs = m.get("full_sort").unwrap();
        assert_eq!(fs.inputs[0].shape, vec![64, 1024]);
        assert_eq!(fs.inputs[0].elems(), 65536);
        assert_eq!(fs.inputs[0].dtype, "int32");
        assert!(m.get("nope").is_none());
    }

    #[test]
    fn parse_rejects_missing_fields() {
        assert!(Manifest::parse(r#"{"artifacts":[{"name":"a"}]}"#).is_err());
        assert!(Manifest::parse(r#"{}"#).is_err());
    }

    #[test]
    fn load_missing_dir_errors() {
        assert!(Manifest::load(Path::new("/nonexistent-dir-xyz")).is_err());
    }
}
