//! Dependency-free PJRT stand-in with the exact API surface the runtime
//! layer uses (`PjRtClient`, `Literal`, `HloModuleProto`, …).
//!
//! The offline build has no `xla` crate, so the AOT'd HLO artifacts are
//! "compiled" by name and executed by native reference kernels whose
//! semantics mirror `python/compile/model.py` bit-for-bit where it matters:
//! the sorters produce the same sorted output the Pallas pipeline would,
//! and `latency_model` evaluates the same closed form the integration
//! tests cross-check against `arch::LatencyParams::access_cycles`. When a
//! real PJRT binding is available this module is the single swap point.

use std::fmt;

/// Opaque error type matching the binding's `xla::Error`.
#[derive(Debug)]
pub struct Error(String);

impl Error {
    fn new(msg: impl Into<String>) -> Error {
        Error(msg.into())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

/// Element storage for a literal (only the dtypes the artifacts use).
#[derive(Clone, Debug, PartialEq)]
enum Payload {
    I32(Vec<i32>),
    F32(Vec<f32>),
    Tuple(Vec<Literal>),
}

/// Scalar types a literal can hold.
pub trait NativeType: Copy {
    fn into_payload(v: Vec<Self>) -> Payload;
    fn from_payload(p: &Payload) -> Option<Vec<Self>>;
}

impl NativeType for i32 {
    fn into_payload(v: Vec<i32>) -> Payload {
        Payload::I32(v)
    }
    fn from_payload(p: &Payload) -> Option<Vec<i32>> {
        match p {
            Payload::I32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl NativeType for f32 {
    fn into_payload(v: Vec<f32>) -> Payload {
        Payload::F32(v)
    }
    fn from_payload(p: &Payload) -> Option<Vec<f32>> {
        match p {
            Payload::F32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

/// A host literal: flat payload + logical dims (row-major).
#[derive(Clone, Debug, PartialEq)]
pub struct Literal {
    payload: Payload,
    dims: Vec<i64>,
}

impl Literal {
    pub fn vec1<T: NativeType>(v: &[T]) -> Literal {
        Literal {
            dims: vec![v.len() as i64],
            payload: T::into_payload(v.to_vec()),
        }
    }

    fn tuple(items: Vec<Literal>) -> Literal {
        Literal {
            payload: Payload::Tuple(items),
            dims: Vec::new(),
        }
    }

    fn element_count(&self) -> usize {
        match &self.payload {
            Payload::I32(v) => v.len(),
            Payload::F32(v) => v.len(),
            Payload::Tuple(_) => 0,
        }
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    /// Reinterpret the flat payload under new dims (element count checked).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal, Error> {
        let want: i64 = dims.iter().product();
        if want as usize != self.element_count() {
            return Err(Error::new(format!(
                "reshape {:?} -> {dims:?}: element count mismatch",
                self.dims
            )));
        }
        Ok(Literal {
            payload: self.payload.clone(),
            dims: dims.to_vec(),
        })
    }

    pub fn to_tuple1(&self) -> Result<Literal, Error> {
        match &self.payload {
            Payload::Tuple(items) if items.len() == 1 => Ok(items[0].clone()),
            _ => Err(Error::new("literal is not a 1-tuple")),
        }
    }

    pub fn to_tuple2(&self) -> Result<(Literal, Literal), Error> {
        match &self.payload {
            Payload::Tuple(items) if items.len() == 2 => {
                Ok((items[0].clone(), items[1].clone()))
            }
            _ => Err(Error::new("literal is not a 2-tuple")),
        }
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>, Error> {
        T::from_payload(&self.payload).ok_or_else(|| Error::new("literal dtype mismatch"))
    }

    pub fn get_first_element<T: NativeType>(&self) -> Result<T, Error> {
        self.to_vec::<T>()?
            .first()
            .copied()
            .ok_or_else(|| Error::new("empty literal"))
    }
}

/// Parsed HLO module (we keep the name; the text itself is checked by the
/// manifest size/hash fields upstream).
pub struct HloModuleProto {
    name: String,
}

impl HloModuleProto {
    /// Read HLO text and extract the module name (`HloModule <name>, ...`).
    pub fn from_text_file(path: &str) -> Result<HloModuleProto, Error> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::new(format!("read {path}: {e}")))?;
        let name = text
            .lines()
            .find_map(|l| l.trim().strip_prefix("HloModule "))
            .map(|rest| {
                rest.split(|c: char| c == ',' || c.is_whitespace())
                    .next()
                    .unwrap_or("")
                    .to_string()
            })
            .filter(|n| !n.is_empty())
            .ok_or_else(|| Error::new(format!("{path}: no HloModule header")))?;
        Ok(HloModuleProto { name })
    }
}

pub struct XlaComputation {
    name: String,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation {
            name: proto.name.clone(),
        }
    }
}

/// The reference kernels the shim can "compile".
#[derive(Clone, Copy, Debug)]
enum Kernel {
    SortChunks,
    MergePass,
    FullSort,
    LatencyModel,
}

pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        Ok(PjRtClient)
    }

    pub fn compile(&self, comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        // Artifact names survive jax's `jit_` prefixing, so substring match.
        let kernel = if comp.name.contains("full_sort") {
            Kernel::FullSort
        } else if comp.name.contains("sort_chunks") {
            Kernel::SortChunks
        } else if comp.name.contains("merge_pass") {
            Kernel::MergePass
        } else if comp.name.contains("latency_model") {
            Kernel::LatencyModel
        } else {
            return Err(Error::new(format!(
                "no native kernel for module '{}'",
                comp.name
            )));
        };
        Ok(PjRtLoadedExecutable { kernel })
    }
}

pub struct PjRtLoadedExecutable {
    kernel: Kernel,
}

pub struct PjRtBuffer {
    literal: Literal,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Ok(self.literal.clone())
    }
}

impl PjRtLoadedExecutable {
    /// Execute with host literals; returns per-device, per-output buffers
    /// (one device, one tuple output — the shape the call sites index).
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        let args: Vec<&Literal> = args.iter().map(|a| a.borrow()).collect();
        let out = match self.kernel {
            Kernel::FullSort => full_sort(&args)?,
            Kernel::SortChunks => sort_rows(&args, 1)?,
            Kernel::MergePass => sort_rows(&args, 2)?,
            Kernel::LatencyModel => latency_model(&args)?,
        };
        Ok(vec![vec![PjRtBuffer { literal: out }]])
    }
}

fn arg<'a>(args: &[&'a Literal], i: usize) -> Result<&'a Literal, Error> {
    args.get(i)
        .copied()
        .ok_or_else(|| Error::new(format!("missing argument {i}")))
}

/// `full_sort`: globally sort the (num_chunks, chunk) i32 batch row-major.
fn full_sort(args: &[&Literal]) -> Result<Literal, Error> {
    let x = arg(args, 0)?;
    let mut data = x.to_vec::<i32>()?;
    data.sort_unstable();
    Ok(Literal::tuple(vec![Literal {
        payload: Payload::I32(data),
        dims: x.dims().to_vec(),
    }]))
}

/// `sort_chunks` (group = 1 row) and `merge_pass` (group = 2 adjacent
/// sorted rows): sort each group of rows independently — for already-sorted
/// rows a pairwise merge and a sort of the pair are identical.
fn sort_rows(args: &[&Literal], group: usize) -> Result<Literal, Error> {
    let x = arg(args, 0)?;
    let dims = x.dims();
    if dims.len() != 2 {
        return Err(Error::new(format!("expected 2-d input, got {dims:?}")));
    }
    let (rows, cols) = (dims[0] as usize, dims[1] as usize);
    if rows % group != 0 {
        return Err(Error::new(format!("{rows} rows not divisible by {group}")));
    }
    let mut data = x.to_vec::<i32>()?;
    for block in data.chunks_mut(group * cols) {
        block.sort_unstable();
    }
    Ok(Literal::tuple(vec![Literal {
        payload: Payload::I32(data),
        dims: dims.to_vec(),
    }]))
}

/// The analytical NUCA latency model — the same closed form as
/// `python/compile/model.py::latency_model` (constants mirrored from
/// `arch::LatencyParams::TILEPRO64`).
fn latency_model(args: &[&Literal]) -> Result<Literal, Error> {
    const L1_HIT: f32 = 2.0;
    const L2_HIT: f32 = 8.0;
    const NOC_HEADER: f32 = 6.0;
    const NOC_HOP: f32 = 1.0;
    const DDR: f32 = 88.0;

    let req = arg(args, 0)?.to_vec::<i32>()?;
    let dst = arg(args, 1)?.to_vec::<i32>()?;
    let level = arg(args, 2)?.to_vec::<i32>()?;
    let cont = arg(args, 3)?.to_vec::<f32>()?;
    let n = level.len();
    if req.len() != 2 * n || dst.len() != 2 * n || cont.len() != n {
        return Err(Error::new("latency_model: inconsistent batch shapes"));
    }
    let mut per = Vec::with_capacity(n);
    let mut total = 0.0f32;
    for i in 0..n {
        let hops = (req[2 * i] - dst[2 * i]).abs() + (req[2 * i + 1] - dst[2 * i + 1]).abs();
        let mesh = NOC_HEADER + 2.0 * NOC_HOP * hops as f32;
        let base = match level[i] {
            0 => L1_HIT,
            1 => L2_HIT,
            2 => L2_HIT + mesh,
            _ => DDR + mesh,
        };
        let cycles = base + cont[i];
        per.push(cycles);
        total += cycles;
    }
    Ok(Literal::tuple(vec![
        Literal {
            payload: Payload::F32(per),
            dims: vec![n as i64],
        },
        Literal {
            payload: Payload::F32(vec![total]),
            dims: Vec::new(),
        },
    ]))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_reshape_checks_counts() {
        let l = Literal::vec1(&[1i32, 2, 3, 4]);
        assert_eq!(l.reshape(&[2, 2]).unwrap().dims(), &[2, 2]);
        assert!(l.reshape(&[3, 2]).is_err());
    }

    #[test]
    fn full_sort_kernel_sorts_globally() {
        let exe = PjRtLoadedExecutable {
            kernel: Kernel::FullSort,
        };
        let lit = Literal::vec1(&[5i32, -1, 3, 0]).reshape(&[2, 2]).unwrap();
        let out = exe.execute::<Literal>(&[lit]).unwrap()[0][0]
            .to_literal_sync()
            .unwrap()
            .to_tuple1()
            .unwrap();
        assert_eq!(out.to_vec::<i32>().unwrap(), vec![-1, 0, 3, 5]);
        assert_eq!(out.dims(), &[2, 2]);
    }

    #[test]
    fn merge_pass_merges_adjacent_sorted_rows() {
        let exe = PjRtLoadedExecutable {
            kernel: Kernel::MergePass,
        };
        // Rows sorted; pairs (0,1) and (2,3) merge independently.
        let lit = Literal::vec1(&[1i32, 4, 2, 3, 9, 9, 0, 8])
            .reshape(&[4, 2])
            .unwrap();
        let out = exe.execute::<Literal>(&[lit]).unwrap()[0][0]
            .to_literal_sync()
            .unwrap()
            .to_tuple1()
            .unwrap();
        assert_eq!(out.to_vec::<i32>().unwrap(), vec![1, 2, 3, 4, 0, 8, 9, 9]);
    }

    #[test]
    fn latency_kernel_matches_rust_params() {
        use crate::arch::{HitLevel, LatencyParams, TileId};
        let exe = PjRtLoadedExecutable {
            kernel: Kernel::LatencyModel,
        };
        let params = LatencyParams::TILEPRO64;
        // Requester (1,0)=tile 1, home (7,7)=tile 63, level 2.
        let req = Literal::vec1(&[1i32, 0]).reshape(&[1, 2]).unwrap();
        let dst = Literal::vec1(&[7i32, 7]).reshape(&[1, 2]).unwrap();
        let level = Literal::vec1(&[2i32]);
        let cont = Literal::vec1(&[0.0f32]);
        let out = exe.execute::<Literal>(&[req, dst, level, cont]).unwrap()[0][0]
            .to_literal_sync()
            .unwrap();
        let (per, total) = out.to_tuple2().unwrap();
        let want = params.access_cycles(TileId(1), HitLevel::Home { home: TileId(63) }) as f32;
        assert_eq!(per.to_vec::<f32>().unwrap(), vec![want]);
        assert_eq!(total.get_first_element::<f32>().unwrap(), want);
    }

    #[test]
    fn compile_rejects_unknown_modules() {
        let client = PjRtClient::cpu().unwrap();
        let comp = XlaComputation {
            name: "jit_mystery".into(),
        };
        assert!(client.compile(&comp).is_err());
        let ok = XlaComputation {
            name: "jit_full_sort".into(),
        };
        assert!(client.compile(&ok).is_ok());
    }
}
