//! PJRT runtime: loads the AOT artifacts (HLO text) once and executes them
//! on the request path. Python is build-time only.

pub mod artifact;
pub mod latency;
pub mod sorter;
pub mod xla;

pub use artifact::{ArtifactError, ArtifactSet, Manifest};
pub use latency::{AccessDesc, LatencyModel, LATENCY_BATCH};
pub use sorter::{ChunkedSorter, SortMetrics, BATCH, CHUNK, NUM_CHUNKS};

use std::path::PathBuf;

/// Locate the artifacts directory: $TILESIM_ARTIFACTS, else ./artifacts
/// relative to the workspace root.
pub fn artifacts_dir() -> PathBuf {
    if let Ok(dir) = std::env::var("TILESIM_ARTIFACTS") {
        return PathBuf::from(dir);
    }
    // Look upward from CWD for an `artifacts/manifest.json`.
    let mut cur = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
    loop {
        let cand = cur.join("artifacts");
        if cand.join("manifest.json").exists() {
            return cand;
        }
        if !cur.pop() {
            return PathBuf::from("artifacts");
        }
    }
}
