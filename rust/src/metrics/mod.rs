//! Reporting helpers: the per-tile home-traffic heatmap that makes the
//! paper's hot-spot story visible (`repro heatmap`), plus small summary
//! statistics used by the CLI and examples.

use crate::arch::{GRID_H, GRID_W};
use crate::sim::RunStats;

/// Render the 8×8 grid of home-port request counts as an ASCII heatmap.
/// Intensity characters: ` .:-=+*#%@` scaled to the max tile.
pub fn home_heatmap(stats: &RunStats) -> String {
    let counts = &stats.tile_home_requests;
    let max = counts.iter().copied().max().unwrap_or(0);
    let ramp: &[u8] = b" .:-=+*#%@";
    let mut out = String::new();
    out.push_str("home-port requests per tile (rows = mesh y):\n");
    for y in 0..GRID_H {
        out.push_str("  ");
        for x in 0..GRID_W {
            let n = counts
                .get((y * GRID_W + x) as usize)
                .copied()
                .unwrap_or(0);
            let ix = if max == 0 {
                0
            } else {
                ((n as f64 / max as f64) * (ramp.len() - 1) as f64).round() as usize
            };
            out.push(ramp[ix] as char);
            out.push(ramp[ix] as char); // double-width for aspect ratio
        }
        out.push('\n');
    }
    let total: u64 = counts.iter().sum();
    out.push_str(&format!(
        "  total {total} requests, hottest tile {max} ({:.1}% of traffic)\n",
        if total == 0 { 0.0 } else { 100.0 * max as f64 / total as f64 }
    ));
    out
}

/// Gini-style concentration of home traffic in [0, 1]: 0 = perfectly
/// spread (hash-for-home's goal), →1 = single hot tile (the disaster).
pub fn home_concentration(stats: &RunStats) -> f64 {
    let counts = &stats.tile_home_requests;
    let total: u64 = counts.iter().sum();
    if total == 0 {
        return 0.0;
    }
    let max = counts.iter().copied().max().unwrap_or(0);
    let n = counts.len() as f64;
    // Normalised max-share: (max/total - 1/n) / (1 - 1/n).
    (max as f64 / total as f64 - 1.0 / n) / (1.0 - 1.0 / n)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stats_with(counts: Vec<u64>) -> RunStats {
        RunStats {
            tile_home_requests: counts,
            ..RunStats::default()
        }
    }

    #[test]
    fn heatmap_renders_8_rows() {
        let s = stats_with(vec![5; 64]);
        let map = home_heatmap(&s);
        assert_eq!(map.lines().count(), 10); // header + 8 rows + footer
    }

    #[test]
    fn heatmap_handles_empty() {
        let s = stats_with(vec![0; 64]);
        let map = home_heatmap(&s);
        assert!(map.contains("total 0 requests"));
    }

    #[test]
    fn concentration_uniform_is_zero() {
        let s = stats_with(vec![10; 64]);
        assert!(home_concentration(&s).abs() < 1e-9);
    }

    #[test]
    fn concentration_single_hot_tile_is_one() {
        let mut counts = vec![0u64; 64];
        counts[0] = 1000;
        let s = stats_with(counts);
        assert!((home_concentration(&s) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn concentration_orders_hot_vs_spread() {
        let mut hot = vec![1u64; 64];
        hot[0] = 1000;
        let spread = vec![16u64; 64];
        assert!(home_concentration(&stats_with(hot)) > home_concentration(&stats_with(spread)));
    }
}
