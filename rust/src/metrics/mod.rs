//! Reporting helpers: per-tile home-traffic and per-link mesh-traffic
//! heatmaps that make the paper's hot-spot story visible (`repro …
//! --heatmap`), plus small summary statistics used by the CLI and examples.
//!
//! Grid dimensions come from the run's [`Machine`] — any `H×W` grid
//! renders, not just the TILEPro64's 8×8.

use crate::arch::{Dir, Machine, TileId};
use crate::sim::RunStats;

const RAMP: &[u8] = b" .:-=+*#%@";

fn ramp_char(n: u64, max: u64) -> char {
    let ix = if max == 0 {
        0
    } else {
        ((n as f64 / max as f64) * (RAMP.len() - 1) as f64).round() as usize
    };
    RAMP[ix] as char
}

/// Render the machine's `H×W` grid of home-port request counts as an ASCII
/// heatmap. Intensity characters: ` .:-=+*#%@` scaled to the max tile.
pub fn home_heatmap(stats: &RunStats, machine: &Machine) -> String {
    let counts = &stats.tile_home_requests;
    debug_assert_eq!(
        counts.len(),
        machine.num_tiles() as usize,
        "tile_home_requests sized for a different machine than {}",
        machine.name()
    );
    let max = counts.iter().copied().max().unwrap_or(0);
    let mut out = String::new();
    out.push_str(&format!(
        "home-port requests per tile, {}x{} {} (rows = mesh y):\n",
        machine.grid_w(),
        machine.grid_h(),
        machine.name()
    ));
    for y in 0..machine.grid_h() {
        out.push_str("  ");
        for x in 0..machine.grid_w() {
            let n = counts
                .get((y * machine.grid_w() + x) as usize)
                .copied()
                .unwrap_or(0);
            let c = ramp_char(n, max);
            out.push(c);
            out.push(c); // double-width for aspect ratio
        }
        out.push('\n');
    }
    let total: u64 = counts.iter().sum();
    out.push_str(&format!(
        "  total {total} requests, hottest tile {max} ({:.1}% of traffic)\n",
        if total == 0 { 0.0 } else { 100.0 * max as f64 / total as f64 }
    ));
    out
}

/// Render per-tile mesh-link traffic: each cell shows the busiest of the
/// tile's four outgoing links; the footer names the hottest directed link
/// chip-wide. Empty string when the run did not model link contention.
pub fn link_heatmap(stats: &RunStats, machine: &Machine) -> String {
    if !stats.links_modelled() {
        return String::new();
    }
    let links = &stats.link_requests;
    debug_assert_eq!(
        links.len(),
        machine.num_links(),
        "link_requests sized for a different machine than {}",
        machine.name()
    );
    let per_tile = |t: TileId| -> u64 {
        Dir::ALL
            .iter()
            .map(|&d| links.get(machine.link_index(t, d)).copied().unwrap_or(0))
            .max()
            .unwrap_or(0)
    };
    let max = machine.tiles().map(per_tile).max().unwrap_or(0);
    let mut out = String::new();
    out.push_str(&format!(
        "mesh-link traffic per tile (max outgoing link), {}x{} {}:\n",
        machine.grid_w(),
        machine.grid_h(),
        machine.name()
    ));
    for y in 0..machine.grid_h() {
        out.push_str("  ");
        for x in 0..machine.grid_w() {
            let c = ramp_char(per_tile(TileId(y * machine.grid_w() + x)), max);
            out.push(c);
            out.push(c);
        }
        out.push('\n');
    }
    match stats.hottest_link() {
        Some((ix, n)) => out.push_str(&format!(
            "  hottest link {} with {n} packets, {} link-queue cycles total\n",
            machine.link_label(ix),
            stats.link_queue_cycles
        )),
        None => out.push_str("  no link traffic\n"),
    }
    out
}

/// Gini-style concentration of home traffic in [0, 1]: 0 = perfectly
/// spread (hash-for-home's goal), →1 = single hot tile (the disaster).
pub fn home_concentration(stats: &RunStats) -> f64 {
    let counts = &stats.tile_home_requests;
    let total: u64 = counts.iter().sum();
    if total == 0 {
        return 0.0;
    }
    let max = counts.iter().copied().max().unwrap_or(0);
    let n = counts.len() as f64;
    // Normalised max-share: (max/total - 1/n) / (1 - 1/n).
    (max as f64 / total as f64 - 1.0 / n) / (1.0 - 1.0 / n)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stats_with(counts: Vec<u64>) -> RunStats {
        RunStats {
            tile_home_requests: counts,
            ..RunStats::default()
        }
    }

    #[test]
    fn heatmap_renders_8_rows() {
        let s = stats_with(vec![5; 64]);
        let map = home_heatmap(&s, &Machine::tilepro64());
        assert_eq!(map.lines().count(), 10); // header + 8 rows + footer
    }

    #[test]
    fn heatmap_renders_machine_aspect() {
        // 4 wide × 8 tall: 8 grid rows, 4 double-width columns each.
        let m = Machine::custom(4, 8, 2).unwrap();
        let s = stats_with(vec![3; 32]);
        let map = home_heatmap(&s, &m);
        assert_eq!(map.lines().count(), 10);
        let row = map.lines().nth(1).unwrap();
        assert_eq!(row.trim_end().len(), 2 + 8);
        // 16×16 renders 16 rows.
        let s = stats_with(vec![1; 256]);
        assert_eq!(home_heatmap(&s, &Machine::nuca256()).lines().count(), 18);
    }

    #[test]
    #[should_panic(expected = "sized for a different machine")]
    #[cfg(debug_assertions)]
    fn heatmap_length_mismatch_asserts() {
        let s = stats_with(vec![0; 64]);
        home_heatmap(&s, &Machine::epiphany16());
    }

    #[test]
    fn heatmap_handles_empty() {
        let s = stats_with(vec![0; 64]);
        let map = home_heatmap(&s, &Machine::tilepro64());
        assert!(map.contains("total 0 requests"));
    }

    #[test]
    fn link_heatmap_empty_without_link_model() {
        let s = stats_with(vec![0; 64]);
        assert_eq!(link_heatmap(&s, &Machine::tilepro64()), "");
    }

    #[test]
    fn link_heatmap_names_hottest_link() {
        let m = Machine::tilepro64();
        let mut links = vec![0u64; m.num_links()];
        let hot = m.link_index(TileId(9), Dir::East);
        links[hot] = 42;
        let s = RunStats {
            tile_home_requests: vec![0; 64],
            link_requests: links,
            link_queue_cycles: 17,
            ..RunStats::default()
        };
        let map = link_heatmap(&s, &m);
        assert!(map.contains("hottest link E(1,1) with 42 packets"), "{map}");
        assert!(map.contains("17 link-queue cycles"));
    }

    #[test]
    fn concentration_uniform_is_zero() {
        let s = stats_with(vec![10; 64]);
        assert!(home_concentration(&s).abs() < 1e-9);
    }

    #[test]
    fn concentration_single_hot_tile_is_one() {
        let mut counts = vec![0u64; 64];
        counts[0] = 1000;
        let s = stats_with(counts);
        assert!((home_concentration(&s) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn concentration_orders_hot_vs_spread() {
        let mut hot = vec![1u64; 64];
        hot[0] = 1000;
        let spread = vec![16u64; 64];
        assert!(home_concentration(&stats_with(hot)) > home_concentration(&stats_with(spread)));
    }
}
