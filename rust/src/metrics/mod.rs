//! Reporting helpers: per-tile home-traffic and per-link mesh-traffic
//! heatmaps that make the paper's hot-spot story visible (`repro …
//! --heatmap`), plus small summary statistics used by the CLI and examples.
//!
//! Grid dimensions come from the run's [`Machine`] — any `H×W` grid
//! renders, not just the TILEPro64's 8×8. A stats/machine pairing whose
//! vector lengths disagree is a caller bug; it is reported as a
//! [`MetricsError`] (not a debug assertion), so a bad pairing fails loudly
//! in release batch runs instead of rendering garbage.
//!
//! Link heatmaps exist per *traffic class* ([`TrafficClass`]): forward
//! requests, data/ack replies, and invalidation fan-out — so a saturated
//! mesh can be attributed to the coherence traffic that caused it.

use crate::arch::{Dir, Machine, Partition, TileId};
use crate::sim::RunStats;

const RAMP: &[u8] = b" .:-=+*#%@";

/// A stats vector didn't match the machine it was rendered against.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum MetricsError {
    /// `what` has `got` entries but `machine` needs `expected`.
    Mismatch {
        what: &'static str,
        expected: usize,
        got: usize,
        machine: String,
    },
}

impl std::fmt::Display for MetricsError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            MetricsError::Mismatch {
                what,
                expected,
                got,
                machine,
            } => write!(
                f,
                "{what} has {got} entries but machine {machine} needs {expected} — \
                 stats were produced on a different machine"
            ),
        }
    }
}

impl std::error::Error for MetricsError {}

fn check_len(
    what: &'static str,
    got: usize,
    expected: usize,
    machine: &Machine,
) -> Result<(), MetricsError> {
    if got == expected {
        Ok(())
    } else {
        Err(MetricsError::Mismatch {
            what,
            expected,
            got,
            machine: machine.name(),
        })
    }
}

fn ramp_char(n: u64, max: u64) -> char {
    let ix = if max == 0 {
        0
    } else {
        ((n as f64 / max as f64) * (RAMP.len() - 1) as f64).round() as usize
    };
    RAMP[ix] as char
}

/// Render the machine's `H×W` grid of home-port request counts as an ASCII
/// heatmap. Intensity characters: ` .:-=+*#%@` scaled to the max tile.
/// Errors when `stats.tile_home_requests` was produced on a different
/// machine (length mismatch).
pub fn home_heatmap(stats: &RunStats, machine: &Machine) -> Result<String, MetricsError> {
    let counts = &stats.tile_home_requests;
    check_len(
        "tile_home_requests",
        counts.len(),
        machine.num_tiles() as usize,
        machine,
    )?;
    let max = counts.iter().copied().max().unwrap_or(0);
    let mut out = String::new();
    out.push_str(&format!(
        "home-port requests per tile, {}x{} {} (rows = mesh y):\n",
        machine.grid_w(),
        machine.grid_h(),
        machine.name()
    ));
    for y in 0..machine.grid_h() {
        out.push_str("  ");
        for x in 0..machine.grid_w() {
            let n = counts[(y * machine.grid_w() + x) as usize];
            let c = ramp_char(n, max);
            out.push(c);
            out.push(c); // double-width for aspect ratio
        }
        out.push('\n');
    }
    let total: u64 = counts.iter().sum();
    out.push_str(&format!(
        "  total {total} requests, hottest tile {max} ({:.1}% of traffic)\n",
        if total == 0 { 0.0 } else { 100.0 * max as f64 / total as f64 }
    ));
    Ok(out)
}

/// Which per-link traffic vector a link heatmap renders.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TrafficClass {
    /// Forward request routes (`RunStats::link_requests`).
    Request,
    /// Data/ack reply routes (`RunStats::link_reply_requests`).
    Reply,
    /// Invalidation fan-out + ack routes (`RunStats::link_inval_requests`).
    Invalidation,
}

impl TrafficClass {
    pub const ALL: [TrafficClass; 3] = [
        TrafficClass::Request,
        TrafficClass::Reply,
        TrafficClass::Invalidation,
    ];

    fn label(self) -> &'static str {
        match self {
            TrafficClass::Request => "requests",
            TrafficClass::Reply => "replies",
            TrafficClass::Invalidation => "invalidations",
        }
    }

    fn counts(self, stats: &RunStats) -> &[u64] {
        match self {
            TrafficClass::Request => &stats.link_requests,
            TrafficClass::Reply => &stats.link_reply_requests,
            TrafficClass::Invalidation => &stats.link_inval_requests,
        }
    }
}

fn link_grid(links: &[u64], machine: &Machine, out: &mut String) {
    let per_tile = |t: TileId| -> u64 {
        Dir::ALL
            .iter()
            .map(|&d| links[machine.link_index(t, d)])
            .max()
            .unwrap_or(0)
    };
    let max = machine.tiles().map(per_tile).max().unwrap_or(0);
    for y in 0..machine.grid_h() {
        out.push_str("  ");
        for x in 0..machine.grid_w() {
            let c = ramp_char(per_tile(TileId(y * machine.grid_w() + x)), max);
            out.push(c);
            out.push(c);
        }
        out.push('\n');
    }
}

/// Render per-tile mesh-link traffic for the request class: each cell
/// shows the busiest of the tile's four outgoing links; the footer names
/// the hottest directed link chip-wide. `Ok` with an empty string when the
/// run did not model link contention; an error when `link_requests` was
/// produced on a different machine.
pub fn link_heatmap(stats: &RunStats, machine: &Machine) -> Result<String, MetricsError> {
    if !stats.links_modelled() {
        return Ok(String::new());
    }
    check_len(
        "link_requests",
        stats.link_requests.len(),
        machine.num_links(),
        machine,
    )?;
    let mut out = String::new();
    out.push_str(&format!(
        "mesh-link traffic per tile (max outgoing link), {}x{} {}:\n",
        machine.grid_w(),
        machine.grid_h(),
        machine.name()
    ));
    link_grid(&stats.link_requests, machine, &mut out);
    match stats.hottest_link() {
        Some((ix, n)) => out.push_str(&format!(
            "  hottest link {} with {n} packets, {} link-queue cycles total\n",
            machine.link_label(ix),
            stats.link_queue_cycles
        )),
        None => out.push_str("  no link traffic\n"),
    }
    // On a heterogeneous fabric, name the service classes so a hot link
    // can be read against how wide it is.
    let classes = physical_service_classes(machine);
    if classes.len() > 1 {
        out.push_str(&format!("  {}\n", service_classes_line(&classes)));
    }
    Ok(out)
}

/// Distinct service values over the *physical* directed links (off-grid
/// boundary slots have table entries but never carry traffic — see
/// `Machine::has_link`), cheapest first with link counts.
fn physical_service_classes(machine: &Machine) -> Vec<(u64, usize)> {
    crate::arch::Fabric::classes_of(machine.tiles().flat_map(|t| {
        Dir::ALL
            .into_iter()
            .filter(move |&d| machine.has_link(t, d))
            .map(move |d| machine.fabric().service(machine.link_index(t, d)))
    }))
}

/// One-line summary of physical link service classes, cheapest first,
/// e.g. `link service classes: 1 cy x 14 links (express), 4 cy x 210 links`.
fn service_classes_line(classes: &[(u64, usize)]) -> String {
    let fastest = classes.first().map(|&(s, _)| s).unwrap_or(0);
    let parts: Vec<String> = classes
        .iter()
        .map(|&(service, links)| {
            format!(
                "{service} cy x {links} links{}",
                if service == fastest && classes.len() > 1 { " (express)" } else { "" }
            )
        })
        .collect();
    format!("link service classes: {}", parts.join(", "))
}

/// Render the per-tile link-service map of a heterogeneous fabric: each
/// cell shows the *fastest* physically existing outgoing link's service
/// time as a digit (`+` for 10 cycles and up), making express
/// rows/columns visible at a glance. Empty string when the physical
/// links are uniform (nothing to show). The service-class legend lives
/// on [`link_heatmap`], so the two never repeat it.
pub fn fabric_map(machine: &Machine) -> String {
    if physical_service_classes(machine).len() <= 1 {
        return String::new();
    }
    let mut out = String::new();
    out.push_str(&format!(
        "link service per tile (fastest outgoing link), {}x{} {}:\n",
        machine.grid_w(),
        machine.grid_h(),
        machine.name()
    ));
    for y in 0..machine.grid_h() {
        out.push_str("  ");
        for x in 0..machine.grid_w() {
            let t = TileId(y * machine.grid_w() + x);
            let fastest = Dir::ALL
                .into_iter()
                .filter(|&d| machine.has_link(t, d))
                .map(|d| machine.fabric().service(machine.link_index(t, d)))
                .min()
                .unwrap_or(0);
            let c = if fastest < 10 {
                (b'0' + fastest as u8) as char
            } else {
                '+'
            };
            out.push(c);
            out.push(c);
        }
        out.push('\n');
    }
    out
}

/// Render one traffic class's per-tile link heatmap. `Ok` with an empty
/// string when the run did not model link contention or the class saw no
/// packets (e.g. coherence billing off); an error on a machine mismatch.
pub fn link_class_heatmap(
    stats: &RunStats,
    machine: &Machine,
    class: TrafficClass,
) -> Result<String, MetricsError> {
    if !stats.links_modelled() {
        return Ok(String::new());
    }
    let counts = class.counts(stats);
    if counts.iter().all(|&n| n == 0) {
        return Ok(String::new());
    }
    check_len(class.label(), counts.len(), machine.num_links(), machine)?;
    let mut out = String::new();
    out.push_str(&format!(
        "mesh-link {} per tile (max outgoing link), {}x{} {}:\n",
        class.label(),
        machine.grid_w(),
        machine.grid_h(),
        machine.name()
    ));
    link_grid(counts, machine, &mut out);
    out.push_str(&format!(
        "  {} {} packets total\n",
        counts.iter().sum::<u64>(),
        class.label()
    ));
    Ok(out)
}

/// Compose per-partition link traffic into one parent-grid heatmap. A
/// partition replay bills its *view-local* links; [`Partition::global_link_index`]
/// maps each onto the parent mesh link it models — exactly, because XY
/// routes inside a rectangle stay inside it and disjoint rectangles never
/// share a parent link, so composition is pure addition with no
/// double-counting. `Ok` with an empty string when no slice modelled link
/// contention; an error when a stats vector does not match its
/// partition's shape.
pub fn partitioned_link_heatmap(
    slices: &[(&Partition, &RunStats)],
    parent: &Machine,
) -> Result<String, MetricsError> {
    let mut links = vec![0u64; parent.num_links()];
    let mut modelled = false;
    for (p, stats) in slices {
        if !stats.links_modelled() {
            continue;
        }
        check_len(
            "partition link_requests",
            stats.link_requests.len(),
            4 * p.num_tiles() as usize,
            parent,
        )?;
        modelled = true;
        for (i, &n) in stats.link_requests.iter().enumerate() {
            links[p.global_link_index(parent, i)] += n;
        }
    }
    if !modelled {
        return Ok(String::new());
    }
    let mut out = String::new();
    out.push_str(&format!(
        "mesh-link traffic per tile (max outgoing link), {} partition server(s) on {}x{} {}:\n",
        slices.len(),
        parent.grid_w(),
        parent.grid_h(),
        parent.name()
    ));
    link_grid(&links, parent, &mut out);
    out.push_str(&format!(
        "  {} packets total across partition replays\n",
        links.iter().sum::<u64>()
    ));
    Ok(out)
}

/// Gini-style concentration of home traffic in [0, 1]: 0 = perfectly
/// spread (hash-for-home's goal), →1 = single hot tile (the disaster).
pub fn home_concentration(stats: &RunStats) -> f64 {
    let counts = &stats.tile_home_requests;
    let total: u64 = counts.iter().sum();
    if total == 0 {
        return 0.0;
    }
    let max = counts.iter().copied().max().unwrap_or(0);
    let n = counts.len() as f64;
    // Normalised max-share: (max/total - 1/n) / (1 - 1/n).
    (max as f64 / total as f64 - 1.0 / n) / (1.0 - 1.0 / n)
}

/// Nearest-rank percentile of an **ascending-sorted** slice: the smallest
/// element whose cumulative rank covers fraction `p` of the samples
/// (`p` in `[0, 1]`). Integer in, integer out — no interpolation — so the
/// serve layer's latency records stay byte-exact across worker counts.
/// An empty slice reports 0 (the serve contract: an empty-arrival
/// scenario yields an all-zero report, not a panic).
///
/// Nearest-rank is monotone in `p` by construction, which is what pins
/// the `p50 ≤ p99 ≤ p999 ≤ max` ordering property in `prop_serve`.
pub fn percentile(sorted: &[u64], p: f64) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    let n = sorted.len();
    let rank = ((p * n as f64).ceil() as usize).clamp(1, n);
    sorted[rank - 1]
}

/// The serve layer's standard latency digest over an ascending-sorted
/// sample: `(p50, p99, p999, max)` by nearest rank.
pub fn latency_digest(sorted: &[u64]) -> (u64, u64, u64, u64) {
    (
        percentile(sorted, 0.50),
        percentile(sorted, 0.99),
        percentile(sorted, 0.999),
        sorted.last().copied().unwrap_or(0),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stats_with(counts: Vec<u64>) -> RunStats {
        RunStats {
            tile_home_requests: counts,
            ..RunStats::default()
        }
    }

    #[test]
    fn heatmap_renders_8_rows() {
        let s = stats_with(vec![5; 64]);
        let map = home_heatmap(&s, &Machine::tilepro64()).unwrap();
        assert_eq!(map.lines().count(), 10); // header + 8 rows + footer
    }

    #[test]
    fn heatmap_renders_machine_aspect() {
        // 4 wide × 8 tall: 8 grid rows, 4 double-width columns each.
        let m = Machine::custom(4, 8, 2).unwrap();
        let s = stats_with(vec![3; 32]);
        let map = home_heatmap(&s, &m).unwrap();
        assert_eq!(map.lines().count(), 10);
        let row = map.lines().nth(1).unwrap();
        assert_eq!(row.trim_end().len(), 2 + 8);
        // 16×16 renders 16 rows.
        let s = stats_with(vec![1; 256]);
        assert_eq!(
            home_heatmap(&s, &Machine::nuca256()).unwrap().lines().count(),
            18
        );
    }

    #[test]
    fn heatmap_length_mismatch_is_an_error() {
        // A 64-tile stats vector against the 16-tile epiphany16: a caller
        // bug that must fail loudly in release builds, not just under
        // debug assertions.
        let s = stats_with(vec![0; 64]);
        match home_heatmap(&s, &Machine::epiphany16()) {
            Err(MetricsError::Mismatch {
                what,
                expected,
                got,
                machine,
            }) => {
                assert_eq!(what, "tile_home_requests");
                assert_eq!((expected, got), (16, 64));
                assert_eq!(machine, "epiphany16");
            }
            other => panic!("expected Mismatch, got {other:?}"),
        }
        let err = home_heatmap(&s, &Machine::epiphany16()).unwrap_err();
        assert!(err.to_string().contains("different machine"), "{err}");
    }

    #[test]
    fn link_heatmap_length_mismatch_is_an_error() {
        let m = Machine::tilepro64();
        let s = RunStats {
            tile_home_requests: vec![0; 64],
            link_requests: vec![1; 4], // wrong machine
            ..RunStats::default()
        };
        assert!(link_heatmap(&s, &m).is_err());
        assert!(link_class_heatmap(&s, &m, TrafficClass::Request).is_err());
    }

    #[test]
    fn heatmap_handles_empty() {
        let s = stats_with(vec![0; 64]);
        let map = home_heatmap(&s, &Machine::tilepro64()).unwrap();
        assert!(map.contains("total 0 requests"));
    }

    #[test]
    fn link_heatmap_empty_without_link_model() {
        let s = stats_with(vec![0; 64]);
        assert_eq!(link_heatmap(&s, &Machine::tilepro64()).unwrap(), "");
        for class in TrafficClass::ALL {
            assert_eq!(
                link_class_heatmap(&s, &Machine::tilepro64(), class).unwrap(),
                ""
            );
        }
    }

    #[test]
    fn link_heatmap_names_hottest_link() {
        let m = Machine::tilepro64();
        let mut links = vec![0u64; m.num_links()];
        let hot = m.link_index(TileId(9), Dir::East);
        links[hot] = 42;
        let s = RunStats {
            tile_home_requests: vec![0; 64],
            link_requests: links,
            link_queue_cycles: 17,
            ..RunStats::default()
        };
        let map = link_heatmap(&s, &m).unwrap();
        assert!(map.contains("hottest link E(1,1) with 42 packets"), "{map}");
        assert!(map.contains("17 link-queue cycles"));
    }

    #[test]
    fn class_heatmaps_render_their_own_vectors() {
        let m = Machine::tilepro64();
        let mut inval = vec![0u64; m.num_links()];
        inval[m.link_index(TileId(0), Dir::East)] = 9;
        let s = RunStats {
            tile_home_requests: vec![0; 64],
            link_requests: vec![0; m.num_links()],
            link_reply_requests: vec![0; m.num_links()],
            link_inval_requests: inval,
            ..RunStats::default()
        };
        let map = link_class_heatmap(&s, &m, TrafficClass::Invalidation).unwrap();
        assert!(map.contains("invalidations"), "{map}");
        assert!(map.contains("9 invalidations packets total"), "{map}");
        // The reply class saw nothing: renders empty rather than a blank grid.
        assert_eq!(link_class_heatmap(&s, &m, TrafficClass::Reply).unwrap(), "");
    }

    #[test]
    fn fabric_map_empty_on_uniform_fabric() {
        assert_eq!(fabric_map(&Machine::tilepro64()), "");
        assert_eq!(fabric_map(&Machine::nuca256()), "");
    }

    #[test]
    fn fabric_map_shows_express_rows() {
        let m = Machine::tilepro64()
            .with_fabric(&crate::arch::FabricSpec::parse("base=4:express-row=0@0.5").unwrap())
            .unwrap();
        let map = fabric_map(&m);
        // Row 0 tiles have a 2-cycle east/west link; the rest sit at 4.
        let rows: Vec<&str> = map.lines().collect();
        assert!(rows[1].contains("22"), "{map}");
        assert!(rows[2].contains("44") && !rows[2].contains('2'), "{map}");
        // The class legend lives on link_heatmap, not here (no repeat).
        assert!(!map.contains("link service classes"), "{map}");
    }

    #[test]
    fn fabric_map_empty_when_only_offgrid_slots_differ() {
        // A rule that only ever hits nonexistent boundary links (west
        // links of a 1-wide grid's row) is physically uniform: nothing
        // to render even though the raw table is heterogeneous.
        let m = Machine::custom(1, 4, 1)
            .unwrap()
            .with_fabric(&crate::arch::FabricSpec::parse("express-row=0@0.5").unwrap())
            .unwrap();
        assert!(m.fabric().uniform_service().is_none(), "table is het");
        assert_eq!(fabric_map(&m), "", "physically uniform");
    }

    #[test]
    fn link_heatmap_annotates_physical_service_classes() {
        let m = Machine::tilepro64()
            .with_fabric(&crate::arch::FabricSpec::parse("base=4:express-row=0@0.5").unwrap())
            .unwrap();
        let s = RunStats {
            tile_home_requests: vec![0; 64],
            link_requests: vec![1; m.num_links()],
            ..RunStats::default()
        };
        let map = link_heatmap(&s, &m).unwrap();
        // Physical counts: an 8x8 mesh has 2*7*8*2 = 224 directed links;
        // row 0 contributes 7 east + 7 west express ones.
        assert!(
            map.contains("link service classes: 2 cy x 14 links (express), 4 cy x 210 links"),
            "{map}"
        );
        // Uniform machines keep the pre-fabric rendering.
        let plain = link_heatmap(&s, &Machine::tilepro64()).unwrap();
        assert!(!plain.contains("link service classes"), "{plain}");
    }

    #[test]
    fn concentration_uniform_is_zero() {
        let s = stats_with(vec![10; 64]);
        assert!(home_concentration(&s).abs() < 1e-9);
    }

    #[test]
    fn concentration_single_hot_tile_is_one() {
        let mut counts = vec![0u64; 64];
        counts[0] = 1000;
        let s = stats_with(counts);
        assert!((home_concentration(&s) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn concentration_orders_hot_vs_spread() {
        let mut hot = vec![1u64; 64];
        hot[0] = 1000;
        let spread = vec![16u64; 64];
        assert!(home_concentration(&stats_with(hot)) > home_concentration(&stats_with(spread)));
    }

    #[test]
    fn percentile_nearest_rank() {
        let v: Vec<u64> = (1..=100).collect();
        assert_eq!(percentile(&v, 0.50), 50);
        assert_eq!(percentile(&v, 0.99), 99);
        assert_eq!(percentile(&v, 0.999), 100);
        assert_eq!(percentile(&v, 0.0), 1, "p0 clamps to the minimum");
        assert_eq!(percentile(&v, 1.0), 100);
        assert_eq!(percentile(&[], 0.5), 0, "empty sample reports zero");
        assert_eq!(percentile(&[7], 0.999), 7);
    }

    #[test]
    fn latency_digest_is_ordered() {
        let v: Vec<u64> = (0..1000).map(|i| i * i).collect();
        let (p50, p99, p999, max) = latency_digest(&v);
        assert!(p50 <= p99 && p99 <= p999 && p999 <= max);
        assert_eq!(max, 999 * 999);
        assert_eq!(latency_digest(&[]), (0, 0, 0, 0));
    }
}
