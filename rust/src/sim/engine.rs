//! The replay engine: executes a `Program` against the simulated memory
//! system in cycle order.
//!
//! Threads are replayed min-clock-first from a binary heap, in bounded
//! quanta (line events), so cross-thread interleaving — and therefore the
//! contention counters — track simulated time. Ops are *pulled* from each
//! thread's [`OpSource`](crate::sim::trace::OpSource) on demand, so a run
//! never materialises a whole trace in host memory.
//!
//! Line accounting has two equivalent paths:
//!
//! - the **page-run fast path** (default): sequential `Read`/`Write` runs
//!   are chunked by page, the homing/translation is resolved *once per
//!   page*, and a run of same-home lines is processed by one bulk call
//!   into [`cache::hierarchy`](crate::cache) (`read_run`/`write_run`) with
//!   contention and invalidation fan-out billed per line inside the run.
//!   `Copy` keeps its per-line read/write interleave but caches the page
//!   translation across lines.
//! - the **per-line reference walk** (`EngineConfig::without_page_runs`):
//!   the original one-lookup-per-line path, kept as the cycle-exactness
//!   oracle (tests pin both paths to byte-identical `RunStats`) and as the
//!   baseline the perf bench compares against.
//!
//! Every line access pays the uncontended latency (`Machine::access_cycles`
//! on the run's machine description), plus queueing at the home tile /
//! memory controller / directional mesh links (noc::contention), plus
//! invalidation fan-out on writes. With link contention on, *all* mesh
//! traversals go through the link servers: the forward request route, the
//! reply route (data for loads, an ack for stores — wormhole-pipelined,
//! see `ContentionModel::reply_path_request`), and the invalidation
//! fan-out + ack routes of coherence writes (gated separately by
//! `--no-coherence-links`). Which chip is simulated is a runtime value:
//! `EngineConfig::for_machine` accepts any `arch::Machine`;
//! `EngineConfig::tilepro64` is the paper-baseline preset (link contention
//! off, pinned byte-identical to the published figure record).

use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::sync::Arc;

use crate::arch::{HitLevel, LatencyParams, Machine, TileId, LINE_BYTES, PAGE_BYTES};
use crate::cache::CacheSystem;
use crate::coherence::{CoherenceAction, HomePermutation, LineCtx, Protocol, ProtocolKind, ProtocolSpec};
use crate::mem::{AllocKind, Allocator, LineId, MemConfig, PageAttr, Placement, Region, VAddr};
use crate::noc::{ContentionConfig, ContentionModel};
use crate::sched::Scheduler;
use crate::sim::stats::RunStats;
use crate::sim::trace::{Loc, Op, OpStream, Program};

/// Hypervisor page-allocation overhead (per call + per page): `new int[n]`
/// is not free, which is why localisation must *amortise* the copy+alloc
/// over enough reuse (Fig. 1's small-repetition regime). Zero-byte allocs
/// are rejected statically by `Program::validate`, so every `Alloc` that
/// reaches the engine bills at least one page.
const ALLOC_BASE_CYCLES: u64 = 600;
const ALLOC_PER_PAGE_CYCLES: u64 = 120;
const FREE_BASE_CYCLES: u64 = 300;

/// Max line events a thread processes per scheduling turn. Small enough to
/// interleave threads faithfully, large enough to amortise heap traffic.
pub(crate) const QUANTUM_LINES: u64 = 128;

const LINES_PER_PAGE: u64 = PAGE_BYTES / LINE_BYTES;

#[derive(Clone, Debug)]
#[non_exhaustive]
pub struct EngineConfig {
    /// The simulated chip. Sizes every resource vector (caches, homes,
    /// sharer bitsets, link servers) and supplies the latency parameters.
    pub machine: Arc<Machine>,
    pub mem: MemConfig,
    pub contention: ContentionConfig,
    /// Fig. 4 ablation: with caches off every access goes to DRAM (routed
    /// via its home tile), which is where "the effect of memory striping is
    /// considerable" per the paper's closing discussion.
    pub caches_enabled: bool,
    /// Use the page-run fast path (resolve homing once per page, bulk
    /// same-home runs). Disable to replay through the per-line reference
    /// walk — cycle-identical, just slower.
    pub page_runs: bool,
    /// Which coherence protocol drives line-state transitions
    /// ([`crate::coherence`]). The default (`write-invalidate`) is the
    /// fused directory path this engine has always billed — pinned
    /// byte-identical — so protocol selection only changes cycles when a
    /// non-default protocol is picked *and* coherence traffic is modelled
    /// on the links.
    pub protocol: ProtocolSpec,
    /// Host worker threads replaying *this one run* (`--intra-jobs`).
    /// 1 (the default) is the sequential engine. >1 shards the simulated
    /// tiles across host cores in deterministic time-sliced epochs; the
    /// resulting `RunStats` are byte-identical at every worker count. The
    /// parallel path is an execution strategy, not a model parameter, so
    /// it is deliberately *not* part of `RunSpec` identity — and it only
    /// engages when [`plan_intra_workers`] says the run qualifies
    /// (static scheduler, caches on — every coherence protocol and the
    /// opaque home permutation compose with the epoch driver); otherwise
    /// the run stays sequential and `RunStats::intra_demoted` names why.
    pub intra_jobs: usize,
}

impl EngineConfig {
    /// The paper-baseline TILEPro64 configuration. Link contention is OFF
    /// here — the published fig1–fig4/table1 record predates the link
    /// model and is pinned byte-identical in CI; enable it with
    /// [`with_link_contention`](Self::with_link_contention) or run on an
    /// explicit machine via [`for_machine`](Self::for_machine).
    pub fn tilepro64(mem: MemConfig) -> Self {
        let mut cfg = EngineConfig::for_machine(Arc::new(Machine::tilepro64()), mem);
        cfg.contention.links = false;
        cfg
    }

    /// Simulate `mem` on an arbitrary machine, with the full contention
    /// model (home ports, controllers, and mesh links) enabled.
    pub fn for_machine(machine: Arc<Machine>, mem: MemConfig) -> Self {
        EngineConfig {
            machine,
            mem,
            contention: ContentionConfig::default(),
            caches_enabled: true,
            page_runs: true,
            protocol: ProtocolSpec::default(),
            intra_jobs: 1,
        }
    }

    /// Replay this run with up to `n` host workers (`--intra-jobs`).
    /// Statistics stay byte-identical at any value; 0 is clamped to 1.
    pub fn with_intra_jobs(mut self, n: usize) -> Self {
        self.intra_jobs = n.max(1);
        self
    }

    /// Select the coherence protocol (`--protocol`). See
    /// [`crate::coherence`] for the menu and semantics.
    pub fn with_protocol(mut self, protocol: ProtocolSpec) -> Self {
        self.protocol = protocol;
        self
    }

    pub fn without_caches(mut self) -> Self {
        self.caches_enabled = false;
        self
    }

    /// Replay through the per-line reference walk (exactness oracle and
    /// perf baseline).
    pub fn without_page_runs(mut self) -> Self {
        self.page_runs = false;
        self
    }

    /// Ablation: drop per-link mesh queueing (`--no-link-contention`).
    pub fn without_link_contention(mut self) -> Self {
        self.contention.links = false;
        self
    }

    /// Model per-link mesh queueing (on by default for `for_machine`).
    pub fn with_link_contention(mut self) -> Self {
        self.contention.links = true;
        self
    }

    /// Ablation: keep forward link queueing but stop billing coherence
    /// traffic — invalidation fan-out and reply paths — on the links
    /// (`--no-coherence-links`).
    pub fn without_coherence_links(mut self) -> Self {
        self.contention.coherence = false;
        self
    }

    /// Bill coherence traffic through the link servers (the default
    /// whenever link contention is on).
    pub fn with_coherence_links(mut self) -> Self {
        self.contention.coherence = true;
        self
    }
}

#[derive(Debug)]
pub enum EngineError {
    Invalid(crate::sim::trace::ProgramError),
    UnboundSlot { thread: usize, slot: u32 },
    Alloc {
        thread: usize,
        source: crate::mem::AllocError,
    },
    Unmapped(VAddr),
    Deadlock(Vec<usize>),
}

impl std::fmt::Display for EngineError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            EngineError::Invalid(e) => write!(f, "program validation failed: {e}"),
            EngineError::UnboundSlot { thread, slot } => {
                write!(f, "thread {thread}: use of unbound slot {slot}")
            }
            EngineError::Alloc { thread, source } => {
                write!(f, "thread {thread}: allocation failed: {source}")
            }
            EngineError::Unmapped(a) => write!(f, "access to unmapped address {a:?}"),
            EngineError::Deadlock(tids) => write!(f, "deadlock: threads {tids:?} blocked forever"),
        }
    }
}

impl std::error::Error for EngineError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            EngineError::Invalid(e) => Some(e),
            EngineError::Alloc { source, .. } => Some(source),
            _ => None,
        }
    }
}

impl From<crate::sim::trace::ProgramError> for EngineError {
    fn from(e: crate::sim::trace::ProgramError) -> EngineError {
        EngineError::Invalid(e)
    }
}

pub(crate) struct ThreadState {
    pub(crate) tile: TileId,
    pub(crate) clock: u64,
    /// The op currently executing (pulled from the thread's stream).
    pub(crate) cur: Option<Op>,
    /// Lines already processed within the current (partially done) op.
    pub(crate) progress: u64,
    pub(crate) done: bool,
}

/// Continuation record for a quantum that an epoch worker had to *park*
/// mid-way (see [`crate::sim::epoch`]): the worker hit a line whose cost is
/// not locally decidable (cache miss, foreign sharer) and deferred the rest
/// of the quantum — including a possibly half-executed line batch — to the
/// sequential drain phase. The drain resumes at the exact heap pop the
/// worker consumed (`key`), bypassing the staleness check (the thread's
/// clock has already advanced past `key` by the lines it did execute).
#[derive(Clone, Copy, Debug)]
pub(crate) struct ParkInfo {
    /// Heap key of the pop the worker consumed for this quantum.
    pub(crate) key: u64,
    /// Quantum budget remaining *before* the parked op (re-)executes.
    pub(crate) budget: u64,
    /// Lines (`Read`/`Write`) or line pairs (`Copy`) of the current batch
    /// the worker already executed and billed.
    pub(crate) batch_done: u64,
    /// Total size of that batch; 0 means no partial batch — the drain just
    /// reruns the quantum loop and re-derives the batch deterministically.
    pub(crate) batch_total: u64,
}

/// Everything `run` threads through the replay loop, bundled so the
/// sequential drain ([`Engine::run_until`]) and the epoch driver
/// ([`crate::sim::epoch`]) operate on the same state. The op streams borrow
/// the program's sources for the duration of the run.
pub(crate) struct RunCtx<'p> {
    pub(crate) threads: Vec<ThreadState>,
    pub(crate) streams: Vec<OpStream<'p>>,
    pub(crate) slots: Vec<Option<Region>>,
    pub(crate) signal_time: Vec<Option<u64>>,
    pub(crate) waiters: Vec<Vec<usize>>,
    /// Min-clock scheduling heap. Lazily pruned: entries whose key no
    /// longer matches the thread's clock are skipped on pop.
    pub(crate) heap: BinaryHeap<Reverse<(u64, usize)>>,
    /// Pending mid-quantum continuations, keyed by thread id.
    pub(crate) resume: Vec<Option<ParkInfo>>,
}

/// Cached page translation for interleaved streams (`Copy`): one
/// `resolve_page` per page crossing instead of one per line.
#[derive(Clone, Copy)]
struct AttrCursor {
    page: u64,
    attr: Option<PageAttr>,
}

impl AttrCursor {
    fn new() -> Self {
        AttrCursor {
            page: u64::MAX,
            attr: None,
        }
    }

    #[inline]
    fn resolve(
        &mut self,
        table: &mut crate::mem::PageTable,
        line: LineId,
        tile: TileId,
    ) -> Result<PageAttr, EngineError> {
        let page = line.page();
        if page.0 != self.page || self.attr.is_none() {
            let attr = table
                .resolve_page(page, tile)
                .map_err(|_| EngineError::Unmapped(line.addr()))?;
            self.page = page.0;
            self.attr = Some(attr);
        }
        Ok(self.attr.expect("cursor filled above"))
    }
}

/// Batched per-run store counters, folded into `RunStats` once per run
/// (see [`Engine::fold_store_agg`]).
#[derive(Default)]
struct StoreAgg {
    l2: u64,
    home_hits: u64,
    invals: u64,
}

/// Bill one store: latency, home-port/link queueing, the ack return path,
/// and — when other tiles shared the line — the invalidation fan-out, both
/// its header latency (critical path to the farthest victim) and its
/// per-victim route + ack occupancy on the link servers.
///
/// This is a free function over split borrows so the reference walk
/// ([`Engine::store`]) and the page-run fast path ([`Engine::write_run`])
/// share it verbatim — billing the servers in a different order would
/// break their cycle-exactness pin.
#[allow(clippy::too_many_arguments)]
fn bill_store_line(
    params: &LatencyParams,
    contention: &mut ContentionModel,
    tile: TileId,
    home: TileId,
    out: crate::cache::WriteOutcome,
    victims: &[TileId],
    now: u64,
    agg: &mut StoreAgg,
) -> u64 {
    let mut c = if home == tile {
        agg.l2 += 1;
        params.l2_hit
    } else {
        // Posted store: issuing cost is small, but the home port bandwidth
        // is consumed — that queueing is the hot-spot mechanism of the
        // non-localised disaster case — and so are the mesh links on the
        // way to the home plus the header-sized ack coming back.
        agg.home_hits += 1;
        params.store_post
            + contention.home_request(home, now, params.home_service)
            + contention.link_path_request(tile, home, now)
            + contention.reply_path_request(home, tile, now, 1)
    };
    if out.invalidated > 0 {
        agg.invals += out.invalidated as u64;
        c += params.noc_header + params.noc_hop * out.invalidation_hops as u64;
        c += contention.invalidation_fanout_request(home, victims, now);
    }
    c
}

/// The engine also exposes the pre-run allocator so workloads can set up
/// shared input arrays (the `main()`-scope `new int[ARRAY_SZ]` of Alg. 3,
/// allocated from tile 0 before threads start).
pub struct Engine {
    pub alloc: Allocator,
    pub(crate) caches: CacheSystem,
    contention: ContentionModel,
    pub(crate) machine: Arc<Machine>,
    /// Copy of `machine.params` — the scalar latency terms are read on
    /// every line event; distance-dependent arithmetic goes through
    /// `machine.access_cycles`.
    pub(crate) params: LatencyParams,
    pub(crate) caches_enabled: bool,
    pub(crate) page_runs: bool,
    /// Requested intra-run host workers (`EngineConfig::intra_jobs`,
    /// clamped to ≥ 1); the effective count comes from
    /// [`plan_intra_workers`] once the scheduler is known.
    intra_jobs: usize,
    /// The pluggable coherence state machine ([`crate::coherence`]).
    protocol: Box<dyn Protocol>,
    /// True when the trait's transitions drive billing: a non-default
    /// protocol was selected *and* coherence traffic is modelled on the
    /// links. Otherwise the fused write-invalidate path runs unchanged
    /// (the pinned-baseline guarantee). The epoch driver reads it to pick
    /// the read-walk mirror (protocols read via `CacheSystem::read`, not
    /// the bulk probe/touch walk).
    pub(crate) protocol_active: bool,
    /// `opaque` mode: a seeded permutation applied to every resolved home
    /// tile (per arXiv:2011.05422's randomised home mapping). The epoch
    /// planner reads it too: the eligibility scan must judge the
    /// *permuted* home, or opaque runs would fence the wrong tiles.
    pub(crate) home_perm: Option<HomePermutation>,
    pub(crate) stats: RunStats,
}

impl Engine {
    pub fn new(cfg: EngineConfig) -> Self {
        let machine = cfg.machine;
        let contention = ContentionModel::new(cfg.contention, machine.clone());
        let protocol_active = !matches!(
            cfg.protocol.kind,
            ProtocolKind::WriteInvalidate | ProtocolKind::Opaque
        ) && contention.coherence_enabled();
        let home_perm = if cfg.protocol.permutes_homes() {
            Some(HomePermutation::new(
                cfg.protocol.opaque_seed,
                machine.num_tiles(),
            ))
        } else {
            None
        };
        Engine {
            alloc: Allocator::new(machine.clone(), cfg.mem),
            caches: CacheSystem::new(machine.clone()),
            contention,
            params: machine.params.clone(),
            caches_enabled: cfg.caches_enabled,
            page_runs: cfg.page_runs,
            intra_jobs: cfg.intra_jobs.max(1),
            protocol: cfg.protocol.build(),
            protocol_active,
            home_perm,
            stats: RunStats {
                clock_hz: machine.params.clock_hz,
                tile_home_requests: vec![0; machine.num_tiles() as usize],
                ..RunStats::default()
            },
            machine,
        }
    }

    /// Allocate a shared input array before the run (from `tile`, heap).
    /// First-touch homing remains unresolved — workers fault pages in.
    pub fn prealloc(&mut self, tile: TileId, bytes: u64) -> Region {
        self.alloc
            .alloc(tile, bytes, AllocKind::Heap)
            .expect("prealloc failed")
    }

    /// Allocate *and initialise* an array from `tile` (models `main()`
    /// writing the input before the parallel section): under
    /// `ucache_hash=none` every page first-touch homes on `tile` — the
    /// "whole array stuck on one tile" starting point of the paper.
    pub fn prealloc_touched(&mut self, tile: TileId, bytes: u64) -> Region {
        let r = self.prealloc(tile, bytes);
        self.alloc.table.touch_region(r.addr, r.bytes, tile);
        r
    }

    pub fn params(&self) -> &LatencyParams {
        &self.params
    }

    pub fn machine(&self) -> &Arc<Machine> {
        &self.machine
    }

    /// Apply the `opaque` home permutation (identity for every other
    /// protocol). Every home-resolution point funnels through here, so the
    /// page-run fast path and the reference walk permute identically.
    #[inline]
    fn map_home(&self, home: TileId) -> TileId {
        match &self.home_perm {
            Some(p) => p.map(home),
            None => home,
        }
    }

    /// Snapshot the directory/owner state of `line` as the protocol
    /// trait's transition input.
    fn line_ctx(&self, tile: TileId, line: LineId, home: TileId) -> LineCtx {
        let was_sharer = self.caches.directory.is_sharer(line, tile);
        LineCtx {
            requestor: tile,
            home,
            others: self.caches.directory.sharer_count(line) - u32::from(was_sharer),
            was_sharer,
            owner: self.caches.owner_of(line),
            links_on: self.contention.coherence_enabled(),
        }
    }

    // ------------------------------------------------------------------
    // Per-line reference walk (the pre-page-run implementation, kept as
    // the cycle-exactness oracle and perf baseline).
    // ------------------------------------------------------------------

    /// Simulate one line access from `tile` at `now`; returns cycles.
    /// First-touch pages fault in here (homed on `tile`).
    fn line_access(
        &mut self,
        tile: TileId,
        line: LineId,
        write: bool,
        now: u64,
    ) -> Result<u64, EngineError> {
        let home = self
            .alloc
            .table
            .resolve_home(line, tile)
            .map_err(|_| EngineError::Unmapped(line.addr()))?;
        let home = self.map_home(home);
        self.stats.line_accesses += 1;
        if !self.caches_enabled {
            return self.uncached_access(tile, line, home, write, now);
        }
        if write {
            return Ok(self.store(tile, line, home, now));
        }
        self.load(tile, line, home, now)
    }

    /// Caches-off mode (Fig. 4 ablation): every access is a DRAM
    /// transaction routed via the line's home tile.
    fn uncached_access(
        &mut self,
        tile: TileId,
        line: LineId,
        home: TileId,
        write: bool,
        now: u64,
    ) -> Result<u64, EngineError> {
        let ctrl = self
            .alloc
            .table
            .controller_of_line(line)
            .map_err(|_| EngineError::Unmapped(line.addr()))?;
        Ok(self.uncached_line(tile, line, home, ctrl, write, now))
    }

    /// One DRAM transaction with the controller already known.
    fn uncached_line(
        &mut self,
        tile: TileId,
        _line: LineId,
        home: TileId,
        ctrl: u32,
        write: bool,
        now: u64,
    ) -> u64 {
        self.stats.ddr_accesses += 1;
        let ctrl_attach = self.machine.controller(ctrl).attach;
        let base = if write {
            // Posted store still pays controller occupancy, not latency.
            self.params.store_post
        } else {
            self.machine
                .access_cycles(tile, HitLevel::Ddr { ctrl_attach })
        };
        let mut cycles = base;
        if home != tile {
            self.stats.tile_home_requests[home.index()] += 1;
            cycles += self
                .contention
                .home_request(home, now, self.params.home_service);
        }
        cycles += self
            .contention
            .ctrl_request(ctrl, now, self.params.ctrl_service);
        // The DRAM transaction occupies every mesh link towards the
        // controller (latency for the hops is already in `base`), and the
        // response occupies the return route: a line of data for a read,
        // a bare ack for a posted write.
        cycles += self.contention.link_path_request(tile, ctrl_attach, now);
        let flits = if write { 1 } else { self.params.line_flits };
        cycles += self
            .contention
            .reply_path_request(ctrl_attach, tile, now, flits);
        cycles
    }

    fn load(
        &mut self,
        tile: TileId,
        line: LineId,
        home: TileId,
        now: u64,
    ) -> Result<u64, EngineError> {
        let place = self.caches.read(tile, line, home);
        let ctrl = if place == crate::cache::ReadPlace::Ddr {
            // Only the DRAM path needs the controller (lazy lookup — this
            // is the reference walk's hottest function).
            self.alloc
                .table
                .controller_of_line(line)
                .map_err(|_| EngineError::Unmapped(line.addr()))?
        } else {
            0
        };
        if self.protocol_active {
            return Ok(self.load_protocol(tile, line, home, place, ctrl, now));
        }
        Ok(self.bill_load(tile, line, home, place, ctrl, now))
    }

    /// Latency + contention for a load that was satisfied at `place`.
    #[inline]
    fn bill_load(
        &mut self,
        tile: TileId,
        _line: LineId,
        home: TileId,
        place: crate::cache::ReadPlace,
        ctrl: u32,
        now: u64,
    ) -> u64 {
        match place {
            crate::cache::ReadPlace::L1 => {
                self.stats.l1_hits += 1;
                self.params.l1_hit
            }
            crate::cache::ReadPlace::L2 => {
                self.stats.l2_hits += 1;
                self.params.l2_hit
            }
            crate::cache::ReadPlace::Home { home } => {
                self.stats.home_hits += 1;
                self.stats.tile_home_requests[home.index()] += 1;
                self.machine.access_cycles(tile, HitLevel::Home { home })
                    + self
                        .contention
                        .home_request(home, now, self.params.home_service)
                    + self.contention.link_path_request(tile, home, now)
                    + self.contention.reply_path_request(
                        home,
                        tile,
                        now,
                        self.params.line_flits,
                    )
            }
            crate::cache::ReadPlace::Ddr => {
                self.stats.ddr_accesses += 1;
                let ctrl_attach = self.machine.controller(ctrl).attach;
                let mut c = self
                    .machine
                    .access_cycles(tile, HitLevel::Ddr { ctrl_attach });
                // A miss on a remotely-homed line is routed *via* the home
                // tile (DDC), occupying its port on the way to DRAM.
                if home != tile {
                    self.stats.tile_home_requests[home.index()] += 1;
                    c += self
                        .contention
                        .home_request(home, now, self.params.home_service);
                }
                c + self
                    .contention
                    .ctrl_request(ctrl, now, self.params.ctrl_service)
                    + self.contention.link_path_request(tile, ctrl_attach, now)
                    + self.contention.reply_path_request(
                        ctrl_attach,
                        tile,
                        now,
                        self.params.line_flits,
                    )
            }
        }
    }

    /// Per-line store (the reference walk's path): a one-line
    /// [`write_run`](Self::write_run), so the billing — including the new
    /// invalidation-route and ack-reply accounting — is shared with the
    /// fast path by construction.
    fn store(&mut self, tile: TileId, line: LineId, home: TileId, now: u64) -> u64 {
        if self.protocol_active {
            return self.store_protocol(tile, line, home, now);
        }
        let params = &self.params;
        let contention = &mut self.contention;
        let mut agg = StoreAgg::default();
        let mut cycles = 0u64;
        self.caches.write_run(tile, line, 1, home, |_line, out, victims| {
            cycles = bill_store_line(params, contention, tile, home, out, victims, now, &mut agg);
        });
        self.fold_store_agg(home, &agg);
        cycles
    }

    // ------------------------------------------------------------------
    // Protocol-lab paths: line-state transitions come from the pluggable
    // `coherence::Protocol`; the engine maps each `CoherenceAction` onto
    // the existing latency terms and contention traffic classes. Only
    // taken when `protocol_active` — the default protocol keeps the fused
    // paths above, byte-identical to the pinned baselines.
    // ------------------------------------------------------------------

    /// Protocol-aware load. Local L1/L2 hits bypass the transition (a
    /// foreign dirty owner implies no other tile holds a copy — see the
    /// invariants on [`crate::coherence::Protocol`]); home/DDR placements
    /// run `on_read` first so a dirty owner flushes (MESI) or forwards
    /// the line directly (MOESI) before the data reply is billed.
    fn load_protocol(
        &mut self,
        tile: TileId,
        line: LineId,
        home: TileId,
        place: crate::cache::ReadPlace,
        ctrl: u32,
        now: u64,
    ) -> u64 {
        if matches!(
            place,
            crate::cache::ReadPlace::L1 | crate::cache::ReadPlace::L2
        ) {
            return self.bill_load(tile, line, home, place, ctrl, now);
        }
        let ctx = self.line_ctx(tile, line, home);
        let actions = self.protocol.on_read(&ctx);
        self.apply_read_actions(tile, line, home, place, ctrl, &actions, now)
    }

    /// Bill and apply a read transition's actions to one line. Shared by
    /// the per-line walk ([`load_protocol`](Self::load_protocol)) and the
    /// page-run bulk path ([`protocol_read_run`](Self::protocol_read_run)),
    /// which evaluates the transition once per uniform run and hands the
    /// same action aggregate in per line — billing order is identical by
    /// construction.
    #[allow(clippy::too_many_arguments)]
    fn apply_read_actions(
        &mut self,
        tile: TileId,
        line: LineId,
        home: TileId,
        place: crate::cache::ReadPlace,
        ctrl: u32,
        actions: &[CoherenceAction],
        now: u64,
    ) -> u64 {
        let line_flits = self.params.line_flits;
        let mut cycles = 0u64;
        let mut forwarded: Option<TileId> = None;
        for &action in actions {
            match action {
                CoherenceAction::WritebackToHome { owner } => {
                    // The dirty owner flushes a line of data to the home
                    // before the home can serve.
                    cycles += self.contention.reply_path_request(
                        owner,
                        home,
                        now + cycles,
                        line_flits,
                    );
                    self.caches.clear_owner(line);
                }
                CoherenceAction::OwnerReply { owner } => {
                    // MOESI: the owner sources the data itself and keeps
                    // the (now Owned) line — no flush to the home.
                    self.stats.owner_replies += 1;
                    forwarded = Some(owner);
                }
                _ => {}
            }
        }
        if let Some(owner) = forwarded {
            // The request still travels to the home directory, but the
            // data reply is owner→requestor, not home→requestor.
            self.stats.home_hits += 1;
            self.stats.tile_home_requests[home.index()] += 1;
            return cycles
                + self.machine.access_cycles(tile, HitLevel::Home { home })
                + self
                    .contention
                    .home_request(home, now + cycles, self.params.home_service)
                + self.contention.link_path_request(tile, home, now + cycles)
                + self
                    .contention
                    .reply_path_request(owner, tile, now + cycles, line_flits);
        }
        cycles + self.bill_load(tile, line, home, place, ctrl, now + cycles)
    }

    /// Protocol-aware store. The transition list from `on_write` decides
    /// the billing; directory/cache mutation reuses the hierarchy's
    /// claim/invalidate walk (or [`CacheSystem::write_update`] for the
    /// non-invalidating protocol) so the scratch-mask contract of
    /// multiword directories is untouched.
    fn store_protocol(&mut self, tile: TileId, line: LineId, home: TileId, now: u64) -> u64 {
        let ctx = self.line_ctx(tile, line, home);
        let actions = self.protocol.on_write(&ctx);
        self.apply_write_actions(tile, line, home, &actions, now)
    }

    /// Bill and apply a write transition's actions to one line. Shared by
    /// [`store_protocol`](Self::store_protocol) and the page-run bulk path
    /// ([`protocol_write_run`](Self::protocol_write_run)); state mutation
    /// is strictly per-line (claim/invalidate walk, owner hand-off,
    /// write-update fan-out recompute their victims from the live
    /// directory), so a run-hoisted action aggregate stays cycle-exact.
    fn apply_write_actions(
        &mut self,
        tile: TileId,
        line: LineId,
        home: TileId,
        actions: &[CoherenceAction],
        now: u64,
    ) -> u64 {
        let line_flits = self.params.line_flits;
        let mut cycles = 0u64;
        // Dirty-owner handoff first: the previous owner's line flushes to
        // the home (MESI) or forwards to the writer (MOESI) before the
        // write claims the line.
        for &action in actions {
            match action {
                CoherenceAction::WritebackToHome { owner } => {
                    cycles += self.contention.reply_path_request(
                        owner,
                        home,
                        now + cycles,
                        line_flits,
                    );
                    self.caches.clear_owner(line);
                }
                CoherenceAction::OwnerReply { owner } => {
                    self.stats.owner_replies += 1;
                    cycles += self.contention.reply_path_request(
                        owner,
                        tile,
                        now + cycles,
                        line_flits,
                    );
                    self.caches.clear_owner(line);
                }
                _ => {}
            }
        }
        if actions.contains(&CoherenceAction::SilentUpgrade) {
            // E/M→M: the sole-sharer writer absorbs the store in its own
            // cache — no traffic at all — and becomes the dirty owner the
            // home will have to chase on the next foreign access.
            self.stats.upgrade_hits += 1;
            self.stats.l2_hits += 1;
            self.caches.set_owner(line, tile);
            self.caches.cache_locally(tile, line);
            return cycles + self.params.l2_hit;
        }
        if actions.contains(&CoherenceAction::UpgradeRoundTrip) {
            // MSI: S→M pays an explicit header-sized upgrade round trip
            // to the home directory, billed on the invalidation class —
            // the cost MESI's silent upgrade avoids.
            self.stats.upgrade_hits += 1;
            let hops = u64::from(self.machine.hops(tile, home));
            cycles += self.params.noc_header + 2 * self.params.noc_hop * hops;
            cycles += self
                .contention
                .invalidation_roundtrip_request(home, tile, now + cycles);
        }
        if self.protocol.kind() == ProtocolKind::WriteUpdate {
            // Write-update: sharers keep their copies valid and receive
            // the new data in place of an invalidation.
            let victims = self.caches.write_update(tile, line, home);
            cycles += if home == tile {
                self.stats.l2_hits += 1;
                self.params.l2_hit
            } else {
                self.stats.home_hits += 1;
                self.stats.tile_home_requests[home.index()] += 1;
                self.params.store_post
                    + self
                        .contention
                        .home_request(home, now + cycles, self.params.home_service)
                    + self.contention.link_path_request(tile, home, now + cycles)
                    + self
                        .contention
                        .reply_path_request(home, tile, now + cycles, 1)
            };
            if !victims.is_empty() {
                let max_hops = victims
                    .iter()
                    .map(|&v| self.machine.hops(home, v))
                    .max()
                    .unwrap_or(0);
                cycles += self.params.noc_header + self.params.noc_hop * u64::from(max_hops);
                cycles += self.contention.update_fanout_request(
                    home,
                    &victims,
                    now + cycles,
                    line_flits,
                );
            }
            return cycles;
        }
        // Invalidating protocols: mutate through the regular
        // claim/invalidate walk, billed via the shared store map.
        let params = &self.params;
        let contention = &mut self.contention;
        let mut agg = StoreAgg::default();
        let mut base = 0u64;
        self.caches.write_run(tile, line, 1, home, |_line, out, victims| {
            base = bill_store_line(
                params,
                contention,
                tile,
                home,
                out,
                victims,
                now + cycles,
                &mut agg,
            );
        });
        self.fold_store_agg(home, &agg);
        cycles + base
    }

    // ------------------------------------------------------------------
    // Page-run fast path.
    // ------------------------------------------------------------------

    /// One line with a pre-resolved page attr (hash-for-home pages, the
    /// `Copy` interleave, and the caches-off mode).
    #[inline]
    fn fast_line(
        &mut self,
        tile: TileId,
        line: LineId,
        attr: PageAttr,
        write: bool,
        now: u64,
    ) -> u64 {
        let home = attr
            .homing
            .home_of(line, self.machine.num_tiles())
            .expect("page attr resolved");
        let home = self.map_home(home);
        if !self.caches_enabled {
            let ctrl = attr
                .placement
                .controller_of(line.addr(), self.machine.num_controllers());
            return self.uncached_line(tile, line, home, ctrl, write, now);
        }
        if write {
            return self.store(tile, line, home, now);
        }
        let place = self.caches.read(tile, line, home);
        let ctrl = if place == crate::cache::ReadPlace::Ddr {
            attr.placement
                .controller_of(line.addr(), self.machine.num_controllers())
        } else {
            0
        };
        if self.protocol_active {
            return self.load_protocol(tile, line, home, place, ctrl, now);
        }
        self.bill_load(tile, line, home, place, ctrl, now)
    }

    /// Sequential access of `count` lines from `first`: chunk by page,
    /// resolve the translation once per page, bulk-process same-home runs.
    fn access_run(
        &mut self,
        tile: TileId,
        first: LineId,
        count: u64,
        write: bool,
        clock0: u64,
    ) -> Result<u64, EngineError> {
        self.stats.line_accesses += count;
        let mut cycles = 0u64;
        let mut l = first.0;
        let end = first.0 + count;
        while l < end {
            let page_end = (l / LINES_PER_PAGE + 1) * LINES_PER_PAGE;
            let run = end.min(page_end) - l;
            let line = LineId(l);
            let attr = self
                .alloc
                .table
                .resolve_page(line.page(), tile)
                .map_err(|_| EngineError::Unmapped(line.addr()))?;
            cycles += self.page_run(tile, line, run, write, attr, clock0 + cycles);
            l += run;
        }
        Ok(cycles)
    }

    /// A run of lines within one page (translation already resolved).
    fn page_run(
        &mut self,
        tile: TileId,
        first: LineId,
        count: u64,
        write: bool,
        attr: PageAttr,
        clock0: u64,
    ) -> u64 {
        // Same-home runs take the bulk path with caches on. Directory
        // protocols batch too: the run is scanned for a uniform directory
        // view, the state transition is evaluated once via the protocol's
        // bulk hooks, and the action aggregate is applied per line — any
        // divergence inside the run falls back to the per-line transition,
        // so streamed, recorded, and reference replays agree by
        // construction.
        if self.caches_enabled {
            if let Some(home) = attr.homing.uniform_page_home(first, self.machine.num_tiles()) {
                let home = self.map_home(home);
                if self.protocol_active {
                    return if write {
                        self.protocol_write_run(tile, first, count, home, clock0)
                    } else {
                        self.protocol_read_run(tile, first, count, home, attr.placement, clock0)
                    };
                }
                return if write {
                    self.write_run(tile, first, count, home, clock0)
                } else {
                    self.read_run(tile, first, count, home, attr.placement, clock0)
                };
            }
        }
        if !self.caches_enabled {
            if let Some(home) = attr.homing.uniform_page_home(first, self.machine.num_tiles()) {
                let home = self.map_home(home);
                return self.uncached_run(tile, first, count, write, attr.placement, home, clock0);
            }
        }
        // Hash-for-home pages (per-line homes) and the caches-off mode:
        // per-line walk, but still one translation per page.
        let mut cycles = 0u64;
        for i in 0..count {
            cycles += self.fast_line(tile, LineId(first.0 + i), attr, write, clock0 + cycles);
        }
        cycles
    }

    /// Bulk load of a same-home run: one call into the cache hierarchy,
    /// latency constants hoisted, stats batched; contention still billed
    /// per line at its in-run timestamp (cycle-exact with the reference
    /// walk).
    fn read_run(
        &mut self,
        tile: TileId,
        first: LineId,
        count: u64,
        home: TileId,
        placement: Placement,
        clock0: u64,
    ) -> u64 {
        let params = &self.params;
        let contention = &mut self.contention;
        let machine = &self.machine;
        let num_ctrls = machine.num_controllers();
        let l1_cost = params.l1_hit;
        let l2_cost = params.l2_hit;
        let line_flits = params.line_flits;
        let home_cost = machine.access_cycles(tile, HitLevel::Home { home });
        let remote = home != tile;
        let (mut l1, mut l2, mut home_hits, mut ddr, mut home_reqs) = (0u64, 0u64, 0u64, 0u64, 0u64);
        let mut cycles = 0u64;
        self.caches
            .read_run(tile, first, count, home, |line, place| {
                let now = clock0 + cycles;
                cycles += match place {
                    crate::cache::ReadPlace::L1 => {
                        l1 += 1;
                        l1_cost
                    }
                    crate::cache::ReadPlace::L2 => {
                        l2 += 1;
                        l2_cost
                    }
                    crate::cache::ReadPlace::Home { .. } => {
                        home_hits += 1;
                        home_reqs += 1;
                        home_cost
                            + contention.home_request(home, now, params.home_service)
                            + contention.link_path_request(tile, home, now)
                            + contention.reply_path_request(home, tile, now, line_flits)
                    }
                    crate::cache::ReadPlace::Ddr => {
                        ddr += 1;
                        let ctrl = placement.controller_of(line.addr(), num_ctrls);
                        let ctrl_attach = machine.controller(ctrl).attach;
                        let mut c = machine.access_cycles(tile, HitLevel::Ddr { ctrl_attach });
                        if remote {
                            home_reqs += 1;
                            c += contention.home_request(home, now, params.home_service);
                        }
                        c + contention.ctrl_request(ctrl, now, params.ctrl_service)
                            + contention.link_path_request(tile, ctrl_attach, now)
                            + contention.reply_path_request(ctrl_attach, tile, now, line_flits)
                    }
                };
            });
        self.stats.l1_hits += l1;
        self.stats.l2_hits += l2;
        self.stats.home_hits += home_hits;
        self.stats.ddr_accesses += ddr;
        self.stats.tile_home_requests[home.index()] += home_reqs;
        cycles
    }

    /// Bulk store of a same-home run: one call into the cache hierarchy;
    /// invalidation fan-out accounted per line inside the run, through the
    /// same [`bill_store_line`] the reference walk uses (cycle-exact).
    fn write_run(
        &mut self,
        tile: TileId,
        first: LineId,
        count: u64,
        home: TileId,
        clock0: u64,
    ) -> u64 {
        let params = &self.params;
        let contention = &mut self.contention;
        let mut agg = StoreAgg::default();
        let mut cycles = 0u64;
        self.caches
            .write_run(tile, first, count, home, |_line, out, victims| {
                let now = clock0 + cycles;
                cycles +=
                    bill_store_line(params, contention, tile, home, out, victims, now, &mut agg);
            });
        self.fold_store_agg(home, &agg);
        cycles
    }

    /// Whether every line of `[first, first+count)` shares the directory
    /// view `ctx0` (pre-access state: sharer membership for the
    /// requestor, foreign-sharer count, dirty owner). The protocol bulk
    /// hooks are only sound over a uniform run — the single evaluated
    /// transition embeds the owner tile and branches on the sharer
    /// shape. Dense indexed probes over the directory's sharer bitsets
    /// and SoA owner column; no allocation.
    fn run_ctx_uniform(&self, tile: TileId, first: LineId, count: u64, ctx0: &LineCtx) -> bool {
        let dir = &self.caches.directory;
        for i in 1..count {
            let line = LineId(first.0 + i);
            let was_sharer = dir.is_sharer(line, tile);
            if was_sharer != ctx0.was_sharer
                || dir.sharer_count(line) - u32::from(was_sharer) != ctx0.others
                || dir.owner_of(line) != ctx0.owner
            {
                return false;
            }
        }
        true
    }

    /// Bulk store of a same-home run under an active protocol: scan the
    /// run for a uniform directory view; when it holds (the common
    /// private-stream case) the transition is evaluated **once** via
    /// [`Protocol::on_write_run`] and its allocation-free action
    /// aggregate applied per line; any divergence — mixed sharers, an
    /// owner transition mid-run — falls back to the per-line transition.
    /// Either way each line still claims/invalidates through the live
    /// directory and bills contention at its in-run timestamp, so the
    /// result is cycle-exact with the per-line reference walk.
    fn protocol_write_run(
        &mut self,
        tile: TileId,
        first: LineId,
        count: u64,
        home: TileId,
        clock0: u64,
    ) -> u64 {
        let ctx0 = self.line_ctx(tile, first, home);
        if self.run_ctx_uniform(tile, first, count, &ctx0) {
            if let Some(acts) = self.protocol.on_write_run(&ctx0) {
                let mut cycles = 0u64;
                for i in 0..count {
                    cycles += self.apply_write_actions(
                        tile,
                        LineId(first.0 + i),
                        home,
                        acts.as_slice(),
                        clock0 + cycles,
                    );
                }
                return cycles;
            }
        }
        let mut cycles = 0u64;
        for i in 0..count {
            cycles += self.store_protocol(tile, LineId(first.0 + i), home, clock0 + cycles);
        }
        cycles
    }

    /// Bulk load of a same-home run under an active protocol. The
    /// reference walk computes the read ctx *after* the cache probe has
    /// recorded the requestor as a sharer, so the uniform ctx is built
    /// from pre-read state (`was_sharer: true`, `others` = foreign
    /// sharers, owner untouched by reads) and scanned before any probe
    /// mutates the run. Per line the cache walk still runs — L1/L2 hits
    /// bypass the transition exactly as
    /// [`load_protocol`](Self::load_protocol) does; home/DDR placements
    /// apply the hoisted aggregate.
    fn protocol_read_run(
        &mut self,
        tile: TileId,
        first: LineId,
        count: u64,
        home: TileId,
        placement: Placement,
        clock0: u64,
    ) -> u64 {
        let num_ctrls = self.machine.num_controllers();
        let dir = &self.caches.directory;
        let s0 = dir.is_sharer(first, tile);
        let ctx0 = LineCtx {
            requestor: tile,
            home,
            others: dir.sharer_count(first) - u32::from(s0),
            was_sharer: true,
            owner: dir.owner_of(first),
            links_on: self.contention.coherence_enabled(),
        };
        // Pre-read uniformity: same foreign-sharer count and owner on
        // every line (the requestor's own pre-read membership cancels
        // out of the post-read ctx, so it need not match).
        let uniform = (1..count).all(|i| {
            let line = LineId(first.0 + i);
            let s = dir.is_sharer(line, tile);
            dir.sharer_count(line) - u32::from(s) == ctx0.others && dir.owner_of(line) == ctx0.owner
        });
        let acts = if uniform {
            self.protocol.on_read_run(&ctx0)
        } else {
            None
        };
        let mut cycles = 0u64;
        if let Some(acts) = acts {
            for i in 0..count {
                let line = LineId(first.0 + i);
                let now = clock0 + cycles;
                let place = self.caches.read(tile, line, home);
                cycles += match place {
                    crate::cache::ReadPlace::L1 | crate::cache::ReadPlace::L2 => {
                        self.bill_load(tile, line, home, place, 0, now)
                    }
                    crate::cache::ReadPlace::Home { .. } => {
                        self.apply_read_actions(tile, line, home, place, 0, acts.as_slice(), now)
                    }
                    crate::cache::ReadPlace::Ddr => {
                        let ctrl = placement.controller_of(line.addr(), num_ctrls);
                        self.apply_read_actions(tile, line, home, place, ctrl, acts.as_slice(), now)
                    }
                };
            }
            return cycles;
        }
        for i in 0..count {
            let line = LineId(first.0 + i);
            let now = clock0 + cycles;
            let place = self.caches.read(tile, line, home);
            let ctrl = if place == crate::cache::ReadPlace::Ddr {
                placement.controller_of(line.addr(), num_ctrls)
            } else {
                0
            };
            cycles += self.load_protocol(tile, line, home, place, ctrl, now);
        }
        cycles
    }

    /// Caches-off bulk path: a same-home run of uncached DRAM
    /// transactions, chunked by striping boundary so the controller —
    /// and with it the uncontended per-line cost — is constant per
    /// chunk. Each chunk is billed through
    /// [`ContentionModel::try_zero_delay_batch`]: when the home port and
    /// controller are idle and keep up with the line stride, the whole
    /// chunk is one O(1) booking (the common case for the bandwidth
    /// microbenches this mode exists for); otherwise the chunk falls
    /// back to the per-line [`uncached_line`](Self::uncached_line) walk,
    /// so delays, stats, and server state stay cycle-exact with the
    /// reference walk in every regime.
    #[allow(clippy::too_many_arguments)]
    fn uncached_run(
        &mut self,
        tile: TileId,
        first: LineId,
        count: u64,
        write: bool,
        placement: Placement,
        home: TileId,
        clock0: u64,
    ) -> u64 {
        const LINES_PER_STRIPE: u64 = crate::mem::STRIPE_BYTES / LINE_BYTES;
        let num_ctrls = self.machine.num_controllers();
        let mut cycles = 0u64;
        let mut l = first.0;
        let end = first.0 + count;
        while l < end {
            let line = LineId(l);
            let ctrl = placement.controller_of(line.addr(), num_ctrls);
            let chunk_end = match placement {
                // Only striping varies the controller inside a page.
                Placement::Striped => end.min((l / LINES_PER_STRIPE + 1) * LINES_PER_STRIPE),
                _ => end,
            };
            let run = chunk_end - l;
            let ctrl_attach = self.machine.controller(ctrl).attach;
            let base = if write {
                self.params.store_post
            } else {
                self.machine
                    .access_cycles(tile, HitLevel::Ddr { ctrl_attach })
            };
            let now = clock0 + cycles;
            let remote = (home != tile).then_some(home);
            if self.contention.try_zero_delay_batch(
                remote,
                self.params.home_service,
                ctrl,
                self.params.ctrl_service,
                now,
                base,
                run,
            ) {
                self.stats.ddr_accesses += run;
                if home != tile {
                    self.stats.tile_home_requests[home.index()] += run;
                }
                cycles += base * run;
            } else {
                for i in 0..run {
                    cycles +=
                        self.uncached_line(tile, LineId(l + i), home, ctrl, write, clock0 + cycles);
                }
            }
            l = chunk_end;
        }
        cycles
    }

    /// Fold a store run's batched counters into the run stats.
    fn fold_store_agg(&mut self, home: TileId, agg: &StoreAgg) {
        self.stats.l2_hits += agg.l2;
        self.stats.home_hits += agg.home_hits;
        self.stats.tile_home_requests[home.index()] += agg.home_hits;
        self.stats.invalidations += agg.invals;
    }

    // ------------------------------------------------------------------
    // Replay loop.
    // ------------------------------------------------------------------

    /// Replay `program` under `sched`; consumes the engine's cache/alloc
    /// state (call on a fresh engine per experiment). The program's op
    /// streams are reset, validated in one streaming pass, then replayed —
    /// generation runs twice per run, but generating is O(ops) while the
    /// replay pays the cache walk per *line*, so the extra pass is noise
    /// even at the 2^26-element CI scale (~2.5 M ops vs ~10^8 line events).
    pub fn run(
        mut self,
        program: &mut Program,
        sched: &mut dyn Scheduler,
    ) -> Result<RunStats, EngineError> {
        program.validate()?;
        let n = program.threads.len();
        assert!(
            n <= 4 * self.machine.num_tiles() as usize,
            "too many threads for a {} machine",
            self.machine.name()
        );

        let mut streams: Vec<OpStream<'_>> =
            program.threads.iter_mut().map(OpStream::new).collect();
        let threads: Vec<ThreadState> = streams
            .iter_mut()
            .enumerate()
            .map(|(tid, stream)| {
                let cur = stream.next_op();
                ThreadState {
                    tile: sched.initial_tile(tid),
                    clock: 0,
                    done: cur.is_none(),
                    cur,
                    progress: 0,
                }
            })
            .collect();
        let heap: BinaryHeap<Reverse<(u64, usize)>> = threads
            .iter()
            .enumerate()
            .filter(|(_, t)| !t.done)
            .map(|(tid, t)| Reverse((t.clock, tid)))
            .collect();
        let mut ctx = RunCtx {
            slots: vec![None; program.num_slots as usize],
            signal_time: vec![None; program.num_events as usize],
            waiters: vec![Vec::new(); program.num_events as usize],
            resume: vec![None; n],
            threads,
            streams,
            heap,
        };

        let workers = plan_intra_workers(
            self.intra_jobs,
            self.machine.num_tiles(),
            sched.is_static(),
            self.protocol_active,
            self.home_perm.is_some(),
            self.caches_enabled,
        );
        if self.intra_jobs > 1 && workers == 1 {
            // Surface the silent demotion: the run is still correct, just
            // sequential. Diagnostic only — never serialized, so the
            // byte-identity contract across worker counts is untouched.
            self.stats.intra_demoted = Some(if !sched.is_static() {
                "dynamic scheduler (migration breaks the epoch partition)"
            } else if !self.caches_enabled {
                "caches-off bandwidth mode (shared servers serialise)"
            } else {
                "single-tile machine"
            });
        }
        if workers > 1 {
            crate::sim::epoch::run_parallel(&mut self, &mut ctx, sched, workers)?;
        } else {
            self.run_until(&mut ctx, None, sched)?;
        }

        let undone: Vec<usize> = ctx
            .threads
            .iter()
            .enumerate()
            .filter(|(_, t)| !t.done)
            .map(|(tid, _)| tid)
            .collect();
        if !undone.is_empty() {
            return Err(EngineError::Deadlock(undone));
        }

        self.stats.makespan_cycles = ctx.threads.iter().map(|t| t.clock).max().unwrap_or(0);
        self.stats.thread_cycles = ctx.threads.iter().map(|t| t.clock).collect();
        self.stats.home_queue_cycles = self.contention.home_delay_cycles;
        self.stats.ctrl_queue_cycles = self.contention.ctrl_delay_cycles;
        if self.contention.links_enabled() {
            self.stats.link_queue_cycles = self.contention.link_delay_cycles;
            self.stats.link_requests = std::mem::take(&mut self.contention.link_requests);
            self.stats.reply_link_cycles = self.contention.reply_link_cycles;
            self.stats.invalidation_link_cycles = self.contention.invalidation_link_cycles;
            self.stats.link_reply_requests =
                std::mem::take(&mut self.contention.link_reply_requests);
            self.stats.link_inval_requests =
                std::mem::take(&mut self.contention.link_inval_requests);
            self.stats.update_fanout_cycles = self.contention.update_fanout_cycles;
        }
        self.stats.allocs = self.alloc.allocs;
        self.stats.frees = self.alloc.frees;
        Ok(self.stats)
    }

    /// Sequential pop loop, bounded: drain the heap until it holds no entry
    /// below `window_end` (`None` = run to completion). This *is* the
    /// original engine loop — the parallel epoch driver calls it per epoch
    /// to drain whatever its workers could not prove independent, and the
    /// single-worker path calls it once with no bound.
    pub(crate) fn run_until(
        &mut self,
        ctx: &mut RunCtx<'_>,
        window_end: Option<u64>,
        sched: &mut dyn Scheduler,
    ) -> Result<(), EngineError> {
        loop {
            match ctx.heap.peek() {
                Some(&Reverse((clock, _))) if window_end.map_or(true, |we| clock < we) => {}
                _ => return Ok(()),
            }
            let Reverse((clock, tid)) = ctx.heap.pop().expect("peeked above");

            // Mid-batch continuation from a parked epoch quantum: resumes
            // the exact pop the worker consumed. Checked *before* the
            // staleness test — the thread's clock has already moved past
            // the pop key by the lines the worker executed — and skips the
            // scheduler tick, which the worker's quantum already earned
            // (parallel replay only runs for static schedulers, whose tick
            // is a no-op; see `Scheduler::is_static`).
            let resume = match ctx.resume[tid] {
                Some(info) if info.key == clock && !ctx.threads[tid].done => ctx.resume[tid].take(),
                _ => None,
            };
            if resume.is_none() {
                // Stale heap entry (thread was re-queued by a signal, an
                // epoch, or a duplicate push).
                if ctx.threads[tid].done || ctx.threads[tid].clock != clock {
                    continue;
                }
                // Scheduler tick: Tile Linux may migrate the thread here.
                if let Some(new_tile) = sched.maybe_migrate(tid, ctx.threads[tid].tile, clock) {
                    ctx.threads[tid].tile = new_tile;
                    ctx.threads[tid].clock += self.params.migration_cost;
                    self.stats.migrations += 1;
                    ctx.heap.push(Reverse((ctx.threads[tid].clock, tid)));
                    continue;
                }
            }
            self.run_quantum(ctx, tid, resume)?;
        }
    }

    /// One scheduling quantum for `tid` (optionally resuming a parked
    /// one). Mirrors the historical inline loop byte-for-byte.
    fn run_quantum(
        &mut self,
        ctx: &mut RunCtx<'_>,
        tid: usize,
        resume: Option<ParkInfo>,
    ) -> Result<(), EngineError> {
        let mut budget = QUANTUM_LINES;
        if let Some(info) = resume {
            budget = info.budget;
            if info.batch_total > 0 {
                let spent = self.finish_parked_batch(ctx, tid, info)?;
                budget = budget.saturating_sub(spent.max(1));
                if ctx.threads[tid].cur.is_none() {
                    ctx.threads[tid].cur = ctx.streams[tid].next_op();
                    if ctx.threads[tid].cur.is_none() {
                        ctx.threads[tid].done = true;
                    }
                }
            }
        }
        let mut blocked = false;
        while budget > 0 && !ctx.threads[tid].done {
            let op = ctx.threads[tid].cur.expect("live thread must hold an op");
            match self.step_op(tid, ctx, op)? {
                StepResult::Progress(lines) => {
                    budget = budget.saturating_sub(lines.max(1));
                }
                StepResult::Blocked(event) => {
                    ctx.waiters[event as usize].push(tid);
                    blocked = true;
                    break;
                }
                StepResult::Signalled(event) => {
                    budget = budget.saturating_sub(1);
                    // Wake waiters: their clock joins the signal time.
                    let now = ctx.signal_time[event as usize].unwrap();
                    for w in ctx.waiters[event as usize].drain(..) {
                        ctx.threads[w].clock = ctx.threads[w].clock.max(now);
                        ctx.heap.push(Reverse((ctx.threads[w].clock, w)));
                    }
                }
            }
            if ctx.threads[tid].cur.is_none() {
                // Current op retired: pull the next from the stream.
                ctx.threads[tid].cur = ctx.streams[tid].next_op();
                if ctx.threads[tid].cur.is_none() {
                    ctx.threads[tid].done = true;
                }
            }
        }
        if !ctx.threads[tid].done && !blocked {
            ctx.heap.push(Reverse((ctx.threads[tid].clock, tid)));
        }
        Ok(())
    }

    /// Complete a line batch an epoch worker left half-executed. The
    /// worker billed the first `batch_done` lines (pairs for `Copy`) at
    /// constant cache-hit cost and advanced the thread clock accordingly,
    /// so billing the remainder from the *current* clock reproduces the
    /// sequential arrival times exactly. Returns the budget units the full
    /// batch consumes (lines, or 2× pairs for `Copy`), which the caller
    /// deducts — the worker deliberately left `budget` untouched for the
    /// parked op.
    fn finish_parked_batch(
        &mut self,
        ctx: &mut RunCtx<'_>,
        tid: usize,
        info: ParkInfo,
    ) -> Result<u64, EngineError> {
        let op = ctx.threads[tid].cur.expect("parked thread must hold an op");
        let (tile, clock0, progress) = {
            let t = &ctx.threads[tid];
            (t.tile, t.clock, t.progress)
        };
        let batch = info.batch_total;
        debug_assert!(info.batch_done < batch, "a finished batch never parks");
        match op {
            Op::Read { loc, bytes } | Op::Write { loc, bytes } => {
                let write = matches!(op, Op::Write { .. });
                let addr = self.resolve(tid, &ctx.slots, loc)?;
                let total_lines = crate::mem::line_count(addr, bytes);
                let first = LineId(addr.line().0 + progress + info.batch_done);
                let count = batch - info.batch_done;
                let cycles = if self.page_runs {
                    self.access_run(tile, first, count, write, clock0)?
                } else {
                    let mut c = 0u64;
                    for l in first.0..first.0 + count {
                        c += self.line_access(tile, LineId(l), write, clock0 + c)?;
                    }
                    c
                };
                let t = &mut ctx.threads[tid];
                t.clock += cycles;
                if progress + batch >= total_lines {
                    t.progress = 0;
                    t.cur = None;
                } else {
                    t.progress = progress + batch;
                }
                Ok(batch)
            }
            Op::Copy { src, dst, bytes } => {
                let s = self.resolve(tid, &ctx.slots, src)?;
                let d = self.resolve(tid, &ctx.slots, dst)?;
                let total_lines = crate::mem::line_count(d, bytes);
                let src_first = s.line().0 + progress + info.batch_done;
                let dst_first = d.line().0 + progress + info.batch_done;
                let count = batch - info.batch_done;
                let mut cycles = 0u64;
                if self.page_runs {
                    let mut src_cursor = AttrCursor::new();
                    let mut dst_cursor = AttrCursor::new();
                    for i in 0..count {
                        let sl = LineId(src_first + i);
                        let sa = src_cursor.resolve(&mut self.alloc.table, sl, tile)?;
                        cycles += self.fast_line(tile, sl, sa, false, clock0 + cycles);
                        let dl = LineId(dst_first + i);
                        let da = dst_cursor.resolve(&mut self.alloc.table, dl, tile)?;
                        cycles += self.fast_line(tile, dl, da, true, clock0 + cycles);
                    }
                    self.stats.line_accesses += 2 * count;
                } else {
                    for i in 0..count {
                        cycles +=
                            self.line_access(tile, LineId(src_first + i), false, clock0 + cycles)?;
                        cycles +=
                            self.line_access(tile, LineId(dst_first + i), true, clock0 + cycles)?;
                    }
                }
                let t = &mut ctx.threads[tid];
                t.clock += cycles;
                if progress + batch >= total_lines {
                    t.progress = 0;
                    t.cur = None;
                } else {
                    t.progress = progress + batch;
                }
                Ok(batch * 2)
            }
            _ => unreachable!("only line-batch ops park mid-batch"),
        }
    }

    pub(crate) fn resolve(
        &self,
        tid: usize,
        slots: &[Option<Region>],
        loc: Loc,
    ) -> Result<VAddr, EngineError> {
        match loc {
            Loc::Abs(a) => Ok(a),
            Loc::Slot { slot, offset } => slots[slot as usize]
                .map(|r| r.addr.offset(offset))
                .ok_or(EngineError::UnboundSlot { thread: tid, slot }),
        }
    }

    fn step_op(
        &mut self,
        tid: usize,
        ctx: &mut RunCtx<'_>,
        op: Op,
    ) -> Result<StepResult, EngineError> {
        let (tile, clock0, progress) = {
            let t = &ctx.threads[tid];
            (t.tile, t.clock, t.progress)
        };
        match op {
            Op::Read { loc, bytes } | Op::Write { loc, bytes } => {
                let write = matches!(op, Op::Write { .. });
                let addr = self.resolve(tid, &ctx.slots, loc)?;
                let total_lines = crate::mem::line_count(addr, bytes);
                let remaining = total_lines - progress;
                let batch = remaining.min(QUANTUM_LINES);
                // Line ids of a range are contiguous: resume at
                // first + progress in O(1) instead of re-skipping the
                // iterator (which made long ranges quadratic).
                let first = LineId(addr.line().0 + progress);
                let cycles = if self.page_runs {
                    self.access_run(tile, first, batch, write, clock0)?
                } else {
                    let mut c = 0u64;
                    for l in first.0..first.0 + batch {
                        c += self.line_access(tile, LineId(l), write, clock0 + c)?;
                    }
                    c
                };
                let t = &mut ctx.threads[tid];
                t.clock += cycles;
                if progress + batch >= total_lines {
                    t.progress = 0;
                    t.cur = None;
                } else {
                    t.progress = progress + batch;
                }
                Ok(StepResult::Progress(batch))
            }
            Op::Copy { src, dst, bytes } => {
                // Per-line interleave of read+write, like memcpy. The fast
                // path keeps the exact interleave (contention order!) but
                // re-resolves the translation only on page crossings.
                let s = self.resolve(tid, &ctx.slots, src)?;
                let d = self.resolve(tid, &ctx.slots, dst)?;
                let total_lines = crate::mem::line_count(d, bytes);
                let remaining = total_lines - progress;
                let batch = remaining.min(QUANTUM_LINES / 2);
                let src_first = s.line().0 + progress;
                let dst_first = d.line().0 + progress;
                let mut cycles = 0u64;
                if self.page_runs {
                    let mut src_cursor = AttrCursor::new();
                    let mut dst_cursor = AttrCursor::new();
                    for i in 0..batch {
                        let sl = LineId(src_first + i);
                        let sa = src_cursor.resolve(&mut self.alloc.table, sl, tile)?;
                        cycles += self.fast_line(tile, sl, sa, false, clock0 + cycles);
                        let dl = LineId(dst_first + i);
                        let da = dst_cursor.resolve(&mut self.alloc.table, dl, tile)?;
                        cycles += self.fast_line(tile, dl, da, true, clock0 + cycles);
                    }
                    self.stats.line_accesses += 2 * batch;
                } else {
                    for i in 0..batch {
                        cycles += self.line_access(
                            tile,
                            LineId(src_first + i),
                            false,
                            clock0 + cycles,
                        )?;
                        cycles += self.line_access(
                            tile,
                            LineId(dst_first + i),
                            true,
                            clock0 + cycles,
                        )?;
                    }
                }
                let t = &mut ctx.threads[tid];
                t.clock += cycles;
                if progress + batch >= total_lines {
                    t.progress = 0;
                    t.cur = None;
                } else {
                    t.progress = progress + batch;
                }
                Ok(StepResult::Progress(batch * 2))
            }
            Op::Compute { cycles } => {
                let t = &mut ctx.threads[tid];
                t.clock += cycles;
                self.stats.compute_cycles += cycles;
                t.cur = None;
                // Compute is cheap to simulate; bill one budget unit.
                Ok(StepResult::Progress(1))
            }
            Op::Alloc { slot, bytes, kind } => {
                debug_assert!(bytes > 0, "validate rejects zero-byte allocs");
                let region = self
                    .alloc
                    .alloc(tile, bytes, kind)
                    .map_err(|source| EngineError::Alloc { thread: tid, source })?;
                ctx.slots[slot as usize] = Some(region);
                let pages = bytes.div_ceil(crate::arch::PAGE_BYTES);
                let t = &mut ctx.threads[tid];
                t.clock += ALLOC_BASE_CYCLES + ALLOC_PER_PAGE_CYCLES * pages;
                t.cur = None;
                Ok(StepResult::Progress(1))
            }
            Op::Free { slot } => {
                let region = ctx.slots[slot as usize]
                    .take()
                    .ok_or(EngineError::UnboundSlot { thread: tid, slot })?;
                // Dirty owners in the dying range (MESI/MOESI silent
                // upgrades leave the home stale) flush before the pages
                // are torn down — the last chance to bill those lines.
                let mut flush = 0u64;
                if self.protocol_active {
                    let first = region.addr.line();
                    let last = VAddr(region.addr.0 + region.bytes - 1).line();
                    for (line, owner) in self.caches.owners_in_range(first, last) {
                        let home = match self.alloc.table.resolve_home(line, owner) {
                            Ok(h) => self.map_home(h),
                            Err(_) => owner,
                        };
                        let ctx = self.line_ctx(owner, line, home);
                        for action in self.protocol.on_evict(&ctx) {
                            if let CoherenceAction::WritebackToHome { .. } = action {
                                flush += self.contention.reply_path_request(
                                    owner,
                                    home,
                                    clock0 + flush,
                                    self.params.line_flits,
                                );
                            }
                        }
                    }
                }
                let freed = self
                    .alloc
                    .free(region.addr)
                    .map_err(|source| EngineError::Alloc { thread: tid, source })?;
                // Freed pages lose all cache + directory state.
                let first = freed.addr.line();
                let last = VAddr(freed.addr.0 + freed.bytes - 1).line();
                self.caches.purge_line_range(first, last);
                let t = &mut ctx.threads[tid];
                t.clock += FREE_BASE_CYCLES + flush;
                t.cur = None;
                Ok(StepResult::Progress(1))
            }
            Op::Signal { event } => {
                let t = &mut ctx.threads[tid];
                t.cur = None;
                ctx.signal_time[event as usize] = Some(t.clock);
                Ok(StepResult::Signalled(event))
            }
            Op::Wait { event } => {
                match ctx.signal_time[event as usize] {
                    Some(s) => {
                        let t = &mut ctx.threads[tid];
                        t.clock = t.clock.max(s);
                        t.cur = None;
                        Ok(StepResult::Progress(1))
                    }
                    None => Ok(StepResult::Blocked(event)),
                }
            }
        }
    }
}

enum StepResult {
    Progress(u64),
    Blocked(u32),
    Signalled(u32),
}

/// Effective intra-run worker count for a run. Pure, so tests can pin the
/// gating table directly.
///
/// The parallel replay engages only when every precondition of its
/// determinism argument holds:
///
/// - `requested > 1` — someone asked for it (`--intra-jobs`);
/// - the scheduler is static ([`Scheduler::is_static`]): threads never
///   migrate, so the tile partition is stable across an epoch;
/// - caches are on: the caches-off mode routes every line through the
///   shared controller/link servers, which serialise anyway.
///
/// An active coherence protocol and the opaque home permutation used to
/// force sequential; both now compose with the epoch driver. Phase-A
/// eligibility already demands own-tile homes and (for writes) no
/// foreign sharer, and under those preconditions every protocol's
/// transition is action-free: `SilentUpgrade` requires a *remote* home,
/// so an own-homed line is never self-owned, and a foreign owner implies
/// a foreign sharer, which fences the quantum to phase B. Phase-A reads
/// are L1/L2 hits that bypass the transition entirely. The opaque
/// permutation is a pure tile bijection the eligibility scan applies
/// before the own-home test (see `epoch::scan_range`), so the partition
/// argument is unchanged. The parameters stay in the signature to keep
/// the decision auditable from tests.
///
/// Otherwise the run stays sequential — same stats, no speedup — and
/// [`RunStats::intra_demoted`](crate::sim::stats::RunStats) names the
/// reason. The count is clamped to the tile count (workers own disjoint
/// tile ranges, so extras would idle).
pub fn plan_intra_workers(
    requested: usize,
    num_tiles: u32,
    sched_static: bool,
    protocol_active: bool,
    permuted_homes: bool,
    caches_enabled: bool,
) -> usize {
    // Accepted-and-composable: kept as parameters so the gating table in
    // the tests records that these are deliberate non-gates.
    let _ = (protocol_active, permuted_homes);
    if requested <= 1 || !sched_static || !caches_enabled {
        return 1;
    }
    requested.min(num_tiles as usize)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::HashPolicy;
    use crate::sched::StaticMapper;
    use crate::sim::trace::TraceBuilder;

    fn engine(policy: HashPolicy) -> Engine {
        Engine::new(EngineConfig::tilepro64(MemConfig {
            hash_policy: policy,
            striping: true,
        }))
    }

    #[test]
    fn single_thread_read_costs_cycles() {
        let mut e = engine(HashPolicy::None);
        let r = e.prealloc(TileId(0), 4096);
        let mut b = TraceBuilder::new();
        b.read(Loc::Abs(r.addr), 4096);
        let mut p = Program::from_builders(vec![b], 0, 0);
        let stats = e.run(&mut p, &mut StaticMapper::new()).unwrap();
        assert_eq!(stats.line_accesses, 64);
        assert_eq!(stats.ddr_accesses, 64, "cold read misses to DDR");
        assert!(stats.makespan_cycles > 64 * 88);
    }

    #[test]
    fn rereads_hit_cache() {
        let mut e = engine(HashPolicy::None);
        let r = e.prealloc(TileId(0), 4096);
        let mut b = TraceBuilder::new();
        b.read(Loc::Abs(r.addr), 4096).read(Loc::Abs(r.addr), 4096);
        let mut p = Program::from_builders(vec![b], 0, 0);
        let stats = e.run(&mut p, &mut StaticMapper::new()).unwrap();
        assert_eq!(stats.l1_hits, 64, "second pass must hit L1");
    }

    #[test]
    fn alloc_binds_slot_and_rehomes() {
        // Thread on tile 5 allocates (policy none): pages home on tile 5,
        // so repeat reads are local.
        let e = engine(HashPolicy::None);
        let mut b = TraceBuilder::new();
        b.alloc(0, 4096, AllocKind::Heap)
            .write(Loc::Slot { slot: 0, offset: 0 }, 4096)
            .read(Loc::Slot { slot: 0, offset: 0 }, 4096);
        // Put the thread on tile 5 via tid=5.
        let empty = TraceBuilder::new();
        let mut p = Program::from_builders(
            vec![empty.clone(), empty.clone(), empty.clone(), empty.clone(), empty, b],
            1,
            0,
        );
        let stats = e.run(&mut p, &mut StaticMapper::new()).unwrap();
        // The write first-touch homes the pages on tile 5 and fills its L2;
        // the re-read must be all local (L1/L2), no DDR, no remote home.
        assert_eq!(stats.l1_hits + stats.l2_hits, 128, "local alloc must stay local");
        assert_eq!(stats.ddr_accesses, 0);
    }

    #[test]
    fn free_purges_cache() {
        let e = engine(HashPolicy::None);
        let mut b = TraceBuilder::new();
        b.alloc(0, 4096, AllocKind::Heap)
            .write(Loc::Slot { slot: 0, offset: 0 }, 4096)
            .free(0)
            .alloc(1, 4096, AllocKind::Heap)
            .read(Loc::Slot { slot: 1, offset: 0 }, 4096);
        let mut p = Program::from_builders(vec![b], 2, 0);
        let stats = e.run(&mut p, &mut StaticMapper::new()).unwrap();
        // The re-alloc reuses the same pages (64 lines), but the purge
        // means the read must go to DDR (no stale hits from the writes).
        assert_eq!(stats.ddr_accesses, 64);
        assert_eq!(stats.l1_hits, 0);
    }

    #[test]
    fn signal_wait_orders_clocks() {
        let mut e = engine(HashPolicy::None);
        let r = e.prealloc(TileId(0), 1 << 20);
        // Thread 0: long read then signal. Thread 1: wait then tiny read.
        let mut b0 = TraceBuilder::new();
        b0.read(Loc::Abs(r.addr), 1 << 20).signal(0);
        let mut b1 = TraceBuilder::new();
        b1.wait(0).read(Loc::Abs(r.addr), 64);
        let mut p = Program::from_builders(vec![b0, b1], 0, 1);
        let stats = e.run(&mut p, &mut StaticMapper::new()).unwrap();
        // Thread 1 must finish after thread 0 signalled.
        assert!(stats.thread_cycles[1] >= stats.thread_cycles[0] - 1000);
    }

    #[test]
    fn deadlock_detected() {
        let mut b = TraceBuilder::new();
        b.wait(0); // nobody signals
        let mut p = Program::from_builders(vec![b], 0, 1);
        let e = engine(HashPolicy::None);
        match e.run(&mut p, &mut StaticMapper::new()) {
            Err(EngineError::Deadlock(t)) => assert_eq!(t, vec![0]),
            other => panic!("expected deadlock, got {other:?}"),
        }
    }

    #[test]
    fn unbound_slot_is_error() {
        let mut b = TraceBuilder::new();
        b.read(Loc::Slot { slot: 0, offset: 0 }, 64);
        let mut p = Program::from_builders(vec![b], 1, 0);
        let e = engine(HashPolicy::None);
        assert!(matches!(
            e.run(&mut p, &mut StaticMapper::new()),
            Err(EngineError::UnboundSlot { .. })
        ));
    }

    #[test]
    fn unmapped_access_is_error() {
        let mut b = TraceBuilder::new();
        b.read(Loc::Abs(VAddr(1 << 30)), 64);
        let mut p = Program::from_builders(vec![b], 0, 0);
        let e = engine(HashPolicy::None);
        assert!(matches!(
            e.run(&mut p, &mut StaticMapper::new()),
            Err(EngineError::Unmapped(_))
        ));
    }

    #[test]
    fn unmapped_access_is_error_in_reference_walk() {
        let mut b = TraceBuilder::new();
        b.read(Loc::Abs(VAddr(1 << 30)), 64);
        let mut p = Program::from_builders(vec![b], 0, 0);
        let e = Engine::new(
            EngineConfig::tilepro64(MemConfig {
                hash_policy: HashPolicy::None,
                striping: true,
            })
            .without_page_runs(),
        );
        assert!(matches!(
            e.run(&mut p, &mut StaticMapper::new()),
            Err(EngineError::Unmapped(_))
        ));
    }

    #[test]
    fn zero_byte_alloc_rejected_before_replay() {
        let mut b = TraceBuilder::new();
        b.alloc(0, 0, AllocKind::Heap);
        let mut p = Program::from_builders(vec![b], 1, 0);
        let e = engine(HashPolicy::None);
        match e.run(&mut p, &mut StaticMapper::new()) {
            Err(EngineError::Invalid(crate::sim::trace::ProgramError::ZeroAlloc {
                thread: 0,
                op: 0,
                slot: 0,
            })) => {}
            other => panic!("expected ZeroAlloc validation error, got {other:?}"),
        }
    }

    #[test]
    fn hash_for_home_spreads_home_hits() {
        // Two threads stream the same shared array twice; under
        // hash-for-home the second pass hits remote homes spread over the
        // chip rather than one tile.
        let mut e = engine(HashPolicy::AllButStack);
        let r = e.prealloc(TileId(0), 1 << 20);
        let mk = |addr| {
            let mut b = TraceBuilder::new();
            b.read(Loc::Abs(addr), 1 << 20);
            b
        };
        let mut p = Program::from_builders(vec![mk(r.addr), mk(r.addr)], 0, 0);
        let stats = e.run(&mut p, &mut StaticMapper::new()).unwrap();
        assert!(stats.home_hits > 0, "expected remote-home L3 hits");
    }

    #[test]
    fn makespan_is_max_thread_clock() {
        let mut e = engine(HashPolicy::None);
        let r = e.prealloc(TileId(0), 1 << 16);
        let mut b0 = TraceBuilder::new();
        b0.read(Loc::Abs(r.addr), 1 << 16);
        let b1 = TraceBuilder::new(); // empty
        let mut p = Program::from_builders(vec![b0, b1], 0, 0);
        let stats = e.run(&mut p, &mut StaticMapper::new()).unwrap();
        assert_eq!(
            stats.makespan_cycles,
            *stats.thread_cycles.iter().max().unwrap()
        );
    }

    /// The load-bearing pin: the page-run fast path must be cycle-exact
    /// with the per-line reference walk, across homing policies, cache
    /// modes, and op mixes (reads, writes, copies, alloc/free, events).
    #[test]
    fn page_run_fast_path_matches_reference_walk() {
        let build = |e: &mut Engine| {
            let shared = e.prealloc_touched(TileId(0), 3 * PAGE_BYTES);
            let cold = e.prealloc(TileId(0), 2 * PAGE_BYTES);
            let mut b0 = TraceBuilder::new();
            b0.read(Loc::Abs(shared.addr), 3 * PAGE_BYTES)
                .write(Loc::Abs(cold.addr.offset(100)), PAGE_BYTES)
                .copy(Loc::Abs(shared.addr), Loc::Abs(cold.addr), PAGE_BYTES + 777)
                .signal(0);
            let mut b1 = TraceBuilder::new();
            b1.alloc(0, PAGE_BYTES / 2, AllocKind::Heap)
                .copy(Loc::Abs(shared.addr), Loc::Slot { slot: 0, offset: 0 }, PAGE_BYTES / 2)
                .read(Loc::Slot { slot: 0, offset: 0 }, PAGE_BYTES / 2)
                .wait(0)
                .write(Loc::Abs(shared.addr.offset(64)), 2 * PAGE_BYTES)
                .free(0);
            Program::from_builders(vec![b0, b1], 1, 1)
        };
        for policy in [HashPolicy::None, HashPolicy::AllButStack] {
            for caches in [true, false] {
                for (links, coherence) in [(false, false), (true, false), (true, true)] {
                    let mk = |page_runs: bool| {
                        let mut cfg = EngineConfig::tilepro64(MemConfig {
                            hash_policy: policy,
                            striping: true,
                        });
                        cfg.caches_enabled = caches;
                        cfg.page_runs = page_runs;
                        cfg.contention.links = links;
                        cfg.contention.coherence = coherence;
                        let mut e = Engine::new(cfg);
                        let mut p = build(&mut e);
                        e.run(&mut p, &mut StaticMapper::new()).unwrap()
                    };
                    let fast = mk(true);
                    let slow = mk(false);
                    assert_eq!(
                        fast.to_json().encode(),
                        slow.to_json().encode(),
                        "fast path diverged ({policy:?}, caches={caches}, links={links}, \
                         coherence={coherence})"
                    );
                    for (a, b, class) in [
                        (&fast.link_requests, &slow.link_requests, "request"),
                        (&fast.link_reply_requests, &slow.link_reply_requests, "reply"),
                        (&fast.link_inval_requests, &slow.link_inval_requests, "inval"),
                    ] {
                        assert_eq!(
                            a, b,
                            "per-link {class} traffic diverged ({policy:?}, caches={caches}, \
                             links={links}, coherence={coherence})"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn stats_carry_the_machine_clock() {
        // epiphany16 must report wall seconds at its 600 MHz clock; the
        // tilepro64 baseline keeps the 860 MHz conversion.
        let machine = Arc::new(crate::arch::Machine::epiphany16());
        let mut e = Engine::new(EngineConfig::for_machine(
            machine.clone(),
            MemConfig {
                hash_policy: HashPolicy::None,
                striping: true,
            },
        ));
        let r = e.prealloc(TileId(0), 4096);
        let mut b = TraceBuilder::new();
        b.read(Loc::Abs(r.addr), 4096);
        let mut p = Program::from_builders(vec![b], 0, 0);
        let stats = e
            .run(&mut p, &mut crate::sched::StaticMapper::for_machine(&machine))
            .unwrap();
        assert_eq!(stats.clock_hz, 600.0e6);
        let expect = stats.makespan_cycles as f64 / 600.0e6;
        assert!((stats.seconds() - expect).abs() < 1e-15);
        let base = engine(HashPolicy::None);
        let mut b = TraceBuilder::new();
        b.compute(10);
        let mut p = Program::from_builders(vec![b], 0, 0);
        let s = base.run(&mut p, &mut StaticMapper::new()).unwrap();
        assert_eq!(s.clock_hz, crate::arch::CLOCK_HZ);
    }

    #[test]
    fn non_default_machine_runs_and_sizes_stats() {
        // A 4×8 non-square grid with links on: the heatmap vector and the
        // link vector are sized by the machine, and remote traffic shows
        // up as link requests.
        let machine = Arc::new(crate::arch::Machine::custom(4, 8, 2).unwrap());
        let mut e = Engine::new(EngineConfig::for_machine(
            machine.clone(),
            MemConfig {
                hash_policy: HashPolicy::AllButStack,
                striping: true,
            },
        ));
        let r = e.prealloc(TileId(0), 1 << 20);
        let mk = |addr| {
            let mut b = TraceBuilder::new();
            b.read(Loc::Abs(addr), 1 << 20);
            b
        };
        let mut p = Program::from_builders(vec![mk(r.addr), mk(r.addr)], 0, 0);
        let stats = e.run(&mut p, &mut StaticMapper::for_machine(&machine)).unwrap();
        assert_eq!(stats.tile_home_requests.len(), 32);
        assert_eq!(stats.link_requests.len(), 4 * 32);
        assert!(
            stats.link_requests.iter().sum::<u64>() > 0,
            "hash-for-home traffic must cross mesh links"
        );
    }

    #[test]
    fn link_contention_slows_the_hot_spot() {
        // Many threads hammering remotely-homed data: with links modelled
        // the makespan cannot shrink, and link queueing must appear.
        let run = |links: bool| {
            let mut cfg = EngineConfig::tilepro64(MemConfig {
                hash_policy: HashPolicy::None,
                striping: true,
            });
            cfg.contention.links = links;
            let mut e = Engine::new(cfg);
            let r = e.prealloc_touched(TileId(0), 1 << 19);
            let mut builders = Vec::new();
            for _ in 0..16 {
                let mut b = TraceBuilder::new();
                b.write(Loc::Abs(r.addr), 1 << 19);
                builders.push(b);
            }
            let mut p = Program::from_builders(builders, 0, 0);
            e.run(&mut p, &mut StaticMapper::new()).unwrap()
        };
        let without = run(false);
        let with = run(true);
        assert!(with.link_queue_cycles > 0, "expected link queueing");
        assert!(!with.link_requests.is_empty());
        assert_eq!(without.link_queue_cycles, 0);
        assert!(without.link_requests.is_empty());
    }

    // ------------------------------------------------------------------
    // Protocol lab: the pluggable coherence protocols.
    // ------------------------------------------------------------------

    /// Baseline chip with full link + coherence modelling and a protocol.
    fn protocol_cfg(spec: ProtocolSpec) -> EngineConfig {
        EngineConfig::tilepro64(MemConfig {
            hash_policy: HashPolicy::None,
            striping: true,
        })
        .with_link_contention()
        .with_protocol(spec)
    }

    /// One thread on tile 1 makes four passes of writes over a page homed
    /// on tile 0: pass 1 claims every line, passes 2–4 are sole-sharer
    /// rewrites — the exact access shape the protocols disagree on.
    fn rewrite_ladder(spec: ProtocolSpec) -> RunStats {
        let mut e = Engine::new(protocol_cfg(spec));
        let r = e.prealloc_touched(TileId(0), PAGE_BYTES);
        let mut b = TraceBuilder::new();
        for _ in 0..4 {
            b.write(Loc::Abs(r.addr), PAGE_BYTES);
        }
        let empty = TraceBuilder::new();
        let mut p = Program::from_builders(vec![empty, b], 0, 0);
        e.run(&mut p, &mut StaticMapper::new()).unwrap()
    }

    #[test]
    fn sole_sharer_rewrites_separate_the_protocols() {
        let wi = rewrite_ladder(ProtocolSpec::default());
        let msi = rewrite_ladder(ProtocolSpec::new(ProtocolKind::Msi));
        let mesi = rewrite_ladder(ProtocolSpec::new(ProtocolKind::Mesi));

        // 64 lines × 3 rewrite passes upgrade under both MSI and MESI.
        assert_eq!(wi.upgrade_hits, 0);
        assert_eq!(msi.upgrade_hits, 192);
        assert_eq!(mesi.upgrade_hits, 192);

        // MSI's upgrades are round trips billed on the invalidation
        // class; MESI's are silent — zero coherence packets.
        assert!(msi.link_inval_requests.iter().sum::<u64>() > 0);
        assert_eq!(mesi.link_inval_requests.iter().sum::<u64>(), 0);

        // Single writer thread, so makespans compose additively: MSI is
        // write-invalidate plus a strictly positive upgrade per rewrite.
        assert!(msi.makespan_cycles > wi.makespan_cycles);

        // MESI rewrites never touch the home (one posted pass instead of
        // four) and absorb the stores locally.
        assert!(mesi.home_hits < wi.home_hits);
        assert!(mesi.l2_hits > wi.l2_hits);
    }

    #[test]
    fn moesi_owner_forwards_what_mesi_flushes() {
        let run = |spec: ProtocolSpec| {
            let mut e = Engine::new(protocol_cfg(spec));
            let r = e.prealloc_touched(TileId(0), 64);
            // Writer on tile 1: the second write silently upgrades it to
            // dirty owner; reader on tile 2 then misses to the home.
            let mut w = TraceBuilder::new();
            w.write(Loc::Abs(r.addr), 64)
                .write(Loc::Abs(r.addr), 64)
                .signal(0);
            let mut rd = TraceBuilder::new();
            rd.wait(0).read(Loc::Abs(r.addr), 64);
            let empty = TraceBuilder::new();
            let mut p = Program::from_builders(vec![empty, w, rd], 0, 1);
            e.run(&mut p, &mut StaticMapper::new()).unwrap()
        };
        let mesi = run(ProtocolSpec::new(ProtocolKind::Mesi));
        let moesi = run(ProtocolSpec::new(ProtocolKind::Moesi));
        assert!(mesi.upgrade_hits > 0 && moesi.upgrade_hits > 0);
        assert_eq!(mesi.owner_replies, 0, "MESI flushes home, never forwards");
        assert!(moesi.owner_replies > 0, "MOESI owner must source the read");
    }

    #[test]
    fn write_update_keeps_reader_copies_valid() {
        let run = |spec: ProtocolSpec| {
            let mut e = Engine::new(protocol_cfg(spec));
            let r = e.prealloc_touched(TileId(0), PAGE_BYTES);
            // Reader on tile 1 caches the page, writer on tile 2 storms
            // over it, reader re-reads.
            let mut a = TraceBuilder::new();
            a.read(Loc::Abs(r.addr), PAGE_BYTES)
                .signal(0)
                .wait(1)
                .read(Loc::Abs(r.addr), PAGE_BYTES);
            let mut w = TraceBuilder::new();
            w.wait(0).write(Loc::Abs(r.addr), PAGE_BYTES).signal(1);
            let empty = TraceBuilder::new();
            let mut p = Program::from_builders(vec![empty, a, w], 0, 2);
            e.run(&mut p, &mut StaticMapper::new()).unwrap()
        };
        let wi = run(ProtocolSpec::default());
        let wu = run(ProtocolSpec::new(ProtocolKind::WriteUpdate));
        // Write-invalidate kills the reader's copies; write-update sends
        // data instead, so the re-read stays in L1.
        assert!(wi.invalidations > 0);
        assert_eq!(wu.invalidations, 0);
        assert!(wu.l1_hits > wi.l1_hits);
        // The update fan-out is real traffic on the coherence class.
        assert!(wu.link_inval_requests.iter().sum::<u64>() > 0);
    }

    #[test]
    fn opaque_permutes_homes_deterministically() {
        let run = |spec: ProtocolSpec| {
            let cfg = EngineConfig::tilepro64(MemConfig {
                hash_policy: HashPolicy::AllButStack,
                striping: true,
            })
            .with_protocol(spec);
            let mut e = Engine::new(cfg);
            let r = e.prealloc(TileId(0), 1 << 20);
            let mk = |addr| {
                let mut b = TraceBuilder::new();
                b.read(Loc::Abs(addr), 1 << 20);
                b
            };
            let mut p = Program::from_builders(vec![mk(r.addr), mk(r.addr)], 0, 0);
            e.run(&mut p, &mut StaticMapper::new()).unwrap()
        };
        let a = run(ProtocolSpec::parse("opaque").unwrap());
        let b = run(ProtocolSpec::parse("opaque").unwrap());
        let base = run(ProtocolSpec::default());
        let reseeded = run(ProtocolSpec::parse("opaque@7").unwrap());
        assert_eq!(a.to_json().encode(), b.to_json().encode(), "seeded = repeatable");
        assert_ne!(
            a.tile_home_requests, base.tile_home_requests,
            "the permutation must move the home traffic"
        );
        assert_ne!(
            a.tile_home_requests, reseeded.tile_home_requests,
            "a different seed is a different placement"
        );
    }

    #[test]
    fn protocols_collapse_to_the_default_when_links_are_off() {
        // The engagement rule: without modelled coherence traffic there is
        // nothing for a protocol to bill, so every variant must replay the
        // paper-baseline (links-off) record byte-identically.
        let run = |spec: ProtocolSpec| {
            let cfg = EngineConfig::tilepro64(MemConfig {
                hash_policy: HashPolicy::None,
                striping: true,
            })
            .with_protocol(spec);
            let mut e = Engine::new(cfg);
            let r = e.prealloc_touched(TileId(0), PAGE_BYTES);
            let mut b = TraceBuilder::new();
            b.write(Loc::Abs(r.addr), PAGE_BYTES)
                .write(Loc::Abs(r.addr), PAGE_BYTES)
                .read(Loc::Abs(r.addr), PAGE_BYTES);
            let empty = TraceBuilder::new();
            let mut p = Program::from_builders(vec![empty, b], 0, 0);
            e.run(&mut p, &mut StaticMapper::new()).unwrap()
        };
        let base = run(ProtocolSpec::default()).to_json().encode();
        for spec in ProtocolSpec::all() {
            if spec.permutes_homes() {
                continue; // opaque intentionally moves homes even off-link
            }
            assert_eq!(
                run(spec).to_json().encode(),
                base,
                "{} must be inert without coherence links",
                spec.label()
            );
        }
    }

    #[test]
    fn explicit_write_invalidate_is_the_pinned_default() {
        // `--protocol write-invalidate` must be a spelling of the default,
        // not a near-copy: byte-identical stats even with links on.
        let run = |spec: ProtocolSpec| rewrite_ladder(spec);
        assert_eq!(
            run(ProtocolSpec::default()).to_json().encode(),
            run(ProtocolSpec::parse("write-invalidate").unwrap())
                .to_json()
                .encode()
        );
    }

    #[test]
    fn coherence_links_bill_invalidations_and_replies() {
        // Two tiles ping-pong writes over a shared tile-0-homed page:
        // every write invalidates the previous writer, so with coherence
        // billing on the invalidation routes and ack replies must show up
        // — and switch off cleanly under --no-coherence-links.
        let run = |coherence: bool| {
            let mut cfg = EngineConfig::tilepro64(MemConfig {
                hash_policy: HashPolicy::None,
                striping: true,
            })
            .with_link_contention();
            cfg.contention.coherence = coherence;
            let mut e = Engine::new(cfg);
            let r = e.prealloc_touched(TileId(0), PAGE_BYTES);
            let mut builders = Vec::new();
            for _ in 0..4 {
                let mut b = TraceBuilder::new();
                for _ in 0..8 {
                    b.write(Loc::Abs(r.addr), PAGE_BYTES);
                }
                builders.push(b);
            }
            let mut p = Program::from_builders(builders, 0, 0);
            e.run(&mut p, &mut StaticMapper::new()).unwrap()
        };
        let with = run(true);
        let without = run(false);
        assert!(with.invalidations > 0, "ping-pong must invalidate");
        assert!(
            with.invalidation_link_cycles > 0,
            "invalidation routes must queue on links"
        );
        assert!(
            with.link_inval_requests.iter().sum::<u64>() > 0
                && with.link_reply_requests.iter().sum::<u64>() > 0,
            "coherence traffic classes must see packets"
        );
        assert_eq!(without.invalidation_link_cycles, 0);
        assert_eq!(without.reply_link_cycles, 0);
        assert!(without.link_inval_requests.iter().all(|&n| n == 0));
        assert!(
            with.makespan_cycles > without.makespan_cycles,
            "billing coherence traffic cannot speed the run up"
        );
    }
}
