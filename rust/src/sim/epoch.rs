//! Deterministic intra-run parallelism: shard one run's simulated tiles
//! across host cores in time-sliced epochs.
//!
//! The sequential engine replays threads min-clock-first off one heap, so
//! its statistics are a pure function of the program — the pinned-baseline
//! guarantee every test leans on. This module parallelises *within* a run
//! without giving that up. Each epoch:
//!
//! 1. **Window.** Take the earliest runnable clock `w0` and fix the window
//!    `[w0, w0 + EPOCH_WINDOW_CYCLES)`.
//! 2. **Scan.** For every live thread, walk the ops it *could* execute
//!    inside the window (using conservative minimum op costs, plus one
//!    quantum's worth of ops past the horizon — a quantum popped just
//!    under the window end can overrun it). Classify the thread
//!    *eligible* iff every scanned op is a plain `Read`/`Write`/`Copy`/
//!    `Compute` over pages that are resolved and homed on the thread's
//!    own tile, and no scanned write has a foreign sharer (its
//!    invalidation would reach another tile). Otherwise collect the
//!    thread's *footprint*: its own tile, every touched page's home tile,
//!    and every tile sharing a line it may write.
//! 3. **Fence.** Union the ineligible footprints. Tiles outside the fence
//!    that host eligible threads form the parallel phase; everything they
//!    do in the window is provably confined to their own tile's caches
//!    and their own-homed directory lines.
//! 4. **Phase A.** Partition the parallel tiles into contiguous ranges,
//!    one scoped worker each (same `std::thread::scope` machinery as
//!    `coordinator::batch`). Each worker replays its threads off a
//!    private heap with the engine's exact quantum/batch/cost rules,
//!    mutating only its own `TileCaches` slice, logging directory ops,
//!    and accumulating a stats delta. Anything it cannot decide locally
//!    (a cache miss, a foreign sharer) *parks*: the quantum stops at that
//!    exact line and the whole tile goes sequential for the rest of the
//!    window.
//! 5. **Commit.** In canonical worker order: move thread states back,
//!    replay the directory logs (disjoint line sets per worker), fold the
//!    deltas, push heap entries and park continuations.
//! 6. **Phase B.** `Engine::run_until(window_end)` — the sequential loop —
//!    drains every remaining pop below the window end: fenced threads,
//!    parked continuations, signals, allocation, migration.
//!
//! Because phase A executes exactly the pops the sequential loop would
//! have executed, with identical per-tile cache-op sequences and identical
//! costs, the resulting `RunStats` are byte-identical at every worker
//! count — the property `prop_intra_run` pins. When nothing qualifies
//! (hash-for-home pages, a dynamic scheduler), the fence covers the chip
//! and every window runs sequentially: correct, just not faster.
//!
//! ## Why directory protocols compose with phase A
//!
//! Phase-A eligibility already demands that every touched page is homed
//! on the thread's own tile and that no scanned write has a foreign
//! sharer. Under those preconditions every pluggable protocol's
//! transition is **action-free**, so the workers' mirrors stay exact:
//!
//! - a dirty owner can only be installed by `SilentUpgrade`, which
//!   requires a *remote* home — an own-homed line is never owned, so
//!   reads have nothing to flush or forward;
//! - `SilentUpgrade`/`UpgradeRoundTrip` likewise require a remote home,
//!   so no phase-A write upgrades;
//! - invalidation and update fan-outs require foreign sharers, which the
//!   scan fences and the park check re-verifies line by line;
//! - phase-A reads are L1/L2 hits (the park check proves residency),
//!   which bypass `on_read` entirely;
//! - write-update's store mutation (`CacheSystem::write_update`) with no
//!   foreign sharer adds the writer as sole sharer and fills the home L2
//!   — the same end state as the claim walk the `write_line` mirror logs.
//!
//! The opaque home permutation composes too: it is a pure tile bijection,
//! so the eligibility scan simply judges the *permuted* home
//! (`scan_range` maps through `Engine::home_perm` before the own-tile
//! test) and the partition argument is unchanged.

use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::mem;

use crate::arch::{LatencyParams, TileId, LINE_BYTES, PAGE_BYTES};
use crate::cache::{Directory, TileCaches};
use crate::coherence::HomePermutation;
use crate::mem::{line_count, Homing, LineId, Placement, Region, VAddr};
use crate::sched::Scheduler;
use crate::sim::engine::{Engine, EngineError, ParkInfo, RunCtx, ThreadState, QUANTUM_LINES};
use crate::sim::trace::{Loc, Op, OpStream};

/// Simulated-cycle width of one epoch window. Large enough to amortise the
/// scan and the two barriers over many quanta (a quantum is ≲ 128 line
/// events of a few cycles each), small enough that cross-thread coupling
/// (signals, contention) stays confined to the sequential drain.
pub(crate) const EPOCH_WINDOW_CYCLES: u64 = 1 << 17;

/// Scan give-up threshold: a thread whose window coverage needs more ops
/// than this is treated as opaque (fence everything). Keeps the planner
/// O(small) even for degenerate zero-latency configurations.
const MAX_SCAN_OPS: usize = 4096;

/// Ops scanned *past* the point where the accumulated minimum cost covers
/// the window. A quantum popped just below the window end still executes
/// up to a full budget of ops (each costs ≥ 1 budget unit), so its ops
/// must be scanned too. +2 is slack for the partially-complete first op.
const SCAN_TAIL_OPS: usize = QUANTUM_LINES as usize + 2;

const LINES_PER_PAGE: u64 = PAGE_BYTES / LINE_BYTES;

/// A directory mutation recorded by a phase-A worker and replayed at
/// commit. Workers only touch lines homed on their own tiles, so the line
/// sets of different workers are disjoint and replay order across workers
/// cannot matter; within a worker the log order is execution order.
enum DirOp {
    /// `Directory::add_sharer(line, tile)` — reads.
    Share(LineId, TileId),
    /// `Directory::claim_local(line, tile)` — writes with no foreign
    /// sharer (the park check guarantees that precondition).
    Claim(LineId, TileId),
}

/// Stats a worker accumulates locally; folded into `RunStats` at commit.
/// Only counters a fenced-off tile can produce: everything else (home
/// hits, DDR, queueing) implies leaving the tile, which parks.
#[derive(Default)]
struct StatsDelta {
    line_accesses: u64,
    l1_hits: u64,
    l2_hits: u64,
    compute_cycles: u64,
}

/// One thread checked out to a phase-A worker: its state, its op stream,
/// and the heap key it was seeded with (`seed`) — used to avoid pushing a
/// duplicate of the entry the global heap still holds.
struct WorkItem<'a, 'p> {
    tid: usize,
    seed: u64,
    st: ThreadState,
    stream: &'a mut OpStream<'p>,
}

struct WorkerOut {
    states: Vec<(usize, ThreadState)>,
    log: Vec<DirOp>,
    delta: StatsDelta,
    /// Heap entries to add at commit (key, tid) — post-phase-A positions
    /// that differ from the seed entry already in the global heap.
    pushes: Vec<(u64, usize)>,
    /// Park continuations for `Engine::run_until` to resume.
    resume: Vec<(usize, ParkInfo)>,
}

enum QuantumEnd {
    Completed,
    Parked(ParkInfo),
}

/// Per-chunk plan: a contiguous tile range and the thread ids (sorted) it
/// will replay.
struct Chunk {
    tile_lo: u32,
    tile_hi: u32,
    tids: Vec<usize>,
}

/// The epoch loop. `workers` ≥ 2 (the engine routes 0/1 to `run_until`).
pub(crate) fn run_parallel(
    eng: &mut Engine,
    ctx: &mut RunCtx<'_>,
    sched: &mut dyn Scheduler,
    workers: usize,
) -> Result<(), EngineError> {
    // Reused across epochs: the fence / footprint / sharer-union bitmasks
    // (one u64 word per 64 tiles) — allocation-free steady state.
    let words = (eng.machine.num_tiles() as usize).div_ceil(64);
    let mut fence = vec![0u64; words];
    let mut foot = vec![0u64; words];
    let mut sharer_scratch = vec![0u64; words];

    loop {
        // Establish the window start: the smallest *live* heap key.
        let window_start = loop {
            match ctx.heap.peek() {
                None => {
                    debug_assert!(ctx.resume.iter().all(Option::is_none));
                    return Ok(());
                }
                Some(&Reverse((clock, tid))) => {
                    if entry_live(ctx, clock, tid) {
                        break clock;
                    }
                    ctx.heap.pop();
                }
            }
        };
        if window_start > u64::MAX - EPOCH_WINDOW_CYCLES {
            // Clock saturation (degenerate): finish sequentially.
            return eng.run_until(ctx, None, sched);
        }
        let window_end = window_start + EPOCH_WINDOW_CYCLES;

        if let Some(chunks) = plan_epoch(
            eng,
            ctx,
            window_end,
            workers,
            &mut fence,
            &mut foot,
            &mut sharer_scratch,
        ) {
            run_phase_a(eng, ctx, chunks, window_end);
        }
        // Phase B: drain everything below the window end sequentially —
        // fenced threads, parked continuations, signals, page faults.
        eng.run_until(ctx, Some(window_end), sched)?;
        debug_assert!(
            ctx.resume.iter().all(Option::is_none),
            "park continuations are always below the window end"
        );
    }
}

/// Is this heap entry current? Mirrors `run_until`'s pop filter: a park
/// continuation matches on its recorded key, everything else on the
/// thread's clock.
fn entry_live(ctx: &RunCtx<'_>, key: u64, tid: usize) -> bool {
    if ctx.threads[tid].done {
        return false;
    }
    match ctx.resume[tid] {
        Some(info) => info.key == key || ctx.threads[tid].clock == key,
        None => ctx.threads[tid].clock == key,
    }
}

#[inline]
fn set_bit(mask: &mut [u64], tile: TileId) {
    mask[tile.index() / 64] |= 1u64 << (tile.index() % 64);
}

#[inline]
fn get_bit(mask: &[u64], tile: TileId) -> bool {
    mask[tile.index() / 64] & (1u64 << (tile.index() % 64)) != 0
}

fn resolve_loc(slots: &[Option<Region>], loc: Loc) -> Option<VAddr> {
    match loc {
        Loc::Abs(a) => Some(a),
        Loc::Slot { slot, offset } => slots
            .get(slot as usize)
            .copied()
            .flatten()
            .map(|r| r.addr.offset(offset)),
    }
}

/// Scan one thread's reachable window ops. Returns `Some(true)` if the
/// thread is eligible for phase A, `Some(false)` if not (footprint OR'd
/// into `foot`), `None` if the thread is opaque (footprint = whole chip).
#[allow(clippy::too_many_arguments)]
fn scan_thread(
    eng: &Engine,
    threads: &[ThreadState],
    streams: &mut [OpStream<'_>],
    slots: &[Option<Region>],
    tid: usize,
    window_end: u64,
    foot: &mut [u64],
    sharer_scratch: &mut [u64],
) -> Option<bool> {
    let t = &threads[tid];
    let own = t.tile;
    set_bit(foot, own);
    let params = &eng.params;
    let num_tiles = eng.machine.num_tiles();
    let table = &eng.alloc.table;
    let dir = &eng.caches.directory;
    let perm = eng.home_perm.as_ref();
    // Lower bound on what one line event costs: reads pay ≥ min(L1, L2),
    // writes ≥ min(L2, posted-store). 0 (degenerate latencies) makes line
    // ops free for horizon purposes — strictly conservative.
    let lb = params.l1_hit.min(params.l2_hit).min(params.store_post);
    let need = window_end - t.clock;
    let mut eligible = true;
    let mut accum = 0u64;
    let mut stop_at: Option<usize> = None;
    let mut idx = 0usize;
    loop {
        if let Some(s) = stop_at {
            if idx >= s {
                break;
            }
        }
        if idx >= MAX_SCAN_OPS {
            return None;
        }
        let op = if idx == 0 {
            t.cur
        } else {
            streams[tid].peek(idx - 1)
        };
        let Some(op) = op else { break };
        let progress = if idx == 0 { t.progress } else { 0 };
        match op {
            Op::Read { loc, bytes } | Op::Write { loc, bytes } => {
                let Some(addr) = resolve_loc(slots, loc) else {
                    // Unbound slot: the error surfaces in phase B.
                    return None;
                };
                let lines = line_count(addr, bytes) - progress;
                let first = LineId(addr.line().0 + progress);
                let write = matches!(op, Op::Write { .. });
                if !scan_range(
                    table,
                    dir,
                    perm,
                    own,
                    num_tiles,
                    foot,
                    sharer_scratch,
                    &mut eligible,
                    first,
                    lines,
                    write,
                    executable_lines(lb, need, accum, lines),
                ) {
                    return None;
                }
                accum = accum.saturating_add(lines.saturating_mul(lb));
            }
            Op::Copy { src, dst, bytes } => {
                let (Some(s), Some(d)) = (resolve_loc(slots, src), resolve_loc(slots, dst))
                else {
                    return None;
                };
                let lines = line_count(d, bytes) - progress;
                let cap = executable_lines(lb.saturating_mul(2), need, accum, lines);
                let sf = LineId(s.line().0 + progress);
                let df = LineId(d.line().0 + progress);
                if !scan_range(
                    table, dir, perm, own, num_tiles, foot, sharer_scratch, &mut eligible, sf,
                    lines, false, cap,
                ) || !scan_range(
                    table, dir, perm, own, num_tiles, foot, sharer_scratch, &mut eligible, df,
                    lines, true, cap,
                ) {
                    return None;
                }
                accum = accum.saturating_add(lines.saturating_mul(2).saturating_mul(lb));
            }
            Op::Compute { cycles } => {
                accum = accum.saturating_add(cycles);
            }
            Op::Signal { .. } | Op::Wait { .. } => {
                // Cross-thread coupling: sequential-only, but costs no
                // cycles and touches no memory — keep scanning so later
                // ops still contribute to the footprint.
                eligible = false;
            }
            Op::Alloc { .. } | Op::Free { .. } => {
                // Page-table / allocator mutation and global cache purges:
                // effects are not attributable to tiles ahead of time.
                return None;
            }
        }
        if stop_at.is_none() && accum >= need {
            stop_at = Some(idx + 1 + SCAN_TAIL_OPS);
        }
        idx += 1;
    }
    Some(eligible)
}

/// Upper bound on how many lines of an op the thread can actually execute
/// inside the window, given `accum` minimum cycles already accounted:
/// bounds the per-line directory scan for huge ops. `per_line == 0` means
/// no bound can be derived.
fn executable_lines(per_line: u64, need: u64, accum: u64, lines: u64) -> u64 {
    if per_line == 0 {
        return lines;
    }
    lines.min((need.saturating_sub(accum)) / per_line + 2 * QUANTUM_LINES)
}

/// Scan one contiguous line range of one op: page homing checks into
/// `foot`/`eligible`, plus (for writes) the invalidation-victim check over
/// the first `cap` lines. Returns false if the range is opaque (unmapped
/// or hash-for-home) and the whole thread scan should abort.
#[allow(clippy::too_many_arguments)]
fn scan_range(
    table: &crate::mem::PageTable,
    dir: &Directory,
    perm: Option<&HomePermutation>,
    own: TileId,
    num_tiles: u32,
    foot: &mut [u64],
    sharer_scratch: &mut [u64],
    eligible: &mut bool,
    first: LineId,
    lines: u64,
    write: bool,
    cap: u64,
) -> bool {
    let capped = lines.min(cap);
    let mut l = first.0;
    let end = first.0 + capped;
    while l < end {
        let page_end = (l / LINES_PER_PAGE + 1) * LINES_PER_PAGE;
        let run = end.min(page_end) - l;
        let line = LineId(l);
        let Some(attr) = table.attr_of(line.page()) else {
            // Unmapped: phase B will produce the exact error.
            return false;
        };
        match attr.homing {
            Homing::Single(_) | Homing::PageHash => {
                let h = attr
                    .homing
                    .uniform_page_home(line, num_tiles)
                    .expect("uniform by construction");
                // Opaque mode permutes every resolved home; eligibility
                // must judge the tile the engine will actually bill.
                let h = perm.map_or(h, |p| p.map(h));
                set_bit(foot, h);
                if h != own {
                    *eligible = false;
                }
            }
            Homing::HashForHome => {
                // Per-line homes span the chip.
                return false;
            }
            Homing::FirstTouch => {
                // Resolving homes the page on its first toucher — which
                // is this thread or another thread that also scans the
                // page as unresolved; either way the home lands on a tile
                // already in some ineligible footprint. The page-table
                // write itself forces phase B.
                *eligible = false;
            }
        }
        if matches!(attr.placement, Placement::FirstTouchNearest) {
            // `resolve_page` would mutate the placement.
            *eligible = false;
        }
        l += run;
    }
    if write && capped > 0 {
        // Fence every tile whose cached copy this write would invalidate.
        sharer_scratch.fill(0);
        dir.union_sharers(first, capped, sharer_scratch);
        sharer_scratch[own.index() / 64] &= !(1u64 << (own.index() % 64));
        if sharer_scratch.iter().any(|&w| w != 0) {
            *eligible = false;
            for (f, s) in foot.iter_mut().zip(sharer_scratch.iter()) {
                *f |= s;
            }
        }
    }
    true
}

/// Scan all live threads, build the fence, and carve the unfenced
/// phase-A tiles into ≤ `workers` contiguous chunks balanced by thread
/// count. `None` = nothing worth parallelising this window.
#[allow(clippy::too_many_arguments)]
fn plan_epoch(
    eng: &Engine,
    ctx: &mut RunCtx<'_>,
    window_end: u64,
    workers: usize,
    fence: &mut Vec<u64>,
    foot: &mut Vec<u64>,
    sharer_scratch: &mut Vec<u64>,
) -> Option<Vec<Chunk>> {
    fence.iter_mut().for_each(|w| *w = 0);
    let mut eligible_tids: Vec<usize> = Vec::new();
    let n = ctx.threads.len();
    for tid in 0..n {
        if ctx.threads[tid].done || ctx.threads[tid].clock >= window_end {
            continue;
        }
        foot.iter_mut().for_each(|w| *w = 0);
        match scan_thread(
            eng,
            &ctx.threads,
            &mut ctx.streams,
            &ctx.slots,
            tid,
            window_end,
            foot,
            sharer_scratch,
        ) {
            Some(true) => eligible_tids.push(tid),
            Some(false) => {
                for (f, s) in fence.iter_mut().zip(foot.iter()) {
                    *f |= s;
                }
            }
            None => return None, // opaque thread fences the whole chip
        }
    }

    // Eligible threads on unfenced tiles, grouped per tile (in tid order —
    // ineligible threads always fence their own tile, so every thread
    // left on an unfenced tile is in phase A).
    let phase_a: Vec<usize> = eligible_tids
        .into_iter()
        .filter(|&tid| !get_bit(fence, ctx.threads[tid].tile))
        .collect();
    if phase_a.len() < 2 {
        return None;
    }

    let num_tiles = eng.machine.num_tiles() as usize;
    let mut per_tile: Vec<u32> = vec![0; num_tiles];
    for &tid in &phase_a {
        per_tile[ctx.threads[tid].tile.index()] += 1;
    }
    // Contiguous tile chunks with ≈ equal thread counts (contiguity lets
    // the TileCaches array be handed out via split_at_mut).
    let total = phase_a.len();
    let target = total.div_ceil(workers) as u32;
    let mut chunks: Vec<Chunk> = Vec::with_capacity(workers);
    let mut lo = 0u32;
    let mut count = 0u32;
    for tile in 0..num_tiles {
        count += per_tile[tile];
        let last = tile + 1 == num_tiles;
        if (count >= target && chunks.len() + 1 < workers) || last {
            let hi = tile as u32 + 1;
            if count > 0 {
                chunks.push(Chunk {
                    tile_lo: lo,
                    tile_hi: hi,
                    tids: Vec::new(),
                });
            }
            lo = hi;
            count = 0;
        }
    }
    if chunks.len() < 2 {
        return None;
    }
    for &tid in &phase_a {
        let tile = ctx.threads[tid].tile.0;
        let c = chunks
            .iter_mut()
            .find(|c| c.tile_lo <= tile && tile < c.tile_hi)
            .expect("every phase-A tile is covered by a chunk");
        c.tids.push(tid);
    }
    Some(chunks)
}

/// Check the phase-A threads out to scoped workers, run them, and commit
/// the results in canonical worker order.
fn run_phase_a(eng: &mut Engine, ctx: &mut RunCtx<'_>, chunks: Vec<Chunk>, window_end: u64) {
    let placeholder = || ThreadState {
        tile: TileId(0),
        clock: 0,
        cur: None,
        progress: 0,
        done: true,
    };
    let mut stream_refs: Vec<Option<&mut OpStream<'_>>> =
        ctx.streams.iter_mut().map(Some).collect();
    let mut work: Vec<Vec<WorkItem<'_, '_>>> = Vec::with_capacity(chunks.len());
    for c in &chunks {
        let mut items = Vec::with_capacity(c.tids.len());
        for &tid in &c.tids {
            let st = mem::replace(&mut ctx.threads[tid], placeholder());
            let stream = stream_refs[tid].take().expect("each tid checked out once");
            items.push(WorkItem {
                tid,
                seed: st.clock,
                st,
                stream,
            });
        }
        work.push(items);
    }

    let (tiles, dir) = eng.caches.tiles_and_dir_mut();
    let params = &eng.params;
    // Which read walk to mirror: the bulk probe/touch walk only runs for
    // the fused default protocol; active protocols (and the per-line
    // engine mode) read via `CacheSystem::read`, sharer bit re-added on
    // every read.
    let bulk_reads = eng.page_runs && !eng.protocol_active;
    let slots = &ctx.slots[..];

    let outs: Vec<WorkerOut> = std::thread::scope(|s| {
        let mut handles = Vec::with_capacity(chunks.len());
        let mut rest = tiles;
        let mut base = 0u32;
        for (c, items) in chunks.iter().zip(work.drain(..)) {
            let (_skip, r) = rest.split_at_mut((c.tile_lo - base) as usize);
            let (mine, r2) = r.split_at_mut((c.tile_hi - c.tile_lo) as usize);
            rest = r2;
            base = c.tile_hi;
            let lo = c.tile_lo;
            handles.push(s.spawn(move || {
                phase_a_worker(mine, lo, dir, params, bulk_reads, slots, items, window_end)
            }));
        }
        handles
            .into_iter()
            .map(|h| h.join().expect("epoch worker panicked"))
            .collect()
    });

    for out in outs {
        for (tid, st) in out.states {
            ctx.threads[tid] = st;
        }
        for op in out.log {
            match op {
                DirOp::Share(line, tile) => eng.caches.directory.add_sharer(line, tile),
                DirOp::Claim(line, tile) => eng.caches.directory.claim_local(line, tile),
            }
        }
        eng.stats.line_accesses += out.delta.line_accesses;
        eng.stats.l1_hits += out.delta.l1_hits;
        eng.stats.l2_hits += out.delta.l2_hits;
        eng.stats.compute_cycles += out.delta.compute_cycles;
        for (key, tid) in out.pushes {
            ctx.heap.push(Reverse((key, tid)));
        }
        for (tid, info) in out.resume {
            ctx.resume[tid] = Some(info);
        }
    }
}

/// One worker's phase A: replay its threads off a private min-clock heap
/// until every one is done, past the window, parked, or deferred behind a
/// parked tile-mate.
#[allow(clippy::too_many_arguments)]
fn phase_a_worker(
    tiles: &mut [TileCaches],
    tile_base: u32,
    dir: &Directory,
    params: &LatencyParams,
    bulk_reads: bool,
    slots: &[Option<Region>],
    mut items: Vec<WorkItem<'_, '_>>,
    window_end: u64,
) -> WorkerOut {
    let mut out = WorkerOut {
        states: Vec::with_capacity(items.len()),
        log: Vec::new(),
        delta: StatsDelta::default(),
        pushes: Vec::new(),
        resume: Vec::new(),
    };
    let mut heap: BinaryHeap<Reverse<(u64, usize)>> = items
        .iter()
        .map(|it| Reverse((it.st.clock, it.tid)))
        .collect();
    let mut parked_tile = vec![false; tiles.len()];
    while let Some(Reverse((key, tid))) = heap.pop() {
        let i = items
            .binary_search_by_key(&tid, |it| it.tid)
            .expect("popped tid belongs to this worker");
        if items[i].st.done || items[i].st.clock != key {
            continue;
        }
        let ti = (items[i].st.tile.0 - tile_base) as usize;
        if parked_tile[ti] {
            // A tile-mate parked earlier in this window: everything at or
            // after the park point must keep sequential order (shared L1/
            // L2), so this pop is deferred unexecuted to phase B.
            if key != items[i].seed {
                out.pushes.push((key, tid));
            }
            continue;
        }
        match worker_quantum(
            &mut items[i],
            &mut tiles[ti],
            dir,
            params,
            bulk_reads,
            slots,
            &mut out.log,
            &mut out.delta,
            key,
        ) {
            QuantumEnd::Completed => {
                let it = &items[i];
                if it.st.done {
                    // Seed entry in the global heap goes stale; the pop
                    // filter skips it.
                } else if it.st.clock < window_end {
                    heap.push(Reverse((it.st.clock, it.tid)));
                } else if it.st.clock != it.seed {
                    out.pushes.push((it.st.clock, it.tid));
                }
            }
            QuantumEnd::Parked(info) => {
                parked_tile[ti] = true;
                if info.key != items[i].seed {
                    out.pushes.push((info.key, tid));
                }
                out.resume.push((tid, info));
            }
        }
    }
    for it in items {
        out.states.push((it.tid, it.st));
    }
    out
}

/// One scheduling quantum inside a worker: the engine's exact budget and
/// batch rules, with every line pre-checked to be locally decidable
/// before any mutation. The first line that is not (cache miss → the home
/// / DRAM / contention machinery; foreign sharer → invalidation fan-out)
/// parks the quantum at that exact point.
#[allow(clippy::too_many_arguments)]
fn worker_quantum(
    it: &mut WorkItem<'_, '_>,
    tc: &mut TileCaches,
    dir: &Directory,
    params: &LatencyParams,
    bulk_reads: bool,
    slots: &[Option<Region>],
    log: &mut Vec<DirOp>,
    delta: &mut StatsDelta,
    key: u64,
) -> QuantumEnd {
    let own = it.st.tile;
    let mut budget = QUANTUM_LINES;
    while budget > 0 && !it.st.done {
        let op = it.st.cur.expect("live thread must hold an op");
        let park0 = |budget| {
            QuantumEnd::Parked(ParkInfo {
                key,
                budget,
                batch_done: 0,
                batch_total: 0,
            })
        };
        match op {
            Op::Read { loc, bytes } | Op::Write { loc, bytes } => {
                let write = matches!(op, Op::Write { .. });
                let Some(addr) = resolve_loc(slots, loc) else {
                    return park0(budget);
                };
                let total = line_count(addr, bytes);
                let progress = it.st.progress;
                let batch = (total - progress).min(QUANTUM_LINES);
                let first = addr.line().0 + progress;
                for i in 0..batch {
                    let line = LineId(first + i);
                    let local = if write {
                        !dir.has_foreign_sharer(line, own)
                    } else {
                        tc.l1.contains(line) || tc.l2.contains(line)
                    };
                    if !local {
                        return QuantumEnd::Parked(ParkInfo {
                            key,
                            budget,
                            batch_done: i,
                            batch_total: if i == 0 { 0 } else { batch },
                        });
                    }
                    it.st.clock += if write {
                        write_line(tc, own, line, log, delta, params)
                    } else if bulk_reads {
                        read_line_bulk(tc, own, line, log, delta, params)
                    } else {
                        read_line_single(tc, own, line, log, delta, params)
                    };
                    delta.line_accesses += 1;
                }
                if progress + batch >= total {
                    it.st.progress = 0;
                    it.st.cur = None;
                } else {
                    it.st.progress = progress + batch;
                }
                budget = budget.saturating_sub(batch.max(1));
            }
            Op::Copy { src, dst, bytes } => {
                let (Some(s), Some(d)) = (resolve_loc(slots, src), resolve_loc(slots, dst))
                else {
                    return park0(budget);
                };
                let total = line_count(d, bytes);
                let progress = it.st.progress;
                let batch = (total - progress).min(QUANTUM_LINES / 2);
                let sfirst = s.line().0 + progress;
                let dfirst = d.line().0 + progress;
                for i in 0..batch {
                    let sl = LineId(sfirst + i);
                    let dl = LineId(dfirst + i);
                    // Pair-boundary park: check both halves before
                    // executing either (the src read cannot change the
                    // dst's foreign-sharer bits, so checking up front is
                    // sound).
                    let local = (tc.l1.contains(sl) || tc.l2.contains(sl))
                        && !dir.has_foreign_sharer(dl, own);
                    if !local {
                        return QuantumEnd::Parked(ParkInfo {
                            key,
                            budget,
                            batch_done: i,
                            batch_total: if i == 0 { 0 } else { batch },
                        });
                    }
                    // `Copy` goes through `CacheSystem::read` in both
                    // engine modes (the fast path's per-line interleave),
                    // so the single-read mirror applies unconditionally.
                    it.st.clock += read_line_single(tc, own, sl, log, delta, params);
                    it.st.clock += write_line(tc, own, dl, log, delta, params);
                    delta.line_accesses += 2;
                }
                if progress + batch >= total {
                    it.st.progress = 0;
                    it.st.cur = None;
                } else {
                    it.st.progress = progress + batch;
                }
                budget = budget.saturating_sub((batch * 2).max(1));
            }
            Op::Compute { cycles } => {
                it.st.clock += cycles;
                delta.compute_cycles += cycles;
                it.st.cur = None;
                budget = budget.saturating_sub(1);
            }
            // The scan proves phase-A threads only carry plain ops within
            // the window horizon; anything else parks defensively and
            // re-runs in phase B.
            _ => return park0(budget),
        }
        if it.st.cur.is_none() {
            it.st.cur = it.stream.next_op();
            if it.st.cur.is_none() {
                it.st.done = true;
            }
        }
    }
    QuantumEnd::Completed
}

/// Mirror of the `read_run` per-line walk (`home == req`) for a line the
/// park check proved resident: L1 probe, else L2 touch + L1 fill + share.
/// Note the bulk walk does *not* re-add the sharer bit on an L1 hit — the
/// L1-resident ⇒ sharer-bit-set invariant — which is why this differs
/// from the single-read mirror below.
#[inline]
fn read_line_bulk(
    tc: &mut TileCaches,
    own: TileId,
    line: LineId,
    log: &mut Vec<DirOp>,
    delta: &mut StatsDelta,
    params: &LatencyParams,
) -> u64 {
    if tc.l1.probe(line) {
        delta.l1_hits += 1;
        params.l1_hit
    } else {
        let hit = tc.l2.touch(line);
        debug_assert!(hit, "park check guarantees L2 residency on L1 miss");
        tc.l1.insert(line);
        log.push(DirOp::Share(line, own));
        delta.l2_hits += 1;
        params.l2_hit
    }
}

/// Mirror of `CacheSystem::read` (`home == req`) for a resident line:
/// like the bulk walk but the sharer bit is recorded on *every* read,
/// L1 hits included.
#[inline]
fn read_line_single(
    tc: &mut TileCaches,
    own: TileId,
    line: LineId,
    log: &mut Vec<DirOp>,
    delta: &mut StatsDelta,
    params: &LatencyParams,
) -> u64 {
    let cost = if tc.l1.probe(line) {
        delta.l1_hits += 1;
        params.l1_hit
    } else {
        let hit = tc.l2.probe(line);
        debug_assert!(hit, "park check guarantees L2 residency on L1 miss");
        tc.l1.insert(line);
        delta.l2_hits += 1;
        params.l2_hit
    };
    log.push(DirOp::Share(line, own));
    cost
}

/// Mirror of the `write_run` per-line walk + `bill_store_line` for a
/// locally-homed line with no foreign sharer: home L2 fill, directory
/// claim, local-store cost.
#[inline]
fn write_line(
    tc: &mut TileCaches,
    own: TileId,
    line: LineId,
    log: &mut Vec<DirOp>,
    delta: &mut StatsDelta,
    params: &LatencyParams,
) -> u64 {
    tc.l2.insert(line);
    log.push(DirOp::Claim(line, own));
    delta.l2_hits += 1;
    params.l2_hit
}
