//! Deterministic discrete-event scheduling primitives.
//!
//! The serve front-end ([`crate::serve`]) models a request pipeline —
//! arrival generator, bounded queue, dispatcher, the chip simulator as the
//! service stage — as components exchanging timestamped events. The only
//! piece they need from the simulator layer is a *deterministic* event
//! queue: a min-time priority queue whose tie-break is insertion order
//! (FIFO among same-cycle events), so a serve scenario replays the exact
//! same event sequence on every run and at every worker count.
//!
//! `std::collections::BinaryHeap` alone is not enough — it is a max-heap
//! and makes no ordering promise for equal keys — so [`EventQueue`] wraps
//! it with a reversed `(time, seq)` key. The payload type `E` needs no
//! ordering of its own.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// A timestamped event queue: `pop` returns events in non-decreasing time
/// order, with same-time events delivered in the order they were pushed.
pub struct EventQueue<E> {
    heap: BinaryHeap<Entry<E>>,
    seq: u64,
}

struct Entry<E> {
    time: u64,
    seq: u64,
    ev: E,
}

// Ordering ignores the payload entirely: the heap key is (time, seq),
// reversed so the std max-heap pops the *earliest* entry first.
impl<E> PartialEq for Entry<E> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl<E> Eq for Entry<E> {}
impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for Entry<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        (other.time, other.seq).cmp(&(self.time, self.seq))
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> EventQueue<E> {
        EventQueue {
            heap: BinaryHeap::new(),
            seq: 0,
        }
    }

    /// Schedule `ev` at absolute cycle `time`.
    pub fn at(&mut self, time: u64, ev: E) {
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(Entry { time, seq, ev });
    }

    /// Earliest pending event, FIFO among ties.
    pub fn pop(&mut self) -> Option<(u64, E)> {
        self.heap.pop().map(|e| (e.time, e.ev))
    }

    /// Timestamp of the earliest pending event.
    pub fn peek_time(&self) -> Option<u64> {
        self.heap.peek().map(|e| e.time)
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        EventQueue::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.at(30, "c");
        q.at(10, "a");
        q.at(20, "b");
        assert_eq!(q.peek_time(), Some(10));
        assert_eq!(q.pop(), Some((10, "a")));
        assert_eq!(q.pop(), Some((20, "b")));
        assert_eq!(q.pop(), Some((30, "c")));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn same_cycle_events_are_fifo() {
        let mut q = EventQueue::new();
        for i in 0..16 {
            q.at(5, i);
        }
        for i in 0..16 {
            assert_eq!(q.pop(), Some((5, i)), "tie-break must be push order");
        }
    }

    #[test]
    fn interleaved_push_pop_stays_ordered() {
        let mut q = EventQueue::new();
        q.at(4, 'x');
        q.at(1, 'y');
        assert_eq!(q.pop(), Some((1, 'y')));
        // A later push at an earlier time than the pending entry wins.
        q.at(2, 'z');
        assert_eq!(q.pop(), Some((2, 'z')));
        assert_eq!(q.pop(), Some((4, 'x')));
        assert!(q.is_empty());
        assert_eq!(q.len(), 0);
    }
}
