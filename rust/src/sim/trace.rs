//! Workload traces: the instruction set of the simulator.
//!
//! A workload (micro-benchmark, merge sort, …) is *generated* as one op
//! sequence per thread, then replayed by the engine in cycle order. Ops
//! reference dynamic allocations symbolically via slots — the address (and
//! therefore the homing!) of `new int[n]` is only known at replay time,
//! because it depends on which tile the thread occupies when the Alloc
//! executes (migrations move threads). This is precisely the mechanism the
//! paper's localisation exploits.
//!
//! Cross-thread synchronisation uses Signal/Wait events (the fork–join of
//! OpenMP nested sections); slots live in a program-global namespace so a
//! parent thread can merge arrays its children allocated (Algorithm 4),
//! with happens-before provided by the events.

use crate::mem::{AllocKind, VAddr};

/// A memory location: absolute (pre-allocated input arrays) or an offset
/// into a replay-time allocation slot.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Loc {
    Abs(VAddr),
    Slot { slot: u32, offset: u64 },
}

impl Loc {
    pub fn offset(self, bytes: u64) -> Loc {
        match self {
            Loc::Abs(a) => Loc::Abs(a.offset(bytes)),
            Loc::Slot { slot, offset } => Loc::Slot {
                slot,
                offset: offset + bytes,
            },
        }
    }
}

/// One simulated operation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Op {
    /// Sequential read of `[loc, loc+bytes)`.
    Read { loc: Loc, bytes: u64 },
    /// Sequential write of `[loc, loc+bytes)`.
    Write { loc: Loc, bytes: u64 },
    /// memcpy: per-line interleaved read of src / write of dst.
    Copy { src: Loc, dst: Loc, bytes: u64 },
    /// Pure ALU work.
    Compute { cycles: u64 },
    /// Allocate `bytes` on the thread's *current* tile into `slot`.
    Alloc {
        slot: u32,
        bytes: u64,
        kind: AllocKind,
    },
    /// Free the region in `slot` (purges caches — Algorithm 1 step 5).
    Free { slot: u32 },
    /// Signal completion event `event`.
    Signal { event: u32 },
    /// Block until `event` is signalled; clock joins to the signal time.
    Wait { event: u32 },
}

/// Builder for one thread's op list.
#[derive(Default, Clone)]
pub struct TraceBuilder {
    ops: Vec<Op>,
}

impl TraceBuilder {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn read(&mut self, loc: Loc, bytes: u64) -> &mut Self {
        if bytes > 0 {
            self.ops.push(Op::Read { loc, bytes });
        }
        self
    }

    pub fn write(&mut self, loc: Loc, bytes: u64) -> &mut Self {
        if bytes > 0 {
            self.ops.push(Op::Write { loc, bytes });
        }
        self
    }

    pub fn copy(&mut self, src: Loc, dst: Loc, bytes: u64) -> &mut Self {
        if bytes > 0 {
            self.ops.push(Op::Copy { src, dst, bytes });
        }
        self
    }

    pub fn compute(&mut self, cycles: u64) -> &mut Self {
        if cycles > 0 {
            self.ops.push(Op::Compute { cycles });
        }
        self
    }

    pub fn alloc(&mut self, slot: u32, bytes: u64, kind: AllocKind) -> &mut Self {
        self.ops.push(Op::Alloc { slot, bytes, kind });
        self
    }

    pub fn free(&mut self, slot: u32) -> &mut Self {
        self.ops.push(Op::Free { slot });
        self
    }

    pub fn signal(&mut self, event: u32) -> &mut Self {
        self.ops.push(Op::Signal { event });
        self
    }

    pub fn wait(&mut self, event: u32) -> &mut Self {
        self.ops.push(Op::Wait { event });
        self
    }

    pub fn ops(&self) -> &[Op] {
        &self.ops
    }

    pub fn into_ops(self) -> Vec<Op> {
        self.ops
    }
}

/// A complete multi-thread workload.
pub struct Program {
    pub threads: Vec<Vec<Op>>,
    pub num_slots: u32,
    pub num_events: u32,
}

#[derive(Debug)]
pub enum ProgramError {
    SlotRange {
        thread: usize,
        op: usize,
        slot: u32,
        num_slots: u32,
    },
    EventRange {
        thread: usize,
        op: usize,
        event: u32,
        num_events: u32,
    },
    DoubleSignal(u32),
}

impl std::fmt::Display for ProgramError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ProgramError::SlotRange {
                thread,
                op,
                slot,
                num_slots,
            } => write!(
                f,
                "thread {thread} op {op}: slot {slot} out of range ({num_slots})"
            ),
            ProgramError::EventRange {
                thread,
                op,
                event,
                num_events,
            } => write!(
                f,
                "thread {thread} op {op}: event {event} out of range ({num_events})"
            ),
            ProgramError::DoubleSignal(ev) => write!(f, "event {ev} signalled more than once"),
        }
    }
}

impl std::error::Error for ProgramError {}

impl Program {
    pub fn new(threads: Vec<Vec<Op>>, num_slots: u32, num_events: u32) -> Self {
        Program {
            threads,
            num_slots,
            num_events,
        }
    }

    pub fn from_builders(builders: Vec<TraceBuilder>, num_slots: u32, num_events: u32) -> Self {
        Program::new(
            builders.into_iter().map(|b| b.into_ops()).collect(),
            num_slots,
            num_events,
        )
    }

    /// Static validation: slot/event indices in range, events signalled at
    /// most once (the engine's Wait assumes single-shot events).
    pub fn validate(&self) -> Result<(), ProgramError> {
        let mut signals = vec![0u32; self.num_events as usize];
        for (t, ops) in self.threads.iter().enumerate() {
            for (i, op) in ops.iter().enumerate() {
                let check_loc = |loc: &Loc| -> Option<u32> {
                    match loc {
                        Loc::Slot { slot, .. } if *slot >= self.num_slots => Some(*slot),
                        _ => None,
                    }
                };
                let bad_slot = match op {
                    Op::Read { loc, .. } | Op::Write { loc, .. } => check_loc(loc),
                    Op::Copy { src, dst, .. } => check_loc(src).or(check_loc(dst)),
                    Op::Alloc { slot, .. } | Op::Free { slot } if *slot >= self.num_slots => {
                        Some(*slot)
                    }
                    _ => None,
                };
                if let Some(slot) = bad_slot {
                    return Err(ProgramError::SlotRange {
                        thread: t,
                        op: i,
                        slot,
                        num_slots: self.num_slots,
                    });
                }
                match op {
                    Op::Signal { event } | Op::Wait { event } => {
                        if *event >= self.num_events {
                            return Err(ProgramError::EventRange {
                                thread: t,
                                op: i,
                                event: *event,
                                num_events: self.num_events,
                            });
                        }
                        if let Op::Signal { event } = op {
                            signals[*event as usize] += 1;
                            if signals[*event as usize] > 1 {
                                return Err(ProgramError::DoubleSignal(*event));
                            }
                        }
                    }
                    _ => {}
                }
            }
        }
        Ok(())
    }

    /// Total bytes moved by Read/Write/Copy ops (for traffic reports).
    pub fn traffic_bytes(&self) -> u64 {
        self.threads
            .iter()
            .flatten()
            .map(|op| match op {
                Op::Read { bytes, .. } | Op::Write { bytes, .. } => *bytes,
                Op::Copy { bytes, .. } => 2 * bytes,
                _ => 0,
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_collects_ops_in_order() {
        let mut b = TraceBuilder::new();
        b.alloc(0, 64, AllocKind::Heap)
            .write(Loc::Slot { slot: 0, offset: 0 }, 64)
            .free(0)
            .signal(0);
        assert_eq!(b.ops().len(), 4);
        assert!(matches!(b.ops()[0], Op::Alloc { .. }));
        assert!(matches!(b.ops()[3], Op::Signal { .. }));
    }

    #[test]
    fn zero_byte_ops_elided() {
        let mut b = TraceBuilder::new();
        b.read(Loc::Abs(VAddr(0)), 0).compute(0);
        assert!(b.ops().is_empty());
    }

    #[test]
    fn loc_offset_arithmetic() {
        assert_eq!(Loc::Abs(VAddr(100)).offset(28), Loc::Abs(VAddr(128)));
        assert_eq!(
            Loc::Slot { slot: 2, offset: 8 }.offset(8),
            Loc::Slot { slot: 2, offset: 16 }
        );
    }

    #[test]
    fn validate_accepts_well_formed() {
        let mut b = TraceBuilder::new();
        b.alloc(0, 64, AllocKind::Heap).signal(0);
        let mut b2 = TraceBuilder::new();
        b2.wait(0).read(Loc::Slot { slot: 0, offset: 0 }, 64);
        let p = Program::from_builders(vec![b, b2], 1, 1);
        p.validate().unwrap();
    }

    #[test]
    fn validate_rejects_bad_slot() {
        let mut b = TraceBuilder::new();
        b.read(Loc::Slot { slot: 9, offset: 0 }, 64);
        let p = Program::from_builders(vec![b], 1, 0);
        assert!(matches!(p.validate(), Err(ProgramError::SlotRange { .. })));
    }

    #[test]
    fn validate_rejects_bad_event() {
        let mut b = TraceBuilder::new();
        b.wait(3);
        let p = Program::from_builders(vec![b], 0, 1);
        assert!(matches!(p.validate(), Err(ProgramError::EventRange { .. })));
    }

    #[test]
    fn validate_rejects_double_signal() {
        let mut b = TraceBuilder::new();
        b.signal(0).signal(0);
        let p = Program::from_builders(vec![b], 0, 1);
        assert!(matches!(p.validate(), Err(ProgramError::DoubleSignal(0))));
    }

    #[test]
    fn traffic_counts_copy_twice() {
        let mut b = TraceBuilder::new();
        b.read(Loc::Abs(VAddr(0)), 100)
            .copy(Loc::Abs(VAddr(0)), Loc::Abs(VAddr(4096)), 50);
        let p = Program::from_builders(vec![b], 0, 0);
        assert_eq!(p.traffic_bytes(), 200);
    }
}
