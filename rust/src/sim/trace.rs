//! Workload traces: the instruction set of the simulator.
//!
//! A workload (micro-benchmark, merge sort, …) is expressed as one *op
//! stream* per thread, replayed by the engine in cycle order. Streams are
//! pull-based ([`OpSource`]): generators emit ops lazily on demand, so the
//! simulable problem size is bounded by the simulated memory model, not by
//! host RAM holding a materialised `Vec<Op>` per thread. A recorded
//! `Vec<Op>` remains one implementation ([`VecSource`]) — used for small
//! programs, tests, and the differential streamed-vs-recorded replay check.
//!
//! Ops reference dynamic allocations symbolically via slots — the address
//! (and therefore the homing!) of `new int[n]` is only known at replay
//! time, because it depends on which tile the thread occupies when the
//! Alloc executes (migrations move threads). This is precisely the
//! mechanism the paper's localisation exploits.
//!
//! Cross-thread synchronisation uses Signal/Wait events (the fork–join of
//! OpenMP nested sections); slots live in a program-global namespace so a
//! parent thread can merge arrays its children allocated (Algorithm 4),
//! with happens-before provided by the events.

use crate::mem::{AllocKind, VAddr};

/// A memory location: absolute (pre-allocated input arrays) or an offset
/// into a replay-time allocation slot.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Loc {
    Abs(VAddr),
    Slot { slot: u32, offset: u64 },
}

impl Loc {
    pub fn offset(self, bytes: u64) -> Loc {
        match self {
            Loc::Abs(a) => Loc::Abs(a.offset(bytes)),
            Loc::Slot { slot, offset } => Loc::Slot {
                slot,
                offset: offset + bytes,
            },
        }
    }
}

/// One simulated operation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Op {
    /// Sequential read of `[loc, loc+bytes)`.
    Read { loc: Loc, bytes: u64 },
    /// Sequential write of `[loc, loc+bytes)`.
    Write { loc: Loc, bytes: u64 },
    /// memcpy: per-line interleaved read of src / write of dst.
    Copy { src: Loc, dst: Loc, bytes: u64 },
    /// Pure ALU work.
    Compute { cycles: u64 },
    /// Allocate `bytes` on the thread's *current* tile into `slot`.
    /// `bytes == 0` is statically rejected by [`Program::validate`].
    Alloc {
        slot: u32,
        bytes: u64,
        kind: AllocKind,
    },
    /// Free the region in `slot` (purges caches — Algorithm 1 step 5).
    Free { slot: u32 },
    /// Signal completion event `event`.
    Signal { event: u32 },
    /// Block until `event` is signalled; clock joins to the signal time.
    Wait { event: u32 },
}

/// A pull-based stream of one thread's ops.
///
/// Sources must be *replayable*: after [`reset`](OpSource::reset) the exact
/// same op sequence is produced again. The engine relies on this — every
/// run streams each source twice (a validation pass, then the replay), and
/// the differential tests pin streamed == recorded.
///
/// `Send` is a supertrait so the intra-run parallel replay can hand each
/// thread's stream to a scoped worker; sources are plain data, so every
/// existing impl satisfies it for free.
pub trait OpSource: Send {
    /// The next op, or `None` when the stream is exhausted.
    fn next_op(&mut self) -> Option<Op>;

    /// Rewind to the beginning for reuse.
    fn reset(&mut self);

    /// Host bytes this source currently keeps resident for op storage
    /// (high-water of any internal buffer). Materialised sources report
    /// their whole vector; streaming sources report their small window —
    /// the number the perf bench records as "peak trace bytes".
    fn resident_bytes(&self) -> u64 {
        0
    }
}

/// A fully materialised op stream (the pre-streaming representation).
pub struct VecSource {
    ops: Vec<Op>,
    pos: usize,
}

impl VecSource {
    pub fn new(ops: Vec<Op>) -> Self {
        VecSource { ops, pos: 0 }
    }
}

impl From<Vec<Op>> for VecSource {
    fn from(ops: Vec<Op>) -> Self {
        VecSource::new(ops)
    }
}

impl OpSource for VecSource {
    fn next_op(&mut self) -> Option<Op> {
        let op = self.ops.get(self.pos).copied();
        if op.is_some() {
            self.pos += 1;
        }
        op
    }

    fn reset(&mut self) {
        self.pos = 0;
    }

    fn resident_bytes(&self) -> u64 {
        (self.ops.capacity() * std::mem::size_of::<Op>()) as u64
    }
}

/// A generator that emits ops in bounded batches. [`SegmentSource`] adapts
/// it into an [`OpSource`]: each `fill` call appends the next batch into
/// the (reused) buffer, so resident memory is one batch, not the stream.
pub trait SegmentGen {
    /// Append the next batch of ops to `out`. Return `false` once the
    /// stream is exhausted (subsequent calls must keep returning `false`).
    /// A `true` return with nothing appended is allowed (empty step).
    fn fill(&mut self, out: &mut TraceBuilder) -> bool;

    /// Rewind the generator to the beginning of its stream.
    fn rewind(&mut self);
}

/// Adapter: a [`SegmentGen`] plus a small replay buffer = an [`OpSource`].
pub struct SegmentSource<G: SegmentGen> {
    source: G,
    buf: TraceBuilder,
    pos: usize,
    done: bool,
}

impl<G: SegmentGen> SegmentSource<G> {
    pub fn new(source: G) -> Self {
        SegmentSource {
            source,
            buf: TraceBuilder::new(),
            pos: 0,
            done: false,
        }
    }

    /// Box the source for storage in a [`Program`].
    pub fn boxed(source: G) -> Box<dyn OpSource>
    where
        G: 'static,
    {
        Box::new(SegmentSource::new(source))
    }
}

impl<G: SegmentGen> OpSource for SegmentSource<G> {
    fn next_op(&mut self) -> Option<Op> {
        loop {
            if let Some(&op) = self.buf.ops().get(self.pos) {
                self.pos += 1;
                return Some(op);
            }
            if self.done {
                return None;
            }
            self.buf.clear();
            self.pos = 0;
            if !self.source.fill(&mut self.buf) {
                self.done = true;
            }
        }
    }

    fn reset(&mut self) {
        self.source.rewind();
        self.buf.clear();
        self.pos = 0;
        self.done = false;
    }

    fn resident_bytes(&self) -> u64 {
        (self.buf.capacity() * std::mem::size_of::<Op>()) as u64
    }
}

/// Builder for a batch of ops (also the sink [`SegmentGen`]s emit into).
#[derive(Default, Clone)]
pub struct TraceBuilder {
    ops: Vec<Op>,
}

impl TraceBuilder {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn read(&mut self, loc: Loc, bytes: u64) -> &mut Self {
        if bytes > 0 {
            self.ops.push(Op::Read { loc, bytes });
        }
        self
    }

    pub fn write(&mut self, loc: Loc, bytes: u64) -> &mut Self {
        if bytes > 0 {
            self.ops.push(Op::Write { loc, bytes });
        }
        self
    }

    pub fn copy(&mut self, src: Loc, dst: Loc, bytes: u64) -> &mut Self {
        if bytes > 0 {
            self.ops.push(Op::Copy { src, dst, bytes });
        }
        self
    }

    pub fn compute(&mut self, cycles: u64) -> &mut Self {
        if cycles > 0 {
            self.ops.push(Op::Compute { cycles });
        }
        self
    }

    pub fn alloc(&mut self, slot: u32, bytes: u64, kind: AllocKind) -> &mut Self {
        self.ops.push(Op::Alloc { slot, bytes, kind });
        self
    }

    pub fn free(&mut self, slot: u32) -> &mut Self {
        self.ops.push(Op::Free { slot });
        self
    }

    pub fn signal(&mut self, event: u32) -> &mut Self {
        self.ops.push(Op::Signal { event });
        self
    }

    pub fn wait(&mut self, event: u32) -> &mut Self {
        self.ops.push(Op::Wait { event });
        self
    }

    pub fn ops(&self) -> &[Op] {
        &self.ops
    }

    pub fn into_ops(self) -> Vec<Op> {
        self.ops
    }

    /// Drop the buffered ops, keeping the allocation for reuse.
    pub fn clear(&mut self) {
        self.ops.clear();
    }

    pub fn capacity(&self) -> usize {
        self.ops.capacity()
    }
}

/// A peekable view over one thread's op stream, used by the replay loop.
///
/// The intra-run parallel engine plans each epoch by *looking ahead* into
/// every thread's stream without consuming it; ops pulled for a peek are
/// parked in `ahead` and handed out by [`next_op`](Self::next_op) in order,
/// so the consumed sequence is identical whether or not any peeks happened
/// (the byte-identical-stats contract across `--intra-jobs` rests on this).
pub struct OpStream<'p> {
    src: &'p mut Box<dyn OpSource>,
    ahead: std::collections::VecDeque<Op>,
}

impl<'p> OpStream<'p> {
    pub fn new(src: &'p mut Box<dyn OpSource>) -> Self {
        OpStream {
            src,
            ahead: std::collections::VecDeque::new(),
        }
    }

    /// The next op, consuming it (look-ahead buffer first, then source).
    #[inline]
    pub fn next_op(&mut self) -> Option<Op> {
        match self.ahead.pop_front() {
            Some(op) => Some(op),
            None => self.src.next_op(),
        }
    }

    /// The op `i` positions ahead of the consumption point (0 = the op
    /// `next_op` would return), without consuming anything.
    pub fn peek(&mut self, i: usize) -> Option<Op> {
        while self.ahead.len() <= i {
            let op = self.src.next_op()?;
            self.ahead.push_back(op);
        }
        self.ahead.get(i).copied()
    }
}

/// A complete multi-thread workload: one op source per thread.
pub struct Program {
    pub threads: Vec<Box<dyn OpSource>>,
    pub num_slots: u32,
    pub num_events: u32,
}

#[derive(Debug)]
pub enum ProgramError {
    SlotRange {
        thread: usize,
        op: usize,
        slot: u32,
        num_slots: u32,
    },
    EventRange {
        thread: usize,
        op: usize,
        event: u32,
        num_events: u32,
    },
    DoubleSignal(u32),
    /// `Op::Alloc` with `bytes == 0`: the allocator has no meaningful
    /// region (and no page) to hand out, so the program is malformed.
    ZeroAlloc {
        thread: usize,
        op: usize,
        slot: u32,
    },
}

impl std::fmt::Display for ProgramError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ProgramError::SlotRange {
                thread,
                op,
                slot,
                num_slots,
            } => write!(
                f,
                "thread {thread} op {op}: slot {slot} out of range ({num_slots})"
            ),
            ProgramError::EventRange {
                thread,
                op,
                event,
                num_events,
            } => write!(
                f,
                "thread {thread} op {op}: event {event} out of range ({num_events})"
            ),
            ProgramError::DoubleSignal(ev) => write!(f, "event {ev} signalled more than once"),
            ProgramError::ZeroAlloc { thread, op, slot } => write!(
                f,
                "thread {thread} op {op}: zero-byte alloc into slot {slot}"
            ),
        }
    }
}

impl std::error::Error for ProgramError {}

impl Program {
    pub fn new(threads: Vec<Box<dyn OpSource>>, num_slots: u32, num_events: u32) -> Self {
        Program {
            threads,
            num_slots,
            num_events,
        }
    }

    /// A program over materialised op vectors ([`VecSource`] per thread).
    pub fn from_ops(threads: Vec<Vec<Op>>, num_slots: u32, num_events: u32) -> Self {
        Program::new(
            threads
                .into_iter()
                .map(|ops| Box::new(VecSource::new(ops)) as Box<dyn OpSource>)
                .collect(),
            num_slots,
            num_events,
        )
    }

    pub fn from_builders(builders: Vec<TraceBuilder>, num_slots: u32, num_events: u32) -> Self {
        Program::from_ops(
            builders.into_iter().map(|b| b.into_ops()).collect(),
            num_slots,
            num_events,
        )
    }

    /// Rewind every thread's stream to the beginning.
    pub fn reset(&mut self) {
        for t in &mut self.threads {
            t.reset();
        }
    }

    /// Materialise every stream into op vectors (the recorded form used by
    /// the differential streamed-vs-recorded test and by tooling). Resets
    /// the streams before and after.
    pub fn record(&mut self) -> Vec<Vec<Op>> {
        self.reset();
        let out = self
            .threads
            .iter_mut()
            .map(|src| {
                let mut ops = Vec::new();
                while let Some(op) = src.next_op() {
                    ops.push(op);
                }
                ops
            })
            .collect();
        self.reset();
        out
    }

    /// Static validation (one streaming pass, then rewinds): slot/event
    /// indices in range, events signalled at most once (the engine's Wait
    /// assumes single-shot events), no zero-byte allocations.
    pub fn validate(&mut self) -> Result<(), ProgramError> {
        self.reset();
        let r = Self::validate_streams(&mut self.threads, self.num_slots, self.num_events);
        self.reset();
        r
    }

    fn validate_streams(
        threads: &mut [Box<dyn OpSource>],
        num_slots: u32,
        num_events: u32,
    ) -> Result<(), ProgramError> {
        let mut signals = vec![0u32; num_events as usize];
        for (t, src) in threads.iter_mut().enumerate() {
            let mut i = 0usize;
            while let Some(op) = src.next_op() {
                let check_loc = |loc: &Loc| -> Option<u32> {
                    match loc {
                        Loc::Slot { slot, .. } if *slot >= num_slots => Some(*slot),
                        _ => None,
                    }
                };
                let bad_slot = match &op {
                    Op::Read { loc, .. } | Op::Write { loc, .. } => check_loc(loc),
                    Op::Copy { src, dst, .. } => check_loc(src).or(check_loc(dst)),
                    Op::Alloc { slot, .. } | Op::Free { slot } if *slot >= num_slots => {
                        Some(*slot)
                    }
                    _ => None,
                };
                if let Some(slot) = bad_slot {
                    return Err(ProgramError::SlotRange {
                        thread: t,
                        op: i,
                        slot,
                        num_slots,
                    });
                }
                match op {
                    Op::Alloc { slot, bytes: 0, .. } => {
                        return Err(ProgramError::ZeroAlloc {
                            thread: t,
                            op: i,
                            slot,
                        });
                    }
                    Op::Signal { event } | Op::Wait { event } => {
                        if event >= num_events {
                            return Err(ProgramError::EventRange {
                                thread: t,
                                op: i,
                                event,
                                num_events,
                            });
                        }
                        if let Op::Signal { event } = op {
                            signals[event as usize] += 1;
                            if signals[event as usize] > 1 {
                                return Err(ProgramError::DoubleSignal(event));
                            }
                        }
                    }
                    _ => {}
                }
                i += 1;
            }
        }
        Ok(())
    }

    /// Total bytes moved by Read/Write/Copy ops (for traffic reports).
    /// Streams every source once, then rewinds.
    pub fn traffic_bytes(&mut self) -> u64 {
        self.reset();
        let mut total = 0u64;
        for src in &mut self.threads {
            while let Some(op) = src.next_op() {
                total += match op {
                    Op::Read { bytes, .. } | Op::Write { bytes, .. } => bytes,
                    Op::Copy { bytes, .. } => 2 * bytes,
                    _ => 0,
                };
            }
        }
        self.reset();
        total
    }

    /// Host bytes currently resident for op storage across all threads
    /// (the streaming win: ~constant, vs the whole trace when recorded).
    pub fn resident_trace_bytes(&self) -> u64 {
        self.threads.iter().map(|t| t.resident_bytes()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_collects_ops_in_order() {
        let mut b = TraceBuilder::new();
        b.alloc(0, 64, AllocKind::Heap)
            .write(Loc::Slot { slot: 0, offset: 0 }, 64)
            .free(0)
            .signal(0);
        assert_eq!(b.ops().len(), 4);
        assert!(matches!(b.ops()[0], Op::Alloc { .. }));
        assert!(matches!(b.ops()[3], Op::Signal { .. }));
    }

    #[test]
    fn zero_byte_ops_elided() {
        let mut b = TraceBuilder::new();
        b.read(Loc::Abs(VAddr(0)), 0).compute(0);
        assert!(b.ops().is_empty());
    }

    #[test]
    fn loc_offset_arithmetic() {
        assert_eq!(Loc::Abs(VAddr(100)).offset(28), Loc::Abs(VAddr(128)));
        assert_eq!(
            Loc::Slot { slot: 2, offset: 8 }.offset(8),
            Loc::Slot { slot: 2, offset: 16 }
        );
    }

    #[test]
    fn validate_accepts_well_formed() {
        let mut b = TraceBuilder::new();
        b.alloc(0, 64, AllocKind::Heap).signal(0);
        let mut b2 = TraceBuilder::new();
        b2.wait(0).read(Loc::Slot { slot: 0, offset: 0 }, 64);
        let mut p = Program::from_builders(vec![b, b2], 1, 1);
        p.validate().unwrap();
    }

    #[test]
    fn validate_rejects_bad_slot() {
        let mut b = TraceBuilder::new();
        b.read(Loc::Slot { slot: 9, offset: 0 }, 64);
        let mut p = Program::from_builders(vec![b], 1, 0);
        assert!(matches!(p.validate(), Err(ProgramError::SlotRange { .. })));
    }

    #[test]
    fn validate_rejects_bad_event() {
        let mut b = TraceBuilder::new();
        b.wait(3);
        let mut p = Program::from_builders(vec![b], 0, 1);
        assert!(matches!(p.validate(), Err(ProgramError::EventRange { .. })));
    }

    #[test]
    fn validate_rejects_double_signal() {
        let mut b = TraceBuilder::new();
        b.signal(0).signal(0);
        let mut p = Program::from_builders(vec![b], 0, 1);
        assert!(matches!(p.validate(), Err(ProgramError::DoubleSignal(0))));
    }

    #[test]
    fn validate_rejects_zero_alloc() {
        let mut b = TraceBuilder::new();
        b.alloc(0, 0, AllocKind::Heap);
        let mut p = Program::from_builders(vec![b], 1, 0);
        assert!(matches!(
            p.validate(),
            Err(ProgramError::ZeroAlloc { thread: 0, op: 0, slot: 0 })
        ));
    }

    #[test]
    fn validate_rewinds_the_streams() {
        let mut b = TraceBuilder::new();
        b.read(Loc::Abs(VAddr(0)), 64).compute(5);
        let mut p = Program::from_builders(vec![b], 0, 0);
        p.validate().unwrap();
        // The stream must replay from the start after validation.
        assert!(matches!(p.threads[0].next_op(), Some(Op::Read { .. })));
    }

    #[test]
    fn traffic_counts_copy_twice() {
        let mut b = TraceBuilder::new();
        b.read(Loc::Abs(VAddr(0)), 100)
            .copy(Loc::Abs(VAddr(0)), Loc::Abs(VAddr(4096)), 50);
        let mut p = Program::from_builders(vec![b], 0, 0);
        assert_eq!(p.traffic_bytes(), 200);
        // Repeatable: traffic_bytes rewinds.
        assert_eq!(p.traffic_bytes(), 200);
    }

    #[test]
    fn vec_source_streams_and_resets() {
        let ops = vec![Op::Compute { cycles: 1 }, Op::Compute { cycles: 2 }];
        let mut s = VecSource::new(ops);
        assert_eq!(s.next_op(), Some(Op::Compute { cycles: 1 }));
        assert_eq!(s.next_op(), Some(Op::Compute { cycles: 2 }));
        assert_eq!(s.next_op(), None);
        s.reset();
        assert_eq!(s.next_op(), Some(Op::Compute { cycles: 1 }));
    }

    /// A batch-at-a-time counter generator for exercising SegmentSource.
    struct Counter {
        next: u64,
        limit: u64,
    }

    impl SegmentGen for Counter {
        fn fill(&mut self, out: &mut TraceBuilder) -> bool {
            if self.next >= self.limit {
                return false;
            }
            // Two ops per batch to exercise intra-batch positions.
            for _ in 0..2 {
                if self.next < self.limit {
                    self.next += 1;
                    out.compute(self.next);
                }
            }
            true
        }

        fn rewind(&mut self) {
            self.next = 0;
        }
    }

    #[test]
    fn segment_source_streams_batches_and_replays() {
        let mut s = SegmentSource::new(Counter { next: 0, limit: 5 });
        let collect = |s: &mut SegmentSource<Counter>| {
            let mut v = Vec::new();
            while let Some(op) = s.next_op() {
                v.push(op);
            }
            v
        };
        let first = collect(&mut s);
        assert_eq!(first.len(), 5);
        assert_eq!(first[4], Op::Compute { cycles: 5 });
        s.reset();
        let second = collect(&mut s);
        assert_eq!(first, second, "reset must replay the identical stream");
    }

    #[test]
    fn record_round_trips_to_vec_program() {
        let mut p = Program::new(
            vec![SegmentSource::boxed(Counter { next: 0, limit: 7 })],
            0,
            0,
        );
        let ops = p.record();
        assert_eq!(ops[0].len(), 7);
        let mut rec = Program::from_ops(ops.clone(), 0, 0);
        assert_eq!(rec.record(), ops);
        // The streamed program still replays after recording.
        assert_eq!(p.record()[0].len(), 7);
    }

    #[test]
    fn streaming_resident_bytes_stay_small() {
        let mut p = Program::new(
            vec![SegmentSource::boxed(Counter { next: 0, limit: 10_000 })],
            0,
            0,
        );
        let n = p.record()[0].len();
        assert_eq!(n, 10_000);
        // The source buffered only a batch (2 ops) at a time.
        let materialised = (n * std::mem::size_of::<Op>()) as u64;
        assert!(
            p.resident_trace_bytes() < materialised / 100,
            "streamed window {} vs materialised {materialised}",
            p.resident_trace_bytes()
        );
    }
}
