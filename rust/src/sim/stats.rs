//! Run statistics: everything the experiment harness reports.

use crate::arch::{LatencyParams, CLOCK_HZ};
use crate::util::json::Json;

#[derive(Clone, Debug)]
pub struct RunStats {
    /// Clock the run's machine converts cycles to seconds at
    /// (`LatencyParams::clock_hz`, set by the engine). Defaults to the
    /// paper platform's 860 MHz, so stats constructed outside an engine —
    /// and every pinned tilepro64 record — keep the historical conversion;
    /// emitted in JSON only when it deviates.
    pub clock_hz: f64,
    /// Wall time of the parallel run = max over threads of finish time.
    pub makespan_cycles: u64,
    pub thread_cycles: Vec<u64>,
    pub line_accesses: u64,
    pub l1_hits: u64,
    pub l2_hits: u64,
    /// Remote-home "L3" hits.
    pub home_hits: u64,
    pub ddr_accesses: u64,
    pub invalidations: u64,
    pub migrations: u64,
    pub home_queue_cycles: u64,
    pub ctrl_queue_cycles: u64,
    /// Total queueing cycles spent waiting for directional mesh links on
    /// *forward* (request-class) traversals (zero when link contention is
    /// not modelled).
    pub link_queue_cycles: u64,
    /// Cycles billed to reply-path traversals — the data/ack response
    /// route, wormhole-pipelined (zero unless coherence-link billing ran).
    pub reply_link_cycles: u64,
    /// Link-queueing cycles billed to invalidation fan-out + ack routes
    /// (zero unless coherence-link billing ran).
    pub invalidation_link_cycles: u64,
    /// Ownership upgrades a non-default protocol performed: MESI/MOESI
    /// silent E→M writes plus MSI S→M upgrade round trips. Zero — and
    /// absent from JSON — under the default write-invalidate protocol.
    pub upgrade_hits: u64,
    /// Reads served by a dirty owner forwarding the line directly to the
    /// requestor (MOESI O-state serves). Same zero/absent contract.
    pub owner_replies: u64,
    /// Link-queueing cycles billed to write-update data fan-out. Same
    /// zero/absent contract.
    pub update_fanout_cycles: u64,
    pub compute_cycles: u64,
    pub allocs: u64,
    pub frees: u64,
    /// Remote requests served by each tile's home port (`num_tiles`
    /// entries) — the hot-spot heatmap of `metrics::home_heatmap`.
    pub tile_home_requests: Vec<u64>,
    /// Per-directed-link traffic counts (`4 * num_tiles` entries indexed
    /// by `Machine::link_index`) — the hottest-link heatmap. **Empty when
    /// link contention was not modelled**, which also keeps the JSON of
    /// link-free runs byte-identical to the pre-link-model record.
    pub link_requests: Vec<u64>,
    /// Per-directed-link reply-class traffic (data/ack responses). Same
    /// indexing and same emptiness contract as `link_requests`; all-zero
    /// when links were modelled but coherence billing was off.
    pub link_reply_requests: Vec<u64>,
    /// Per-directed-link invalidation-class traffic (fan-out + acks).
    pub link_inval_requests: Vec<u64>,
    /// Why a requested `--intra-jobs N` (N > 1) run stayed sequential, if
    /// it did — e.g. a dynamic scheduler or the caches-off mode. `None`
    /// when parallel replay engaged or was never requested. Diagnostic
    /// only: **never serialized**, so stats JSON stays byte-identical
    /// across worker counts (the `prop_intra_run` contract).
    pub intra_demoted: Option<&'static str>,
}

impl Default for RunStats {
    fn default() -> Self {
        RunStats {
            clock_hz: CLOCK_HZ,
            makespan_cycles: 0,
            thread_cycles: Vec::new(),
            line_accesses: 0,
            l1_hits: 0,
            l2_hits: 0,
            home_hits: 0,
            ddr_accesses: 0,
            invalidations: 0,
            migrations: 0,
            home_queue_cycles: 0,
            ctrl_queue_cycles: 0,
            link_queue_cycles: 0,
            reply_link_cycles: 0,
            invalidation_link_cycles: 0,
            upgrade_hits: 0,
            owner_replies: 0,
            update_fanout_cycles: 0,
            compute_cycles: 0,
            allocs: 0,
            frees: 0,
            tile_home_requests: Vec::new(),
            link_requests: Vec::new(),
            link_reply_requests: Vec::new(),
            link_inval_requests: Vec::new(),
            intra_demoted: None,
        }
    }
}

impl RunStats {
    /// Simulated wall seconds at the run's machine clock (860 MHz on the
    /// paper baseline; 600 MHz on epiphany16, per arXiv:1704.08343).
    pub fn seconds(&self) -> f64 {
        self.makespan_cycles as f64 / self.clock_hz
    }

    pub fn seconds_with(&self, params: &LatencyParams) -> f64 {
        params.cycles_to_seconds(self.makespan_cycles)
    }

    /// Fraction of line accesses satisfied in the requester's own caches.
    pub fn local_hit_rate(&self) -> f64 {
        if self.line_accesses == 0 {
            return 0.0;
        }
        (self.l1_hits + self.l2_hits) as f64 / self.line_accesses as f64
    }

    pub fn ddr_rate(&self) -> f64 {
        if self.line_accesses == 0 {
            return 0.0;
        }
        self.ddr_accesses as f64 / self.line_accesses as f64
    }

    /// Whether link contention was modelled for this run.
    pub fn links_modelled(&self) -> bool {
        !self.link_requests.is_empty()
    }

    /// The mesh-saturation signal the falseshare sweep reports: queueing
    /// on forward routes plus queueing on invalidation fan-out routes.
    pub fn coherence_link_cycles(&self) -> u64 {
        self.link_queue_cycles + self.invalidation_link_cycles
    }

    /// Index and request count of the busiest directed link, if any saw
    /// traffic (label it via `Machine::link_label`).
    pub fn hottest_link(&self) -> Option<(usize, u64)> {
        self.link_requests
            .iter()
            .copied()
            .enumerate()
            .max_by_key(|&(ix, n)| (n, std::cmp::Reverse(ix)))
            .filter(|&(_, n)| n > 0)
    }

    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("makespan_cycles", Json::num(self.makespan_cycles as f64)),
            ("seconds", Json::num(self.seconds())),
        ];
        // The clock only appears when it deviates from the paper
        // platform's 860 MHz: pinned tilepro64 records keep their bytes.
        if self.clock_hz != CLOCK_HZ {
            fields.push(("clock_hz", Json::num(self.clock_hz)));
        }
        fields.extend([
            ("line_accesses", Json::num(self.line_accesses as f64)),
            ("l1_hits", Json::num(self.l1_hits as f64)),
            ("l2_hits", Json::num(self.l2_hits as f64)),
            ("home_hits", Json::num(self.home_hits as f64)),
            ("ddr_accesses", Json::num(self.ddr_accesses as f64)),
            ("invalidations", Json::num(self.invalidations as f64)),
            ("migrations", Json::num(self.migrations as f64)),
            ("home_queue_cycles", Json::num(self.home_queue_cycles as f64)),
            ("ctrl_queue_cycles", Json::num(self.ctrl_queue_cycles as f64)),
            ("compute_cycles", Json::num(self.compute_cycles as f64)),
            ("allocs", Json::num(self.allocs as f64)),
            ("frees", Json::num(self.frees as f64)),
            (
                "tile_home_requests",
                Json::arr(self.tile_home_requests.iter().map(|&n| Json::num(n as f64))),
            ),
        ]);
        // Link fields only exist when the run modelled link contention:
        // runs without it (including the pinned tilepro64 paper baseline)
        // keep their pre-link-model JSON bytes.
        if self.links_modelled() {
            fields.push(("link_queue_cycles", Json::num(self.link_queue_cycles as f64)));
            fields.push((
                "link_requests_total",
                Json::num(self.link_requests.iter().sum::<u64>() as f64),
            ));
            let (hot_ix, hot_n) = self.hottest_link().unwrap_or((0, 0));
            fields.push((
                "hottest_link",
                Json::obj(vec![
                    ("index", Json::num(hot_ix as f64)),
                    ("requests", Json::num(hot_n as f64)),
                ]),
            ));
            // Coherence-traffic classes (all-zero when --no-coherence-links).
            fields.push((
                "reply_link_cycles",
                Json::num(self.reply_link_cycles as f64),
            ));
            fields.push((
                "invalidation_link_cycles",
                Json::num(self.invalidation_link_cycles as f64),
            ));
            fields.push((
                "link_reply_total",
                Json::num(self.link_reply_requests.iter().sum::<u64>() as f64),
            ));
            fields.push((
                "link_inval_total",
                Json::num(self.link_inval_requests.iter().sum::<u64>() as f64),
            ));
        }
        // Per-protocol counters appear only when a non-default protocol
        // actually produced them: every pinned default-protocol record —
        // with or without link modelling — keeps its bytes.
        if self.upgrade_hits > 0 {
            fields.push(("upgrade_hits", Json::num(self.upgrade_hits as f64)));
        }
        if self.owner_replies > 0 {
            fields.push(("owner_replies", Json::num(self.owner_replies as f64)));
        }
        if self.update_fanout_cycles > 0 {
            fields.push((
                "update_fanout_cycles",
                Json::num(self.update_fanout_cycles as f64),
            ));
        }
        Json::obj(fields)
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        let links = if self.links_modelled() {
            format!(
                " link {} reply {} inval-link {}",
                self.link_queue_cycles, self.reply_link_cycles, self.invalidation_link_cycles
            )
        } else {
            String::new()
        };
        let mut proto = String::new();
        if self.upgrade_hits > 0 {
            proto.push_str(&format!(" upgrades {}", self.upgrade_hits));
        }
        if self.owner_replies > 0 {
            proto.push_str(&format!(" owner-replies {}", self.owner_replies));
        }
        if self.update_fanout_cycles > 0 {
            proto.push_str(&format!(" update-fanout {}", self.update_fanout_cycles));
        }
        format!(
            "{:.3} ms | {} accesses | hits L1 {:.1}% L2 {:.1}% home {:.1}% ddr {:.1}% | {} inval | {} migr | queue home {} ctrl {}{}{proto}",
            self.seconds() * 1e3,
            self.line_accesses,
            pct(self.l1_hits, self.line_accesses),
            pct(self.l2_hits, self.line_accesses),
            pct(self.home_hits, self.line_accesses),
            pct(self.ddr_accesses, self.line_accesses),
            self.invalidations,
            self.migrations,
            self.home_queue_cycles,
            self.ctrl_queue_cycles,
            links,
        )
    }
}

fn pct(n: u64, d: u64) -> f64 {
    if d == 0 {
        0.0
    } else {
        100.0 * n as f64 / d as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seconds_at_clock() {
        let s = RunStats {
            makespan_cycles: 860_000,
            ..Default::default()
        };
        assert!((s.seconds() - 1e-3).abs() < 1e-12);
    }

    #[test]
    fn per_machine_clock_changes_seconds_and_json() {
        // The same cycle count is more wall time at the Epiphany's
        // 600 MHz, and the deviating clock is recorded in the JSON.
        let s = RunStats {
            makespan_cycles: 600_000_000,
            clock_hz: 600.0e6,
            ..Default::default()
        };
        assert!((s.seconds() - 1.0).abs() < 1e-12);
        assert_eq!(s.to_json().get("clock_hz").unwrap().encode(), "600000000");
        // Default (860 MHz) stats keep their pre-clock JSON bytes.
        let baseline = RunStats {
            makespan_cycles: 860_000,
            ..Default::default()
        };
        assert!(baseline.to_json().get("clock_hz").is_none());
        assert!((baseline.seconds() - 1e-3).abs() < 1e-12);
    }

    #[test]
    fn hit_rates() {
        let s = RunStats {
            line_accesses: 100,
            l1_hits: 50,
            l2_hits: 25,
            ddr_accesses: 10,
            ..Default::default()
        };
        assert!((s.local_hit_rate() - 0.75).abs() < 1e-12);
        assert!((s.ddr_rate() - 0.10).abs() < 1e-12);
    }

    #[test]
    fn empty_stats_rates_are_zero() {
        let s = RunStats::default();
        assert_eq!(s.local_hit_rate(), 0.0);
        assert_eq!(s.ddr_rate(), 0.0);
    }

    #[test]
    fn json_has_all_keys() {
        let j = RunStats::default().to_json();
        for k in ["makespan_cycles", "seconds", "migrations", "ddr_accesses"] {
            assert!(j.get(k).is_some(), "missing {k}");
        }
    }

    #[test]
    fn link_fields_only_when_modelled() {
        let plain = RunStats::default().to_json();
        assert!(plain.get("link_queue_cycles").is_none());
        let s = RunStats {
            link_queue_cycles: 7,
            link_requests: vec![0, 3, 1, 3],
            ..Default::default()
        };
        let j = s.to_json();
        assert!(j.get("link_queue_cycles").is_some());
        assert!(j.get("hottest_link").is_some());
        assert!(j.get("reply_link_cycles").is_some());
        assert!(j.get("invalidation_link_cycles").is_some());
        // Ties break towards the lowest index.
        assert_eq!(s.hottest_link(), Some((1, 3)));
        assert!(s.summary().contains("link 7"));
    }

    #[test]
    fn coherence_fields_follow_the_link_gate() {
        // Baseline (no links modelled): the coherence fields must not leak
        // into the pinned figure JSON.
        let plain = RunStats {
            reply_link_cycles: 5,
            invalidation_link_cycles: 9,
            ..Default::default()
        };
        let j = plain.to_json();
        assert!(j.get("reply_link_cycles").is_none());
        assert!(j.get("invalidation_link_cycles").is_none());
        assert_eq!(plain.coherence_link_cycles(), 9);
        let linked = RunStats {
            link_queue_cycles: 4,
            invalidation_link_cycles: 9,
            link_requests: vec![1, 0, 0, 0],
            link_inval_requests: vec![0, 2, 0, 0],
            ..Default::default()
        };
        assert_eq!(linked.coherence_link_cycles(), 13);
        assert_eq!(
            linked.to_json().get("link_inval_total").unwrap().encode(),
            "2"
        );
        assert!(linked.summary().contains("inval-link 9"));
    }

    #[test]
    fn protocol_counters_gated_on_nonzero() {
        // Default-protocol stats (all three zero) keep their bytes even
        // when links were modelled.
        let plain = RunStats {
            link_requests: vec![1, 0, 0, 0],
            ..Default::default()
        };
        let j = plain.to_json();
        assert!(j.get("upgrade_hits").is_none());
        assert!(j.get("owner_replies").is_none());
        assert!(j.get("update_fanout_cycles").is_none());
        let s = RunStats {
            upgrade_hits: 3,
            owner_replies: 2,
            update_fanout_cycles: 11,
            ..Default::default()
        };
        let j = s.to_json();
        assert_eq!(j.get("upgrade_hits").unwrap().encode(), "3");
        assert_eq!(j.get("owner_replies").unwrap().encode(), "2");
        assert_eq!(j.get("update_fanout_cycles").unwrap().encode(), "11");
        let line = s.summary();
        assert!(line.contains("upgrades 3"));
        assert!(line.contains("owner-replies 2"));
        assert!(line.contains("update-fanout 11"));
        assert!(!plain.summary().contains("upgrades"));
    }

    #[test]
    fn intra_demotion_never_serializes() {
        // The demotion note is a CLI diagnostic; if it leaked into the
        // JSON, a demoted run's record would differ from the same run at
        // `--intra-jobs 1`, breaking the byte-identity contract.
        let s = RunStats {
            intra_demoted: Some("dynamic scheduler"),
            ..Default::default()
        };
        assert_eq!(s.to_json().encode(), RunStats::default().to_json().encode());
        assert!(!s.summary().contains("dynamic scheduler"));
    }

    #[test]
    fn hottest_link_none_when_idle() {
        let s = RunStats {
            link_requests: vec![0; 8],
            ..Default::default()
        };
        assert_eq!(s.hottest_link(), None);
        assert!(s.to_json().get("link_queue_cycles").is_some());
    }
}
