//! Run statistics: everything the experiment harness reports.

use crate::arch::{LatencyParams, CLOCK_HZ};
use crate::util::json::Json;

#[derive(Clone, Debug, Default)]
pub struct RunStats {
    /// Wall time of the parallel run = max over threads of finish time.
    pub makespan_cycles: u64,
    pub thread_cycles: Vec<u64>,
    pub line_accesses: u64,
    pub l1_hits: u64,
    pub l2_hits: u64,
    /// Remote-home "L3" hits.
    pub home_hits: u64,
    pub ddr_accesses: u64,
    pub invalidations: u64,
    pub migrations: u64,
    pub home_queue_cycles: u64,
    pub ctrl_queue_cycles: u64,
    pub compute_cycles: u64,
    pub allocs: u64,
    pub frees: u64,
    /// Remote requests served by each tile's home port (64 entries) — the
    /// hot-spot heatmap of `metrics::heatmap`.
    pub tile_home_requests: Vec<u64>,
}

impl RunStats {
    pub fn seconds(&self) -> f64 {
        self.makespan_cycles as f64 / CLOCK_HZ
    }

    pub fn seconds_with(&self, params: &LatencyParams) -> f64 {
        params.cycles_to_seconds(self.makespan_cycles)
    }

    /// Fraction of line accesses satisfied in the requester's own caches.
    pub fn local_hit_rate(&self) -> f64 {
        if self.line_accesses == 0 {
            return 0.0;
        }
        (self.l1_hits + self.l2_hits) as f64 / self.line_accesses as f64
    }

    pub fn ddr_rate(&self) -> f64 {
        if self.line_accesses == 0 {
            return 0.0;
        }
        self.ddr_accesses as f64 / self.line_accesses as f64
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("makespan_cycles", Json::num(self.makespan_cycles as f64)),
            ("seconds", Json::num(self.seconds())),
            ("line_accesses", Json::num(self.line_accesses as f64)),
            ("l1_hits", Json::num(self.l1_hits as f64)),
            ("l2_hits", Json::num(self.l2_hits as f64)),
            ("home_hits", Json::num(self.home_hits as f64)),
            ("ddr_accesses", Json::num(self.ddr_accesses as f64)),
            ("invalidations", Json::num(self.invalidations as f64)),
            ("migrations", Json::num(self.migrations as f64)),
            ("home_queue_cycles", Json::num(self.home_queue_cycles as f64)),
            ("ctrl_queue_cycles", Json::num(self.ctrl_queue_cycles as f64)),
            ("compute_cycles", Json::num(self.compute_cycles as f64)),
            ("allocs", Json::num(self.allocs as f64)),
            ("frees", Json::num(self.frees as f64)),
            (
                "tile_home_requests",
                Json::arr(self.tile_home_requests.iter().map(|&n| Json::num(n as f64))),
            ),
        ])
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        format!(
            "{:.3} ms | {} accesses | hits L1 {:.1}% L2 {:.1}% home {:.1}% ddr {:.1}% | {} inval | {} migr | queue home {} ctrl {}",
            self.seconds() * 1e3,
            self.line_accesses,
            pct(self.l1_hits, self.line_accesses),
            pct(self.l2_hits, self.line_accesses),
            pct(self.home_hits, self.line_accesses),
            pct(self.ddr_accesses, self.line_accesses),
            self.invalidations,
            self.migrations,
            self.home_queue_cycles,
            self.ctrl_queue_cycles,
        )
    }
}

fn pct(n: u64, d: u64) -> f64 {
    if d == 0 {
        0.0
    } else {
        100.0 * n as f64 / d as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seconds_at_clock() {
        let s = RunStats {
            makespan_cycles: 860_000,
            ..Default::default()
        };
        assert!((s.seconds() - 1e-3).abs() < 1e-12);
    }

    #[test]
    fn hit_rates() {
        let s = RunStats {
            line_accesses: 100,
            l1_hits: 50,
            l2_hits: 25,
            ddr_accesses: 10,
            ..Default::default()
        };
        assert!((s.local_hit_rate() - 0.75).abs() < 1e-12);
        assert!((s.ddr_rate() - 0.10).abs() < 1e-12);
    }

    #[test]
    fn empty_stats_rates_are_zero() {
        let s = RunStats::default();
        assert_eq!(s.local_hit_rate(), 0.0);
        assert_eq!(s.ddr_rate(), 0.0);
    }

    #[test]
    fn json_has_all_keys() {
        let j = RunStats::default().to_json();
        for k in ["makespan_cycles", "seconds", "migrations", "ddr_accesses"] {
            assert!(j.get(k).is_some(), "missing {k}");
        }
    }
}
