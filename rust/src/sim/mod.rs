//! Cycle-approximate replay simulation: traces, the engine, run stats, and
//! the discrete-event primitives the serve front-end schedules with.

pub mod devent;
pub mod engine;
pub(crate) mod epoch;
pub mod stats;
pub mod trace;

pub use devent::EventQueue;
pub use engine::{plan_intra_workers, Engine, EngineConfig, EngineError};
pub use stats::RunStats;
pub use trace::{
    Loc, Op, OpSource, OpStream, Program, ProgramError, SegmentGen, SegmentSource, TraceBuilder,
    VecSource,
};
