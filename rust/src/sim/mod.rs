//! Cycle-approximate replay simulation: traces, the engine, and run stats.

pub mod engine;
pub mod stats;
pub mod trace;

pub use engine::{Engine, EngineConfig, EngineError};
pub use stats::RunStats;
pub use trace::{
    Loc, Op, OpSource, Program, ProgramError, SegmentGen, SegmentSource, TraceBuilder, VecSource,
};
