//! Cycle-approximate replay simulation: traces, the engine, and run stats.

pub mod engine;
pub(crate) mod epoch;
pub mod stats;
pub mod trace;

pub use engine::{plan_intra_workers, Engine, EngineConfig, EngineError};
pub use stats::RunStats;
pub use trace::{
    Loc, Op, OpSource, OpStream, Program, ProgramError, SegmentGen, SegmentSource, TraceBuilder,
    VecSource,
};
