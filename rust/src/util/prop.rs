//! Lightweight property-testing driver (no proptest in the offline env).
//!
//! `check` runs a property over many seeded random cases and, on failure,
//! reports the seed so the case replays deterministically:
//!
//! ```ignore
//! prop::check("sorted output", 256, |rng| {
//!     let xs = rng.i32_vec(rng.below(100) as usize);
//!     let ys = sort(&xs);
//!     prop::assert_holds(is_sorted(&ys), "not sorted")
//! });
//! ```
//!
//! No shrinking — cases are generated small-biased instead (sizes drawn from
//! a distribution weighted toward edge sizes 0/1/2), which in practice keeps
//! counterexamples readable.

use crate::util::rng::Rng;

pub type PropResult = Result<(), String>;

pub fn assert_holds(cond: bool, msg: &str) -> PropResult {
    if cond {
        Ok(())
    } else {
        Err(msg.to_string())
    }
}

pub fn assert_eq_dbg<T: PartialEq + std::fmt::Debug>(a: T, b: T, what: &str) -> PropResult {
    if a == b {
        Ok(())
    } else {
        Err(format!("{what}: {a:?} != {b:?}"))
    }
}

/// Run `cases` seeded random trials of `prop`. Panics (test failure) with
/// the failing seed embedded in the message.
pub fn check<F>(name: &str, cases: u64, mut prop: F)
where
    F: FnMut(&mut Rng) -> PropResult,
{
    // Base seed can be pinned via TILESIM_PROP_SEED to replay a failure.
    let base = std::env::var("TILESIM_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE_u64);
    for case in 0..cases {
        let seed = base.wrapping_add(case.wrapping_mul(0x9E3779B97F4A7C15));
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property '{name}' failed on case {case} (replay with TILESIM_PROP_SEED={base}, \
                 case seed {seed}): {msg}"
            );
        }
    }
}

/// Size generator biased toward edge cases: 0, 1, 2 appear often; the rest
/// is log-uniform up to `max`.
pub fn size_biased(rng: &mut Rng, max: usize) -> usize {
    match rng.below(8) {
        0 => 0,
        1 => 1,
        2 => 2,
        3 => max,
        _ => {
            if max < 2 {
                return max;
            }
            let bits = 64 - (max as u64).leading_zeros() as u64;
            let b = rng.below(bits) + 1;
            (rng.below((1u64 << b).min(max as u64)) as usize).min(max)
        }
    }
}

/// Power-of-two size up to `max` (the bitonic/merge workloads need these).
pub fn pow2_biased(rng: &mut Rng, max_log2: u32) -> usize {
    1usize << rng.below(max_log2 as u64 + 1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_completes() {
        check("tautology", 50, |_| Ok(()));
    }

    #[test]
    #[should_panic(expected = "property 'contradiction' failed")]
    fn failing_property_panics_with_seed() {
        check("contradiction", 5, |_| Err("nope".into()));
    }

    #[test]
    fn size_biased_in_range() {
        let mut rng = Rng::new(1);
        for _ in 0..1000 {
            assert!(size_biased(&mut rng, 100) <= 100);
        }
    }

    #[test]
    fn size_biased_hits_edges() {
        let mut rng = Rng::new(2);
        let sizes: Vec<usize> = (0..200).map(|_| size_biased(&mut rng, 50)).collect();
        assert!(sizes.contains(&0));
        assert!(sizes.contains(&1));
        assert!(sizes.contains(&50));
    }

    #[test]
    fn pow2_is_power_of_two() {
        let mut rng = Rng::new(3);
        for _ in 0..100 {
            let n = pow2_biased(&mut rng, 10);
            assert!(n.is_power_of_two() && n <= 1024);
        }
    }
}
