//! Deterministic PRNG (SplitMix64 seeding + xoshiro256**).
//!
//! The offline build has no `rand` crate, and determinism is a feature here
//! anyway: every simulated experiment (thread migrations, workload data,
//! hash-for-home line placement) must replay bit-identically from a seed so
//! EXPERIMENTS.md numbers are reproducible.

/// SplitMix64: used to expand a single u64 seed into xoshiro state.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256** — fast, high-quality, 256-bit state.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed deterministically; two `Rng`s from the same seed are identical.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent stream (e.g. one per simulated thread).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0xA076_1D64_78BD_642F))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, bound)` via Lemire's multiply-shift (unbiased enough
    /// for simulation purposes; bound must be non-zero).
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo < hi);
        lo + self.below(hi - lo)
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli trial.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Random i32 (full range) — workload data generator.
    #[inline]
    pub fn i32(&mut self) -> i32 {
        self.next_u32() as i32
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Vector of random i32s — the standard workload input.
    pub fn i32_vec(&mut self, n: usize) -> Vec<i32> {
        (0..n).map(|_| self.i32()).collect()
    }
}

/// Stateless 64-bit mix used for hash-for-home line placement: must be a
/// pure function of the line address (the hardware hashes the physical
/// address), not of any RNG stream.
#[inline]
pub fn mix64(mut z: u64) -> u64 {
    z = (z ^ (z >> 33)).wrapping_mul(0xFF51_AFD7_ED55_8CCD);
    z = (z ^ (z >> 33)).wrapping_mul(0xC4CE_B9FE_1A85_EC53);
    z ^ (z >> 33)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn below_respects_bound() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            assert!(r.below(13) < 13);
        }
    }

    #[test]
    fn below_hits_all_residues() {
        let mut r = Rng::new(11);
        let mut seen = [false; 8];
        for _ in 0..1_000 {
            seen[r.below(8) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn chance_extremes() {
        let mut r = Rng::new(5);
        assert!(!(0..100).any(|_| r.chance(0.0)));
        assert!((0..100).all(|_| r.chance(1.0)));
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Rng::new(9);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let av: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let bv: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(av, bv);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(13);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn mix64_is_pure_and_spreads() {
        assert_eq!(mix64(12345), mix64(12345));
        // Consecutive addresses should land on different residues mod 64
        // reasonably often (hash-for-home decentralisation).
        let mut seen = std::collections::HashSet::new();
        for line in 0u64..64 {
            seen.insert(mix64(line) % 64);
        }
        assert!(seen.len() > 32, "hash too clumpy: {}", seen.len());
    }

    #[test]
    fn chance_is_calibrated() {
        let mut r = Rng::new(21);
        let hits = (0..100_000).filter(|_| r.chance(0.25)).count();
        let frac = hits as f64 / 100_000.0;
        assert!((frac - 0.25).abs() < 0.01, "p=0.25 measured {frac}");
    }
}
