//! Minimal JSON: a value tree, an emitter, and a recursive-descent parser.
//!
//! Purpose-built because the offline environment has no serde. Scope: what
//! this repo needs — parsing `artifacts/manifest.json` and emitting
//! experiment/bench reports. Supports the full JSON grammar except `\u`
//! surrogate pairs beyond the BMP.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn num<T: Into<f64>>(n: T) -> Json {
        Json::Num(n.into())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Compact single-line encoding.
    pub fn encode(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.encode())
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

#[derive(Debug)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

pub fn parse(text: &str) -> Result<Json, ParseError> {
    let mut p = Parser {
        bytes: text.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing data"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, ParseError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Copy a full UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip_scalar() {
        for text in ["null", "true", "false", "42", "-3.5", "\"hi\""] {
            let v = parse(text).unwrap();
            assert_eq!(parse(&v.encode()).unwrap(), v);
        }
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
    }

    #[test]
    fn parse_manifest_shape() {
        let text = r#"{"artifacts":[{"name":"full_sort","file":"full_sort.hlo.txt",
            "inputs":[{"shape":[64,1024],"dtype":"int32"}],"sha256":"ab","bytes":7}]}"#;
        let v = parse(text).unwrap();
        let a = &v.get("artifacts").unwrap().as_arr().unwrap()[0];
        assert_eq!(a.get("name").unwrap().as_str().unwrap(), "full_sort");
        let shape = a.get("inputs").unwrap().as_arr().unwrap()[0]
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|j| j.as_usize().unwrap())
            .collect::<Vec<_>>();
        assert_eq!(shape, vec![64, 1024]);
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(parse("1 2").is_err());
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
    }

    #[test]
    fn encode_escapes() {
        let v = Json::str("a\"b\\c\nd");
        assert_eq!(parse(&v.encode()).unwrap(), v);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(parse(r#""A""#).unwrap(), Json::str("A"));
    }

    #[test]
    fn integers_encode_without_fraction() {
        assert_eq!(Json::num(5.0).encode(), "5");
        assert_eq!(Json::num(5.5).encode(), "5.5");
    }

    #[test]
    fn obj_helpers() {
        let v = Json::obj(vec![("x", Json::num(1.0)), ("y", Json::str("z"))]);
        assert_eq!(v.get("x").unwrap().as_usize().unwrap(), 1);
        assert!(v.get("missing").is_none());
    }
}
