//! Tiny CLI argument parser (no clap in the offline environment).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional args.
//! Unknown flags are an error so typos in experiment sweeps fail loudly
//! instead of silently running the wrong configuration. [`TargetSpec`] is
//! the shared resolution of the target-selection flags (`--machine`,
//! `--fabric`, `--protocol`, link billing) with one conflict-error path.

use crate::arch::{FabricSpec, MachineSpec};
use crate::coherence::ProtocolSpec;
use std::collections::BTreeMap;

#[derive(Debug)]
pub enum CliError {
    UnknownFlag(String),
    MissingValue(String),
    BadValue(String, String),
    /// Two flags that cannot be combined — the one conflict path every
    /// target-selection error funnels through, so each message is a single
    /// line naming the offending flag(s).
    Conflict(String),
}

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CliError::UnknownFlag(name) => write!(f, "unknown flag --{name}"),
            CliError::MissingValue(name) => write!(f, "flag --{name} expects a value"),
            CliError::BadValue(name, v) => write!(f, "invalid value for --{name}: {v}"),
            CliError::Conflict(msg) => write!(f, "{msg}"),
        }
    }
}

impl std::error::Error for CliError {}

/// Declarative spec: flag names that take values vs boolean switches.
pub struct Args {
    values: BTreeMap<String, String>,
    switches: Vec<String>,
    positional: Vec<String>,
}

impl Args {
    /// Parse `argv` (without the program name). `value_flags` lists flags
    /// that consume a value; `bool_flags` are presence-only switches.
    pub fn parse(
        argv: &[String],
        value_flags: &[&str],
        bool_flags: &[&str],
    ) -> Result<Args, CliError> {
        let mut values = BTreeMap::new();
        let mut switches = Vec::new();
        let mut positional = Vec::new();
        let mut i = 0;
        while i < argv.len() {
            let arg = &argv[i];
            if let Some(stripped) = arg.strip_prefix("--") {
                let (name, inline) = match stripped.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                if bool_flags.contains(&name.as_str()) {
                    if inline.is_some() {
                        return Err(CliError::BadValue(name, "switch takes no value".into()));
                    }
                    switches.push(name);
                } else if value_flags.contains(&name.as_str()) {
                    let v = match inline {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .cloned()
                                .ok_or_else(|| CliError::MissingValue(name.clone()))?
                        }
                    };
                    values.insert(name, v);
                } else {
                    return Err(CliError::UnknownFlag(name));
                }
            } else {
                positional.push(arg.clone());
            }
            i += 1;
        }
        Ok(Args {
            values,
            switches,
            positional,
        })
    }

    pub fn flag(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn get_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn usize(&self, name: &str, default: usize) -> Result<usize, CliError> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => parse_usize(v)
                .ok_or_else(|| CliError::BadValue(name.to_string(), v.to_string())),
        }
    }

    pub fn u64(&self, name: &str, default: u64) -> Result<u64, CliError> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| CliError::BadValue(name.to_string(), v.to_string())),
        }
    }

    pub fn f64(&self, name: &str, default: f64) -> Result<f64, CliError> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| CliError::BadValue(name.to_string(), v.to_string())),
        }
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

/// The simulated target named on a command line: machine grid, optional
/// fabric overlay, link/coherence billing, and coherence protocol.
///
/// Every subcommand used to re-implement fragments of this resolution by
/// hand; [`TargetSpec::from_args`] is now the single parse + conflict path
/// for `--machine`, `--fabric`, `--protocol`, and the link-billing
/// switches, so a conflict is always a one-line [`CliError::Conflict`]
/// naming the flag instead of a silently ignored setting.
#[derive(Debug, Clone)]
pub struct TargetSpec {
    pub machine: MachineSpec,
    pub fabric: Option<FabricSpec>,
    pub link_contention: bool,
    pub coherence_links: bool,
    pub protocol: ProtocolSpec,
}

impl TargetSpec {
    /// Resolve the target from parsed args.
    ///
    /// - `--fabric` may lead with its own machine clause
    ///   (`--fabric 8x8:ctrl=corners:…`); naming the machine there *and*
    ///   in `--machine` is a conflict. Only the syntax is checked here —
    ///   whether the fabric fits the machine is validated by each
    ///   subcommand's capacity path, so ladder sweeps get to report their
    ///   own flag conflicts first.
    /// - Link contention defaults on for every machine except the
    ///   paper-baseline tilepro64 (whose published figure record predates
    ///   the link model) and whenever a fabric is applied; coherence-link
    ///   billing follows it. `--[no-]link-contention` /
    ///   `--[no-]coherence-links` override either way.
    /// - A non-default directory protocol only engages on the coherence
    ///   link servers, so it defaults the billing ON; explicitly turning
    ///   the links off underneath it is a conflict, not a silent collapse
    ///   to the default protocol. (`opaque` is exempt: home permutation
    ///   works with the links off.)
    pub fn from_args(args: &Args) -> Result<TargetSpec, CliError> {
        let machine_flag = match args.get("machine") {
            None => None,
            Some(s) => Some(
                MachineSpec::parse(s)
                    .map_err(|e| CliError::BadValue("machine".into(), e.to_string()))?,
            ),
        };
        let (fabric_machine, fabric) = match args.get("fabric") {
            None => (None, None),
            Some(s) => {
                let (m, f) = FabricSpec::parse(s)
                    .map_err(|e| CliError::BadValue("fabric".into(), e.to_string()))?
                    .split_machine();
                (m, if f.is_noop() { None } else { Some(f) })
            }
        };
        let machine = match (machine_flag, fabric_machine) {
            (Some(_), Some(_)) => {
                return Err(CliError::Conflict(
                    "--machine conflicts with the machine clause in --fabric: name the \
                     machine in one place"
                        .into(),
                ))
            }
            (Some(m), None) | (None, Some(m)) => m,
            (None, None) => MachineSpec::TilePro64,
        };
        let protocol = match args.get("protocol") {
            None => ProtocolSpec::default(),
            Some(s) => {
                ProtocolSpec::parse(s).map_err(|e| CliError::BadValue("protocol".into(), e))?
            }
        };
        let needs_links = !protocol.is_default() && !protocol.permutes_homes();
        let link_contention = if args.flag("no-link-contention") {
            false
        } else if args.flag("link-contention") || needs_links {
            true
        } else {
            machine != MachineSpec::TilePro64 || fabric.is_some()
        };
        let coherence_links = if args.flag("no-coherence-links") {
            false
        } else if args.flag("coherence-links") {
            true
        } else {
            link_contention
        };
        if needs_links && !(link_contention && coherence_links) {
            return Err(CliError::Conflict(format!(
                "--protocol {} needs coherence-link billing: drop --no-link-contention / \
                 --no-coherence-links (or use the default protocol)",
                protocol.label()
            )));
        }
        Ok(TargetSpec {
            machine,
            fabric,
            link_contention,
            coherence_links,
            protocol,
        })
    }
}

/// Accepts plain integers plus `k`/`m`/`g` suffixes (binary-ish decimal:
/// 1k = 1000) and `ki`/`mi` (1024-based), e.g. `--size 100m`.
pub fn parse_usize(s: &str) -> Option<usize> {
    let lower = s.to_ascii_lowercase();
    let (digits, mult): (&str, usize) = if let Some(d) = lower.strip_suffix("ki") {
        (d, 1 << 10)
    } else if let Some(d) = lower.strip_suffix("mi") {
        (d, 1 << 20)
    } else if let Some(d) = lower.strip_suffix("gi") {
        (d, 1 << 30)
    } else if let Some(d) = lower.strip_suffix('k') {
        (d, 1_000)
    } else if let Some(d) = lower.strip_suffix('m') {
        (d, 1_000_000)
    } else if let Some(d) = lower.strip_suffix('g') {
        (d, 1_000_000_000)
    } else {
        (lower.as_str(), 1)
    };
    digits.parse::<usize>().ok().map(|n| n * mult)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(|w| w.to_string()).collect()
    }

    #[test]
    fn parses_values_and_switches() {
        let a = Args::parse(
            &argv("--size 100m --json --threads=32 run"),
            &["size", "threads"],
            &["json"],
        )
        .unwrap();
        assert_eq!(a.usize("size", 0).unwrap(), 100_000_000);
        assert_eq!(a.usize("threads", 0).unwrap(), 32);
        assert!(a.flag("json"));
        assert_eq!(a.positional(), &["run".to_string()]);
    }

    #[test]
    fn unknown_flag_errors() {
        assert!(Args::parse(&argv("--nope"), &[], &[]).is_err());
    }

    #[test]
    fn missing_value_errors() {
        assert!(Args::parse(&argv("--size"), &["size"], &[]).is_err());
    }

    #[test]
    fn defaults_apply() {
        let a = Args::parse(&argv(""), &["size"], &[]).unwrap();
        assert_eq!(a.usize("size", 7).unwrap(), 7);
        assert_eq!(a.get_or("size", "x"), "x");
    }

    #[test]
    fn size_suffixes() {
        assert_eq!(parse_usize("64ki"), Some(65536));
        assert_eq!(parse_usize("1m"), Some(1_000_000));
        assert_eq!(parse_usize("12"), Some(12));
        assert_eq!(parse_usize("bad"), None);
    }

    #[test]
    fn bad_numeric_value_errors() {
        let a = Args::parse(&argv("--size nope"), &["size"], &[]).unwrap();
        assert!(a.usize("size", 0).is_err());
    }

    const TARGET_VALUES: &[&str] = &["machine", "fabric", "protocol"];
    const TARGET_BOOLS: &[&str] = &[
        "link-contention",
        "no-link-contention",
        "coherence-links",
        "no-coherence-links",
    ];

    fn target(s: &str) -> Result<TargetSpec, CliError> {
        TargetSpec::from_args(&Args::parse(&argv(s), TARGET_VALUES, TARGET_BOOLS).unwrap())
    }

    #[test]
    fn target_defaults_to_the_paper_baseline() {
        let t = target("").unwrap();
        assert_eq!(t.machine, MachineSpec::TilePro64);
        assert!(t.fabric.is_none());
        assert!(!t.link_contention && !t.coherence_links);
        assert!(t.protocol.is_default());
    }

    #[test]
    fn target_off_baseline_machine_turns_links_on() {
        let t = target("--machine nuca256").unwrap();
        assert!(t.link_contention && t.coherence_links);
        let t = target("--machine nuca256 --no-link-contention").unwrap();
        assert!(!t.link_contention && !t.coherence_links);
    }

    #[test]
    fn target_machine_in_two_places_is_one_conflict_line() {
        let err = target("--machine nuca256 --fabric 8x8:ctrl=corners").unwrap_err();
        assert!(matches!(err, CliError::Conflict(_)));
        assert!(err.to_string().contains("--machine"), "{err}");
    }

    #[test]
    fn target_protocol_defaults_links_on() {
        let t = target("--protocol mesi").unwrap();
        assert!(t.link_contention && t.coherence_links);
        assert_eq!(t.protocol.label(), "mesi");
        // The paper baseline stays links-off when the protocol is default.
        assert!(!target("--protocol write-invalidate").unwrap().link_contention);
    }

    #[test]
    fn target_protocol_with_links_off_is_a_conflict() {
        for flags in ["--protocol msi --no-link-contention", "--protocol moesi --no-coherence-links"]
        {
            let err = target(flags).unwrap_err();
            assert!(matches!(err, CliError::Conflict(_)), "{flags}: {err}");
            assert!(err.to_string().contains("--protocol"), "{err}");
        }
        // Opaque permutes homes without the link servers: no conflict.
        let t = target("--protocol opaque@7 --no-link-contention").unwrap();
        assert!(!t.link_contention && t.protocol.permutes_homes());
    }

    #[test]
    fn target_bad_protocol_is_a_bad_value() {
        assert!(matches!(
            target("--protocol mosi").unwrap_err(),
            CliError::BadValue(_, _)
        ));
    }
}
