//! Tiny CLI argument parser (no clap in the offline environment).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional args.
//! Unknown flags are an error so typos in experiment sweeps fail loudly
//! instead of silently running the wrong configuration.

use std::collections::BTreeMap;

#[derive(Debug)]
pub enum CliError {
    UnknownFlag(String),
    MissingValue(String),
    BadValue(String, String),
}

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CliError::UnknownFlag(name) => write!(f, "unknown flag --{name}"),
            CliError::MissingValue(name) => write!(f, "flag --{name} expects a value"),
            CliError::BadValue(name, v) => write!(f, "invalid value for --{name}: {v}"),
        }
    }
}

impl std::error::Error for CliError {}

/// Declarative spec: flag names that take values vs boolean switches.
pub struct Args {
    values: BTreeMap<String, String>,
    switches: Vec<String>,
    positional: Vec<String>,
}

impl Args {
    /// Parse `argv` (without the program name). `value_flags` lists flags
    /// that consume a value; `bool_flags` are presence-only switches.
    pub fn parse(
        argv: &[String],
        value_flags: &[&str],
        bool_flags: &[&str],
    ) -> Result<Args, CliError> {
        let mut values = BTreeMap::new();
        let mut switches = Vec::new();
        let mut positional = Vec::new();
        let mut i = 0;
        while i < argv.len() {
            let arg = &argv[i];
            if let Some(stripped) = arg.strip_prefix("--") {
                let (name, inline) = match stripped.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                if bool_flags.contains(&name.as_str()) {
                    if inline.is_some() {
                        return Err(CliError::BadValue(name, "switch takes no value".into()));
                    }
                    switches.push(name);
                } else if value_flags.contains(&name.as_str()) {
                    let v = match inline {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .cloned()
                                .ok_or_else(|| CliError::MissingValue(name.clone()))?
                        }
                    };
                    values.insert(name, v);
                } else {
                    return Err(CliError::UnknownFlag(name));
                }
            } else {
                positional.push(arg.clone());
            }
            i += 1;
        }
        Ok(Args {
            values,
            switches,
            positional,
        })
    }

    pub fn flag(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn get_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn usize(&self, name: &str, default: usize) -> Result<usize, CliError> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => parse_usize(v)
                .ok_or_else(|| CliError::BadValue(name.to_string(), v.to_string())),
        }
    }

    pub fn u64(&self, name: &str, default: u64) -> Result<u64, CliError> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| CliError::BadValue(name.to_string(), v.to_string())),
        }
    }

    pub fn f64(&self, name: &str, default: f64) -> Result<f64, CliError> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| CliError::BadValue(name.to_string(), v.to_string())),
        }
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

/// Accepts plain integers plus `k`/`m`/`g` suffixes (binary-ish decimal:
/// 1k = 1000) and `ki`/`mi` (1024-based), e.g. `--size 100m`.
pub fn parse_usize(s: &str) -> Option<usize> {
    let lower = s.to_ascii_lowercase();
    let (digits, mult): (&str, usize) = if let Some(d) = lower.strip_suffix("ki") {
        (d, 1 << 10)
    } else if let Some(d) = lower.strip_suffix("mi") {
        (d, 1 << 20)
    } else if let Some(d) = lower.strip_suffix("gi") {
        (d, 1 << 30)
    } else if let Some(d) = lower.strip_suffix('k') {
        (d, 1_000)
    } else if let Some(d) = lower.strip_suffix('m') {
        (d, 1_000_000)
    } else if let Some(d) = lower.strip_suffix('g') {
        (d, 1_000_000_000)
    } else {
        (lower.as_str(), 1)
    };
    digits.parse::<usize>().ok().map(|n| n * mult)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(|w| w.to_string()).collect()
    }

    #[test]
    fn parses_values_and_switches() {
        let a = Args::parse(
            &argv("--size 100m --json --threads=32 run"),
            &["size", "threads"],
            &["json"],
        )
        .unwrap();
        assert_eq!(a.usize("size", 0).unwrap(), 100_000_000);
        assert_eq!(a.usize("threads", 0).unwrap(), 32);
        assert!(a.flag("json"));
        assert_eq!(a.positional(), &["run".to_string()]);
    }

    #[test]
    fn unknown_flag_errors() {
        assert!(Args::parse(&argv("--nope"), &[], &[]).is_err());
    }

    #[test]
    fn missing_value_errors() {
        assert!(Args::parse(&argv("--size"), &["size"], &[]).is_err());
    }

    #[test]
    fn defaults_apply() {
        let a = Args::parse(&argv(""), &["size"], &[]).unwrap();
        assert_eq!(a.usize("size", 7).unwrap(), 7);
        assert_eq!(a.get_or("size", "x"), "x");
    }

    #[test]
    fn size_suffixes() {
        assert_eq!(parse_usize("64ki"), Some(65536));
        assert_eq!(parse_usize("1m"), Some(1_000_000));
        assert_eq!(parse_usize("12"), Some(12));
        assert_eq!(parse_usize("bad"), None);
    }

    #[test]
    fn bad_numeric_value_errors() {
        let a = Args::parse(&argv("--size nope"), &["size"], &[]).unwrap();
        assert!(a.usize("size", 0).is_err());
    }
}
