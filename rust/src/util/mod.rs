//! Shared utilities built from scratch for the offline environment:
//! deterministic PRNG, JSON, CLI parsing, and a property-test driver.

pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
