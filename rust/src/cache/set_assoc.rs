//! Generic set-associative LRU cache over line ids (timing-only: the
//! simulator tracks presence, not data).

use crate::mem::LineId;

#[derive(Clone, Copy, Debug)]
struct Way {
    tag: u64, // full line id (cheap and unambiguous)
    lru: u64, // last-touch timestamp
    valid: bool,
}

const EMPTY: Way = Way {
    tag: 0,
    lru: 0,
    valid: false,
};

/// Set-associative cache with true-LRU replacement.
pub struct SetAssoc {
    sets: usize,
    ways: usize,
    data: Vec<Way>,
    tick: u64,
    pub hits: u64,
    pub misses: u64,
}

impl SetAssoc {
    pub fn new(sets: usize, ways: usize) -> Self {
        assert!(sets.is_power_of_two(), "sets must be a power of two");
        assert!(ways >= 1);
        SetAssoc {
            sets,
            ways,
            data: vec![EMPTY; sets * ways],
            tick: 0,
            hits: 0,
            misses: 0,
        }
    }

    #[inline]
    fn set_of(&self, line: LineId) -> usize {
        (line.0 as usize) & (self.sets - 1)
    }

    #[inline]
    fn set_slice(&mut self, line: LineId) -> &mut [Way] {
        let base = self.set_of(line) * self.ways;
        &mut self.data[base..base + self.ways]
    }

    /// Probe without inserting. Hit updates LRU.
    #[inline]
    pub fn probe(&mut self, line: LineId) -> bool {
        self.tick += 1;
        let tick = self.tick;
        for slot in self.set_slice(line) {
            if slot.valid && slot.tag == line.0 {
                slot.lru = tick;
                self.hits += 1;
                return true;
            }
        }
        self.misses += 1;
        false
    }

    /// Insert (fill) a line; returns the evicted line if any. Single pass:
    /// refresh on hit, otherwise fill the best way (empty beats LRU).
    #[inline]
    pub fn insert(&mut self, line: LineId) -> Option<LineId> {
        self.tick += 1;
        let tick = self.tick;
        let slots = self.set_slice(line);
        let mut victim = 0usize;
        let mut victim_key = u64::MAX; // invalid ways compare as key 0
        for (w, slot) in slots.iter().enumerate() {
            if slot.valid && slot.tag == line.0 {
                slots[w].lru = tick;
                return None;
            }
            let key = if slot.valid { slot.lru.max(1) } else { 0 };
            if key < victim_key {
                victim_key = key;
                victim = w;
            }
        }
        let slot = &mut slots[victim];
        let evicted = if slot.valid { Some(LineId(slot.tag)) } else { None };
        *slot = Way {
            tag: line.0,
            lru: tick,
            valid: true,
        };
        evicted
    }

    /// Probe-and-fill in one set walk: on hit refresh LRU (counts a hit),
    /// on miss insert the line (counts a miss). State-equivalent to
    /// `probe(); if miss { insert(); }` — the victim choice and relative
    /// LRU order are identical — with half the set walks. This is the
    /// bulk page-run path's workhorse.
    #[inline]
    pub fn touch(&mut self, line: LineId) -> bool {
        self.tick += 1;
        let tick = self.tick;
        let slots = self.set_slice(line);
        let mut victim = 0usize;
        let mut victim_key = u64::MAX; // invalid ways compare as key 0
        let mut hit = false;
        for (w, slot) in slots.iter().enumerate() {
            if slot.valid && slot.tag == line.0 {
                victim = w;
                hit = true;
                break;
            }
            let key = if slot.valid { slot.lru.max(1) } else { 0 };
            if key < victim_key {
                victim_key = key;
                victim = w;
            }
        }
        if hit {
            slots[victim].lru = tick;
        } else {
            slots[victim] = Way {
                tag: line.0,
                lru: tick,
                valid: true,
            };
        }
        if hit {
            self.hits += 1;
        } else {
            self.misses += 1;
        }
        hit
    }

    /// Remove a line if present (coherence invalidation). Returns whether it
    /// was present.
    #[inline]
    pub fn invalidate(&mut self, line: LineId) -> bool {
        for slot in self.set_slice(line) {
            if slot.valid && slot.tag == line.0 {
                slot.valid = false;
                return true;
            }
        }
        false
    }

    /// Drop every line in `[first, last]` (page purge on free).
    pub fn purge_line_range(&mut self, first: LineId, last: LineId) -> u64 {
        let mut purged = 0;
        for slot in &mut self.data {
            if slot.valid && slot.tag >= first.0 && slot.tag <= last.0 {
                slot.valid = false;
                purged += 1;
            }
        }
        purged
    }

    pub fn contains(&self, line: LineId) -> bool {
        let set = (line.0 as usize) & (self.sets - 1);
        (0..self.ways).any(|w| {
            let s = self.data[set * self.ways + w];
            s.valid && s.tag == line.0
        })
    }

    pub fn resident_lines(&self) -> u64 {
        self.data.iter().filter(|w| w.valid).count() as u64
    }

    pub fn capacity_lines(&self) -> u64 {
        (self.sets * self.ways) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn miss_then_hit() {
        let mut c = SetAssoc::new(4, 2);
        assert!(!c.probe(LineId(5)));
        c.insert(LineId(5));
        assert!(c.probe(LineId(5)));
        assert_eq!(c.hits, 1);
        assert_eq!(c.misses, 1);
    }

    #[test]
    fn lru_evicts_oldest() {
        let mut c = SetAssoc::new(1, 2); // one set, two ways
        c.insert(LineId(0));
        c.insert(LineId(1));
        c.probe(LineId(0)); // 0 is now MRU
        let evicted = c.insert(LineId(2)).unwrap();
        assert_eq!(evicted, LineId(1));
        assert!(c.contains(LineId(0)) && c.contains(LineId(2)));
    }

    #[test]
    fn set_conflict_only_within_set() {
        let mut c = SetAssoc::new(4, 1);
        c.insert(LineId(0));
        c.insert(LineId(1)); // different set — no eviction
        assert!(c.contains(LineId(0)));
        assert_eq!(c.insert(LineId(4)), Some(LineId(0))); // same set as 0
    }

    #[test]
    fn reinsert_refreshes_without_eviction() {
        let mut c = SetAssoc::new(1, 2);
        c.insert(LineId(0));
        c.insert(LineId(1));
        assert_eq!(c.insert(LineId(0)), None);
        // 1 is LRU now.
        assert_eq!(c.insert(LineId(2)), Some(LineId(1)));
    }

    #[test]
    fn invalidate_removes() {
        let mut c = SetAssoc::new(4, 2);
        c.insert(LineId(9));
        assert!(c.invalidate(LineId(9)));
        assert!(!c.contains(LineId(9)));
        assert!(!c.invalidate(LineId(9)));
    }

    #[test]
    fn purge_range() {
        let mut c = SetAssoc::new(16, 2);
        for l in 0..10 {
            c.insert(LineId(l));
        }
        let purged = c.purge_line_range(LineId(3), LineId(6));
        assert_eq!(purged, 4);
        assert!(c.contains(LineId(2)) && c.contains(LineId(7)));
        assert!(!c.contains(LineId(4)));
    }

    #[test]
    fn capacity_and_residency() {
        let mut c = SetAssoc::new(8, 2);
        assert_eq!(c.capacity_lines(), 16);
        for l in 0..100 {
            c.insert(LineId(l));
        }
        assert!(c.resident_lines() <= 16);
    }

    #[test]
    fn touch_equivalent_to_probe_then_insert() {
        // Same op sequence through both implementations: identical hit/miss
        // answers, counters, and final residency.
        let ops: Vec<u64> = (0..400u64).map(|i| (i * 7 + i / 3) % 37).collect();
        let mut a = SetAssoc::new(8, 2);
        let mut b = SetAssoc::new(8, 2);
        for &l in &ops {
            let hit_a = a.touch(LineId(l));
            let hit_b = b.probe(LineId(l));
            if !hit_b {
                b.insert(LineId(l));
            }
            assert_eq!(hit_a, hit_b, "line {l}");
        }
        assert_eq!((a.hits, a.misses), (b.hits, b.misses));
        for l in 0..64 {
            assert_eq!(a.contains(LineId(l)), b.contains(LineId(l)), "line {l}");
        }
    }

    #[test]
    fn working_set_within_capacity_all_hits() {
        // 64-set 2-way = 128 lines; a 64-line working set must not thrash.
        let mut c = SetAssoc::new(64, 2);
        for l in 0..64 {
            c.insert(LineId(l));
        }
        for _ in 0..3 {
            for l in 0..64 {
                assert!(c.probe(LineId(l)), "line {l} should stay resident");
            }
        }
    }
}
