//! Dynamic Distributed Cache (DDC) model: per-tile set-associative L1/L2,
//! the home-tile "L3" union, and the coherence directory.

pub mod directory;
pub mod hierarchy;
pub mod set_assoc;

pub use directory::{Directory, InvalidationFanout};
pub use hierarchy::{CacheSystem, ReadPlace, TileCaches, WriteLevel, WriteOutcome};
pub use set_assoc::SetAssoc;
