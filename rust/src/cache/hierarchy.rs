//! The DDC lookup path.
//!
//! **Loads**: requester L1 → requester L2 → home tile L2 (the distributed
//! "L3") → DDR; read-allocate into the requester's caches, sharer recorded
//! at the home directory.
//!
//! **Stores**: TILEPro64 stores are write-through to the *home* cache — a
//! store to a remotely-homed line is posted over the mesh to the home tile
//! (fire-and-forget via the store buffer; bandwidth-limited at the home
//! port, not latency-limited) and does **not** allocate in the writer's
//! private caches. A store to a locally-homed line writes the writer's own
//! L2 (which *is* the home/L3 for that line). Either way the home
//! invalidates every other sharer. This asymmetry is why the paper's
//! localisation matters: re-homing data on the tile that uses it turns both
//! loads and stores into local L2 traffic.

use std::sync::Arc;

use crate::arch::{CacheGeometry, Machine, TileId};
use crate::cache::directory::Directory;
use crate::cache::set_assoc::SetAssoc;
use crate::mem::LineId;

/// Per-tile private caches.
pub struct TileCaches {
    pub l1: SetAssoc,
    pub l2: SetAssoc,
}

impl TileCaches {
    fn new(geom: &CacheGeometry) -> Self {
        TileCaches {
            l1: SetAssoc::new(geom.l1_sets(), geom.l1_ways),
            l2: SetAssoc::new(geom.l2_sets(), geom.l2_ways),
        }
    }
}

/// Where a load was satisfied. Unlike [`HitLevel`](crate::arch::HitLevel)
/// this carries no
/// controller attach point — the cache walk doesn't need it, and resolving
/// the controller costs a page-table lookup the engine only pays on the
/// DDR path.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReadPlace {
    L1,
    L2,
    Home { home: TileId },
    Ddr,
}

/// Where a store landed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WriteLevel {
    /// Line homed on the writing tile: write into own L2.
    LocalL2,
    /// Remotely homed: posted to the home tile's L2 over the mesh.
    RemotePost { home: TileId },
}

#[derive(Debug, Clone, Copy)]
pub struct WriteOutcome {
    pub level: WriteLevel,
    /// Copies invalidated at other tiles.
    pub invalidated: u32,
    /// Home→farthest-victim distance (critical path of the fan-out).
    pub invalidation_hops: u32,
}

/// Every tile's caches plus the coherence directory, sized off the
/// machine description.
pub struct CacheSystem {
    tiles: Vec<TileCaches>,
    pub directory: Directory,
}

impl CacheSystem {
    pub fn new(machine: Arc<Machine>) -> Self {
        let geom = machine.geometry;
        CacheSystem {
            tiles: (0..machine.num_tiles())
                .map(|_| TileCaches::new(&geom))
                .collect(),
            directory: Directory::new(machine),
        }
    }

    /// Load `line` from `req`; `home` from the page table.
    ///
    /// The L2 is the *home* cache: only locally-homed lines allocate in the
    /// requester's L2. A remotely-homed line is served by its home tile's
    /// L2 and cached locally in the small L1 only — so a working set larger
    /// than L1 keeps paying the remote-home latency on every pass. This is
    /// the architectural fact the paper's localisation exploits (re-homing
    /// a chunk locally lets the 64 KB L2 absorb it).
    pub fn read(&mut self, req: TileId, line: LineId, home: TileId) -> ReadPlace {
        let rc = &mut self.tiles[req.index()];
        let place = if rc.l1.probe(line) {
            ReadPlace::L1
        } else if home == req {
            if rc.l2.probe(line) {
                rc.l1.insert(line);
                ReadPlace::L2
            } else {
                // We are the home and our L2 missed ⇒ straight to DRAM
                // (paper §2: local homing sends L2 misses directly to DDR).
                rc.l2.insert(line);
                rc.l1.insert(line);
                ReadPlace::Ddr
            }
        } else {
            // Remote home: probe the home's L2 — the "L3" hit. Fill only
            // our L1 with the returned line.
            let home_hit = self.tiles[home.index()].l2.probe(line);
            if !home_hit {
                self.tiles[home.index()].l2.insert(line);
            }
            self.tiles[req.index()].l1.insert(line);
            if home_hit {
                ReadPlace::Home { home }
            } else {
                ReadPlace::Ddr
            }
        };
        self.directory.add_sharer(line, req);
        place
    }

    /// Store to `line` from `req`. (One-line shorthand over
    /// [`write_run`](Self::write_run); callers that need the invalidation
    /// victim set — e.g. to bill the fan-out routes — use `write_run`
    /// directly.)
    pub fn write(&mut self, req: TileId, line: LineId, home: TileId) -> WriteOutcome {
        let mut out = None;
        self.write_run(req, line, 1, home, |_line, o, _victims| out = Some(o));
        out.expect("write_run visits exactly one line")
    }

    /// Bulk load of `count` sequential lines from `first`, all homed on
    /// `home` (the page-run fast path — one call per same-home run instead
    /// of one per line). `on_line` is invoked per line, in order, with the
    /// line's [`ReadPlace`]; per-line cache/directory state transitions are
    /// identical to calling [`read`](Self::read) in a loop.
    pub fn read_run(
        &mut self,
        req: TileId,
        first: LineId,
        count: u64,
        home: TileId,
        mut on_line: impl FnMut(LineId, ReadPlace),
    ) {
        // An L1 hit needs no directory touch: a line enters the L1 only
        // through a read that records the sharer, and every path that
        // clears the sharer bit (write invalidation, free purge) also
        // drops the L1 copy — so L1-resident ⇒ sharer bit already set.
        // (L2 hits don't share the invariant: the home L2 also holds lines
        // on behalf of *remote* requesters.)
        if home == req {
            for i in 0..count {
                let line = LineId(first.0 + i);
                let rc = &mut self.tiles[req.index()];
                let place = if rc.l1.probe(line) {
                    ReadPlace::L1
                } else {
                    let place = if rc.l2.touch(line) {
                        ReadPlace::L2
                    } else {
                        // Home L2 missed ⇒ straight to DRAM (paper §2:
                        // local homing sends L2 misses directly to DDR).
                        ReadPlace::Ddr
                    };
                    rc.l1.insert(line);
                    self.directory.add_sharer(line, req);
                    place
                };
                on_line(line, place);
            }
        } else {
            for i in 0..count {
                let line = LineId(first.0 + i);
                let place = if self.tiles[req.index()].l1.probe(line) {
                    ReadPlace::L1
                } else {
                    // Remote home: probe-and-fill the home's L2 (the "L3"),
                    // fill only our L1 with the returned line.
                    let home_hit = self.tiles[home.index()].l2.touch(line);
                    self.tiles[req.index()].l1.insert(line);
                    self.directory.add_sharer(line, req);
                    if home_hit {
                        ReadPlace::Home { home }
                    } else {
                        ReadPlace::Ddr
                    }
                };
                on_line(line, place);
            }
        }
    }

    /// Bulk store of `count` sequential same-home lines (the per-line
    /// store path is [`write`](Self::write), a one-line run). Invalidation
    /// fan-out is computed per line; the common no-other-sharer case skips
    /// the fan-out allocation entirely. `on_line` also receives the
    /// invalidated tiles (empty when none) so the engine can bill the
    /// home→victim fan-out and ack routes through the link servers.
    pub fn write_run(
        &mut self,
        req: TileId,
        first: LineId,
        count: u64,
        home: TileId,
        mut on_line: impl FnMut(LineId, WriteOutcome, &[TileId]),
    ) {
        let level = if home == req {
            WriteLevel::LocalL2
        } else {
            WriteLevel::RemotePost { home }
        };
        for i in 0..count {
            let line = LineId(first.0 + i);
            // The home L2 caches the line either way (own L2 *is* the home
            // cache when local; posted fill when remote).
            self.tiles[home.index()].l2.insert(line);
            let others = self.directory.write_claim(line, req);
            if others == 0 {
                on_line(
                    line,
                    WriteOutcome {
                        level,
                        invalidated: 0,
                        invalidation_hops: 0,
                    },
                    &[],
                );
                continue;
            }
            let fan = self.directory.fanout(others, home);
            for victim in &fan.victims {
                let vc = &mut self.tiles[victim.index()];
                vc.l1.invalidate(line);
                vc.l2.invalidate(line);
            }
            on_line(
                line,
                WriteOutcome {
                    level,
                    invalidated: fan.victims.len() as u32,
                    invalidation_hops: fan.max_hops_from_home,
                },
                &fan.victims,
            );
        }
    }

    /// Drop all cached copies and directory state for a freed region.
    pub fn purge_line_range(&mut self, first: LineId, last: LineId) {
        for t in &mut self.tiles {
            t.l1.purge_line_range(first, last);
            t.l2.purge_line_range(first, last);
        }
        self.directory.purge_line_range(first, last);
    }

    // ---- protocol-lab hooks (dirty owners + non-invalidating stores) ----
    //
    // Owner state lives in the directory's flat SoA column (alongside
    // the sharer bitsets) so the page-run uniformity scan reads both
    // with dense indexed loads; these are thin delegations kept for the
    // engine's existing call sites.

    /// The tile holding `line` dirty (M/O), if any.
    #[inline]
    pub fn owner_of(&self, line: LineId) -> Option<TileId> {
        self.directory.owner_of(line)
    }

    /// Record a silent-upgrade write: `tile` now holds `line` modified.
    #[inline]
    pub fn set_owner(&mut self, line: LineId, tile: TileId) {
        self.directory.set_owner(line, tile)
    }

    /// Drop the dirty-owner record (writeback, invalidation, purge).
    #[inline]
    pub fn clear_owner(&mut self, line: LineId) -> Option<TileId> {
        self.directory.clear_owner(line)
    }

    /// Dirty owners inside `[first, last]`, in line order — the free-time
    /// writeback set the engine bills before purging a region.
    pub fn owners_in_range(&self, first: LineId, last: LineId) -> Vec<(LineId, TileId)> {
        self.directory.owners_in_range(first, last)
    }

    /// Make a silently-upgraded line resident in the owner's private
    /// caches (the dirty data lives with the owner, not the home).
    pub fn cache_locally(&mut self, tile: TileId, line: LineId) {
        let tc = &mut self.tiles[tile.index()];
        tc.l2.insert(line);
        tc.l1.insert(line);
    }

    /// Write-update store: home caches the new data and every *other*
    /// sharer keeps its copy valid (it receives the update in place
    /// instead of an invalidation). Returns the update fan-out victims —
    /// the sharers other than the writer — for the engine to bill.
    pub fn write_update(&mut self, req: TileId, line: LineId, home: TileId) -> Vec<TileId> {
        self.tiles[home.index()].l2.insert(line);
        let mut victims = self.directory.sharers_of(line);
        victims.retain(|&t| t != req);
        self.directory.add_sharer(line, req);
        victims
    }

    pub fn tile(&self, t: TileId) -> &TileCaches {
        &self.tiles[t.index()]
    }

    /// Split borrow for the intra-run parallel replay: every tile's private
    /// caches mutably (the driver hands each epoch worker a disjoint
    /// sub-slice via `split_at_mut`) alongside a *shared* view of the
    /// directory (workers read sharer masks for park decisions and log
    /// their own-homed mutations for a sequential commit).
    pub fn tiles_and_dir_mut(&mut self) -> (&mut [TileCaches], &Directory) {
        (&mut self.tiles, &self.directory)
    }

    /// Aggregate (hits, misses) over all private caches (reporting).
    pub fn totals(&self) -> (u64, u64) {
        self.tiles.iter().fold((0, 0), |(h, m), t| {
            (h + t.l1.hits + t.l2.hits, m + t.l1.misses + t.l2.misses)
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sys() -> CacheSystem {
        CacheSystem::new(Arc::new(Machine::tilepro64()))
    }

    #[test]
    fn cold_local_home_goes_to_ddr_then_hits_l1() {
        let mut s = sys();
        assert_eq!(s.read(TileId(0), LineId(1), TileId(0)), ReadPlace::Ddr);
        assert_eq!(s.read(TileId(0), LineId(1), TileId(0)), ReadPlace::L1);
    }

    #[test]
    fn remote_home_ddc_l3_hit() {
        let mut s = sys();
        let home = TileId(9);
        s.read(home, LineId(7), home); // home fills its L2
        assert_eq!(s.read(TileId(0), LineId(7), home), ReadPlace::Home { home });
    }

    #[test]
    fn remote_cold_miss_fills_home_l2() {
        let mut s = sys();
        let home = TileId(9);
        assert_eq!(s.read(TileId(0), LineId(7), home), ReadPlace::Ddr);
        // A second remote requester now hits the home "L3".
        assert_eq!(s.read(TileId(1), LineId(7), home), ReadPlace::Home { home });
    }

    #[test]
    fn local_store_writes_own_l2() {
        let mut s = sys();
        let out = s.write(TileId(5), LineId(8), TileId(5));
        assert_eq!(out.level, WriteLevel::LocalL2);
        // The line is now in our L2: a read hits locally.
        let place = s.read(TileId(5), LineId(8), TileId(5));
        assert!(matches!(place, ReadPlace::L2 | ReadPlace::L1));
    }

    #[test]
    fn remote_store_posts_and_does_not_allocate_locally() {
        let mut s = sys();
        let home = TileId(9);
        let out = s.write(TileId(0), LineId(4), home);
        assert_eq!(out.level, WriteLevel::RemotePost { home });
        assert!(!s.tile(TileId(0)).l2.contains(LineId(4)));
        // ...but the home now caches it: a read from a third tile is an L3 hit.
        assert_eq!(s.read(TileId(1), LineId(4), home), ReadPlace::Home { home });
    }

    #[test]
    fn write_invalidates_remote_copies() {
        let mut s = sys();
        let home = TileId(4);
        s.read(TileId(1), LineId(3), home);
        s.read(TileId(2), LineId(3), home);
        assert_eq!(s.read(TileId(2), LineId(3), home), ReadPlace::L1);
        let out = s.write(TileId(1), LineId(3), home);
        assert!(out.invalidated >= 1);
        // Tile 2 re-reads: must refetch (stale copy purged).
        let place = s.read(TileId(2), LineId(3), home);
        assert_ne!(place, ReadPlace::L1, "stale copy survived");
    }

    #[test]
    fn single_writer_invalidates_nothing() {
        let mut s = sys();
        s.write(TileId(5), LineId(8), TileId(5));
        let out = s.write(TileId(5), LineId(8), TileId(5));
        assert_eq!(out.invalidated, 0);
    }

    #[test]
    fn purge_forces_refetch() {
        let mut s = sys();
        s.read(TileId(0), LineId(5), TileId(0));
        s.purge_line_range(LineId(0), LineId(10));
        assert_eq!(s.read(TileId(0), LineId(5), TileId(0)), ReadPlace::Ddr);
    }

    #[test]
    fn capacity_thrash_evicts() {
        let mut s = sys();
        let t = TileId(0);
        let cap = s.tile(t).l2.capacity_lines();
        for l in 0..(cap * 4) {
            s.read(t, LineId(l), t);
        }
        assert_eq!(
            s.read(t, LineId(0), t),
            ReadPlace::Ddr,
            "line 0 should have been evicted"
        );
    }

    #[test]
    fn working_set_fitting_l2_stays_resident() {
        // A 768-line (48 KB) stream fits the 64 KB L2: second pass must not
        // touch DRAM. This is the localisation win in miniature.
        let mut s = sys();
        let t = TileId(0);
        for l in 0..768 {
            s.read(t, LineId(l), t);
        }
        for l in 0..768 {
            let place = s.read(t, LineId(l), t);
            assert!(
                matches!(place, ReadPlace::L1 | ReadPlace::L2),
                "line {l} fell out: {place:?}"
            );
        }
    }

    #[test]
    fn remote_lines_fill_l1_only() {
        let mut s = sys();
        let home = TileId(9);
        for l in 0..1000 {
            s.read(TileId(0), LineId(l), home);
        }
        assert_eq!(
            s.tile(TileId(0)).l2.resident_lines(),
            0,
            "remote lines must not allocate in the reader L2"
        );
        assert!(s.tile(TileId(0)).l1.resident_lines() > 0);
    }

    #[test]
    fn read_run_matches_per_line_reads() {
        // Same access pattern through the bulk call and the per-line walk:
        // identical ReadPlace sequence and identical final cache state.
        for home in [TileId(0), TileId(9)] {
            let req = TileId(0);
            let mut bulk = sys();
            let mut perline = sys();
            // Warm partially so the run sees a mix of hits and misses.
            for l in 0..100 {
                bulk.read(req, LineId(l * 2), home);
                perline.read(req, LineId(l * 2), home);
            }
            let mut places = Vec::new();
            bulk.read_run(req, LineId(0), 300, home, |_, p| places.push(p));
            for (i, l) in (0..300).enumerate() {
                assert_eq!(
                    perline.read(req, LineId(l), home),
                    places[i],
                    "home {home:?} line {l}"
                );
            }
            assert_eq!(bulk.totals(), perline.totals(), "home {home:?}");
        }
    }

    #[test]
    fn write_run_matches_per_line_writes() {
        for home in [TileId(0), TileId(9)] {
            let req = TileId(1);
            let mut bulk = sys();
            let mut perline = sys();
            // Seed sharers so some writes fan out invalidations.
            for s in [TileId(2), TileId(3)] {
                for l in 0..50 {
                    bulk.read(s, LineId(l * 3), home);
                    perline.read(s, LineId(l * 3), home);
                }
            }
            let mut outs = Vec::new();
            bulk.write_run(req, LineId(0), 160, home, |_, o, victims| {
                assert_eq!(victims.len() as u32, o.invalidated, "home {home:?}");
                outs.push((o.level, o.invalidated, o.invalidation_hops))
            });
            for (i, l) in (0..160).enumerate() {
                let o = perline.write(req, LineId(l), home);
                assert_eq!(
                    (o.level, o.invalidated, o.invalidation_hops),
                    outs[i],
                    "home {home:?} line {l}"
                );
            }
            assert_eq!(
                bulk.directory.invalidations_sent,
                perline.directory.invalidations_sent
            );
        }
    }

    #[test]
    fn write_run_reports_invalidation_victims() {
        // Two remote sharers of a line: the writing run must hand the
        // engine exactly those tiles (the fan-out routes it will bill).
        let mut s = sys();
        let home = TileId(4);
        s.read(TileId(2), LineId(0), home);
        s.read(TileId(3), LineId(0), home);
        let mut seen: Vec<Vec<TileId>> = Vec::new();
        s.write_run(TileId(1), LineId(0), 2, home, |_, _, v| seen.push(v.to_vec()));
        assert_eq!(seen[0], vec![TileId(2), TileId(3)]);
        assert!(seen[1].is_empty(), "line 1 had no sharers");
    }

    #[test]
    fn owner_map_tracks_and_purges() {
        let mut s = sys();
        assert_eq!(s.owner_of(LineId(9)), None);
        s.set_owner(LineId(9), TileId(3));
        s.set_owner(LineId(11), TileId(4));
        s.set_owner(LineId(40), TileId(5));
        assert_eq!(s.owner_of(LineId(9)), Some(TileId(3)));
        assert_eq!(
            s.owners_in_range(LineId(0), LineId(20)),
            vec![(LineId(9), TileId(3)), (LineId(11), TileId(4))]
        );
        assert_eq!(s.clear_owner(LineId(9)), Some(TileId(3)));
        assert_eq!(s.owner_of(LineId(9)), None);
        // A region free drops the owners it covers, keeps the rest.
        s.purge_line_range(LineId(0), LineId(20));
        assert_eq!(s.owner_of(LineId(11)), None);
        assert_eq!(s.owner_of(LineId(40)), Some(TileId(5)));
    }

    #[test]
    fn cache_locally_makes_the_line_a_local_hit() {
        let mut s = sys();
        let home = TileId(9);
        s.cache_locally(TileId(1), LineId(6));
        assert_eq!(s.read(TileId(1), LineId(6), home), ReadPlace::L1);
    }

    #[test]
    fn write_update_keeps_sharers_valid() {
        let mut s = sys();
        let home = TileId(4);
        s.read(TileId(2), LineId(0), home);
        s.read(TileId(3), LineId(0), home);
        let victims = s.write_update(TileId(1), LineId(0), home);
        assert_eq!(victims, vec![TileId(2), TileId(3)]);
        // Unlike write-invalidate, the sharers' copies survive: tile 2
        // still hits its L1, and the writer joined the sharer set.
        assert_eq!(s.read(TileId(2), LineId(0), home), ReadPlace::L1);
        assert!(s.directory.is_sharer(LineId(0), TileId(1)));
        // A second update from the same writer excludes itself.
        let victims = s.write_update(TileId(1), LineId(0), home);
        assert_eq!(victims, vec![TileId(2), TileId(3)]);
    }

    #[test]
    fn totals_count_hits_and_misses() {
        let mut s = sys();
        s.read(TileId(0), LineId(0), TileId(0));
        s.read(TileId(0), LineId(0), TileId(0));
        let (h, m) = s.totals();
        assert!(h >= 1 && m >= 1);
    }
}
