//! Home-tile coherence directory.
//!
//! DDC serves coherence through the home tile: it tracks which tiles hold a
//! copy of each line and, on a write, invalidates every other sharer (paper
//! §2: "If another tile writes new data to the cache line, the home tile is
//! responsible to invalidate all copies"). Sharer sets are bitmasks sized
//! by the machine's tile count — one 64-bit word per line on grids up to 64
//! tiles (the tilepro64/epiphany16 fast path), `ceil(tiles/64)` words on
//! larger grids like the 16×16 nuca256.
//!
//! The victim set a [`write_claim`](Directory::write_claim) /
//! [`fanout`](Directory::fanout) pair produces is not just latency
//! bookkeeping: the engine hands it to the contention model, which walks
//! the XY route home→victim per invalidated tile (plus the ack return
//! path) and bills every directed mesh link — the coherence traffic the
//! paper's localisation keeps off the mesh.

use std::sync::Arc;

use crate::arch::{Machine, TileId};
use crate::mem::LineId;

/// Owner-column sentinel: no tile holds the line dirty.
const NO_OWNER: u32 = u32::MAX;

/// Sharer masks stored in a dense vector indexed by line id: the allocator
/// bump-allocates a compact address space, and the workloads stream
/// sequentially, so adjacent entries share (host) cache lines — an order of
/// magnitude faster than any hash map on the per-line-event hot path.
pub struct Directory {
    machine: Arc<Machine>,
    /// 64-bit words per line (= `ceil(num_tiles / 64)`, at least 1).
    words: usize,
    sharers: Vec<u64>,
    /// Other-sharer mask of the most recent multi-word
    /// [`write_claim`](Self::write_claim) — see that method's contract.
    scratch: Vec<u64>,
    /// Debug guard for the scratch contract: set by a multi-word
    /// `write_claim` that found other sharers, consumed by `fanout`.
    #[cfg(debug_assertions)]
    scratch_armed: bool,
    /// Dirty-owner column of the ownership protocols (MESI/MOESI):
    /// `owners[line]` is the owning tile or [`NO_OWNER`]. Flat SoA
    /// alongside the sharer bitsets so the page-run uniformity scan
    /// probes sharer mask and owner with two dense indexed loads and no
    /// allocation; `owned_lines` keeps the default write-through
    /// protocol's no-owner probe O(1).
    owners: Vec<u32>,
    owned_lines: usize,
    tracked: usize,
    pub invalidations_sent: u64,
}

/// Result of a write's coherence action.
#[derive(Debug, PartialEq, Eq)]
pub struct InvalidationFanout {
    /// Tiles whose copies were invalidated (excludes the writer).
    pub victims: Vec<TileId>,
    /// Mesh distance from home to the farthest victim (latency critical path).
    pub max_hops_from_home: u32,
}

impl Directory {
    pub fn new(machine: Arc<Machine>) -> Self {
        let words = (machine.num_tiles() as usize).div_ceil(64).max(1);
        Directory {
            machine,
            words,
            sharers: Vec::new(),
            scratch: vec![0; words],
            #[cfg(debug_assertions)]
            scratch_armed: false,
            owners: Vec::new(),
            owned_lines: 0,
            tracked: 0,
            invalidations_sent: 0,
        }
    }

    /// The tile holding `line` dirty (M/O), if any. The `owned_lines`
    /// early-out keeps this free for the default protocol, whose writes
    /// never create owners.
    #[inline]
    pub fn owner_of(&self, line: LineId) -> Option<TileId> {
        if self.owned_lines == 0 {
            return None;
        }
        match self.owners.get(line.0 as usize) {
            Some(&t) if t != NO_OWNER => Some(TileId(t)),
            _ => None,
        }
    }

    /// Record a silent-upgrade write: `tile` now holds `line` modified.
    pub fn set_owner(&mut self, line: LineId, tile: TileId) {
        let ix = line.0 as usize;
        if ix >= self.owners.len() {
            self.owners.resize(ix + 1, NO_OWNER);
        }
        if self.owners[ix] == NO_OWNER {
            self.owned_lines += 1;
        }
        self.owners[ix] = tile.0;
    }

    /// Drop the dirty-owner record (writeback, invalidation, purge).
    pub fn clear_owner(&mut self, line: LineId) -> Option<TileId> {
        if self.owned_lines == 0 {
            return None;
        }
        match self.owners.get_mut(line.0 as usize) {
            Some(slot) if *slot != NO_OWNER => {
                let t = TileId(*slot);
                *slot = NO_OWNER;
                self.owned_lines -= 1;
                Some(t)
            }
            _ => None,
        }
    }

    /// Dirty owners inside `[first, last]`, in line order — the
    /// free-time writeback set the engine bills before purging a region.
    pub fn owners_in_range(&self, first: LineId, last: LineId) -> Vec<(LineId, TileId)> {
        if self.owned_lines == 0 {
            return Vec::new();
        }
        let lo = (first.0 as usize).min(self.owners.len());
        let hi = (last.0 as usize + 1).min(self.owners.len());
        self.owners[lo..hi]
            .iter()
            .enumerate()
            .filter(|&(_, &t)| t != NO_OWNER)
            .map(|(i, &t)| (LineId((lo + i) as u64), TileId(t)))
            .collect()
    }

    #[inline]
    fn slot_mut(&mut self, line: LineId) -> &mut [u64] {
        let base = line.0 as usize * self.words;
        if base + self.words > self.sharers.len() {
            self.sharers.resize(base + self.words, 0);
        }
        &mut self.sharers[base..base + self.words]
    }

    #[inline]
    fn slot(&self, line: LineId) -> &[u64] {
        let base = line.0 as usize * self.words;
        self.sharers
            .get(base..base + self.words)
            .unwrap_or(&[])
    }

    /// OR the sharer masks of `count` consecutive lines starting at
    /// `first` into `acc` (tile-bit layout, `acc.len() >= self.words`).
    /// Read-only; the epoch planner uses it to find every tile a write
    /// run could invalidate, so those tiles can be fenced out of the
    /// parallel phase.
    pub(crate) fn union_sharers(&self, first: LineId, count: u64, acc: &mut [u64]) {
        for i in 0..count {
            for (w, &m) in self.slot(LineId(first.0 + i)).iter().enumerate() {
                acc[w] |= m;
            }
        }
    }

    /// Record that `tile` now caches `line`.
    #[inline]
    pub fn add_sharer(&mut self, line: LineId, tile: TileId) {
        let (word, bit) = (tile.index() / 64, tile.index() % 64);
        let slot = self.slot_mut(line);
        let was_zero = slot.iter().all(|&w| w == 0);
        slot[word] |= 1u64 << bit;
        if was_zero {
            self.tracked += 1;
        }
    }

    /// Remove one sharer (e.g. on eviction notification or purge).
    pub fn remove_sharer(&mut self, line: LineId, tile: TileId) {
        let base = line.0 as usize * self.words;
        if base + self.words > self.sharers.len() {
            return;
        }
        let slot = &mut self.sharers[base..base + self.words];
        let had_any = slot.iter().any(|&w| w != 0);
        slot[tile.index() / 64] &= !(1u64 << (tile.index() % 64));
        if had_any && slot.iter().all(|&w| w == 0) {
            self.tracked -= 1;
        }
    }

    pub fn sharers_of(&self, line: LineId) -> Vec<TileId> {
        let slot = self.slot(line);
        let mut out = Vec::new();
        for (wi, &mask) in slot.iter().enumerate() {
            let mut m = mask;
            while m != 0 {
                let i = m.trailing_zeros();
                m &= m - 1;
                out.push(TileId((wi * 64) as u32 + i));
            }
        }
        out
    }

    pub fn sharer_count(&self, line: LineId) -> u32 {
        self.slot(line).iter().map(|w| w.count_ones()).sum()
    }

    /// Whether `tile` currently holds a tracked copy of `line` — the
    /// protocol layer's "was the writer already a sharer" probe (an S→M
    /// upgrade vs a plain write miss).
    #[inline]
    pub fn is_sharer(&self, line: LineId, tile: TileId) -> bool {
        self.slot(line)
            .get(tile.index() / 64)
            .is_some_and(|w| w & (1u64 << (tile.index() % 64)) != 0)
    }

    /// Whether any tile *other than* `tile` holds a tracked copy of `line`.
    /// Read-only (`&self`): the intra-run parallel replay uses this as the
    /// park predicate for epoch-phase-A stores — a foreign sharer means the
    /// store would fan out invalidations, which must run on the sequential
    /// phase-B path.
    #[inline]
    pub fn has_foreign_sharer(&self, line: LineId, tile: TileId) -> bool {
        let (tword, tbit) = (tile.index() / 64, tile.index() % 64);
        self.slot(line).iter().enumerate().any(|(w, &mask)| {
            let m = if w == tword { mask & !(1u64 << tbit) } else { mask };
            m != 0
        })
    }

    /// Claim `line` for `writer`, *knowing* there are no other sharers
    /// (checked by [`has_foreign_sharer`](Self::has_foreign_sharer) before
    /// the epoch worker logged the claim). State-identical to the
    /// no-other-sharer case of [`write_claim`](Self::write_claim) — sole
    /// bit set, `tracked` bumped on first tracking — without touching the
    /// multi-word scratch contract.
    #[inline]
    pub fn claim_local(&mut self, line: LineId, writer: TileId) {
        let (word, bit) = (writer.index() / 64, writer.index() % 64);
        let slot = self.slot_mut(line);
        let was_zero = slot.iter().all(|&w| w == 0);
        debug_assert!(
            slot.iter().enumerate().all(|(w, &mask)| {
                (if w == word { mask & !(1u64 << bit) } else { mask }) == 0
            }),
            "claim_local requires no foreign sharers"
        );
        slot[word] = 1u64 << bit;
        if was_zero {
            self.tracked += 1;
        }
    }

    /// Fast-path write claim: make `writer` the sole sharer of `line` and
    /// return a non-zero value iff there were *other* previous sharers (0
    /// in the common private-stream case — no fan-out, no allocation). On
    /// single-word machines the return value *is* the other-sharer mask;
    /// on multi-word machines the full mask is parked in `self.scratch`
    /// and the return value is the OR of its words, so callers must expand
    /// it with [`fanout`](Self::fanout) before the next `write_claim` (the
    /// cache hierarchy calls them back to back per line).
    #[inline]
    pub fn write_claim(&mut self, line: LineId, writer: TileId) -> u64 {
        let writer_word = writer.index() / 64;
        let writer_bit = 1u64 << (writer.index() % 64);
        if self.words == 1 {
            let slot = self.slot_mut(line);
            let mask = slot[0];
            slot[0] = writer_bit;
            if mask == 0 {
                self.tracked += 1;
            }
            return mask & !writer_bit;
        }
        let words = self.words;
        let base = line.0 as usize * words;
        if base + words > self.sharers.len() {
            self.sharers.resize(base + words, 0);
        }
        let mut others = 0u64;
        let mut was_zero = true;
        for w in 0..words {
            let mask = self.sharers[base + w];
            was_zero &= mask == 0;
            let other = if w == writer_word { mask & !writer_bit } else { mask };
            self.scratch[w] = other;
            others |= other;
            self.sharers[base + w] = if w == writer_word { writer_bit } else { 0 };
        }
        if was_zero {
            self.tracked += 1;
        }
        #[cfg(debug_assertions)]
        {
            debug_assert!(
                !self.scratch_armed,
                "previous write_claim's other-sharer mask was never expanded by fanout"
            );
            self.scratch_armed = others != 0;
        }
        others
    }

    /// Expand an other-sharer summary (from [`write_claim`](Self::write_claim))
    /// into the invalidation fan-out and account it. Hop distances use the
    /// machine's grid.
    pub fn fanout(&mut self, others: u64, home: TileId) -> InvalidationFanout {
        if others == 0 {
            return InvalidationFanout {
                victims: Vec::new(),
                max_hops_from_home: 0,
            };
        }
        let mut max_h = 0;
        let single = [others];
        #[cfg(debug_assertions)]
        if self.words > 1 {
            debug_assert!(
                self.scratch_armed,
                "fanout must follow the write_claim whose mask it expands"
            );
            self.scratch_armed = false;
        }
        let masks: &[u64] = if self.words == 1 { &single } else { &self.scratch };
        let mut victims =
            Vec::with_capacity(masks.iter().map(|m| m.count_ones() as usize).sum());
        for (wi, &mask) in masks.iter().enumerate() {
            let mut m = mask;
            while m != 0 {
                let i = m.trailing_zeros();
                m &= m - 1;
                let t = TileId((wi * 64) as u32 + i);
                max_h = max_h.max(self.machine.hops(home, t));
                victims.push(t);
            }
        }
        self.invalidations_sent += victims.len() as u64;
        InvalidationFanout {
            victims,
            max_hops_from_home: max_h,
        }
    }

    /// Write by `writer` to `line` homed at `home`: every other sharer is
    /// invalidated; the writer remains the sole sharer.
    pub fn write_invalidate(
        &mut self,
        line: LineId,
        home: TileId,
        writer: TileId,
    ) -> InvalidationFanout {
        let others = self.write_claim(line, writer);
        self.fanout(others, home)
    }

    /// Drop all directory state for lines in `[first, last]` (region free).
    pub fn purge_line_range(&mut self, first: LineId, last: LineId) {
        let max_line = self.sharers.len() / self.words;
        let lo = (first.0 as usize).min(max_line);
        let hi = (last.0 as usize + 1).min(max_line);
        for line in lo..hi {
            let slot = &mut self.sharers[line * self.words..(line + 1) * self.words];
            if slot.iter().any(|&w| w != 0) {
                self.tracked -= 1;
                slot.fill(0);
            }
        }
        if self.owned_lines != 0 {
            let lo = (first.0 as usize).min(self.owners.len());
            let hi = (last.0 as usize + 1).min(self.owners.len());
            for slot in &mut self.owners[lo..hi] {
                if *slot != NO_OWNER {
                    *slot = NO_OWNER;
                    self.owned_lines -= 1;
                }
            }
        }
    }

    pub fn tracked_lines(&self) -> usize {
        self.tracked
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dir() -> Directory {
        Directory::new(Arc::new(Machine::tilepro64()))
    }

    /// 16×16 grid: 256 tiles, 4 words per sharer set.
    fn dir256() -> Directory {
        Directory::new(Arc::new(Machine::nuca256()))
    }

    #[test]
    fn add_and_list_sharers() {
        let mut d = dir();
        d.add_sharer(LineId(1), TileId(0));
        d.add_sharer(LineId(1), TileId(63));
        assert_eq!(d.sharers_of(LineId(1)), vec![TileId(0), TileId(63)]);
        assert_eq!(d.sharer_count(LineId(1)), 2);
    }

    #[test]
    fn add_is_idempotent() {
        let mut d = dir();
        d.add_sharer(LineId(1), TileId(5));
        d.add_sharer(LineId(1), TileId(5));
        assert_eq!(d.sharer_count(LineId(1)), 1);
    }

    #[test]
    fn is_sharer_tracks_membership() {
        let mut d = dir();
        assert!(!d.is_sharer(LineId(4), TileId(9)));
        d.add_sharer(LineId(4), TileId(9));
        assert!(d.is_sharer(LineId(4), TileId(9)));
        assert!(!d.is_sharer(LineId(4), TileId(10)));
        d.remove_sharer(LineId(4), TileId(9));
        assert!(!d.is_sharer(LineId(4), TileId(9)));
        // Multi-word machines probe the right word.
        let mut d = dir256();
        d.add_sharer(LineId(1), TileId(200));
        assert!(d.is_sharer(LineId(1), TileId(200)));
        assert!(!d.is_sharer(LineId(1), TileId(72)));
    }

    #[test]
    fn write_invalidates_others_keeps_writer() {
        let mut d = dir();
        for t in [0u32, 7, 12] {
            d.add_sharer(LineId(9), TileId(t));
        }
        let f = d.write_invalidate(LineId(9), TileId(0), TileId(7));
        assert_eq!(f.victims, vec![TileId(0), TileId(12)]);
        assert_eq!(d.sharers_of(LineId(9)), vec![TileId(7)]);
        assert_eq!(d.invalidations_sent, 2);
    }

    #[test]
    fn write_with_no_sharers_is_free() {
        let mut d = dir();
        let f = d.write_invalidate(LineId(1), TileId(0), TileId(3));
        assert!(f.victims.is_empty());
        assert_eq!(f.max_hops_from_home, 0);
        assert_eq!(d.sharers_of(LineId(1)), vec![TileId(3)]);
    }

    #[test]
    fn fanout_hops_is_max_distance() {
        let mut d = dir();
        d.add_sharer(LineId(2), TileId(0)); // corner (0,0)
        d.add_sharer(LineId(2), TileId(63)); // corner (7,7): 14 hops from 0
        let f = d.write_invalidate(LineId(2), TileId(0), TileId(1));
        assert_eq!(f.max_hops_from_home, 14);
    }

    #[test]
    fn remove_sharer_cleans_up() {
        let mut d = dir();
        d.add_sharer(LineId(3), TileId(1));
        d.remove_sharer(LineId(3), TileId(1));
        assert_eq!(d.tracked_lines(), 0);
    }

    #[test]
    fn purge_range_drops_state() {
        let mut d = dir();
        d.add_sharer(LineId(10), TileId(1));
        d.add_sharer(LineId(20), TileId(1));
        d.purge_line_range(LineId(0), LineId(15));
        assert_eq!(d.sharer_count(LineId(10)), 0);
        assert_eq!(d.sharer_count(LineId(20)), 1);
    }

    #[test]
    fn multiword_sharers_cross_word_boundaries() {
        let mut d = dir256();
        for t in [0u32, 63, 64, 127, 128, 255] {
            d.add_sharer(LineId(5), TileId(t));
        }
        assert_eq!(d.sharer_count(LineId(5)), 6);
        assert_eq!(
            d.sharers_of(LineId(5)),
            [0u32, 63, 64, 127, 128, 255].map(TileId).to_vec()
        );
        assert_eq!(d.tracked_lines(), 1);
    }

    #[test]
    fn multiword_write_invalidates_high_tiles() {
        let mut d = dir256();
        d.add_sharer(LineId(9), TileId(70));
        d.add_sharer(LineId(9), TileId(255));
        let f = d.write_invalidate(LineId(9), TileId(0), TileId(70));
        assert_eq!(f.victims, vec![TileId(255)]);
        assert_eq!(d.sharers_of(LineId(9)), vec![TileId(70)]);
        // (0,0) -> (15,15) on a 16-wide grid = 30 hops.
        assert_eq!(f.max_hops_from_home, 30);
    }

    #[test]
    fn owner_column_tracks_sets_clears_and_purges() {
        let mut d = dir();
        assert_eq!(d.owner_of(LineId(9)), None);
        d.set_owner(LineId(9), TileId(3));
        d.set_owner(LineId(11), TileId(4));
        d.set_owner(LineId(40), TileId(5));
        assert_eq!(d.owner_of(LineId(9)), Some(TileId(3)));
        // Re-setting an owned line must not double-count it.
        d.set_owner(LineId(9), TileId(7));
        assert_eq!(d.owner_of(LineId(9)), Some(TileId(7)));
        assert_eq!(
            d.owners_in_range(LineId(0), LineId(20)),
            vec![(LineId(9), TileId(7)), (LineId(11), TileId(4))]
        );
        assert_eq!(d.clear_owner(LineId(9)), Some(TileId(7)));
        assert_eq!(d.clear_owner(LineId(9)), None);
        assert_eq!(d.owner_of(LineId(9)), None);
        // A region purge drops the owners it covers, keeps the rest.
        d.purge_line_range(LineId(0), LineId(20));
        assert_eq!(d.owner_of(LineId(11)), None);
        assert_eq!(d.owner_of(LineId(40)), Some(TileId(5)));
        // Probes past the column's end are owner-free, not a panic.
        assert_eq!(d.owner_of(LineId(1 << 20)), None);
        assert!(d.owners_in_range(LineId(100), LineId(1 << 20)).is_empty());
    }

    #[test]
    fn multiword_remove_and_purge() {
        let mut d = dir256();
        d.add_sharer(LineId(1), TileId(200));
        d.remove_sharer(LineId(1), TileId(200));
        assert_eq!(d.tracked_lines(), 0);
        d.add_sharer(LineId(2), TileId(129));
        d.purge_line_range(LineId(0), LineId(4));
        assert_eq!(d.sharer_count(LineId(2)), 0);
        assert_eq!(d.tracked_lines(), 0);
    }
}
