//! Home-tile coherence directory.
//!
//! DDC serves coherence through the home tile: it tracks which tiles hold a
//! copy of each line and, on a write, invalidates every other sharer (paper
//! §2: "If another tile writes new data to the cache line, the home tile is
//! responsible to invalidate all copies"). Sharer sets are 64-bit masks —
//! one bit per tile — so the whole directory is a hash map of u64s.

use crate::arch::{hops, TileId};
use crate::mem::LineId;

/// Sharer masks stored in a dense vector indexed by line id: the allocator
/// bump-allocates a compact address space, and the workloads stream
/// sequentially, so adjacent entries share (host) cache lines — an order of
/// magnitude faster than any hash map on the per-line-event hot path.
#[derive(Default)]
pub struct Directory {
    sharers: Vec<u64>,
    tracked: usize,
    pub invalidations_sent: u64,
}

/// Result of a write's coherence action.
#[derive(Debug, PartialEq, Eq)]
pub struct InvalidationFanout {
    /// Tiles whose copies were invalidated (excludes the writer).
    pub victims: Vec<TileId>,
    /// Mesh distance from home to the farthest victim (latency critical path).
    pub max_hops_from_home: u32,
}

impl Directory {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    fn slot_mut(&mut self, line: LineId) -> &mut u64 {
        let ix = line.0 as usize;
        if ix >= self.sharers.len() {
            self.sharers.resize(ix + 1, 0);
        }
        &mut self.sharers[ix]
    }

    #[inline]
    fn mask_of(&self, line: LineId) -> u64 {
        self.sharers.get(line.0 as usize).copied().unwrap_or(0)
    }

    /// Record that `tile` now caches `line`.
    #[inline]
    pub fn add_sharer(&mut self, line: LineId, tile: TileId) {
        let was_zero = {
            let slot = self.slot_mut(line);
            let w = *slot == 0;
            *slot |= 1u64 << tile.index();
            w
        };
        if was_zero {
            self.tracked += 1;
        }
    }

    /// Remove one sharer (e.g. on eviction notification or purge).
    pub fn remove_sharer(&mut self, line: LineId, tile: TileId) {
        if let Some(mask) = self.sharers.get_mut(line.0 as usize) {
            let was = *mask;
            *mask &= !(1u64 << tile.index());
            if was != 0 && *mask == 0 {
                self.tracked -= 1;
            }
        }
    }

    pub fn sharers_of(&self, line: LineId) -> Vec<TileId> {
        let mask = self.mask_of(line);
        (0..64)
            .filter(|&i| mask & (1u64 << i) != 0)
            .map(|i| TileId(i as u32))
            .collect()
    }

    pub fn sharer_count(&self, line: LineId) -> u32 {
        self.mask_of(line).count_ones()
    }

    /// Fast-path write claim: make `writer` the sole sharer of `line` and
    /// return the mask of *other* previous sharers (0 in the common
    /// private-stream case — no fan-out, no allocation). The page-run bulk
    /// path calls this per line and only expands the fan-out when needed.
    #[inline]
    pub fn write_claim(&mut self, line: LineId, writer: TileId) -> u64 {
        let writer_bit = 1u64 << writer.index();
        let slot = self.slot_mut(line);
        let mask = *slot;
        *slot = writer_bit;
        if mask == 0 {
            self.tracked += 1;
        }
        mask & !writer_bit
    }

    /// Expand an other-sharer mask (from [`write_claim`](Self::write_claim))
    /// into the invalidation fan-out and account it.
    pub fn fanout(&mut self, others: u64, home: TileId) -> InvalidationFanout {
        if others == 0 {
            return InvalidationFanout {
                victims: Vec::new(),
                max_hops_from_home: 0,
            };
        }
        let mut victims = Vec::with_capacity(others.count_ones() as usize);
        let mut max_h = 0;
        let mut m = others;
        while m != 0 {
            let i = m.trailing_zeros();
            m &= m - 1;
            let t = TileId(i);
            max_h = max_h.max(hops(home, t));
            victims.push(t);
        }
        self.invalidations_sent += victims.len() as u64;
        InvalidationFanout {
            victims,
            max_hops_from_home: max_h,
        }
    }

    /// Write by `writer` to `line` homed at `home`: every other sharer is
    /// invalidated; the writer remains the sole sharer.
    pub fn write_invalidate(
        &mut self,
        line: LineId,
        home: TileId,
        writer: TileId,
    ) -> InvalidationFanout {
        let others = self.write_claim(line, writer);
        self.fanout(others, home)
    }

    /// Drop all directory state for lines in `[first, last]` (region free).
    pub fn purge_line_range(&mut self, first: LineId, last: LineId) {
        let lo = first.0 as usize;
        let hi = (last.0 as usize + 1).min(self.sharers.len());
        for slot in self.sharers.get_mut(lo..hi).unwrap_or(&mut []) {
            if *slot != 0 {
                self.tracked -= 1;
                *slot = 0;
            }
        }
    }

    pub fn tracked_lines(&self) -> usize {
        self.tracked
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn add_and_list_sharers() {
        let mut d = Directory::new();
        d.add_sharer(LineId(1), TileId(0));
        d.add_sharer(LineId(1), TileId(63));
        assert_eq!(d.sharers_of(LineId(1)), vec![TileId(0), TileId(63)]);
        assert_eq!(d.sharer_count(LineId(1)), 2);
    }

    #[test]
    fn add_is_idempotent() {
        let mut d = Directory::new();
        d.add_sharer(LineId(1), TileId(5));
        d.add_sharer(LineId(1), TileId(5));
        assert_eq!(d.sharer_count(LineId(1)), 1);
    }

    #[test]
    fn write_invalidates_others_keeps_writer() {
        let mut d = Directory::new();
        for t in [0u32, 7, 12] {
            d.add_sharer(LineId(9), TileId(t));
        }
        let f = d.write_invalidate(LineId(9), TileId(0), TileId(7));
        assert_eq!(f.victims, vec![TileId(0), TileId(12)]);
        assert_eq!(d.sharers_of(LineId(9)), vec![TileId(7)]);
        assert_eq!(d.invalidations_sent, 2);
    }

    #[test]
    fn write_with_no_sharers_is_free() {
        let mut d = Directory::new();
        let f = d.write_invalidate(LineId(1), TileId(0), TileId(3));
        assert!(f.victims.is_empty());
        assert_eq!(f.max_hops_from_home, 0);
        assert_eq!(d.sharers_of(LineId(1)), vec![TileId(3)]);
    }

    #[test]
    fn fanout_hops_is_max_distance() {
        let mut d = Directory::new();
        d.add_sharer(LineId(2), TileId(0)); // corner (0,0)
        d.add_sharer(LineId(2), TileId(63)); // corner (7,7): 14 hops from 0
        let f = d.write_invalidate(LineId(2), TileId(0), TileId(1));
        assert_eq!(f.max_hops_from_home, 14);
    }

    #[test]
    fn remove_sharer_cleans_up() {
        let mut d = Directory::new();
        d.add_sharer(LineId(3), TileId(1));
        d.remove_sharer(LineId(3), TileId(1));
        assert_eq!(d.tracked_lines(), 0);
    }

    #[test]
    fn purge_range_drops_state() {
        let mut d = Directory::new();
        d.add_sharer(LineId(10), TileId(1));
        d.add_sharer(LineId(20), TileId(1));
        d.purge_line_range(LineId(0), LineId(15));
        assert_eq!(d.sharer_count(LineId(10)), 0);
        assert_eq!(d.sharer_count(LineId(20)), 1);
    }
}
