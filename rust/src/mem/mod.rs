//! Simulated memory system: addressing, DDC homing, page table, per-tile
//! allocator, and controller striping — the substrate the paper's
//! programming technique manipulates.

pub mod addr;
pub mod alloc;
pub mod homing;
pub mod page;
pub mod striping;

pub use addr::{line_count, lines_in_range, pages_in_range, LineId, PageId, VAddr};
pub use alloc::{AllocError, Allocator, MemConfig, Region};
pub use homing::{AllocKind, HashPolicy, Homing};
pub use page::{PageAttr, PageFault, PageTable};
pub use striping::{Placement, STRIPE_BYTES};
