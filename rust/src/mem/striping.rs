//! Memory striping across the machine's DDR controllers.
//!
//! Paper §5.3: pages are either allocated behind one specific controller
//! (non-striping: picked by proximity to the page's tile, i.e. first
//! toucher) or striped across all controllers in 8 KB chunks (the default;
//! "Linux boots believing it has a single controller four times larger").
//! The controller count comes from the runtime `Machine` (4 on the
//! tilepro64 preset, so the seed's striping pattern is unchanged). *Where*
//! those controllers attach to the mesh is the machine's
//! [`CtrlPlacement`](crate::arch::CtrlPlacement) (edges by default;
//! sides/corners/interior under a fabric spec): striping picks the
//! controller *id* behind an address, while the placement decides which
//! tile that id's DRAM port hangs off — and therefore every route the
//! NoC bills for the access.

use crate::mem::addr::VAddr;

/// Striping chunk size (8 KB per the TILEPro64 manual).
pub const STRIPE_BYTES: u64 = 8 * 1024;

/// Controller placement of one allocation region.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Placement {
    /// Whole region behind one controller.
    Fixed(u32),
    /// Round-robin 8 KB chunks over all controllers.
    Striped,
    /// Non-striped but not yet placed: resolved to `Fixed(nearest)` when
    /// the page is first touched (see `PageTable::resolve_home`).
    FirstTouchNearest,
}

impl Placement {
    /// Placement for a fresh region in the given boot mode. Non-striped
    /// placement is deferred to first touch; callers that already know the
    /// owning tile (stacks, pre-touched arrays) resolve immediately to
    /// `Fixed(machine.nearest_controller(tile).id)`.
    pub fn for_alloc(striping_enabled: bool) -> Placement {
        if striping_enabled {
            Placement::Striped
        } else {
            Placement::FirstTouchNearest
        }
    }

    /// Which of the machine's `num_controllers` serves the DRAM behind
    /// `addr`. Unresolved placement defaults to controller 0 (only
    /// reachable if a region is queried without ever being accessed).
    #[inline]
    pub fn controller_of(self, addr: VAddr, num_controllers: u32) -> u32 {
        match self {
            Placement::Fixed(c) => c,
            Placement::Striped => ((addr.0 / STRIPE_BYTES) % num_controllers as u64) as u32,
            Placement::FirstTouchNearest => 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const C4: u32 = 4;

    #[test]
    fn striped_round_robins_8k_chunks() {
        let p = Placement::Striped;
        assert_eq!(p.controller_of(VAddr(0), C4), 0);
        assert_eq!(p.controller_of(VAddr(8 * 1024), C4), 1);
        assert_eq!(p.controller_of(VAddr(16 * 1024), C4), 2);
        assert_eq!(p.controller_of(VAddr(24 * 1024), C4), 3);
        assert_eq!(p.controller_of(VAddr(32 * 1024), C4), 0);
    }

    #[test]
    fn striped_constant_within_chunk() {
        let p = Placement::Striped;
        assert_eq!(
            p.controller_of(VAddr(1), C4),
            p.controller_of(VAddr(8 * 1024 - 1), C4)
        );
    }

    #[test]
    fn striped_wraps_at_machine_controller_count() {
        // A single-controller machine (epiphany16) stripes trivially; an
        // 8-controller one (nuca256) uses the full cycle.
        let p = Placement::Striped;
        for chunk in 0..16u64 {
            assert_eq!(p.controller_of(VAddr(chunk * STRIPE_BYTES), 1), 0);
            assert_eq!(
                p.controller_of(VAddr(chunk * STRIPE_BYTES), 8),
                (chunk % 8) as u32
            );
        }
    }

    #[test]
    fn fixed_ignores_address() {
        let p = Placement::Fixed(2);
        for a in [0u64, 9999, 1 << 30] {
            assert_eq!(p.controller_of(VAddr(a), C4), 2);
        }
    }

    #[test]
    fn for_alloc_modes() {
        assert_eq!(Placement::for_alloc(true), Placement::Striped);
        assert_eq!(Placement::for_alloc(false), Placement::FirstTouchNearest);
    }

    #[test]
    fn striped_balances_over_large_region() {
        let p = Placement::Striped;
        let mut counts = [0u32; 4];
        for chunk in 0..4096u64 {
            counts[p.controller_of(VAddr(chunk * STRIPE_BYTES), C4) as usize] += 1;
        }
        assert!(counts.iter().all(|&c| c == 1024), "{counts:?}");
    }
}
