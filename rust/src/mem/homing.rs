//! DDC homing: which tile serves as the coherence home ("L3") for a line.
//!
//! Three classes (paper §2): *local homing* (page homed on the tile that
//! uses it), *remote homing* (homed on some other single tile), and *hash
//! for home* (line-granularity hashing across all tiles). The Tile Linux
//! boot option `ucache_hash` selects the default for user memory:
//! `all-but-stack` (hash everything except stacks) or `none` (single-tile
//! homing).
//!
//! Crucially, under `none` a heap page's home is decided by **first touch**
//! (the page faults in from the toucher's tile), like NUMA first-touch
//! placement. This is the mechanism the paper's localisation exploits: the
//! input array initialised by `main()` is stuck on tile 0, but a chunk
//! copied into a worker's fresh `new int[n]` is first-touched — and
//! therefore homed — on the worker's own tile (Algorithm 1 step 4).
//!
//! Hashes spread over *the machine's* tile count, passed in by the caller
//! (the page table and engine hold the `Machine`); for the tilepro64
//! preset (`num_tiles = 64`) the hash values are identical to the seed's.

use crate::arch::TileId;
use crate::mem::addr::LineId;
use crate::util::rng::mix64;

/// Homing of one page.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Homing {
    /// Entire page homed on one tile (local homing when it's the using
    /// tile, remote homing otherwise — same mechanism).
    Single(TileId),
    /// Hashed across all tiles at cache-line granularity.
    HashForHome,
    /// Hashed across tiles at *page* granularity (not a TILEPro64 mode;
    /// used by the granularity ablation to quantify the paper's "hash for
    /// home at line granularity is too fine-grained" argument).
    PageHash,
    /// Not yet resolved: the first access will home the page on the
    /// accessing tile (`ucache_hash=none` fault-in behaviour).
    FirstTouch,
}

impl Homing {
    /// Effective home tile of a line on a `num_tiles`-tile machine, if
    /// already determined. The hash must be a pure function of the line
    /// address (hardware hashes the PA).
    #[inline]
    pub fn home_of(self, line: LineId, num_tiles: u32) -> Option<TileId> {
        match self {
            Homing::Single(t) => Some(t),
            Homing::HashForHome => {
                Some(TileId((mix64(line.0) % num_tiles as u64) as u32))
            }
            Homing::PageHash => {
                Some(TileId((mix64(line.page().0) % num_tiles as u64) as u32))
            }
            Homing::FirstTouch => None,
        }
    }

    /// Resolve first-touch homing against the touching tile.
    #[inline]
    pub fn resolved(self, toucher: TileId) -> Homing {
        match self {
            Homing::FirstTouch => Homing::Single(toucher),
            h => h,
        }
    }

    /// The home every line of a page shares under this homing, or `None`
    /// when homes vary per line (hash-for-home) or are unresolved.
    /// `any_line_in_page` anchors the page-hash case — any line of the
    /// page gives the same answer. This is the same-home-run test of the
    /// engine's page-run fast path.
    #[inline]
    pub fn uniform_page_home(self, any_line_in_page: LineId, num_tiles: u32) -> Option<TileId> {
        match self {
            Homing::Single(t) => Some(t),
            Homing::PageHash => self.home_of(any_line_in_page, num_tiles),
            Homing::HashForHome | Homing::FirstTouch => None,
        }
    }
}

/// The `ucache_hash` boot option.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum HashPolicy {
    /// Default: hash-for-home for all user memory except stacks.
    AllButStack,
    /// `ucache_hash=none`: single-tile homing, assigned at first touch.
    None,
}

/// What kind of allocation is being homed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AllocKind {
    Heap,
    Stack,
}

impl HashPolicy {
    /// Homing the hypervisor assigns to a fresh page allocated from `tile`
    /// (paper §5: stacks are always homed on the task's tile; heap pages
    /// hash under `all-but-stack` or first-touch under `none`).
    #[inline]
    pub fn homing_for(self, tile: TileId, kind: AllocKind) -> Homing {
        match (self, kind) {
            (HashPolicy::AllButStack, AllocKind::Heap) => Homing::HashForHome,
            (HashPolicy::AllButStack, AllocKind::Stack) => Homing::Single(tile),
            (HashPolicy::None, AllocKind::Heap) => Homing::FirstTouch,
            (HashPolicy::None, AllocKind::Stack) => Homing::Single(tile),
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            HashPolicy::AllButStack => "all-but-stack",
            HashPolicy::None => "none",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const T64: u32 = 64;

    #[test]
    fn single_homing_is_constant() {
        let h = Homing::Single(TileId(5));
        for l in 0..100 {
            assert_eq!(h.home_of(LineId(l), T64), Some(TileId(5)));
        }
    }

    #[test]
    fn hash_for_home_is_deterministic() {
        let h = Homing::HashForHome;
        assert_eq!(h.home_of(LineId(123), T64), h.home_of(LineId(123), T64));
    }

    #[test]
    fn hash_for_home_spreads_lines() {
        let h = Homing::HashForHome;
        let mut seen = std::collections::HashSet::new();
        for l in 0..1024 {
            seen.insert(h.home_of(LineId(l), T64).unwrap());
        }
        // A 1024-line region should touch nearly every tile.
        assert!(seen.len() > 56, "only {} tiles used", seen.len());
    }

    #[test]
    fn hash_for_home_balances_load() {
        let h = Homing::HashForHome;
        let mut counts = [0u32; 64];
        for l in 0..64_000 {
            counts[h.home_of(LineId(l), T64).unwrap().index()] += 1;
        }
        let (min, max) = (
            *counts.iter().min().unwrap(),
            *counts.iter().max().unwrap(),
        );
        assert!(max < min * 2, "imbalanced: min={min} max={max}");
    }

    #[test]
    fn hash_respects_machine_tile_count() {
        // The same lines hash in-range on any machine, including the
        // non-square 4×8 = 32-tile grid.
        for tiles in [4u32, 16, 32, 256] {
            for l in 0..4096u64 {
                let home = Homing::HashForHome.home_of(LineId(l), tiles).unwrap();
                assert!(home.0 < tiles, "home {home:?} out of range on {tiles} tiles");
            }
        }
    }

    #[test]
    fn page_hash_constant_within_page_varies_across() {
        let h = Homing::PageHash;
        let lines_per_page = crate::arch::PAGE_BYTES / crate::arch::LINE_BYTES;
        let first = h.home_of(LineId(0), T64).unwrap();
        for l in 0..lines_per_page {
            assert_eq!(h.home_of(LineId(l), T64).unwrap(), first);
        }
        let homes: std::collections::HashSet<_> = (0..64)
            .map(|p| h.home_of(LineId(p * lines_per_page), T64).unwrap())
            .collect();
        assert!(homes.len() > 32, "pages should spread: {}", homes.len());
    }

    #[test]
    fn first_touch_unresolved_then_resolves() {
        let h = Homing::FirstTouch;
        assert_eq!(h.home_of(LineId(0), T64), None);
        let r = h.resolved(TileId(9));
        assert_eq!(r, Homing::Single(TileId(9)));
        assert_eq!(r.home_of(LineId(0), T64), Some(TileId(9)));
        // Resolution is sticky: a later toucher doesn't re-home.
        assert_eq!(r.resolved(TileId(1)), Homing::Single(TileId(9)));
    }

    #[test]
    fn policy_all_but_stack() {
        let p = HashPolicy::AllButStack;
        assert_eq!(p.homing_for(TileId(3), AllocKind::Heap), Homing::HashForHome);
        assert_eq!(
            p.homing_for(TileId(3), AllocKind::Stack),
            Homing::Single(TileId(3))
        );
    }

    #[test]
    fn policy_none_heap_is_first_touch() {
        let p = HashPolicy::None;
        assert_eq!(p.homing_for(TileId(7), AllocKind::Heap), Homing::FirstTouch);
        assert_eq!(
            p.homing_for(TileId(7), AllocKind::Stack),
            Homing::Single(TileId(7))
        );
    }
}
