//! Page table: per-page homing and controller placement metadata, with
//! first-touch resolution (the fault-in path of `ucache_hash=none`).

use std::sync::Arc;

use crate::arch::{Machine, TileId};
use crate::mem::addr::{LineId, PageId, VAddr};
use crate::mem::homing::Homing;
use crate::mem::striping::Placement;

/// Metadata the hypervisor attaches to a mapped page.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PageAttr {
    pub homing: Homing,
    pub placement: Placement,
}

/// Page table over the simulated address space. The allocator hands out
/// addresses from a compact bump region, so a dense vector indexed by page
/// id beats a tree by an order of magnitude on the hot resolve path (the
/// engine touches it for every simulated cache line). Holds the machine
/// description to size homing hashes and resolve nearest controllers.
#[derive(Debug)]
pub struct PageTable {
    machine: Arc<Machine>,
    pages: Vec<Option<PageAttr>>,
    mapped: usize,
}

#[derive(Debug)]
pub enum PageFault {
    Unmapped(VAddr),
    DoubleMap(PageId),
}

impl std::fmt::Display for PageFault {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PageFault::Unmapped(a) => write!(f, "unmapped address {a:?}"),
            PageFault::DoubleMap(p) => write!(f, "double map of page {p:?}"),
        }
    }
}

impl std::error::Error for PageFault {}

impl PageTable {
    pub fn new(machine: Arc<Machine>) -> Self {
        PageTable {
            machine,
            pages: Vec::new(),
            mapped: 0,
        }
    }

    pub fn machine(&self) -> &Arc<Machine> {
        &self.machine
    }

    #[inline]
    fn slot(&self, page: PageId) -> Option<&Option<PageAttr>> {
        self.pages.get(page.0 as usize)
    }

    /// Map every page overlapping `[addr, addr+bytes)` with `attr`.
    pub fn map_region(&mut self, addr: VAddr, bytes: u64, attr: PageAttr) -> Result<(), PageFault> {
        for p in crate::mem::addr::pages_in_range(addr, bytes) {
            let ix = p.0 as usize;
            if ix >= self.pages.len() {
                self.pages.resize(ix + 1, None);
            }
            if self.pages[ix].is_some() {
                return Err(PageFault::DoubleMap(p));
            }
            self.pages[ix] = Some(attr);
            self.mapped += 1;
        }
        Ok(())
    }

    pub fn unmap_region(&mut self, addr: VAddr, bytes: u64) {
        for p in crate::mem::addr::pages_in_range(addr, bytes) {
            if let Some(slot) = self.pages.get_mut(p.0 as usize) {
                if slot.take().is_some() {
                    self.mapped -= 1;
                }
            }
        }
    }

    pub fn attr_of(&self, page: PageId) -> Option<PageAttr> {
        self.slot(page).copied().flatten()
    }

    /// Home tile of a line, resolving first-touch homing (and first-touch
    /// DRAM placement) against `toucher` — the fault-in path. This is the
    /// engine's hottest lookup: one call per simulated cache line.
    #[inline]
    pub fn resolve_home(&mut self, line: LineId, toucher: TileId) -> Result<TileId, PageFault> {
        let num_tiles = self.machine.num_tiles();
        let attr = self
            .pages
            .get_mut(line.page().0 as usize)
            .and_then(|s| s.as_mut())
            .ok_or(PageFault::Unmapped(line.addr()))?;
        if matches!(attr.homing, Homing::FirstTouch) {
            attr.homing = attr.homing.resolved(toucher);
        }
        if matches!(attr.placement, Placement::FirstTouchNearest) {
            attr.placement = Placement::Fixed(self.machine.nearest_controller(toucher).id);
        }
        Ok(attr
            .homing
            .home_of(line, num_tiles)
            .expect("homing resolved above"))
    }

    /// Resolve a whole page's attributes once — first-touch homing and
    /// placement fault in against `toucher`, exactly as the first
    /// [`resolve_home`](Self::resolve_home) on any of its lines would —
    /// and return a copy. The engine's page-run fast path calls this once
    /// per page instead of `resolve_home` once per line; homing is
    /// per-page metadata, so the resolved attr is valid for every line of
    /// the page.
    #[inline]
    pub fn resolve_page(&mut self, page: PageId, toucher: TileId) -> Result<PageAttr, PageFault> {
        let attr = self
            .pages
            .get_mut(page.0 as usize)
            .and_then(|s| s.as_mut())
            .ok_or(PageFault::Unmapped(page.addr()))?;
        if matches!(attr.homing, Homing::FirstTouch) {
            attr.homing = attr.homing.resolved(toucher);
        }
        if matches!(attr.placement, Placement::FirstTouchNearest) {
            attr.placement = Placement::Fixed(self.machine.nearest_controller(toucher).id);
        }
        Ok(*attr)
    }

    /// Home of a line if already determined (read-only; tests/reports).
    pub fn home_of_line(&self, line: LineId) -> Result<Option<TileId>, PageFault> {
        let attr = self
            .attr_of(line.page())
            .ok_or(PageFault::Unmapped(line.addr()))?;
        Ok(attr.homing.home_of(line, self.machine.num_tiles()))
    }

    /// Pre-resolve every page of a region as touched by `tile` (models
    /// `main()` initialising an array before the parallel section).
    pub fn touch_region(&mut self, addr: VAddr, bytes: u64, tile: TileId) {
        for p in crate::mem::addr::pages_in_range(addr, bytes) {
            if let Some(attr) = self.pages.get_mut(p.0 as usize).and_then(|s| s.as_mut()) {
                if matches!(attr.homing, Homing::FirstTouch) {
                    attr.homing = attr.homing.resolved(tile);
                }
                if matches!(attr.placement, Placement::FirstTouchNearest) {
                    attr.placement = Placement::Fixed(self.machine.nearest_controller(tile).id);
                }
            }
        }
    }

    /// DRAM controller behind a line (must be resolved or striped/fixed).
    #[inline]
    pub fn controller_of_line(&self, line: LineId) -> Result<u32, PageFault> {
        let attr = self
            .attr_of(line.page())
            .ok_or(PageFault::Unmapped(line.addr()))?;
        Ok(attr
            .placement
            .controller_of(line.addr(), self.machine.num_controllers()))
    }

    pub fn mapped_pages(&self) -> usize {
        self.mapped
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::PAGE_BYTES;
    use crate::mem::homing::Homing;

    fn table() -> PageTable {
        PageTable::new(Arc::new(Machine::tilepro64()))
    }

    fn attr(t: u32) -> PageAttr {
        PageAttr {
            homing: Homing::Single(TileId(t)),
            placement: Placement::Fixed(0),
        }
    }

    fn ft_attr() -> PageAttr {
        PageAttr {
            homing: Homing::FirstTouch,
            placement: Placement::FirstTouchNearest,
        }
    }

    #[test]
    fn map_and_lookup() {
        let mut pt = table();
        pt.map_region(VAddr(0), 2 * PAGE_BYTES, attr(4)).unwrap();
        assert_eq!(pt.home_of_line(LineId(0)).unwrap(), Some(TileId(4)));
        assert_eq!(
            pt.home_of_line(VAddr(2 * PAGE_BYTES - 1).line()).unwrap(),
            Some(TileId(4))
        );
        assert!(pt.home_of_line(VAddr(2 * PAGE_BYTES).line()).is_err());
    }

    #[test]
    fn double_map_rejected() {
        let mut pt = table();
        pt.map_region(VAddr(0), PAGE_BYTES, attr(1)).unwrap();
        assert!(pt.map_region(VAddr(0), 1, attr(2)).is_err());
    }

    #[test]
    fn unmap_releases() {
        let mut pt = table();
        pt.map_region(VAddr(0), PAGE_BYTES, attr(1)).unwrap();
        pt.unmap_region(VAddr(0), PAGE_BYTES);
        assert_eq!(pt.mapped_pages(), 0);
        pt.map_region(VAddr(0), PAGE_BYTES, attr(2)).unwrap();
        assert_eq!(pt.home_of_line(LineId(0)).unwrap(), Some(TileId(2)));
    }

    #[test]
    fn first_touch_resolves_to_toucher() {
        let mut pt = table();
        pt.map_region(VAddr(0), PAGE_BYTES, ft_attr()).unwrap();
        assert_eq!(pt.home_of_line(LineId(0)).unwrap(), None);
        let home = pt.resolve_home(LineId(0), TileId(13)).unwrap();
        assert_eq!(home, TileId(13));
        // Sticky: a different tile touching later does not re-home.
        let home = pt.resolve_home(LineId(1), TileId(50)).unwrap();
        assert_eq!(home, TileId(13), "page homing is per-page and sticky");
        // Placement resolved to tile 13's nearest controller.
        assert!(pt.controller_of_line(LineId(0)).is_ok());
    }

    #[test]
    fn touch_region_pre_resolves() {
        let mut pt = table();
        pt.map_region(VAddr(0), 2 * PAGE_BYTES, ft_attr()).unwrap();
        pt.touch_region(VAddr(0), 2 * PAGE_BYTES, TileId(0));
        assert_eq!(pt.home_of_line(LineId(0)).unwrap(), Some(TileId(0)));
        let far_line = VAddr(PAGE_BYTES).line();
        assert_eq!(pt.home_of_line(far_line).unwrap(), Some(TileId(0)));
    }

    #[test]
    fn different_pages_home_independently() {
        let mut pt = table();
        pt.map_region(VAddr(0), 2 * PAGE_BYTES, ft_attr()).unwrap();
        pt.resolve_home(LineId(0), TileId(3)).unwrap();
        let second_page_line = VAddr(PAGE_BYTES).line();
        let home = pt.resolve_home(second_page_line, TileId(7)).unwrap();
        assert_eq!(home, TileId(7));
        assert_eq!(pt.home_of_line(LineId(0)).unwrap(), Some(TileId(3)));
    }

    #[test]
    fn hash_for_home_line_granularity() {
        let mut pt = table();
        pt.map_region(
            VAddr(0),
            PAGE_BYTES,
            PageAttr {
                homing: Homing::HashForHome,
                placement: Placement::Striped,
            },
        )
        .unwrap();
        let homes: std::collections::HashSet<_> = (0..1024)
            .map(|l| pt.home_of_line(LineId(l)).unwrap().unwrap())
            .collect();
        assert!(homes.len() > 32, "hash-for-home must spread within a page");
    }

    #[test]
    fn first_touch_placement_follows_controller_placement() {
        // The non-striped fault-in path resolves to the *machine's*
        // nearest controller — so a corner-placed fabric redirects the
        // page's DRAM to a different controller than the edge default.
        use crate::arch::FabricSpec;
        let corners = Machine::tilepro64()
            .with_fabric(&FabricSpec::parse("ctrl=corners").unwrap())
            .unwrap();
        let mut edge_pt = table();
        let mut corner_pt = PageTable::new(Arc::new(corners));
        for pt in [&mut edge_pt, &mut corner_pt] {
            pt.map_region(VAddr(0), PAGE_BYTES, ft_attr()).unwrap();
            // Touch from tile 56 = (0,7): bottom-left corner.
            pt.resolve_home(LineId(0), TileId(56)).unwrap();
        }
        // Edge layout: nearest is controller 2 (attach (2,7)); corner
        // layout: nearest is the (0,7) corner controller.
        let edge_ctrl = edge_pt.controller_of_line(LineId(0)).unwrap();
        let corner_ctrl = corner_pt.controller_of_line(LineId(0)).unwrap();
        assert_eq!(edge_ctrl, 2);
        let corner_attach = corner_pt.machine().controller(corner_ctrl).attach;
        assert_eq!(corner_attach, TileId(56), "corner placement must win");
    }

    #[test]
    fn unmapped_controller_faults() {
        let pt = table();
        assert!(pt.controller_of_line(LineId(99)).is_err());
    }

    #[test]
    fn resolve_on_unmapped_faults() {
        let mut pt = table();
        assert!(pt.resolve_home(LineId(5), TileId(0)).is_err());
    }
}
