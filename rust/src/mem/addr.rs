//! Virtual addresses and line/page arithmetic.
//!
//! The TILEPro64 exposes a 32-bit virtual / 36-bit physical space; the
//! simulator uses a flat 36-bit space with 64 B lines and 64 KB pages.

use crate::arch::{LINE_BYTES, PAGE_BYTES};

/// Simulated virtual address.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Debug)]
pub struct VAddr(pub u64);

/// Cache-line index (addr / 64).
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Debug)]
pub struct LineId(pub u64);

/// Page index (addr / 64 KiB).
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Debug)]
pub struct PageId(pub u64);

impl VAddr {
    #[inline]
    pub fn line(self) -> LineId {
        LineId(self.0 / LINE_BYTES)
    }

    #[inline]
    pub fn page(self) -> PageId {
        PageId(self.0 / PAGE_BYTES)
    }

    #[inline]
    pub fn offset(self, bytes: u64) -> VAddr {
        VAddr(self.0 + bytes)
    }
}

impl LineId {
    #[inline]
    pub fn addr(self) -> VAddr {
        VAddr(self.0 * LINE_BYTES)
    }

    #[inline]
    pub fn page(self) -> PageId {
        PageId(self.0 * LINE_BYTES / PAGE_BYTES)
    }
}

impl PageId {
    #[inline]
    pub fn addr(self) -> VAddr {
        VAddr(self.0 * PAGE_BYTES)
    }
}

/// Iterate the line ids touched by `[addr, addr + bytes)`.
pub fn lines_in_range(addr: VAddr, bytes: u64) -> impl Iterator<Item = LineId> {
    let first = addr.0 / LINE_BYTES;
    let last = if bytes == 0 {
        first // empty: yields nothing via the range below
    } else {
        (addr.0 + bytes - 1) / LINE_BYTES + 1
    };
    (first..last).map(LineId)
}

/// Number of lines touched by `[addr, addr + bytes)` (O(1)).
pub fn line_count(addr: VAddr, bytes: u64) -> u64 {
    if bytes == 0 {
        return 0;
    }
    (addr.0 + bytes - 1) / LINE_BYTES - addr.0 / LINE_BYTES + 1
}

/// Pages overlapped by `[addr, addr + bytes)`.
pub fn pages_in_range(addr: VAddr, bytes: u64) -> impl Iterator<Item = PageId> {
    let first = addr.0 / PAGE_BYTES;
    let last = if bytes == 0 {
        first
    } else {
        (addr.0 + bytes - 1) / PAGE_BYTES + 1
    };
    (first..last).map(PageId)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn line_and_page_of_addr() {
        let a = VAddr(64 * 1024 + 65);
        assert_eq!(a.line(), LineId(1025));
        assert_eq!(a.page(), PageId(1));
    }

    #[test]
    fn lines_in_range_aligned() {
        let ls: Vec<_> = lines_in_range(VAddr(0), 256).collect();
        assert_eq!(ls, vec![LineId(0), LineId(1), LineId(2), LineId(3)]);
    }

    #[test]
    fn lines_in_range_unaligned_straddles() {
        // [60, 70) straddles lines 0 and 1.
        let ls: Vec<_> = lines_in_range(VAddr(60), 10).collect();
        assert_eq!(ls, vec![LineId(0), LineId(1)]);
    }

    #[test]
    fn lines_in_range_empty() {
        assert_eq!(lines_in_range(VAddr(100), 0).count(), 0);
        assert_eq!(line_count(VAddr(100), 0), 0);
    }

    #[test]
    fn line_count_matches_iterator() {
        for (addr, bytes) in [(0u64, 1u64), (63, 2), (64, 64), (1, 10_000), (4096, 65_536)] {
            assert_eq!(
                line_count(VAddr(addr), bytes),
                lines_in_range(VAddr(addr), bytes).count() as u64,
                "addr={addr} bytes={bytes}"
            );
        }
    }

    #[test]
    fn pages_in_range_spans_boundary() {
        let ps: Vec<_> = pages_in_range(VAddr(64 * 1024 - 1), 2).collect();
        assert_eq!(ps, vec![PageId(0), PageId(1)]);
    }

    #[test]
    fn single_byte_is_one_line() {
        assert_eq!(line_count(VAddr(127), 1), 1);
    }
}
