//! Per-tile heap allocator over the simulated address space.
//!
//! Allocation is the *mechanism of the paper's technique*: a thread that
//! `new[]`s a chunk gets pages whose homing is decided by the boot-time
//! `HashPolicy` and the allocating tile — so copying a chunk into a fresh
//! allocation from the worker thread is exactly what re-homes it (Algorithm
//! 1 step 4). Freeing (step 5) recycles address space and purges stale
//! cache state (the engine hooks `free` for that).

use crate::arch::{Machine, TileId, PAGE_BYTES};
use crate::mem::addr::VAddr;
use crate::mem::homing::{AllocKind, HashPolicy, Homing};
use crate::mem::page::{PageAttr, PageFault, PageTable};
use crate::mem::striping::Placement;
use std::collections::BTreeMap;
use std::sync::Arc;

/// One live allocation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Region {
    pub addr: VAddr,
    /// Requested bytes (page-rounded internally).
    pub bytes: u64,
    /// Tile that performed the allocation.
    pub tile: TileId,
    pub kind: AllocKind,
}

impl Region {
    /// Sub-range of this region, `elems` of `esize` bytes from `start_elem`.
    pub fn slice(&self, start_elem: u64, elems: u64, esize: u64) -> (VAddr, u64) {
        let off = start_elem * esize;
        let len = elems * esize;
        debug_assert!(off + len <= self.bytes, "slice out of bounds");
        (self.addr.offset(off), len)
    }
}

#[derive(Debug)]
pub enum AllocError {
    Page(PageFault),
    UnknownFree(VAddr),
    Zero,
}

impl std::fmt::Display for AllocError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AllocError::Page(e) => write!(f, "page fault: {e}"),
            AllocError::UnknownFree(a) => write!(f, "free of unknown address {a:?}"),
            AllocError::Zero => write!(f, "zero-byte allocation"),
        }
    }
}

impl std::error::Error for AllocError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            AllocError::Page(e) => Some(e),
            _ => None,
        }
    }
}

impl From<PageFault> for AllocError {
    fn from(e: PageFault) -> AllocError {
        AllocError::Page(e)
    }
}

/// Boot-time memory configuration (the knobs of Table 1 / Fig. 4).
#[derive(Clone, Copy, Debug)]
pub struct MemConfig {
    pub hash_policy: HashPolicy,
    pub striping: bool,
}

pub struct Allocator {
    pub table: PageTable,
    config: MemConfig,
    next: u64,
    /// Size-class free lists (rounded bytes → addresses), so the paper's
    /// alloc/free-per-level merge pattern reuses address space instead of
    /// growing without bound.
    free: BTreeMap<u64, Vec<VAddr>>,
    live: BTreeMap<VAddr, Region>,
    /// Cumulative counters for reports.
    pub allocs: u64,
    pub frees: u64,
}

impl Allocator {
    pub fn new(machine: Arc<Machine>, config: MemConfig) -> Self {
        Allocator {
            table: PageTable::new(machine),
            config,
            // Start above the null page.
            next: PAGE_BYTES,
            free: BTreeMap::new(),
            live: BTreeMap::new(),
            allocs: 0,
            frees: 0,
        }
    }

    pub fn config(&self) -> MemConfig {
        self.config
    }

    fn rounded(bytes: u64) -> u64 {
        bytes.div_ceil(PAGE_BYTES) * PAGE_BYTES
    }

    /// Allocate `bytes` from `tile`; homing/placement follow the boot
    /// config (what `new[]` does in the paper's code).
    pub fn alloc(&mut self, tile: TileId, bytes: u64, kind: AllocKind) -> Result<Region, AllocError> {
        let homing = self.config.hash_policy.homing_for(tile, kind);
        let placement = if self.config.striping {
            Placement::Striped
        } else if matches!(homing, Homing::FirstTouch) {
            // Non-striped placement follows the page's eventual home.
            Placement::FirstTouchNearest
        } else {
            // Stacks and hashed pages: DRAM placed near the allocating tile.
            Placement::Fixed(self.table.machine().nearest_controller(tile).id)
        };
        self.alloc_with(tile, bytes, kind, homing, placement)
    }

    /// Allocate with explicit homing/placement (remote homing experiments
    /// and tests use this; the public API path goes through `alloc`).
    pub fn alloc_with(
        &mut self,
        tile: TileId,
        bytes: u64,
        kind: AllocKind,
        homing: Homing,
        placement: Placement,
    ) -> Result<Region, AllocError> {
        if bytes == 0 {
            return Err(AllocError::Zero);
        }
        let rounded = Self::rounded(bytes);
        let addr = match self.free.get_mut(&rounded).and_then(|v| v.pop()) {
            Some(a) => a,
            None => {
                let a = VAddr(self.next);
                self.next += rounded;
                a
            }
        };
        self.table
            .map_region(addr, rounded, PageAttr { homing, placement })?;
        let region = Region {
            addr,
            bytes,
            tile,
            kind,
        };
        self.live.insert(addr, region);
        self.allocs += 1;
        Ok(region)
    }

    /// Free a region; returns it so the cache layer can purge its lines.
    pub fn free(&mut self, addr: VAddr) -> Result<Region, AllocError> {
        let region = self
            .live
            .remove(&addr)
            .ok_or(AllocError::UnknownFree(addr))?;
        let rounded = Self::rounded(region.bytes);
        self.table.unmap_region(region.addr, rounded);
        self.free.entry(rounded).or_default().push(addr);
        self.frees += 1;
        Ok(region)
    }

    pub fn live_regions(&self) -> usize {
        self.live.len()
    }

    /// Total address space handed out (high-water mark), for reports.
    pub fn high_water_bytes(&self) -> u64 {
        self.next - PAGE_BYTES
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::addr::LineId;

    fn alloc_default(policy: HashPolicy, striping: bool) -> Allocator {
        Allocator::new(
            Arc::new(Machine::tilepro64()),
            MemConfig {
                hash_policy: policy,
                striping,
            },
        )
    }

    #[test]
    fn heap_alloc_hash_policy_all_but_stack() {
        let mut a = alloc_default(HashPolicy::AllButStack, true);
        let heap = a.alloc(TileId(3), 1024, AllocKind::Heap).unwrap();
        let stack = a.alloc(TileId(3), 1024, AllocKind::Stack).unwrap();
        // Heap pages hash across tiles; stack pages home on tile 3.
        let homes: std::collections::HashSet<_> = (0..512)
            .map(|i| {
                a.table
                    .home_of_line(LineId(heap.addr.line().0 + i))
                    .unwrap()
                    .unwrap()
            })
            .collect();
        assert!(homes.len() > 16);
        assert_eq!(
            a.table.home_of_line(stack.addr.line()).unwrap(),
            Some(TileId(3))
        );
    }

    #[test]
    fn heap_alloc_policy_none_homes_at_first_touch() {
        let mut a = alloc_default(HashPolicy::None, true);
        let r = a.alloc(TileId(9), 256 * 1024, AllocKind::Heap).unwrap();
        // Unresolved until touched…
        assert_eq!(a.table.home_of_line(r.addr.line()).unwrap(), None);
        // …then homed on the toucher, NOT the allocator: this is the
        // localisation mechanism (worker copies ⇒ worker-homed pages).
        let home = a.table.resolve_home(r.addr.line(), TileId(22)).unwrap();
        assert_eq!(home, TileId(22));
        for i in [1u64, 100, 1000] {
            assert_eq!(
                a.table
                    .resolve_home(LineId(r.addr.line().0 + i), TileId(50))
                    .unwrap(),
                TileId(22),
                "same page stays on first toucher"
            );
        }
    }

    #[test]
    fn regions_do_not_overlap() {
        let mut a = alloc_default(HashPolicy::None, true);
        let r1 = a.alloc(TileId(0), 100, AllocKind::Heap).unwrap();
        let r2 = a.alloc(TileId(1), 100, AllocKind::Heap).unwrap();
        let end1 = r1.addr.0 + Allocator::rounded(r1.bytes);
        assert!(r2.addr.0 >= end1 || r1.addr.0 >= r2.addr.0 + Allocator::rounded(r2.bytes));
    }

    #[test]
    fn free_then_realloc_reuses_address_and_rehomes() {
        let mut a = alloc_default(HashPolicy::None, true);
        let r1 = a.alloc(TileId(0), PAGE_BYTES, AllocKind::Heap).unwrap();
        a.table.resolve_home(r1.addr.line(), TileId(0)).unwrap();
        a.free(r1.addr).unwrap();
        let r2 = a.alloc(TileId(5), PAGE_BYTES, AllocKind::Heap).unwrap();
        assert_eq!(r1.addr, r2.addr);
        // Fresh pages: first-touch decides again (step 4 of Algorithm 1).
        assert_eq!(a.table.home_of_line(r2.addr.line()).unwrap(), None);
        assert_eq!(
            a.table.resolve_home(r2.addr.line(), TileId(5)).unwrap(),
            TileId(5)
        );
    }

    #[test]
    fn double_free_errors() {
        let mut a = alloc_default(HashPolicy::None, true);
        let r = a.alloc(TileId(0), 64, AllocKind::Heap).unwrap();
        a.free(r.addr).unwrap();
        assert!(a.free(r.addr).is_err());
    }

    #[test]
    fn zero_alloc_errors() {
        let mut a = alloc_default(HashPolicy::None, true);
        assert!(a.alloc(TileId(0), 0, AllocKind::Heap).is_err());
    }

    #[test]
    fn striping_mode_reflected_in_controller() {
        let mut s = alloc_default(HashPolicy::None, true);
        let r = s.alloc(TileId(0), 64 * 1024, AllocKind::Heap).unwrap();
        let c0 = s.table.controller_of_line(r.addr.line()).unwrap();
        let c1 = s
            .table
            .controller_of_line(r.addr.offset(8 * 1024).line())
            .unwrap();
        assert_ne!(c0, c1, "striped region must alternate controllers");

        let mut ns = alloc_default(HashPolicy::None, false);
        let r = ns.alloc(TileId(63), 64 * 1024, AllocKind::Heap).unwrap();
        // Resolve by first touch from tile 63 (bottom row → controller 2/3).
        ns.table.resolve_home(r.addr.line(), TileId(63)).unwrap();
        let c0 = ns.table.controller_of_line(r.addr.line()).unwrap();
        let c1 = ns
            .table
            .controller_of_line(r.addr.offset(8 * 1024).line())
            .unwrap();
        assert_eq!(c0, c1, "non-striped region stays on one controller");
        assert!(c0 >= 2, "placed near the touching tile");
    }

    #[test]
    fn non_striped_hashed_heap_places_near_allocator() {
        let mut ns = alloc_default(HashPolicy::AllButStack, false);
        let r = ns.alloc(TileId(0), 64 * 1024, AllocKind::Heap).unwrap();
        let c = ns.table.controller_of_line(r.addr.line()).unwrap();
        assert!(c < 2, "tile 0 is near the top controllers");
    }

    #[test]
    fn slice_arithmetic() {
        let mut a = alloc_default(HashPolicy::None, true);
        let r = a.alloc(TileId(0), 4096, AllocKind::Heap).unwrap();
        let (addr, len) = r.slice(10, 20, 4);
        assert_eq!(addr.0, r.addr.0 + 40);
        assert_eq!(len, 80);
    }

    #[test]
    fn live_region_count_tracks() {
        let mut a = alloc_default(HashPolicy::None, true);
        let r1 = a.alloc(TileId(0), 64, AllocKind::Heap).unwrap();
        let _r2 = a.alloc(TileId(0), 64, AllocKind::Heap).unwrap();
        assert_eq!(a.live_regions(), 2);
        a.free(r1.addr).unwrap();
        assert_eq!(a.live_regions(), 1);
        assert_eq!(a.allocs, 2);
        assert_eq!(a.frees, 1);
    }
}
