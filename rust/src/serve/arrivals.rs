//! Seeded open-loop arrival processes.
//!
//! An *open-loop* load generator emits requests on its own clock,
//! regardless of whether the server keeps up — the regime where queueing
//! delay and tail latency actually show (a closed loop self-throttles and
//! hides the saturation knee). Two shapes:
//!
//! - **Poisson**: independent exponential inter-arrival gaps — the
//!   classic memoryless stream.
//! - **Bursty**: requests arrive in back-to-back clumps of `burst`
//!   (same-cycle), with exponential gaps between clumps scaled up by the
//!   burst size so the *mean* rate matches the Poisson stream.
//!
//! Determinism contract: the gap sequence is a pure function of
//! `(spec, seed)` — the underlying uniform draws do **not** depend on the
//! configured rate, so re-rating a scenario rescales every gap pointwise.
//! That is what makes per-request latency *provably* monotone in offered
//! load for a FIFO scenario (`prop_serve` pins it) rather than only
//! statistically so.

use crate::util::rng::Rng;

/// Which arrival shape a serve scenario drives (`--arrival`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ArrivalSpec {
    /// Independent exponential gaps.
    Poisson,
    /// Clumps of `burst` same-cycle arrivals, exponential gaps between
    /// clumps, same mean rate as Poisson.
    Bursty { burst: u32 },
}

impl ArrivalSpec {
    /// Parse `poisson`, `bursty` (burst of 8), or `bursty@K`.
    pub fn parse(s: &str) -> Result<ArrivalSpec, String> {
        match s {
            "poisson" => Ok(ArrivalSpec::Poisson),
            "bursty" => Ok(ArrivalSpec::Bursty { burst: 8 }),
            _ => match s.strip_prefix("bursty@").and_then(|k| k.parse::<u32>().ok()) {
                Some(burst) if burst >= 2 => Ok(ArrivalSpec::Bursty { burst }),
                _ => Err(format!(
                    "bad arrival process '{s}': want poisson | bursty | bursty@K (K >= 2)"
                )),
            },
        }
    }

    pub fn label(self) -> String {
        match self {
            ArrivalSpec::Poisson => "poisson".into(),
            ArrivalSpec::Bursty { burst } => format!("bursty@{burst}"),
        }
    }
}

impl Default for ArrivalSpec {
    fn default() -> Self {
        ArrivalSpec::Poisson
    }
}

/// Quantised exponential variate: `ceil(-ln(1-u) * mean)` cycles, floored
/// at 1 so time always advances between (clumps of) arrivals. Monotone in
/// `mean` for a fixed draw `u` — the pointwise-rescaling property above.
fn exp_gap(u: f64, mean: f64) -> u64 {
    let e = -(1.0 - u).ln();
    (e * mean).ceil().max(1.0) as u64
}

/// The generator: yields inter-arrival gaps in cycles, one per request.
pub struct ArrivalGen {
    rng: Rng,
    spec: ArrivalSpec,
    mean_gap: f64,
    emitted: u64,
}

impl ArrivalGen {
    /// `mean_gap` is the target mean inter-arrival time in cycles (the
    /// inverse of the offered rate). Values below 1 cycle saturate at 1.
    pub fn new(spec: ArrivalSpec, mean_gap: f64, seed: u64) -> ArrivalGen {
        ArrivalGen {
            // Fork a dedicated stream so arrival draws can never collide
            // with a workload that happens to share the scenario seed.
            rng: Rng::new(seed).fork(0x5e7e),
            spec,
            mean_gap: mean_gap.max(1.0),
            emitted: 0,
        }
    }

    /// Gap in cycles between the previous request and the next one
    /// (0 = same cycle, inside a burst).
    pub fn next_gap(&mut self) -> u64 {
        let i = self.emitted;
        self.emitted += 1;
        match self.spec {
            ArrivalSpec::Poisson => exp_gap(self.rng.f64(), self.mean_gap),
            ArrivalSpec::Bursty { burst } => {
                if i % burst as u64 == 0 {
                    exp_gap(self.rng.f64(), self.mean_gap * burst as f64)
                } else {
                    0
                }
            }
        }
    }

    /// Absolute arrival times for `n` requests (cumulative gaps) — the
    /// statistical tests and the docs examples read the stream this way.
    pub fn arrival_times(spec: ArrivalSpec, mean_gap: f64, seed: u64, n: u64) -> Vec<u64> {
        let mut g = ArrivalGen::new(spec, mean_gap, seed);
        let mut t = 0u64;
        (0..n)
            .map(|_| {
                t += g.next_gap();
                t
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_round_trips_and_rejects_garbage() {
        for s in ["poisson", "bursty@4", "bursty@16"] {
            assert_eq!(ArrivalSpec::parse(s).unwrap().label(), s);
        }
        assert_eq!(
            ArrivalSpec::parse("bursty").unwrap(),
            ArrivalSpec::Bursty { burst: 8 }
        );
        for s in ["", "burst", "bursty@1", "bursty@", "bursty@x", "uniform"] {
            assert!(ArrivalSpec::parse(s).is_err(), "{s} must not parse");
        }
    }

    #[test]
    fn identical_seeds_yield_identical_streams() {
        for spec in [ArrivalSpec::Poisson, ArrivalSpec::Bursty { burst: 4 }] {
            let a = ArrivalGen::arrival_times(spec, 500.0, 42, 1000);
            let b = ArrivalGen::arrival_times(spec, 500.0, 42, 1000);
            assert_eq!(a, b, "{}", spec.label());
            let c = ArrivalGen::arrival_times(spec, 500.0, 43, 1000);
            assert_ne!(a, c, "a different seed must move the stream");
        }
    }

    #[test]
    fn poisson_mean_gap_within_tolerance() {
        // 20k exponential samples: the sample mean's std error is
        // mean/sqrt(n) ≈ 0.7%; a 5% band is comfortably away from flaky
        // while still catching a wrong rate by construction.
        let n = 20_000u64;
        let mean = 1000.0;
        let times = ArrivalGen::arrival_times(ArrivalSpec::Poisson, mean, 7, n);
        let empirical = *times.last().unwrap() as f64 / n as f64;
        assert!(
            (empirical - mean).abs() / mean < 0.05,
            "empirical mean gap {empirical} vs configured {mean}"
        );
    }

    #[test]
    fn bursty_matches_rate_and_clumps() {
        let n = 20_000u64;
        let mean = 1000.0;
        let times = ArrivalGen::arrival_times(ArrivalSpec::Bursty { burst: 8 }, mean, 7, n);
        let empirical = *times.last().unwrap() as f64 / n as f64;
        assert!(
            (empirical - mean).abs() / mean < 0.05,
            "bursty stream must keep the Poisson mean rate, got {empirical}"
        );
        // Clump shape: within a burst, arrivals share a cycle.
        assert_eq!(times[1], times[0], "burst members arrive together");
        assert!(times[8] > times[7], "bursts are separated by a real gap");
    }

    #[test]
    fn higher_rate_means_pointwise_earlier_arrivals() {
        // The load-monotonicity keystone: same seed, shorter mean gap ⇒
        // every arrival happens no later.
        for spec in [ArrivalSpec::Poisson, ArrivalSpec::Bursty { burst: 4 }] {
            let slow = ArrivalGen::arrival_times(spec, 2000.0, 11, 2000);
            let fast = ArrivalGen::arrival_times(spec, 500.0, 11, 2000);
            assert!(
                slow.iter().zip(&fast).all(|(s, f)| f <= s),
                "{}: rescaling the rate must rescale gaps pointwise",
                spec.label()
            );
        }
    }

    #[test]
    fn gaps_always_advance_between_clumps() {
        let mut g = ArrivalGen::new(ArrivalSpec::Poisson, 1.0, 3);
        for _ in 0..1000 {
            assert!(g.next_gap() >= 1, "poisson gaps are floored at one cycle");
        }
    }
}
