//! Seeded open-loop arrival processes.
//!
//! An *open-loop* load generator emits requests on its own clock,
//! regardless of whether the server keeps up — the regime where queueing
//! delay and tail latency actually show (a closed loop self-throttles and
//! hides the saturation knee). Two shapes:
//!
//! - **Poisson**: independent exponential inter-arrival gaps — the
//!   classic memoryless stream.
//! - **Bursty**: requests arrive in back-to-back clumps of `burst`
//!   (same-cycle), with exponential gaps between clumps scaled up by the
//!   burst size so the *mean* rate matches the Poisson stream.
//!
//! Determinism contract: the gap sequence is a pure function of
//! `(spec, seed)` — the underlying uniform draws do **not** depend on the
//! configured rate, so re-rating a scenario rescales every gap pointwise.
//! That is what makes per-request latency *provably* monotone in offered
//! load for a FIFO scenario (`prop_serve` pins it) rather than only
//! statistically so.

use crate::util::cli::parse_usize;
use crate::util::rng::Rng;

/// Which arrival shape a serve scenario drives (`--arrival`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ArrivalSpec {
    /// Independent exponential gaps.
    Poisson,
    /// Clumps of `burst` same-cycle arrivals, exponential gaps between
    /// clumps, same mean rate as Poisson.
    Bursty { burst: u32 },
}

impl ArrivalSpec {
    /// Parse `poisson`, `bursty` (burst of 8), or `bursty@K`.
    pub fn parse(s: &str) -> Result<ArrivalSpec, String> {
        match s {
            "poisson" => Ok(ArrivalSpec::Poisson),
            "bursty" => Ok(ArrivalSpec::Bursty { burst: 8 }),
            _ => match s.strip_prefix("bursty@").and_then(|k| k.parse::<u32>().ok()) {
                Some(burst) if burst >= 2 => Ok(ArrivalSpec::Bursty { burst }),
                _ => Err(format!(
                    "bad arrival process '{s}': want poisson | bursty | bursty@K (K >= 2)"
                )),
            },
        }
    }

    pub fn label(self) -> String {
        match self {
            ArrivalSpec::Poisson => "poisson".into(),
            ArrivalSpec::Bursty { burst } => format!("bursty@{burst}"),
        }
    }
}

impl Default for ArrivalSpec {
    fn default() -> Self {
        ArrivalSpec::Poisson
    }
}

/// The request-size distribution of a serve scenario (`--size`): either a
/// single fixed size (the classic stream) or a percentage mix such as
/// `80%4ki,20%64ki` — each arrival draws its element count from the mix.
///
/// Determinism contract: size draws come from their **own** forked rng
/// stream ([`SizeMix::rng_for`]), never from the arrival-gap stream, so
/// adding a mix to a scenario does not move a single arrival time — and a
/// degenerate single-size mix consumes no draws at all, keeping
/// fixed-size records byte-identical to the pre-mix driver.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SizeMix {
    /// `(percent, elems)` clauses; percentages sum to 100. A single
    /// clause at 100% is the fixed-size stream.
    clauses: Vec<(u32, u64)>,
}

impl SizeMix {
    /// The fixed-size stream every scenario starts from.
    pub fn single(elems: u64) -> SizeMix {
        SizeMix { clauses: vec![(100, elems)] }
    }

    /// Parse a `--size` argument: a plain element count (`4096`, `16ki`)
    /// or a mix of `PCT%ELEMS` clauses summing to 100
    /// (`80%4ki,20%64ki`). Labels round-trip (sizes normalise to digits).
    pub fn parse(s: &str) -> Result<SizeMix, String> {
        let err = || {
            format!(
                "bad --size '{s}': want ELEMS or a mix PCT%ELEMS,... summing to 100 \
                 (e.g. 80%4ki,20%64ki)"
            )
        };
        if !s.contains('%') {
            let elems = parse_usize(s).filter(|&e| e > 0).ok_or_else(err)?;
            return Ok(SizeMix::single(elems as u64));
        }
        let clauses = s
            .split(',')
            .map(|c| {
                let (pct, elems) = c.split_once('%')?;
                let pct = pct.parse::<u32>().ok().filter(|&p| p > 0)?;
                let elems = parse_usize(elems).filter(|&e| e > 0)? as u64;
                Some((pct, elems))
            })
            .collect::<Option<Vec<_>>>()
            .ok_or_else(err)?;
        if clauses.iter().map(|&(p, _)| p as u64).sum::<u64>() != 100 {
            return Err(format!(
                "bad --size '{s}': mix percentages must sum to 100"
            ));
        }
        Ok(SizeMix { clauses })
    }

    /// Stable label (round-trips through [`parse`](Self::parse)); a
    /// single-size mix labels as the bare element count.
    pub fn label(&self) -> String {
        if self.is_single() {
            return format!("{}", self.clauses[0].1);
        }
        self.clauses
            .iter()
            .map(|(p, e)| format!("{p}%{e}"))
            .collect::<Vec<_>>()
            .join(",")
    }

    /// Whether every arrival has the same size (no draws consumed).
    pub fn is_single(&self) -> bool {
        self.clauses.len() == 1
    }

    pub fn clauses(&self) -> &[(u32, u64)] {
        &self.clauses
    }

    /// Smallest clause size — the bound `ServeScenario::check` holds
    /// against the workload's `2 x threads` floor.
    pub fn min_elems(&self) -> u64 {
        self.clauses.iter().map(|&(_, e)| e).min().expect("non-empty mix")
    }

    /// Exact integer expected size, `sum(pct x elems) / 100` rounded
    /// down — the ρ anchor of a mixed stream (for a single size this *is*
    /// the size).
    pub fn mean_elems(&self) -> u64 {
        let weighted: u128 = self
            .clauses
            .iter()
            .map(|&(p, e)| p as u128 * e as u128)
            .sum();
        (weighted / 100) as u64
    }

    /// The dedicated size-draw stream for a scenario seed. A different
    /// fork constant from the arrival stream's (`0x5e7e`), so gaps and
    /// sizes can never collide.
    pub fn rng_for(seed: u64) -> Rng {
        Rng::new(seed).fork(0x512e)
    }

    /// Size of the next arrival. Single-size mixes return the size
    /// without touching the rng (fixed-size streams stay byte-identical
    /// to the pre-mix driver); true mixes consume exactly one draw.
    pub fn draw(&self, rng: &mut Rng) -> u64 {
        if self.is_single() {
            return self.clauses[0].1;
        }
        let mut roll = rng.below(100) as u32;
        for &(pct, elems) in &self.clauses {
            if roll < pct {
                return elems;
            }
            roll -= pct;
        }
        unreachable!("mix percentages sum to 100")
    }
}

impl Default for SizeMix {
    fn default() -> Self {
        SizeMix::single(4096)
    }
}

/// Quantised exponential variate: `ceil(-ln(1-u) * mean)` cycles, floored
/// at 1 so time always advances between (clumps of) arrivals. Monotone in
/// `mean` for a fixed draw `u` — the pointwise-rescaling property above.
fn exp_gap(u: f64, mean: f64) -> u64 {
    let e = -(1.0 - u).ln();
    (e * mean).ceil().max(1.0) as u64
}

/// The generator: yields inter-arrival gaps in cycles, one per request.
pub struct ArrivalGen {
    rng: Rng,
    spec: ArrivalSpec,
    mean_gap: f64,
    emitted: u64,
}

impl ArrivalGen {
    /// `mean_gap` is the target mean inter-arrival time in cycles (the
    /// inverse of the offered rate). Values below 1 cycle saturate at 1.
    pub fn new(spec: ArrivalSpec, mean_gap: f64, seed: u64) -> ArrivalGen {
        ArrivalGen {
            // Fork a dedicated stream so arrival draws can never collide
            // with a workload that happens to share the scenario seed.
            rng: Rng::new(seed).fork(0x5e7e),
            spec,
            mean_gap: mean_gap.max(1.0),
            emitted: 0,
        }
    }

    /// Gap in cycles between the previous request and the next one
    /// (0 = same cycle, inside a burst).
    pub fn next_gap(&mut self) -> u64 {
        let i = self.emitted;
        self.emitted += 1;
        match self.spec {
            ArrivalSpec::Poisson => exp_gap(self.rng.f64(), self.mean_gap),
            ArrivalSpec::Bursty { burst } => {
                if i % burst as u64 == 0 {
                    exp_gap(self.rng.f64(), self.mean_gap * burst as f64)
                } else {
                    0
                }
            }
        }
    }

    /// Absolute arrival times for `n` requests (cumulative gaps) — the
    /// statistical tests and the docs examples read the stream this way.
    pub fn arrival_times(spec: ArrivalSpec, mean_gap: f64, seed: u64, n: u64) -> Vec<u64> {
        let mut g = ArrivalGen::new(spec, mean_gap, seed);
        let mut t = 0u64;
        (0..n)
            .map(|_| {
                t += g.next_gap();
                t
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_round_trips_and_rejects_garbage() {
        for s in ["poisson", "bursty@4", "bursty@16"] {
            assert_eq!(ArrivalSpec::parse(s).unwrap().label(), s);
        }
        assert_eq!(
            ArrivalSpec::parse("bursty").unwrap(),
            ArrivalSpec::Bursty { burst: 8 }
        );
        for s in ["", "burst", "bursty@1", "bursty@", "bursty@x", "uniform"] {
            assert!(ArrivalSpec::parse(s).is_err(), "{s} must not parse");
        }
    }

    #[test]
    fn identical_seeds_yield_identical_streams() {
        for spec in [ArrivalSpec::Poisson, ArrivalSpec::Bursty { burst: 4 }] {
            let a = ArrivalGen::arrival_times(spec, 500.0, 42, 1000);
            let b = ArrivalGen::arrival_times(spec, 500.0, 42, 1000);
            assert_eq!(a, b, "{}", spec.label());
            let c = ArrivalGen::arrival_times(spec, 500.0, 43, 1000);
            assert_ne!(a, c, "a different seed must move the stream");
        }
    }

    #[test]
    fn poisson_mean_gap_within_tolerance() {
        // 20k exponential samples: the sample mean's std error is
        // mean/sqrt(n) ≈ 0.7%; a 5% band is comfortably away from flaky
        // while still catching a wrong rate by construction.
        let n = 20_000u64;
        let mean = 1000.0;
        let times = ArrivalGen::arrival_times(ArrivalSpec::Poisson, mean, 7, n);
        let empirical = *times.last().unwrap() as f64 / n as f64;
        assert!(
            (empirical - mean).abs() / mean < 0.05,
            "empirical mean gap {empirical} vs configured {mean}"
        );
    }

    #[test]
    fn bursty_matches_rate_and_clumps() {
        let n = 20_000u64;
        let mean = 1000.0;
        let times = ArrivalGen::arrival_times(ArrivalSpec::Bursty { burst: 8 }, mean, 7, n);
        let empirical = *times.last().unwrap() as f64 / n as f64;
        assert!(
            (empirical - mean).abs() / mean < 0.05,
            "bursty stream must keep the Poisson mean rate, got {empirical}"
        );
        // Clump shape: within a burst, arrivals share a cycle.
        assert_eq!(times[1], times[0], "burst members arrive together");
        assert!(times[8] > times[7], "bursts are separated by a real gap");
    }

    #[test]
    fn higher_rate_means_pointwise_earlier_arrivals() {
        // The load-monotonicity keystone: same seed, shorter mean gap ⇒
        // every arrival happens no later.
        for spec in [ArrivalSpec::Poisson, ArrivalSpec::Bursty { burst: 4 }] {
            let slow = ArrivalGen::arrival_times(spec, 2000.0, 11, 2000);
            let fast = ArrivalGen::arrival_times(spec, 500.0, 11, 2000);
            assert!(
                slow.iter().zip(&fast).all(|(s, f)| f <= s),
                "{}: rescaling the rate must rescale gaps pointwise",
                spec.label()
            );
        }
    }

    #[test]
    fn size_mix_parse_label_round_trips() {
        for s in ["4096", "80%4096,20%65536", "50%1024,30%2048,20%4096"] {
            let m = SizeMix::parse(s).unwrap();
            assert_eq!(m.label(), s);
            assert_eq!(SizeMix::parse(&m.label()).unwrap(), m);
        }
        // Suffixes normalise to digits in the label.
        assert_eq!(SizeMix::parse("4ki").unwrap().label(), "4096");
        assert_eq!(SizeMix::parse("80%4ki,20%64ki").unwrap().label(), "80%4096,20%65536");
        for s in ["", "0", "x", "80%4096", "80%4096,30%1024", "0%4,100%8", "50%0,50%8"] {
            assert!(SizeMix::parse(s).is_err(), "'{s}' must not parse");
        }
    }

    #[test]
    fn size_mix_stats_are_exact() {
        let m = SizeMix::parse("75%1000,25%3000").unwrap();
        assert!(!m.is_single());
        assert_eq!(m.min_elems(), 1000);
        assert_eq!(m.mean_elems(), 1500);
        let s = SizeMix::single(4096);
        assert!(s.is_single());
        assert_eq!(s.mean_elems(), 4096);
    }

    #[test]
    fn size_draws_are_seeded_and_match_the_mix() {
        let m = SizeMix::parse("80%1024,20%8192").unwrap();
        let draw_n = |seed: u64, n: usize| -> Vec<u64> {
            let mut rng = SizeMix::rng_for(seed);
            (0..n).map(|_| m.draw(&mut rng)).collect()
        };
        let a = draw_n(42, 4000);
        assert_eq!(a, draw_n(42, 4000), "same seed, same size stream");
        assert_ne!(a, draw_n(43, 4000), "a different seed must move the stream");
        let small = a.iter().filter(|&&e| e == 1024).count();
        assert!(a.iter().all(|&e| e == 1024 || e == 8192));
        // 80% of 4000 ± a loose statistical band.
        assert!((2900..=3500).contains(&small), "small count {small}");
    }

    #[test]
    fn single_size_mix_consumes_no_draws() {
        // The byte-identity keystone: a fixed-size stream must leave its
        // rng untouched, whatever the seed.
        let m = SizeMix::single(2048);
        let mut a = SizeMix::rng_for(7);
        let mut b = SizeMix::rng_for(7);
        for _ in 0..10 {
            assert_eq!(m.draw(&mut a), 2048);
        }
        assert_eq!(a.next_u64(), b.next_u64(), "draw() must not advance the rng");
    }

    #[test]
    fn gaps_always_advance_between_clumps() {
        let mut g = ArrivalGen::new(ArrivalSpec::Poisson, 1.0, 3);
        for _ in 0..1000 {
            assert!(g.next_gap() >= 1, "poisson gaps are floored at one cycle");
        }
    }
}
