//! The serve front-end: the ROADMAP's "serve heavy traffic from millions
//! of users" layer, built on top of the simulator.
//!
//! The paper proves the *per-run* win: localisation makes one sort on one
//! chip fast. This module asks the service question the manycore era
//! actually poses — what offered load can that chip sustain, and what do
//! the latency tails look like on the way to saturation? It models the
//! request path as a deterministic discrete-event pipeline:
//!
//! ```text
//!   open-loop arrivals        bounded queue          dispatcher           chip
//!   (Poisson | bursty,   →   (drop-tail,       →   (immediate |     →   (P partition
//!    seeded, rate = ρ/s₁,     --queue-cap,          batchN[@wait],        servers; replay
//!    sized by --size)         fifo | sjf take)      free-server pick)     = service)
//! ```
//!
//! - [`arrivals`] — seeded open-loop arrival generators ([`ArrivalSpec`])
//!   and the request-size mix they draw from ([`SizeMix`]).
//! - [`queue`] — the bounded request queue, batching policies
//!   ([`BatchPolicy`]), and the dispatch take order ([`Admission`]).
//! - [`driver`] — one scenario's event loop and its latency/throughput
//!   digest ([`ServeScenario`], [`ServeReport`]).
//! - [`dispatch`] — the spatial multi-server loop: `--partitions` carves
//!   the chip ([`crate::arch::PartitionSpec`]) and one logical server per
//!   partition serves concurrent batches on disjoint tile sets
//!   ([`ServerSlice`] is its per-server digest).
//! - [`sweep`] — the `repro batch serve` grid (load × policy × machine ×
//!   protocol × partitioning), ladder structure, and saturation-knee
//!   detection ([`ServeSweep`]).
//!
//! The chip simulator enters as *one component among queues*: a batch of
//! `k` requests is served by one engine replay of the scenario's workload
//! at `k×` the elements, so every service time is a real simulated
//! makespan on real machine tiles — protocol, fabric, and contention
//! effects included — while a scenario's cost stays bounded by memoising
//! per batch size.
//!
//! Determinism is the same contract as the batch layer: reports are pure
//! functions of their scenario, sharded by index over the worker pool —
//! `repro batch serve --json` is byte-identical at any `--jobs` /
//! `--intra-jobs` (`rust/tests/serve_determinism.rs`), and the properties
//! (percentile ordering, throughput conservation, load monotonicity) are
//! pinned in `rust/tests/prop_serve.rs`.

pub mod arrivals;
pub mod dispatch;
pub mod driver;
pub mod queue;
pub mod sweep;

pub use arrivals::{ArrivalGen, ArrivalSpec, SizeMix};
pub use dispatch::ServerSlice;
pub use driver::{ServeReport, ServeScenario};
pub use queue::{Admission, BatchPolicy, RequestQueue};
pub use sweep::{ServeSweep, KNEE_FRACTION};
