//! Spatial multi-server dispatch: P partitions serving concurrent batches.
//!
//! The single-server driver ([`crate::serve::driver`]) replays every batch
//! on the whole chip, one at a time. This module carves the machine by the
//! scenario's [`PartitionSpec`] and runs one logical server per partition:
//! a shared bounded queue feeds a free-partition list, and a batch
//! dispatched to partition `i` replays on that partition's sub-grid view
//! ([`RunSpec::on_partition`]) while the other partitions keep serving —
//! the queue drains whenever *any* server frees. Requests never share
//! directory homes or links across partitions (the partition view confines
//! every page by construction), so concurrent service needs no new
//! contention model: the cost of a batch is exactly its partition replay.
//!
//! Server assignment is locality-aware and deterministic: a free partition
//! whose previous batch led with the same request size is preferred
//! (lowest partition index among matches — its working-set shape is
//! already "warm" in the memo sense), falling back to round-robin over
//! free partitions with the cursor advancing only on fallback picks.
//!
//! Service times are memoised per `(partition shape, total batch elems)`:
//! same-shaped partitions share a view ([`Machine::subgrid_view`] is a
//! pure function of shape), so a P-way ladder costs at most
//! `distinct_shapes x max_batch` distinct engine replays — the same
//! amortisation bound as the single-server per-k memo.
//!
//! The ρ anchor stays the **whole-chip** single-request service time `s₁`
//! whatever P is, so a P-ladder at fixed ρ shares its arrival stream
//! across every rung — that is what makes throughput monotone in P
//! testable pointwise, and what the knee-shift claim (knee moves right
//! ~P×) is measured against.
//!
//! A whole-chip partition's view is the parent machine itself and this
//! loop degenerates to the single-server event loop exactly, so a `P = 1`
//! record is byte-identical to the plain driver's (`serve_partition.rs`
//! and the CI smoke pin this).

use std::collections::HashMap;

use crate::arch::{Machine, Partition};
use crate::coordinator::batch::RunSpec;
use crate::metrics::latency_digest;
use crate::serve::arrivals::{ArrivalGen, SizeMix};
use crate::serve::driver::{rate_per_sec, ServeReport, ServeScenario};
use crate::serve::queue::{BatchPolicy, RequestQueue};
use crate::sim::devent::EventQueue;
use crate::util::json::Json;

/// Per-server digest of one multi-server scenario: which partition, how
/// much it served, and how busy it was over the scenario horizon.
#[derive(Clone, Debug, Default)]
pub struct ServerSlice {
    /// Partition label, e.g. `p0:4x4@0,0`.
    pub partition: String,
    pub batches: u64,
    pub completed: u64,
    pub max_batch_served: u64,
    /// Cycles this server spent replaying batches.
    pub busy_cycles: u64,
    /// Single mean-size request service time on this partition's shape —
    /// the per-server capacity anchor (bigger than the whole-chip `s₁`:
    /// fewer tiles serve the same request).
    pub service_cycles_one: u64,
    /// `busy_cycles / makespan` — the busy/idle accounting.
    pub utilisation: f64,
}

impl ServerSlice {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("partition", Json::str(self.partition.clone())),
            ("batches", Json::num(self.batches as f64)),
            ("completed", Json::num(self.completed as f64)),
            ("max_batch_served", Json::num(self.max_batch_served as f64)),
            ("busy_cycles", Json::num(self.busy_cycles as f64)),
            ("service_cycles_one", Json::num(self.service_cycles_one as f64)),
            ("utilisation", Json::num(self.utilisation)),
        ])
    }
}

/// Events of the multi-server discrete-event loop.
enum Ev {
    /// One request arrives.
    Arrival,
    /// Server `i`'s in-flight batch completes.
    Done(usize),
    /// The oldest queued request's batch-fill timer expired.
    Timeout,
}

/// Mutable per-server state during the loop.
#[derive(Default)]
struct Server {
    busy: bool,
    /// Head-request size of the last dispatched batch (locality key).
    last_size: Option<u64>,
    /// Arrival cycles of the in-flight batch's requests.
    in_flight: Vec<u64>,
    busy_cycles: u64,
    batches: u64,
    completed: u64,
    max_batch: u64,
}

/// Replay cost of a batch totalling `elems` on a partition, memoised per
/// `(shape, elems)` — position never enters (same-shape views are equal).
fn service_cycles(
    run: &RunSpec,
    part: &Partition,
    parent: &Machine,
    elems: u64,
    intra_jobs: usize,
    memo: &mut HashMap<(u32, u32, u64), u64>,
) -> u64 {
    *memo
        .entry((part.width(), part.height(), elems))
        .or_insert_with(|| {
            let mut r = run.clone();
            r.elems = elems;
            r.on_partition(part, parent, intra_jobs).makespan_cycles
        })
}

/// Pick the server for the batch whose head request has `head` elements:
/// the lowest-indexed free server whose last batch led with the same size,
/// else round-robin from the cursor (which advances only on fallback, so
/// affinity hits don't skew the rotation). `None` when every server is
/// busy.
fn pick_server(servers: &[Server], rr_cursor: &mut usize, head: u64) -> Option<usize> {
    if let Some(i) = servers
        .iter()
        .position(|s| !s.busy && s.last_size == Some(head))
    {
        return Some(i);
    }
    let p = servers.len();
    for off in 0..p {
        let i = (*rr_cursor + off) % p;
        if !servers[i].busy {
            *rr_cursor = (i + 1) % p;
            return Some(i);
        }
    }
    None
}

/// Run a partitioned scenario's discrete-event loop to completion. The
/// single-server semantics (batch-take rule, fill-timer arming, the
/// makespan-excludes-stale-timers rule) are preserved verbatim; only the
/// server count changed.
pub(crate) fn simulate(s: &ServeScenario, intra_jobs: usize) -> ServeReport {
    let mut report = ServeReport::zero(s);
    let parent = s.run.machine.build();
    let parts = s
        .partitions
        .carve(&parent)
        .expect("partition spec validated by ServeScenario::check");
    if s.requests == 0 {
        return report;
    }

    // The ρ anchor: whole-chip single-request service time, exactly the
    // plain driver's `cache[0]` replay. Seed the memo with it so a
    // whole-chip partition never re-replays the anchor size.
    let anchor = s.run.execute_intra(intra_jobs);
    let s1 = anchor.makespan_cycles;
    let clock = anchor.clock_hz;
    report.service_cycles_one = s1;
    report.clock_hz = clock;
    let mean_gap = (s1 as f64 / s.rho).max(1.0);
    let mut memo: HashMap<(u32, u32, u64), u64> = HashMap::new();
    memo.insert((parent.grid_w(), parent.grid_h(), s.run.elems), s1);

    let mut events: EventQueue<Ev> = EventQueue::new();
    let mut gen = ArrivalGen::new(s.arrival, mean_gap, s.run.seed);
    let mut size_rng = SizeMix::rng_for(s.run.seed);
    let mut queue = RequestQueue::new(s.queue_cap);
    let mut latencies: Vec<u64> = Vec::new();
    let mut servers: Vec<Server> = parts.iter().map(|_| Server::default()).collect();
    let mut armed_timeout: Option<u64> = None;
    let mut arrived = 0u64;
    let mut rr_cursor = 0usize;
    events.at(gen.next_gap(), Ev::Arrival);
    while let Some((now, ev)) = events.pop() {
        // Makespan tracks arrivals and completions; a stale fill timer
        // popping after the last Done must not stretch the horizon.
        if !matches!(ev, Ev::Timeout) {
            report.makespan_cycles = now;
        }
        match ev {
            Ev::Arrival => {
                arrived += 1;
                report.last_arrival_cycles = now;
                let elems = s.sizes.draw(&mut size_rng);
                queue.offer(now, elems);
                if arrived < s.requests {
                    events.at(now + gen.next_gap(), Ev::Arrival);
                }
            }
            Ev::Done(i) => {
                let srv = &mut servers[i];
                for a in srv.in_flight.drain(..) {
                    latencies.push(now - a);
                }
                srv.busy = false;
            }
            Ev::Timeout => {}
        }
        // Dispatch loop: the queue drains onto every free server the
        // policy allows — server k+1 starts in the same cycle server k
        // did when enough requests are queued.
        loop {
            if queue.is_empty() || servers.iter().all(|srv| srv.busy) {
                break;
            }
            let take = match s.policy {
                BatchPolicy::Immediate => Some(1),
                BatchPolicy::Batch { max, wait } => {
                    let oldest = queue.front_arrival().expect("non-empty queue");
                    if queue.len() >= max as usize
                        || arrived == s.requests
                        || now >= oldest + wait
                    {
                        Some(queue.len().min(max as usize))
                    } else {
                        // Hold for more arrivals; arm the fill timer once
                        // per deadline (stale timers pop as no-ops).
                        if armed_timeout != Some(oldest + wait) {
                            events.at(oldest + wait, Ev::Timeout);
                            armed_timeout = Some(oldest + wait);
                        }
                        None
                    }
                }
            };
            let Some(k) = take else { break };
            let head = queue.head_elems(s.admission).expect("non-empty queue");
            let i = pick_server(&servers, &mut rr_cursor, head)
                .expect("a free server exists: checked above");
            let batch = queue.take(k, s.admission);
            let total: u64 = batch.iter().map(|r| r.elems).sum();
            let svc = service_cycles(&s.run, &parts[i], &parent, total, intra_jobs, &mut memo);
            let srv = &mut servers[i];
            srv.in_flight = batch.iter().map(|r| r.arrival).collect();
            srv.last_size = Some(head);
            srv.busy = true;
            srv.busy_cycles += svc;
            srv.batches += 1;
            srv.completed += batch.len() as u64;
            srv.max_batch = srv.max_batch.max(batch.len() as u64);
            report.batches += 1;
            report.max_batch_served = report.max_batch_served.max(batch.len() as u64);
            armed_timeout = None;
            events.at(now + svc, Ev::Done(i));
        }
    }

    latencies.sort_unstable();
    report.completed = latencies.len() as u64;
    report.dropped = queue.dropped;
    report.queue_peak = queue.peak_depth as u64;
    let (p50, p99, p999, max) = latency_digest(&latencies);
    report.p50_cycles = p50;
    report.p99_cycles = p99;
    report.p999_cycles = p999;
    report.max_cycles = max;
    report.mean_cycles = if latencies.is_empty() {
        0.0
    } else {
        latencies.iter().map(|&l| l as u128).sum::<u128>() as f64 / latencies.len() as f64
    };
    report.offered_rps = rate_per_sec(arrived, report.last_arrival_cycles, clock);
    report.completed_rps = rate_per_sec(report.completed, report.makespan_cycles, clock);
    // Per-server digests only when there is more than one server: a
    // single-server record (partitioned or not) keeps the plain driver's
    // bytes.
    if parts.len() > 1 {
        report.servers = parts
            .iter()
            .zip(&servers)
            .map(|(p, srv)| ServerSlice {
                partition: p.label(),
                batches: srv.batches,
                completed: srv.completed,
                max_batch_served: srv.max_batch,
                busy_cycles: srv.busy_cycles,
                service_cycles_one: service_cycles(
                    &s.run, p, &parent, s.run.elems, intra_jobs, &mut memo,
                ),
                utilisation: if report.makespan_cycles == 0 {
                    0.0
                } else {
                    srv.busy_cycles as f64 / report.makespan_cycles as f64
                },
            })
            .collect();
    }
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::PartitionSpec;
    use crate::serve::arrivals::ArrivalSpec;
    use crate::serve::queue::Admission;

    fn partitioned(
        partitions: &str,
        rho: f64,
        requests: u64,
        policy: BatchPolicy,
    ) -> ServeScenario {
        ServeScenario::new(
            RunSpec::mergesort(8, 1 << 10, 4, 42),
            ArrivalSpec::Poisson,
            rho,
            requests,
            1 << 20,
            policy,
        )
        .with_partitions(PartitionSpec::parse(partitions).unwrap())
    }

    #[test]
    fn four_partitions_serve_concurrently() {
        let s = partitioned("4", 2.0, 60, BatchPolicy::Immediate);
        s.check().unwrap();
        let r = s.simulate(1);
        assert_eq!(r.completed + r.dropped, 60);
        assert_eq!(r.servers.len(), 4);
        let spread = r.servers.iter().filter(|sv| sv.batches > 0).count();
        assert!(spread >= 2, "overload must use more than one partition");
        assert_eq!(
            r.servers.iter().map(|sv| sv.completed).sum::<u64>(),
            r.completed,
            "per-server completions must sum to the aggregate"
        );
        assert_eq!(r.servers.iter().map(|sv| sv.batches).sum::<u64>(), r.batches);
        for sv in &r.servers {
            assert!(sv.utilisation >= 0.0 && sv.utilisation <= 1.0, "{}", sv.partition);
            assert!(sv.busy_cycles <= r.makespan_cycles);
            assert!(
                sv.service_cycles_one > r.service_cycles_one,
                "a quadrant serves a request slower than the whole chip"
            );
        }
    }

    #[test]
    fn partitions_scale_overload_throughput() {
        // At rho=2 the quad is arrival-bound: its completed req/s tracks
        // the offered rate (= 2x the single server's capacity), so the
        // measured ratio approaches 2 from below as the horizon grows —
        // 1.8 leaves room for the finite-horizon tails. At rho=4 both
        // sides are capacity-bound and the 4-partition capacity ratio
        // shows directly: comfortably >= 2x.
        let at = |partitions: &str, rho: f64| {
            partitioned(partitions, rho, 80, BatchPolicy::Immediate).simulate(1)
        };
        let (s2, q2) = (at("whole", 2.0), at("4", 2.0));
        assert!(
            q2.completed_rps >= 1.8 * s2.completed_rps,
            "4 partitions at rho=2 must track the 2x offered rate: {} vs {}",
            q2.completed_rps,
            s2.completed_rps
        );
        let (s4, q4) = (at("whole", 4.0), at("4", 4.0));
        assert!(
            q4.completed_rps >= 2.0 * s4.completed_rps,
            "4 partitions at rho=4 must at least double capacity: {} vs {}",
            q4.completed_rps,
            s4.completed_rps
        );
    }

    #[test]
    fn round_robin_rotates_and_affinity_prefers_matches() {
        let mut servers: Vec<Server> = (0..3).map(|_| Server::default()).collect();
        let mut rr = 0usize;
        // No affinity yet: strict rotation.
        assert_eq!(pick_server(&servers, &mut rr, 64), Some(0));
        servers[0].busy = true;
        servers[0].last_size = Some(64);
        assert_eq!(pick_server(&servers, &mut rr, 64), Some(1));
        servers[1].busy = true;
        servers[1].last_size = Some(512);
        // Server 0 frees; a 64-sized head prefers it over cursor order.
        servers[0].busy = false;
        assert_eq!(pick_server(&servers, &mut rr, 64), Some(0), "affinity match");
        // Cursor was not advanced by the affinity hit: fallback resumes at 2.
        assert_eq!(pick_server(&servers, &mut rr, 99), Some(2));
        servers[2].busy = true;
        servers[0].busy = true;
        servers[1].busy = true;
        assert_eq!(pick_server(&servers, &mut rr, 64), None, "all busy");
    }

    #[test]
    fn sjf_admission_reorders_under_a_mix() {
        let mix = SizeMix::parse("50%1024,50%8192").unwrap();
        let fifo = partitioned("2", 3.0, 40, BatchPolicy::Immediate).with_sizes(mix.clone());
        let sjf = fifo.clone().with_admission(Admission::Sjf);
        fifo.check().unwrap();
        sjf.check().unwrap();
        let rf = fifo.simulate(1);
        let rs = sjf.simulate(1);
        assert_eq!(rf.completed + rf.dropped, 40);
        assert_eq!(rs.completed + rs.dropped, 40);
        // At 3x overload the queue holds mixed sizes, so SJF's take order
        // (and therefore the latency record) must diverge from FIFO's.
        assert_ne!(
            rf.to_json().encode(),
            rs.to_json().encode(),
            "SJF must reorder a backlogged size mix"
        );
    }

    #[test]
    fn dispatch_report_is_deterministic_and_intra_jobs_invariant() {
        let s = partitioned("2x2", 1.5, 30, BatchPolicy::Batch { max: 4, wait: 0 })
            .with_sizes(SizeMix::parse("75%1024,25%4096").unwrap());
        let a = s.simulate(1).to_json().encode();
        let b = s.simulate(1).to_json().encode();
        let c = s.simulate(2).to_json().encode();
        assert_eq!(a, b, "same scenario, same bytes");
        assert_eq!(a, c, "intra-run workers must not change the report");
    }
}
