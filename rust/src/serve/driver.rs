//! The open-loop serve driver: one scenario = arrivals → bounded queue →
//! dispatcher → the chip simulator as the service stage.
//!
//! A [`ServeScenario`] fixes the request workload (a [`RunSpec`] template
//! — one request = one replay of that spec), the arrival shape and offered
//! load, the queue bound, and the batching policy. [`ServeScenario::simulate`]
//! runs the discrete-event loop over [`EventQueue`] and produces a
//! [`ServeReport`]: per-request latency percentiles (p50/p99/p999/max by
//! nearest rank, in exact cycles), completed-vs-offered throughput, drops,
//! and batching shape.
//!
//! Offered load is expressed as **ρ** (`--rhos`): the arrival rate as a
//! fraction of the single-request service rate, so `ρ = 1` is the
//! single-server saturation point by construction and a ladder crossing 1
//! must show the knee. The driver measures the single-request service time
//! `s₁` by replaying the template once, then sets the mean inter-arrival
//! gap to `s₁/ρ`.
//!
//! The dispatcher maps each batch onto the machine's tiles through the
//! existing engine machinery: a batch of `k` requests is one replay of the
//! template with `k×` the elements (the chunked sorter's contract — one
//! dispatch sorts the concatenated keys). Batch service times are memoised
//! per `k`, so a scenario costs at most `max_batch` engine replays no
//! matter how many requests flow through it.
//!
//! Everything here is sequential and a pure function of the scenario +
//! `intra_jobs`-independent stats, so reports are byte-identical at any
//! `--jobs`/`--intra-jobs` (pinned by `rust/tests/serve_determinism.rs`).

use crate::arch::PartitionSpec;
use crate::coordinator::batch::RunSpec;
use crate::metrics::latency_digest;
use crate::serve::arrivals::{ArrivalGen, ArrivalSpec, SizeMix};
use crate::serve::dispatch::{self, ServerSlice};
use crate::serve::queue::{Admission, BatchPolicy, RequestQueue};
use crate::sim::devent::EventQueue;
use crate::util::json::Json;

/// One fully-specified serve cell: workload template × arrival process ×
/// offered load × queue bound × batch policy, plus the spatial axes
/// (partitioning, admission order, request-size mix).
///
/// Build with [`ServeScenario::new`] plus the `with_*` builders — the
/// struct is `#[non_exhaustive]` so new axes can land without breaking
/// out-of-crate constructors (the same contract as [`RunSpec`]).
#[derive(Clone, Debug)]
#[non_exhaustive]
pub struct ServeScenario {
    /// The per-request workload. `run.elems` is the *mean* request size
    /// (== the fixed size for a single-size stream); a batch replays the
    /// template at the batch's total element count.
    pub run: RunSpec,
    pub arrival: ArrivalSpec,
    /// Offered load as a fraction of the whole-chip single-request
    /// service rate (the anchor stays whole-chip even when partitioned,
    /// so a P-ladder shares its arrival stream across every rung).
    pub rho: f64,
    /// Open-loop arrival count (0 = empty scenario, all-zero report).
    pub requests: u64,
    /// Bounded queue depth; arrivals beyond it drop (drop-tail).
    pub queue_cap: usize,
    pub policy: BatchPolicy,
    /// Spatial partitioning (`--partitions`): `Whole` is the
    /// single-server baseline and keeps the pre-partition record bytes.
    pub partitions: PartitionSpec,
    /// Dispatch take order (`--admission`): FIFO or shortest-job-first.
    pub admission: Admission,
    /// Request-size distribution (`--size`); single-size by construction
    /// from [`ServeScenario::new`], kept in sync with `run.elems` by
    /// [`with_sizes`](Self::with_sizes).
    pub sizes: SizeMix,
}

/// Events of the serve pipeline's discrete-event loop.
enum Ev {
    /// One request arrives.
    Arrival,
    /// The in-flight batch completes.
    Done,
    /// The oldest queued request's batch-fill timer expired.
    Timeout,
}

impl ServeScenario {
    /// The baseline cell: single whole-chip server, FIFO admission, a
    /// fixed request size of `run.elems`. Layer the spatial axes on with
    /// the `with_*` builders.
    pub fn new(
        run: RunSpec,
        arrival: ArrivalSpec,
        rho: f64,
        requests: u64,
        queue_cap: usize,
        policy: BatchPolicy,
    ) -> ServeScenario {
        let sizes = SizeMix::single(run.elems);
        ServeScenario {
            run,
            arrival,
            rho,
            requests,
            queue_cap,
            policy,
            partitions: PartitionSpec::Whole,
            admission: Admission::Fifo,
            sizes,
        }
    }

    /// Carve the chip (`--partitions`).
    pub fn with_partitions(mut self, partitions: PartitionSpec) -> ServeScenario {
        self.partitions = partitions;
        self
    }

    /// Select the dispatch take order (`--admission`).
    pub fn with_admission(mut self, admission: Admission) -> ServeScenario {
        self.admission = admission;
        self
    }

    /// Drive a request-size mix (`--size 80%4ki,20%64ki`). Re-anchors the
    /// template at the mix's exact mean size so `run.elems` (the ρ
    /// anchor) and the drawn stream stay consistent.
    pub fn with_sizes(mut self, sizes: SizeMix) -> ServeScenario {
        self.run.elems = sizes.mean_elems();
        self.sizes = sizes;
        self
    }

    /// Gated suffix shared by [`label`](Self::label) and
    /// [`ladder_label`](Self::ladder_label): each spatial axis appears
    /// only when it deviates from the baseline, so pre-partition labels
    /// keep their bytes.
    fn label_suffix(&self) -> String {
        let mut s = String::new();
        if !self.run.protocol.is_default() {
            s.push_str(&format!(" proto={}", self.run.protocol.label()));
        }
        if !self.partitions.is_whole() {
            s.push_str(&format!(" part={}", self.partitions.label()));
        }
        if !self.admission.is_default() {
            s.push_str(&format!(" adm={}", self.admission.label()));
        }
        if !self.sizes.is_single() {
            s.push_str(&format!(" mix={}", self.sizes.label()));
        }
        s
    }

    /// Row label: `machine/policy/arrival rho=R` plus the gated deviation
    /// suffix (protocol/partitions/admission/mix — same gating as
    /// [`RunSpec::label`]).
    pub fn label(&self) -> String {
        format!(
            "{}/{}/{} rho={}{}",
            self.run.machine.label(),
            self.policy.label(),
            self.arrival.label(),
            self.rho,
            self.label_suffix()
        )
    }

    /// Ladder key: everything but the offered load. Scenarios sharing this
    /// key form one throughput-vs-load curve (where the knee is detected).
    pub fn ladder_label(&self) -> String {
        format!(
            "{}/{}/{}{}",
            self.run.machine.label(),
            self.policy.label(),
            self.arrival.label(),
            self.label_suffix()
        )
    }

    /// CLI-time validation: the template (at its largest batch size) must
    /// fit the machine — and, when partitioned, every partition — and the
    /// scenario's knobs must be sane.
    pub fn check(&self) -> Result<(), String> {
        if !(self.rho > 0.0) {
            return Err(format!("bad serve scenario: rho must be > 0, got {}", self.rho));
        }
        if self.queue_cap == 0 {
            return Err("bad serve scenario: queue-cap must be >= 1".into());
        }
        if self.sizes.min_elems() < 2 * self.run.threads as u64 {
            return Err(format!(
                "bad serve scenario: request size {} below 2x{} threads",
                self.sizes.min_elems(),
                self.run.threads
            ));
        }
        if self.run.elems != self.sizes.mean_elems() {
            return Err(format!(
                "bad serve scenario: template size {} is not the size mix's mean {} \
                 (build with ServeScenario::with_sizes)",
                self.run.elems,
                self.sizes.mean_elems()
            ));
        }
        self.run.check_thread_capacity()?;
        let machine = self.run.machine.build();
        let parts = self
            .partitions
            .carve(&machine)
            .map_err(|e| format!("bad serve scenario: {e}"))?;
        for p in &parts {
            if self.run.threads > 4 * p.num_tiles() as usize {
                return Err(format!(
                    "bad serve scenario: {} threads exceed partition {} \
                     ({} tiles x 4 thread contexts)",
                    self.run.threads,
                    p.label(),
                    p.num_tiles()
                ));
            }
            p.view(&machine).map_err(|e| format!("bad serve scenario: {e}"))?;
        }
        Ok(())
    }

    /// Spec half of the scenario's JSON record (the report rides next to
    /// it — see [`crate::serve::sweep`]). The spatial axes are emitted
    /// only when they deviate from the baseline, so pre-partition records
    /// keep their bytes — and a whole-chip `--partitions` run is
    /// byte-identical to the plain driver's record.
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("run", self.run.to_json()),
            ("arrival", Json::str(self.arrival.label())),
            ("rho", Json::num(self.rho)),
            ("requests", Json::num(self.requests as f64)),
            ("queue_cap", Json::num(self.queue_cap as f64)),
            ("policy", Json::str(self.policy.label())),
        ];
        if !self.partitions.is_whole() {
            fields.push(("partitions", Json::str(self.partitions.label())));
        }
        if !self.admission.is_default() {
            fields.push(("admission", Json::str(self.admission.label())));
        }
        if !self.sizes.is_single() {
            fields.push(("size_mix", Json::str(self.sizes.label())));
        }
        Json::obj(fields)
    }

    /// Service time in cycles for a batch of `k` requests: one replay of
    /// the template at `k × elems`, memoised in `cache[k-1]`.
    fn service_cycles(
        &self,
        cache: &mut [Option<(u64, f64)>],
        k: usize,
        intra_jobs: usize,
    ) -> u64 {
        if cache[k - 1].is_none() {
            let mut r = self.run.clone();
            r.elems = self.run.elems * k as u64;
            let stats = r.execute_intra(intra_jobs);
            cache[k - 1] = Some((stats.makespan_cycles, stats.clock_hz));
        }
        cache[k - 1].unwrap().0
    }

    /// True when none of the spatial axes deviate from the baseline —
    /// the scenario routes through the original single-server loop.
    /// Note the comparison is against `PartitionSpec::Whole` *exactly*:
    /// an explicit `--partitions 1x1` routes through the multi-server
    /// dispatcher, which `rust/tests/serve_partition.rs` exploits to pin
    /// byte-identity across the two loops.
    fn is_plain(&self) -> bool {
        self.partitions == PartitionSpec::Whole
            && self.admission.is_default()
            && self.sizes.is_single()
    }

    /// Run the scenario's discrete-event loop to completion and digest it.
    /// Deterministic at any `intra_jobs` (engine stats are byte-identical
    /// across intra-run worker counts).
    pub fn simulate(&self, intra_jobs: usize) -> ServeReport {
        if self.is_plain() {
            self.simulate_plain(intra_jobs)
        } else {
            dispatch::simulate(self, intra_jobs)
        }
    }

    /// The original single-server event loop: one whole-chip server, FIFO
    /// admission, fixed request size. Kept verbatim as the byte-identity
    /// baseline the partitioned dispatcher is checked against.
    fn simulate_plain(&self, intra_jobs: usize) -> ServeReport {
        let mut report = ServeReport::zero(self);
        if self.requests == 0 {
            return report;
        }
        let max_batch = self.policy.max_batch() as usize;
        let mut cache: Vec<Option<(u64, f64)>> = vec![None; max_batch];
        let s1 = self.service_cycles(&mut cache, 1, intra_jobs);
        let clock = cache[0].unwrap().1;
        let mean_gap = (s1 as f64 / self.rho).max(1.0);
        report.service_cycles_one = s1;
        report.clock_hz = clock;

        let mut events: EventQueue<Ev> = EventQueue::new();
        let mut gen = ArrivalGen::new(self.arrival, mean_gap, self.run.seed);
        let mut queue = RequestQueue::new(self.queue_cap);
        let mut latencies: Vec<u64> = Vec::new();
        let mut in_flight: Vec<u64> = Vec::new();
        let mut busy = false;
        let mut armed_timeout: Option<u64> = None;
        let mut arrived = 0u64;
        events.at(gen.next_gap(), Ev::Arrival);
        while let Some((now, ev)) = events.pop() {
            // Makespan tracks arrivals and completions; a stale fill timer
            // popping after the last Done must not stretch the horizon.
            if !matches!(ev, Ev::Timeout) {
                report.makespan_cycles = now;
            }
            match ev {
                Ev::Arrival => {
                    arrived += 1;
                    report.last_arrival_cycles = now;
                    queue.offer(now, self.run.elems);
                    if arrived < self.requests {
                        events.at(now + gen.next_gap(), Ev::Arrival);
                    }
                }
                Ev::Done => {
                    for a in in_flight.drain(..) {
                        latencies.push(now - a);
                    }
                    busy = false;
                }
                Ev::Timeout => {}
            }
            if busy || queue.is_empty() {
                continue;
            }
            let take = match self.policy {
                BatchPolicy::Immediate => Some(1),
                BatchPolicy::Batch { max, wait } => {
                    let oldest = queue.front_arrival().expect("non-empty queue");
                    if queue.len() >= max as usize
                        || arrived == self.requests
                        || now >= oldest + wait
                    {
                        Some(queue.len().min(max as usize))
                    } else {
                        // Hold for more arrivals; arm the fill timer once
                        // per deadline (stale timers pop as no-ops).
                        if armed_timeout != Some(oldest + wait) {
                            events.at(oldest + wait, Ev::Timeout);
                            armed_timeout = Some(oldest + wait);
                        }
                        None
                    }
                }
            };
            if let Some(k) = take {
                in_flight = queue.take(k, Admission::Fifo).iter().map(|r| r.arrival).collect();
                let svc = self.service_cycles(&mut cache, k, intra_jobs);
                report.batches += 1;
                report.max_batch_served = report.max_batch_served.max(k as u64);
                busy = true;
                armed_timeout = None;
                events.at(now + svc, Ev::Done);
            }
        }

        latencies.sort_unstable();
        report.completed = latencies.len() as u64;
        report.dropped = queue.dropped;
        report.queue_peak = queue.peak_depth as u64;
        let (p50, p99, p999, max) = latency_digest(&latencies);
        report.p50_cycles = p50;
        report.p99_cycles = p99;
        report.p999_cycles = p999;
        report.max_cycles = max;
        report.mean_cycles = if latencies.is_empty() {
            0.0
        } else {
            latencies.iter().map(|&l| l as u128).sum::<u128>() as f64 / latencies.len() as f64
        };
        report.offered_rps = rate_per_sec(arrived, report.last_arrival_cycles, clock);
        report.completed_rps = rate_per_sec(report.completed, report.makespan_cycles, clock);
        report
    }
}

/// `n` events over `cycles` simulated cycles as a per-second rate. Both
/// numerator and denominator are *empirical* (the measured stream, not the
/// configured rate): `completed ≤ arrived` and `makespan ≥ last arrival`
/// make `completed_rps ≤ offered_rps` an identity, which is the
/// throughput-conservation property `prop_serve` pins.
pub(crate) fn rate_per_sec(n: u64, cycles: u64, clock_hz: f64) -> f64 {
    if cycles == 0 {
        return 0.0;
    }
    n as f64 * clock_hz / cycles as f64
}

/// The digest of one simulated scenario. All cycle counts are exact
/// integers; the derived f64 rates are pure functions of them.
#[derive(Clone, Debug, Default)]
pub struct ServeReport {
    /// Requests the generator emitted (== the scenario's `requests`).
    pub offered: u64,
    pub completed: u64,
    pub dropped: u64,
    /// Engine replays dispatched and the largest batch one replay served.
    pub batches: u64,
    pub max_batch_served: u64,
    pub queue_peak: u64,
    /// Single-request service time (the ρ anchor) and the machine clock.
    pub service_cycles_one: u64,
    pub clock_hz: f64,
    pub last_arrival_cycles: u64,
    pub makespan_cycles: u64,
    pub p50_cycles: u64,
    pub p99_cycles: u64,
    pub p999_cycles: u64,
    pub max_cycles: u64,
    pub mean_cycles: f64,
    pub offered_rps: f64,
    pub completed_rps: f64,
    /// Per-server slices when the chip is partitioned into more than one
    /// server (empty — and absent from JSON — otherwise, so single-server
    /// records keep their bytes).
    pub servers: Vec<ServerSlice>,
}

impl ServeReport {
    pub(crate) fn zero(s: &ServeScenario) -> ServeReport {
        ServeReport {
            offered: s.requests,
            ..ServeReport::default()
        }
    }

    /// Latency in milliseconds for the table renderer (cycles stay the
    /// record of truth in JSON).
    pub fn ms(&self, cycles: u64) -> f64 {
        if self.clock_hz == 0.0 {
            0.0
        } else {
            cycles as f64 / self.clock_hz * 1e3
        }
    }

    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("offered", Json::num(self.offered as f64)),
            ("completed", Json::num(self.completed as f64)),
            ("dropped", Json::num(self.dropped as f64)),
            ("batches", Json::num(self.batches as f64)),
            ("max_batch_served", Json::num(self.max_batch_served as f64)),
            ("queue_peak", Json::num(self.queue_peak as f64)),
            ("service_cycles_one", Json::num(self.service_cycles_one as f64)),
            ("last_arrival_cycles", Json::num(self.last_arrival_cycles as f64)),
            ("makespan_cycles", Json::num(self.makespan_cycles as f64)),
            ("p50_cycles", Json::num(self.p50_cycles as f64)),
            ("p99_cycles", Json::num(self.p99_cycles as f64)),
            ("p999_cycles", Json::num(self.p999_cycles as f64)),
            ("max_cycles", Json::num(self.max_cycles as f64)),
            ("mean_cycles", Json::num(self.mean_cycles)),
            ("p50_ms", Json::num(self.ms(self.p50_cycles))),
            ("p99_ms", Json::num(self.ms(self.p99_cycles))),
            ("p999_ms", Json::num(self.ms(self.p999_cycles))),
            ("offered_rps", Json::num(self.offered_rps)),
            ("completed_rps", Json::num(self.completed_rps)),
        ];
        if !self.servers.is_empty() {
            fields.push((
                "servers",
                Json::arr(self.servers.iter().map(ServerSlice::to_json).collect()),
            ));
        }
        Json::obj(fields)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::batch::RunSpec;

    fn tiny(rho: f64, requests: u64, policy: BatchPolicy) -> ServeScenario {
        ServeScenario::new(
            RunSpec::mergesort(8, 1 << 10, 4, 42),
            ArrivalSpec::Poisson,
            rho,
            requests,
            1 << 20,
            policy,
        )
    }

    #[test]
    fn empty_scenario_is_all_zero_not_a_panic() {
        let r = tiny(0.5, 0, BatchPolicy::Immediate).simulate(1);
        assert_eq!(
            (r.completed, r.dropped, r.batches, r.makespan_cycles),
            (0, 0, 0, 0)
        );
        assert_eq!((r.p50_cycles, r.p999_cycles, r.max_cycles), (0, 0, 0));
        assert_eq!(r.offered_rps, 0.0);
        assert_eq!(r.completed_rps, 0.0);
    }

    #[test]
    fn low_load_completes_everything_without_drops() {
        let r = tiny(0.5, 40, BatchPolicy::Immediate).simulate(1);
        assert_eq!(r.completed, 40);
        assert_eq!(r.dropped, 0);
        assert_eq!(r.batches, 40, "immediate policy: one replay per request");
        assert!(r.service_cycles_one > 0);
        assert!(r.p50_cycles >= r.service_cycles_one, "latency includes service");
        assert!(r.makespan_cycles > r.last_arrival_cycles);
    }

    #[test]
    fn batching_coalesces_under_pressure() {
        let r = tiny(2.0, 60, BatchPolicy::Batch { max: 8, wait: 0 }).simulate(1);
        assert_eq!(r.completed, 60);
        assert!(r.batches < 60, "overload must coalesce: {} batches", r.batches);
        assert!(r.max_batch_served > 1);
        assert!(r.max_batch_served <= 8);
    }

    #[test]
    fn bounded_queue_drops_under_overload() {
        let mut s = tiny(4.0, 60, BatchPolicy::Immediate);
        s.queue_cap = 2;
        let r = s.simulate(1);
        assert!(r.dropped > 0, "cap-2 queue at 4x load must drop");
        assert_eq!(r.completed + r.dropped, 60);
        assert!(r.queue_peak <= 2);
    }

    #[test]
    fn report_is_deterministic_and_intra_jobs_invariant() {
        let s = tiny(1.2, 30, BatchPolicy::Batch { max: 4, wait: 0 });
        let a = s.simulate(1).to_json().encode();
        let b = s.simulate(1).to_json().encode();
        let c = s.simulate(2).to_json().encode();
        assert_eq!(a, b, "same scenario, same bytes");
        assert_eq!(a, c, "intra-run workers must not change the report");
    }

    #[test]
    fn fill_timer_holds_then_flushes() {
        // wait >> inter-arrival gap: batches should fill to max; the tail
        // flushes partial when arrivals run out.
        let s = tiny(1.0, 20, BatchPolicy::Batch { max: 4, wait: u64::MAX / 2 });
        let r = s.simulate(1);
        assert_eq!(r.completed, 20);
        assert_eq!(r.max_batch_served, 4, "timer must let batches fill");
    }

    #[test]
    fn scenario_check_catches_bad_knobs() {
        assert!(tiny(0.0, 10, BatchPolicy::Immediate).check().is_err());
        let mut s = tiny(1.0, 10, BatchPolicy::Immediate);
        s.queue_cap = 0;
        assert!(s.check().is_err());
        let s = tiny(1.0, 10, BatchPolicy::Immediate).with_sizes(SizeMix::single(4));
        assert!(s.check().is_err(), "request below 2x threads");
        let mut s = tiny(1.0, 10, BatchPolicy::Immediate);
        s.run.elems = 999;
        assert!(s.check().is_err(), "template size out of sync with the mix's mean");
        let s = tiny(1.0, 10, BatchPolicy::Immediate)
            .with_partitions(PartitionSpec::parse("3x3").unwrap());
        assert!(s.check().is_err(), "8x8 grid does not divide 3x3");
        let s = tiny(1.0, 10, BatchPolicy::Immediate)
            .with_partitions(PartitionSpec::parse("16").unwrap());
        assert!(s.check().is_ok(), "4 threads fit a 2x2-tile partition");
        assert!(tiny(1.0, 10, BatchPolicy::Immediate).check().is_ok());
    }
}
