//! The serve scenario grid: offered load × batch policy × machine ×
//! protocol × chip partitioning, executed through the batch worker pool,
//! digested into throughput-vs-offered-load ladders with saturation-knee
//! detection.
//!
//! Grid order is row-major over (machine, protocol, partitioning, policy,
//! ρ) with ρ innermost and ascending, so scenarios sharing everything but
//! ρ are contiguous — each such group is one **ladder** (one curve of the
//! throughput-vs-load plot). Because the ρ anchor stays the whole-chip
//! `s₁` whatever the partitioning (see [`crate::serve::dispatch`]), the
//! ladders of a `--partitions` ladder-of-ladders share their arrival
//! streams rung-for-rung: the knee moving right with P is a like-for-like
//! comparison. The knee of a ladder is the first rung whose
//! completed throughput falls below [`KNEE_FRACTION`] of its offered rate:
//! below the knee the server keeps up (the drain after the last arrival is
//! noise); past it the queue grows without bound over the horizon and
//! completed throughput pins at the service capacity.
//!
//! JSON shape (`repro batch serve --json`):
//!
//! ```text
//! {"title": …,
//!  "scenarios": [{"spec": {…}, "report": {…}}, …],
//!  "ladders": [{"label": "tilepro64/immediate/poisson",
//!               "rows": [{"rho": 0.5, "offered_rps": …, "completed_rps": …,
//!                         "p50_cycles": …, "p99_cycles": …, "p999_cycles": …}, …],
//!               "knee": {"rho": 1.2, "offered_rps": …, "completed_rps": …} | null}, …],
//!  "table": {…}}
//! ```
//!
//! Determinism: scenarios are sharded over [`execute_indexed`] (results
//! keyed by index), each report is a pure function of its scenario, and
//! every Json object serialises with sorted keys — so the record is
//! byte-identical at any `--jobs`/`--intra-jobs`.

use crate::arch::{MachineSpec, PartitionSpec};
use crate::coherence::ProtocolSpec;
use crate::coordinator::batch::{execute_indexed, BatchRunner, RunSpec};
use crate::harness::SweepTable;
use crate::serve::arrivals::{ArrivalSpec, SizeMix};
use crate::serve::driver::{ServeReport, ServeScenario};
use crate::serve::queue::{Admission, BatchPolicy};
use crate::util::json::Json;

/// A ladder keeps up while `completed_rps >= KNEE_FRACTION * offered_rps`;
/// the first rung below is the saturation knee. 0.95 leaves room for the
/// finite-horizon drain tail (the server finishing its queue after the
/// last arrival) without ever absorbing a real ρ > 1 overload.
pub const KNEE_FRACTION: f64 = 0.95;

/// The full serve grid plus its ladder structure (scenario indices).
pub struct ServeSweep {
    pub title: String,
    pub scenarios: Vec<ServeScenario>,
    /// `(ladder label, scenario indices in ascending-ρ order)`.
    pub ladders: Vec<(String, Vec<usize>)>,
}

impl ServeSweep {
    /// Expand the grid. `template` fixes the per-request workload (case,
    /// size, threads, seed); machine/protocol are overlaid per cell, and
    /// the spatial axes (`partitions`, `admission`, `sizes`) apply to
    /// every cell. Rungs (`rhos`) are sorted ascending per ladder. Link +
    /// coherence billing turn on for non-default protocols (a directory
    /// protocol with the links off measures nothing — same rule as the
    /// protocol lab); `links` forces them on everywhere.
    pub fn grid(
        template: &RunSpec,
        machines: &[MachineSpec],
        protocols: &[ProtocolSpec],
        policies: &[BatchPolicy],
        arrival: ArrivalSpec,
        rhos: &[f64],
        requests: u64,
        queue_cap: usize,
        links: bool,
        partitions: &PartitionSpec,
        admission: Admission,
        sizes: &SizeMix,
    ) -> ServeSweep {
        assert!(
            !machines.is_empty() && !protocols.is_empty() && !policies.is_empty(),
            "empty serve grid axes"
        );
        assert!(!rhos.is_empty(), "need at least one --rhos rung");
        let mut rhos = rhos.to_vec();
        rhos.sort_by(|a, b| a.partial_cmp(b).expect("rho is never NaN"));
        let mut scenarios = Vec::new();
        let mut ladders = Vec::new();
        for &m in machines {
            for &p in protocols {
                let billed = links || !p.is_default();
                for &policy in policies {
                    let start = scenarios.len();
                    for &rho in &rhos {
                        scenarios.push(
                            ServeScenario::new(
                                template
                                    .clone()
                                    .on_machine(m, billed, billed)
                                    .with_protocol(p),
                                arrival,
                                rho,
                                requests,
                                queue_cap,
                                policy,
                            )
                            .with_partitions(partitions.clone())
                            .with_admission(admission)
                            .with_sizes(sizes.clone()),
                        );
                    }
                    let label = scenarios[start].ladder_label();
                    ladders.push((label, (start..scenarios.len()).collect()));
                }
            }
        }
        let mut extras = String::new();
        if !partitions.is_whole() {
            extras.push_str(&format!(", partitions {}", partitions.label()));
        }
        if !admission.is_default() {
            extras.push_str(&format!(", admission {}", admission.label()));
        }
        ServeSweep {
            // `sizes.label()` prints a single size as bare digits, so
            // pre-partition titles keep their bytes.
            title: format!(
                "Serve front-end: {} request(s) of {} ints x {} thread(s) per replay, \
                 {} arrivals ({} ladder(s) x {} rung(s)){}",
                requests,
                sizes.label(),
                template.threads,
                arrival.label(),
                ladders.len(),
                rhos.len(),
                extras
            ),
            scenarios,
            ladders,
        }
    }

    /// CLI-time validation of every cell (see [`ServeScenario::check`]).
    pub fn check(&self) -> Result<(), String> {
        for s in &self.scenarios {
            s.check()?;
        }
        Ok(())
    }

    /// Simulate every scenario through the batch pool. Reports are
    /// index-aligned with `self.scenarios` at any worker count.
    pub fn run(&self, runner: &BatchRunner) -> Vec<ServeReport> {
        let intra = runner.intra_jobs();
        execute_indexed(&self.scenarios, runner.jobs(), |_, s| s.simulate(intra))
    }

    /// One table row per scenario: the latency digest (ms) plus the
    /// throughput pair — the human-readable half of the record.
    pub fn table(&self, reports: &[ServeReport]) -> SweepTable {
        let mut t = SweepTable::new(
            &self.title,
            "ladder rho=R",
            ["p50_ms", "p99_ms", "p999_ms", "offered_rps", "completed_rps", "dropped"]
                .iter()
                .map(|s| s.to_string())
                .collect(),
        );
        for (s, r) in self.scenarios.iter().zip(reports) {
            t.push_row(
                s.label(),
                vec![
                    r.ms(r.p50_cycles),
                    r.ms(r.p99_cycles),
                    r.ms(r.p999_cycles),
                    r.offered_rps,
                    r.completed_rps,
                    r.dropped as f64,
                ],
            );
        }
        t
    }

    /// Knee rung of one ladder: index *into the ladder's rows* of the
    /// first rung that fails to keep up, or `None` if every rung keeps up.
    fn knee(&self, rows: &[usize], reports: &[ServeReport]) -> Option<usize> {
        rows.iter().position(|&i| {
            let r = &reports[i];
            r.offered_rps > 0.0 && r.completed_rps < KNEE_FRACTION * r.offered_rps
        })
    }

    /// The headline stderr report: per ladder, the throughput curve and
    /// where (whether) it saturates.
    pub fn report(&self, reports: &[ServeReport]) -> String {
        let mut out = String::from("serve: throughput-vs-offered-load ladders:\n");
        for (label, rows) in &self.ladders {
            let knee = self.knee(rows, reports);
            out.push_str(&format!("  {label}:\n"));
            for (j, &i) in rows.iter().enumerate() {
                let s = &self.scenarios[i];
                let r = &reports[i];
                out.push_str(&format!(
                    "    rho={:<5} offered {:>12.1} req/s, completed {:>12.1} req/s, \
                     p99 {:.3} ms, dropped {}{}\n",
                    s.rho,
                    r.offered_rps,
                    r.completed_rps,
                    r.ms(r.p99_cycles),
                    r.dropped,
                    if knee == Some(j) { "   <-- saturation knee" } else { "" }
                ));
            }
            match knee {
                Some(j) => out.push_str(&format!(
                    "    knee at rho={} (completed < {:.0}% of offered)\n",
                    self.scenarios[rows[j]].rho,
                    KNEE_FRACTION * 100.0
                )),
                None => out.push_str("    no knee inside this rho ladder\n"),
            }
        }
        out
    }

    /// The full machine-readable record (see module docs for the shape).
    pub fn to_json(&self, reports: &[ServeReport]) -> Json {
        let scenarios = self
            .scenarios
            .iter()
            .zip(reports)
            .map(|(s, r)| Json::obj(vec![("spec", s.to_json()), ("report", r.to_json())]))
            .collect::<Vec<_>>();
        let ladders = self
            .ladders
            .iter()
            .map(|(label, rows)| {
                let knee = self.knee(rows, reports).map(|j| rows[j]);
                let row_objs = rows
                    .iter()
                    .map(|&i| ladder_row(&self.scenarios[i], &reports[i]))
                    .collect::<Vec<_>>();
                Json::obj(vec![
                    ("label", Json::str(label.clone())),
                    ("rows", Json::arr(row_objs)),
                    (
                        "knee",
                        match knee {
                            Some(i) => ladder_row(&self.scenarios[i], &reports[i]),
                            None => Json::Null,
                        },
                    ),
                ])
            })
            .collect::<Vec<_>>();
        Json::obj(vec![
            ("title", Json::str(self.title.clone())),
            ("scenarios", Json::arr(scenarios)),
            ("ladders", Json::arr(ladders)),
            ("table", self.table(reports).to_json()),
        ])
    }
}

/// One rung of a ladder's throughput-vs-load curve.
fn ladder_row(s: &ServeScenario, r: &ServeReport) -> Json {
    Json::obj(vec![
        ("rho", Json::num(s.rho)),
        ("offered_rps", Json::num(r.offered_rps)),
        ("completed_rps", Json::num(r.completed_rps)),
        ("p50_cycles", Json::num(r.p50_cycles as f64)),
        ("p99_cycles", Json::num(r.p99_cycles as f64)),
        ("p999_cycles", Json::num(r.p999_cycles as f64)),
        ("dropped", Json::num(r.dropped as f64)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_sweep(rhos: &[f64]) -> ServeSweep {
        ServeSweep::grid(
            &RunSpec::mergesort(8, 1 << 10, 4, 42),
            &[MachineSpec::TilePro64],
            &[ProtocolSpec::default()],
            &[BatchPolicy::Immediate, BatchPolicy::Batch { max: 4, wait: 0 }],
            ArrivalSpec::Poisson,
            rhos,
            24,
            1 << 20,
            false,
            &PartitionSpec::Whole,
            Admission::Fifo,
            &SizeMix::single(1 << 10),
        )
    }

    #[test]
    fn grid_shape_and_ladder_indices() {
        let sw = tiny_sweep(&[1.3, 0.5]);
        assert_eq!(sw.scenarios.len(), 4, "2 policies x 2 rhos");
        assert_eq!(sw.ladders.len(), 2);
        for (_, rows) in &sw.ladders {
            assert_eq!(rows.len(), 2);
            // Rungs sorted ascending even though input was descending.
            assert!(sw.scenarios[rows[0]].rho < sw.scenarios[rows[1]].rho);
        }
        sw.check().unwrap();
    }

    #[test]
    fn overload_rung_is_the_knee() {
        let sw = tiny_sweep(&[0.4, 1.6]);
        let reports = sw.run(&BatchRunner::new(2));
        for (_, rows) in &sw.ladders {
            let knee = sw.knee(rows, &reports);
            assert_eq!(
                knee,
                Some(1),
                "rho=1.6 must saturate while rho=0.4 keeps up"
            );
        }
        let j = sw.to_json(&reports);
        let ladders = j.get("ladders").and_then(|l| l.as_arr()).unwrap();
        for l in ladders {
            assert!(
                !matches!(l.get("knee"), Some(&Json::Null) | None),
                "knee must be reported in JSON"
            );
        }
        assert!(sw.report(&reports).contains("saturation knee"));
    }

    #[test]
    fn non_default_protocol_turns_billing_on() {
        let sw = ServeSweep::grid(
            &RunSpec::mergesort(8, 1 << 10, 4, 42),
            &[MachineSpec::TilePro64],
            &[ProtocolSpec::default(), ProtocolSpec::parse("mesi").unwrap()],
            &[BatchPolicy::Immediate],
            ArrivalSpec::Poisson,
            &[0.5],
            8,
            64,
            false,
            &PartitionSpec::Whole,
            Admission::Fifo,
            &SizeMix::single(1 << 10),
        );
        assert!(!sw.scenarios[0].run.link_contention, "default stays baseline");
        assert!(sw.scenarios[1].run.link_contention);
        assert!(sw.scenarios[1].run.coherence_links);
        assert_ne!(sw.ladders[0].0, sw.ladders[1].0, "protocol in ladder label");
    }

    #[test]
    fn partitioned_grid_carries_the_spatial_axes() {
        let sw = ServeSweep::grid(
            &RunSpec::mergesort(8, 1 << 10, 4, 42),
            &[MachineSpec::TilePro64],
            &[ProtocolSpec::default()],
            &[BatchPolicy::Immediate],
            ArrivalSpec::Poisson,
            &[0.5, 2.0],
            12,
            1 << 20,
            false,
            &PartitionSpec::parse("2x2").unwrap(),
            Admission::Sjf,
            &SizeMix::parse("50%1024,50%4096").unwrap(),
        );
        sw.check().unwrap();
        assert!(sw.title.contains("partitions 2x2"), "{}", sw.title);
        assert!(sw.title.contains("admission sjf"), "{}", sw.title);
        let label = &sw.ladders[0].0;
        assert!(label.contains("part=2x2"), "{label}");
        assert!(label.contains("adm=sjf"), "{label}");
        assert!(label.contains("mix=50%1024,50%4096"), "{label}");
        assert_eq!(
            sw.scenarios[0].run.elems,
            2560,
            "template re-anchored at the mix's mean size"
        );
        let reports = sw.run(&BatchRunner::new(2));
        assert_eq!(reports[0].servers.len(), 4, "per-server slices in the report");
    }

    #[test]
    fn reports_identical_across_pool_widths() {
        let sw = tiny_sweep(&[0.6, 1.2]);
        let a = sw.to_json(&sw.run(&BatchRunner::new(1))).encode();
        let b = sw.to_json(&sw.run(&BatchRunner::new(4))).encode();
        assert_eq!(a, b);
    }
}
