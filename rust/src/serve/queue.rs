//! Bounded request queue, batching policies, and admission order.
//!
//! Admission is drop-tail: a request arriving at a full queue is counted
//! and discarded — the open-loop generator never blocks, so past the
//! saturation knee the drop counter (not backpressure) is what gives.
//! Dispatch policy decides how many queued requests one engine replay
//! serves:
//!
//! - [`BatchPolicy::Immediate`] — one request per replay, pure FIFO.
//! - [`BatchPolicy::Batch`] — coalesce up to `max` requests into one
//!   replay (a batch of k sorts k× the keys in a single run, amortising
//!   the per-replay fixed cost). `wait` caps how long the oldest request
//!   may be held while the batch fills; `wait = 0` is greedy coalescing —
//!   take whatever is queued whenever the server frees up.
//!
//! [`Admission`] decides *which* queued requests a dispatch takes:
//! arrival order ([`Admission::Fifo`], the default) or smallest request
//! first ([`Admission::Sjf`] — shortest-job-first by element count,
//! arrival sequence as the deterministic tie-break). SJF only bites when
//! the arrival stream mixes sizes (`--size 80%4ki,20%64ki`); with one
//! size it degenerates to FIFO, which is why the CLI rejects that combo.

use std::collections::VecDeque;

use crate::util::cli::parse_usize;

/// How the dispatcher groups queued requests onto the chip (`--policies`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BatchPolicy {
    /// One request per engine replay.
    Immediate,
    /// Up to `max` requests per replay; hold the oldest at most `wait`
    /// cycles while the batch fills (0 = never hold).
    Batch { max: u32, wait: u64 },
}

impl BatchPolicy {
    /// Parse `immediate`, `batchN` (greedy), or `batchN@W` (fill timer of
    /// `W` cycles, k/m/g suffixes accepted).
    pub fn parse(s: &str) -> Result<BatchPolicy, String> {
        if s == "immediate" {
            return Ok(BatchPolicy::Immediate);
        }
        let err = || {
            format!("bad batch policy '{s}': want immediate | batchN | batchN@W (N >= 2)")
        };
        let rest = s.strip_prefix("batch").ok_or_else(err)?;
        let (n, wait) = match rest.split_once('@') {
            None => (rest, 0u64),
            Some((n, w)) => (n, parse_usize(w).ok_or_else(err)? as u64),
        };
        match n.parse::<u32>() {
            Ok(max) if max >= 2 => Ok(BatchPolicy::Batch { max, wait }),
            _ => Err(err()),
        }
    }

    pub fn label(self) -> String {
        match self {
            BatchPolicy::Immediate => "immediate".into(),
            BatchPolicy::Batch { max, wait: 0 } => format!("batch{max}"),
            BatchPolicy::Batch { max, wait } => format!("batch{max}@{wait}"),
        }
    }

    /// Largest batch one replay may serve under this policy.
    pub fn max_batch(self) -> u32 {
        match self {
            BatchPolicy::Immediate => 1,
            BatchPolicy::Batch { max, .. } => max,
        }
    }
}

/// Which queued requests a dispatch takes (`--admission`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum Admission {
    /// Arrival order.
    #[default]
    Fifo,
    /// Shortest job first by element count; arrival sequence breaks ties,
    /// so equal-sized requests still go in FIFO order.
    Sjf,
}

impl Admission {
    pub fn parse(s: &str) -> Result<Admission, String> {
        match s {
            "fifo" => Ok(Admission::Fifo),
            "sjf" => Ok(Admission::Sjf),
            _ => Err(format!("bad --admission '{s}': want fifo | sjf")),
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            Admission::Fifo => "fifo",
            Admission::Sjf => "sjf",
        }
    }

    pub fn is_default(self) -> bool {
        self == Admission::Fifo
    }
}

/// One queued request: when it arrived, how big it is, and its admission
/// sequence number (the SJF tie-break).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct QueuedRequest {
    pub arrival: u64,
    pub elems: u64,
    pub seq: u64,
}

/// Bounded queue of pending requests, FIFO by admission; [`Admission`]
/// decides the *take* order at dispatch time.
pub struct RequestQueue {
    capacity: usize,
    q: VecDeque<QueuedRequest>,
    next_seq: u64,
    /// Requests refused at a full queue (drop-tail admission).
    pub dropped: u64,
    /// High-water mark of the queue depth.
    pub peak_depth: usize,
}

impl RequestQueue {
    pub fn new(capacity: usize) -> RequestQueue {
        RequestQueue {
            capacity,
            q: VecDeque::new(),
            next_seq: 0,
            dropped: 0,
            peak_depth: 0,
        }
    }

    /// Admit a request of `elems` elements that arrived at cycle `now`;
    /// returns `false` (and counts the drop) when the queue is full.
    pub fn offer(&mut self, now: u64, elems: u64) -> bool {
        if self.q.len() >= self.capacity {
            self.dropped += 1;
            return false;
        }
        self.q.push_back(QueuedRequest { arrival: now, elems, seq: self.next_seq });
        self.next_seq += 1;
        self.peak_depth = self.peak_depth.max(self.q.len());
        true
    }

    /// Arrival cycle of the oldest queued request (the batch-fill timer's
    /// anchor, whatever the admission order — holding is about how stale
    /// the queue is, not which request goes first).
    pub fn front_arrival(&self) -> Option<u64> {
        self.q.front().map(|r| r.arrival)
    }

    /// Size of the request a dispatch under `admission` would serve
    /// first — the locality-affinity key of the multi-server dispatcher.
    pub fn head_elems(&self, admission: Admission) -> Option<u64> {
        match admission {
            Admission::Fifo => self.q.front().map(|r| r.elems),
            Admission::Sjf => self.q.iter().min_by_key(|r| (r.elems, r.seq)).map(|r| r.elems),
        }
    }

    /// Dequeue `n` requests in `admission` order: the `n` oldest (FIFO)
    /// or the `n` smallest by `(elems, seq)` (SJF). Clamps to the queue
    /// length; the returned batch is in take order.
    pub fn take(&mut self, n: usize, admission: Admission) -> Vec<QueuedRequest> {
        let n = n.min(self.q.len());
        match admission {
            Admission::Fifo => self.q.drain(..n).collect(),
            Admission::Sjf => {
                let mut order: Vec<usize> = (0..self.q.len()).collect();
                order.sort_by_key(|&i| (self.q[i].elems, self.q[i].seq));
                order.truncate(n);
                let batch: Vec<QueuedRequest> = order.iter().map(|&i| self.q[i]).collect();
                // Remove back-to-front so earlier indices stay valid.
                order.sort_unstable_by(|a, b| b.cmp(a));
                for i in order {
                    self.q.remove(i);
                }
                batch
            }
        }
    }

    pub fn len(&self) -> usize {
        self.q.len()
    }

    pub fn is_empty(&self) -> bool {
        self.q.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policy_parse_round_trips() {
        for s in ["immediate", "batch8", "batch4@512", "batch2@1k"] {
            let p = BatchPolicy::parse(s).unwrap();
            // 1k normalises to cycles in the label.
            let back = BatchPolicy::parse(&p.label()).unwrap();
            assert_eq!(p, back, "{s}");
        }
        assert_eq!(BatchPolicy::parse("batch8").unwrap().max_batch(), 8);
        assert_eq!(BatchPolicy::parse("immediate").unwrap().max_batch(), 1);
        for s in ["", "batch", "batch1", "batch0", "batch8@", "batch8@x", "b8"] {
            assert!(BatchPolicy::parse(s).is_err(), "{s} must not parse");
        }
    }

    #[test]
    fn admission_parse_round_trips() {
        for s in ["fifo", "sjf"] {
            assert_eq!(Admission::parse(s).unwrap().label(), s);
        }
        assert!(Admission::parse("fifo").unwrap().is_default());
        assert!(!Admission::parse("sjf").unwrap().is_default());
        for s in ["", "FIFO", "shortest", "sjf2"] {
            assert!(Admission::parse(s).is_err(), "{s} must not parse");
        }
    }

    fn arrivals(q: &[QueuedRequest]) -> Vec<u64> {
        q.iter().map(|r| r.arrival).collect()
    }

    #[test]
    fn queue_is_fifo_and_bounded() {
        let mut q = RequestQueue::new(3);
        assert!(q.offer(10, 64) && q.offer(20, 64) && q.offer(30, 64));
        assert!(!q.offer(40, 64), "fourth request must drop");
        assert_eq!(q.dropped, 1);
        assert_eq!(q.peak_depth, 3);
        assert_eq!(q.front_arrival(), Some(10));
        assert_eq!(q.head_elems(Admission::Fifo), Some(64));
        assert_eq!(arrivals(&q.take(2, Admission::Fifo)), vec![10, 20]);
        assert_eq!(q.len(), 1);
        // Room again after the take.
        assert!(q.offer(50, 64));
        assert_eq!(
            arrivals(&q.take(10, Admission::Fifo)),
            vec![30, 50],
            "take clamps to queue length"
        );
        assert!(q.is_empty());
    }

    #[test]
    fn sjf_takes_smallest_with_fifo_tie_break() {
        let mut q = RequestQueue::new(8);
        q.offer(1, 512);
        q.offer(2, 64);
        q.offer(3, 512);
        q.offer(4, 64);
        q.offer(5, 128);
        // Head under SJF is the earliest 64; FIFO head is the 512.
        assert_eq!(q.head_elems(Admission::Sjf), Some(64));
        assert_eq!(q.head_elems(Admission::Fifo), Some(512));
        // Fill-timer anchor stays the oldest arrival either way.
        assert_eq!(q.front_arrival(), Some(1));
        let batch = q.take(3, Admission::Sjf);
        assert_eq!(arrivals(&batch), vec![2, 4, 5], "both 64s (in order), then 128");
        // The two 512s remain, still in arrival order.
        assert_eq!(arrivals(&q.take(10, Admission::Sjf)), vec![1, 3]);
        assert!(q.is_empty());
    }

    #[test]
    fn seq_numbers_survive_interleaved_takes() {
        let mut q = RequestQueue::new(8);
        q.offer(1, 100);
        q.offer(2, 100);
        q.take(1, Admission::Sjf);
        q.offer(3, 100);
        // Ties break by admission sequence even across takes.
        assert_eq!(arrivals(&q.take(2, Admission::Sjf)), vec![2, 3]);
    }
}
