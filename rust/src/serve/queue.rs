//! Bounded request queue and batching policies.
//!
//! Admission is drop-tail: a request arriving at a full queue is counted
//! and discarded — the open-loop generator never blocks, so past the
//! saturation knee the drop counter (not backpressure) is what gives.
//! Dispatch policy decides how many queued requests one engine replay
//! serves:
//!
//! - [`BatchPolicy::Immediate`] — one request per replay, pure FIFO.
//! - [`BatchPolicy::Batch`] — coalesce up to `max` requests into one
//!   replay (a batch of k sorts k× the keys in a single run, amortising
//!   the per-replay fixed cost). `wait` caps how long the oldest request
//!   may be held while the batch fills; `wait = 0` is greedy coalescing —
//!   take whatever is queued whenever the server frees up.

use std::collections::VecDeque;

use crate::util::cli::parse_usize;

/// How the dispatcher groups queued requests onto the chip (`--policies`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BatchPolicy {
    /// One request per engine replay.
    Immediate,
    /// Up to `max` requests per replay; hold the oldest at most `wait`
    /// cycles while the batch fills (0 = never hold).
    Batch { max: u32, wait: u64 },
}

impl BatchPolicy {
    /// Parse `immediate`, `batchN` (greedy), or `batchN@W` (fill timer of
    /// `W` cycles, k/m/g suffixes accepted).
    pub fn parse(s: &str) -> Result<BatchPolicy, String> {
        if s == "immediate" {
            return Ok(BatchPolicy::Immediate);
        }
        let err = || {
            format!("bad batch policy '{s}': want immediate | batchN | batchN@W (N >= 2)")
        };
        let rest = s.strip_prefix("batch").ok_or_else(err)?;
        let (n, wait) = match rest.split_once('@') {
            None => (rest, 0u64),
            Some((n, w)) => (n, parse_usize(w).ok_or_else(err)? as u64),
        };
        match n.parse::<u32>() {
            Ok(max) if max >= 2 => Ok(BatchPolicy::Batch { max, wait }),
            _ => Err(err()),
        }
    }

    pub fn label(self) -> String {
        match self {
            BatchPolicy::Immediate => "immediate".into(),
            BatchPolicy::Batch { max, wait: 0 } => format!("batch{max}"),
            BatchPolicy::Batch { max, wait } => format!("batch{max}@{wait}"),
        }
    }

    /// Largest batch one replay may serve under this policy.
    pub fn max_batch(self) -> u32 {
        match self {
            BatchPolicy::Immediate => 1,
            BatchPolicy::Batch { max, .. } => max,
        }
    }
}

/// Bounded FIFO of pending requests, each remembered by its arrival cycle.
pub struct RequestQueue {
    capacity: usize,
    q: VecDeque<u64>,
    /// Requests refused at a full queue (drop-tail admission).
    pub dropped: u64,
    /// High-water mark of the queue depth.
    pub peak_depth: usize,
}

impl RequestQueue {
    pub fn new(capacity: usize) -> RequestQueue {
        RequestQueue {
            capacity,
            q: VecDeque::new(),
            dropped: 0,
            peak_depth: 0,
        }
    }

    /// Admit a request that arrived at cycle `now`; returns `false` (and
    /// counts the drop) when the queue is full.
    pub fn offer(&mut self, now: u64) -> bool {
        if self.q.len() >= self.capacity {
            self.dropped += 1;
            return false;
        }
        self.q.push_back(now);
        self.peak_depth = self.peak_depth.max(self.q.len());
        true
    }

    /// Arrival cycle of the oldest queued request.
    pub fn front_arrival(&self) -> Option<u64> {
        self.q.front().copied()
    }

    /// Dequeue the `n` oldest requests' arrival cycles (FIFO).
    pub fn take(&mut self, n: usize) -> Vec<u64> {
        self.q.drain(..n.min(self.q.len())).collect()
    }

    pub fn len(&self) -> usize {
        self.q.len()
    }

    pub fn is_empty(&self) -> bool {
        self.q.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policy_parse_round_trips() {
        for s in ["immediate", "batch8", "batch4@512", "batch2@1k"] {
            let p = BatchPolicy::parse(s).unwrap();
            // 1k normalises to cycles in the label.
            let back = BatchPolicy::parse(&p.label()).unwrap();
            assert_eq!(p, back, "{s}");
        }
        assert_eq!(BatchPolicy::parse("batch8").unwrap().max_batch(), 8);
        assert_eq!(BatchPolicy::parse("immediate").unwrap().max_batch(), 1);
        for s in ["", "batch", "batch1", "batch0", "batch8@", "batch8@x", "b8"] {
            assert!(BatchPolicy::parse(s).is_err(), "{s} must not parse");
        }
    }

    #[test]
    fn queue_is_fifo_and_bounded() {
        let mut q = RequestQueue::new(3);
        assert!(q.offer(10) && q.offer(20) && q.offer(30));
        assert!(!q.offer(40), "fourth request must drop");
        assert_eq!(q.dropped, 1);
        assert_eq!(q.peak_depth, 3);
        assert_eq!(q.front_arrival(), Some(10));
        assert_eq!(q.take(2), vec![10, 20]);
        assert_eq!(q.len(), 1);
        // Room again after the take.
        assert!(q.offer(50));
        assert_eq!(q.take(10), vec![30, 50], "take clamps to queue length");
        assert!(q.is_empty());
    }
}
