//! Tile Linux (SMP Linux 2.6.26) scheduler model.
//!
//! The paper's observation (§4): "The Tile Linux tries to migrate the
//! threads during the execution time, and those migrations are costly not
//! only in terms of cache misses but also because of the resulting delay."
//! We model exactly that: a decent initial spread (the kernel does balance
//! run queues), then periodic load-balancer ticks that, with some
//! probability, bounce a thread to another core. Every parameter is
//! seeded/deterministic so experiments replay exactly; the migration rate
//! is swept in `benches/ablation_migration.rs`.

use super::Scheduler;
use crate::arch::{Machine, TileId};
use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug)]
pub struct TileLinuxConfig {
    /// Load-balancer tick interval per thread, in cycles (~1.2 ms at
    /// 860 MHz ≈ the 2.6-era rebalance period on this core count).
    pub check_interval: u64,
    /// Probability a tick moves the thread.
    pub migrate_prob: f64,
    pub seed: u64,
}

impl Default for TileLinuxConfig {
    fn default() -> Self {
        TileLinuxConfig {
            check_interval: 1_000_000,
            migrate_prob: 0.20,
            seed: 0x7115_11EC,
        }
    }
}

pub struct TileLinuxScheduler {
    cfg: TileLinuxConfig,
    rng: Rng,
    num_tiles: u32,
    /// Initial placement permutation (kernel spreads across idle cores but
    /// in an order the application cannot rely on).
    perm: Vec<u32>,
    next_check: Vec<u64>,
    pub migrations: u64,
}

impl TileLinuxScheduler {
    /// Scheduler on the default TILEPro64 preset (the paper's platform;
    /// the seeded permutation over 64 tiles is unchanged from the seed).
    pub fn new(cfg: TileLinuxConfig) -> Self {
        Self::new_on(cfg, &Machine::tilepro64())
    }

    /// Scheduler spreading over an arbitrary machine's tiles.
    pub fn new_on(cfg: TileLinuxConfig, machine: &Machine) -> Self {
        let num_tiles = machine.num_tiles();
        let mut rng = Rng::new(cfg.seed);
        let mut perm: Vec<u32> = (0..num_tiles).collect();
        rng.shuffle(&mut perm);
        TileLinuxScheduler {
            cfg,
            rng,
            num_tiles,
            perm,
            next_check: Vec::new(),
            migrations: 0,
        }
    }

    pub fn with_seed(seed: u64) -> Self {
        Self::new(TileLinuxConfig {
            seed,
            ..Default::default()
        })
    }

    pub fn with_seed_on(seed: u64, machine: &Machine) -> Self {
        Self::new_on(
            TileLinuxConfig {
                seed,
                ..Default::default()
            },
            machine,
        )
    }
}

impl Scheduler for TileLinuxScheduler {
    fn initial_tile(&mut self, tid: usize) -> TileId {
        if self.next_check.len() <= tid {
            self.next_check.resize(tid + 1, self.cfg.check_interval);
        }
        TileId(self.perm[tid % self.num_tiles as usize])
    }

    fn maybe_migrate(&mut self, tid: usize, current: TileId, now: u64) -> Option<TileId> {
        if tid >= self.next_check.len() || now < self.next_check[tid] {
            return None;
        }
        self.next_check[tid] = now + self.cfg.check_interval;
        if !self.rng.chance(self.cfg.migrate_prob) {
            return None;
        }
        // Load balancer picks another core; it doesn't know about home
        // caches (that's the paper's point), so the target is arbitrary.
        let mut target = TileId(self.rng.below(self.num_tiles as u64) as u32);
        if target == current {
            target = TileId((target.0 + 1) % self.num_tiles);
        }
        self.migrations += 1;
        Some(target)
    }

    fn label(&self) -> &'static str {
        "tile-linux"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_placement() {
        let mut a = TileLinuxScheduler::with_seed(1);
        let mut b = TileLinuxScheduler::with_seed(1);
        for tid in 0..64 {
            assert_eq!(a.initial_tile(tid), b.initial_tile(tid));
        }
    }

    #[test]
    fn initial_placement_is_a_spread() {
        let mut s = TileLinuxScheduler::with_seed(2);
        let tiles: std::collections::HashSet<_> = (0..64).map(|t| s.initial_tile(t)).collect();
        assert_eq!(tiles.len(), 64, "kernel spreads threads over all cores");
    }

    #[test]
    fn migrations_happen_over_time() {
        let mut s = TileLinuxScheduler::with_seed(3);
        let t0 = s.initial_tile(0);
        let mut migrated = 0;
        let mut tile = t0;
        for step in 1..200u64 {
            if let Some(n) = s.maybe_migrate(0, tile, step * 2_000_000) {
                tile = n;
                migrated += 1;
            }
        }
        assert!(migrated > 10, "expected migrations, got {migrated}");
        assert_eq!(s.migrations, migrated);
    }

    #[test]
    fn no_migration_before_interval() {
        let mut s = TileLinuxScheduler::with_seed(4);
        let t = s.initial_tile(0);
        assert_eq!(s.maybe_migrate(0, t, 10), None);
    }

    #[test]
    fn migration_never_targets_current_tile() {
        let mut s = TileLinuxScheduler::with_seed(5);
        let mut tile = s.initial_tile(0);
        for step in 1..500u64 {
            if let Some(n) = s.maybe_migrate(0, tile, step * 2_000_000) {
                assert_ne!(n, tile);
                tile = n;
            }
        }
    }

    #[test]
    fn machine_bound_scheduler_stays_in_range() {
        let m = Machine::custom(4, 8, 2).unwrap();
        let mut s = TileLinuxScheduler::with_seed_on(9, &m);
        let mut tile = TileId(0);
        for tid in 0..64 {
            let t = s.initial_tile(tid);
            assert!(t.0 < 32, "initial tile {t:?} off the 4x8 grid");
            tile = t;
        }
        for step in 1..500u64 {
            if let Some(n) = s.maybe_migrate(0, tile, step * 2_000_000) {
                assert!(n.0 < 32, "migration target {n:?} off the 4x8 grid");
                tile = n;
            }
        }
    }

    #[test]
    fn zero_probability_never_migrates() {
        let mut s = TileLinuxScheduler::new(TileLinuxConfig {
            migrate_prob: 0.0,
            ..Default::default()
        });
        let t = s.initial_tile(0);
        for step in 1..100u64 {
            assert_eq!(s.maybe_migrate(0, t, step * 10_000_000), None);
        }
    }
}
