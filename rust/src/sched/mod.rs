//! Thread→tile placement: the paper's second building block.
//!
//! `StaticMapper` pins thread i to core i (the `STATIC_MAPPING` /
//! `sched_setaffinity` path of Algorithm 3); `TileLinuxScheduler` models the
//! stock SMP Linux behaviour — reasonable initial spread but periodic
//! load-balancing migrations that cost time and flush cache locality.

pub mod static_map;
pub mod tile_linux;

use crate::arch::TileId;

/// Placement policy consulted by the engine.
pub trait Scheduler {
    /// Tile a thread starts on.
    fn initial_tile(&mut self, tid: usize) -> TileId;

    /// Called periodically per thread (roughly every scheduling quantum);
    /// returning `Some(t)` migrates the thread to `t` (costing
    /// `LatencyParams::migration_cost` and all cache locality).
    fn maybe_migrate(&mut self, tid: usize, current: TileId, now_cycles: u64) -> Option<TileId>;

    fn label(&self) -> &'static str;

    /// True iff this scheduler is stateless and never migrates:
    /// `maybe_migrate` always returns `None` (with no side effects), so
    /// skipping its per-quantum tick cannot change any observable state.
    /// The intra-run parallel replay is only taken for static schedulers —
    /// migrating threads between tiles mid-epoch would break the
    /// tile-partitioned determinism argument.
    fn is_static(&self) -> bool {
        false
    }
}

pub use static_map::StaticMapper;
pub use tile_linux::{TileLinuxConfig, TileLinuxScheduler};
