//! Static ordered mapping: thread i pinned to core `i % 64`, forever.
//!
//! This is Algorithm 3's `STATIC_MAPPING` block: each leaf thread takes the
//! next counter value and `sched_setaffinity`s itself onto that core — "in
//! the ordered way", deliberately, so Fig. 4's controller-utilisation
//! asymmetry (threads 0–31 fill the top half of the chip) is reproduced.

use super::Scheduler;
use crate::arch::{TileId, NUM_TILES};

#[derive(Default)]
pub struct StaticMapper;

impl StaticMapper {
    pub fn new() -> Self {
        StaticMapper
    }
}

impl Scheduler for StaticMapper {
    fn initial_tile(&mut self, tid: usize) -> TileId {
        TileId((tid as u32) % NUM_TILES)
    }

    fn maybe_migrate(&mut self, _tid: usize, _current: TileId, _now: u64) -> Option<TileId> {
        None
    }

    fn label(&self) -> &'static str {
        "static"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ordered_pinning() {
        let mut s = StaticMapper::new();
        assert_eq!(s.initial_tile(0), TileId(0));
        assert_eq!(s.initial_tile(31), TileId(31));
        assert_eq!(s.initial_tile(64), TileId(0)); // wraps
    }

    #[test]
    fn never_migrates() {
        let mut s = StaticMapper::new();
        for now in [0u64, 1_000_000, u64::MAX / 2] {
            assert_eq!(s.maybe_migrate(3, TileId(3), now), None);
        }
    }

    #[test]
    fn first_32_threads_fill_upper_half() {
        // The Fig. 4 premise: threads 0..31 sit on rows 0..3 (top half).
        let mut s = StaticMapper::new();
        for tid in 0..32 {
            assert!(s.initial_tile(tid).coord().y < 4);
        }
    }
}
