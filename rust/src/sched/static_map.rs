//! Static ordered mapping: thread i pinned to core `i % num_tiles`, forever.
//!
//! This is Algorithm 3's `STATIC_MAPPING` block: each leaf thread takes the
//! next counter value and `sched_setaffinity`s itself onto that core — "in
//! the ordered way", deliberately, so Fig. 4's controller-utilisation
//! asymmetry (threads 0–31 fill the top half of the chip) is reproduced.

use super::Scheduler;
use crate::arch::{Machine, TileId};

pub struct StaticMapper {
    num_tiles: u32,
}

impl StaticMapper {
    /// Mapper for the default TILEPro64 preset (tests and the paper runs).
    pub fn new() -> Self {
        StaticMapper::for_machine(&Machine::tilepro64())
    }

    /// Mapper sized to an arbitrary machine's tile count.
    pub fn for_machine(machine: &Machine) -> Self {
        StaticMapper {
            num_tiles: machine.num_tiles(),
        }
    }
}

impl Default for StaticMapper {
    fn default() -> Self {
        StaticMapper::new()
    }
}

impl Scheduler for StaticMapper {
    fn initial_tile(&mut self, tid: usize) -> TileId {
        TileId((tid as u32) % self.num_tiles)
    }

    fn maybe_migrate(&mut self, _tid: usize, _current: TileId, _now: u64) -> Option<TileId> {
        None
    }

    fn label(&self) -> &'static str {
        "static"
    }

    fn is_static(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ordered_pinning() {
        let mut s = StaticMapper::new();
        assert_eq!(s.initial_tile(0), TileId(0));
        assert_eq!(s.initial_tile(31), TileId(31));
        assert_eq!(s.initial_tile(64), TileId(0)); // wraps
    }

    #[test]
    fn never_migrates() {
        let mut s = StaticMapper::new();
        for now in [0u64, 1_000_000, u64::MAX / 2] {
            assert_eq!(s.maybe_migrate(3, TileId(3), now), None);
        }
    }

    #[test]
    fn first_32_threads_fill_upper_half() {
        // The Fig. 4 premise: threads 0..31 sit on rows 0..3 (top half).
        let mut s = StaticMapper::new();
        for tid in 0..32 {
            assert!(s.initial_tile(tid).coord().y < 4);
        }
    }

    #[test]
    fn wraps_at_machine_tile_count() {
        let m = Machine::custom(4, 4, 1).unwrap();
        let mut s = StaticMapper::for_machine(&m);
        assert_eq!(s.initial_tile(15), TileId(15));
        assert_eq!(s.initial_tile(16), TileId(0));
        assert_eq!(s.initial_tile(17), TileId(1));
    }
}
