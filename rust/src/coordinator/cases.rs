//! Table 1: the 8-case design of experiments.
//!
//! The paper moves "smoothly from the conventional programming approach
//! towards the completely localised technique by changing one parameter at
//! a time": programming style × mapper × hash policy.

use std::sync::Arc;

use crate::arch::Machine;
use crate::mem::{HashPolicy, MemConfig};
use crate::sched::{Scheduler, StaticMapper, TileLinuxScheduler};
use crate::sim::EngineConfig;
use crate::workloads::mergesort::Variant;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MapperKind {
    TileLinux,
    Static,
}

impl MapperKind {
    pub fn label(self) -> &'static str {
        match self {
            MapperKind::TileLinux => "Tile Linux",
            MapperKind::Static => "Static Mapper",
        }
    }

    /// Instantiate the scheduler for the default TILEPro64 machine (Tile
    /// Linux is seeded for replayability).
    pub fn scheduler(self, seed: u64) -> Box<dyn Scheduler> {
        match self {
            MapperKind::TileLinux => Box::new(TileLinuxScheduler::with_seed(seed)),
            MapperKind::Static => Box::new(StaticMapper::new()),
        }
    }

    /// Instantiate the scheduler spreading over `machine`'s tiles.
    pub fn scheduler_on(self, seed: u64, machine: &Machine) -> Box<dyn Scheduler> {
        match self {
            MapperKind::TileLinux => Box::new(TileLinuxScheduler::with_seed_on(seed, machine)),
            MapperKind::Static => Box::new(StaticMapper::for_machine(machine)),
        }
    }
}

/// One row of Table 1.
#[derive(Clone, Copy, Debug)]
pub struct CaseSpec {
    /// 1-based case id as in the paper.
    pub id: u8,
    pub localised: bool,
    pub mapper: MapperKind,
    pub hash: HashPolicy,
}

impl CaseSpec {
    pub fn label(&self) -> String {
        format!(
            "Case {}: {} | {} | {}",
            self.id,
            if self.localised { "Localised" } else { "Non-localised" },
            self.mapper.label(),
            match self.hash {
                HashPolicy::AllButStack => "All but stack",
                HashPolicy::None => "None",
            }
        )
    }

    pub fn short(&self) -> String {
        format!("case{}", self.id)
    }

    /// Merge-sort variant this case runs (localised cases use Algorithm 4).
    pub fn mergesort_variant(&self) -> Variant {
        if self.localised {
            Variant::Localised
        } else {
            Variant::NonLocalised
        }
    }

    /// Engine configuration for this case on the paper-baseline TILEPro64
    /// (striping per Fig. 2: enabled; link contention off — see
    /// [`EngineConfig::tilepro64`]).
    pub fn engine_config(&self, striping: bool) -> EngineConfig {
        EngineConfig::tilepro64(MemConfig {
            hash_policy: self.hash,
            striping,
        })
    }

    /// Engine configuration for this case on an arbitrary machine, with
    /// link contention as requested.
    pub fn engine_config_on(
        &self,
        machine: Arc<Machine>,
        striping: bool,
        link_contention: bool,
    ) -> EngineConfig {
        let mut cfg = EngineConfig::for_machine(
            machine,
            MemConfig {
                hash_policy: self.hash,
                striping,
            },
        );
        cfg.contention.links = link_contention;
        cfg
    }
}

/// The eight cases exactly as in Table 1.
pub fn table1() -> [CaseSpec; 8] {
    use HashPolicy::*;
    use MapperKind::*;
    [
        CaseSpec { id: 1, localised: false, mapper: TileLinux, hash: AllButStack },
        CaseSpec { id: 2, localised: false, mapper: TileLinux, hash: None },
        CaseSpec { id: 3, localised: false, mapper: Static, hash: AllButStack },
        CaseSpec { id: 4, localised: false, mapper: Static, hash: None },
        CaseSpec { id: 5, localised: true, mapper: TileLinux, hash: AllButStack },
        CaseSpec { id: 6, localised: true, mapper: TileLinux, hash: None },
        CaseSpec { id: 7, localised: true, mapper: Static, hash: AllButStack },
        CaseSpec { id: 8, localised: true, mapper: Static, hash: None },
    ]
}

pub fn case(id: u8) -> CaseSpec {
    table1()[(id - 1) as usize]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eight_distinct_cases() {
        let cases = table1();
        assert_eq!(cases.len(), 8);
        for (i, c) in cases.iter().enumerate() {
            assert_eq!(c.id as usize, i + 1);
        }
        // All combinations distinct.
        let mut keys: Vec<_> = cases
            .iter()
            .map(|c| (c.localised, c.mapper == MapperKind::Static, c.hash == HashPolicy::None))
            .collect();
        keys.sort();
        keys.dedup();
        assert_eq!(keys.len(), 8);
    }

    #[test]
    fn matches_paper_table1() {
        // Spot-check the paper's rows.
        let c1 = case(1);
        assert!(!c1.localised && c1.mapper == MapperKind::TileLinux && c1.hash == HashPolicy::AllButStack);
        let c4 = case(4);
        assert!(!c4.localised && c4.mapper == MapperKind::Static && c4.hash == HashPolicy::None);
        let c8 = case(8);
        assert!(c8.localised && c8.mapper == MapperKind::Static && c8.hash == HashPolicy::None);
    }

    #[test]
    fn localised_cases_use_algorithm4() {
        assert_eq!(case(8).mergesort_variant(), Variant::Localised);
        assert_eq!(case(3).mergesort_variant(), Variant::NonLocalised);
    }

    #[test]
    fn labels_render() {
        assert_eq!(
            case(8).label(),
            "Case 8: Localised | Static Mapper | None"
        );
        assert_eq!(case(2).short(), "case2");
    }

    #[test]
    fn schedulers_instantiate() {
        assert_eq!(MapperKind::Static.scheduler(0).label(), "static");
        assert_eq!(MapperKind::TileLinux.scheduler(0).label(), "tile-linux");
    }
}
