//! The coordinator: the paper's contribution as a first-class feature.
//!
//! - [`localise`] — Algorithm 1 as a reusable API over any chunk kernel.
//! - [`cases`] — the Table 1 experiment matrix.
//! - [`experiment`] — sweep-spec builders that regenerate every
//!   figure/table through the batch pool.
//! - [`batch`] — the parallel sweep executor: `SweepSpec` grids sharded
//!   across host cores into a deterministic `ResultStore`.

pub mod batch;
pub mod cases;
pub mod experiment;
pub mod localise;

pub use batch::{derive_seeds, BatchRunner, Metric, ResultStore, RunSpec, SweepSpec, Workload};
pub use cases::{case, table1, CaseSpec, MapperKind};
pub use localise::{build_program, ChunkKernel, LocaliseConfig};
