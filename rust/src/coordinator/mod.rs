//! The coordinator: the paper's contribution as a first-class feature.
//!
//! - [`localise`] — Algorithm 1 as a reusable API over any chunk kernel.
//! - [`cases`] — the Table 1 experiment matrix.
//! - [`experiment`] — drivers that regenerate every figure/table.

pub mod cases;
pub mod experiment;
pub mod localise;

pub use cases::{case, table1, CaseSpec, MapperKind};
pub use localise::{build_program, ChunkKernel, LocaliseConfig};
