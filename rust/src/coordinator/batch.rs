//! Parallel batch execution of the experiment matrix.
//!
//! The paper's figures are grids of *independent* simulator runs (case ×
//! elems × threads × variant × seed), and every run is deterministic given
//! its `RunSpec` — so the sweep itself is an embarrassingly parallel
//! workload. This module shards an explicit [`SweepSpec`] across host cores
//! with a scoped-thread worker pool (std only), collects per-run
//! [`RunStats`] into a [`ResultStore`], and renders both the paper-style
//! [`SweepTable`] text and machine-readable JSON.
//!
//! Determinism is load-bearing: results are keyed by run index, not by
//! completion order, so `--jobs 1` and `--jobs N` produce byte-identical
//! JSON (`rust/tests/batch_determinism.rs` pins this).

use std::sync::atomic::{AtomicUsize, Ordering};

use crate::arch::{FabricSpec, MachineSpec};
use crate::coherence::ProtocolSpec;
use crate::coordinator::cases::case;
use crate::harness::SweepTable;
use crate::sim::{Engine, RunStats};
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::workloads::{mergesort, microbench, pingpong, radix};

/// Which trace generator a run replays.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Workload {
    /// Algorithm 2 with `reps` copy repetitions (Fig. 1).
    Microbench { reps: u32 },
    /// Algorithms 3/4 (Figs. 2–4, Table 1).
    Mergesort { variant: mergesort::Variant },
    /// The related-work radix baseline.
    Radix { digit_bits: u32 },
    /// Write ping-pong / false sharing (the `falseshare` coherence sweep).
    PingPong { passes: u32 },
}

impl Workload {
    pub fn label(&self) -> String {
        match self {
            Workload::Microbench { reps } => format!("microbench/r{reps}"),
            Workload::Mergesort { variant } => format!("mergesort/{}", variant.label()),
            Workload::Radix { digit_bits } => format!("radix/b{digit_bits}"),
            Workload::PingPong { passes } => format!("pingpong/p{passes}"),
        }
    }
}

/// One fully-specified simulator run. Everything the engine needs is here;
/// two equal specs always replay to identical [`RunStats`].
///
/// Build specs with [`RunSpec::new`] (or a convenience constructor like
/// [`RunSpec::mergesort`]) plus the `with_*`/`on_machine` builders — the
/// struct is `#[non_exhaustive]`, so out-of-crate literals won't compile
/// and new axes (like `protocol`) can land without breaking callers.
#[derive(Clone, Debug)]
#[non_exhaustive]
pub struct RunSpec {
    /// Table 1 case id (1..=8) — picks mapper, hash policy, and whether the
    /// localised programming style applies.
    pub case_id: u8,
    pub workload: Workload,
    pub elems: u64,
    pub threads: usize,
    pub striping: bool,
    /// Fig. 4's cache-off ablation.
    pub caches: bool,
    /// Which chip the run simulates. The default (tilepro64) replays the
    /// seed's figure record byte-identically.
    pub machine: MachineSpec,
    /// Model per-link mesh queueing. Off in the paper-baseline figure
    /// specs (the published record predates the link model); on for
    /// machine sweeps unless `--no-link-contention`.
    pub link_contention: bool,
    /// Bill coherence traffic (invalidation fan-out + reply paths) on the
    /// links. Follows `link_contention` unless `--no-coherence-links`.
    pub coherence_links: bool,
    /// Heterogeneous fabric applied on top of `machine`: controller
    /// placement and/or per-link service rules (`--fabric`, the placement
    /// and fabric sweeps). `None` — the baseline — leaves the machine's
    /// uniform fabric and `EdgesEven` controllers untouched, keeping the
    /// pinned figure JSON byte-identical.
    pub fabric: Option<FabricSpec>,
    /// Coherence protocol driven by the engine's protocol lab
    /// ([`crate::coherence`]). Default (`write-invalidate`) replays the
    /// fused directory path byte-identically and is omitted from labels
    /// and JSON.
    pub protocol: ProtocolSpec,
    /// Engine page-run fast path (`--no-page-runs` clears it). An
    /// execution strategy like `intra_jobs`, except it *is* spec-visible
    /// so CI can pin fast == reference on the same spec; stats are
    /// byte-identical either way, so it stays out of labels and JSON.
    pub page_runs: bool,
    pub seed: u64,
}

impl RunSpec {
    /// The base spec: `workload` under Table-1 `case_id` on the
    /// paper-baseline tilepro64 (striping and caches on, link contention
    /// off, default protocol). Layer deviations on with the `with_*`
    /// builders.
    pub fn new(case_id: u8, workload: Workload, elems: u64, threads: usize, seed: u64) -> RunSpec {
        RunSpec {
            case_id,
            workload,
            elems,
            threads,
            striping: true,
            caches: true,
            machine: MachineSpec::TilePro64,
            link_contention: false,
            coherence_links: false,
            fabric: None,
            protocol: ProtocolSpec::default(),
            page_runs: true,
            seed,
        }
    }

    /// Convenience: merge sort for `case_id` with the case's own variant,
    /// on the paper-baseline tilepro64.
    pub fn mergesort(case_id: u8, elems: u64, threads: usize, seed: u64) -> RunSpec {
        RunSpec::new(
            case_id,
            Workload::Mergesort {
                variant: case(case_id).mergesort_variant(),
            },
            elems,
            threads,
            seed,
        )
    }

    /// Fig. 3's striping axis.
    pub fn with_striping(mut self, striping: bool) -> RunSpec {
        self.striping = striping;
        self
    }

    /// Fig. 4's cache-off ablation.
    pub fn without_caches(mut self) -> RunSpec {
        self.caches = false;
        self
    }

    /// Re-aim the run at `machine` with link/coherence billing chosen.
    pub fn on_machine(
        mut self,
        machine: MachineSpec,
        link_contention: bool,
        coherence_links: bool,
    ) -> RunSpec {
        self.machine = machine;
        self.link_contention = link_contention;
        self.coherence_links = coherence_links;
        self
    }

    /// Apply a heterogeneous fabric on top of the machine (`None` is the
    /// uniform baseline).
    pub fn with_fabric(mut self, fabric: Option<FabricSpec>) -> RunSpec {
        self.fabric = fabric;
        self
    }

    /// Select the coherence protocol (`--protocol`).
    pub fn with_protocol(mut self, protocol: ProtocolSpec) -> RunSpec {
        self.protocol = protocol;
        self
    }

    /// Force the per-line reference walk (`--no-page-runs`) — the oracle
    /// the page-run fast path is pinned against.
    pub fn without_page_runs(mut self) -> RunSpec {
        self.page_runs = false;
        self
    }

    /// Whether this run deviates from the paper-baseline machine model
    /// (non-tilepro64 grid, link contention on, and/or a fabric applied).
    fn non_baseline_machine(&self) -> bool {
        self.machine != MachineSpec::TilePro64 || self.link_contention || self.fabric.is_some()
    }

    /// CLI-time guard: a run must not ask for more than 4 threads per tile
    /// of its machine (the engine's assert), and any fabric must actually
    /// fit the machine (placement capacity, region bounds). Returning an
    /// `Err` here beats a panic inside a pool worker.
    pub fn check_thread_capacity(&self) -> Result<(), String> {
        check_thread_capacity(self.threads, self.machine)?;
        self.machine
            .build_with_fabric(self.fabric.as_ref())
            .map_err(|e| e.to_string())?;
        Ok(())
    }

    /// The machine this run simulates, fabric applied. Callers must have
    /// validated the spec (see [`check_thread_capacity`](Self::check_thread_capacity)).
    fn build_machine(&self) -> std::sync::Arc<crate::arch::Machine> {
        self.machine
            .build_with_fabric(self.fabric.as_ref())
            .expect("fabric validated at the CLI")
    }

    pub fn label(&self) -> String {
        let machine = if self.non_baseline_machine() {
            format!(
                " on {}{}{}{}",
                self.machine.label(),
                match &self.fabric {
                    Some(f) => format!(" fab[{}]", f.label()),
                    None => String::new(),
                },
                if self.link_contention { "" } else { " nolinks" },
                if self.link_contention && !self.coherence_links {
                    " nocoh"
                } else {
                    ""
                }
            )
        } else {
            String::new()
        };
        let protocol = if self.protocol.is_default() {
            String::new()
        } else {
            format!(" proto={}", self.protocol.label())
        };
        format!(
            "case{} {} n={} t={}{}{}{}{} s={}",
            self.case_id,
            self.workload.label(),
            self.elems,
            self.threads,
            if self.striping { "" } else { " nostripe" },
            if self.caches { "" } else { " nocache" },
            machine,
            protocol,
            self.seed
        )
    }

    /// Build and replay this run on a fresh engine.
    pub fn execute(&self) -> RunStats {
        self.execute_intra(1)
    }

    /// Replay this run with `intra_jobs` host workers parallelising the
    /// replay *itself* (the epoch driver, see
    /// [`crate::sim::plan_intra_workers`]). The worker count is an
    /// execution strategy, deliberately not part of the spec: stats are
    /// byte-identical at every count, so records never mention it.
    pub fn execute_intra(&self, intra_jobs: usize) -> RunStats {
        self.execute_on(self.build_machine(), intra_jobs)
    }

    /// Replay this spec confined to one spatial partition of `parent`: the
    /// engine runs on the partition's sub-grid view
    /// ([`crate::arch::Partition::view`] — parent params and clock, the
    /// partition's own controller set), so homing, page table, and
    /// directory confine every page of the request to the partition's
    /// tiles by construction. Stats come back in view-local coordinates;
    /// [`crate::arch::Partition::global_link_index`] translates per-link
    /// vectors onto the parent grid (XY routes are translation-invariant,
    /// so the translation is exact). The spec's own `machine`/`fabric`
    /// fields are ignored here — the partition decides the chip.
    pub fn on_partition(
        &self,
        part: &crate::arch::Partition,
        parent: &crate::arch::Machine,
        intra_jobs: usize,
    ) -> RunStats {
        debug_assert!(self.fabric.is_none(), "partition replays are uniform-fabric");
        let view = part.view(parent).expect("partition carved from this parent");
        self.execute_on(std::sync::Arc::new(view), intra_jobs)
    }

    /// The shared replay core: run this spec's workload on an
    /// already-built machine (the spec's own, or a partition view).
    fn execute_on(
        &self,
        machine: std::sync::Arc<crate::arch::Machine>,
        intra_jobs: usize,
    ) -> RunStats {
        let c = case(self.case_id);
        let mut cfg = c.engine_config_on(machine.clone(), self.striping, self.link_contention);
        cfg.contention.coherence = self.coherence_links;
        cfg = cfg.with_protocol(self.protocol).with_intra_jobs(intra_jobs);
        if !self.caches {
            cfg = cfg.without_caches();
        }
        if !self.page_runs {
            cfg = cfg.without_page_runs();
        }
        let mut engine = Engine::new(cfg);
        let mut program = match self.workload {
            Workload::Microbench { reps } => microbench::build(
                &mut engine,
                &microbench::MicrobenchConfig {
                    elems: self.elems,
                    threads: self.threads,
                    reps,
                    localised: c.localised,
                },
            ),
            Workload::Mergesort { variant } => mergesort::build(
                &mut engine,
                &mergesort::MergesortConfig {
                    elems: self.elems,
                    threads: self.threads,
                    variant,
                },
            ),
            Workload::Radix { digit_bits } => radix::build(
                &mut engine,
                &radix::RadixConfig {
                    elems: self.elems,
                    threads: self.threads,
                    digit_bits,
                    localised: c.localised,
                },
            ),
            Workload::PingPong { passes } => pingpong::build(
                &mut engine,
                &pingpong::PingPongConfig {
                    elems: self.elems,
                    threads: self.threads,
                    passes,
                    localised: c.localised,
                },
            ),
        };
        let mut sched = c.mapper.scheduler_on(self.seed, &machine);
        engine
            .run(&mut program, sched.as_mut())
            .expect("batch run failed")
    }

    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("case", Json::num(self.case_id as f64)),
            ("workload", Json::str(self.workload.label())),
            ("elems", Json::num(self.elems as f64)),
            ("threads", Json::num(self.threads as f64)),
            ("striping", Json::Bool(self.striping)),
            ("caches", Json::Bool(self.caches)),
            // Seeds are full-range u64 (derive_seeds): a JSON double would
            // round them and break replay-from-record, so emit as a string.
            ("seed", Json::str(self.seed.to_string())),
        ];
        // Machine fields only for non-baseline runs: the pinned tilepro64
        // figure record keeps its pre-machine-layer JSON bytes. The
        // coherence flag is emitted only when it deviates from its
        // links-follow default, keeping pre-coherence link records stable.
        if self.non_baseline_machine() {
            fields.push(("machine", Json::str(self.machine.label())));
            fields.push(("link_contention", Json::Bool(self.link_contention)));
            if self.coherence_links != self.link_contention {
                fields.push(("coherence_links", Json::Bool(self.coherence_links)));
            }
            // The fabric clause only appears when one was applied, so
            // pre-fabric machine-sweep records keep their bytes too.
            if let Some(f) = &self.fabric {
                fields.push(("fabric", Json::str(f.label())));
            }
        }
        // Same deviation gate for the protocol lab: the default
        // write-invalidate protocol never appears, so every pre-protocol
        // record keeps its bytes.
        if !self.protocol.is_default() {
            fields.push(("protocol", Json::str(self.protocol.label())));
        }
        Json::obj(fields)
    }
}

/// How grid cells are rendered from run stats.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Metric {
    /// Cell = simulated seconds of the run at that cell.
    Seconds,
    /// Cell = baseline makespan / run makespan (Fig. 2 speed-ups).
    SpeedupVsBaseline,
    /// One run per row rendered as two columns: seconds and speed-up vs
    /// the baseline (Table 1).
    SecondsAndSpeedup,
}

impl Metric {
    fn label(&self) -> &'static str {
        match self {
            Metric::Seconds => "seconds",
            Metric::SpeedupVsBaseline => "speedup_vs_baseline",
            Metric::SecondsAndSpeedup => "seconds_and_speedup",
        }
    }
}

/// An explicit, fully-expanded sweep: a `row_labels.len() × series.len()`
/// grid of [`RunSpec`]s (row-major) plus an optional baseline run.
///
/// # Examples
///
/// Expand a small case × size × thread grid and run it through the worker
/// pool — the result table has one row per (elems, threads, seed) point
/// and one column per (case, workload) series:
///
/// ```
/// use tilesim::coordinator::{BatchRunner, SweepSpec, Workload};
/// use tilesim::workloads::mergesort::Variant;
///
/// let spec = SweepSpec::grid(
///     "doc demo",
///     &[1, 8],                                             // Table 1 cases
///     &[Workload::Mergesort { variant: Variant::Localised }],
///     &[1 << 12],                                          // elems
///     &[2],                                                // threads
///     &[7],                                                // seeds
/// );
/// spec.validate();
/// assert_eq!(spec.runs.len(), 2);
/// let table = BatchRunner::new(1).table(&spec);
/// assert_eq!(table.rows.len(), 1);
/// assert_eq!(
///     table.series,
///     vec!["case1/mergesort/localised", "case8/mergesort/localised"],
/// );
/// ```
pub struct SweepSpec {
    pub title: String,
    pub x_label: String,
    pub series: Vec<String>,
    pub row_labels: Vec<String>,
    /// Grid cells, row-major (`runs[r * series.len() + c]`), except under
    /// [`Metric::SecondsAndSpeedup`] where there is one run per row.
    pub runs: Vec<RunSpec>,
    pub baseline: Option<RunSpec>,
    pub metric: Metric,
}

impl SweepSpec {
    /// Runs per row under this spec's metric.
    fn runs_per_row(&self) -> usize {
        match self.metric {
            Metric::SecondsAndSpeedup => 1,
            _ => self.series.len(),
        }
    }

    /// Check the grid shape; panics early instead of mis-rendering later.
    pub fn validate(&self) {
        assert_eq!(
            self.runs.len(),
            self.row_labels.len() * self.runs_per_row(),
            "sweep grid shape mismatch: {} runs for {} rows × {} per row",
            self.runs.len(),
            self.row_labels.len(),
            self.runs_per_row()
        );
        if matches!(
            self.metric,
            Metric::SpeedupVsBaseline | Metric::SecondsAndSpeedup
        ) {
            assert!(self.baseline.is_some(), "metric requires a baseline run");
        }
    }

    /// The explicit cross-product grid: one series per (case, workload)
    /// combination, one row per (elems, threads, seed) point. Seeds come
    /// pre-derived (see [`derive_seeds`]).
    pub fn grid(
        title: &str,
        cases: &[u8],
        workloads: &[Workload],
        elems: &[u64],
        threads: &[usize],
        seeds: &[u64],
    ) -> SweepSpec {
        assert!(
            !cases.is_empty() && !workloads.is_empty(),
            "empty series axes"
        );
        assert!(
            !elems.is_empty() && !threads.is_empty() && !seeds.is_empty(),
            "empty row axes"
        );
        let mut series = Vec::new();
        for &c in cases {
            for w in workloads {
                series.push(format!("case{c}/{}", w.label()));
            }
        }
        let mut row_labels = Vec::new();
        let mut runs = Vec::new();
        for &n in elems {
            for &t in threads {
                for &s in seeds {
                    row_labels.push(format!("{n}x{t}@{s}"));
                    for &c in cases {
                        for w in workloads {
                            runs.push(RunSpec::new(c, *w, n, t, s));
                        }
                    }
                }
            }
        }
        SweepSpec {
            title: title.to_string(),
            x_label: "elems x threads @ seed".to_string(),
            series,
            row_labels,
            runs,
            baseline: None,
            metric: Metric::Seconds,
        }
    }

    /// CLI-time guard: every run (baseline included) must fit its
    /// machine's thread capacity — see [`RunSpec::check_thread_capacity`].
    pub fn check_thread_capacity(&self) -> Result<(), String> {
        for r in self.runs.iter().chain(self.baseline.iter()) {
            r.check_thread_capacity()?;
        }
        Ok(())
    }

    /// Re-target every run of the sweep (baseline included) at `machine`,
    /// with link contention and coherence-link billing as requested — how
    /// `--machine` re-aims the figure specs at a different chip.
    pub fn on_machine(
        mut self,
        machine: MachineSpec,
        link_contention: bool,
        coherence_links: bool,
    ) -> SweepSpec {
        for r in self.runs.iter_mut().chain(self.baseline.iter_mut()) {
            r.machine = machine;
            r.link_contention = link_contention;
            r.coherence_links = coherence_links;
        }
        if machine != MachineSpec::TilePro64 || link_contention {
            self.title = format!("{} [machine {}]", self.title, machine.label());
        }
        self
    }

    /// Apply a fabric (placement + link rules) to every run of the sweep,
    /// baseline included — how `--fabric` re-aims a figure spec. `None`
    /// leaves the sweep untouched.
    pub fn with_fabric(mut self, fabric: Option<FabricSpec>) -> SweepSpec {
        if let Some(f) = fabric {
            for r in self.runs.iter_mut().chain(self.baseline.iter_mut()) {
                r.fabric = Some(f.clone());
            }
            self.title = format!("{} [fabric {}]", self.title, f.label());
        }
        self
    }

    /// Run the whole sweep (baseline included) under a coherence protocol
    /// — how `--protocol` re-aims a figure spec. The default protocol
    /// leaves the sweep untouched (pinned records keep their bytes).
    pub fn with_protocol(mut self, protocol: ProtocolSpec) -> SweepSpec {
        if !protocol.is_default() {
            for r in self.runs.iter_mut().chain(self.baseline.iter_mut()) {
                r.protocol = protocol;
            }
            self.title = format!("{} [protocol {}]", self.title, protocol.label());
        }
        self
    }
}

/// The engine accepts at most 4 threads per tile; check it at the CLI
/// instead of panicking inside a pool worker (shared by every subcommand
/// that takes `--machine`, including ones without a `RunSpec`).
pub fn check_thread_capacity(threads: usize, machine: MachineSpec) -> Result<(), String> {
    let tiles = machine.build().num_tiles();
    if threads > 4 * tiles as usize {
        return Err(format!(
            "{} threads exceed 4x the {} machine's {} tiles",
            threads,
            machine.label(),
            tiles
        ));
    }
    Ok(())
}

/// Per-run deterministic seeds derived from a base seed via `util::rng` —
/// independent of worker count and scheduling order.
pub fn derive_seeds(base: u64, n: usize) -> Vec<u64> {
    let mut rng = Rng::new(base);
    (0..n).map(|_| rng.next_u64()).collect()
}

/// Stats for every run of a sweep, index-aligned with `spec.runs`.
pub struct ResultStore {
    pub results: Vec<RunStats>,
    pub baseline: Option<RunStats>,
}

impl ResultStore {
    /// Render the paper-style table for `spec` (the spec this store was
    /// produced from).
    pub fn table(&self, spec: &SweepSpec) -> SweepTable {
        spec.validate();
        let mut t = SweepTable::new(&spec.title, &spec.x_label, spec.series.clone());
        let base = self
            .baseline
            .as_ref()
            .map(|b| b.makespan_cycles as f64)
            .unwrap_or(0.0);
        let per_row = spec.runs_per_row();
        for (r, label) in spec.row_labels.iter().enumerate() {
            let cells = &self.results[r * per_row..(r + 1) * per_row];
            let row = match spec.metric {
                Metric::Seconds => cells.iter().map(|s| s.seconds()).collect(),
                Metric::SpeedupVsBaseline => cells
                    .iter()
                    .map(|s| base / s.makespan_cycles as f64)
                    .collect(),
                Metric::SecondsAndSpeedup => {
                    let s = &cells[0];
                    vec![s.seconds(), base / s.makespan_cycles as f64]
                }
            };
            t.push_row(label.clone(), row);
        }
        t
    }

    /// Full machine-readable record: every spec + stats pair, the baseline,
    /// and the rendered table. Byte-identical across worker counts.
    pub fn to_json(&self, spec: &SweepSpec) -> Json {
        let runs = spec
            .runs
            .iter()
            .zip(&self.results)
            .map(|(r, s)| Json::obj(vec![("spec", r.to_json()), ("stats", s.to_json())]))
            .collect::<Vec<_>>();
        let baseline = match (&spec.baseline, &self.baseline) {
            (Some(r), Some(s)) => Json::obj(vec![("spec", r.to_json()), ("stats", s.to_json())]),
            _ => Json::Null,
        };
        Json::obj(vec![
            ("title", Json::str(spec.title.clone())),
            ("metric", Json::str(spec.metric.label())),
            ("baseline", baseline),
            ("runs", Json::arr(runs)),
            ("table", self.table(spec).to_json()),
        ])
    }
}

/// The scoped-thread worker pool that shards runs across host cores.
pub struct BatchRunner {
    jobs: usize,
    intra_jobs: usize,
}

impl BatchRunner {
    /// `jobs = 0` means one worker per available host core.
    pub fn new(jobs: usize) -> BatchRunner {
        let jobs = if jobs == 0 {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        } else {
            jobs
        };
        BatchRunner {
            jobs,
            intra_jobs: 1,
        }
    }

    /// Honour `TILESIM_JOBS` / `TILESIM_INTRA_JOBS` if set, else use every
    /// host core for the outer pool and sequential replay inside each run.
    /// This is the default path for the experiment drivers and bench
    /// binaries.
    pub fn auto() -> BatchRunner {
        let jobs = std::env::var("TILESIM_JOBS")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(0);
        let intra = std::env::var("TILESIM_INTRA_JOBS")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(1);
        BatchRunner::new(jobs).with_intra_jobs(intra)
    }

    /// `--intra-jobs`: host workers *inside* each run (the epoch driver).
    /// The thread budget is `jobs × intra_jobs`; the inner count is
    /// clamped down so the product never oversubscribes the host — the
    /// outer pool wins because independent runs scale perfectly while
    /// intra-run replay only covers the fenced-off fraction of a window.
    pub fn with_intra_jobs(mut self, intra_jobs: usize) -> BatchRunner {
        let avail = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        self.intra_jobs = intra_jobs.max(1).min((avail / self.jobs).max(1));
        self
    }

    pub fn jobs(&self) -> usize {
        self.jobs
    }

    /// Effective per-run worker count after the `jobs × intra_jobs` clamp.
    pub fn intra_jobs(&self) -> usize {
        self.intra_jobs
    }

    /// Execute every run of `spec` (baseline included) across the pool.
    pub fn run(&self, spec: &SweepSpec) -> ResultStore {
        spec.validate();
        let mut all: Vec<&RunSpec> = spec.runs.iter().collect();
        if let Some(b) = &spec.baseline {
            all.push(b);
        }
        let mut stats = execute_all(&all, self.jobs, self.intra_jobs);
        let baseline = spec.baseline.as_ref().map(|_| stats.pop().expect("baseline"));
        ResultStore {
            results: stats,
            baseline,
        }
    }

    /// Shorthand: run the sweep and render its table.
    pub fn table(&self, spec: &SweepSpec) -> SweepTable {
        self.run(spec).table(spec)
    }
}

impl Default for BatchRunner {
    fn default() -> Self {
        BatchRunner::auto()
    }
}

/// Shard `runs` over `jobs` workers; results are index-aligned with the
/// input regardless of which worker ran what.
fn execute_all(runs: &[&RunSpec], jobs: usize, intra_jobs: usize) -> Vec<RunStats> {
    execute_indexed(runs, jobs, |_, r| r.execute_intra(intra_jobs))
}

/// The pool's generic core: shard any indexed workload over `jobs`
/// scoped-thread workers (work-stealing over an atomic cursor) with
/// results *index-aligned* to the input, independent of which worker ran
/// what and in what order. [`BatchRunner::run`] shards `RunSpec`s through
/// this; the serve front-end ([`crate::serve`]) shards whole scenario
/// simulations — both inherit the byte-identical-at-any-`--jobs` contract
/// from the index alignment alone.
pub fn execute_indexed<T, R, F>(items: &[T], jobs: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    let jobs = jobs.max(1).min(items.len().max(1));
    if jobs == 1 {
        return items.iter().enumerate().map(|(i, t)| f(i, t)).collect();
    }
    let next = AtomicUsize::new(0);
    let per_worker: Vec<Vec<(usize, R)>> = std::thread::scope(|s| {
        let workers: Vec<_> = (0..jobs)
            .map(|_| {
                s.spawn(|| {
                    let mut local = Vec::new();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= items.len() {
                            break;
                        }
                        local.push((i, f(i, &items[i])));
                    }
                    local
                })
            })
            .collect();
        workers
            .into_iter()
            .map(|w| w.join().expect("batch worker panicked"))
            .collect()
    });
    let mut out: Vec<Option<R>> = items.iter().map(|_| None).collect();
    for (i, r) in per_worker.into_iter().flatten() {
        out[i] = Some(r);
    }
    out.into_iter()
        .map(|o| o.expect("worker pool dropped a run"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_spec() -> SweepSpec {
        SweepSpec::grid(
            "tiny",
            &[1, 8],
            &[Workload::Mergesort {
                variant: mergesort::Variant::NonLocalised,
            }],
            &[1 << 12],
            &[2, 4],
            &[7],
        )
    }

    #[test]
    fn grid_expands_full_cross_product() {
        let spec = tiny_spec();
        assert_eq!(spec.series.len(), 2);
        assert_eq!(spec.row_labels.len(), 2);
        assert_eq!(spec.runs.len(), 4);
        spec.validate();
    }

    #[test]
    fn spec_execution_is_deterministic() {
        let spec = RunSpec::mergesort(8, 1 << 12, 4, 42);
        let a = spec.execute();
        let b = spec.execute();
        assert_eq!(a.makespan_cycles, b.makespan_cycles);
        assert_eq!(a.thread_cycles, b.thread_cycles);
    }

    #[test]
    fn pool_results_are_index_aligned() {
        let spec = tiny_spec();
        let serial = BatchRunner::new(1).run(&spec);
        let parallel = BatchRunner::new(4).run(&spec);
        for (a, b) in serial.results.iter().zip(&parallel.results) {
            assert_eq!(a.makespan_cycles, b.makespan_cycles);
            assert_eq!(a.line_accesses, b.line_accesses);
        }
    }

    #[test]
    fn intra_jobs_clamped_to_host_budget() {
        let avail = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        // jobs = every core: no headroom left for intra-run workers.
        let r = BatchRunner::new(avail).with_intra_jobs(8);
        assert_eq!(r.intra_jobs(), 1);
        // jobs = 1: the whole budget is available inside the run.
        let r = BatchRunner::new(1).with_intra_jobs(avail);
        assert_eq!(r.intra_jobs(), avail);
        // Requests are floored at 1 either way.
        assert_eq!(BatchRunner::new(1).with_intra_jobs(0).intra_jobs(), 1);
    }

    #[test]
    fn intra_run_replay_matches_sequential() {
        // The core determinism contract at the spec level: the same run
        // replayed with intra-run workers produces byte-identical stats
        // (prop_intra_run sweeps this across workloads and protocols).
        let spec = RunSpec::mergesort(8, 1 << 14, 8, 42);
        let seq = spec.execute_intra(1).to_json().encode();
        let par = spec.execute_intra(4).to_json().encode();
        assert_eq!(seq, par);
    }

    #[test]
    fn execute_indexed_is_index_aligned_at_any_job_count() {
        // The generic pool core: results line up with the input no matter
        // how many workers raced over the cursor (serve leans on this).
        let items: Vec<u64> = (0..37).collect();
        let serial = execute_indexed(&items, 1, |i, &x| (i as u64) * 1000 + x);
        for jobs in [2usize, 4, 16] {
            let parallel = execute_indexed(&items, jobs, |i, &x| (i as u64) * 1000 + x);
            assert_eq!(serial, parallel, "jobs={jobs}");
        }
        let empty: Vec<u64> = Vec::new();
        assert!(execute_indexed(&empty, 4, |_, &x| x).is_empty());
    }

    #[test]
    fn derive_seeds_is_stable_and_distinct() {
        let a = derive_seeds(2014, 8);
        let b = derive_seeds(2014, 8);
        assert_eq!(a, b);
        let mut uniq = a.clone();
        uniq.sort_unstable();
        uniq.dedup();
        assert_eq!(uniq.len(), 8, "derived seeds must be distinct");
        assert_ne!(derive_seeds(2015, 8), a);
    }

    #[test]
    fn table_renders_grid_shape() {
        let spec = tiny_spec();
        let t = BatchRunner::new(2).table(&spec);
        assert_eq!(t.rows.len(), 2);
        assert_eq!(t.series.len(), 2);
        assert!(t.rows.iter().all(|(_, v)| v.iter().all(|&x| x > 0.0)));
    }

    #[test]
    #[should_panic(expected = "sweep grid shape mismatch")]
    fn malformed_grid_is_rejected() {
        let mut spec = tiny_spec();
        spec.runs.pop();
        BatchRunner::new(1).run(&spec);
    }

    #[test]
    fn baseline_spec_json_has_no_machine_fields() {
        // The pinned figure record must keep its pre-machine-layer bytes.
        let spec = RunSpec::mergesort(8, 1 << 12, 4, 42);
        let j = spec.to_json();
        assert!(j.get("machine").is_none());
        assert!(j.get("link_contention").is_none());
        let mut on = spec.clone();
        on.machine = MachineSpec::Epiphany16;
        on.link_contention = true;
        let j = on.to_json();
        assert_eq!(j.get("machine").unwrap().encode(), "\"epiphany16\"");
        assert!(on.label().contains("on epiphany16"));
    }

    #[test]
    fn machine_changes_the_simulation() {
        let base = RunSpec::mergesort(8, 1 << 12, 4, 42);
        let mut eph = base.clone();
        eph.machine = MachineSpec::Epiphany16;
        let mut big = base.clone();
        big.machine = MachineSpec::Nuca256;
        let (a, b, c) = (base.execute(), eph.execute(), big.execute());
        assert_ne!(
            a.makespan_cycles, b.makespan_cycles,
            "epiphany16 must simulate differently from tilepro64"
        );
        assert_ne!(a.makespan_cycles, c.makespan_cycles);
        assert_eq!(b.tile_home_requests.len(), 16);
        assert_eq!(c.tile_home_requests.len(), 256);
    }

    #[test]
    fn on_machine_retargets_baseline_too() {
        let spec = crate::coordinator::experiment::table1_spec(1 << 12, 4, 7)
            .on_machine(MachineSpec::Nuca256, true, true);
        assert!(spec.runs.iter().all(|r| r.machine == MachineSpec::Nuca256));
        let b = spec.baseline.as_ref().expect("table1 has a baseline");
        assert_eq!(b.machine, MachineSpec::Nuca256);
        assert!(b.link_contention && b.coherence_links);
        assert!(spec.title.contains("[machine nuca256]"));
    }

    #[test]
    fn coherence_flag_emitted_only_when_it_deviates() {
        let mut spec = RunSpec::mergesort(8, 1 << 12, 4, 42);
        spec.machine = MachineSpec::Nuca256;
        spec.link_contention = true;
        spec.coherence_links = true;
        assert!(spec.to_json().get("coherence_links").is_none());
        assert!(!spec.label().contains("nocoh"));
        spec.coherence_links = false;
        assert_eq!(
            spec.to_json().get("coherence_links").unwrap().encode(),
            "false"
        );
        assert!(spec.label().contains("nocoh"));
    }

    #[test]
    fn fabric_json_and_label_gated_like_machine_fields() {
        let mut spec = RunSpec::mergesort(8, 1 << 12, 4, 42);
        assert!(spec.to_json().get("fabric").is_none());
        spec.fabric = Some(FabricSpec::parse("ctrl=corners").unwrap());
        // A fabric alone makes the run non-baseline, even on tilepro64.
        let j = spec.to_json();
        assert_eq!(j.get("machine").unwrap().encode(), "\"tilepro64\"");
        assert_eq!(j.get("fabric").unwrap().encode(), "\"ctrl=corners\"");
        assert!(spec.label().contains("fab[ctrl=corners]"));
        assert!(spec.check_thread_capacity().is_ok());
        // An incompatible fabric is caught at CLI-validation time.
        spec.fabric = Some(FabricSpec::parse("express-row=9@0.5").unwrap());
        assert!(
            spec.check_thread_capacity().is_err(),
            "row 9 does not fit an 8x8 grid"
        );
    }

    #[test]
    fn placement_fabric_changes_the_simulation() {
        // Corner controllers move every DRAM route, so the same sort must
        // replay to a different makespan than the edge-placed baseline.
        let mut base = RunSpec::mergesort(3, 1 << 13, 8, 42);
        base.link_contention = true;
        base.coherence_links = true;
        let mut corners = base.clone();
        corners.fabric = Some(FabricSpec::parse("ctrl=corners").unwrap());
        let (a, b) = (base.execute(), corners.execute());
        assert_ne!(
            a.makespan_cycles, b.makespan_cycles,
            "controller placement must change the simulation"
        );
        assert_eq!(a.ddr_accesses, b.ddr_accesses, "same traffic, different routes");
    }

    #[test]
    fn with_fabric_retargets_all_runs_and_baseline() {
        let f = FabricSpec::parse("base=4:express-row=0@0.5").unwrap();
        let spec = crate::coordinator::experiment::table1_spec(1 << 12, 4, 7)
            .on_machine(MachineSpec::Nuca256, true, true)
            .with_fabric(Some(f.clone()));
        assert!(spec.runs.iter().all(|r| r.fabric.as_ref() == Some(&f)));
        assert_eq!(spec.baseline.as_ref().unwrap().fabric.as_ref(), Some(&f));
        assert!(spec.title.contains("[fabric base=4:express-row=0@0.5]"));
        assert!(spec.check_thread_capacity().is_ok());
    }

    #[test]
    fn protocol_json_and_label_gated_on_non_default() {
        let spec = RunSpec::mergesort(8, 1 << 12, 4, 42);
        assert!(spec.to_json().get("protocol").is_none());
        assert!(!spec.label().contains("proto="));
        let spec = spec.with_protocol(ProtocolSpec::parse("mesi").unwrap());
        assert_eq!(spec.to_json().get("protocol").unwrap().encode(), "\"mesi\"");
        assert!(spec.label().contains("proto=mesi"));
        // Spelling the default out loud is still the default.
        let spec = spec.with_protocol(ProtocolSpec::parse("write-invalidate").unwrap());
        assert!(spec.to_json().get("protocol").is_none());
    }

    #[test]
    fn protocol_changes_the_simulation_only_with_coherence_links() {
        // Non-localised microbench re-writes its output slice every rep —
        // sole-sharer rewrites that MESI absorbs silently.
        let base = RunSpec::new(1, Workload::Microbench { reps: 3 }, 1 << 12, 4, 42)
            .on_machine(MachineSpec::Nuca256, true, true);
        let mesi = base.clone().with_protocol(ProtocolSpec::parse("mesi").unwrap());
        let (a, b) = (base.execute(), mesi.execute());
        assert_eq!(a.upgrade_hits, 0);
        assert!(b.upgrade_hits > 0, "rewrites must silently upgrade");
        assert_ne!(a.makespan_cycles, b.makespan_cycles);
        // Links off: the protocol is inert and the runs replay identically.
        let off = RunSpec::new(1, Workload::Microbench { reps: 3 }, 1 << 12, 4, 42);
        let off_mesi = off.clone().with_protocol(ProtocolSpec::parse("mesi").unwrap());
        assert_eq!(
            off.execute().to_json().encode(),
            off_mesi.execute().to_json().encode()
        );
    }

    #[test]
    fn sweep_with_protocol_retargets_runs_and_title() {
        let p = ProtocolSpec::parse("moesi").unwrap();
        let spec = tiny_spec().with_protocol(p);
        assert!(spec.runs.iter().all(|r| r.protocol == p));
        assert!(spec.title.contains("[protocol moesi]"));
        // The default protocol leaves titles (and pinned records) alone.
        let untouched = tiny_spec().with_protocol(ProtocolSpec::default());
        assert!(!untouched.title.contains("protocol"));
    }

    #[test]
    fn coherence_billing_changes_the_simulation() {
        // Ping-pong on a linked machine: turning coherence billing off
        // must not leave the makespan unchanged (the fan-out routes are
        // load-bearing), and must zero the coherence stats.
        let mut on = RunSpec::mergesort(4, 1 << 12, 8, 42);
        on.workload = Workload::PingPong { passes: 4 };
        on.machine = MachineSpec::Nuca256;
        on.link_contention = true;
        on.coherence_links = true;
        let mut off = on.clone();
        off.coherence_links = false;
        let (a, b) = (on.execute(), off.execute());
        assert!(a.invalidation_link_cycles > 0);
        assert_eq!(b.invalidation_link_cycles, 0);
        assert!(
            a.makespan_cycles > b.makespan_cycles,
            "coherence billing must cost cycles: {} vs {}",
            a.makespan_cycles,
            b.makespan_cycles
        );
    }
}
