//! Experiment drivers: one function per paper artefact (Fig. 1–4, Table 1).
//!
//! Each driver is now a thin *sweep-spec builder*: `figN_spec` expands the
//! paper's parameter grid into an explicit [`SweepSpec`] and `figN` executes
//! it through the [`batch`] worker pool (parallel across host cores,
//! deterministic regardless of `--jobs`), returning the same `SweepTable`
//! the sequential drivers used to produce. The bench binaries and the
//! `repro` CLI are thin wrappers around these.
//!
//! [`batch`]: crate::coordinator::batch

use crate::arch::{CtrlPlacement, FabricSpec, MachineSpec};
use crate::coherence::ProtocolSpec;
use crate::coordinator::batch::{BatchRunner, Metric, RunSpec, SweepSpec, Workload};
use crate::coordinator::cases::{table1, CaseSpec};
use crate::harness::SweepTable;
use crate::mem::HashPolicy;
use crate::sim::{Engine, RunStats};
use crate::workloads::mergesort;

/// Default seed for Tile Linux scheduling in experiments.
pub const DEFAULT_SEED: u64 = 2014;

/// Run merge sort for one configuration.
pub fn run_mergesort(
    case: &CaseSpec,
    elems: u64,
    threads: usize,
    striping: bool,
    seed: u64,
) -> RunStats {
    run_mergesort_variant(case, case.mergesort_variant(), elems, threads, striping, seed)
}

/// Merge sort with an explicit variant (Fig. 3's intermediate-step series).
pub fn run_mergesort_variant(
    case: &CaseSpec,
    variant: mergesort::Variant,
    elems: u64,
    threads: usize,
    striping: bool,
    seed: u64,
) -> RunStats {
    let mut engine = Engine::new(case.engine_config(striping));
    let mut program = mergesort::build(
        &mut engine,
        &mergesort::MergesortConfig {
            elems,
            threads,
            variant,
        },
    );
    let mut sched = case.mapper.scheduler(seed);
    engine
        .run(&mut program, sched.as_mut())
        .expect("mergesort run failed")
}

// ---------------------------------------------------------------------------
// Fig. 1 — micro-benchmark execution time vs repetitions
// ---------------------------------------------------------------------------

/// Paper setup: 1 M integers, 63 threads; localised (case 8: static map,
/// hash disabled) vs non-localised (case 1: Tile Linux default mapping,
/// hash-for-home), expressed as an explicit sweep grid.
pub fn fig1_spec(elems: u64, threads: usize, reps_sweep: &[u32], seed: u64) -> SweepSpec {
    let mb = |case_id: u8, reps: u32| {
        RunSpec::new(case_id, Workload::Microbench { reps }, elems, threads, seed)
    };
    let mut runs = Vec::new();
    let mut row_labels = Vec::new();
    for &reps in reps_sweep {
        row_labels.push(reps.to_string());
        runs.push(mb(1, reps));
        runs.push(mb(8, reps));
    }
    SweepSpec {
        title: format!("Fig.1 micro-benchmark, {elems} ints, {threads} threads (exec time, s)"),
        x_label: "repetitions".into(),
        series: vec!["non-localised".into(), "localised".into()],
        row_labels,
        runs,
        baseline: None,
        metric: Metric::Seconds,
    }
}

pub fn fig1(elems: u64, threads: usize, reps_sweep: &[u32], seed: u64) -> SweepTable {
    BatchRunner::auto().table(&fig1_spec(elems, threads, reps_sweep, seed))
}

// ---------------------------------------------------------------------------
// Fig. 2 / Table 1 — merge-sort speed-up, all 8 cases × thread counts
// ---------------------------------------------------------------------------

/// Speed-up for every Table 1 case over the thread sweep. The base (1.0)
/// is Case 1 at a single thread, exactly as in §5.1: "execution time with
/// a single thread under the default hashing scheme and the default Linux
/// scheduling policy".
pub fn fig2_spec(elems: u64, thread_sweep: &[usize], seed: u64) -> SweepSpec {
    let cases = table1();
    let mut runs = Vec::new();
    let mut row_labels = Vec::new();
    for &threads in thread_sweep {
        row_labels.push(threads.to_string());
        for c in &cases {
            runs.push(RunSpec::mergesort(c.id, elems, threads, seed));
        }
    }
    SweepSpec {
        title: format!("Fig.2 merge sort speed-up, {elems} ints (base: case 1 @ 1 thread)"),
        x_label: "threads".into(),
        series: cases.iter().map(|c| c.short()).collect(),
        row_labels,
        runs,
        baseline: Some(RunSpec::mergesort(1, elems, 1, seed)),
        metric: Metric::SpeedupVsBaseline,
    }
}

pub fn fig2(elems: u64, thread_sweep: &[usize], seed: u64) -> SweepTable {
    BatchRunner::auto().table(&fig2_spec(elems, thread_sweep, seed))
}

/// Table 1 rendered as execution times at a fixed thread count.
pub fn table1_spec(elems: u64, threads: usize, seed: u64) -> SweepSpec {
    let cases = table1();
    SweepSpec {
        title: format!(
            "Table 1 cases: merge sort of {elems} ints, {threads} threads (exec time, s)"
        ),
        x_label: "case".into(),
        series: vec!["seconds".into(), "speedup_vs_case1".into()],
        row_labels: cases.iter().map(|c| c.short()).collect(),
        runs: cases
            .iter()
            .map(|c| RunSpec::mergesort(c.id, elems, threads, seed))
            .collect(),
        baseline: Some(RunSpec::mergesort(1, elems, threads, seed)),
        metric: Metric::SecondsAndSpeedup,
    }
}

pub fn table1_times(elems: u64, threads: usize, seed: u64) -> SweepTable {
    BatchRunner::auto().table(&table1_spec(elems, threads, seed))
}

// ---------------------------------------------------------------------------
// Fig. 3 — best cases across input sizes (+ intermediate step)
// ---------------------------------------------------------------------------

/// §5.2: cases 3, 4, 7, 8 plus "case 3 + intermediate step", 64 threads,
/// sweeping the input size. Execution time in seconds.
pub fn fig3_spec(sizes: &[u64], threads: usize, seed: u64) -> SweepSpec {
    let mut runs = Vec::new();
    let mut row_labels = Vec::new();
    for &elems in sizes {
        row_labels.push(elems.to_string());
        runs.push(RunSpec::mergesort(3, elems, threads, seed));
        runs.push(RunSpec::new(
            3,
            Workload::Mergesort {
                variant: mergesort::Variant::NonLocalisedIntermediate,
            },
            elems,
            threads,
            seed,
        ));
        runs.push(RunSpec::mergesort(4, elems, threads, seed));
        runs.push(RunSpec::mergesort(7, elems, threads, seed));
        runs.push(RunSpec::mergesort(8, elems, threads, seed));
    }
    SweepSpec {
        title: format!("Fig.3 exec time vs input size, {threads} threads (s)"),
        x_label: "elems".into(),
        series: vec![
            "case3".into(),
            "case3+interm".into(),
            "case4".into(),
            "case7".into(),
            "case8".into(),
        ],
        row_labels,
        runs,
        baseline: None,
        metric: Metric::Seconds,
    }
}

pub fn fig3(sizes: &[u64], threads: usize, seed: u64) -> SweepTable {
    BatchRunner::auto().table(&fig3_spec(sizes, threads, seed))
}

// ---------------------------------------------------------------------------
// Fig. 4 — memory striping, static mapping
// ---------------------------------------------------------------------------

/// §5.3: execution time with striping on/off over the thread sweep, static
/// mapping, for the non-localised (hash) and localised (none) styles.
pub fn fig4_spec(elems: u64, thread_sweep: &[usize], seed: u64) -> SweepSpec {
    let with_striping = |case_id: u8, threads: usize, striping: bool| {
        RunSpec::mergesort(case_id, elems, threads, seed).with_striping(striping)
    };
    let mut runs = Vec::new();
    let mut row_labels = Vec::new();
    for &threads in thread_sweep {
        row_labels.push(threads.to_string());
        runs.push(with_striping(3, threads, true));
        runs.push(with_striping(3, threads, false));
        runs.push(with_striping(8, threads, true));
        runs.push(with_striping(8, threads, false));
    }
    SweepSpec {
        title: format!("Fig.4 striping influence, static mapping, {elems} ints (exec time, s)"),
        x_label: "threads".into(),
        series: vec![
            "case3 striped".into(),
            "case3 non-striped".into(),
            "case8 striped".into(),
            "case8 non-striped".into(),
        ],
        row_labels,
        runs,
        baseline: None,
        metric: Metric::Seconds,
    }
}

pub fn fig4(elems: u64, thread_sweep: &[usize], seed: u64) -> SweepTable {
    BatchRunner::auto().table(&fig4_spec(elems, thread_sweep, seed))
}

/// Fig. 4's closing observation: "the effect of memory striping is
/// considerable when caching is turned off across the system". Same sweep
/// as fig4 but with the caches disabled — every access is a DRAM
/// transaction, so controller reach/contention dominates.
pub fn fig4_cache_off_spec(elems: u64, thread_sweep: &[usize], seed: u64) -> SweepSpec {
    let cache_off = |threads: usize, striping: bool| {
        RunSpec::mergesort(3, elems, threads, seed)
            .with_striping(striping)
            .without_caches()
    };
    let mut runs = Vec::new();
    let mut row_labels = Vec::new();
    for &threads in thread_sweep {
        row_labels.push(threads.to_string());
        runs.push(cache_off(threads, true));
        runs.push(cache_off(threads, false));
    }
    SweepSpec {
        title: format!("Fig.4 ablation: caches OFF, static mapping, {elems} ints (exec time, s)"),
        x_label: "threads".into(),
        series: vec!["striped".into(), "non-striped".into()],
        row_labels,
        runs,
        baseline: None,
        metric: Metric::Seconds,
    }
}

pub fn fig4_cache_off(elems: u64, thread_sweep: &[usize], seed: u64) -> SweepTable {
    BatchRunner::auto().table(&fig4_cache_off_spec(elems, thread_sweep, seed))
}

// ---------------------------------------------------------------------------
// Grid scaling — same workload on growing NUCA grids (machine layer)
// ---------------------------------------------------------------------------

/// Default machine ladder for the grid-scaling sweep: 4×4 → 8×8 → 16×16.
pub fn grid_scaling_machines() -> Vec<MachineSpec> {
    vec![
        MachineSpec::Custom { w: 4, h: 4, ctrls: 2 },
        MachineSpec::TilePro64,
        MachineSpec::Nuca256,
    ]
}

/// Fig.5-style sweep enabled by the machine-description layer: the same
/// merge sort at every grid size, with the full contention model including
/// per-link mesh queueing. One row per machine; series are case 3
/// (non-localised, hash-for-home — traffic spread but all remote), case 4
/// (non-localised, single-home — the hot-region disaster), and case 8
/// (localised — traffic stays on-tile). On the 16×16 grid the
/// non-localised cases queue on mesh links (`link_queue_cycles` in the
/// JSON record) while the localised case stays near zero.
pub fn grid_scaling_spec(
    elems: u64,
    threads: usize,
    machines: &[MachineSpec],
    seed: u64,
    link_contention: bool,
    coherence_links: bool,
) -> SweepSpec {
    let mut runs = Vec::new();
    let mut row_labels = Vec::new();
    for &m in machines {
        row_labels.push(m.label());
        for case_id in [3u8, 4, 8] {
            runs.push(RunSpec::mergesort(case_id, elems, threads, seed).on_machine(
                m,
                link_contention,
                link_contention && coherence_links,
            ));
        }
    }
    SweepSpec {
        title: format!(
            "Grid scaling: merge sort of {elems} ints, {threads} threads across NUCA grids \
             (exec time, s{})",
            if link_contention { ", link contention on" } else { ", links off" }
        ),
        x_label: "machine".into(),
        series: vec![
            "case3 hash".into(),
            "case4 one-home".into(),
            "case8 localised".into(),
        ],
        row_labels,
        runs,
        baseline: None,
        metric: Metric::Seconds,
    }
}

pub fn grid_scaling(
    elems: u64,
    threads: usize,
    machines: &[MachineSpec],
    seed: u64,
    link_contention: bool,
) -> SweepTable {
    BatchRunner::auto().table(&grid_scaling_spec(
        elems,
        threads,
        machines,
        seed,
        link_contention,
        link_contention,
    ))
}

// ---------------------------------------------------------------------------
// False sharing — write ping-pong across grid sizes (coherence traffic)
// ---------------------------------------------------------------------------

/// Default machine ladder for the falseshare sweep: the paper's 8×8
/// against the forward-looking 16×16, where the coherence traffic should
/// visibly saturate the mesh.
pub fn falseshare_machines() -> Vec<MachineSpec> {
    vec![MachineSpec::TilePro64, MachineSpec::Nuca256]
}

/// The coherence-traffic sweep enabled by invalidation/reply link billing:
/// the write ping-pong workload ([`crate::workloads::pingpong`]) at each
/// grid size, non-localised (case 4: static mapping, no hash — every
/// falsely-shared line homed on tile 0, invalidations ping-ponging across
/// the mesh) against localised (case 8: privatised writes). Link and
/// coherence billing are always on — measuring that traffic is the point —
/// so even the 8×8 row is a non-baseline machine config.
///
/// The headline number is not the seconds table but the per-row
/// [`falseshare_report`] ratio of `link_queue_cycles +
/// invalidation_link_cycles` between the two variants.
pub fn falseshare_spec(
    elems: u64,
    threads: usize,
    passes: u32,
    machines: &[MachineSpec],
    seed: u64,
) -> SweepSpec {
    let mut runs = Vec::new();
    let mut row_labels = Vec::new();
    for &m in machines {
        row_labels.push(m.label());
        for case_id in [4u8, 8] {
            runs.push(
                RunSpec::new(case_id, Workload::PingPong { passes }, elems, threads, seed)
                    .on_machine(m, true, true),
            );
        }
    }
    SweepSpec {
        title: format!(
            "False sharing: write ping-pong of {elems} ints, {threads} threads x {passes} \
             passes, coherence links billed (exec time, s)"
        ),
        x_label: "machine".into(),
        series: vec!["case4 falseshare".into(), "case8 localised".into()],
        row_labels,
        runs,
        baseline: None,
        metric: Metric::Seconds,
    }
}

pub fn falseshare(
    elems: u64,
    threads: usize,
    passes: u32,
    machines: &[MachineSpec],
    seed: u64,
) -> SweepTable {
    BatchRunner::auto().table(&falseshare_spec(elems, threads, passes, machines, seed))
}

/// Per-machine coherence-traffic ratios for a falseshare result store:
/// `(link_queue_cycles + invalidation_link_cycles)` of the non-localised
/// variant over the localised one — the "how much mesh does false sharing
/// burn" number the sweep exists to report.
pub fn falseshare_report(
    spec: &SweepSpec,
    store: &crate::coordinator::batch::ResultStore,
) -> String {
    let mut out = String::from(
        "coherence traffic on the mesh (link_queue_cycles + invalidation_link_cycles):\n",
    );
    for (row, label) in spec.row_labels.iter().enumerate() {
        let shared = store.results[row * 2].coherence_link_cycles();
        let local = store.results[row * 2 + 1].coherence_link_cycles();
        let ratio = if local == 0 {
            f64::INFINITY
        } else {
            shared as f64 / local as f64
        };
        out.push_str(&format!(
            "  {label:>10}: non-localised {shared} vs localised {local} (ratio {ratio:.1})\n"
        ));
    }
    out
}

// ---------------------------------------------------------------------------
// Controller placement — the Fig. 4-style crossover per placement strategy
// ---------------------------------------------------------------------------

/// Default placement ladder for the `placement` sweep.
pub fn placement_ladder() -> Vec<CtrlPlacement> {
    vec![
        CtrlPlacement::EdgesEven,
        CtrlPlacement::Sides,
        CtrlPlacement::Corners,
        CtrlPlacement::Interior,
    ]
}

/// Default machines for the placement sweep: the paper's 8×8 and a 16×16
/// with 4 controllers (4 ≤ every named placement's capacity, corners
/// included, so the strategies stay comparable).
pub fn placement_machines() -> Vec<MachineSpec> {
    vec![
        MachineSpec::TilePro64,
        MachineSpec::Custom { w: 16, h: 16, ctrls: 4 },
    ]
}

/// The controller-placement ablation the ROADMAP names: Fig. 4's striping
/// × programming-style grid (case 3 hash / case 8 localised, striped vs
/// non-striped) re-run per placement strategy per machine, link/coherence
/// billing per the CLI (on unless `--no-link-contention`). One row per
/// machine × placement; where the striped/non-striped crossover sits per
/// placement is what [`placement_report`] extracts.
pub fn placement_spec(
    elems: u64,
    threads: usize,
    machines: &[MachineSpec],
    placements: &[CtrlPlacement],
    seed: u64,
    link_contention: bool,
    coherence_links: bool,
) -> SweepSpec {
    let mut runs = Vec::new();
    let mut row_labels = Vec::new();
    for &m in machines {
        for p in placements {
            row_labels.push(format!("{}/{}", m.label(), p.label()));
            for (case_id, striping) in [(3u8, true), (3, false), (8, true), (8, false)] {
                runs.push(
                    RunSpec::mergesort(case_id, elems, threads, seed)
                        .with_striping(striping)
                        .on_machine(m, link_contention, link_contention && coherence_links)
                        .with_fabric(Some(FabricSpec {
                            ctrl: Some(p.clone()),
                            ..FabricSpec::default()
                        })),
                );
            }
        }
    }
    SweepSpec {
        title: format!(
            "Controller placement: merge sort of {elems} ints, {threads} threads, \
             Fig.4 striping grid per placement (exec time, s)"
        ),
        x_label: "machine/placement".into(),
        series: vec![
            "case3 striped".into(),
            "case3 non-striped".into(),
            "case8 striped".into(),
            "case8 non-striped".into(),
        ],
        row_labels,
        runs,
        baseline: None,
        metric: Metric::Seconds,
    }
}

/// The Fig. 4-style crossover table for a placement sweep: per row, the
/// non-striped/striped makespan ratio of the non-localised (case 3) and
/// localised (case 8) styles. A ratio above 1 means striping wins; where
/// it crosses 1 between the two styles is the paper's crossover, now
/// measurable per controller placement.
pub fn placement_report(
    spec: &SweepSpec,
    store: &crate::coordinator::batch::ResultStore,
) -> String {
    let mut out =
        String::from("Fig.4-style striping crossover (non-striped / striped makespan):\n");
    for (row, label) in spec.row_labels.iter().enumerate() {
        let cells = &store.results[row * 4..row * 4 + 4];
        let ratio = |ns: &RunStats, s: &RunStats| {
            ns.makespan_cycles as f64 / s.makespan_cycles as f64
        };
        out.push_str(&format!(
            "  {label:>24}: case3 {:.3}, case8 {:.3}\n",
            ratio(&cells[1], &cells[0]),
            ratio(&cells[3], &cells[2]),
        ));
    }
    out
}

// ---------------------------------------------------------------------------
// Fabric — express-channel strength sweep on the write ping-pong
// ---------------------------------------------------------------------------

/// Default express-channel strengths for the `fabric` sweep: 1 (uniform),
/// then progressively wider express channels. Strings because they embed
/// in the `FabricSpec` factor syntax verbatim.
pub fn fabric_strengths() -> Vec<String> {
    vec!["1".into(), "0.5".into(), "0.25".into()]
}

/// Default machines for the fabric sweep (two grid sizes).
pub fn fabric_machines() -> Vec<MachineSpec> {
    vec![MachineSpec::TilePro64, MachineSpec::Nuca256]
}

/// The express-channel fabric at one strength: a base service of 4 cycles
/// per link so fractional strengths quantise (4 → 2 → 1), with row 0 and
/// column 0 as the express channels — the edge row/column every XY route
/// into the corner-homed hot spot funnels through, so widening them
/// directly relieves the ping-pong's coherence traffic.
pub fn express_fabric(strength: &str) -> Result<FabricSpec, crate::arch::FabricError> {
    // The strength is spliced into the spec string, so insist it is a
    // bare decimal factor — `0.5:dir=E@8` must not inject extra clauses.
    crate::arch::fabric::Factor::parse(strength)?;
    FabricSpec::parse(&format!(
        "base=4:express-row=0@{strength}:express-col=0@{strength}"
    ))
}

/// The express-channel sweep: the write ping-pong at every machine ×
/// strength, non-localised (case 4) against localised (case 8), link and
/// coherence billing per the CLI (on unless ablated — with links off the
/// fabric is inert and the sweep measures nothing). Widening the express
/// channels must strictly reduce the non-localised variant's
/// `link_queue_cycles` (pinned by the CI smoke and
/// `rust/tests/prop_fabric.rs`).
#[allow(clippy::too_many_arguments)]
pub fn fabric_sweep_spec(
    elems: u64,
    threads: usize,
    passes: u32,
    machines: &[MachineSpec],
    strengths: &[String],
    seed: u64,
    link_contention: bool,
    coherence_links: bool,
) -> Result<SweepSpec, crate::arch::FabricError> {
    let mut runs = Vec::new();
    let mut row_labels = Vec::new();
    for &m in machines {
        for s in strengths {
            let fabric = express_fabric(s)?;
            row_labels.push(format!("{}@x{s}", m.label()));
            for case_id in [4u8, 8] {
                runs.push(
                    RunSpec::new(case_id, Workload::PingPong { passes }, elems, threads, seed)
                        .on_machine(m, link_contention, link_contention && coherence_links)
                        .with_fabric(Some(fabric.clone())),
                );
            }
        }
    }
    Ok(SweepSpec {
        title: format!(
            "Express-channel fabric: write ping-pong of {elems} ints, {threads} threads x \
             {passes} passes, row-0/col-0 channels at each strength (exec time, s)"
        ),
        x_label: "machine@strength".into(),
        series: vec!["case4 pingpong".into(), "case8 localised".into()],
        row_labels,
        runs,
        baseline: None,
        metric: Metric::Seconds,
    })
}

/// Per-machine link-queueing trajectory of a fabric sweep: the
/// non-localised column's `link_queue_cycles` at each express strength.
pub fn fabric_report(
    spec: &SweepSpec,
    store: &crate::coordinator::batch::ResultStore,
) -> String {
    let mut out = String::from(
        "non-localised link_queue_cycles per express strength (rows in sweep order):\n",
    );
    for (row, label) in spec.row_labels.iter().enumerate() {
        let s = &store.results[row * 2];
        out.push_str(&format!(
            "  {label:>16}: link_queue {} (+ inval {})\n",
            s.link_queue_cycles, s.invalidation_link_cycles
        ));
    }
    out
}

// ---------------------------------------------------------------------------
// Protocol lab — the same workloads under every coherence protocol
// ---------------------------------------------------------------------------

/// Default machine ladder for the `protocol` sweep: the paper's 8×8
/// against the 16×16, where the fan-out routes are long enough for the
/// protocols' traffic shapes to pull the makespans apart.
pub fn protocol_machines() -> Vec<MachineSpec> {
    vec![MachineSpec::TilePro64, MachineSpec::Nuca256]
}

/// The coherence-protocol lab: three workloads with very different sharing
/// shapes — the rewrite-heavy micro-benchmark (case 3: static mapping,
/// hash-for-home, so every repeated store to a remote-homed line is a
/// protocol decision), the false-sharing write ping-pong (case 4: single
/// home), and the merge sort (case 3) — each run on every machine under
/// every protocol in [`ProtocolSpec::all`] order. Link and coherence
/// billing are always on: with the links off every protocol collapses to
/// the fused default path and the sweep measures nothing.
///
/// One row per machine × workload; one series column per protocol. The
/// headline is not the seconds table but [`protocol_report`]: which
/// protocol wins each row, and where the winner flips between machines.
pub fn protocol_spec(
    elems: u64,
    threads: usize,
    reps: u32,
    passes: u32,
    machines: &[MachineSpec],
    seed: u64,
) -> SweepSpec {
    let protocols = ProtocolSpec::all();
    let mut runs = Vec::new();
    let mut row_labels = Vec::new();
    for &m in machines {
        for tag in ["microbench", "pingpong", "mergesort"] {
            row_labels.push(format!("{}/{tag}", m.label()));
            for &p in &protocols {
                let base = match tag {
                    "microbench" => {
                        RunSpec::new(3, Workload::Microbench { reps }, elems, threads, seed)
                    }
                    "pingpong" => {
                        RunSpec::new(4, Workload::PingPong { passes }, elems, threads, seed)
                    }
                    _ => RunSpec::mergesort(3, elems, threads, seed),
                };
                runs.push(base.on_machine(m, true, true).with_protocol(p));
            }
        }
    }
    SweepSpec {
        title: format!(
            "Protocol lab: microbench/ping-pong/merge sort of {elems} ints, {threads} threads \
             under each coherence protocol (exec time, s)"
        ),
        x_label: "machine/workload".into(),
        series: protocols.iter().map(|p| p.label()).collect(),
        row_labels,
        runs,
        baseline: None,
        metric: Metric::Seconds,
    }
}

pub fn protocol_sweep(
    elems: u64,
    threads: usize,
    reps: u32,
    passes: u32,
    machines: &[MachineSpec],
    seed: u64,
) -> SweepTable {
    BatchRunner::auto().table(&protocol_spec(elems, threads, reps, passes, machines, seed))
}

/// Winner index for one row of a protocol sweep: first minimum makespan in
/// series order, so ties break towards the earlier (default-most) protocol.
fn protocol_row_winner(cells: &[RunStats]) -> usize {
    let mut win = 0;
    for (i, c) in cells.iter().enumerate() {
        if c.makespan_cycles < cells[win].makespan_cycles {
            win = i;
        }
    }
    win
}

/// Count of distinct makespans in one row — how much the protocol choice
/// moved this workload at all.
fn protocol_row_distinct(cells: &[RunStats]) -> usize {
    let mut v: Vec<u64> = cells.iter().map(|c| c.makespan_cycles).collect();
    v.sort_unstable();
    v.dedup();
    v.len()
}

/// The protocol lab's headline report: per row, the winning protocol (ties
/// break towards the series-order default), how many distinct makespans
/// the protocols produced, and per-protocol upgrade/invalidation traffic;
/// then the cross-machine winner flips per workload. The flip list is
/// informational — which protocol wins a contended row is a queueing
/// outcome, not a structural constant — but "at least one row where the
/// protocols disagree" is structural (MSI's upgrade round-trips can never
/// replay as MESI's silent upgrades) and the CI smoke pins it.
pub fn protocol_report(
    spec: &SweepSpec,
    store: &crate::coordinator::batch::ResultStore,
) -> String {
    let np = spec.series.len();
    let mut out = String::from(
        "protocol lab: winner per row (first minimum in series order) and traffic:\n",
    );
    let mut winners: Vec<(String, String, String)> = Vec::new(); // (workload, machine, winner)
    for (row, label) in spec.row_labels.iter().enumerate() {
        let cells = &store.results[row * np..(row + 1) * np];
        let win = protocol_row_winner(cells);
        out.push_str(&format!(
            "  {label:>22}: winner {} ({} distinct makespans)\n",
            spec.series[win],
            protocol_row_distinct(cells)
        ));
        for (i, c) in cells.iter().enumerate() {
            out.push_str(&format!(
                "      {:>16}: {:>12} cycles, upgrades {}, owner replies {}, inval link \
                 cycles {}\n",
                spec.series[i],
                c.makespan_cycles,
                c.upgrade_hits,
                c.owner_replies,
                c.invalidation_link_cycles
            ));
        }
        if let Some((machine, workload)) = label.split_once('/') {
            winners.push((
                workload.to_string(),
                machine.to_string(),
                spec.series[win].clone(),
            ));
        }
    }
    out.push_str("cross-machine winner flips:\n");
    let mut any = false;
    let mut seen: Vec<&str> = Vec::new();
    for (wl, _, _) in &winners {
        if seen.contains(&wl.as_str()) {
            continue;
        }
        seen.push(wl);
        let per: Vec<(&str, &str)> = winners
            .iter()
            .filter(|(w, _, _)| w == wl)
            .map(|(_, m, p)| (m.as_str(), p.as_str()))
            .collect();
        if per.iter().any(|(_, p)| *p != per[0].1) {
            any = true;
            let detail = per
                .iter()
                .map(|(m, p)| format!("{m}:{p}"))
                .collect::<Vec<_>>()
                .join(", ");
            out.push_str(&format!("  {wl}: {detail}\n"));
        }
    }
    if !any {
        out.push_str("  none (the same protocol wins on every machine)\n");
    }
    out
}

/// Machine-readable twin of [`protocol_report`], meant to ride next to the
/// sweep's own `to_json` record: `protocols` (series order), one entry per
/// row with the winner and distinct-makespan count, and the per-workload
/// cross-machine flip list.
pub fn protocol_report_json(
    spec: &SweepSpec,
    store: &crate::coordinator::batch::ResultStore,
) -> crate::util::json::Json {
    use crate::util::json::Json;
    let np = spec.series.len();
    let mut rows = Vec::new();
    let mut winners: Vec<(String, String, String)> = Vec::new();
    for (row, label) in spec.row_labels.iter().enumerate() {
        let cells = &store.results[row * np..(row + 1) * np];
        let win = protocol_row_winner(cells);
        rows.push(Json::obj(vec![
            ("label", Json::str(label.clone())),
            ("winner", Json::str(spec.series[win].clone())),
            (
                "distinct_makespans",
                Json::num(protocol_row_distinct(cells) as f64),
            ),
            (
                "makespan_cycles",
                Json::arr(cells.iter().map(|c| Json::num(c.makespan_cycles as f64))),
            ),
        ]));
        if let Some((machine, workload)) = label.split_once('/') {
            winners.push((
                workload.to_string(),
                machine.to_string(),
                spec.series[win].clone(),
            ));
        }
    }
    let mut flips = Vec::new();
    let mut seen: Vec<&str> = Vec::new();
    for (wl, _, _) in &winners {
        if seen.contains(&wl.as_str()) {
            continue;
        }
        seen.push(wl);
        let per: Vec<(&String, &String)> = winners
            .iter()
            .filter(|(w, _, _)| w == wl)
            .map(|(_, m, p)| (m, p))
            .collect();
        if per.iter().any(|(_, p)| *p != per[0].1) {
            flips.push(Json::obj(vec![
                ("workload", Json::str(wl.clone())),
                (
                    "winners",
                    Json::arr(per.iter().map(|(m, p)| {
                        Json::obj(vec![
                            ("machine", Json::str((*m).clone())),
                            ("protocol", Json::str((*p).clone())),
                        ])
                    })),
                ),
            ]));
        }
    }
    Json::obj(vec![
        (
            "protocols",
            Json::arr(spec.series.iter().map(|s| Json::str(s.clone()))),
        ),
        ("rows", Json::arr(rows)),
        ("flips", Json::arr(flips)),
    ])
}

// ---------------------------------------------------------------------------
// Serve front-end — default ladders for `repro batch serve`
// ---------------------------------------------------------------------------

/// Default machine ladder for the serve grid: the paper's 8×8 only — one
/// chip, one service curve; widen with `--machines` to compare chips.
pub fn serve_machines() -> Vec<MachineSpec> {
    vec![MachineSpec::TilePro64]
}

/// Default offered-load rungs (ρ = arrival rate × single-request service
/// time): below the knee, near it, and past it — a ladder crossing ρ = 1
/// must detect a saturation knee on a single-server queue, which is what
/// the CI smoke pins.
pub fn serve_rhos() -> Vec<f64> {
    vec![0.5, 0.8, 1.2]
}

/// Default dispatch policies: pure FIFO against greedy 8-way coalescing —
/// the pair that shows the batching trade (worse p50 at low load, higher
/// sustained throughput past the knee).
pub fn serve_policies() -> Vec<crate::serve::BatchPolicy> {
    vec![
        crate::serve::BatchPolicy::Immediate,
        crate::serve::BatchPolicy::Batch { max: 8, wait: 0 },
    ]
}

/// Default per-request workload for the serve grid: the paper's localised
/// merge sort (Table 1 case 8) at a small request size — each request
/// sorts `elems` keys, a batch of k sorts `k × elems` in one replay.
pub fn serve_template(case_id: u8, elems: u64, threads: usize, seed: u64) -> RunSpec {
    RunSpec::mergesort(case_id, elems, threads, seed)
}

/// Default partition ladder for multi-server scaling (the perf bench and
/// CI smoke): whole chip, two halves, four quadrants. Every rung shares
/// the whole-chip ρ anchor, so the same arrival stream hits each — the
/// knee shift and capacity ratio are like-for-like.
pub fn serve_partition_ladder() -> Vec<crate::arch::PartitionSpec> {
    vec![
        crate::arch::PartitionSpec::Whole,
        crate::arch::PartitionSpec::Auto(2),
        crate::arch::PartitionSpec::Auto(4),
    ]
}

/// §2's three homing classes head-to-head on the repeated-scan kernel:
/// local homing (first touch by the worker), remote homing (one fixed
/// other tile — the machine's far corner), and hash-for-home — plus the
/// localised fix. Runs on any machine (with an optional fabric applied);
/// `link_contention` per the CLI.
pub fn homing_classes(
    elems: u64,
    threads: usize,
    passes: u32,
    machine: MachineSpec,
    fabric: Option<&FabricSpec>,
    link_contention: bool,
) -> SweepTable {
    use crate::coordinator::localise::{build_program, LocaliseConfig, ELEM_BYTES};
    use crate::mem::{AllocKind, Homing, Placement};
    use crate::sim::{Loc, TraceBuilder};
    use std::rc::Rc;

    struct Scan(u32);
    impl crate::coordinator::ChunkKernel for Scan {
        fn steps(&self) -> u32 {
            self.0
        }
        fn emit_step(&self, t: &mut TraceBuilder, chunk: Loc, bytes: u64, _i: usize, _s: u32) {
            t.read(chunk, bytes);
        }
    }

    let m = machine
        .build_with_fabric(fabric)
        .expect("fabric validated at the CLI");
    let far_tile = crate::arch::TileId(m.num_tiles() - 1);
    let run = |homing: Homing, localised: bool| {
        let mut cfg = crate::sim::EngineConfig::for_machine(
            m.clone(),
            crate::mem::MemConfig {
                hash_policy: HashPolicy::None,
                striping: true,
            },
        );
        cfg.contention.links = link_contention;
        let mut e = Engine::new(cfg);
        let input = e
            .alloc
            .alloc_with(
                crate::arch::TileId(0),
                elems * ELEM_BYTES,
                AllocKind::Heap,
                homing,
                Placement::Striped,
            )
            .expect("alloc");
        let mut p = build_program(
            &input,
            elems,
            &LocaliseConfig { threads, localised },
            Rc::new(Scan(passes)),
        );
        e.run(&mut p, &mut crate::sched::StaticMapper::for_machine(&m))
            .expect("run")
            .seconds()
    };
    let mut t = SweepTable::new(
        &format!(
            "Homing classes (paper §2), {elems} ints, {threads} threads, {passes} passes on {} (s)",
            machine.label()
        ),
        "class",
        vec!["seconds".into()],
    );
    t.push_row("local (first touch)", vec![run(Homing::FirstTouch, false)]);
    t.push_row(
        format!("remote (tile {})", far_tile.0),
        vec![run(Homing::Single(far_tile), false)],
    );
    t.push_row("hash-for-home", vec![run(Homing::HashForHome, false)]);
    t.push_row("localised", vec![run(Homing::FirstTouch, true)]);
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::cases::case;

    const N: u64 = 1 << 14; // small sizes keep unit tests quick

    #[test]
    fn fig1_table_shape() {
        let t = fig1(1 << 14, 8, &[1, 4], DEFAULT_SEED);
        assert_eq!(t.rows.len(), 2);
        assert_eq!(t.series.len(), 2);
        assert!(t.rows.iter().all(|(_, v)| v.iter().all(|&x| x > 0.0)));
    }

    #[test]
    fn fig1_gap_grows_with_reps() {
        let t = fig1(1 << 15, 8, &[1, 16], DEFAULT_SEED);
        let gap = |row: &Vec<f64>| row[0] / row[1]; // non-loc / loc
        let g1 = gap(&t.rows[0].1);
        let g16 = gap(&t.rows[1].1);
        assert!(g16 > g1, "gap must grow with repetitions: {g1} -> {g16}");
    }

    #[test]
    fn fig2_case8_beats_case2() {
        // The tile-0 hot spot needs a sort bigger than tile 0's L2 to bite;
        // use a larger input than the other smoke tests.
        let t = fig2(1 << 18, &[16], DEFAULT_SEED);
        let row = &t.rows[0].1;
        let (case2, case8) = (row[1], row[7]);
        assert!(
            case8 > case2 * 1.8,
            "case 8 speedup {case8} must dwarf case 2 {case2}"
        );
    }

    #[test]
    fn fig2_static_beats_tile_linux() {
        // Needs a run long enough for load-balancer ticks to fire (the
        // paper's runs are seconds long; migrations are the whole point).
        let t = fig2(1 << 20, &[8], DEFAULT_SEED);
        let row = &t.rows[0].1;
        // case3 (static) vs case1 (linux), both non-localised hash.
        assert!(row[2] > row[0], "static {} vs linux {}", row[2], row[0]);
    }

    #[test]
    fn table1_times_has_8_rows() {
        let t = table1_times(N, 4, DEFAULT_SEED);
        assert_eq!(t.rows.len(), 8);
    }

    #[test]
    fn fig3_has_five_series() {
        let t = fig3(&[N], 4, DEFAULT_SEED);
        assert_eq!(t.series.len(), 5);
        assert_eq!(t.rows.len(), 1);
    }

    #[test]
    fn fig4_runs_both_modes() {
        let t = fig4(N, &[4], DEFAULT_SEED);
        assert_eq!(t.rows[0].1.len(), 4);
    }

    #[test]
    fn fig4_cache_off_striping_matters_more() {
        // Caches off: striping's relative effect at 32 threads must exceed
        // its cached counterpart (the paper's "much more observable").
        let off = fig4_cache_off(1 << 16, &[32], DEFAULT_SEED);
        let row = &off.rows[0].1;
        let rel_off = (row[1] - row[0]) / row[0];
        let on = fig4(1 << 16, &[32], DEFAULT_SEED);
        let r = &on.rows[0].1;
        let rel_on = (r[1] - r[0]).abs() / r[0];
        assert!(
            rel_off > rel_on,
            "cache-off striping effect {rel_off:.3} must exceed cached {rel_on:.3}"
        );
    }

    #[test]
    fn homing_classes_order() {
        let t = homing_classes(1 << 16, 16, 8, MachineSpec::TilePro64, None, false);
        let secs: Vec<f64> = t.rows.iter().map(|(_, v)| v[0]).collect();
        // localised fastest; remote single-tile the worst of the reads.
        let (_local, remote, hash, localised) = (secs[0], secs[1], secs[2], secs[3]);
        assert!(localised < hash, "localised {localised} vs hash {hash}");
        assert!(remote > hash, "remote hot spot {remote} vs hash {hash}");
    }

    #[test]
    fn homing_classes_runs_on_small_machine() {
        // The remote row must pick an on-grid far tile (15 on epiphany16),
        // not the tilepro64's tile 63.
        let t = homing_classes(1 << 14, 8, 2, MachineSpec::Epiphany16, None, true);
        assert_eq!(t.rows.len(), 4);
        assert_eq!(t.rows[1].0, "remote (tile 15)");
        assert!(t.rows.iter().all(|(_, v)| v[0] > 0.0));
    }

    #[test]
    fn grid_scaling_spec_shape() {
        let machines = grid_scaling_machines();
        let spec = grid_scaling_spec(1 << 14, 4, &machines, DEFAULT_SEED, true, true);
        spec.validate();
        assert_eq!(spec.row_labels, vec!["4x4:2", "tilepro64", "nuca256"]);
        assert_eq!(spec.series.len(), 3);
        assert!(spec.runs.iter().all(|r| r.link_contention));
    }

    #[test]
    fn grid_scaling_links_bite_non_localised_on_16x16() {
        // The acceptance pin: at 16×16 the non-localised single-home case
        // queues on mesh links; the localised style barely touches them.
        let spec =
            grid_scaling_spec(1 << 16, 16, &[MachineSpec::Nuca256], DEFAULT_SEED, true, true);
        let store = crate::coordinator::batch::BatchRunner::auto().run(&spec);
        let one_home = &store.results[1]; // case 4 column
        let localised = &store.results[2]; // case 8 column
        assert!(
            one_home.link_queue_cycles > 0,
            "non-localised 16x16 run must queue on links"
        );
        assert!(
            localised.link_queue_cycles * 5 < one_home.link_queue_cycles,
            "localised link queueing {} should be far below non-localised {}",
            localised.link_queue_cycles,
            one_home.link_queue_cycles
        );
    }

    #[test]
    fn falseshare_spec_shape() {
        let machines = falseshare_machines();
        let spec = falseshare_spec(1 << 13, 8, 2, &machines, DEFAULT_SEED);
        spec.validate();
        assert_eq!(spec.row_labels, vec!["tilepro64", "nuca256"]);
        assert_eq!(spec.series.len(), 2);
        assert!(spec
            .runs
            .iter()
            .all(|r| r.link_contention && r.coherence_links));
    }

    #[test]
    fn falseshare_16x16_saturates_the_non_localised_variant() {
        // The acceptance pin: with coherence-link billing on, the 16×16
        // non-localised ping-pong's link_queue + invalidation_link cycles
        // must dwarf the localised variant's, and the report says so.
        let spec = falseshare_spec(1 << 13, 16, 4, &[MachineSpec::Nuca256], DEFAULT_SEED);
        let store = crate::coordinator::batch::BatchRunner::auto().run(&spec);
        let shared = store.results[0].coherence_link_cycles();
        let local = store.results[1].coherence_link_cycles();
        assert!(shared > 0, "ping-pong must queue on the mesh");
        assert!(
            shared > 10 * local.max(1),
            "non-localised coherence traffic {shared} must dwarf localised {local}"
        );
        assert!(
            store.results[0].invalidation_link_cycles > 0,
            "invalidation routes must be billed"
        );
        let report = falseshare_report(&spec, &store);
        assert!(report.contains("nuca256"), "{report}");
        assert!(report.contains("ratio"), "{report}");
    }

    #[test]
    fn falseshare_hurts_more_on_16x16_than_8x8() {
        // Fig. 4-crossover flavour: the same ping-pong burns more mesh on
        // the larger grid (longer fan-out routes, more links crossed).
        let spec = falseshare_spec(1 << 13, 16, 4, &falseshare_machines(), DEFAULT_SEED);
        let store = crate::coordinator::batch::BatchRunner::auto().run(&spec);
        let small = store.results[0].coherence_link_cycles();
        let big = store.results[2].coherence_link_cycles();
        assert!(
            big > small,
            "16x16 coherence traffic {big} must exceed 8x8's {small}"
        );
    }

    #[test]
    fn placement_spec_shape_and_report() {
        let spec = placement_spec(
            1 << 13,
            8,
            &placement_machines(),
            &placement_ladder(),
            DEFAULT_SEED,
            true,
            true,
        );
        spec.validate();
        assert_eq!(spec.row_labels.len(), 2 * 4);
        assert_eq!(spec.row_labels[0], "tilepro64/edges");
        assert_eq!(spec.row_labels[6], "16x16:4/corners");
        assert_eq!(spec.runs.len(), 8 * 4);
        assert!(spec.check_thread_capacity().is_ok());
        assert!(spec
            .runs
            .iter()
            .all(|r| r.link_contention && r.fabric.is_some()));
        let store = crate::coordinator::batch::BatchRunner::auto().run(&spec);
        let report = placement_report(&spec, &store);
        assert!(report.contains("tilepro64/corners"), "{report}");
        assert!(report.contains("case3"), "{report}");
    }

    #[test]
    fn placement_moves_the_makespan_on_16x16() {
        // The CI smoke's in-tree twin: corners vs edges on a 16×16 grid
        // must simulate differently (every DRAM route changes).
        let m = [MachineSpec::Custom { w: 16, h: 16, ctrls: 4 }];
        let edges =
            placement_spec(1 << 14, 16, &m, &[CtrlPlacement::EdgesEven], DEFAULT_SEED, true, true);
        let corners =
            placement_spec(1 << 14, 16, &m, &[CtrlPlacement::Corners], DEFAULT_SEED, true, true);
        let runner = crate::coordinator::batch::BatchRunner::auto();
        let (a, b) = (runner.run(&edges), runner.run(&corners));
        let makespans =
            |s: &crate::coordinator::batch::ResultStore| -> Vec<u64> {
                s.results.iter().map(|r| r.makespan_cycles).collect()
            };
        assert_ne!(makespans(&a), makespans(&b), "placement must matter");
    }

    #[test]
    fn fabric_sweep_shape_and_express_reduces_link_queueing() {
        let strengths = fabric_strengths();
        let spec = fabric_sweep_spec(
            1 << 13,
            16,
            4,
            &[MachineSpec::Nuca256],
            &strengths,
            DEFAULT_SEED,
            true,
            true,
        )
        .unwrap();
        spec.validate();
        assert_eq!(spec.row_labels, vec!["nuca256@x1", "nuca256@x0.5", "nuca256@x0.25"]);
        let store = crate::coordinator::batch::BatchRunner::auto().run(&spec);
        // Non-localised column (even indices): widening the express
        // channels must strictly reduce forward link queueing.
        let q: Vec<u64> = (0..3)
            .map(|row| store.results[row * 2].link_queue_cycles)
            .collect();
        assert!(q[0] > 0, "uniform ping-pong must queue on links");
        assert!(
            q[0] > q[1] && q[1] > q[2],
            "express channels must strictly reduce link queueing: {q:?}"
        );
        let report = fabric_report(&spec, &store);
        assert!(report.contains("nuca256@x0.25"), "{report}");
    }

    #[test]
    fn express_fabric_rejects_clause_injection() {
        // Strengths are spliced into the spec string: only bare decimal
        // factors may pass, never extra clauses.
        assert!(express_fabric("0.5").is_ok());
        assert!(express_fabric("2").is_ok());
        for s in ["0.5:dir=E@8", "1@2", "x", "", "0.5:ctrl=corners"] {
            assert!(express_fabric(s).is_err(), "strength '{s}' should fail");
        }
    }

    #[test]
    fn protocol_spec_shape() {
        let machines = protocol_machines();
        let spec = protocol_spec(1 << 12, 4, 2, 2, &machines, DEFAULT_SEED);
        spec.validate();
        assert_eq!(spec.row_labels.len(), 6);
        assert_eq!(spec.row_labels[0], "tilepro64/microbench");
        assert_eq!(spec.row_labels[5], "nuca256/mergesort");
        assert_eq!(spec.series.len(), 6);
        assert_eq!(spec.series[0], "write-invalidate");
        assert_eq!(spec.runs.len(), 36);
        assert!(spec
            .runs
            .iter()
            .all(|r| r.link_contention && r.coherence_links));
        // The default-protocol column stays unlabeled in run labels/JSON;
        // every other column carries its protocol.
        assert!(!spec.runs[0].label().contains("proto="));
        assert!(spec.runs[1].label().contains("proto=msi"));
    }

    #[test]
    fn protocol_lab_separates_the_protocols_and_reports_it() {
        // One machine keeps the runtime down; the structural separations
        // the engine tests pin (MSI upgrade round-trips on the mesh vs
        // MESI silent upgrades) must survive the batch pipeline.
        let spec = protocol_spec(1 << 12, 4, 4, 4, &[MachineSpec::TilePro64], DEFAULT_SEED);
        let store = crate::coordinator::batch::BatchRunner::auto().run(&spec);
        let np = spec.series.len();
        let mb = &store.results[..np]; // microbench row, series order
        let (wi, msi, mesi) = (&mb[0], &mb[1], &mb[2]);
        assert_eq!(wi.upgrade_hits, 0, "fused default path counts no upgrades");
        assert!(msi.upgrade_hits > 0 && mesi.upgrade_hits > 0);
        assert!(
            msi.invalidation_link_cycles > mesi.invalidation_link_cycles,
            "MSI must bill upgrade round-trips on the invalidation class: {} vs {}",
            msi.invalidation_link_cycles,
            mesi.invalidation_link_cycles
        );
        assert_ne!(
            msi.makespan_cycles, mesi.makespan_cycles,
            "upgrade round-trips cannot replay as silent upgrades"
        );
        let report = protocol_report(&spec, &store);
        assert!(report.contains("winner"), "{report}");
        assert!(report.contains("tilepro64/microbench"), "{report}");
        let json = protocol_report_json(&spec, &store);
        let rows = match json.get("rows").unwrap() {
            crate::util::json::Json::Arr(v) => v,
            other => panic!("rows must be an array, got {other}"),
        };
        let distinct = rows
            .iter()
            .filter_map(|r| r.get("distinct_makespans").and_then(|d| d.as_usize()))
            .max()
            .unwrap();
        assert!(distinct >= 2, "at least one row must separate the protocols");
    }

    #[test]
    fn run_helpers_deterministic() {
        let a = run_mergesort(&case(1), N, 4, true, 7).makespan_cycles;
        let b = run_mergesort(&case(1), N, 4, true, 7).makespan_cycles;
        assert_eq!(a, b, "same seed must replay identically");
    }
}
