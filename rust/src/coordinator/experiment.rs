//! Experiment drivers: one function per paper artefact (Fig. 1–4, Table 1).
//!
//! Each driver sweeps the paper's parameter grid, runs the simulator, and
//! returns a `SweepTable` whose rows/series mirror the published figure.
//! The bench binaries and the `repro` CLI are thin wrappers around these.

use crate::coordinator::cases::{table1, CaseSpec};
use crate::harness::SweepTable;
use crate::mem::HashPolicy;
use crate::sim::{Engine, RunStats};
use crate::workloads::{mergesort, microbench};

/// Default seed for Tile Linux scheduling in experiments.
pub const DEFAULT_SEED: u64 = 2014;

/// Run the micro-benchmark for one configuration.
pub fn run_microbench(case: &CaseSpec, elems: u64, threads: usize, reps: u32, seed: u64) -> RunStats {
    let mut engine = Engine::new(case.engine_config(true));
    let program = microbench::build(
        &mut engine,
        &microbench::MicrobenchConfig {
            elems,
            threads,
            reps,
            localised: case.localised,
        },
    );
    let mut sched = case.mapper.scheduler(seed);
    engine.run(&program, sched.as_mut()).expect("microbench run failed")
}

/// Run merge sort for one configuration.
pub fn run_mergesort(
    case: &CaseSpec,
    elems: u64,
    threads: usize,
    striping: bool,
    seed: u64,
) -> RunStats {
    run_mergesort_variant(case, case.mergesort_variant(), elems, threads, striping, seed)
}

/// Merge sort with an explicit variant (Fig. 3's intermediate-step series).
pub fn run_mergesort_variant(
    case: &CaseSpec,
    variant: mergesort::Variant,
    elems: u64,
    threads: usize,
    striping: bool,
    seed: u64,
) -> RunStats {
    let mut engine = Engine::new(case.engine_config(striping));
    let program = mergesort::build(
        &mut engine,
        &mergesort::MergesortConfig {
            elems,
            threads,
            variant,
        },
    );
    let mut sched = case.mapper.scheduler(seed);
    engine.run(&program, sched.as_mut()).expect("mergesort run failed")
}

// ---------------------------------------------------------------------------
// Fig. 1 — micro-benchmark execution time vs repetitions
// ---------------------------------------------------------------------------

/// Paper setup: 1 M integers, 63 threads; localised (static map, hash
/// disabled) vs non-localised (Tile Linux default mapping, hash-for-home).
pub fn fig1(elems: u64, threads: usize, reps_sweep: &[u32], seed: u64) -> SweepTable {
    let localised = CaseSpec {
        id: 8,
        localised: true,
        mapper: crate::coordinator::cases::MapperKind::Static,
        hash: HashPolicy::None,
    };
    let non_localised = CaseSpec {
        id: 1,
        localised: false,
        mapper: crate::coordinator::cases::MapperKind::TileLinux,
        hash: HashPolicy::AllButStack,
    };
    let mut t = SweepTable::new(
        &format!("Fig.1 micro-benchmark, {elems} ints, {threads} threads (exec time, s)"),
        "repetitions",
        vec!["non-localised".into(), "localised".into()],
    );
    for &reps in reps_sweep {
        let nl = run_microbench(&non_localised, elems, threads, reps, seed);
        let lo = run_microbench(&localised, elems, threads, reps, seed);
        t.push_row(reps.to_string(), vec![nl.seconds(), lo.seconds()]);
    }
    t
}

// ---------------------------------------------------------------------------
// Fig. 2 / Table 1 — merge-sort speed-up, all 8 cases × thread counts
// ---------------------------------------------------------------------------

/// Speed-up for every Table 1 case over the thread sweep. The base (1.0)
/// is Case 1 at a single thread, exactly as in §5.1: "execution time with
/// a single thread under the default hashing scheme and the default Linux
/// scheduling policy".
pub fn fig2(elems: u64, thread_sweep: &[usize], seed: u64) -> SweepTable {
    let cases = table1();
    let base = run_mergesort(&cases[0], elems, 1, true, seed).makespan_cycles as f64;
    let mut t = SweepTable::new(
        &format!("Fig.2 merge sort speed-up, {elems} ints (base: case 1 @ 1 thread)"),
        "threads",
        cases.iter().map(|c| c.short()).collect(),
    );
    for &threads in thread_sweep {
        let row = cases
            .iter()
            .map(|c| base / run_mergesort(c, elems, threads, true, seed).makespan_cycles as f64)
            .collect();
        t.push_row(threads.to_string(), row);
    }
    t
}

/// Table 1 rendered as execution times at a fixed thread count.
pub fn table1_times(elems: u64, threads: usize, seed: u64) -> SweepTable {
    let mut t = SweepTable::new(
        &format!("Table 1 cases: merge sort of {elems} ints, {threads} threads (exec time, s)"),
        "case",
        vec!["seconds".into(), "speedup_vs_case1".into()],
    );
    let cases = table1();
    let c1 = run_mergesort(&cases[0], elems, threads, true, seed).makespan_cycles as f64;
    for c in &cases {
        let s = run_mergesort(c, elems, threads, true, seed);
        t.push_row(c.short(), vec![s.seconds(), c1 / s.makespan_cycles as f64]);
    }
    t
}

// ---------------------------------------------------------------------------
// Fig. 3 — best cases across input sizes (+ intermediate step)
// ---------------------------------------------------------------------------

/// §5.2: cases 3, 4, 7, 8 plus "case 3 + intermediate step", 64 threads,
/// sweeping the input size. Execution time in seconds.
pub fn fig3(sizes: &[u64], threads: usize, seed: u64) -> SweepTable {
    let cases = table1();
    let series: Vec<String> = vec![
        "case3".into(),
        "case3+interm".into(),
        "case4".into(),
        "case7".into(),
        "case8".into(),
    ];
    let mut t = SweepTable::new(
        &format!("Fig.3 exec time vs input size, {threads} threads (s)"),
        "elems",
        series,
    );
    for &elems in sizes {
        let c3 = run_mergesort(&cases[2], elems, threads, true, seed);
        let c3i = run_mergesort_variant(
            &cases[2],
            mergesort::Variant::NonLocalisedIntermediate,
            elems,
            threads,
            true,
            seed,
        );
        let c4 = run_mergesort(&cases[3], elems, threads, true, seed);
        let c7 = run_mergesort(&cases[6], elems, threads, true, seed);
        let c8 = run_mergesort(&cases[7], elems, threads, true, seed);
        t.push_row(
            elems.to_string(),
            vec![c3.seconds(), c3i.seconds(), c4.seconds(), c7.seconds(), c8.seconds()],
        );
    }
    t
}

// ---------------------------------------------------------------------------
// Fig. 4 — memory striping, static mapping
// ---------------------------------------------------------------------------

/// §5.3: execution time with striping on/off over the thread sweep, static
/// mapping, for the non-localised (hash) and localised (none) styles.
pub fn fig4(elems: u64, thread_sweep: &[usize], seed: u64) -> SweepTable {
    let cases = table1();
    let c3 = &cases[2]; // non-localised, static, hash
    let c8 = &cases[7]; // localised, static, none
    let mut t = SweepTable::new(
        &format!("Fig.4 striping influence, static mapping, {elems} ints (exec time, s)"),
        "threads",
        vec![
            "case3 striped".into(),
            "case3 non-striped".into(),
            "case8 striped".into(),
            "case8 non-striped".into(),
        ],
    );
    for &threads in thread_sweep {
        t.push_row(
            threads.to_string(),
            vec![
                run_mergesort(c3, elems, threads, true, seed).seconds(),
                run_mergesort(c3, elems, threads, false, seed).seconds(),
                run_mergesort(c8, elems, threads, true, seed).seconds(),
                run_mergesort(c8, elems, threads, false, seed).seconds(),
            ],
        );
    }
    t
}

/// Fig. 4's closing observation: "the effect of memory striping is
/// considerable when caching is turned off across the system". Same sweep
/// as fig4 but with the caches disabled — every access is a DRAM
/// transaction, so controller reach/contention dominates.
pub fn fig4_cache_off(elems: u64, thread_sweep: &[usize], seed: u64) -> SweepTable {
    let c3 = crate::coordinator::cases::case(3);
    let mut t = SweepTable::new(
        &format!("Fig.4 ablation: caches OFF, static mapping, {elems} ints (exec time, s)"),
        "threads",
        vec!["striped".into(), "non-striped".into()],
    );
    for &threads in thread_sweep {
        let run = |striping: bool| {
            let mut engine =
                Engine::new(c3.engine_config(striping).without_caches());
            let program = mergesort::build(
                &mut engine,
                &mergesort::MergesortConfig {
                    elems,
                    threads,
                    variant: mergesort::Variant::NonLocalised,
                },
            );
            let mut sched = c3.mapper.scheduler(seed);
            engine
                .run(&program, sched.as_mut())
                .expect("cache-off run failed")
                .seconds()
        };
        t.push_row(threads.to_string(), vec![run(true), run(false)]);
    }
    t
}

/// §2's three homing classes head-to-head on the repeated-scan kernel:
/// local homing (first touch by the worker), remote homing (one fixed
/// other tile), and hash-for-home — plus the localised fix.
pub fn homing_classes(elems: u64, threads: usize, passes: u32) -> SweepTable {
    use crate::coordinator::localise::{build_program, LocaliseConfig, ELEM_BYTES};
    use crate::mem::{AllocKind, Homing, Placement};
    use crate::sim::{Loc, TraceBuilder};

    struct Scan(u32);
    impl crate::coordinator::ChunkKernel for Scan {
        fn emit(&self, t: &mut TraceBuilder, chunk: Loc, bytes: u64, _i: usize) {
            for _ in 0..self.0 {
                t.read(chunk, bytes);
            }
        }
    }

    let run = |homing: Homing, localised: bool| {
        let mut e = Engine::new(crate::sim::EngineConfig::tilepro64(crate::mem::MemConfig {
            hash_policy: HashPolicy::None,
            striping: true,
        }));
        let input = e
            .alloc
            .alloc_with(
                crate::arch::TileId(0),
                elems * ELEM_BYTES,
                AllocKind::Heap,
                homing,
                Placement::Striped,
            )
            .expect("alloc");
        let p = build_program(
            &input,
            elems,
            &LocaliseConfig { threads, localised },
            &Scan(passes),
        );
        e.run(&p, &mut crate::sched::StaticMapper::new())
            .expect("run")
            .seconds()
    };
    let mut t = SweepTable::new(
        &format!("Homing classes (paper §2), {elems} ints, {threads} threads, {passes} passes (s)"),
        "class",
        vec!["seconds".into()],
    );
    t.push_row("local (first touch)", vec![run(Homing::FirstTouch, false)]);
    t.push_row(
        "remote (tile 63)",
        vec![run(Homing::Single(crate::arch::TileId(63)), false)],
    );
    t.push_row("hash-for-home", vec![run(Homing::HashForHome, false)]);
    t.push_row("localised", vec![run(Homing::FirstTouch, true)]);
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::cases::case;

    const N: u64 = 1 << 14; // small sizes keep unit tests quick

    #[test]
    fn fig1_table_shape() {
        let t = fig1(1 << 14, 8, &[1, 4], DEFAULT_SEED);
        assert_eq!(t.rows.len(), 2);
        assert_eq!(t.series.len(), 2);
        assert!(t.rows.iter().all(|(_, v)| v.iter().all(|&x| x > 0.0)));
    }

    #[test]
    fn fig1_gap_grows_with_reps() {
        let t = fig1(1 << 15, 8, &[1, 16], DEFAULT_SEED);
        let gap = |row: &Vec<f64>| row[0] / row[1]; // non-loc / loc
        let g1 = gap(&t.rows[0].1);
        let g16 = gap(&t.rows[1].1);
        assert!(g16 > g1, "gap must grow with repetitions: {g1} -> {g16}");
    }

    #[test]
    fn fig2_case8_beats_case2() {
        // The tile-0 hot spot needs a sort bigger than tile 0's L2 to bite;
        // use a larger input than the other smoke tests.
        let t = fig2(1 << 18, &[16], DEFAULT_SEED);
        let row = &t.rows[0].1;
        let (case2, case8) = (row[1], row[7]);
        assert!(
            case8 > case2 * 1.8,
            "case 8 speedup {case8} must dwarf case 2 {case2}"
        );
    }

    #[test]
    fn fig2_static_beats_tile_linux() {
        // Needs a run long enough for load-balancer ticks to fire (the
        // paper's runs are seconds long; migrations are the whole point).
        let t = fig2(1 << 20, &[8], DEFAULT_SEED);
        let row = &t.rows[0].1;
        // case3 (static) vs case1 (linux), both non-localised hash.
        assert!(row[2] > row[0], "static {} vs linux {}", row[2], row[0]);
    }

    #[test]
    fn table1_times_has_8_rows() {
        let t = table1_times(N, 4, DEFAULT_SEED);
        assert_eq!(t.rows.len(), 8);
    }

    #[test]
    fn fig3_has_five_series() {
        let t = fig3(&[N], 4, DEFAULT_SEED);
        assert_eq!(t.series.len(), 5);
        assert_eq!(t.rows.len(), 1);
    }

    #[test]
    fn fig4_runs_both_modes() {
        let t = fig4(N, &[4], DEFAULT_SEED);
        assert_eq!(t.rows[0].1.len(), 4);
    }

    #[test]
    fn fig4_cache_off_striping_matters_more() {
        // Caches off: striping's relative effect at 32 threads must exceed
        // its cached counterpart (the paper's "much more observable").
        let off = fig4_cache_off(1 << 16, &[32], DEFAULT_SEED);
        let row = &off.rows[0].1;
        let rel_off = (row[1] - row[0]) / row[0];
        let on = fig4(1 << 16, &[32], DEFAULT_SEED);
        let r = &on.rows[0].1;
        let rel_on = (r[1] - r[0]).abs() / r[0];
        assert!(
            rel_off > rel_on,
            "cache-off striping effect {rel_off:.3} must exceed cached {rel_on:.3}"
        );
    }

    #[test]
    fn homing_classes_order() {
        let t = homing_classes(1 << 16, 16, 8);
        let secs: Vec<f64> = t.rows.iter().map(|(_, v)| v[0]).collect();
        // localised fastest; remote single-tile the worst of the reads.
        let (_local, remote, hash, localised) = (secs[0], secs[1], secs[2], secs[3]);
        assert!(localised < hash, "localised {localised} vs hash {hash}");
        assert!(remote > hash, "remote hot spot {remote} vs hash {hash}");
    }

    #[test]
    fn run_helpers_deterministic() {
        let a = run_mergesort(&case(1), N, 4, true, 7).makespan_cycles;
        let b = run_mergesort(&case(1), N, 4, true, 7).makespan_cycles;
        assert_eq!(a, b, "same seed must replay identically");
    }
}
