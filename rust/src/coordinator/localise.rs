//! Algorithm 1 as a first-class, workload-agnostic API.
//!
//! The paper's pitch is that localisation is a *programming style*, not an
//! architecture-specific library: (1) divide the array into m parts, (2)
//! assign each part to a thread, (3) map threads to cores, (4) copy each
//! part into a freshly allocated array (re-homing it on the worker's tile),
//! (5) free it when done. [`build_program`] packages steps 1–5 over any
//! per-chunk kernel; the extra workloads (map/stencil/histogram/reduce) are
//! all expressed through it, demonstrating the claimed generality.
//!
//! Kernels emit *lazily*: a kernel declares how many emission steps it has
//! (typically its pass/sweep count) and appends one step's ops at a time,
//! so a thread's trace is streamed through a bounded buffer instead of
//! materialised up front — arbitrarily large pass counts cost no host RAM.

use std::rc::Rc;

use crate::mem::{AllocKind, Region};
use crate::sim::trace::{OpSource, SegmentGen, SegmentSource};
use crate::sim::{Engine, Loc, Program, TraceBuilder};
use crate::workloads::microbench::part_bounds;

pub const ELEM_BYTES: u64 = 4;

/// A per-chunk computation, emitted step by step. `emit_step` receives the
/// thread's (batch) trace builder, the location of its (possibly
/// localised) chunk, the chunk size in bytes, the thread index, and the
/// step index in `0..steps()` — and appends that step's access pattern.
/// One step should be a bounded batch (a pass, a sweep, …): it is the unit
/// the streaming trace pipeline buffers.
pub trait ChunkKernel {
    /// Number of emission steps (default: a single step).
    fn steps(&self) -> u32 {
        1
    }

    /// Append step `step`'s ops for `thread`'s chunk.
    fn emit_step(&self, t: &mut TraceBuilder, chunk: Loc, bytes: u64, thread: usize, step: u32);

    /// Human-readable name (reports).
    fn name(&self) -> &'static str {
        "kernel"
    }
}

/// Blanket impl so closures can be used as single-step kernels.
impl<F> ChunkKernel for F
where
    F: Fn(&mut TraceBuilder, Loc, u64, usize),
{
    fn emit_step(&self, t: &mut TraceBuilder, chunk: Loc, bytes: u64, thread: usize, _step: u32) {
        self(t, chunk, bytes, thread)
    }
}

#[derive(Clone, Copy, Debug)]
pub struct LocaliseConfig {
    pub threads: usize,
    /// Apply steps 4–5 (the localisation); `false` runs the kernel directly
    /// on the shared input (the conventional style).
    pub localised: bool,
}

/// Streaming per-thread source: optional localisation prologue (steps 4 of
/// Algorithm 1), then one kernel step per batch, then the free (step 5).
struct ChunkGen {
    kernel: Rc<dyn ChunkKernel>,
    shared_chunk: Loc,
    bytes: u64,
    thread: usize,
    slot: u32,
    localised: bool,
    step: u32,
}

impl SegmentGen for ChunkGen {
    fn fill(&mut self, out: &mut TraceBuilder) -> bool {
        let ksteps = self.kernel.steps();
        if self.localised {
            let local = Loc::Slot {
                slot: self.slot,
                offset: 0,
            };
            match self.step {
                0 => {
                    // Step 4: copy into a fresh local array (first touch
                    // re-homes).
                    out.alloc(self.slot, self.bytes, AllocKind::Heap);
                    out.copy(self.shared_chunk, local, self.bytes);
                }
                s if s <= ksteps => {
                    self.kernel
                        .emit_step(out, local, self.bytes, self.thread, s - 1);
                }
                s if s == ksteps + 1 => {
                    // Step 5: free as soon as the thread finishes.
                    out.free(self.slot);
                }
                _ => return false,
            }
        } else {
            if self.step >= ksteps {
                return false;
            }
            self.kernel
                .emit_step(out, self.shared_chunk, self.bytes, self.thread, self.step);
        }
        self.step += 1;
        true
    }

    fn rewind(&mut self) {
        self.step = 0;
    }
}

/// Build a program that applies `kernel` to every chunk of `input`
/// (`elems` elements), per Algorithm 1.
pub fn build_program(
    input: &Region,
    elems: u64,
    cfg: &LocaliseConfig,
    kernel: Rc<dyn ChunkKernel>,
) -> Program {
    assert!(cfg.threads >= 1 && elems >= cfg.threads as u64);
    let mut sources: Vec<Box<dyn OpSource>> = Vec::with_capacity(cfg.threads);
    for i in 0..cfg.threads {
        // Step 1+2: divide and assign by pointer arithmetic.
        let (start, end) = part_bounds(elems, cfg.threads, i);
        sources.push(SegmentSource::boxed(ChunkGen {
            kernel: kernel.clone(),
            shared_chunk: Loc::Abs(input.addr.offset(start * ELEM_BYTES)),
            bytes: (end - start) * ELEM_BYTES,
            thread: i,
            slot: i as u32,
            localised: cfg.localised,
            step: 0,
        }));
    }
    // Step 3 (mapping) is the scheduler passed to Engine::run.
    Program::new(sources, cfg.threads as u32, 0)
}

/// Convenience: fresh engine + input as if initialised by `main` on tile 0,
/// build per Algorithm 1, run under `sched`.
pub fn run_localised(
    engine_cfg: crate::sim::EngineConfig,
    elems: u64,
    cfg: &LocaliseConfig,
    kernel: Rc<dyn ChunkKernel>,
    sched: &mut dyn crate::sched::Scheduler,
) -> Result<crate::sim::RunStats, crate::sim::EngineError> {
    let mut engine = Engine::new(engine_cfg);
    let input = engine.prealloc_touched(crate::arch::TileId(0), elems * ELEM_BYTES);
    let mut program = build_program(&input, elems, cfg, kernel);
    engine.run(&mut program, sched)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::TileId;
    use crate::mem::{HashPolicy, MemConfig};
    use crate::sched::StaticMapper;
    use crate::sim::{Engine, EngineConfig};

    struct RepeatedScan {
        passes: u32,
    }

    impl ChunkKernel for RepeatedScan {
        fn steps(&self) -> u32 {
            self.passes
        }
        fn emit_step(&self, t: &mut TraceBuilder, chunk: Loc, bytes: u64, _t: usize, _s: u32) {
            t.read(chunk, bytes);
        }
        fn name(&self) -> &'static str {
            "repeated-scan"
        }
    }

    fn engine(policy: HashPolicy) -> Engine {
        Engine::new(EngineConfig::tilepro64(MemConfig {
            hash_policy: policy,
            striping: true,
        }))
    }

    #[test]
    fn builds_non_localised_without_allocs() {
        let mut e = engine(HashPolicy::None);
        let input = e.prealloc_touched(TileId(0), 4096 * ELEM_BYTES);
        let mut p = build_program(
            &input,
            4096,
            &LocaliseConfig {
                threads: 4,
                localised: false,
            },
            Rc::new(RepeatedScan { passes: 2 }),
        );
        p.validate().unwrap();
        let stats = e.run(&mut p, &mut StaticMapper::new()).unwrap();
        assert_eq!(stats.allocs, 1); // just the prealloc
        assert_eq!(stats.frees, 0);
    }

    #[test]
    fn localised_allocs_and_frees_per_thread() {
        let mut e = engine(HashPolicy::None);
        let input = e.prealloc_touched(TileId(0), 4096 * ELEM_BYTES);
        let mut p = build_program(
            &input,
            4096,
            &LocaliseConfig {
                threads: 4,
                localised: true,
            },
            Rc::new(RepeatedScan { passes: 2 }),
        );
        let stats = e.run(&mut p, &mut StaticMapper::new()).unwrap();
        assert_eq!(stats.allocs, 1 + 4);
        assert_eq!(stats.frees, 4);
    }

    #[test]
    fn streams_one_pass_per_batch() {
        let mut e = engine(HashPolicy::None);
        let input = e.prealloc_touched(TileId(0), 4096 * ELEM_BYTES);
        let mut p = build_program(
            &input,
            4096,
            &LocaliseConfig {
                threads: 2,
                localised: true,
            },
            Rc::new(RepeatedScan { passes: 100 }),
        );
        let recorded = p.record();
        // alloc+copy, 100 single-read passes, free.
        assert_eq!(recorded[0].len(), 2 + 100 + 1);
        assert_eq!(recorded, p.record(), "reset must replay identically");
    }

    #[test]
    fn localisation_pays_off_with_reuse() {
        // Enough passes: localised beats conventional under local homing —
        // the generic API reproduces the microbenchmark result.
        let mk = |localised| {
            let mut e = engine(HashPolicy::None);
            let input = e.prealloc_touched(TileId(0), (1 << 16) * ELEM_BYTES);
            let mut p = build_program(
                &input,
                1 << 16,
                &LocaliseConfig {
                    threads: 16,
                    localised,
                },
                Rc::new(RepeatedScan { passes: 12 }),
            );
            e.run(&mut p, &mut StaticMapper::new()).unwrap()
        };
        let conv = mk(false);
        let loc = mk(true);
        assert!(
            loc.makespan_cycles < conv.makespan_cycles,
            "localised {} vs conventional {}",
            loc.makespan_cycles,
            conv.makespan_cycles
        );
    }

    #[test]
    fn closure_kernels_work() {
        let mut e = engine(HashPolicy::None);
        let input = e.prealloc_touched(TileId(0), 1024 * ELEM_BYTES);
        let kernel = |t: &mut TraceBuilder, chunk: Loc, bytes: u64, _i: usize| {
            t.read(chunk, bytes).compute(bytes / 4);
        };
        let mut p = build_program(
            &input,
            1024,
            &LocaliseConfig {
                threads: 2,
                localised: true,
            },
            Rc::new(kernel),
        );
        p.validate().unwrap();
    }
}
