//! Algorithm 1 as a first-class, workload-agnostic API.
//!
//! The paper's pitch is that localisation is a *programming style*, not an
//! architecture-specific library: (1) divide the array into m parts, (2)
//! assign each part to a thread, (3) map threads to cores, (4) copy each
//! part into a freshly allocated array (re-homing it on the worker's tile),
//! (5) free it when done. `LocalisedRunner` packages steps 1–5 over any
//! per-chunk kernel; the extra workloads (map/stencil/histogram/reduce) are
//! all expressed through it, demonstrating the claimed generality.

use crate::mem::{AllocKind, Region};
use crate::sim::{Engine, Loc, Program, TraceBuilder};
use crate::workloads::microbench::part_bounds;

pub const ELEM_BYTES: u64 = 4;

/// A per-chunk computation. `emit` receives the thread's trace builder,
/// the location of its (possibly localised) chunk, the chunk size in
/// bytes, and the thread index — and appends whatever access pattern the
/// kernel performs on that chunk.
pub trait ChunkKernel {
    fn emit(&self, t: &mut TraceBuilder, chunk: Loc, bytes: u64, thread: usize);

    /// Human-readable name (reports).
    fn name(&self) -> &'static str {
        "kernel"
    }
}

/// Blanket impl so closures can be used as kernels.
impl<F> ChunkKernel for F
where
    F: Fn(&mut TraceBuilder, Loc, u64, usize),
{
    fn emit(&self, t: &mut TraceBuilder, chunk: Loc, bytes: u64, thread: usize) {
        self(t, chunk, bytes, thread)
    }
}

#[derive(Clone, Copy, Debug)]
pub struct LocaliseConfig {
    pub threads: usize,
    /// Apply steps 4–5 (the localisation); `false` runs the kernel directly
    /// on the shared input (the conventional style).
    pub localised: bool,
}

/// Build a program that applies `kernel` to every chunk of `input`
/// (`elems` elements), per Algorithm 1.
pub fn build_program(
    input: &Region,
    elems: u64,
    cfg: &LocaliseConfig,
    kernel: &dyn ChunkKernel,
) -> Program {
    assert!(cfg.threads >= 1 && elems >= cfg.threads as u64);
    let mut builders = Vec::with_capacity(cfg.threads);
    for i in 0..cfg.threads {
        // Step 1+2: divide and assign by pointer arithmetic.
        let (start, end) = part_bounds(elems, cfg.threads, i);
        let bytes = (end - start) * ELEM_BYTES;
        let shared_chunk = Loc::Abs(input.addr.offset(start * ELEM_BYTES));
        let mut t = TraceBuilder::new();
        if cfg.localised {
            // Step 4: copy into a fresh local array (first touch re-homes).
            let slot = i as u32;
            let local = Loc::Slot { slot, offset: 0 };
            t.alloc(slot, bytes, AllocKind::Heap);
            t.copy(shared_chunk, local, bytes);
            kernel.emit(&mut t, local, bytes, i);
            // Step 5: free as soon as the thread finishes.
            t.free(slot);
        } else {
            kernel.emit(&mut t, shared_chunk, bytes, i);
        }
        builders.push(t);
    }
    // Step 3 (mapping) is the scheduler passed to Engine::run.
    Program::from_builders(builders, cfg.threads as u32, 0)
}

/// Convenience: fresh engine + input as if initialised by `main` on tile 0,
/// build per Algorithm 1, run under `sched`.
pub fn run_localised(
    engine_cfg: crate::sim::EngineConfig,
    elems: u64,
    cfg: &LocaliseConfig,
    kernel: &dyn ChunkKernel,
    sched: &mut dyn crate::sched::Scheduler,
) -> Result<crate::sim::RunStats, crate::sim::EngineError> {
    let mut engine = Engine::new(engine_cfg);
    let input = engine.prealloc_touched(crate::arch::TileId(0), elems * ELEM_BYTES);
    let program = build_program(&input, elems, cfg, kernel);
    engine.run(&program, sched)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::TileId;
    use crate::mem::{HashPolicy, MemConfig};
    use crate::sched::StaticMapper;
    use crate::sim::{Engine, EngineConfig};

    struct RepeatedScan {
        passes: u32,
    }

    impl ChunkKernel for RepeatedScan {
        fn emit(&self, t: &mut TraceBuilder, chunk: Loc, bytes: u64, _thread: usize) {
            for _ in 0..self.passes {
                t.read(chunk, bytes);
            }
        }
        fn name(&self) -> &'static str {
            "repeated-scan"
        }
    }

    fn engine(policy: HashPolicy) -> Engine {
        Engine::new(EngineConfig::tilepro64(MemConfig {
            hash_policy: policy,
            striping: true,
        }))
    }

    #[test]
    fn builds_non_localised_without_allocs() {
        let mut e = engine(HashPolicy::None);
        let input = e.prealloc_touched(TileId(0), 4096 * ELEM_BYTES);
        let p = build_program(
            &input,
            4096,
            &LocaliseConfig {
                threads: 4,
                localised: false,
            },
            &RepeatedScan { passes: 2 },
        );
        p.validate().unwrap();
        let stats = e.run(&p, &mut StaticMapper::new()).unwrap();
        assert_eq!(stats.allocs, 1); // just the prealloc
        assert_eq!(stats.frees, 0);
    }

    #[test]
    fn localised_allocs_and_frees_per_thread() {
        let mut e = engine(HashPolicy::None);
        let input = e.prealloc_touched(TileId(0), 4096 * ELEM_BYTES);
        let p = build_program(
            &input,
            4096,
            &LocaliseConfig {
                threads: 4,
                localised: true,
            },
            &RepeatedScan { passes: 2 },
        );
        let stats = e.run(&p, &mut StaticMapper::new()).unwrap();
        assert_eq!(stats.allocs, 1 + 4);
        assert_eq!(stats.frees, 4);
    }

    #[test]
    fn localisation_pays_off_with_reuse() {
        // Enough passes: localised beats conventional under local homing —
        // the generic API reproduces the microbenchmark result.
        let mk = |localised| {
            let mut e = engine(HashPolicy::None);
            let input = e.prealloc_touched(TileId(0), (1 << 16) * ELEM_BYTES);
            let p = build_program(
                &input,
                1 << 16,
                &LocaliseConfig {
                    threads: 16,
                    localised,
                },
                &RepeatedScan { passes: 12 },
            );
            e.run(&p, &mut StaticMapper::new()).unwrap()
        };
        let conv = mk(false);
        let loc = mk(true);
        assert!(
            loc.makespan_cycles < conv.makespan_cycles,
            "localised {} vs conventional {}",
            loc.makespan_cycles,
            conv.makespan_cycles
        );
    }

    #[test]
    fn closure_kernels_work() {
        let mut e = engine(HashPolicy::None);
        let input = e.prealloc_touched(TileId(0), 1024 * ELEM_BYTES);
        let kernel = |t: &mut TraceBuilder, chunk: Loc, bytes: u64, _i: usize| {
            t.read(chunk, bytes).compute(bytes / 4);
        };
        let p = build_program(
            &input,
            1024,
            &LocaliseConfig {
                threads: 2,
                localised: true,
            },
            &kernel,
        );
        p.validate().unwrap();
    }
}
