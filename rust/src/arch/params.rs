//! Latency and capacity parameters of the simulated chip.
//!
//! `LatencyParams::TILEPRO64` is the single source of truth shared with the
//! L2 analytical model (`python/compile/model.py` mirrors these constants);
//! `rust/tests/integration_runtime.rs` executes the AOT'd latency model and
//! cross-checks it against `access_cycles` below, so drift fails CI.

use super::topology::{hops, TileId};

/// Core clock of the paper's evaluation platform (860 MHz per Fig. 1).
/// This is the TILEPro64 preset's value and the fallback for stats that
/// predate per-machine clocks; every machine carries its own clock in
/// [`LatencyParams::clock_hz`] (the Epiphany-III runs at 600 MHz).
pub const CLOCK_HZ: f64 = 860.0e6;

/// Cache line size in bytes (TILEPro64 L2 line).
pub const LINE_BYTES: u64 = 64;

/// Page size for homing decisions (TILEPro64 large user pages).
pub const PAGE_BYTES: u64 = 64 * 1024;

/// Where an access was satisfied.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum HitLevel {
    /// Requester's own L1D.
    L1,
    /// Requester's own L2.
    L2,
    /// The line's home tile L2 — the distributed "L3" of DDC.
    Home { home: TileId },
    /// DRAM behind a memory controller (attach tile recorded for hops).
    Ddr { ctrl_attach: TileId },
}

#[derive(Clone, Debug)]
pub struct LatencyParams {
    /// Core clock in Hz — the cycles→seconds conversion for this machine
    /// (threaded into `RunStats::seconds`, the report tables, and JSON).
    pub clock_hz: f64,
    pub l1_hit: u64,
    pub l2_hit: u64,
    /// Fixed NoC packetisation overhead per remote round trip.
    pub noc_header: u64,
    /// Cycles per mesh hop (one direction).
    pub noc_hop: u64,
    /// DRAM access latency (row activation + transfer), excluding the mesh.
    pub ddr: u64,
    /// Cycles a store to a *remotely homed* line costs the issuing thread:
    /// stores are posted through the store buffer (write-through to home),
    /// so the mesh round trip is hidden; bandwidth is billed at the home
    /// port by the contention model instead.
    pub store_post: u64,
    /// Home-tile L2 service occupancy per request (bandwidth term used by
    /// the contention model, not added to an uncontended access).
    pub home_service: u64,
    /// Memory-controller service occupancy per line.
    pub ctrl_service: u64,
    /// Directional mesh-link occupancy per line-sized packet (bandwidth
    /// term used by the link-contention model; an uncontended traversal is
    /// already covered by `noc_hop` latency).
    pub link_service: u64,
    /// OS cost of migrating a thread (save/restore, run-queue latency).
    pub migration_cost: u64,
    /// Per-element ALU cost for workload "compute" phases (e.g. one merge
    /// comparison), in cycles.
    pub compute_per_elem: u64,
    /// Flits per cache-line payload on this mesh (line bytes / flit bytes).
    /// Used by the reply-path wormhole approximation: a data reply costs
    /// `max(header_hops * noc_hop, line_flits * link_service)` to traverse,
    /// not a per-hop serial walk of the whole payload.
    pub line_flits: u64,
}

impl LatencyParams {
    pub const TILEPRO64: LatencyParams = LatencyParams {
        clock_hz: CLOCK_HZ,
        l1_hit: 2,
        l2_hit: 8,
        noc_header: 6,
        noc_hop: 1,
        ddr: 88,
        // Sustained remote-store rate is limited by the (shallow) store
        // buffer: roughly one line per local-L2-write time, slightly
        // cheaper because the writer never waits for the ack.
        store_post: 6,
        home_service: 2,
        ctrl_service: 4,
        // One 64 B line ≈ four 16 B flit beats on the TILEPro-class mesh;
        // links are wider than a home port is deep, so per-link occupancy
        // sits between hop latency and home service.
        link_service: 1,
        migration_cost: 30_000,
        compute_per_elem: 1,
        // One 64 B line crosses the 16 B-wide TILEPro mesh in four beats.
        line_flits: 4,
    };

    /// Epiphany-III eLink/eMesh calibration (Richie et al.,
    /// arXiv:1704.08343). The 16-core Epiphany has no caches — each core
    /// owns 32 KB of flat local SRAM — so the "L1/L2" terms model local
    /// SRAM banks, and the single off-chip eLink is the only DRAM path:
    ///
    /// - local SRAM loads complete in a cycle; a "home bank" lookup is a
    ///   few cycles of arbitration;
    /// - eMesh *writes* stream at ~1.5 cycles/hop fire-and-forget, while
    ///   reads are round trips an order of magnitude slower — modelled as
    ///   a cheap `store_post` against a doubled `noc_hop`;
    /// - the eLink sustains ~600 MB/s against a 600 MHz clock: ~16 cycles
    ///   of controller occupancy per 64 B line and a long DRAM latency;
    /// - the eMesh datapath is 8 B wide, so a line is 8 flits.
    pub const EPIPHANY16: LatencyParams = LatencyParams {
        // The Epiphany-III cores clock at 600 MHz (arXiv:1704.08343),
        // not the TILEPro's 860: a cycle-identical run is ~1.43x slower
        // in wall seconds.
        clock_hz: 600.0e6,
        l1_hit: 1,
        l2_hit: 4,
        noc_header: 3,
        noc_hop: 2,
        ddr: 300,
        store_post: 2,
        home_service: 1,
        ctrl_service: 16,
        link_service: 1,
        migration_cost: 30_000,
        compute_per_elem: 1,
        line_flits: 8,
    };

    /// Forward-looking 16×16 NUCA calibration for the nuca256 preset,
    /// which previously inherited the TILEPro numbers verbatim.
    /// Derivation (scaled from `TILEPRO64`, constants that are fixed in
    /// *time* re-expressed in cycles at the faster clock):
    ///
    /// - **clock**: a 256-core die implies a newer process node; we take
    ///   1.2 GHz (~1.4x the TILEPro's 860 MHz) as a conservative target.
    /// - **ddr**: DRAM latency is wall-time-bound. 88 cy @ 860 MHz
    ///   ≈ 102 ns ≈ 123 cy @ 1.2 GHz.
    /// - **ctrl_service**: per-line controller occupancy is
    ///   bandwidth-bound. 4 cy @ 860 MHz ≈ 4.7 ns ≈ 6 cy @ 1.2 GHz
    ///   (same DDR parts, more cycles each).
    /// - **noc_header**: the deeper 16×16 mesh needs an extra flit of
    ///   route header and deeper VC arbitration: 6 → 8 cycles.
    /// - **migration_cost**: OS work is wall-time-bound like DRAM:
    ///   30k cy @ 860 MHz ≈ 35 µs ≈ 42k cy @ 1.2 GHz.
    /// - on-chip SRAM and mesh pipelines scale with the clock, so
    ///   `l1_hit`/`l2_hit`/`noc_hop`/`link_service`/`home_service`/
    ///   `store_post` keep their cycle counts, and the 16 B mesh
    ///   datapath keeps `line_flits` at 4.
    pub const NUCA256: LatencyParams = LatencyParams {
        clock_hz: 1.2e9,
        l1_hit: 2,
        l2_hit: 8,
        noc_header: 8,
        noc_hop: 1,
        ddr: 123,
        store_post: 6,
        home_service: 2,
        ctrl_service: 6,
        link_service: 1,
        migration_cost: 42_000,
        compute_per_elem: 1,
        line_flits: 4,
    };

    /// Uncontended cycles for one cache-line access satisfied at `level`,
    /// requested from `req`, with hop counts taken on the TILEPro64
    /// preset's 8×8 grid. Matches `latency_model` in the L2 model (which
    /// is AOT-compiled for that grid); the engine uses the runtime-grid
    /// twin [`Machine::access_cycles`](crate::arch::Machine::access_cycles).
    #[inline]
    pub fn access_cycles(&self, req: TileId, level: HitLevel) -> u64 {
        match level {
            HitLevel::L1 => self.l1_hit,
            HitLevel::L2 => self.l2_hit,
            HitLevel::Home { home } => {
                self.l2_hit + self.noc_header + 2 * self.noc_hop * hops(req, home) as u64
            }
            HitLevel::Ddr { ctrl_attach } => {
                self.ddr + self.noc_header + 2 * self.noc_hop * hops(req, ctrl_attach) as u64
            }
        }
    }

    /// Convert simulated cycles to seconds at *this machine's* clock.
    #[inline]
    pub fn cycles_to_seconds(&self, cycles: u64) -> f64 {
        cycles as f64 / self.clock_hz
    }
}

/// Cache geometry. TILEPro64: 8 KB L1D (2-way), 64 KB unified L2 (4-way),
/// 64 B lines.
#[derive(Clone, Copy, Debug)]
pub struct CacheGeometry {
    pub l1_bytes: u64,
    pub l1_ways: usize,
    pub l2_bytes: u64,
    pub l2_ways: usize,
}

impl CacheGeometry {
    pub const TILEPRO64: CacheGeometry = CacheGeometry {
        l1_bytes: 8 * 1024,
        l1_ways: 2,
        l2_bytes: 64 * 1024,
        l2_ways: 4,
    };

    /// Epiphany-III local-memory stand-in: each core owns 32 KB of flat
    /// SRAM (no caches on the real chip), modelled here as a small
    /// register-file-like "L1" in front of the 32 KB bank so the shared
    /// cache-walk code applies unchanged.
    pub const EPIPHANY16: CacheGeometry = CacheGeometry {
        l1_bytes: 4 * 1024,
        l1_ways: 2,
        l2_bytes: 32 * 1024,
        l2_ways: 4,
    };

    pub fn l1_sets(&self) -> usize {
        (self.l1_bytes / LINE_BYTES) as usize / self.l1_ways
    }

    pub fn l2_sets(&self) -> usize {
        (self.l2_bytes / LINE_BYTES) as usize / self.l2_ways
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::topology::Coord;

    const P: LatencyParams = LatencyParams::TILEPRO64;

    #[test]
    fn l1_is_cheapest() {
        let t = TileId(0);
        let far = TileId::from_coord(Coord { x: 7, y: 7 });
        let l1 = P.access_cycles(t, HitLevel::L1);
        let l2 = P.access_cycles(t, HitLevel::L2);
        let l3 = P.access_cycles(t, HitLevel::Home { home: far });
        let ddr = P.access_cycles(t, HitLevel::Ddr { ctrl_attach: far });
        assert!(l1 < l2 && l2 < l3 && l3 < ddr);
    }

    #[test]
    fn home_hit_on_own_tile_still_pays_header() {
        // DDC: even a local-home "L3" lookup goes through the coherence
        // engine, so it costs more than a plain L2 hit.
        let t = TileId(9);
        let local_home = P.access_cycles(t, HitLevel::Home { home: t });
        assert_eq!(local_home, P.l2_hit + P.noc_header);
    }

    #[test]
    fn home_latency_scales_with_distance() {
        let t = TileId(0);
        let near = P.access_cycles(t, HitLevel::Home { home: TileId(1) });
        let far = P.access_cycles(
            t,
            HitLevel::Home { home: TileId::from_coord(Coord { x: 7, y: 7 }) },
        );
        assert_eq!(far - near, 2 * P.noc_hop * 13);
    }

    #[test]
    fn cycles_to_seconds_at_860mhz() {
        let s = P.cycles_to_seconds(860_000_000);
        assert!((s - 1.0).abs() < 1e-12);
    }

    #[test]
    fn geometry_sets() {
        let g = CacheGeometry::TILEPRO64;
        assert_eq!(g.l1_sets(), 64);
        assert_eq!(g.l2_sets(), 256);
    }

    #[test]
    fn epiphany_elink_is_the_bottleneck() {
        // arXiv:1704.08343: the single ~600 MB/s eLink, not the on-chip
        // eMesh, bounds off-chip traffic — controller occupancy per line
        // must dwarf both link occupancy and home service.
        let e = LatencyParams::EPIPHANY16;
        assert!(e.ctrl_service > 4 * e.link_service.max(e.home_service));
        // eMesh writes are fire-and-forget and cheaper than TILEPro's.
        assert!(e.store_post < LatencyParams::TILEPRO64.store_post);
        // 8 B eMesh datapath: twice the flits per line of the 16 B TILEPro.
        assert_eq!(e.line_flits, 2 * LatencyParams::TILEPRO64.line_flits);
        assert_eq!(CacheGeometry::EPIPHANY16.l2_sets(), 128);
    }

    #[test]
    fn per_machine_clocks() {
        // tilepro64 keeps the 860 MHz global constant (pinned JSON);
        // epiphany16 reports wall seconds at its real 600 MHz clock.
        assert_eq!(LatencyParams::TILEPRO64.clock_hz, CLOCK_HZ);
        let s = LatencyParams::EPIPHANY16.cycles_to_seconds(600_000_000);
        assert!((s - 1.0).abs() < 1e-12, "600M epiphany cycles must be 1 s");
        // The same cycle count is worth more wall time on the slower chip.
        let cycles = 1_000_000;
        assert!(
            LatencyParams::EPIPHANY16.cycles_to_seconds(cycles)
                > LatencyParams::TILEPRO64.cycles_to_seconds(cycles)
        );
    }

    #[test]
    fn nuca256_scales_wall_time_bound_constants() {
        let n = LatencyParams::NUCA256;
        let t = LatencyParams::TILEPRO64;
        assert!(n.clock_hz > t.clock_hz);
        // Wall-time-bound terms must take *more* cycles at the faster
        // clock (same nanoseconds): DRAM latency, controller occupancy,
        // migration cost.
        assert!(n.ddr > t.ddr && n.ctrl_service > t.ctrl_service);
        assert!(n.migration_cost > t.migration_cost);
        // DRAM wall latency is preserved within a cycle of rounding.
        let wall = |p: &LatencyParams, cy: u64| cy as f64 / p.clock_hz;
        assert!((wall(&n, n.ddr) - wall(&t, t.ddr)).abs() < 1.5 / t.clock_hz);
        // Clock-scaled pipelines keep their cycle counts.
        assert_eq!((n.l1_hit, n.l2_hit, n.noc_hop), (t.l1_hit, t.l2_hit, t.noc_hop));
        assert_eq!(n.line_flits, t.line_flits);
        // Deeper mesh: more header overhead.
        assert!(n.noc_header > t.noc_header);
    }

    #[test]
    fn matches_python_constants() {
        // Mirror of python/compile/model.py — change both together.
        assert_eq!(P.l1_hit, 2);
        assert_eq!(P.l2_hit, 8);
        assert_eq!(P.noc_header, 6);
        assert_eq!(P.noc_hop, 1);
        assert_eq!(P.ddr, 88);
    }
}
