//! Tile/coordinate primitives shared by every machine, plus the TILEPro64
//! preset's grid constants and helpers.
//!
//! Simulation code sizes everything off the runtime
//! [`Machine`](crate::arch::Machine) description; the constants and the
//! free helpers below (`TileId::coord`, `hops`, `controllers`,
//! `nearest_controller`) are pinned to the TILEPro64 preset's 8×8 grid and
//! survive only as that preset's values — used by `arch` itself, by the
//! AOT'd analytical latency model (compiled for the TILEPro64), and by
//! tests.

/// TILEPro64 preset: mesh width (tiles per row).
pub const GRID_W: u32 = 8;
/// TILEPro64 preset: mesh height (rows).
pub const GRID_H: u32 = 8;
/// TILEPro64 preset: total tiles. Tile Linux reserves one tile for itself,
/// so user code gets at most `NUM_TILES - 1 = 63` worker threads — the
/// paper's "maximum numbers of cores available".
pub const NUM_TILES: u32 = GRID_W * GRID_H;
/// TILEPro64 preset: number of DDR memory controllers.
pub const NUM_CONTROLLERS: u32 = 4;

/// A tile (core) id in row-major order: `id = y * GRID_W + x`.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Debug)]
pub struct TileId(pub u32);

/// Mesh coordinates.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub struct Coord {
    pub x: u32,
    pub y: u32,
}

/// A directed mesh-link direction. Each tile owns up to four outgoing
/// links; `Machine::link_index` densely numbers them for the contention
/// model's per-link servers.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub enum Dir {
    East,
    West,
    North,
    South,
}

impl Dir {
    pub const ALL: [Dir; 4] = [Dir::East, Dir::West, Dir::North, Dir::South];

    #[inline]
    pub fn index(self) -> usize {
        match self {
            Dir::East => 0,
            Dir::West => 1,
            Dir::North => 2,
            Dir::South => 3,
        }
    }

    pub fn letter(self) -> char {
        match self {
            Dir::East => 'E',
            Dir::West => 'W',
            Dir::North => 'N',
            Dir::South => 'S',
        }
    }
}

impl TileId {
    /// Coordinates on the TILEPro64 preset's 8×8 grid. Machine-aware code
    /// must use [`Machine::coord`](crate::arch::Machine::coord) instead.
    #[inline]
    pub fn coord(self) -> Coord {
        Coord {
            x: self.0 % GRID_W,
            y: self.0 / GRID_W,
        }
    }

    #[inline]
    pub fn from_coord(c: Coord) -> TileId {
        debug_assert!(c.x < GRID_W && c.y < GRID_H);
        TileId(c.y * GRID_W + c.x)
    }

    #[inline]
    pub fn index(self) -> usize {
        self.0 as usize
    }

    pub fn all() -> impl Iterator<Item = TileId> {
        (0..NUM_TILES).map(TileId)
    }
}

/// XY dimension-order routing hop count == Manhattan distance, on the
/// TILEPro64 preset's grid (the AOT'd latency model is compiled against
/// this 8×8 layout). Machine-aware code uses `Machine::hops`, which agrees
/// with this for the default machine by construction.
#[inline]
pub fn hops(a: TileId, b: TileId) -> u32 {
    let ca = a.coord();
    let cb = b.coord();
    ca.x.abs_diff(cb.x) + ca.y.abs_diff(cb.y)
}

/// A memory controller and its mesh attach point.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Controller {
    pub id: u32,
    /// The tile whose mesh port the controller hangs off.
    pub attach: TileId,
}

/// TILEPro64 places two controllers on the top edge and two on the bottom;
/// we attach them at columns 2 and 5 of rows 0 and 7. Rows 0–3 are
/// therefore "near" controllers 0/1 and far from 2/3 — the asymmetry behind
/// the paper's Fig. 4 striping discussion.
pub fn controllers() -> [Controller; NUM_CONTROLLERS as usize] {
    [
        Controller { id: 0, attach: TileId::from_coord(Coord { x: 2, y: 0 }) },
        Controller { id: 1, attach: TileId::from_coord(Coord { x: 5, y: 0 }) },
        Controller { id: 2, attach: TileId::from_coord(Coord { x: 2, y: 7 }) },
        Controller { id: 3, attach: TileId::from_coord(Coord { x: 5, y: 7 }) },
    ]
}

/// Nearest controller to a tile (used for non-striped page placement: the
/// hypervisor allocates a page's DRAM behind one controller, picked by
/// proximity to the allocating/homing tile).
pub fn nearest_controller(t: TileId) -> Controller {
    let cs = controllers();
    *cs.iter()
        .min_by_key(|c| (hops(t, c.attach), c.id))
        .expect("non-empty controller set")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn coord_round_trip() {
        for t in TileId::all() {
            assert_eq!(TileId::from_coord(t.coord()), t);
        }
    }

    #[test]
    fn hops_is_manhattan() {
        let a = TileId::from_coord(Coord { x: 0, y: 0 });
        let b = TileId::from_coord(Coord { x: 7, y: 7 });
        assert_eq!(hops(a, b), 14);
        assert_eq!(hops(a, a), 0);
        assert_eq!(hops(a, b), hops(b, a));
    }

    #[test]
    fn hops_triangle_inequality() {
        let a = TileId(3);
        let b = TileId(42);
        let c = TileId(60);
        assert!(hops(a, c) <= hops(a, b) + hops(b, c));
    }

    #[test]
    fn sixty_four_tiles() {
        assert_eq!(TileId::all().count(), 64);
    }

    #[test]
    fn controllers_attach_to_edges() {
        for c in controllers() {
            let y = c.attach.coord().y;
            assert!(y == 0 || y == GRID_H - 1);
        }
    }

    #[test]
    fn upper_rows_map_to_top_controllers() {
        // The paper: threads on rows 0..3 (cores 0..31) only reach the two
        // top controllers in non-striping mode.
        for t in TileId::all().filter(|t| t.coord().y < 4) {
            assert!(nearest_controller(t).id < 2, "tile {t:?}");
        }
        for t in TileId::all().filter(|t| t.coord().y >= 4) {
            assert!(nearest_controller(t).id >= 2, "tile {t:?}");
        }
    }

    #[test]
    fn nearest_controller_is_deterministic_tiebreak() {
        // Column 3.5 midpoint ties are broken by controller id.
        for t in TileId::all() {
            let c1 = nearest_controller(t);
            let c2 = nearest_controller(t);
            assert_eq!(c1, c2);
        }
    }
}
