//! Runtime machine description: *which* NUCA grid we are simulating.
//!
//! The seed simulator baked the TILEPro64 into compile-time constants
//! (`GRID_W`/`GRID_H`/`NUM_TILES`/`NUM_CONTROLLERS`), so it could only ever
//! reproduce one chip. [`Machine`] makes the chip a runtime value — grid
//! dimensions, memory-controller placement, and the latency/geometry
//! parameter sets — constructed once (usually from a [`MachineSpec`]
//! preset or a `WxH:ctrls` CLI spec) and threaded through every layer:
//! homing hashes, striping, sharer bitsets, the NoC servers, schedulers,
//! the replay engine, and the heatmap renderers.
//!
//! The old constants survive only as the [`MachineSpec::TilePro64`]
//! preset's values; `--machine tilepro64` (the default) reproduces the
//! seed's figure JSON byte-identically.

use std::sync::Arc;

use super::fabric::{CtrlPlacement, Fabric, FabricError, FabricSpec};
use super::params::{CacheGeometry, LatencyParams};
use super::topology::{controllers, Controller, Coord, Dir, TileId};

/// A parseable, copyable selector for a [`Machine`] — what a `RunSpec`
/// carries across the batch pool and what `--machine` parses into.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum MachineSpec {
    /// The paper's evaluation platform: 8×8 mesh, 4 edge controllers.
    #[default]
    TilePro64,
    /// Adapteva Epiphany-III-shaped grid (Richie et al., arXiv:1704.08343):
    /// 4×4 RISC array with a single external-memory link on the east edge.
    Epiphany16,
    /// A forward-looking 16×16 NUCA grid with 8 edge controllers — the
    /// "future manycore" the paper pitches localisation for.
    Nuca256,
    /// Arbitrary `WxH:ctrls` grid with evenly spaced edge controllers.
    Custom { w: u32, h: u32, ctrls: u32 },
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum MachineError {
    BadSpec(String),
    BadGrid { w: u32, h: u32 },
    BadControllers { ctrls: u32, w: u32, h: u32 },
}

impl std::fmt::Display for MachineError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            MachineError::BadSpec(s) => write!(
                f,
                "bad machine spec '{s}' (want tilepro64 | epiphany16 | nuca256 | WxH | WxH:ctrls)"
            ),
            MachineError::BadGrid { w, h } => {
                write!(f, "bad grid {w}x{h}: want 1 <= W,H <= 64")
            }
            MachineError::BadControllers { ctrls, w, h } => write!(
                f,
                "bad controller count {ctrls} for a {w}x{h} grid: want 1..={}",
                Machine::controller_capacity(*w, *h)
            ),
        }
    }
}

impl std::error::Error for MachineError {}

impl MachineSpec {
    /// Parse a `--machine` argument: a preset name or `WxH[:ctrls]`.
    ///
    /// # Examples
    ///
    /// ```
    /// use tilesim::arch::MachineSpec;
    ///
    /// // Presets round-trip through their labels…
    /// assert_eq!(MachineSpec::parse("epiphany16").unwrap().label(), "epiphany16");
    ///
    /// // …and `WxH:ctrls` builds an arbitrary mesh.
    /// let spec = MachineSpec::parse("4x8:2").unwrap();
    /// let machine = spec.build();
    /// assert_eq!((machine.grid_w(), machine.grid_h()), (4, 8));
    /// assert_eq!(machine.num_controllers(), 2);
    ///
    /// // Out-of-range grids are rejected at parse time.
    /// assert!(MachineSpec::parse("65x4").is_err());
    /// ```
    pub fn parse(s: &str) -> Result<MachineSpec, MachineError> {
        match s {
            "tilepro64" => return Ok(MachineSpec::TilePro64),
            "epiphany16" => return Ok(MachineSpec::Epiphany16),
            "nuca256" => return Ok(MachineSpec::Nuca256),
            _ => {}
        }
        let (grid, ctrls) = match s.split_once(':') {
            Some((g, c)) => {
                let ctrls = c
                    .parse::<u32>()
                    .map_err(|_| MachineError::BadSpec(s.to_string()))?;
                (g, Some(ctrls))
            }
            None => (s, None),
        };
        let (w, h) = grid
            .split_once('x')
            .ok_or_else(|| MachineError::BadSpec(s.to_string()))?;
        let w = w
            .parse::<u32>()
            .map_err(|_| MachineError::BadSpec(s.to_string()))?;
        let h = h
            .parse::<u32>()
            .map_err(|_| MachineError::BadSpec(s.to_string()))?;
        let ctrls = ctrls.unwrap_or_else(|| 4.min(Machine::controller_capacity(w, h.max(1))));
        Machine::validate(w, h, ctrls)?;
        Ok(MachineSpec::Custom { w, h, ctrls })
    }

    /// Stable label used in run-spec JSON and table titles.
    pub fn label(self) -> String {
        match self {
            MachineSpec::TilePro64 => "tilepro64".into(),
            MachineSpec::Epiphany16 => "epiphany16".into(),
            MachineSpec::Nuca256 => "nuca256".into(),
            MachineSpec::Custom { w, h, ctrls } => format!("{w}x{h}:{ctrls}"),
        }
    }

    /// Materialise the description. Presets are valid by construction;
    /// `Custom` was validated at parse time (and is re-checked here).
    pub fn build(self) -> Machine {
        match self {
            MachineSpec::TilePro64 => Machine::tilepro64(),
            MachineSpec::Epiphany16 => Machine::epiphany16(),
            MachineSpec::Nuca256 => Machine::nuca256(),
            MachineSpec::Custom { w, h, ctrls } => {
                Machine::custom(w, h, ctrls).expect("validated at parse time")
            }
        }
    }

    /// Shared handle, the form every subsystem holds.
    pub fn build_arc(self) -> Arc<Machine> {
        Arc::new(self.build())
    }

    /// Build the machine with an optional [`FabricSpec`] applied — the
    /// one place the machine+fabric pairing is materialised (the batch
    /// executor, the homing driver, and the CLI heatmaps all call this).
    /// Errors when the fabric does not fit this machine.
    pub fn build_with_fabric(
        self,
        fabric: Option<&FabricSpec>,
    ) -> Result<Arc<Machine>, FabricError> {
        let m = self.build();
        Ok(Arc::new(match fabric {
            Some(f) => m.with_fabric(f)?,
            None => m,
        }))
    }
}

/// The simulated chip, as a runtime value. All topology questions
/// (coordinates, hop counts, controller proximity, link indices) go
/// through this; latency arithmetic that depends on distance lives here
/// too ([`Machine::access_cycles`]).
#[derive(Clone, Debug)]
pub struct Machine {
    spec: MachineSpec,
    grid_w: u32,
    grid_h: u32,
    controllers: Vec<Controller>,
    /// Per-directed-link service times. Uniform at the scalar
    /// `params.link_service` unless a [`FabricSpec`] was applied.
    fabric: Fabric,
    pub params: LatencyParams,
    pub geometry: CacheGeometry,
}

impl Machine {
    /// Distinct attach columns per edge: a single-row grid has only one
    /// edge, so it holds W controllers; taller grids hold W per edge.
    fn controller_capacity(w: u32, h: u32) -> u32 {
        if h == 1 {
            w
        } else {
            2 * w
        }
    }

    fn validate(w: u32, h: u32, ctrls: u32) -> Result<(), MachineError> {
        if w == 0 || h == 0 || w > 64 || h > 64 {
            return Err(MachineError::BadGrid { w, h });
        }
        if ctrls == 0 || ctrls > Machine::controller_capacity(w, h) {
            return Err(MachineError::BadControllers { ctrls, w, h });
        }
        Ok(())
    }

    /// The paper's evaluation platform. Grid, controller attach points,
    /// latencies, and cache geometry are exactly the seed's compile-time
    /// constants, so the default machine replays byte-identically.
    pub fn tilepro64() -> Machine {
        Machine {
            spec: MachineSpec::TilePro64,
            grid_w: 8,
            grid_h: 8,
            controllers: controllers().to_vec(),
            fabric: Fabric::uniform(4 * 64, LatencyParams::TILEPRO64.link_service),
            params: LatencyParams::TILEPRO64,
            geometry: CacheGeometry::TILEPRO64,
        }
    }

    /// Epiphany-III-shaped 4×4 array: one external-memory link on the east
    /// edge (middle row), as in the Parallella's eLink, with
    /// eLink/eMesh-calibrated latency and local-memory geometry
    /// ([`LatencyParams::EPIPHANY16`], per Richie et al., arXiv:1704.08343)
    /// rather than the TILEPro numbers the preset originally reused.
    pub fn epiphany16() -> Machine {
        Machine {
            spec: MachineSpec::Epiphany16,
            grid_w: 4,
            grid_h: 4,
            controllers: vec![Controller {
                id: 0,
                attach: TileId(7), // (x=3, y=1): east edge, middle row
            }],
            fabric: Fabric::uniform(4 * 16, LatencyParams::EPIPHANY16.link_service),
            params: LatencyParams::EPIPHANY16,
            geometry: CacheGeometry::EPIPHANY16,
        }
    }

    /// A 16×16 forward-looking NUCA grid with 8 edge controllers, carrying
    /// its own scaled [`LatencyParams::NUCA256`] (1.2 GHz clock,
    /// wall-time-bound DRAM constants re-expressed in cycles) instead of
    /// silently inheriting the TILEPro numbers.
    pub fn nuca256() -> Machine {
        let mut m = Machine::custom_with_spec(16, 16, 8, MachineSpec::Nuca256)
            .expect("nuca256 preset is valid");
        m.params = LatencyParams::NUCA256;
        m.fabric = Fabric::uniform(m.num_links(), m.params.link_service);
        m
    }

    /// Arbitrary grid. Controllers alternate between the top and bottom
    /// edges (top gets the extra one when odd) at evenly spaced columns —
    /// the generalisation of the TILEPro64's 2-top/2-bottom placement.
    pub fn custom(w: u32, h: u32, ctrls: u32) -> Result<Machine, MachineError> {
        Machine::custom_with_spec(w, h, ctrls, MachineSpec::Custom { w, h, ctrls })
    }

    fn custom_with_spec(
        w: u32,
        h: u32,
        ctrls: u32,
        spec: MachineSpec,
    ) -> Result<Machine, MachineError> {
        Machine::validate(w, h, ctrls)?;
        // The default placement: evenly spaced top/bottom edge columns
        // (a single-row grid has one edge, all controllers on it at
        // distinct columns) — exactly the pre-fabric construction, now
        // shared with the placement-strategy ablation.
        let cs = CtrlPlacement::EdgesEven
            .controllers(w, h, ctrls)
            .expect("validated above: EdgesEven capacity == controller_capacity");
        Ok(Machine {
            spec,
            grid_w: w,
            grid_h: h,
            controllers: cs,
            fabric: Fabric::uniform(
                (4 * w * h) as usize,
                LatencyParams::TILEPRO64.link_service,
            ),
            params: LatencyParams::TILEPRO64,
            geometry: CacheGeometry::TILEPRO64,
        })
    }

    /// A `w`×`h` sub-grid *view* of this machine, the replay target of one
    /// spatial partition ([`crate::arch::PartitionSpec`]): the partition's
    /// dimensions, this machine's latency/geometry parameter set (clock
    /// included — a quadrant of a nuca256 keeps nuca256 physics, not the
    /// `Custom`-machine TILEPro defaults), a proportional share of this
    /// machine's controllers placed `EdgesEven` (the partition's own
    /// homing/memory domain), and a uniform fabric at the scalar
    /// `link_service` (partition replays never carry a heterogeneous
    /// fabric). The view is a pure function of `(w, h)` and this machine —
    /// positions don't enter — which is what lets the serve dispatcher
    /// memoise service times per partition *shape*. The full-grid view is
    /// this machine itself, so a whole-chip partition replays
    /// byte-identically to an unpartitioned run.
    pub fn subgrid_view(&self, w: u32, h: u32) -> Result<Machine, MachineError> {
        if (w, h) == (self.grid_w, self.grid_h) {
            return Ok(self.clone());
        }
        let share = self.num_controllers() as u64 * (w * h) as u64;
        let ctrls = (share.div_ceil(self.num_tiles() as u64) as u32)
            .clamp(1, Machine::controller_capacity(w, h));
        Machine::validate(w, h, ctrls)?;
        let cs = CtrlPlacement::EdgesEven
            .controllers(w, h, ctrls)
            .expect("validated above: EdgesEven capacity == controller_capacity");
        Ok(Machine {
            spec: MachineSpec::Custom { w, h, ctrls },
            grid_w: w,
            grid_h: h,
            controllers: cs,
            fabric: Fabric::uniform((4 * w * h) as usize, self.params.link_service),
            params: self.params.clone(),
            geometry: self.geometry,
        })
    }

    /// Re-derive this machine with a [`FabricSpec`] applied: the
    /// controller list is rebuilt when the spec names a placement (named
    /// strategies keep this machine's controller count, so striping stays
    /// comparable; an explicit tile list sets its own count), and the
    /// per-link service table is rebuilt from the spec's base and region
    /// rules. A leading machine clause in the spec is ignored here —
    /// split it off with [`FabricSpec::split_machine`] first.
    pub fn with_fabric(&self, spec: &FabricSpec) -> Result<Machine, FabricError> {
        let mut m = self.clone();
        if let Some(p) = &spec.ctrl {
            m.controllers = p.controllers(m.grid_w, m.grid_h, m.num_controllers())?;
        }
        m.fabric = spec.build_table(&m)?;
        Ok(m)
    }

    pub fn spec(&self) -> MachineSpec {
        self.spec
    }

    pub fn name(&self) -> String {
        self.spec.label()
    }

    #[inline]
    pub fn grid_w(&self) -> u32 {
        self.grid_w
    }

    #[inline]
    pub fn grid_h(&self) -> u32 {
        self.grid_h
    }

    #[inline]
    pub fn num_tiles(&self) -> u32 {
        self.grid_w * self.grid_h
    }

    #[inline]
    pub fn num_controllers(&self) -> u32 {
        self.controllers.len() as u32
    }

    pub fn controllers(&self) -> &[Controller] {
        &self.controllers
    }

    #[inline]
    pub fn controller(&self, id: u32) -> Controller {
        self.controllers[id as usize]
    }

    /// Mesh coordinates of a tile on *this* grid (row-major ids).
    #[inline]
    pub fn coord(&self, t: TileId) -> Coord {
        debug_assert!(t.0 < self.num_tiles(), "tile {t:?} out of range");
        Coord {
            x: t.0 % self.grid_w,
            y: t.0 / self.grid_w,
        }
    }

    /// Tile at mesh coordinates on this grid.
    #[inline]
    pub fn tile_at(&self, c: Coord) -> TileId {
        debug_assert!(c.x < self.grid_w && c.y < self.grid_h, "coord {c:?} out of range");
        TileId(c.y * self.grid_w + c.x)
    }

    pub fn tiles(&self) -> impl Iterator<Item = TileId> {
        (0..self.num_tiles()).map(TileId)
    }

    /// XY dimension-order hop count == Manhattan distance on this grid.
    #[inline]
    pub fn hops(&self, a: TileId, b: TileId) -> u32 {
        let ca = self.coord(a);
        let cb = self.coord(b);
        ca.x.abs_diff(cb.x) + ca.y.abs_diff(cb.y)
    }

    /// Nearest controller by mesh distance, id as the deterministic
    /// tiebreak (non-striped page placement).
    pub fn nearest_controller(&self, t: TileId) -> Controller {
        *self
            .controllers
            .iter()
            .min_by_key(|c| (self.hops(t, c.attach), c.id))
            .expect("non-empty controller set")
    }

    /// Uncontended cycles for one cache-line access satisfied at `level`,
    /// requested from `req` — the distance-dependent latency arithmetic,
    /// on this machine's grid. (The tilepro64-pinned twin used by the AOT
    /// latency model is `LatencyParams::access_cycles`.)
    #[inline]
    pub fn access_cycles(&self, req: TileId, level: super::params::HitLevel) -> u64 {
        use super::params::HitLevel;
        let p = &self.params;
        match level {
            HitLevel::L1 => p.l1_hit,
            HitLevel::L2 => p.l2_hit,
            HitLevel::Home { home } => {
                p.l2_hit + p.noc_header + 2 * p.noc_hop * self.hops(req, home) as u64
            }
            HitLevel::Ddr { ctrl_attach } => {
                p.ddr + p.noc_header + 2 * p.noc_hop * self.hops(req, ctrl_attach) as u64
            }
        }
    }

    /// Number of directional mesh-link servers: every tile has up to four
    /// outgoing links (E/W/N/S); edge slots exist but never see traffic.
    #[inline]
    pub fn num_links(&self) -> usize {
        4 * self.num_tiles() as usize
    }

    /// The per-link service-time table ([`Fabric`]) of this machine.
    #[inline]
    pub fn fabric(&self) -> &Fabric {
        &self.fabric
    }

    /// Whether the directed link leaving `from` towards `dir` physically
    /// exists (a neighbour tile is there). Off-grid boundary slots have
    /// table entries and servers but never carry traffic; reporting code
    /// should skip them.
    #[inline]
    pub fn has_link(&self, from: TileId, dir: Dir) -> bool {
        let c = self.coord(from);
        match dir {
            Dir::East => c.x + 1 < self.grid_w,
            Dir::West => c.x > 0,
            Dir::North => c.y > 0,
            Dir::South => c.y + 1 < self.grid_h,
        }
    }

    /// Dense index of the directed link leaving `from` towards `dir`.
    #[inline]
    pub fn link_index(&self, from: TileId, dir: Dir) -> usize {
        dir.index() * self.num_tiles() as usize + from.index()
    }

    /// Human-readable link name, e.g. `E(3,1)` (for heatmaps/JSON).
    pub fn link_label(&self, index: usize) -> String {
        let n = self.num_tiles() as usize;
        let dir = Dir::ALL[index / n];
        let c = self.coord(TileId((index % n) as u32));
        format!("{}({},{})", dir.letter(), c.x, c.y)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::params::HitLevel;
    use crate::arch::topology::{hops, nearest_controller};

    #[test]
    fn tilepro64_matches_seed_constants() {
        let m = Machine::tilepro64();
        assert_eq!((m.grid_w(), m.grid_h(), m.num_tiles()), (8, 8, 64));
        assert_eq!(m.num_controllers(), 4);
        assert_eq!(m.controllers(), &controllers()[..]);
        // Topology answers agree with the compile-time helpers.
        for a in m.tiles() {
            assert_eq!(m.coord(a), a.coord());
            assert_eq!(m.tile_at(m.coord(a)), a);
            assert_eq!(m.nearest_controller(a), nearest_controller(a));
            for b in [TileId(0), TileId(9), TileId(63)] {
                assert_eq!(m.hops(a, b), hops(a, b));
                assert_eq!(
                    m.access_cycles(a, HitLevel::Home { home: b }),
                    m.params.access_cycles(a, HitLevel::Home { home: b })
                );
            }
        }
    }

    #[test]
    fn spec_parse_round_trips() {
        for s in ["tilepro64", "epiphany16", "nuca256"] {
            let spec = MachineSpec::parse(s).unwrap();
            assert_eq!(spec.label(), s);
            assert_eq!(spec.build().name(), s);
        }
        let spec = MachineSpec::parse("4x8:2").unwrap();
        assert_eq!(spec, MachineSpec::Custom { w: 4, h: 8, ctrls: 2 });
        assert_eq!(spec.label(), "4x8:2");
        // Controller count defaults to min(4, 2*W).
        assert_eq!(
            MachineSpec::parse("2x3").unwrap(),
            MachineSpec::Custom { w: 2, h: 3, ctrls: 4 }
        );
    }

    #[test]
    fn bad_specs_are_rejected() {
        let bad = [
            "", "weird", "0x4", "4x0", "65x4", "4x4:0", "4x4:99", "4x", "x4", "axb", "4x1:5",
        ];
        for s in bad {
            assert!(MachineSpec::parse(s).is_err(), "spec '{s}' should fail");
        }
    }

    #[test]
    fn single_row_grid_has_distinct_attach_points() {
        // h == 1: one edge only — controllers must not stack on the same
        // tile (that would double the modelled DRAM bandwidth there).
        let m = Machine::custom(4, 1, 2).unwrap();
        let attaches: std::collections::HashSet<_> =
            m.controllers().iter().map(|c| c.attach).collect();
        assert_eq!(attaches.len(), 2, "{:?}", m.controllers());
        assert!(Machine::custom(4, 1, 4).is_ok());
        assert!(Machine::custom(4, 1, 5).is_err(), "capacity is W on one row");
        // Default controller count respects the single-edge capacity.
        assert_eq!(
            MachineSpec::parse("2x1").unwrap(),
            MachineSpec::Custom { w: 2, h: 1, ctrls: 2 }
        );
    }

    #[test]
    fn custom_controllers_sit_on_edges() {
        let m = Machine::custom(5, 7, 5).unwrap();
        assert_eq!(m.num_controllers(), 5);
        let mut ids: Vec<u32> = m.controllers().iter().map(|c| c.id).collect();
        ids.dedup();
        assert_eq!(ids.len(), 5, "controller ids must be distinct");
        for c in m.controllers() {
            let y = m.coord(c.attach).y;
            assert!(y == 0 || y == m.grid_h() - 1, "{c:?} not on an edge row");
            assert!(c.attach.0 < m.num_tiles());
        }
    }

    #[test]
    fn non_square_coords_round_trip() {
        let m = Machine::custom(4, 8, 2).unwrap();
        assert_eq!(m.num_tiles(), 32);
        for t in m.tiles() {
            assert_eq!(m.tile_at(m.coord(t)), t);
        }
        // Row-major: tile 5 of a 4-wide grid is (1, 1).
        assert_eq!(m.coord(TileId(5)), Coord { x: 1, y: 1 });
        assert_eq!(m.hops(TileId(0), TileId(31)), 3 + 7);
    }

    #[test]
    fn epiphany16_has_one_east_link() {
        let m = Machine::epiphany16();
        assert_eq!((m.num_tiles(), m.num_controllers()), (16, 1));
        let c = m.controllers()[0];
        assert_eq!(m.coord(c.attach), Coord { x: 3, y: 1 });
        // Every tile resolves to the single controller.
        for t in m.tiles() {
            assert_eq!(m.nearest_controller(t).id, 0);
        }
    }

    #[test]
    fn presets_carry_uniform_fabric_and_their_own_clock() {
        for m in [Machine::tilepro64(), Machine::epiphany16(), Machine::nuca256()] {
            assert_eq!(
                m.fabric().uniform_service(),
                Some(m.params.link_service),
                "{} fabric must default to the scalar link_service",
                m.name()
            );
            assert_eq!(m.fabric().num_links(), m.num_links());
        }
        assert_eq!(Machine::epiphany16().params.clock_hz, 600.0e6);
        assert_eq!(Machine::nuca256().params.ddr, LatencyParams::NUCA256.ddr);
    }

    #[test]
    fn with_fabric_rebuilds_controllers_and_table() {
        use crate::arch::fabric::{CtrlPlacement, FabricSpec};
        let m = Machine::tilepro64();
        let spec = FabricSpec::parse("ctrl=corners:base=4:express-row=0@0.5").unwrap();
        let f = m.with_fabric(&spec).unwrap();
        // Same count, corner attach points.
        assert_eq!(f.num_controllers(), 4);
        let attaches: Vec<u32> = f.controllers().iter().map(|c| c.attach.0).collect();
        assert_eq!(attaches, vec![0, 63, 7, 56]);
        // Row 0 east/west at 2, everything else at 4.
        assert_eq!(f.fabric().service(f.link_index(TileId(0), Dir::East)), 2);
        assert_eq!(f.fabric().service(f.link_index(TileId(8), Dir::East)), 4);
        // The base machine is untouched.
        assert_eq!(m.fabric().uniform_service(), Some(1));
        assert_eq!(m.nearest_controller(TileId(0)).attach, TileId(2));
        assert_eq!(f.nearest_controller(TileId(0)).attach, TileId(0));
        // Incompatible specs are rejected, not applied.
        assert!(m
            .with_fabric(&FabricSpec::parse("express-row=8@0.5").unwrap())
            .is_err());
        assert!(m
            .with_fabric(&FabricSpec {
                ctrl: Some(CtrlPlacement::Corners),
                ..FabricSpec::default()
            })
            .is_ok());
        // 8 controllers cannot sit on 4 corners.
        let eight = Machine::custom(16, 16, 8).unwrap();
        assert!(eight
            .with_fabric(&FabricSpec {
                ctrl: Some(CtrlPlacement::Corners),
                ..FabricSpec::default()
            })
            .is_err());
    }

    #[test]
    fn link_indices_are_dense_and_distinct() {
        let m = Machine::custom(3, 2, 1).unwrap();
        let mut seen = std::collections::HashSet::new();
        for t in m.tiles() {
            for dir in Dir::ALL {
                let ix = m.link_index(t, dir);
                assert!(ix < m.num_links());
                assert!(seen.insert(ix), "duplicate link index {ix}");
            }
        }
        assert_eq!(seen.len(), m.num_links());
        assert_eq!(m.link_label(m.link_index(TileId(4), Dir::North)), "N(1,1)");
    }
}
