//! Architecture description of the simulated manycore.
//!
//! [`Machine`] is the runtime machine description — grid dimensions,
//! memory-controller placement, the heterogeneous link [`Fabric`], latency
//! (including the per-machine clock) and cache-geometry parameters — that
//! every simulation layer is parameterised by. [`topology`] holds the
//! tile/coordinate primitives plus the TILEPro64 preset's constants (which
//! survive only as that preset's values); [`params`] holds the latency and
//! capacity parameter sets; [`fabric`] holds the per-link service tables,
//! controller-placement strategies, and the `FabricSpec` parser;
//! [`partition`] carves a machine into disjoint rectangular sub-grids
//! (the spatial multi-server serving domains).

pub mod fabric;
pub mod machine;
pub mod params;
pub mod partition;
pub mod topology;

pub use fabric::{CtrlPlacement, Fabric, FabricError, FabricSpec, LinkRegion, LinkRule};
pub use machine::{Machine, MachineError, MachineSpec};
pub use partition::{Partition, PartitionError, PartitionSpec, Rect};
pub use params::{CacheGeometry, HitLevel, LatencyParams, CLOCK_HZ, LINE_BYTES, PAGE_BYTES};
pub use topology::{
    controllers, hops, nearest_controller, Controller, Coord, Dir, TileId, GRID_H, GRID_W,
    NUM_CONTROLLERS, NUM_TILES,
};
