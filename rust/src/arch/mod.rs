//! Architecture description of the simulated manycore: the 8×8 tile mesh,
//! memory-controller placement, and the latency/capacity parameter set.

pub mod params;
pub mod topology;

pub use params::{CacheGeometry, HitLevel, LatencyParams, CLOCK_HZ, LINE_BYTES, PAGE_BYTES};
pub use topology::{
    controllers, hops, nearest_controller, Controller, Coord, TileId, GRID_H, GRID_W,
    NUM_CONTROLLERS, NUM_TILES,
};
